"""Shared helpers for the paper-figure benchmarks.

Scale presets: the container is a single CPU core, so 'quick' uses a GPT-nano
(2L x 64d) on short synthetic streams — the paper's *qualitative* claims (SNR
orderings, LR/init/vocab effects, optimizer gaps) reproduce at this scale
(App. H shows rule transfer across widths); 'full' matches the paper's
GPT-small recipe and is what one would run on real hardware.
"""
from __future__ import annotations

import csv
from pathlib import Path
from typing import Any, Dict, List, Optional

import jax.numpy as jnp

from repro.data import DataConfig, ZipfLM
from repro.models import LayerSlot, ModelConfig
from repro.train import Trainer, TrainerConfig

RESULTS = Path(__file__).resolve().parent / "results"


def gpt_nano(vocab: int = 128, width: int = 64, layers: int = 2, heads: int = 4) -> ModelConfig:
    return ModelConfig(
        name=f"gpt_nano_w{width}", n_layers=layers, d_model=width,
        n_heads=heads, n_kv_heads=heads, d_ff=4 * width, vocab_size=vocab,
        gated_mlp=False, pattern=(LayerSlot("attn", "dense"),),
        pos="learned", max_position=128, norm="layernorm",
        tie_embeddings=True, init_scheme="mitchell",
        dtype=jnp.float32, remat=False,
    )


def nano_data(cfg: ModelConfig, *, seq: int = 32, batch: int = 8, alpha: float = 1.2,
              seed: int = 0) -> ZipfLM:
    return ZipfLM(DataConfig(vocab_size=cfg.vocab_size, seq_len=seq, global_batch=batch,
                             alpha=alpha, seed=seed))


def train_once(cfg, optimizer: str, lr: float, *, steps: int, data: Optional[ZipfLM] = None,
               measure_snr: bool = False, rules=None, seed: int = 0,
               snr_every: int = 20) -> Trainer:
    data = data or nano_data(cfg, seed=seed)
    tc = TrainerConfig(total_steps=steps, log_every=max(steps // 4, 1), seed=seed,
                       measure_snr=measure_snr, snr_early_every=snr_every,
                       snr_late_every=snr_every * 10)
    tr = Trainer(cfg, optimizer, lr, data, tc, rules=rules)
    tr.run()
    return tr


def write_csv(name: str, rows: List[Dict[str, Any]]):
    RESULTS.mkdir(parents=True, exist_ok=True)
    path = RESULTS / name
    if not rows:
        return path
    with open(path, "w", newline="") as f:
        w = csv.DictWriter(f, fieldnames=list(rows[0].keys()))
        w.writeheader()
        w.writerows(rows)
    return path


def emit(name: str, us_per_call: float, derived: str):
    """The harness contract: one ``name,us_per_call,derived`` CSV line."""
    print(f"{name},{us_per_call:.1f},{derived}")


def append_bench_history(bench: str, metrics: Dict[str, Any],
                         name: str = "BENCH_opt_speed.json"):
    """Append one machine-readable perf-trajectory entry to
    ``results/<name>`` (a JSON list; one element per bench invocation with a
    timestamp). The CSVs are per-run snapshots that each run overwrites —
    this file is the *history* `make bench` accretes, so a perf regression
    shows up as a trajectory, not a diff someone has to remember to take.
    A corrupt or missing file starts a fresh list rather than failing the
    bench.

    Schema 2: entries carry a ``"schema"`` version field, and an entry
    whose metrics are byte-identical to the file's previous entry for the
    same bench is dropped (re-running an analytic gate in a loop must not
    grow the history with copies — a flat trajectory is one point). The
    deterministic-metrics benches (roofline models, launch counts) rely on
    this; wall-clock benches always differ and always append."""
    import json
    import time

    RESULTS.mkdir(parents=True, exist_ok=True)
    path = RESULTS / name
    try:
        history = json.loads(path.read_text())
        if not isinstance(history, list):
            raise ValueError("history root must be a list")
    except (OSError, ValueError):
        history = []
    if history and history[-1].get("bench") == bench and \
            json.dumps(history[-1].get("metrics"), sort_keys=True) \
            == json.dumps(metrics, sort_keys=True):
        return path
    history.append({"ts": time.strftime("%Y-%m-%dT%H:%M:%S%z"),
                    "bench": bench, "schema": 2, "metrics": metrics})
    path.write_text(json.dumps(history, indent=1) + "\n")
    return path
