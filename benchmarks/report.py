"""Generate EXPERIMENTS.md from the dry-run records + benchmark CSVs.

    PYTHONPATH=src python -m benchmarks.report

Sections: §Paper-validation (benchmark CSV digests), §Dry-run (all 80 cells),
§Roofline (single-pod, per-cell three-term analysis), §Perf (inlined from
benchmarks/perf_log.md, the hand-maintained hypothesis->change->result log).
"""
from __future__ import annotations

import csv
import json
from pathlib import Path

HERE = Path(__file__).resolve().parent
DRYRUN = HERE / "results" / "dryrun"
RESULTS = HERE / "results"
REPO = HERE.parent

PEAK = 197e12
HBM_BW = 819e9
ICI = 50e9

SHAPE_ORDER = ("train_4k", "prefill_32k", "decode_32k", "long_500k")


def load_records():
    recs = {}
    for p in sorted(DRYRUN.glob("*.json")):
        r = json.loads(p.read_text())
        recs[(r["arch"], r["shape"], r["mesh"])] = r
    return recs


def fmt_s(x):
    if x is None:
        return "-"
    if x >= 1:
        return f"{x:.1f}s"
    return f"{x*1e3:.1f}ms"


def _decode_floor_bytes(rec):
    """Per-device bandwidth floor for one decode step: params + cache read once."""
    from repro.configs import SHAPES, get_config
    from repro.models.transformer import abstract_decode_cache
    import jax

    seq, gb, _ = SHAPES[rec["shape"]]
    cfg = get_config(rec["arch"])
    cache = abstract_decode_cache(cfg, gb, seq)
    cache_bytes = sum(l.size * l.dtype.itemsize for l in jax.tree.leaves(cache))
    param_bytes = rec["n_params"] * 2  # bf16
    return (cache_bytes + param_bytes) / rec["n_chips"]


def roofline_rows(recs):
    rows = []
    for (arch, shape, mesh), r in sorted(recs.items()):
        if mesh != "single" or r.get("status") != "ok":
            continue
        rf = r.get("roofline", {})
        comp, mem, coll = rf.get("compute_s"), rf.get("memory_s"), rf.get("collective_s")
        dom = rf.get("dominant")
        mf_dev = r.get("model_flops_per_dev", 0.0)
        ratio = r.get("useful_flops_ratio")
        if r["kind"] == "decode":
            floor = _decode_floor_bytes(r) / HBM_BW
            frac = floor / mem if mem else None
            note = "decode is bandwidth-floor bound: stream params+cache once/token"
        else:
            bound = max(comp or 0, mem or 0, coll or 0)
            frac = (mf_dev / PEAK) / bound if bound else None
            if dom == "collective":
                note = "TP activation all-reduces dominate: RS+AG conversion / ICI overlap"
            elif dom == "memory" and (ratio or 1) < 0.1:
                note = "attention replicated (heads % 16 != 0): reshard attention over batch"
            else:
                note = "bf16 collectives + fused optimizer kernel cut streamed bytes"
        rows.append({
            "arch": arch, "shape": shape, "compute": comp, "memory": mem,
            "collective": coll, "dominant": dom, "model_flops_dev": mf_dev,
            "useful_ratio": ratio, "fraction": frac, "note": note,
        })
    return rows


def section_dryrun(recs):
    out = ["## §Dry-run — 40 cells x {single 16x16, multi 2x16x16}",
           "",
           "Every runnable (architecture x input-shape) cell lowers, SPMD-partitions and",
           "compiles for both production meshes via `jax.jit(...).lower().compile()`",
           "with ShapeDtypeStruct inputs (no allocation). `temp`/`args` come from",
           "`compiled.memory_analysis()` (per-device).",
           "",
           "**Methodology caveat (CPU backend):** the dry-run compiles against XLA:CPU,",
           "which (a) upcasts bf16 arithmetic to fp32 (≈2x inflation of activation",
           "temporaries and collective payloads vs a TPU lowering) and (b) fuses far",
           "less aggressively. Temp figures are therefore conservative upper bounds.",
           "",
           "| arch | shape | mesh | status | accum | temp GiB | args GiB | fits 16GiB | compile |",
           "|---|---|---|---|---|---|---|---|---|"]
    for (arch, shape, mesh), r in sorted(
            recs.items(), key=lambda kv: (kv[0][0], SHAPE_ORDER.index(kv[0][1]), kv[0][2])):
        if r.get("status") == "skipped":
            out.append(f"| {arch} | {shape} | {mesh} | SKIP: {r.get('reason','')} | - | - | - | - | - |")
            continue
        temp = r.get("mem_temp_size_in_bytes", 0) / 2**30
        args = r.get("mem_argument_size_in_bytes", 0) / 2**30
        out.append(
            f"| {arch} | {shape} | {mesh} | ok | {r.get('grad_accum','-')} | "
            f"{temp:.2f} | {args:.2f} | {'yes' if r.get('fits_hbm') else 'NO'} | {r.get('compile_s','-')}s |")
    n_ok = sum(1 for r in recs.values() if r.get("status") == "ok")
    n_skip = sum(1 for r in recs.values() if r.get("status") == "skipped")
    n_fit = sum(1 for r in recs.values() if r.get("fits_hbm"))
    out += ["",
            f"**{len(recs)} cells: {n_ok} compiled ({n_fit} fit 16 GiB/chip), {n_skip} skipped per assignment rules.**",
            "",
            "Skips: `long_500k` runs only for the sub-quadratic archs (falcon-mamba,",
            "jamba); encoder-only archs (hubert) have no decode step. The only",
            "over-budget default cell is qwen1.5-32b `decode_32k` — its 64-layer MHA",
            "(kv=40) cache at 32k x batch 128 is 5.5 TB in bf16 (>21 GiB/chip on one",
            "pod before activations): a genuine capacity limit. The **int8-KV variant**",
            "(`--variant optimized`, `qwen15_32b__decode_32k__*_optimized.json`) fits:",
            "11.3 GiB args + 2.0 GiB temp single-pod, with <0.5% logit error and 100%",
            "argmax agreement (tests/test_arch_smoke.py::test_int8_kv_cache_decode).",
            ""]
    return "\n".join(out)


def section_roofline(rows):
    out = ["## §Roofline — single-pod (256 x TPU v5e), per (arch x shape)",
           "",
           "Terms (seconds/step/chip): compute = dot-FLOPs / 197 TF/s; memory =",
           "HBM-traffic proxy / 819 GB/s; collective = collective bytes / 50 GB/s.",
           "All three derive from the compiled HLO with while-loop trip-count",
           "correction (`repro.launch.hlo_analysis`; `compiled.cost_analysis()` counts",
           "each loop body once — verified to under-report a scanned model by ~n_layers).",
           "FLOPs are exact dot accounting; HBM traffic sums operand+output bytes of",
           "top-level (unfused) ops, a conservative upper bound on the CPU lowering;",
           "collective bytes sum operand sizes of all-gather/all-reduce/reduce-scatter/",
           "all-to-all/collective-permute, x loop trips.",
           "",
           "`MODEL_FLOPS` = 6·N·D (train) or 2·N_active·D (inference); `useful` =",
           "MODEL_FLOPS / HLO dot FLOPs (gap = remat recompute + attention quadratic",
           "work + sharding-replication waste). `roofline frac` = useful-FLOPs time /",
           "dominant term (train/prefill) or bandwidth-floor / memory term (decode).",
           "",
           "| arch | shape | compute | memory | collective | dominant | useful "
           "| roofline frac | what moves the dominant term |",
           "|---|---|---|---|---|---|---|---|---|"]
    for r in rows:
        frac = f"{r['fraction']:.3f}" if r["fraction"] else "-"
        useful = f"{r['useful_ratio']:.2f}" if r["useful_ratio"] else "-"
        out.append(
            f"| {r['arch']} | {r['shape']} | {fmt_s(r['compute'])} | {fmt_s(r['memory'])} | "
            f"{fmt_s(r['collective'])} | {r['dominant']} | {useful} | {frac} | {r['note']} |")
    out.append("")
    return "\n".join(out)


def section_validation():
    out = ["## §Paper-validation — benchmark digests (CPU-scale reproductions)",
           "",
           "Scale note: offline container, single CPU core — models are GPT-nano-class",
           "on Zipfian synthetic streams (DESIGN.md §7). The paper's *qualitative*",
           "claims are what these validate; absolute losses are not comparable.",
           ""]
    bench_out = REPO / "bench_output.txt"
    if bench_out.exists():
        out += ["One-line digests (`name,us_per_call,derived` from `benchmarks.run`):", "", "```"]
        out += [ln for ln in bench_out.read_text().splitlines() if ln.strip()]
        out += ["```", "",
                "Reproduced: token-dim SNR collapse with vocab tail (Fig 7 mechanism),",
                "SNR falls with lr (Fig 8), SlimAdam tracks Adam's lr curve within noise",
                "and spikes least at large lr (Figs 1/10/11), rules stable across",
                "datasets/widths (Tables 1-2), ResNets most compressible (Fig 5),",
                "99.8% mean table-3 second-moment savings at full scale (Fig 10 top).",
                "Scale-limited results, reported honestly: the Fig 7 *loss-gap* sign and",
                "the Fig 9 init ordering need the paper's 10k-step/full-width setting —",
                "at nano scale the 1/depth-scaled init measures *lower* SNR; the",
                "benchmark is the right experiment to run at full scale.", ""]
    digests = {
        "lr_sweep.csv": "Fig 1/10(bottom): final loss per (optimizer, lr)",
        "snr_trajectories.csv": "Fig 2/3: SNR_K trajectories per layer role",
        "vocab_tail.csv": "Fig 7: vocab size vs token-dim SNR and compression loss gap",
        "lr_compressibility.csv": "Fig 8: mean best-K SNR falls with lr",
        "init_comparison.csv": "Fig 9: Mitchell vs torch-default init SNR",
        "savings_by_arch.csv": "Fig 10(top): table-3 savings across the 10 assigned archs",
        "rule_robustness.csv": "Tables 1-2/Fig 30: rule stability across data/width",
        "opt_memory.csv": "optimizer state bytes at full scale",
        "opt_speed.csv": "fused-kernel micro-bench + v5e projection",
        "stability.csv": "Fig 11: loss-spike magnitude at large lr",
        "resnet_snr.csv": "Fig 5/§3.1.3: ResNet SNR by depth (most-compressible regime)",
    }
    for name, desc in digests.items():
        p = RESULTS / name
        out.append(f"- **{name}** — {desc}" + ("" if p.exists() else " *(not yet generated)*"))
        if p.exists() and name in ("savings_by_arch.csv", "opt_memory.csv"):
            rows = list(csv.DictReader(open(p)))
            cols = list(rows[0].keys())
            out.append("")
            out.append("| " + " | ".join(cols) + " |")
            out.append("|" + "---|" * len(cols))
            for r in rows:
                out.append("| " + " | ".join(str(r[c]) for c in cols) + " |")
            out.append("")
    out.append("")
    return "\n".join(out)


def main():
    recs = load_records()
    rows = roofline_rows(recs)
    perf_log = HERE / "perf_log.md"
    perf = perf_log.read_text() if perf_log.exists() else "*(perf iterations pending)*\n"

    doc = "\n".join([
        "# EXPERIMENTS",
        "",
        "Generated by `PYTHONPATH=src python -m benchmarks.report` from",
        "`benchmarks/results/` (dry-run JSONs + benchmark CSVs). Regenerate after",
        "re-running `repro.launch.sweep` or `benchmarks.run`.",
        "",
        section_validation(),
        section_dryrun(recs),
        section_roofline(rows),
        "## §Perf — hillclimb log (hypothesis -> change -> measure -> verdict)",
        "",
        perf,
    ])
    (REPO / "EXPERIMENTS.md").write_text(doc)
    print(f"wrote EXPERIMENTS.md ({len(doc.splitlines())} lines, {len(rows)} roofline rows)")


if __name__ == "__main__":
    main()
