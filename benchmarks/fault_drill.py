"""Fault drill: prove an injected run survives and converges.

The resilience substrate's acceptance test (ISSUE 6): a guarded gpt_small
run with **NaN-gradient**, **loss-spike**, **torn-checkpoint**, and
**checkpoint-IO-failure** injections must (a) complete, (b) land within 2%
of the un-injected run's final eval loss on a held-out stream, and (c) show
every injection in the guard counters. A separate pass injects a **kernel
failure** and checks the per-leaf degradation to the jnp reference path
keeps the update numerically correct.

    PYTHONPATH=src python -m benchmarks.fault_drill [--preset quick|full]

Exit code 1 on any tolerance/counter failure (CI gate: scripts/ci.sh
fault-drill).
"""
from __future__ import annotations

import argparse
import shutil
import sys
import tempfile
import warnings
from pathlib import Path

import jax
import jax.numpy as jnp

from repro.configs import get_reduced
from repro.data import DataConfig, ZipfLM
from repro.train import (
    FaultPlan,
    GuardConfig,
    Trainer,
    TrainerConfig,
    inject_checkpoint_io_failure,
    inject_kernel_failure,
    tear_checkpoint,
)
from repro.train.step import make_eval_step

from .common import append_bench_history, emit

REL_TOL = 0.02   # injected final eval loss within 2% of clean
EVAL_SEED = 123
EVAL_BATCHES = 4


def _eval_loss(cfg, params, *, seq: int, batch: int) -> float:
    """Mean eval loss over a fixed held-out stream (same for every run)."""
    data = ZipfLM(DataConfig(vocab_size=cfg.vocab_size, seq_len=seq,
                             global_batch=batch, seed=EVAL_SEED))
    step = jax.jit(make_eval_step(cfg))
    losses = []
    for i in range(EVAL_BATCHES):
        b = {k: jnp.asarray(v) for k, v in data.batch(i).items()}
        losses.append(float(step(params, b)["loss"]))
    return sum(losses) / len(losses)


def _make_trainer(cfg, steps, *, seq, batch, backend, ckpt_dir=None,
                  ckpt_every=0, faults=None) -> Trainer:
    data = ZipfLM(DataConfig(vocab_size=cfg.vocab_size, seq_len=seq,
                             global_batch=batch, seed=0))
    tc = TrainerConfig(
        total_steps=steps, log_every=max(steps // 2, 1), seed=0,
        backend=backend, ckpt_every=ckpt_every, ckpt_dir=ckpt_dir,
        guard=GuardConfig(max_bad_steps=2, min_history=4, spike_z=6.0))
    return Trainer(cfg, "slim", 3e-3, data, tc, faults=faults)


def main(preset: str = "quick") -> None:
    steps = 40 if preset == "quick" else 200
    seq, batch = (32, 8) if preset == "quick" else (128, 8)
    backend = "fused"
    cfg = get_reduced("gpt_small")
    half = steps // 2
    failures = []

    # -- clean reference run (guarded, no injections) ----------------------
    clean = _make_trainer(cfg, steps, seq=seq, batch=batch, backend=backend)
    clean.run()
    clean_loss = _eval_loss(cfg, clean.params, seq=seq, batch=batch)

    # -- injected run ------------------------------------------------------
    # NaN grads early, then a consecutive spike pair in the second half that
    # escalates past max_bad_steps into a rollback — whose newest checkpoint
    # we tear mid-run, forcing restore() to fall back to an older valid one.
    faults = FaultPlan(nan_grad_steps=(7,),
                       spike_steps=(half + 4, half + 5), spike_scale=100.0)
    tmp = Path(tempfile.mkdtemp(prefix="fault_drill_"))
    try:
        tr = _make_trainer(cfg, steps, seq=seq, batch=batch, backend=backend,
                           ckpt_dir=str(tmp), ckpt_every=5, faults=faults)
        with warnings.catch_warnings():
            warnings.simplefilter("ignore")
            tr.run(half)
            # torn-checkpoint injection: corrupt the newest step on disk the
            # way a preemption mid-write would
            torn = tear_checkpoint(tmp)
            # checkpoint-IO-failure injection: the next save raises OSError
            with inject_checkpoint_io_failure(fail_on=(1,)) as io_state:
                tr.run(steps)
        inj_loss = _eval_loss(cfg, tr.params, seq=seq, batch=batch)
    finally:
        shutil.rmtree(tmp, ignore_errors=True)

    rel = abs(inj_loss - clean_loss) / max(clean_loss, 1e-9)
    c = tr.guard.counters
    if tr.step != steps:
        failures.append(f"injected run stopped at step {tr.step}/{steps}")
    if rel > REL_TOL:
        failures.append(f"injected eval loss {inj_loss:.4f} deviates "
                        f"{rel:.1%} from clean {clean_loss:.4f} (> {REL_TOL:.0%})")
    if c["skipped"] < 1:
        failures.append("NaN-grad injection not visible: guard skipped == 0")
    if c["spikes"] < 1:
        failures.append("spike injection not visible: guard spikes == 0")
    if c["rollbacks"] < 1:
        failures.append("no rollback despite consecutive spikes")
    if tr.ckpt_failures < 1 or io_state["failed"] < 1:
        failures.append("checkpoint-IO injection not visible: "
                        f"ckpt_failures={tr.ckpt_failures}, "
                        f"injected={io_state['failed']}")

    # -- kernel-failure degradation pass -----------------------------------
    # Force the fused path's pallas launches to raise: every leaf must
    # degrade to the jnp reference path and the update must match a clean
    # jnp run bit-for-bit (same math, same order).
    from repro.optim import fused as fused_mod

    deg_tr = _make_trainer(cfg, 3, seq=seq, batch=batch, backend="fused")
    ref_tr = _make_trainer(cfg, 3, seq=seq, batch=batch, backend="jnp")
    with warnings.catch_warnings():
        warnings.simplefilter("ignore")
        with inject_kernel_failure():
            deg_tr.run()
            degraded = fused_mod.kernel_degraded_leaves()
        ref_tr.run()
    fused_mod.reset_kernel_degradation()
    if degraded < 1:
        failures.append("kernel-failure injection produced no degraded leaves")
    deg_delta = max(
        float(jnp.max(jnp.abs(a.astype(jnp.float32) - b.astype(jnp.float32))))
        for a, b in zip(jax.tree_util.tree_leaves(deg_tr.params),
                        jax.tree_util.tree_leaves(ref_tr.params)))
    if deg_delta > 1e-5:
        failures.append(f"degraded-path params deviate from jnp oracle "
                        f"by {deg_delta:.2e}")

    metrics = {
        "preset": preset, "steps": steps,
        "clean_eval_loss": round(clean_loss, 6),
        "injected_eval_loss": round(inj_loss, 6),
        "rel_diff": round(rel, 6),
        "guard_skipped": c["skipped"], "guard_spikes": c["spikes"],
        "guard_backoffs": c["backoffs"], "guard_rollbacks": c["rollbacks"],
        "guard_nonfinite_total": c["nonfinite_total"],
        "ckpt_failures": tr.ckpt_failures, "torn_step": torn,
        "degraded_leaves": degraded,
        "degraded_param_delta": deg_delta,
        "ok": not failures,
    }
    append_bench_history("fault_drill", metrics, name="BENCH_stability.json")
    emit("fault_drill_rel_diff", rel * 1e6,
         f"clean={clean_loss:.4f};injected={inj_loss:.4f};"
         f"rollbacks={c['rollbacks']};skipped={c['skipped']};"
         f"degraded={degraded}")
    for f in failures:
        print(f"FAULT DRILL FAILURE: {f}", file=sys.stderr)
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", choices=("quick", "full"), default="quick")
    main(ap.parse_args().preset)
