"""Serving chaos drill: prove an injected serve run drains correctly.

The serving fault layer's acceptance test (ISSUE 10), mirroring
``fault_drill`` at request granularity. One continuous-batching run on a
deliberately tight page pool is hit with every injection
:class:`repro.serve.ServeFaultPlan` offers — kernel launch failures on
chosen decode steps and prefill chunks, poisoned logits for one request,
a freelist squeeze forcing preemption, and a clock stall blowing one
request's deadline — and must:

  (a) **drain** — no ``PoolExhausted``/``LivelockError`` escapes; every
      accepted request completes with a meaningful ``finish_reason``;
  (b) **stay correct** — greedy token parity with a clean (un-injected)
      run for every unpoisoned, un-deadlined request, and prefix parity
      for the poisoned one (tokens sampled before the poison are good);
  (c) **not leak** — ``used_pages == 0`` and ``alloc_count == free_count``
      after the drain, squeeze pages included;
  (d) **account** — every injection visible in ``Engine.metrics()``
      (degraded_steps, nan_retired, deadline_expired, injected_stalls,
      preempted), within a bounded number of scheduler steps.

A second, tiny engine checks the admission-control contract: flooding past
``max_queue``/``admit_watermark`` yields :class:`repro.serve.Rejected`
verdicts and counters, never an exception.

    PYTHONPATH=src python -m benchmarks.serve_drill [--preset quick|full]

Exit code 1 on any gate failure (CI: scripts/ci.sh serve-drill).
"""
from __future__ import annotations

import argparse
import sys
import warnings

import jax
import numpy as np

from repro.configs import get_reduced
from repro.serve import (
    Engine,
    Rejected,
    Request,
    ServeConfig,
    ServeFaultPlan,
)

from .common import append_bench_history, emit

MAX_SCHED_STEPS = 200     # bounded-drain gate: tight pool, 6 short requests


def _make_engine(cfg, params, **overrides) -> Engine:
    sc = ServeConfig(max_seq=48, max_new_tokens=8, max_slots=3,
                     page_size=4, pool_pages=13, prefill_chunk=4,
                     **overrides)
    return Engine(cfg, params, sc)


def _prompts(n: int, s: int, vocab: int) -> np.ndarray:
    return np.asarray(jax.random.randint(
        jax.random.PRNGKey(1), (n, s), 0, vocab))


def _run(eng: Engine, prompts: np.ndarray, *, deadline_rid_idx=None,
         deadline_s=None):
    rids = []
    for i, p in enumerate(prompts):
        dl = deadline_s if i == deadline_rid_idx else None
        rid = eng.submit(Request(prompt=p, eos_id=None, deadline_s=dl))
        assert not isinstance(rid, Rejected), "drill pool must admit all"
        rids.append(rid)
    return rids, eng.run_until_drained()


def main(preset: str = "quick") -> None:
    n_requests = 6 if preset == "quick" else 12
    s_prompt = 8
    cfg = get_reduced("gpt_small")
    params, _ = cfg.init(jax.random.PRNGKey(0))
    prompts = _prompts(n_requests, s_prompt, cfg.vocab_size)
    failures = []

    # -- clean reference run ----------------------------------------------
    clean_eng = _make_engine(cfg, params)
    clean_rids, clean_done = _run(clean_eng, prompts)
    clean_tokens = {i: clean_done[r].tokens for i, r in enumerate(clean_rids)}

    # -- injected run ------------------------------------------------------
    # The poisoned request is submission index 2 (rids count up from 0 per
    # engine, so its rid is 2 here); the deadline request is index 5, killed
    # by a 10s virtual-clock stall at scheduler step 1 — before it can be
    # admitted out of the queue on this 3-slot engine.
    eng = _make_engine(cfg, params)
    poison_idx, deadline_idx = 2, n_requests - 1
    plan = ServeFaultPlan(
        kernel_fail_steps=(2, 5),
        prefill_fail_chunks=(1,),
        poison_rids=(poison_idx,),
        poison_after=2,
        squeeze_window=(1, 5),
        squeeze_pages=4,
        stall_steps=(1,),
        stall_s=10.0,
    )
    err = None
    with warnings.catch_warnings():
        warnings.simplefilter("ignore")
        with plan.install(eng):
            try:
                rids, done = _run(eng, prompts,
                                  deadline_rid_idx=deadline_idx,
                                  deadline_s=5.0)
            except Exception as e:  # noqa: BLE001 — the gate is "drains"
                err = e
    if err is not None:
        failures.append(f"injected run did not drain: "
                        f"{type(err).__name__}: {err}")
        m = eng.metrics()
    else:
        m = eng.metrics()

        # (a) every accepted request completed
        missing = set(rids) - set(done)
        if missing:
            failures.append(f"requests never completed: {sorted(missing)}")

        # (b) parity with the clean run
        for i, rid in enumerate(rids):
            if rid not in done:
                continue
            got = done[rid].tokens
            want = clean_tokens[i]
            if i == deadline_idx:
                if done[rid].finish_reason != "deadline":
                    failures.append(
                        f"deadline request finished with "
                        f"'{done[rid].finish_reason}', expected 'deadline'")
            elif i == poison_idx:
                if done[rid].finish_reason != "nan":
                    failures.append(
                        f"poisoned request finished with "
                        f"'{done[rid].finish_reason}', expected 'nan'")
                if not np.array_equal(got, want[:len(got)]):
                    failures.append(
                        "poisoned request's pre-poison tokens deviate from "
                        "the clean run")
            else:
                if not np.array_equal(got, want):
                    failures.append(
                        f"request {i} tokens deviate from the clean run "
                        f"under injection (reason "
                        f"'{done[rid].finish_reason}')")

        # (c) zero leaks, squeeze pages included
        if eng.pool.used_pages != 0:
            failures.append(f"page leak: {eng.pool.used_pages} pages still "
                            f"allocated after drain")
        if eng.pool.alloc_count != eng.pool.free_count:
            failures.append(f"alloc/free imbalance: "
                            f"{eng.pool.alloc_count} allocated vs "
                            f"{eng.pool.free_count} freed")

        # (d) every injection visible in the metrics snapshot
        if m.degraded_steps < 3:
            failures.append(f"kernel injections not fully visible: "
                            f"degraded_steps={m.degraded_steps} < 3")
        if m.nan_retired != 1 or m.injected_poison < 1:
            failures.append(f"poison injection not visible: "
                            f"nan_retired={m.nan_retired}, "
                            f"injected_poison={m.injected_poison}")
        if m.deadline_expired != 1:
            failures.append(f"stall-vs-deadline injection not visible: "
                            f"deadline_expired={m.deadline_expired}")
        if m.injected_stalls < 1:
            failures.append("clock-stall injection never fired")
        if m.preempted < 1:
            failures.append("pool squeeze provoked no preemption — the "
                            "drill pool is not tight enough to exercise "
                            "recompute")
        if m.sched_steps > MAX_SCHED_STEPS:
            failures.append(f"drain took {m.sched_steps} scheduler steps "
                            f"(> {MAX_SCHED_STEPS}) — backoff churn")

    # -- admission control / backpressure contract -------------------------
    bp = _make_engine(cfg, params, max_queue=2, admit_watermark=1.0)
    verdicts = [bp.submit(Request(prompt=p))
                for p in _prompts(8, s_prompt, cfg.vocab_size)]
    rejected = [v for v in verdicts if isinstance(v, Rejected)]
    accepted = [v for v in verdicts if not isinstance(v, Rejected)]
    if not rejected:
        failures.append("flooding past max_queue/admit_watermark rejected "
                        "nothing")
    if bp.metrics().rejected != len(rejected):
        failures.append("Rejected verdicts and rejection counters disagree")
    with warnings.catch_warnings():
        warnings.simplefilter("ignore")
        bp_done = bp.run_until_drained()
    if set(bp_done) != set(accepted) or bp.pool.used_pages != 0:
        failures.append("backpressured engine failed to drain the accepted "
                        "requests cleanly")

    metrics = {
        "preset": preset, "n_requests": n_requests,
        "prompt_len": s_prompt,
        "drained": err is None,
        "sched_steps": m.sched_steps,
        "decode_steps": m.decode_steps,
        "tokens_out": m.tokens_out,
        "degraded_steps": m.degraded_steps,
        "nan_retired": m.nan_retired,
        "injected_poison": m.injected_poison,
        "deadline_expired": m.deadline_expired,
        "injected_stalls": m.injected_stalls,
        "preempted": m.preempted,
        "livelock_backoffs": m.livelock_backoffs,
        "page_high_water": m.page_high_water,
        "used_pages_after_drain": eng.pool.used_pages,
        "rejected_queue": bp.metrics().rejected_queue,
        "rejected_pool": bp.metrics().rejected_pool,
        "greedy_parity": not any("deviate" in f for f in failures),
        "ok": not failures,
    }
    append_bench_history("serve_drill", metrics,
                         name="BENCH_serve_stability.json")
    emit("serve_drill_steps", float(m.sched_steps),
         f"degraded={m.degraded_steps};nan={m.nan_retired};"
         f"deadline={m.deadline_expired};preempted={m.preempted};"
         f"backoffs={m.livelock_backoffs};"
         f"rejected={bp.metrics().rejected}")
    for f in failures:
        print(f"SERVE DRILL FAILURE: {f}", file=sys.stderr)
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", choices=("quick", "full"), default="quick")
    main(ap.parse_args().preset)
