"""Paper Fig. 10 (top): fraction of second moments saved vs (lr, cutoff),
plus the exact table-3 savings for every assigned full-scale architecture."""
import time


from repro.configs import ARCH_IDS, get_config
from repro.core import derive_rules, second_moment_savings, table3_rules

from .common import emit, gpt_nano, train_once, write_csv


def main(preset: str = "quick"):
    steps = 120 if preset == "quick" else 1000
    t0 = time.time()
    rows = []
    cfg = gpt_nano()
    for lr in (1e-3, 3e-3, 1e-2):
        tr = train_once(cfg, "adam", lr, steps=steps, measure_snr=True, snr_every=20)
        for cutoff in (0.5, 1.0, 2.0):
            rules = derive_rules(tr.snr.averaged(), tr.meta, cutoff=cutoff)
            s = second_moment_savings(tr.params, tr.meta, rules)
            rows.append({"model": "gpt_nano", "lr": lr, "cutoff": cutoff,
                         "saved_fraction": round(s["saved_fraction"], 4)})
    write_csv("savings_vs_lr_cutoff.csv", rows)

    arch_rows = []
    for arch in ARCH_IDS:
        fcfg = get_config(arch)
        params_abs, meta = fcfg.abstract()
        rules = table3_rules(meta)
        s = second_moment_savings(params_abs, meta, rules)
        arch_rows.append({"arch": arch,
                          "total_moments_B": round(s["total_second_moments"] / 1e9, 3),
                          "stored_moments_B": round(s["stored_second_moments"] / 1e9, 4),
                          "saved_fraction": round(s["saved_fraction"], 4)})
    write_csv("savings_by_arch.csv", arch_rows)
    mean_saved = sum(r["saved_fraction"] for r in arch_rows) / len(arch_rows)
    lo = min(rows, key=lambda r: r["lr"])
    emit("savings", (time.time() - t0) * 1e6 / (3 * steps),
         f"snr-rules @small-lr save {lo['saved_fraction']:.1%}; table3 mean across "
         f"{len(arch_rows)} archs: {mean_saved:.1%}")
    return arch_rows


if __name__ == "__main__":
    main()
