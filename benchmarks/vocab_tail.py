"""Paper Fig. 7 + App. G: the two-layer linear model. Growing the
vocabulary (heavier tail) kills token-dim SNR; compressing the token dim
then costs loss while compressing the embedding dim stays free."""
import time

import jax
import jax.numpy as jnp

from repro.core import (SNRTracker, measure_tree_snr, rules_as_tree)
from repro.core.slim_adam import slim_adam
from repro.data import linear_model_batches
from repro.models import linear_lm
from repro.optim import adamw
from repro.train.step import make_train_step
from repro.train.trainer import find_adam_nu

from .common import emit, write_csv


def run_linear(vocab, steps, optimizer_rules=None, lr=3e-3, seed=0, snr_every=20):
    cfg = linear_lm.LinearLMConfig(vocab_size=vocab, d_model=32)
    params, meta = cfg.init(jax.random.PRNGKey(seed))
    if optimizer_rules is None:
        tx = adamw(lr, b2=0.999, weight_decay=1e-4)
    else:
        dims = rules_as_tree(optimizer_rules, params, meta)
        tx = slim_adam(lr, dims, b2=0.999, weight_decay=1e-4)
    step_fn = jax.jit(make_train_step(cfg, tx, forward_fn=linear_lm.forward))
    data = linear_model_batches(vocab, seq_len=32, batch=8, seed=seed)
    opt = tx.init(params)
    tracker = SNRTracker()
    loss = None
    for s in range(steps):
        batch = {k: jnp.asarray(v) for k, v in data.batch(s).items()}
        params, opt, metrics = step_fn(params, opt, batch)
        if optimizer_rules is None and (s + 1) % snr_every == 0:
            tracker.update(measure_tree_snr(find_adam_nu(opt), meta), s + 1)
        loss = float(metrics["loss"])
    return loss, tracker.averaged(), meta


def main(preset: str = "quick"):
    steps = 240 if preset == "quick" else 1000
    vocabs = (64, 512, 2048) if preset == "quick" else (1024, 4096, 16384, 49152)
    t0 = time.time()
    rows = []
    for v in vocabs:
        base_loss, avg, meta = run_linear(v, steps)
        head = avg.get("head", {})
        embd = avg.get("embed", {})
        # token dim of the head is its fan_out ('vocab'); embed dim is fan_in
        row = {"vocab": v, "adam_loss": round(base_loss, 4),
               "head_snr_token_dim": round(head.get("fan_out", 0), 3),
               "head_snr_embed_dim": round(head.get("fan_in", 0), 3),
               "embd_snr_token_dim": round(embd.get("fan_in", 0), 3),
               "embd_snr_embed_dim": round(embd.get("fan_out", 0), 3)}
        # loss gap when compressing token dim vs embedding dim (Fig 7 right)
        for label, rules in (
            ("embed_dims", {"embed": ("embed",), "head": ("embed",)}),
            ("token_dims", {"embed": ("vocab",), "head": ("vocab",)}),
        ):
            loss_c, _, _ = run_linear(v, steps, optimizer_rules=rules)
            row[f"dloss_{label}"] = round(loss_c - base_loss, 4)
        rows.append(row)
    write_csv("vocab_tail.csv", rows)
    r0, rN = rows[0], rows[-1]
    emit("vocab_tail", (time.time() - t0) * 1e6 / (len(vocabs) * 3 * steps),
         f"token-dim SNR {r0['head_snr_token_dim']}->{rN['head_snr_token_dim']} as vocab "
         f"{r0['vocab']}->{rN['vocab']}; dloss(token)={rN['dloss_token_dims']:+.3f} "
         f"vs dloss(embed)={rN['dloss_embed_dims']:+.3f}")
    return rows


if __name__ == "__main__":
    main()
