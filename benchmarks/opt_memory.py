"""Optimizer-state memory per assigned architecture: Adam vs SlimAdam vs
baselines (the paper's Fig. 10 savings, materialized as bytes at full scale)."""
import time

import jax
import jax.numpy as jnp

from repro.configs import ARCH_IDS, get_config
from repro.train.trainer import make_optimizer

from .common import emit, write_csv


def state_bytes(tx, params_abs):
    state = jax.eval_shape(tx.init, params_abs)
    return sum(l.size * l.dtype.itemsize for l in jax.tree.leaves(state))


def main(preset: str = "quick"):
    t0 = time.time()
    rows = []
    archs = ARCH_IDS if preset != "quick" else ARCH_IDS[:10]
    for arch in archs:
        cfg = get_config(arch, param_dtype=jnp.bfloat16)
        params_abs, meta = cfg.abstract()
        n_param_bytes = sum(l.size * l.dtype.itemsize for l in jax.tree.leaves(params_abs))
        row = {"arch": arch, "param_GB": round(n_param_bytes / 2**30, 2)}
        for name in ("adam", "slim", "adalayer", "adam_mini_v2", "adafactor", "sm3", "lion"):
            tx = make_optimizer(name, 3e-4, params_abs, meta)
            row[f"{name}_GB"] = round(state_bytes(tx, params_abs) / 2**30, 3)
        row["slim_vs_adam_saved"] = round(1 - row["slim_GB"] / row["adam_GB"], 4)
        rows.append(row)
    write_csv("opt_memory.csv", rows)
    mean = sum(r["slim_vs_adam_saved"] for r in rows) / len(rows)
    emit("opt_memory", (time.time() - t0) * 1e6 / len(rows),
         f"slim saves {mean:.1%} of Adam optimizer-state bytes on average "
         f"(near the 50% second-moment ceiling)")
    return rows


if __name__ == "__main__":
    main()
