"""Serving fast-path bench + static gates (paged KV pool, chunked prefill).

Drives the request-level engine over a batch of gpt_small-reduced requests
and reports tokens/s, mean TTFT, and page-pool utilization, appending the
machine-readable trajectory to ``results/BENCH_serve.json``. Two gates run
regardless of wall clock (interp-mode CPU numbers are not load-bearing):

  * **launch gate** — one paged decode step must trace to O(1) pallas
    launches per attention slot (the page walk lives in the kernel grid,
    not the HLO), independent of pool size or request count;
  * **prefill gate** — chunked prefill must cost ``ceil(S/C)`` device steps
    per request, >= 4x fewer than the token-by-token loop's ``S``;
  * **parity gate** — greedy paged output token-identical to the legacy
    ``generate()`` oracle.

    PYTHONPATH=src python -m benchmarks.serve_bench [--preset quick|full]

Exit code 1 on any gate failure (CI: scripts/ci.sh bench-serve).
"""
from __future__ import annotations

import argparse
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.analysis.jaxpr_tools import count_pallas_launches
from repro.configs import get_reduced
from repro.models import transformer
from repro.serve import Engine, Request, ServeConfig

from .common import append_bench_history, emit

PREFILL_SPEEDUP_FLOOR = 4.0


def main(preset: str = "quick") -> None:
    n_requests = 6 if preset == "quick" else 16
    s_prompt, chunk = 32, 8
    cfg = get_reduced("gpt_small")
    params, _ = cfg.init(jax.random.PRNGKey(0))
    sc = ServeConfig(max_seq=64, max_new_tokens=16, max_slots=4,
                     page_size=8, prefill_chunk=chunk)
    prompts = np.asarray(jax.random.randint(
        jax.random.PRNGKey(1), (n_requests, s_prompt), 0, cfg.vocab_size))
    failures = []

    # -- serving run -------------------------------------------------------
    eng = Engine(cfg, params, sc)
    rids = [eng.submit(Request(prompt=p)) for p in prompts]
    t0 = time.monotonic()
    done = eng.run_until_drained()
    wall = time.monotonic() - t0
    tokens = sum(len(done[r].tokens) for r in rids)
    ttft = float(np.mean([done[r].ttft_s for r in rids]))
    tok_s = tokens / max(wall, 1e-9)
    if set(done) != set(rids):
        failures.append(f"{len(rids) - len(done)} requests never completed")
    if eng.pool.used_pages != 0:
        failures.append(f"page leak: {eng.pool.used_pages} pages still "
                        f"allocated after drain")

    # -- launch gate -------------------------------------------------------
    n_attn = sum(1 for s in cfg.pattern if s.mixer == "attn")
    state = transformer.PagedState(
        pools=eng._device_pools(),
        table=jnp.asarray(eng.scheduler.table),
        lengths=jnp.ones((sc.max_slots,), jnp.int32),
        active=jnp.ones((sc.max_slots,), bool))
    launches = count_pallas_launches(
        lambda p, s, t: transformer.paged_decode_step(cfg, p, s, t),
        params, state, jnp.zeros((sc.max_slots, 1), jnp.int32))
    if launches != n_attn:
        failures.append(
            f"paged decode traces to {launches} pallas launches, expected "
            f"O(1) = {n_attn} (one per attention slot; the page walk must "
            f"live in the kernel grid, not the HLO)")

    # -- prefill gate ------------------------------------------------------
    expected_chunks = n_requests * (-(-s_prompt // chunk))
    speedup = (n_requests * s_prompt) / max(eng.prefill_chunks, 1)
    if eng.prefill_chunks != expected_chunks:
        failures.append(f"prefill took {eng.prefill_chunks} device steps, "
                        f"expected {expected_chunks} = n_req * ceil(S/C)")
    if speedup < PREFILL_SPEEDUP_FLOOR:
        failures.append(f"chunked prefill only {speedup:.1f}x fewer steps "
                        f"than token-by-token (< {PREFILL_SPEEDUP_FLOOR}x)")

    # -- parity gate -------------------------------------------------------
    par_prompts = jnp.asarray(prompts[:2])
    pg = Engine(cfg, params, sc).generate(par_prompts)
    lg = Engine(cfg, params, ServeConfig(
        max_seq=sc.max_seq, max_new_tokens=sc.max_new_tokens,
        paged=False)).generate(par_prompts)
    if not np.array_equal(np.asarray(pg), np.asarray(lg)):
        failures.append("greedy paged output differs from the legacy "
                        "generate() oracle")

    metrics = {
        "preset": preset, "n_requests": n_requests,
        "prompt_len": s_prompt, "max_new": sc.max_new_tokens,
        "tokens": tokens, "wall_s": round(wall, 4),
        "tokens_per_s": round(tok_s, 2), "ttft_ms": round(ttft * 1e3, 3),
        "prefill_chunks": eng.prefill_chunks,
        "prefill_speedup": round(speedup, 2),
        "decode_steps": eng.decode_steps,
        "pallas_launches_per_decode": launches,
        "page_high_water": eng.pool.high_water,
        "preempted": eng.scheduler.preempted,
        "greedy_parity": not any("oracle" in f for f in failures),
        "ok": not failures,
    }
    append_bench_history("serve", metrics, name="BENCH_serve.json")
    emit("serve_decode", wall * 1e6 / max(tokens, 1),
         f"tok_s={tok_s:.1f};ttft_ms={ttft * 1e3:.1f};"
         f"prefill_x={speedup:.1f};launches={launches};"
         f"high_water={eng.pool.high_water}")
    for f in failures:
        print(f"SERVE BENCH FAILURE: {f}", file=sys.stderr)
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", choices=("quick", "full"), default="quick")
    main(ap.parse_args().preset)
