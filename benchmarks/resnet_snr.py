"""Paper Fig. 5 / §3.1.3: ResNets are the most compressible regime — high SNR
on intermediate convs (rising with depth), first conv resists fan_out, the
classifier sits near SNR ~ 1."""
import time

import jax

from repro.core import SNRTracker, derive_rules, measure_tree_snr, second_moment_savings
from repro.models.resnet import ResNetConfig, forward, synthetic_cifar
from repro.optim import adamw, apply_updates
from repro.train.loss import cross_entropy
from repro.train.trainer import find_adam_nu

from .common import emit, write_csv


def main(preset: str = "quick"):
    steps = 150 if preset == "quick" else 2000
    cfg = ResNetConfig(stages=(1, 1), width=8, classes=10) if preset == "quick" \
        else ResNetConfig(classes=100)
    size = 8 if preset == "quick" else 32
    t0 = time.time()
    params, meta = cfg.init(jax.random.PRNGKey(0))
    tx = adamw(1e-3, b2=0.999, weight_decay=0.01)
    state = tx.init(params)

    def loss_fn(p, batch):
        lg, _ = forward(cfg, p, batch)
        return cross_entropy(lg[:, None, :], batch["labels"][:, None])

    grad_fn = jax.jit(jax.value_and_grad(loss_fn))
    tracker = SNRTracker()
    for s in range(steps):
        batch = synthetic_cifar(jax.random.PRNGKey(s), 32, cfg.classes, size=size)
        loss, g = grad_fn(params, batch)
        u, state = tx.update(g, state, params)
        params = apply_updates(params, u)
        if (s + 1) % 25 == 0:
            tracker.update(measure_tree_snr(find_adam_nu(state), meta), s + 1)

    avg = tracker.averaged()
    rows = [{"param": p_, "K": k, "snr": round(v, 3)}
            for p_, ks in sorted(avg.items()) for k, v in ks.items()]
    write_csv("resnet_snr.csv", rows)
    convs = {p_: ks for p_, ks in avg.items() if "conv" in p_ and "stem" not in p_}
    mid_best = sum(max(ks.values()) for ks in convs.values()) / max(len(convs), 1)
    stem = avg.get("stem.conv", {})
    head = avg.get("head", {})
    rules = derive_rules(avg, meta, cutoff=1.0)
    sav = second_moment_savings(params, meta, rules)
    emit("resnet_snr", (time.time() - t0) * 1e6 / steps,
         f"mid-conv best-K SNR={mid_best:.2f} stem fan_out={stem.get('fan_out', 0):.2f} "
         f"head={max(head.values()) if head else 0:.2f}; snr-rules save {sav['saved_fraction']:.1%} "
         f"(paper: ResNets most compressible, final loss={float(loss):.3f})")
    return avg


if __name__ == "__main__":
    main()
