"""Benchmark harness: one entry per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--preset quick|full] [--only name]

Prints ``name,us_per_call,derived`` CSV lines (harness contract) and writes
full tables to benchmarks/results/.
"""
import argparse
import sys
import traceback

from . import (lr_sweep, snr_trajectories, vocab_tail, lr_compressibility,
               init_comparison, savings, rule_robustness, opt_memory,
               opt_speed, stability, resnet_snr, fault_drill, serve_bench,
               serve_drill)

ALL = {
    "lr_sweep": lr_sweep.main,                    # Fig 1 / Fig 10 bottom
    "snr_trajectories": snr_trajectories.main,    # Fig 2/3, App C
    "vocab_tail": vocab_tail.main,                # Fig 7, App G
    "lr_compressibility": lr_compressibility.main,  # Fig 8, App D
    "init_comparison": init_comparison.main,      # Fig 9, App E
    "savings": savings.main,                      # Fig 10 top
    "rule_robustness": rule_robustness.main,      # Tables 1-2, Fig 30
    "opt_memory": opt_memory.main,                # memory table (full-scale archs)
    "opt_speed": opt_speed.main,                  # kernel micro-bench
    "opt_speed_tree": opt_speed.tree_main,        # whole-tree fused step, jnp vs fused
    "opt_speed_sharded": opt_speed.sharded_main,  # per-shard bytes on the production mesh
    "stability": stability.main,                  # Fig 11
    "resnet_snr": resnet_snr.main,                # Fig 5, §3.1.3
    "fault_drill": fault_drill.main,              # resilience substrate gate
    "serve_bench": serve_bench.main,              # paged serving fast-path gate
    "serve_drill": serve_drill.main,              # serving fault-tolerance gate
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", choices=("quick", "full"), default="quick")
    ap.add_argument("--only", choices=list(ALL), default=None)
    args = ap.parse_args()
    names = [args.only] if args.only else list(ALL)
    failed = []
    for name in names:
        try:
            ALL[name](args.preset)
        except Exception:
            failed.append(name)
            traceback.print_exc()
            print(f"{name},-1,FAILED")
    if failed:
        sys.exit(1)


if __name__ == '__main__':
    main()
