"""Paper Fig. 1 / Fig. 10 (bottom): final loss vs learning rate for Adam,
SlimAdam and the low-memory baselines. SlimAdam must track Adam's curve;
Lion/SM3/Adafactor shift or degrade."""
import time

from .common import emit, gpt_nano, train_once, write_csv

OPTS = ("adam", "slim", "adalayer", "adalayer_ln_tl", "adam_mini_v2",
        "lion", "sm3", "adafactor")


def main(preset: str = "quick"):
    steps = 60 if preset == "quick" else 400
    lrs = (1e-3, 3e-3, 1e-2, 3e-2)
    cfg = gpt_nano()
    rows = []
    t0 = time.time()
    for opt in OPTS:
        for lr in lrs:
            tr = train_once(cfg, opt, lr, steps=steps)
            loss = tr.metrics_log[-1]["loss"]
            rows.append({"optimizer": opt, "lr": lr, "final_loss": round(loss, 4)})
    write_csv("lr_sweep.csv", rows)
    by_opt = {o: min(r["final_loss"] for r in rows if r["optimizer"] == o) for o in OPTS}
    gap = by_opt["slim"] - by_opt["adam"]
    emit("lr_sweep", (time.time() - t0) * 1e6 / (len(OPTS) * len(lrs) * steps),
         f"best: adam={by_opt['adam']:.3f} slim={by_opt['slim']:.3f} gap={gap:+.3f} "
         f"adalayer={by_opt['adalayer']:.3f} lion={by_opt['lion']:.3f}")
    return rows


if __name__ == "__main__":
    main()
