"""Paper Fig. 2/3 + App. C: SNR_K trajectories along an Adam run, per layer
role and candidate dimension; embedding must resist token-dim compression."""
import time

from .common import emit, gpt_nano, train_once, write_csv


def main(preset: str = "quick"):
    steps = 200 if preset == "quick" else 2000
    cfg = gpt_nano(vocab=256)
    t0 = time.time()
    tr = train_once(cfg, "adam", 3e-3, steps=steps, measure_snr=True, snr_every=20)
    rows = []
    for pname, by_k in tr.snr.trajectory.items():
        for k, series in by_k.items():
            for i, v in enumerate(series):
                rows.append({"param": pname, "K": k, "measurement": i,
                             "step": tr.snr.steps[i], "snr": round(v, 4)})
    write_csv("snr_trajectories.csv", rows)
    avg = tr.snr.averaged()
    emb = avg.get("embed", {})
    emit("snr_trajectories", (time.time() - t0) * 1e6 / steps,
         f"embed: token-dim(fan_in)={emb.get('fan_in', 0):.2f} "
         f"embed-dim(fan_out)={emb.get('fan_out', 0):.2f} "
         f"(paper: embed dim >> token dim)")
    return avg


if __name__ == "__main__":
    main()
