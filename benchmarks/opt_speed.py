"""Optimizer update micro-bench: jnp paths vs fused Pallas kernels
(interpret mode on CPU = correctness harness; the 'tpu_proj_us' column
reports the roofline-projected TPU v5e time from streamed bytes / 819 GB/s).

Two entries:
  * ``main``      — single-tensor kernel micro-bench (p/g/m/v on one leaf);
  * ``tree_main`` — whole-GPT-small-param-tree optimizer step, jnp vs fused
    vs bucketed-fused, with the per-leaf bytes-streamed model summed over
    the tree (the acceptance roofline: fan_in-compressed leaves stream
    5/7 of dense-Adam bytes).
"""
import time

import jax
import jax.numpy as jnp

from repro.core import rules_as_tree, table3_rules
from repro.core.slim_adam import scale_by_slim_adam
from repro.kernels import fused_adam_op, slim_update_op
from repro.kernels.ref import adam_update_ref, slim_update_ref
from repro.optim import scale_by_adam

from .common import emit, write_csv

HBM_BW = 819e9


def timeit(fn, *args, iters=5):
    """(mean_us, min_us) per call. The warm-up result is blocked on so the
    compile/dispatch tail can't leak into the first timed iteration, and each
    iteration is blocked individually so min-of-iters is a real floor."""
    jax.block_until_ready(fn(*args))  # compile + flush dispatch tail
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        times.append(time.perf_counter() - t0)
    return sum(times) / iters * 1e6, min(times) * 1e6


def main(preset: str = "quick"):
    r, c = (1024, 1024) if preset == "quick" else (4096, 8192)
    ks = jax.random.split(jax.random.PRNGKey(0), 4)
    p = jax.random.normal(ks[0], (r, c))
    g = jax.random.normal(ks[1], (r, c)) * 0.1
    m = jnp.zeros((r, c))
    v = jnp.zeros((r, c))
    v_row = jnp.zeros((r, 1))
    kw = dict(lr=1e-3, b1=0.9, b2=0.95, eps=1e-8, wd=0.1, count=1)

    jnp_adam = jax.jit(lambda *a: adam_update_ref(*a, **kw))
    jnp_slim = jax.jit(lambda *a: slim_update_ref(*a, **kw))
    t_jnp_adam = timeit(jnp_adam, p, g, m, v)
    t_jnp_slim = timeit(jnp_slim, p, g, m, v_row)
    t_pal_adam = timeit(lambda *a: fused_adam_op(*a, **kw), p, g, m, v)
    t_pal_slim = timeit(lambda *a: slim_update_op(*a, axis=1, **kw), p, g, m, v_row)

    n = r * c * 4
    adam_bytes = 7 * n              # p,g,m,v read + p,m,v write
    slim_bytes = 5 * n + 2 * r * 4  # v is O(R)
    rows = [
        {"impl": "jnp_adam", "us": round(t_jnp_adam[0], 1), "min_us": round(t_jnp_adam[1], 1),
         "tpu_proj_us": round(adam_bytes / HBM_BW * 1e6, 1)},
        {"impl": "jnp_slim", "us": round(t_jnp_slim[0], 1), "min_us": round(t_jnp_slim[1], 1),
         "tpu_proj_us": round(slim_bytes / HBM_BW * 1e6, 1)},
        {"impl": "pallas_adam(interp)", "us": round(t_pal_adam[0], 1), "min_us": round(t_pal_adam[1], 1),
         "tpu_proj_us": round(adam_bytes / HBM_BW * 1e6, 1)},
        {"impl": "pallas_slim(interp)", "us": round(t_pal_slim[0], 1), "min_us": round(t_pal_slim[1], 1),
         "tpu_proj_us": round(slim_bytes / HBM_BW * 1e6, 1)},
    ]
    write_csv("opt_speed.csv", rows)
    emit("opt_speed", t_jnp_adam[0],
         f"slim streams {slim_bytes/adam_bytes:.2f}x of adam bytes -> "
         f"projected v5e {slim_bytes/HBM_BW*1e6:.1f}us vs {adam_bytes/HBM_BW*1e6:.1f}us per {r}x{c} tensor")
    return rows


def _tree_bytes(params, dims_leaves, *, dense_passes=7, slim_passes=5):
    """Roofline bytes-streamed model for one full-tree optimizer step.

    Defaults model the p-apply form (7 passes dense, 5 + O(kept) slim); the
    GradientTransformation form actually timed in ``tree_main`` (update
    emitted, params untouched) streams 6 / 4 + O(kept) — pass those counts
    so projection and measurement describe the same operation.

    Compressed leaves run transpose-free whenever ``canon_nd`` reaches the
    batched (B, R, C) canonical form by pure reshape — reduced dims trailing
    (minor kernel), leading (major/sublane kernel), *or* sandwiched between
    kept axes (batched major kernel: the kept prefix becomes a batch grid
    dim, which covers every scan-stacked leaf like (layers, embed, heads,
    hd) reducing embed). Only a genuinely interleaved K — the reduced dims
    not forming one contiguous block with kept dims only outside it (e.g. a
    kept dim inside the reduced span) — still needs a boundary
    transpose, and a pallas_call is an optimization barrier, so that
    re-layout materializes (+2 passes per full-size operand: write the copy
    + re-read or re-write it). That traffic is charged here — the 5/7 floor
    holds for every reshape-reachable leaf, batch-reachable ones included.
    Returns (dense_bytes, compressed_bytes, compressed_dense_equiv,
    transpose_free_compressed_bytes, transpose_free_dense_equiv)."""
    from repro.kernels import canon_nd

    dense = compressed = compressed_dense_equiv = 0
    tf_compressed = tf_dense_equiv = 0
    for p, dims in zip(jax.tree.leaves(params), dims_leaves):
        n = int(p.size) * 4
        if dims:
            cn = canon_nd(p.shape, tuple(dims))
            b = slim_passes * n + 2 * cn.kept_size * 4
            if cn.is_transpose:
                # every full-size pass belongs to an operand that must be
                # re-laid out (the O(kept) moment is separate and tiny)
                b += 2 * slim_passes * n
            else:
                tf_compressed += b
                tf_dense_equiv += dense_passes * n
            compressed += b
            compressed_dense_equiv += dense_passes * n
        else:
            dense += dense_passes * n
    return dense, compressed, compressed_dense_equiv, tf_compressed, tf_dense_equiv


def _gpt_small_full_leaves():
    """Named shape-leaves + per-leaf dims for the real 124M GPT-small.

    Shapes via eval_shape (no 124M-param materialization); meta from the
    reduced config, whose tree structure and axis names are identical. One
    derivation shared by the ``tree_main`` headline roofline and the
    ``roofline_check`` CI gate, so the gate validates exactly the leaf set
    the benchmark projects. Returns (full_cfg, params_full, named, dims)."""
    from repro.configs import gpt_small
    from repro.core import rules_as_tree, table3_rules
    from repro.core.labels import flatten_with_names

    _, meta = gpt_small.reduced().init(jax.random.PRNGKey(0))
    full = gpt_small.config()
    params_full = jax.eval_shape(lambda k: full.init(k)[0], jax.random.PRNGKey(0))
    dims_full = rules_as_tree(table3_rules(meta), params_full, meta)
    named, _ = flatten_with_names(params_full)
    dfl = [tuple(d) for d in
           jax.tree_util.tree_flatten(params_full)[1].flatten_up_to(dims_full)]
    return full, params_full, named, dfl


def tree_main(preset: str = "quick"):
    """Whole-param-tree optimizer step: jnp vs fused vs bucketed-fused."""
    from repro.configs import gpt_small

    cfg = gpt_small.reduced() if preset == "quick" else gpt_small.config()
    params, meta = cfg.init(jax.random.PRNGKey(0))
    grads = jax.tree.map(
        lambda p: 0.1 * jax.random.normal(jax.random.PRNGKey(p.size % 97), p.shape), params)
    rules = table3_rules(meta)
    dims = rules_as_tree(rules, params, meta)
    dims_leaves = [tuple(d) for d in
                   jax.tree_util.tree_flatten(params)[1].flatten_up_to(dims)]

    setups = [
        ("adam_jnp", scale_by_adam(0.9, 0.95, 1e-8)),
        ("adam_fused", scale_by_adam(0.9, 0.95, 1e-8, backend="fused", bucket_min_size=0)),
        ("adam_fused_bucketed", scale_by_adam(0.9, 0.95, 1e-8, backend="fused")),
        ("slim_jnp", scale_by_slim_adam(dims, 0.9, 0.95, 1e-8)),
        ("slim_fused", scale_by_slim_adam(dims, 0.9, 0.95, 1e-8, backend="fused", bucket_min_size=0)),
        ("slim_fused_bucketed", scale_by_slim_adam(dims, 0.9, 0.95, 1e-8, backend="fused")),
    ]

    # The timed op is tx.update — the GradientTransformation form (update
    # emitted, params untouched): 6 passes dense, 4 + O(rows) slim. The CSV
    # projection uses those pass counts so measured-vs-roofline compares the
    # same operation.
    n_total = sum(int(p.size) for p in jax.tree.leaves(params)) * 4
    adam_bytes = 6 * n_total
    dense_b, comp_b, *_ = _tree_bytes(params, dims_leaves, dense_passes=6, slim_passes=4)
    slim_bytes = dense_b + comp_b

    rows = []
    for name, tx in setups:
        state = tx.init(params)
        step = jax.jit(lambda g, s, tx=tx: tx.update(g, s))
        t_mean, t_min = timeit(step, grads, state, iters=3)
        b = adam_bytes if name.startswith("adam") else slim_bytes
        rows.append({"impl": name, "us": round(t_mean, 1), "min_us": round(t_min, 1),
                     "bytes": b, "tpu_proj_us": round(b / HBM_BW * 1e6, 1)})
    write_csv("opt_speed_tree.csv", rows)

    # Headline roofline for the full AdamW *apply* form (7 passes dense,
    # 5 + O(kept) slim — the paper's 5-vs-7 claim) on the real GPT-small
    # regardless of preset.
    full, params_full, _, dfl = _gpt_small_full_leaves()
    fdense_b, fcomp_b, _, ftf_b, ftf_dense = _tree_bytes(params_full, dfl)
    f_adam = 7 * sum(int(p.size) for p in jax.tree.leaves(params_full)) * 4
    f_slim = fdense_b + fcomp_b
    tf_ratio = ftf_b / ftf_dense if ftf_dense else 1.0
    # Track the implementation this benchmark exists for: the bucketed fused
    # slim step (a fused-path regression must move the trajectory metric).
    fused_us = next(r["us"] for r in rows if r["impl"] == "slim_fused_bucketed")
    emit("opt_speed_tree", fused_us,
         f"{full.name} full-apply form: fused tree step streams {f_slim/f_adam:.2f}x "
         f"of dense-Adam bytes (re-layout traffic charged only for genuinely "
         f"interleaved-K leaves); transpose-free compressed leaves — fan_in "
         f"via the minor kernel, fan_out via the major/sublane kernel, "
         f"scan-stacked middle-K via the batched major kernel — hit the "
         f"5/7={5/7:.3f} tensor-pass floor ({tf_ratio:.3f}x bytes incl. "
         f"O(kept) reduced moments) -> "
         f"projected v5e {f_slim/HBM_BW*1e3:.2f}ms vs {f_adam/HBM_BW*1e3:.2f}ms")
    return rows


def roofline_check() -> int:
    """CI gate (`make bench-roofline`): run the opt_speed_tree byte model
    over the *full* GPT-small leaf set and fail if any compressed leaf
    regresses to a transposing plan (``is_transpose=True``) — i.e. if the
    planner stops reaching the batched canonical form for the scan-stacked
    leaves, or either 2-D orientation for the rest. Analytic (eval_shape +
    planner); no kernels run, so it is interpret-mode safe and fast."""
    from repro.kernels import canon_nd

    full, params_full, named, dfl = _gpt_small_full_leaves()
    regressed = []
    for (name, p), dims in zip(named, dfl):
        if not dims:
            continue
        cn = canon_nd(p.shape, dims)
        tag = f"batch={cn.batch}" if cn.batch > 1 else cn.orientation
        print(f"  {name:45s} {str(p.shape):22s} K={dims} -> {tag}"
              + (" TRANSPOSE" if cn.is_transpose else ""))
        if cn.is_transpose:
            regressed.append((name, p.shape, dims))
    dense_b, comp_b, _, tf_b, tf_dense = _tree_bytes(params_full, dfl)
    n_total = sum(int(p.size) for p in jax.tree.leaves(params_full)) * 4
    ratio = (dense_b + comp_b) / (7 * n_total)
    floor = f"{tf_b / tf_dense * 7 / 5:.4f}x of 5/7" if tf_dense else "n/a (no transpose-free leaves)"
    print(f"{full.name}: compressed tree streams {ratio:.4f}x of dense-Adam "
          f"bytes (transpose-free floor {floor})")
    if regressed:
        print(f"ROOFLINE REGRESSION: {len(regressed)} leaf/leaves plan a "
              f"materialized transpose: {regressed}")
        return 1
    print("roofline OK: every compressed GPT-small leaf is transpose-free")
    return 0


if __name__ == "__main__":
    import argparse
    import sys

    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", choices=("quick", "full"), default="quick")
    ap.add_argument("--check-roofline", action="store_true",
                    help="planner gate only: fail if any gpt_small leaf transposes")
    args = ap.parse_args()
    if args.check_roofline:
        sys.exit(roofline_check())
    main(args.preset)
    tree_main(args.preset)
