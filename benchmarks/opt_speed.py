"""Optimizer update micro-bench: jnp paths vs fused Pallas kernels
(interpret mode on CPU = correctness harness; the 'tpu_proj_us' column
reports the roofline-projected TPU v5e time from streamed bytes / 819 GB/s).

Two entries:
  * ``main``      — single-tensor kernel micro-bench (p/g/m/v on one leaf);
  * ``tree_main`` — whole-GPT-small-param-tree optimizer step, jnp vs fused
    vs bucketed-fused, with the per-leaf bytes-streamed model summed over
    the tree (the acceptance roofline: fan_in-compressed leaves stream
    5/7 of dense-Adam bytes).
"""
import time

import jax
import jax.numpy as jnp

from repro.analysis.jaxpr_tools import count_pallas_launches
from repro.core import rules_as_tree, table3_rules
from repro.core.slim_adam import scale_by_slim_adam
from repro.kernels import fused_adam_op, slim_update_op
from repro.kernels.ref import adam_update_ref, slim_update_ref
from repro.optim import scale_by_adam

from .common import append_bench_history, emit, write_csv

HBM_BW = 819e9


def timeit(fn, *args, iters=5):
    """(mean_us, min_us) per call. The warm-up result is blocked on so the
    compile/dispatch tail can't leak into the first timed iteration, and each
    iteration is blocked individually so min-of-iters is a real floor."""
    jax.block_until_ready(fn(*args))  # compile + flush dispatch tail
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        times.append(time.perf_counter() - t0)
    return sum(times) / iters * 1e6, min(times) * 1e6


def main(preset: str = "quick"):
    r, c = (1024, 1024) if preset == "quick" else (4096, 8192)
    ks = jax.random.split(jax.random.PRNGKey(0), 4)
    p = jax.random.normal(ks[0], (r, c))
    g = jax.random.normal(ks[1], (r, c)) * 0.1
    m = jnp.zeros((r, c))
    v = jnp.zeros((r, c))
    v_row = jnp.zeros((r, 1))
    kw = dict(lr=1e-3, b1=0.9, b2=0.95, eps=1e-8, wd=0.1, count=1)

    jnp_adam = jax.jit(lambda *a: adam_update_ref(*a, **kw))
    jnp_slim = jax.jit(lambda *a: slim_update_ref(*a, **kw))
    t_jnp_adam = timeit(jnp_adam, p, g, m, v)
    t_jnp_slim = timeit(jnp_slim, p, g, m, v_row)
    t_pal_adam = timeit(lambda *a: fused_adam_op(*a, **kw), p, g, m, v)
    t_pal_slim = timeit(lambda *a: slim_update_op(*a, axis=1, **kw), p, g, m, v_row)

    n = r * c * 4
    adam_bytes = 7 * n              # p,g,m,v read + p,m,v write
    slim_bytes = 5 * n + 2 * r * 4  # v is O(R)
    rows = [
        {"impl": "jnp_adam", "us": round(t_jnp_adam[0], 1), "min_us": round(t_jnp_adam[1], 1),
         "tpu_proj_us": round(adam_bytes / HBM_BW * 1e6, 1)},
        {"impl": "jnp_slim", "us": round(t_jnp_slim[0], 1), "min_us": round(t_jnp_slim[1], 1),
         "tpu_proj_us": round(slim_bytes / HBM_BW * 1e6, 1)},
        {"impl": "pallas_adam(interp)", "us": round(t_pal_adam[0], 1), "min_us": round(t_pal_adam[1], 1),
         "tpu_proj_us": round(adam_bytes / HBM_BW * 1e6, 1)},
        {"impl": "pallas_slim(interp)", "us": round(t_pal_slim[0], 1), "min_us": round(t_pal_slim[1], 1),
         "tpu_proj_us": round(slim_bytes / HBM_BW * 1e6, 1)},
    ]
    write_csv("opt_speed.csv", rows)
    emit("opt_speed", t_jnp_adam[0],
         f"slim streams {slim_bytes/adam_bytes:.2f}x of adam bytes -> "
         f"projected v5e {slim_bytes/HBM_BW*1e6:.1f}us vs {adam_bytes/HBM_BW*1e6:.1f}us per {r}x{c} tensor")
    append_bench_history("opt_speed", {
        "preset": preset, "shape": [r, c],
        "rows": rows, "slim_to_adam_bytes": round(slim_bytes / adam_bytes, 4),
    })
    return rows


def _tree_bytes(params, dims_leaves, *, dense_passes=7, slim_passes=5):
    """Roofline bytes-streamed model for one full-tree optimizer step.

    Defaults model the p-apply form (7 passes dense, 5 + O(kept) slim); the
    GradientTransformation form actually timed in ``tree_main`` (update
    emitted, params untouched) streams 6 / 4 + O(kept) — pass those counts
    so projection and measurement describe the same operation.

    Compressed leaves run transpose-free whenever ``canon_nd`` reaches the
    batched (B, R, C) canonical form by pure reshape — reduced dims trailing
    (minor kernel), leading (major/sublane kernel), *or* sandwiched between
    kept axes (batched major kernel: the kept prefix becomes a batch grid
    dim, which covers every scan-stacked leaf like (layers, embed, heads,
    hd) reducing embed). Only a genuinely interleaved K — the reduced dims
    not forming one contiguous block with kept dims only outside it (e.g. a
    kept dim inside the reduced span) — still needs a boundary
    transpose, and a pallas_call is an optimization barrier, so that
    re-layout materializes (+2 passes per full-size operand: write the copy
    + re-read or re-write it). That traffic is charged here — the 5/7 floor
    holds for every reshape-reachable leaf, batch-reachable ones included.
    Returns (dense_bytes, compressed_bytes, compressed_dense_equiv,
    transpose_free_compressed_bytes, transpose_free_dense_equiv)."""
    from repro.kernels import canon_nd

    dense = compressed = compressed_dense_equiv = 0
    tf_compressed = tf_dense_equiv = 0
    for p, dims in zip(jax.tree.leaves(params), dims_leaves):
        n = int(p.size) * 4
        if dims:
            cn = canon_nd(p.shape, tuple(dims))
            b = slim_passes * n + 2 * cn.kept_size * 4
            if cn.is_transpose:
                # every full-size pass belongs to an operand that must be
                # re-laid out (the O(kept) moment is separate and tiny)
                b += 2 * slim_passes * n
            else:
                tf_compressed += b
                tf_dense_equiv += dense_passes * n
            compressed += b
            compressed_dense_equiv += dense_passes * n
        else:
            dense += dense_passes * n
    return dense, compressed, compressed_dense_equiv, tf_compressed, tf_dense_equiv


def _gpt_small_full_leaves():
    """Named shape-leaves + per-leaf dims/meta for the real 124M GPT-small.

    Shapes via eval_shape (no 124M-param materialization); meta from the
    reduced config, whose tree structure and axis names are identical. One
    derivation shared by the ``tree_main`` headline roofline and the
    ``roofline_check`` CI gates, so the gates validate exactly the leaf set
    the benchmark projects. Returns (full_cfg, params_full, named, dims,
    metas) with ``metas`` aligned leaf-for-leaf with ``named``."""
    from repro.configs import gpt_small
    from repro.core import rules_as_tree, table3_rules
    from repro.core.labels import flatten_with_names

    _, meta = gpt_small.reduced().init(jax.random.PRNGKey(0))
    full = gpt_small.config()
    params_full = jax.eval_shape(lambda k: full.init(k)[0], jax.random.PRNGKey(0))
    dims_full = rules_as_tree(table3_rules(meta), params_full, meta)
    named, _ = flatten_with_names(params_full)
    dfl = [tuple(d) for d in
           jax.tree_util.tree_flatten(params_full)[1].flatten_up_to(dims_full)]
    metas = [m for _, m in flatten_with_names(meta)[0]]
    return full, params_full, named, dfl, metas


def tree_main(preset: str = "quick"):
    """Whole-param-tree optimizer step: jnp vs fused vs bucketed-fused."""
    from repro.configs import gpt_small

    cfg = gpt_small.reduced() if preset == "quick" else gpt_small.config()
    params, meta = cfg.init(jax.random.PRNGKey(0))
    grads = jax.tree.map(
        lambda p: 0.1 * jax.random.normal(jax.random.PRNGKey(p.size % 97), p.shape), params)
    rules = table3_rules(meta)
    dims = rules_as_tree(rules, params, meta)
    dims_leaves = [tuple(d) for d in
                   jax.tree_util.tree_flatten(params)[1].flatten_up_to(dims)]

    # The per-leaf fused setups pin megakernel=False — they measure the
    # O(leaves) dispatch the megaplan replaced; *_fused_mega is the default
    # grouped path (O(groups) launches, see the `launches` column).
    setups = [
        ("adam_jnp", scale_by_adam(0.9, 0.95, 1e-8)),
        ("adam_fused", scale_by_adam(0.9, 0.95, 1e-8, backend="fused",
                                     bucket_min_size=0, megakernel=False)),
        ("adam_fused_bucketed", scale_by_adam(0.9, 0.95, 1e-8, backend="fused",
                                              megakernel=False)),
        ("adam_fused_mega", scale_by_adam(0.9, 0.95, 1e-8, backend="fused")),
        ("slim_jnp", scale_by_slim_adam(dims, 0.9, 0.95, 1e-8)),
        ("slim_fused", scale_by_slim_adam(dims, 0.9, 0.95, 1e-8, backend="fused",
                                          bucket_min_size=0, megakernel=False)),
        ("slim_fused_bucketed", scale_by_slim_adam(dims, 0.9, 0.95, 1e-8,
                                                   backend="fused",
                                                   megakernel=False)),
        ("slim_fused_mega", scale_by_slim_adam(dims, 0.9, 0.95, 1e-8,
                                               backend="fused")),
    ]

    # The timed op is tx.update — the GradientTransformation form (update
    # emitted, params untouched): 6 passes dense, 4 + O(rows) slim. The CSV
    # projection uses those pass counts so measured-vs-roofline compares the
    # same operation.
    n_total = sum(int(p.size) for p in jax.tree.leaves(params)) * 4
    adam_bytes = 6 * n_total
    dense_b, comp_b, *_ = _tree_bytes(params, dims_leaves, dense_passes=6, slim_passes=4)
    slim_bytes = dense_b + comp_b

    rows = []
    for name, tx in setups:
        state = tx.init(params)
        step = jax.jit(lambda g, s, tx=tx: tx.update(g, s))
        t_mean, t_min = timeit(step, grads, state, iters=3)
        b = adam_bytes if name.startswith("adam") else slim_bytes
        rows.append({"impl": name, "us": round(t_mean, 1), "min_us": round(t_min, 1),
                     "launches": count_pallas_launches(step, grads, state),
                     "bytes": b, "tpu_proj_us": round(b / HBM_BW * 1e6, 1)})
    write_csv("opt_speed_tree.csv", rows)

    # Headline roofline for the full AdamW *apply* form (7 passes dense,
    # 5 + O(kept) slim — the paper's 5-vs-7 claim) on the real GPT-small
    # regardless of preset.
    full, params_full, _, dfl, _ = _gpt_small_full_leaves()
    fdense_b, fcomp_b, _, ftf_b, ftf_dense = _tree_bytes(params_full, dfl)
    f_adam = 7 * sum(int(p.size) for p in jax.tree.leaves(params_full)) * 4
    f_slim = fdense_b + fcomp_b
    tf_ratio = ftf_b / ftf_dense if ftf_dense else 1.0
    # Track the implementation this benchmark exists for: the default fused
    # slim step — the megaplan-grouped path since the O(1)-launch rework (a
    # fused-path regression must move the trajectory metric).
    fused_us = next(r["us"] for r in rows if r["impl"] == "slim_fused_mega")
    emit("opt_speed_tree", fused_us,
         f"{full.name} full-apply form: fused tree step streams {f_slim/f_adam:.2f}x "
         f"of dense-Adam bytes (re-layout traffic charged only for genuinely "
         f"interleaved-K leaves); transpose-free compressed leaves — fan_in "
         f"via the minor kernel, fan_out via the major/sublane kernel, "
         f"scan-stacked middle-K via the batched major kernel — hit the "
         f"5/7={5/7:.3f} tensor-pass floor ({tf_ratio:.3f}x bytes incl. "
         f"O(kept) reduced moments) -> "
         f"projected v5e {f_slim/HBM_BW*1e3:.2f}ms vs {f_adam/HBM_BW*1e3:.2f}ms")
    append_bench_history("opt_speed_tree", {
        "preset": preset, "rows": rows,
        "full_apply_slim_to_adam_bytes": round(f_slim / f_adam, 5),
        "transpose_free_ratio": round(tf_ratio, 5),
    })
    return rows


def roofline_check() -> int:
    """CI gate (`make bench-roofline`): run the opt_speed_tree byte model
    over the *full* GPT-small leaf set and fail if any compressed leaf
    regresses to a transposing plan (``is_transpose=True``) — i.e. if the
    planner stops reaching the batched canonical form for the scan-stacked
    leaves, or either 2-D orientation for the rest. Analytic (eval_shape +
    planner); no kernels run, so it is interpret-mode safe and fast."""
    from repro.kernels import canon_nd

    full, params_full, named, dfl, _ = _gpt_small_full_leaves()
    regressed = []
    for (name, p), dims in zip(named, dfl):
        if not dims:
            continue
        cn = canon_nd(p.shape, dims)
        tag = f"batch={cn.batch}" if cn.batch > 1 else cn.orientation
        print(f"  {name:45s} {str(p.shape):22s} K={dims} -> {tag}"
              + (" TRANSPOSE" if cn.is_transpose else ""))
        if cn.is_transpose:
            regressed.append((name, p.shape, dims))
    dense_b, comp_b, _, tf_b, tf_dense = _tree_bytes(params_full, dfl)
    n_total = sum(int(p.size) for p in jax.tree.leaves(params_full)) * 4
    ratio = (dense_b + comp_b) / (7 * n_total)
    floor = f"{tf_b / tf_dense * 7 / 5:.4f}x of 5/7" if tf_dense else "n/a (no transpose-free leaves)"
    print(f"{full.name}: compressed tree streams {ratio:.4f}x of dense-Adam "
          f"bytes (transpose-free floor {floor})")
    if regressed:
        print(f"ROOFLINE REGRESSION: {len(regressed)} leaf/leaves plan a "
              f"materialized transpose: {regressed}")
        return 1
    print("roofline OK: every compressed GPT-small leaf is transpose-free")
    return 0


# The megakernel launch gate: GPT-small's whole-tree update must run in at
# most this many pallas launches (the O(leaves) -> O(groups) claim; both the
# reduced and the full config plan well under it — 1 adam group, 4 slim).
_GATE_MAX_LAUNCHES = 8


def launch_check() -> int:
    """CI gate (`scripts/ci.sh bench-roofline`): the megakernel O(1)-launch
    claim, decided on the jaxpr (``count_pallas_launches``) rather than
    interp-mode wall clocks. Fails when the default fused tree update emits
    more pallas launches than its megaplan has groups, when it exceeds
    ``_GATE_MAX_LAUNCHES``, or when grouping stops strictly beating the
    per-leaf dispatch. Wall clock is gated only on a real TPU backend
    (fused step must not be slower than jnp); interp runs record the
    roofline-projected TPU step times instead. On failure the megaplan
    group tables are dumped to ``results/megaplan_groups.csv`` as the CI
    artifact."""
    from repro.configs import gpt_small
    from repro.kernels.megaplan import plan_megagroups
    from repro.kernels.slim_update import PRECOND_BUFS

    cfg = gpt_small.reduced()
    params, meta = cfg.init(jax.random.PRNGKey(0))
    dims = rules_as_tree(table3_rules(meta), params, meta)
    treedef = jax.tree_util.tree_flatten(params)[1]
    dims_leaves = [tuple(d) for d in treedef.flatten_up_to(dims)]
    grads = jax.tree.map(lambda p: 0.1 * jnp.ones(p.shape, p.dtype), params)
    leaves = jax.tree.leaves(params)
    shapes = tuple(tuple(p.shape) for p in leaves)
    dts = tuple(str(p.dtype) for p in leaves)
    plans = {
        "adam": plan_megagroups(shapes, dts, tuple(() for _ in leaves),
                                n_bufs=PRECOND_BUFS),
        "slim": plan_megagroups(shapes, dts, tuple(dims_leaves),
                                n_bufs=PRECOND_BUFS),
    }

    txs = {
        "adam_mega": scale_by_adam(0.9, 0.95, 1e-8, backend="fused"),
        "adam_perleaf": scale_by_adam(0.9, 0.95, 1e-8, backend="fused",
                                      megakernel=False, bucket_min_size=0),
        "adam_jnp": scale_by_adam(0.9, 0.95, 1e-8),
        "slim_mega": scale_by_slim_adam(dims, 0.9, 0.95, 1e-8, backend="fused"),
        "slim_perleaf": scale_by_slim_adam(dims, 0.9, 0.95, 1e-8,
                                           backend="fused", megakernel=False,
                                           bucket_min_size=0),
        "slim_jnp": scale_by_slim_adam(dims, 0.9, 0.95, 1e-8),
    }
    counts = {}
    for name, tx in txs.items():
        state = tx.init(params)
        counts[name] = count_pallas_launches(
            lambda g, s, tx=tx: tx.update(g, s), grads, state)

    bad = []
    for opt in ("adam", "slim"):
        mega, per = counts[f"{opt}_mega"], counts[f"{opt}_perleaf"]
        bound = len(plans[opt].groups)
        print(f"  {opt}: megakernel {mega} launches (megaplan groups {bound}),"
              f" per-leaf {per}, leaves {len(leaves)}, jnp {counts[opt + '_jnp']}")
        if counts[opt + "_jnp"]:
            bad.append(f"{opt}_jnp traces {counts[opt + '_jnp']} pallas "
                       f"launches — the jnp baseline must stay kernel-free")
        if mega > bound:
            bad.append(f"{opt} megakernel step emits {mega} launches > its "
                       f"megaplan's {bound} groups — a group degraded or the "
                       f"dispatcher double-launches")
        if mega > _GATE_MAX_LAUNCHES:
            bad.append(f"{opt} megakernel step emits {mega} launches > the "
                       f"GPT-small bound {_GATE_MAX_LAUNCHES}")
        if per > bound and mega >= per:
            bad.append(f"{opt} megakernel step ({mega} launches) no longer "
                       f"beats the per-leaf dispatch ({per})")

    # Wall-clock gate: only meaningful where kernels compile (interp-mode
    # pallas on CPU is a correctness harness, orders of magnitude off).
    n_total = sum(int(p.size) for p in leaves) * 4
    dense_b, comp_b, *_ = _tree_bytes(params, dims_leaves,
                                      dense_passes=6, slim_passes=4)
    proj = {"adam": 6 * n_total / HBM_BW * 1e6,
            "slim": (dense_b + comp_b) / HBM_BW * 1e6}
    measured = {}
    if jax.default_backend() == "tpu":
        for opt in ("adam", "slim"):
            t_fused = t_jnp = None
            for kind in ("mega", "jnp"):
                tx = txs[f"{opt}_{kind}"]
                state = tx.init(params)
                step = jax.jit(lambda g, s, tx=tx: tx.update(g, s))
                t = timeit(step, grads, state, iters=3)[1]
                measured[f"{opt}_{kind}_min_us"] = round(t, 1)
                t_fused, t_jnp = (t, t_jnp) if kind == "mega" else (t_fused, t)
            print(f"  {opt}: fused {t_fused:.1f}us vs jnp {t_jnp:.1f}us "
                  f"(projected {proj[opt]:.1f}us)")
            if t_fused > t_jnp:
                bad.append(f"{opt} fused step ({t_fused:.1f}us) slower than "
                           f"jnp ({t_jnp:.1f}us) on the TPU backend")
    else:
        print(f"  backend '{jax.default_backend()}': wall-clock gate skipped "
              f"(interp-mode kernels); projected v5e step times "
              f"adam {proj['adam']:.1f}us, slim {proj['slim']:.1f}us")

    append_bench_history("opt_speed_launches", {
        "config": cfg.name, "leaves": len(leaves), "launches": counts,
        "groups": {opt: len(p.groups) for opt, p in plans.items()},
        "max_launches_gate": _GATE_MAX_LAUNCHES,
        "proj_us": {k: round(v, 1) for k, v in proj.items()},
        **({"measured": measured} if measured else {}),
    })
    if bad:
        art = write_csv("megaplan_groups.csv", [
            {"plan": opt, "group": gi, "kind": g.kind, "batch": g.batch,
             "rows": g.rows, "cols": g.cols, "axis": g.axis,
             "leaf": seg.index, "shape": str(seg.shape), "K": str(seg.dims),
             "offset": seg.offset, "length": seg.length}
            for opt, p in plans.items()
            for gi, g in enumerate(p.groups) for seg in g.segments])
        print("LAUNCH GATE FAILURE (megaplan group tables dumped to "
              f"{art}):")
        for b in bad:
            print(f"  {b}")
        return 1
    print(f"launch check OK: megakernel tree update is O(groups) — "
          f"adam {counts['adam_mega']}, slim {counts['slim_mega']} launches "
          f"(<= {_GATE_MAX_LAUNCHES}) vs {len(leaves)} leaves per-leaf")
    return 0


# ---------------------------------------------------------------------------
# Sharded roofline: per-shard HBM bytes + ICI bytes on the production mesh
# ---------------------------------------------------------------------------

# Sharded per-leaf full-size pass counts (the full-apply 7/5 model of
# `_tree_bytes`, regime-adjusted):
#   local  — the unchanged slim kernel on the local shard: 5 passes + O(kept)
#   psum   — still 5, now Pallas-resident end to end: slim_partial_stats
#            (read g, m; write m') -> lax.psum -> slim_finalize (read m';
#            write update), see repro.optim.fused._psum_slim_leaf. The
#            collective itself is ICI traffic, charged separately.
#   jnp    — reference math per shard ('psum_jnp' finalize fallbacks charge
#            the same 5 + the psum ICI); interleaved-K leaves: XLA
#            materializes the g^2 round-trip (+2 local passes), the
#            analogue of the transpose surcharge
#
# O(kept) moment terms: a psum leaf with an owner placement stores v as a
# 1/A owner slice (A = the placed psum-group factor) — the write *and* the
# next step's read are deduped, and the broadcast back to full lines rides
# the partial-sums all-reduce (each shard folds b2*v for its owned lines
# into the payload), so ICI is unchanged. Transient O(kept) line buffers
# around the collective (partial sums, the psum output) are not charged,
# consistent with the PR-4 model.
_SHARDED_PASSES = {"local": 5, "psum": 5, "jnp": 7}

def _snr_stat_lines():
    """Per-regime extra-output counts of the with_snr kernel variants, read
    from the analysis registry's eval_shape signature matrix — the same
    signatures ``python -m repro.analysis`` diffs against
    ``golden_signatures.json``, so the roofline gate and the static checker
    observe one source of truth.

    Returns ({'psum': n, 'local': n, 'jnp': n}, full_size_outputs) where a
    non-empty second element means a with_snr variant grew a full-size
    output (the gate fails on it)."""
    from repro.analysis.registry import snr_stat_lines

    return snr_stat_lines()


def _health_stat_outputs():
    """Extra-output shapes of every kernel's ``with_health`` variant, read
    from the analysis registry (one tiny accumulator per kernel is the
    anomaly-guard O(1) claim; see ``repro.analysis.kernelcheck``'s okept
    check, which enforces the same bound across the whole case matrix).

    Returns a list of (kernel_name, extra_output_shapes); the gate fails if
    any kernel adds more than one extra output or any extra output holds
    more than the 2 health scalars."""
    from repro.analysis.registry import health_stat_outputs

    return health_stat_outputs()

# CI gate ceilings (tightened for the owner-write scheme; see ROADMAP's
# sharded roofline record for the decomposition):
#   compressed-leaf per-shard ratio — the paper-relevant figure: compressed
#   leaves stream ~0.7150x of per-shard dense Adam on the production mesh
#   (5/7 = 0.7143 floor + the O(kept) terms the owner dedupe cannot remove,
#   chiefly embed's non-256-divisible vocab).
_GATE_COMPRESSED_RATIO = 0.716
#   full-tree per-shard ratio — includes the dense K=() leaves (norm scales,
#   pos_embed), whose relative weight is ~3.5x larger per shard than on a
#   single device (embed shards 256x, pos_embed only 16x), which is why
#   this sits above the single-device 0.715 record. 0.72166 achieved.
_GATE_FULL_RATIO = 0.722
#   fused-SNR measure-step delta must stay O(kept): bounded by 4 stat lines
#   per compressed leaf's kept bytes.
_GATE_SNR_LINES = 4


def sharded_roofline(check: bool = False, mesh_shape=(("data", 16), ("model", 16))) -> int:
    """Per-shard byte model for the fused SlimAdam step under shard_map on
    the production (data=16, model=16) mesh.

    Analytic like :func:`roofline_check` — specs come from the production
    rule table over a device-free :class:`repro.sharding.shardspec.SpecMesh`,
    regimes from the same ``plan_sharded_leaf`` the dispatcher runs, HBM
    bytes from local shard shapes, and ICI bytes from the psum lines
    (ring all-reduce: ``2 * (A-1)/A`` of the O(kept_local) stats per hop
    direction, ``ICI_BW_PER_LINK`` in ``repro.launch.mesh``).

    With ``check=True`` this is the CI gate, failing when:

      * any transpose-free leaf streams more than single-device bytes /
        min(per-dim shard counts) — sharding must never *inflate* a shard's
        traffic past an even split of the unsharded leaf;
      * any psum leaf falls back to the jnp finalize (``regime_counts``
        reports 'psum_jnp' > 0) — the psum regime must stay Pallas-resident;
      * the compressed-leaf per-shard ratio exceeds
        ``_GATE_COMPRESSED_RATIO`` or the full-tree ratio exceeds
        ``_GATE_FULL_RATIO`` — the owner-write dedupe must hold;
      * a fused-SNR measure step adds more than ``_GATE_SNR_LINES`` O(kept)
        stat lines per compressed leaf over a plain update step — the
        from-update measurement must stay O(kept);
      * a ``with_health`` kernel variant adds anything beyond one 2-scalar
        accumulator output (``_health_stat_outputs``) — the anomaly guard's
        in-pass stats must stay O(1) bytes per leaf, so the update-step
        byte ratios above are provably unchanged by guarded training.
    """
    import math

    from repro.kernels import canon_nd
    from repro.kernels.slim_update import PRECOND_BUFS
    from repro.launch.mesh import ICI_BW_PER_LINK
    from repro.sharding.logical import ShardingContext
    from repro.sharding.shardspec import (SpecMesh, dim_shards, owner_factor,
                                          plan_sharded_leaf, regime_counts)

    mesh = SpecMesh(dict(mesh_shape))
    ctx = ShardingContext(mesh)
    full, params_full, named, dfl, metas = _gpt_small_full_leaves()
    snr_lines, snr_oversize = _snr_stat_lines()
    health_outputs = _health_stat_outputs()

    rows = []
    failures = []
    plans = []
    tot_hbm = tot_ici = tot_dense_local = 0
    comp_hbm = comp_dense_local = 0
    snr_extra = kept_total = 0
    for (name, p), dims, m in zip(named, dfl, metas):
        shape = tuple(p.shape)
        n_single = math.prod(shape) * 4
        spec = ctx.spec_for(m.axes, shape)
        factors = dim_shards(shape, spec, mesh)
        local_n = math.prod(s // f for s, f in zip(shape, factors)) * 4
        owner = 1
        if not dims:
            single = 7 * n_single
            hbm, ici, regime, tf = 7 * local_n, 0.0, "dense", True
        else:
            plan = plan_sharded_leaf(shape, jnp.float32, dims, spec, mesh,
                                     n_bufs=PRECOND_BUFS)
            plans.append(plan)
            regime = plan.regime
            dset = {d % len(shape) for d in dims}
            kept_local = math.prod(
                s // f for i, (s, f) in enumerate(zip(shape, factors)) if i not in dset) * 4
            cn = canon_nd(shape, dims)
            tf = not cn.is_transpose
            single = 5 * n_single + 2 * (cn.kept_size * 4)
            if not tf:
                single += 2 * 5 * n_single
            # Owner-shard moment storage: the persistent v read + write
            # shrink by the placed psum-group factor; the broadcast rides
            # the existing all-reduce, so ICI is unchanged.
            owner = owner_factor(plan, mesh) if plan.regime == "psum" else 1
            hbm = _SHARDED_PASSES[plan.regime] * local_n + 2 * kept_local // owner
            ici = 0.0
            if plan.regime == "psum":
                a = math.prod(mesh.shape[ax] for ax in plan.psum_axes)
                ici = 2.0 * (a - 1) / a * kept_local
            snr_extra += snr_lines[plan.regime] * kept_local
            kept_total += kept_local
            comp_hbm += hbm
            comp_dense_local += 7 * local_n
        tot_hbm += hbm
        tot_ici += ici
        tot_dense_local += 7 * local_n
        # min over the per-dim shard counts (unsharded dims count 1, so any
        # partially-replicated leaf is bounded by its full single-device
        # bytes — sharding must never inflate a shard's traffic).
        min_shards = min(factors)
        bound = single / min_shards
        ok = (hbm + ici) <= bound
        if tf and not ok:
            failures.append((name, shape, dims, hbm + ici, bound))
        rows.append({
            "name": name, "shape": str(shape), "K": str(dims), "spec": str(spec),
            "regime": regime, "shards": int(math.prod(factors)),
            "owner_dedupe": owner,
            "hbm_bytes_per_shard": int(hbm), "ici_bytes_per_shard": int(ici),
            "single_device_bytes": int(single),
            "bound_bytes": int(bound), "within_bound": ok,
        })
    write_csv("opt_speed_sharded.csv", rows)
    counts = regime_counts(plans)
    n_chips = math.prod(dict(mesh_shape).values())
    ratio = tot_hbm / tot_dense_local
    comp_ratio = comp_hbm / comp_dense_local if comp_dense_local else 1.0
    print(f"{full.name} on {dict(mesh_shape)} ({n_chips} chips): compressed "
          f"regimes {counts}; per-shard HBM {tot_hbm/2**20:.2f} MiB "
          f"({ratio:.4f}x of per-shard dense Adam full-tree; compressed "
          f"leaves {comp_ratio:.4f}x), ICI {tot_ici/2**10:.1f} KiB charged "
          f"separately (psum lines; owner-slice broadcasts ride the same "
          f"all-reduce)")
    print(f"fused-SNR measure step: +{snr_extra/2**10:.1f} KiB O(kept) stat "
          f"lines ({snr_extra/tot_hbm*100:.2f}% of a plain update step; zero "
          f"extra full-size passes)")
    proj_us = (tot_hbm / HBM_BW + tot_ici / ICI_BW_PER_LINK) * 1e6
    emit("opt_speed_sharded", proj_us,
         f"per-shard fused slim step streams {comp_ratio:.4f}x of per-shard "
         f"dense-Adam bytes over the compressed leaves ({ratio:.4f}x full "
         f"tree) on the ({'x'.join(str(v) for v in dict(mesh_shape).values())}) mesh; "
         f"psum ICI traffic {tot_ici/2**10:.1f} KiB/step -> projected v5e "
         f"{proj_us:.1f}us/step/chip")
    append_bench_history("opt_speed_sharded", {
        "mesh": dict(mesh_shape), "hbm_ratio_full_tree": round(ratio, 5),
        "hbm_ratio_compressed": round(comp_ratio, 5),
        "hbm_mib_per_shard": round(tot_hbm / 2**20, 3),
        "ici_kib_per_shard": round(tot_ici / 2**10, 2),
        "proj_us_per_step_chip": round(proj_us, 2),
        "snr_extra_kib": round(snr_extra / 2**10, 2),
        "health_extra_scalars": sum(math.prod(s) for _, shapes in health_outputs
                                    for s in shapes),
        "regimes": counts,
    })
    if check:
        bad = []
        if failures:
            bad.append(f"{len(failures)} transpose-free leaf/leaves exceed "
                       f"single-device bytes / min(shard counts): " +
                       "; ".join(f"{n} {s} K={d}: {g:.0f} > {b:.0f}"
                                 for n, s, d, g, b in failures))
        if counts.get("psum_jnp", 0):
            bad.append(f"{counts['psum_jnp']} psum leaf/leaves regressed to "
                       f"the jnp finalize fallback (regime_counts={counts}) — "
                       f"the psum regime must stay Pallas-resident")
        if comp_ratio > _GATE_COMPRESSED_RATIO:
            bad.append(f"compressed-leaf per-shard ratio {comp_ratio:.4f} > "
                       f"{_GATE_COMPRESSED_RATIO} — owner-write dedupe regressed")
        if ratio > _GATE_FULL_RATIO:
            bad.append(f"full-tree per-shard ratio {ratio:.4f} > {_GATE_FULL_RATIO}")
        if snr_oversize:
            bad.append(f"a with_snr kernel variant emits full-size extra "
                       f"output(s) {snr_oversize} — the from-update SNR must "
                       f"add only O(kept) stat lines")
        if snr_extra > _GATE_SNR_LINES * kept_total:
            bad.append(f"fused-SNR measure-step delta {snr_extra} B "
                       f"({max(snr_lines.values())} stat lines per leaf, from "
                       f"the kernels' with_snr signatures) exceeds "
                       f"{_GATE_SNR_LINES} O(kept) lines "
                       f"({_GATE_SNR_LINES * kept_total} B) — no longer O(kept)")
        health_bad = [(k, shapes) for k, shapes in health_outputs
                      if len(shapes) != 1
                      or any(math.prod(s) > 2 for s in shapes)]
        if health_bad:
            bad.append(f"with_health kernel variant(s) add more than one "
                       f"2-scalar accumulator: {health_bad} — in-pass health "
                       f"must stay O(1) bytes per leaf")
        if bad:
            print("SHARDED ROOFLINE REGRESSION:")
            for b in bad:
                print(f"  {b}")
            return 1
        print(f"sharded roofline OK: per-shard byte bound holds, psum regime "
              f"Pallas-resident ({counts['psum']} leaves, 0 jnp fallbacks), "
              f"compressed ratio {comp_ratio:.4f} <= {_GATE_COMPRESSED_RATIO}, "
              f"fused-SNR delta O(kept), in-pass health O(1)/leaf")
    return 0


def sharded_main(preset: str = "quick"):
    """benchmarks.run entry: table + CSV, no gating (preset-independent —
    the model is analytic over the full GPT-small)."""
    del preset
    sharded_roofline(check=False)


if __name__ == "__main__":
    import argparse
    import sys

    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", choices=("quick", "full"), default="quick")
    ap.add_argument("--check-roofline", action="store_true",
                    help="planner gate only: fail if any gpt_small leaf transposes "
                         "(with --sharded: per-shard byte bound on the production mesh)")
    ap.add_argument("--sharded", action="store_true",
                    help="per-shard HBM + ICI byte model under shard_map on the "
                         "production (data=16, model=16) mesh")
    ap.add_argument("--check-launches", action="store_true",
                    help="megakernel gate: GPT-small tree update must run in "
                         "O(groups) pallas launches (and beat jnp wall-clock "
                         "on a real TPU backend)")
    args = ap.parse_args()
    if args.check_launches:
        sys.exit(launch_check())
    if args.check_roofline:
        sys.exit(sharded_roofline(check=True) if args.sharded else roofline_check())
    if args.sharded:
        sys.exit(sharded_roofline(check=False))
    main(args.preset)
    tree_main(args.preset)
