"""Optimizer update micro-bench: jnp paths vs fused Pallas kernels
(interpret mode on CPU = correctness harness; the 'derived' column reports
the roofline-projected TPU v5e time from streamed bytes / 819 GB/s)."""
import time

import jax
import jax.numpy as jnp

from repro.kernels import fused_adam_op, slim_update_op
from repro.kernels.ref import adam_update_ref, slim_update_ref

from .common import emit, write_csv

HBM_BW = 819e9


def timeit(fn, *args, iters=5):
    fn(*args)  # compile
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e6


def main(preset: str = "quick"):
    r, c = (1024, 1024) if preset == "quick" else (4096, 8192)
    ks = jax.random.split(jax.random.PRNGKey(0), 4)
    p = jax.random.normal(ks[0], (r, c))
    g = jax.random.normal(ks[1], (r, c)) * 0.1
    m = jnp.zeros((r, c))
    v = jnp.zeros((r, c))
    v_row = jnp.zeros((r, 1))
    kw = dict(lr=1e-3, b1=0.9, b2=0.95, eps=1e-8, wd=0.1, count=1)

    jnp_adam = jax.jit(lambda *a: adam_update_ref(*a, **kw))
    jnp_slim = jax.jit(lambda *a: slim_update_ref(*a, **kw))
    t_jnp_adam = timeit(jnp_adam, p, g, m, v)
    t_jnp_slim = timeit(jnp_slim, p, g, m, v_row)
    t_pal_adam = timeit(lambda *a: fused_adam_op(*a, **kw), p, g, m, v)
    t_pal_slim = timeit(lambda *a: slim_update_op(*a, axis=1, **kw), p, g, m, v_row)

    n = r * c * 4
    adam_bytes = 7 * n              # p,g,m,v read + p,m,v write
    slim_bytes = 5 * n + 2 * r * 4  # v is O(R)
    rows = [
        {"impl": "jnp_adam", "us": round(t_jnp_adam, 1), "tpu_proj_us": round(adam_bytes / HBM_BW * 1e6, 1)},
        {"impl": "jnp_slim", "us": round(t_jnp_slim, 1), "tpu_proj_us": round(slim_bytes / HBM_BW * 1e6, 1)},
        {"impl": "pallas_adam(interp)", "us": round(t_pal_adam, 1), "tpu_proj_us": round(adam_bytes / HBM_BW * 1e6, 1)},
        {"impl": "pallas_slim(interp)", "us": round(t_pal_slim, 1), "tpu_proj_us": round(slim_bytes / HBM_BW * 1e6, 1)},
    ]
    write_csv("opt_speed.csv", rows)
    emit("opt_speed", t_jnp_adam,
         f"slim streams {slim_bytes/adam_bytes:.2f}x of adam bytes -> "
         f"projected v5e {slim_bytes/HBM_BW*1e6:.1f}us vs {adam_bytes/HBM_BW*1e6:.1f}us per {r}x{c} tensor")
    return rows


if __name__ == "__main__":
    main()
