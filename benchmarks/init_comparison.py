"""Paper Fig. 9 / App. E: Mitchell init (1/depth residual scaling) yields
higher SNR than torch-default init, especially for residual writers."""
import dataclasses
import time

from .common import emit, gpt_nano, train_once, write_csv


def main(preset: str = "quick"):
    steps = 300 if preset == "quick" else 1000
    t0 = time.time()
    rows = []
    out = {}
    for scheme in ("mitchell", "normal", "torch_default"):
        # the 1/depth residual scaling needs depth to matter: 6 layers
        cfg = dataclasses.replace(gpt_nano(width=96, layers=6), init_scheme=scheme)
        tr = train_once(cfg, "adam", 3e-3, steps=steps, measure_snr=True, snr_every=20)
        avg = tr.snr.averaged()
        best = {p: max(ks.values()) for p, ks in avg.items() if ks}
        resid = [v for p, v in best.items() if "wo" in p or "w_down" in p]
        out[scheme] = sum(resid) / max(len(resid), 1)
        for p, v in best.items():
            rows.append({"init": scheme, "param": p, "best_snr": round(v, 4)})
    write_csv("init_comparison.csv", rows)
    emit("init_comparison", (time.time() - t0) * 1e6 / (3 * steps),
         f"residual-writer SNR: mitchell={out['mitchell']:.2f} "
         f"no-1/depth-scaling={out['normal']:.2f} "
         f"torch_default={out['torch_default']:.2f} "
         f"(paper mechanism: 1/depth residual scaling raises SNR)")
    return out


if __name__ == "__main__":
    main()
