"""Paper Fig. 8 / App. D: larger learning rates lower averaged SNR values
(less compressible) across layer types."""
import time

from .common import emit, gpt_nano, train_once, write_csv


def main(preset: str = "quick"):
    steps = 120 if preset == "quick" else 1000
    lrs = (3e-4, 1e-3, 3e-3, 1e-2) if preset == "quick" else (1e-4, 3e-4, 1e-3, 3e-3, 1e-2)
    cfg = gpt_nano()
    t0 = time.time()
    rows = []
    for lr in lrs:
        tr = train_once(cfg, "adam", lr, steps=steps, measure_snr=True, snr_every=20)
        avg = tr.snr.averaged()
        # best-K SNR averaged over matrix-like params (the paper's K*)
        best = {p: max(ks.values()) for p, ks in avg.items() if ks}
        mean_best = sum(best.values()) / max(len(best), 1)
        rows.append({"lr": lr, "mean_best_snr": round(mean_best, 4),
                     **{f"snr[{p}]": round(v, 3) for p, v in sorted(best.items())[:6]}})
    write_csv("lr_compressibility.csv", rows)
    emit("lr_compressibility", (time.time() - t0) * 1e6 / (len(lrs) * steps),
         "mean best-K SNR by lr: " + " ".join(f"{r['lr']:g}:{r['mean_best_snr']:.2f}" for r in rows))
    return rows


if __name__ == "__main__":
    main()
