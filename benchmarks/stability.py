"""Paper Fig. 11: at large LR SlimAdam tracks Adam's training dynamics
while AdaLayer / Adam-mini destabilize (loss spikes)."""
import time

from .common import emit, gpt_nano, train_once, write_csv


def main(preset: str = "quick"):
    steps = 100 if preset == "quick" else 600
    big_lr = 3e-2
    t0 = time.time()
    rows, spikes = [], {}
    for opt in ("adam", "slim", "adalayer", "adam_mini_v2"):
        tr = train_once(gpt_nano(), opt, big_lr, steps=steps)
        losses = [m["loss"] for m in tr.metrics_log]
        spikes[opt] = (max(losses[i + 1] - losses[i] for i in range(len(losses) - 1))
                       if len(losses) > 1 else 0.0)
        for m in tr.metrics_log:
            rows.append({"optimizer": opt, "step": m["step"], "loss": round(m["loss"], 4)})
    write_csv("stability.csv", rows)
    emit("stability", (time.time() - t0) * 1e6 / (4 * steps),
         "max upward loss jump @lr=3e-2: " +
         " ".join(f"{k}:{v:+.3f}" for k, v in spikes.items()))
    return spikes


if __name__ == "__main__":
    main()
