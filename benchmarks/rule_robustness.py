"""Paper Tables 1-2 + Fig. 30: compression rules are stable across datasets
(tail exponents) and widths; depth-averaged rules match per-layer rules
(our scan-stacked tensors are natively depth-averaged)."""
import time

from repro.core import derive_rules

from .common import emit, gpt_nano, nano_data, train_once, write_csv


def _rules(cfg, alpha, width_note, steps, seed=0):
    data = nano_data(cfg, alpha=alpha, seed=seed)
    tr = train_once(cfg, "adam", 3e-3, steps=steps, data=data,
                    measure_snr=True, snr_every=20)
    return derive_rules(tr.snr.averaged(), tr.meta, cutoff=1.0)


def main(preset: str = "quick"):
    steps = 120 if preset == "quick" else 1000
    t0 = time.time()
    base = _rules(gpt_nano(), alpha=1.2, width_note="w64", steps=steps)
    other_ds = _rules(gpt_nano(), alpha=1.5, width_note="w64", steps=steps)
    wide = _rules(gpt_nano(width=128), alpha=1.2, width_note="w128", steps=steps)

    def diff(a, b):
        keys = set(a) & set(b)
        return sorted(k for k in keys if a[k] != b[k])

    ds_diff = diff(base, other_ds)
    width_diff = diff(base, wide)
    rows = ([{"comparison": "dataset(alpha 1.2 vs 1.5)", "param": k,
              "rule_a": str(base[k]), "rule_b": str(other_ds[k])} for k in ds_diff]
            + [{"comparison": "width(64 vs 128)", "param": k,
                "rule_a": str(base.get(k)), "rule_b": str(wide.get(k))} for k in width_diff])
    write_csv("rule_robustness.csv", rows)
    n = len(base)
    emit("rule_robustness", (time.time() - t0) * 1e6 / (3 * steps),
         f"rule diffs: dataset {len(ds_diff)}/{n}, width {len(width_diff)}/{n} "
         f"(paper: small handful, mostly MLPs)")
    return rows


if __name__ == "__main__":
    main()
