"""Quickstart: swap Adam for SlimAdam on any model in three lines.

    PYTHONPATH=src python examples/quickstart.py [--backend jnp|fused|auto]
"""
import argparse

import jax
import jax.numpy as jnp

from repro.configs import get_reduced
from repro.core import rules_as_tree, second_moment_savings, table3_rules
from repro.core.slim_adam import slim_adam
from repro.data import DataConfig, ZipfLM
from repro.train.step import make_train_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--backend", default="jnp", choices=("jnp", "fused", "auto"),
                    help="optimizer execution backend (fused = Pallas kernels)")
    args = ap.parse_args()

    cfg = get_reduced("smollm_135m")
    params, meta = cfg.init(jax.random.PRNGKey(0))

    # --- the three lines: derive rules, build the optimizer, done -------
    rules = table3_rules(meta)                       # paper Table 3 defaults
    dims = rules_as_tree(rules, params, meta)
    tx = slim_adam(3e-4, dims, backend=args.backend)  # drop-in AdamW recipe
    # ---------------------------------------------------------------------

    s = second_moment_savings(params, meta, rules)
    print(f"model: {cfg.name} ({sum(x.size for x in jax.tree.leaves(params)):,} params)")
    print(f"second moments stored: {s['stored_second_moments']:,.0f} "
          f"of {s['total_second_moments']:,.0f} ({s['saved_fraction']:.1%} saved)")

    data = ZipfLM(DataConfig(vocab_size=cfg.vocab_size, seq_len=32, global_batch=8))
    step = jax.jit(make_train_step(cfg, tx))
    opt = tx.init(params)
    for i in range(20):
        batch = {k: jnp.asarray(v) for k, v in data.batch(i).items()}
        params, opt, metrics = step(params, opt, batch)
    print(f"20 SlimAdam steps: loss {float(metrics['loss']):.3f} "
          f"grad_norm {float(metrics['grad_norm']):.3f}")


if __name__ == "__main__":
    main()
