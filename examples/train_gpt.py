"""End-to-end training driver: GPT + SlimAdam with SNR measurement,
checkpoint/restart and a final rule report.

    PYTHONPATH=src python examples/train_gpt.py --preset cpu --steps 200
    PYTHONPATH=src python examples/train_gpt.py --preset full   # 124M GPT-small
                                                                # (paper recipe;
                                                                #  sized for TPU)
"""
import argparse

from repro.configs import get_config, get_reduced
from repro.core import second_moment_savings
from repro.data import DataConfig, ZipfLM
from repro.train import Trainer, TrainerConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", choices=("cpu", "full"), default="cpu")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--optimizer", default="adam",
                    help="adam (measure SNR) | slim | slim_snr | adam_mini_v2 | ...")
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--ckpt", default="/tmp/repro_gpt_ckpt")
    ap.add_argument("--backend", default="jnp", choices=("jnp", "fused", "auto"),
                    help="optimizer execution backend")
    args = ap.parse_args()

    if args.preset == "full":
        cfg = get_config("gpt_small")          # 124M, paper App. B.1
        seq, batch = 1024, 32
    else:
        cfg = get_reduced("gpt_small")
        seq, batch = 64, 8

    data = ZipfLM(DataConfig(vocab_size=cfg.vocab_size, seq_len=seq, global_batch=batch))
    tc = TrainerConfig(total_steps=args.steps, log_every=max(args.steps // 10, 1),
                       ckpt_every=max(args.steps // 4, 1), ckpt_dir=args.ckpt,
                       measure_snr=(args.optimizer == "adam"), snr_early_every=20,
                       backend=args.backend)
    tr = Trainer(cfg, args.optimizer, args.lr, data, tc)
    if tr.step:
        print(f"resumed from checkpoint at step {tr.step}")
    final = tr.run()
    print("final:", final)

    if args.optimizer == "adam" and tr.snr.count:
        rules = tr.derive_slim_rules(cutoff=1.0)
        s = second_moment_savings(tr.params, tr.meta, rules)
        print(f"SNR-derived SlimAdam rules would save "
              f"{s['saved_fraction']:.1%} of second moments:")
        for name, rule in sorted(rules.items()):
            if rule:
                print(f"  compress {name:50s} along {rule}")


if __name__ == "__main__":
    main()
