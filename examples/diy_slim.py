"""Paper §5 "DIY: Build Your Own Low-Memory Adam": run a short Adam probe
on *your* model, inspect the per-layer SNR table, derive rules, and train
with them — the full workflow on a hybrid MoE model.

    PYTHONPATH=src python examples/diy_slim.py [--backend jnp|fused|auto]
"""
import argparse

from repro.configs import get_reduced
from repro.core import second_moment_savings
from repro.data import DataConfig, ZipfLM
from repro.train import Trainer, TrainerConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--backend", default="jnp", choices=("jnp", "fused", "auto"),
                    help="optimizer execution backend (fused = Pallas kernels)")
    args = ap.parse_args()

    cfg = get_reduced("jamba_v01_52b")   # mamba + attention + MoE in one model
    data = ZipfLM(DataConfig(vocab_size=cfg.vocab_size, seq_len=32, global_batch=4))

    # 1) probe: short Adam run with SNR measurement
    tc = TrainerConfig(total_steps=60, log_every=20, measure_snr=True,
                       snr_early_every=10, backend=args.backend)
    probe = Trainer(cfg, "adam", 3e-3, data, tc)
    probe.run()

    print("time-averaged SNR per candidate dimension (>1 = compressible):")
    for name, ks in sorted(probe.snr.averaged().items()):
        if ks:
            best = max(ks, key=ks.get)
            print(f"  {name:55s} " + " ".join(f"{k}={v:6.2f}" for k, v in ks.items())
                  + f"   -> K*={best}")

    # 2) derive rules at the probe LR, report savings
    rules = probe.derive_slim_rules(cutoff=1.0)
    s = second_moment_savings(probe.params, probe.meta, rules)
    print(f"\nderived rules compress {sum(1 for r in rules.values() if r)}"
          f"/{len(rules)} tensors -> {s['saved_fraction']:.1%} second moments saved")

    # 3) train with the derived rules (SlimAdam)
    slim = Trainer(cfg, "slim_snr", 3e-3, data,
                   TrainerConfig(total_steps=60, log_every=20,
                                 backend=args.backend), rules=rules)
    final = slim.run()
    print(f"SlimAdam(SNR rules) final loss: {final['loss']:.3f}")


if __name__ == "__main__":
    main()
