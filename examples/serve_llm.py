"""Batched serving demo: prefill + decode with KV/SSM caches across
architecture families (dense GQA, pure-SSM, hybrid MoE).

    PYTHONPATH=src python examples/serve_llm.py --arch jamba_v01_52b
"""
import argparse

import jax

from repro.configs import get_reduced
from repro.serve import Engine, ServeConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm_135m")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.0)
    args = ap.parse_args()

    cfg = get_reduced(args.arch)
    params, _ = cfg.init(jax.random.PRNGKey(0))
    eng = Engine(cfg, params, ServeConfig(max_new_tokens=args.new_tokens,
                                          max_seq=64, temperature=args.temperature))
    prompts = jax.random.randint(jax.random.PRNGKey(1), (args.batch, 8), 0, cfg.vocab_size)
    out = eng.generate(prompts)
    print(f"arch={args.arch} cache slots={list(cfg.pattern)}")
    for i, row in enumerate(out):
        toks = list(map(int, row))
        print(f"  req{i}: prompt={toks[:8]} -> generated={toks[8:]}")


if __name__ == "__main__":
    main()
