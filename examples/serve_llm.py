"""Serving demo: the request-level engine over the paged fast path.

Attention-only architectures decode through the paged KV pool (continuous
batching, chunked prefill, per-request sampling); SSM/hybrid archs fall
back to the legacy batch loop behind the same Engine.

    PYTHONPATH=src python examples/serve_llm.py --arch smollm_135m
    PYTHONPATH=src python examples/serve_llm.py --arch jamba_v01_52b  # legacy path
"""
import argparse

import jax
import numpy as np

from repro.configs import get_reduced
from repro.models.transformer import supports_paged
from repro.serve import Engine, Request, ServeConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm_135m")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.0)
    args = ap.parse_args()

    cfg = get_reduced(args.arch)
    params, _ = cfg.init(jax.random.PRNGKey(0))
    eng = Engine(cfg, params, ServeConfig(max_seq=64, page_size=8,
                                          max_slots=4, prefill_chunk=8))
    prompts = np.asarray(jax.random.randint(
        jax.random.PRNGKey(1), (args.batch, 8), 0, cfg.vocab_size))

    if not supports_paged(cfg):
        # legacy fallback keeps the old batch surface working
        eng.sc.max_new_tokens = args.new_tokens
        eng.sc.temperature = args.temperature
        out = eng.generate(prompts)
        print(f"arch={args.arch} path=legacy slots={list(cfg.pattern)}")
        for i, row in enumerate(out):
            toks = list(map(int, row))
            print(f"  req{i}: prompt={toks[:8]} -> generated={toks[8:]}")
        return

    # request-level API: per-request sampling, ragged completions, metrics
    rids = [eng.submit(Request(prompt=p, max_new_tokens=args.new_tokens,
                               temperature=args.temperature, seed=i))
            for i, p in enumerate(prompts)]
    done = eng.run_until_drained()
    print(f"arch={args.arch} path=paged pool={eng.pool.n_pages}x"
          f"{eng.pool.page_size} high_water={eng.pool.high_water} "
          f"prefill_chunks={eng.prefill_chunks} decode_steps={eng.decode_steps}")
    for i, rid in enumerate(rids):
        c = done[rid]
        print(f"  req{i}: prompt={list(map(int, c.prompt))} -> "
              f"generated={list(map(int, c.tokens))} "
              f"[{c.finish_reason}, ttft={c.ttft_s * 1e3:.0f}ms]")


if __name__ == "__main__":
    main()
