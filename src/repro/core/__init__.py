"""Paper core: SNR analysis of Adam's second moments + SlimAdam.

Public API:

    from repro.core import (
        ParamMeta, SNRTracker, measure_tree_snr, derive_rules, table3_rules,
        rules_as_tree, slim_adam, scale_by_slim_adam, second_moment_savings,
    )
"""
from . import baselines
from .labels import ParamMeta, STRUCTURAL_AXES, flatten_with_names, path_str, validate_meta
from .rules import (
    DEFAULT_CUTOFF,
    Rule,
    derive_rules,
    rules_as_tree,
    rules_to_dims,
    second_moment_savings,
    table3_rules,
)
from .slim_adam import ScaleBySlimAdamState, scale_by_slim_adam, second_moment_elements, slim_adam
from .snr import (
    SNRTracker,
    compression_ratio,
    measure_leaf_snr,
    measure_leaf_snr_per_layer,
    measure_tree_snr,
    snr_along_dims,
)

__all__ = [
    "ParamMeta",
    "STRUCTURAL_AXES",
    "flatten_with_names",
    "path_str",
    "validate_meta",
    "SNRTracker",
    "compression_ratio",
    "measure_leaf_snr",
    "measure_leaf_snr_per_layer",
    "measure_tree_snr",
    "snr_along_dims",
    "DEFAULT_CUTOFF",
    "Rule",
    "derive_rules",
    "rules_as_tree",
    "rules_to_dims",
    "second_moment_savings",
    "table3_rules",
    "ScaleBySlimAdamState",
    "scale_by_slim_adam",
    "slim_adam",
    "second_moment_elements",
    "baselines",
]
