"""SlimAdam — the paper's low-memory Adam family (Eq. 2) + the SNR-tuned member.

The second-moment update for a tensor with compression dims K is

    V_{t+1} = b2 * V_t + (1 - b2) * E_K[G_t^2]

with V *stored reduced* over K (we keep the reduced axes as size-1 so the
preconditioner broadcast is free and sharding specs carry over). K = () for a
tensor recovers exact Adam for that tensor; K = all dims recovers AdaLayer.

``scale_by_slim_adam`` takes a pytree of positional reduction-dim tuples (one
per parameter; build it with ``repro.core.rules.rules_as_tree``), so the
transformation itself stays independent of model metadata.
"""
from __future__ import annotations

from typing import Any, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from ..optim import fused
from ..optim.base import (
    GradientTransformation,
    ScalarOrSchedule,
    add_decayed_weights,
    chain,
    clip_by_global_norm,
    resolve_backend,
    scale_by_learning_rate,
)
PyTree = Any
Dims = Tuple[int, ...]


class ScaleBySlimAdamState(NamedTuple):
    count: jnp.ndarray
    mu: PyTree          # first moments, full shape (fp32)
    nu: PyTree          # second moments, reduced over K (size-1 kept dims, fp32)
    # From-update SNR snapshot: a params-structured pytree of scalars (None
    # for K = () leaves), populated only by transformations built with
    # ``emit_snr=True`` — the paper's compressibility diagnostic riding the
    # update pass (SNR_K of b2*V + (1-b2)*g^2) instead of a separate nu
    # read. None (an empty subtree) otherwise, so ordinary states carry no
    # extra leaves.
    snr: PyTree = None
    # In-pass gradient health (emit_health states only; None otherwise — a
    # None field contributes no pytree leaves, so checkpoints/jit layouts of
    # plain states are unchanged). See repro.optim.fused.StepHealth.
    health: object = None


def _reduced_zeros(p: jnp.ndarray, dims: Dims) -> jnp.ndarray:
    shape = tuple(1 if i in set(dims) else s for i, s in enumerate(p.shape))
    return jnp.zeros(shape, jnp.float32)


def second_moment_elements(params: PyTree, dims_tree: PyTree) -> int:
    """Stored second-moment entry count (for memory accounting/tests)."""
    sizes = jax.tree.map(
        lambda p, d: int(_reduced_zeros(p, tuple(d)).size), params, dims_tree,
        is_leaf=lambda x: isinstance(x, tuple),
    )
    return sum(jax.tree.leaves(sizes))


def scale_by_slim_adam(
    dims_tree: PyTree,
    b1: float = 0.9,
    b2: float = 0.95,
    eps: float = 1e-8,
    *,
    use_first_moment: bool = True,
    backend: str = "jnp",
    bucket_min_size: int = fused.DEFAULT_BUCKET_MIN,
    mesh=None,
    param_specs=None,
    emit_snr: bool = False,
    emit_health: bool = False,
    megakernel: bool = True,
) -> GradientTransformation:
    """Adam preconditioner with mean-shared second moments along per-leaf dims.

    ``dims_tree``: pytree with the *same structure as params*, each leaf a
    (possibly empty) tuple of reduction dims. Tuples are static — they shape
    the state pytree at init.

    ``emit_snr=True`` makes each update also measure the from-update SNR of
    every compressed leaf (SNR_K of the dense reconstruction
    ``b2*V + (1-b2)*g^2``) and publish it on ``state.snr`` — on the fused
    backend the stats ride the update kernels' strip loops, so a measure
    step adds only O(kept) HBM traffic over a plain step (the jnp backend
    fuses them into the same XLA pass). Build a *second* transformation with
    this flag for measure steps and reuse the same state: the two update
    functions share state layout apart from ``snr``.

    ``backend`` selects the execution path (``repro.optim.base.BACKENDS``):
    'fused' routes K != () leaves through the slim Pallas kernels (any
    dims-subset, canonicalized transpose-free) and K = () leaves through the
    dense kernel — by default grouped into megaplan super-tensors so a whole
    tree update costs O(groups) ≈ O(1) launches (``megakernel=False``
    restores the per-leaf dispatch with small-leaf bucketing); the jnp path
    remains the per-leaf fallback. State layout is backend-independent.

    ``mesh`` + ``param_specs`` (PartitionSpec pytree mirroring params) make
    the fused backend shard-aware: the tree update runs under ``shard_map``
    with per-leaf regime plans — local kernels where the reduced dims are
    whole per shard, ``lax.psum``-completed reductions where they are split,
    per-shard jnp for interleaved-K-after-sharding leaves (see
    ``repro.sharding.shardspec``). Ignored by the jnp backend, which
    partitions natively under pjit.

    ``emit_health=True`` publishes a :class:`repro.optim.fused.StepHealth`
    on ``state.health`` each update — per-leaf non-finite counts plus the
    finite-masked grad sumsq, accumulated inside the kernels' existing
    passes (see ``repro.train.guard``).
    """
    backend_r = resolve_backend(backend)
    if backend_r == "fused" and (mesh is not None or param_specs is not None):
        from ..sharding.shardspec import normalize_spec_leaves, sharded_pair

        mesh, param_specs = sharded_pair(mesh, param_specs, "scale_by_slim_adam")
    else:
        mesh = None
    # Tuples inside a pytree would be traversed; treat them as leaves by
    # flattening once against params at init/update time.

    def init_fn(params):
        p_leaves, treedef = jax.tree_util.tree_flatten(params)
        d_leaves = treedef.flatten_up_to(dims_tree)
        mu = jax.tree_util.tree_unflatten(
            treedef, [jnp.zeros(p.shape, jnp.float32) for p in p_leaves]
        ) if use_first_moment else None
        nu = jax.tree_util.tree_unflatten(
            treedef, [_reduced_zeros(p, tuple(d)) for p, d in zip(p_leaves, d_leaves)]
        )
        return ScaleBySlimAdamState(count=jnp.zeros([], jnp.int32), mu=mu, nu=nu)

    def update_fn(updates, state, params=None):
        del params
        count = state.count + 1
        g_leaves, treedef = jax.tree_util.tree_flatten(updates)
        d_leaves = [tuple(d) for d in treedef.flatten_up_to(dims_tree)]
        nu_leaves = treedef.flatten_up_to(state.nu)

        unflat = lambda leaves: jax.tree_util.tree_unflatten(treedef, leaves)
        if backend_r == "fused":
            mu_leaves = treedef.flatten_up_to(state.mu) if use_first_moment else None
            spec_leaves = (None if mesh is None else normalize_spec_leaves(
                param_specs, treedef, "scale_by_slim_adam"))
            out = fused.slim_tree_update(
                g_leaves, mu_leaves, nu_leaves, d_leaves, b1=b1, b2=b2,
                eps=eps, count=count, use_first_moment=use_first_moment,
                bucket_min_size=bucket_min_size, mesh=mesh,
                spec_leaves=spec_leaves, emit_snr=emit_snr,
                with_health=emit_health, megakernel=megakernel)
            u, mu_l, nu_l = out[:3]
            return unflat(u), ScaleBySlimAdamState(
                count=count, mu=unflat(mu_l) if use_first_moment else None,
                nu=unflat(nu_l), snr=unflat(out[3]) if emit_snr else None,
                health=out[-1] if emit_health else None)

        # Per-leaf reference math shared with the fused backend's fallback
        # leaves — one definition of the semantics oracle.
        mu_leaves = treedef.flatten_up_to(state.mu) if use_first_moment else [None] * len(g_leaves)
        outs = [fused.jnp_slim_leaf(g, m, v, dims, b1=b1, b2=b2, eps=eps,
                                    count=count, use_first_moment=use_first_moment)
                for g, m, v, dims in zip(g_leaves, mu_leaves, nu_leaves, d_leaves)]
        mu_out = unflat([o[1] for o in outs]) if use_first_moment else None
        snr = None
        if emit_snr:
            snr = unflat([fused.jnp_update_snr_leaf(g, o[2], dims, b2=b2)
                          if dims else None
                          for g, o, dims in zip(g_leaves, outs, d_leaves)])
        health = (fused._health_from_rows([fused.leaf_health(g) for g in g_leaves])
                  if emit_health else None)
        return (
            unflat([o[0] for o in outs]),
            ScaleBySlimAdamState(count=count, mu=mu_out,
                                 nu=unflat([o[2] for o in outs]), snr=snr,
                                 health=health),
        )

    return GradientTransformation(init_fn, update_fn)


def slim_adam(
    learning_rate: ScalarOrSchedule,
    dims_tree: PyTree,
    b1: float = 0.9,
    b2: float = 0.95,
    eps: float = 1e-8,
    weight_decay: float = 0.1,
    grad_clip: Optional[float] = 1.0,
    backend: str = "jnp",
    mesh=None,
    param_specs=None,
    emit_snr: bool = False,
    emit_health: bool = False,
    megakernel: bool = True,
) -> GradientTransformation:
    """Drop-in AdamW recipe with SlimAdam's compressed preconditioner.

    Uses the *same* hyperparameters as Adam — the paper's requirement that
    users can swap optimizers without re-tuning. ``mesh``/``param_specs``/
    ``emit_snr``/``emit_health``/``megakernel`` thread to
    :func:`scale_by_slim_adam` for the shard-aware fused backend, the
    from-update SNR measurement, the in-pass anomaly stats, and the grouped
    launch plan.
    """
    parts = []
    if grad_clip is not None:
        parts.append(clip_by_global_norm(grad_clip))
    parts.append(scale_by_slim_adam(dims_tree, b1=b1, b2=b2, eps=eps, backend=backend,
                                    mesh=mesh, param_specs=param_specs,
                                    emit_snr=emit_snr, emit_health=emit_health,
                                    megakernel=megakernel))
    if weight_decay:
        parts.append(add_decayed_weights(weight_decay, mask=lambda p: jax.tree.map(lambda x: x.ndim >= 2, p)))
    parts.append(scale_by_learning_rate(learning_rate))
    return chain(*parts)
