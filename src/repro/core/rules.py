"""Compression-rule derivation (paper §5: "DIY: Build Your Own Low-Memory Adam").

A *rule* for one parameter is either ``None`` (keep full per-parameter second
moments — plain Adam for that tensor) or a tuple of logical axis names to
average the squared gradients over (stored reduced along those axes).

Two ways to obtain rules:
  * :func:`derive_rules` — from a measured time-averaged SNR dict (the paper's
    prescription: compress along the argmax-SNR candidate iff it clears a
    cutoff; vector-like tensors always stay uncompressed);
  * :func:`table3_rules` — the paper's Table 3 "recommended" static rules, the
    transferable defaults users apply without running their own SNR pass.
"""
from __future__ import annotations

from typing import Any, Dict, Mapping, Optional, Tuple

import jax
import numpy as np

from .labels import flatten_with_names

Rule = Optional[Tuple[str, ...]]

DEFAULT_CUTOFF = 1.0  # SNR >~ 1 <=> signal dominates noise (paper §3)


def derive_rules(
    avg_snr: Mapping[str, Mapping[str, float]],
    meta: Any,
    *,
    cutoff: float = DEFAULT_CUTOFF,
) -> Dict[str, Rule]:
    """SNR-guided rules: argmax-SNR candidate if it exceeds ``cutoff``.

    ``avg_snr`` is ``SNRTracker.averaged()``; keys are dotted param names.
    Scan-stacked tensors carry one SNR per candidate (depth-averaged), which
    the paper shows performs identically to per-layer rules (Fig. 30).
    """
    meta_named, _ = flatten_with_names(meta)
    meta_by_name = dict(meta_named)
    rules: Dict[str, Rule] = {}
    for name, m in meta_by_name.items():
        cands = m.candidate_ks()
        if not cands:  # vector-like: paper leaves uncompressed
            rules[name] = None
            continue
        scores = avg_snr.get(name, {})
        best_label, best_val = None, -np.inf
        for label, axes in cands.items():
            v = float(scores.get(label, -np.inf))
            if v > best_val:
                best_label, best_val = label, v
        if best_label is not None and best_val >= cutoff:
            rules[name] = cands[best_label]
        else:
            rules[name] = None
    return rules


# Paper Table 3 (recommended compression dimensions per layer role). Values
# are 'fan_in' / 'fan_out' / 'both' / None, resolved per-tensor via the meta's
# candidate sets. Roles absent from the table fall back to ``default``.
_TABLE3: Dict[str, Optional[str]] = {
    "attn_q": "fan_in",
    "attn_k": "fan_in",
    "attn_v": "fan_out",
    "attn_o": "fan_out",
    "mlp_up": "fan_out",
    "mlp_gate": "fan_out",
    "mlp_down": "fan_out",
    # Token embedding: compress the embedding dim, never the token dim. In the
    # paper's W:fan_in->fan_out convention the embedding dim is the embedding
    # layer's fan_out and the LM head's fan_in; our metas encode exactly that.
    "token_embedding": "fan_out",
    "lm_head": "fan_in",
    "patch_embed": "fan_in",
    "head": "fan_in",
    # ResNet convs: §3.1.3 shows intermediate convs compress along both dims;
    # fan_in is the conservative default (first-layer-safe per Table 3)
    "conv": "fan_in",
    "norm": None,           # paper: LayerNorm moments are compression-averse
    "bias": None,
    "attn_qkv_bias": None,
    "pos_embedding": None,
    "moe_router": None,     # vector-like per expert; negligible memory
    # SSM family: no paper prior; defaults mirror the MLP findings (in-proj ~
    # up-proj -> fan_out; out-proj ~ down-proj -> fan_out). Scalar-ish SSM
    # params (A_log, D, dt bias, conv) stay uncompressed: vector-like.
    "ssm_in": "fan_out",
    "ssm_out": "fan_out",
    "ssm_x": "fan_in",
    "ssm_dt": "fan_in",
    "ssm_conv": None,
    "ssm_a": None,
    "ssm_d": None,
    "frontend": None,
}


def table3_rules(meta: Any, *, overrides: Optional[Mapping[str, Optional[str]]] = None) -> Dict[str, Rule]:
    """Static rules from paper Table 3, keyed by dotted param name."""
    table = dict(_TABLE3)
    if overrides:
        table.update(overrides)
    meta_named, _ = flatten_with_names(meta)
    rules: Dict[str, Rule] = {}
    for name, m in meta_named:
        cands = m.candidate_ks()
        label = table.get(m.role)
        if not cands or label is None:
            rules[name] = None
        elif label in cands:
            rules[name] = cands[label]
        else:  # e.g. a tensor with only fan_in candidates asked for fan_out
            rules[name] = None
    return rules


def rules_to_dims(rules: Mapping[str, Rule], meta: Any) -> Dict[str, Tuple[int, ...]]:
    """Resolve logical-axis rules to positional reduction dims per param."""
    meta_named, _ = flatten_with_names(meta)
    out: Dict[str, Tuple[int, ...]] = {}
    for name, m in meta_named:
        r = rules.get(name)
        out[name] = m.dims_of(r) if r else ()
    return out


def rules_as_tree(rules: Mapping[str, Rule], params: Any, meta: Any) -> Any:
    """Rebuild a pytree (same structure as params) of positional-dim tuples."""
    dims = rules_to_dims(rules, meta)
    named, treedef = flatten_with_names(params)
    leaves = [dims[name] for name, _ in named]
    return jax.tree_util.tree_unflatten(treedef, leaves)


def second_moment_savings(params: Any, meta: Any, rules: Mapping[str, Rule]) -> Dict[str, float]:
    """Fraction of Adam's second-moment entries eliminated (paper Fig. 10 top)."""
    named, _ = flatten_with_names(params)
    meta_named, _ = flatten_with_names(meta)
    total = 0
    kept = 0
    for (name, p), (_, m) in zip(named, meta_named):
        n = int(np.prod(p.shape)) if p.shape else 1
        total += n
        r = rules.get(name)
        if not r:
            kept += n
            continue
        dims = set(m.dims_of(r))
        k = 1
        for i, s in enumerate(p.shape):
            if i not in dims:
                k *= s
        kept += k
    return {
        "total_second_moments": float(total),
        "stored_second_moments": float(kept),
        "saved_fraction": 1.0 - kept / max(total, 1),
    }
