"""Layer-wise SNR analysis of Adam's second moments (paper Eq. 3-4).

For a second-moment tensor V and compression dims K:

    SNR_K(V) = E_{K'}[ (E_K[V])^2 / Var_K[V] ]

where the inner mean/variance run over K and the outer expectation averages
the ratio over every remaining dim K'. ``SNR_K >~ 1`` means the entries along
K are well represented by their mean -> compressible.

This module is pure-jnp and jit-safe; :class:`SNRTracker` accumulates the
paper's time-averaged SNR (Eq. 4) across measurement steps.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, Mapping, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from ..optim.base import resolve_backend
from .labels import ParamMeta, flatten_with_names

_VAR_EPS = 1e-30  # guards 0/0 for exactly-constant slices; SNR -> huge (compressible)


def snr_along_dims(v: jnp.ndarray, dims: Tuple[int, ...], *, per_remaining_dim: Optional[int] = None,
                   backend: str = "jnp", mesh=None, spec=None) -> jnp.ndarray:
    """SNR_K for positional reduction dims.

    Returns a scalar, or — when ``per_remaining_dim`` names a remaining dim —
    a vector over that dim (used for per-depth curves on scan-stacked params).

    ``backend='fused'`` computes the scalar form through the fused snr_stats
    kernel: one pass over V yields per-row (sum, sum-sq) jointly, so the
    measurement adds a single read of V instead of XLA's separate mean and
    variance reductions. The per-remaining-dim form always runs in jnp.

    ``mesh`` + ``spec`` (the moment's PartitionSpec) run the scalar form
    under ``shard_map`` so the measurement is correct for a sharded V
    instead of silently per-host: reduction lines whole on every shard are
    measured locally and the per-line ratios averaged with a ``lax.pmean``;
    reduction lines *split* across shards compute per-shard partial centered
    stats (the kernels' partial-sums entry point off the fused backend, jnp
    otherwise), rebase them to a mesh-common shift, and ``lax.psum`` before
    the ratio — the one-pass centered-variance trick composes across the
    shard boundary (see ``repro.kernels.ref.rebase_centered_stats``).
    """
    if not dims:
        raise ValueError("K must be non-empty for SNR; K=None means 'no compression'")
    if mesh is not None and spec is not None:
        from ..sharding.shardspec import mesh_is_trivial

        if not mesh_is_trivial(mesh):
            if per_remaining_dim is not None:
                raise ValueError("per-remaining-dim SNR curves are single-device "
                                 "only; pass mesh=None for per-depth reporting")
            return _sharded_snr(v, tuple(dims), spec, mesh, backend)
    if resolve_backend(backend) == "fused" and per_remaining_dim is None:
        # snr_op is the jit-cached centered-stats kernel + finalization (its
        # eps equals _VAR_EPS); only the canonicalization happens here.
        from ..kernels.ops import canon_apply, default_interpret, leaf_plan, snr_op
        from ..kernels.snr_stats import CENTERED_BUFS
        # leaf_plan names whichever batched (B, R, C) layout a pure reshape
        # reaches — trailing K (minor), leading K (major), or a scan-stacked
        # kept/K/kept pattern (batched major) — and gates on VMEM. It routes
        # to jnp when the plan would transpose (an interleaved K would
        # materialize a full re-layout of V across the kernel boundary, ~3x
        # the single read this path promises) or the reduction line can't be
        # strip-tiled at all.
        plan = leaf_plan(v.shape, v.dtype, dims, n_bufs=CENTERED_BUFS,
                         allow_transpose=False)
        if plan.route == "slim":
            v2 = canon_apply(v.astype(jnp.float32), plan.cn)
            return snr_op(v2, axis=plan.cn.axis, interpret=default_interpret())
    v = v.astype(jnp.float32)
    mean = jnp.mean(v, axis=dims, keepdims=True)
    var = jnp.mean(jnp.square(v - mean), axis=dims, keepdims=True)
    ratio = jnp.square(mean) / (var + _VAR_EPS)
    ratio = jnp.squeeze(ratio, axis=dims)
    if per_remaining_dim is None:
        return jnp.mean(ratio)
    # Map the original dim index to its index after squeezing K dims.
    kept = [d for d in range(v.ndim) if d not in dims]
    if per_remaining_dim not in kept:
        raise ValueError(f"dim {per_remaining_dim} was reduced by K={dims}")
    axis_after = kept.index(per_remaining_dim)
    other = tuple(i for i in range(ratio.ndim) if i != axis_after)
    return jnp.mean(ratio, axis=other)


def _psum_line_snr(v_loc: jnp.ndarray, dims: Tuple[int, ...], axes: Tuple[str, ...],
                   red_total: int, backend: str) -> jnp.ndarray:
    """Per-shard body for reduction lines split across ``axes``: partial
    centered stats (kernel or jnp), rebase to a mesh-common shift, psum,
    finalize. Returns the local mean of the completed per-line ratios."""
    from ..kernels.ref import rebase_centered_stats, snr_from_centered_stats, \
        snr_stats_centered_partial_ref

    v32 = v_loc.astype(jnp.float32)
    dset = {d % v32.ndim for d in dims}
    n_loc = 1
    for d in sorted(dset):
        n_loc *= v32.shape[d]
    s1 = s1c = s2c = first = None
    if resolve_backend(backend) == "fused":
        from ..kernels.ops import canon_apply, default_interpret, leaf_plan, snr_partial_op
        from ..kernels.snr_stats import CENTERED_BUFS

        plan = leaf_plan(v32.shape, v32.dtype, tuple(sorted(dset)),
                         n_bufs=CENTERED_BUFS, allow_transpose=False)
        if plan.route == "slim":
            v2 = canon_apply(v32, plan.cn)
            s1, s1c, s2c, first = snr_partial_op(v2, axis=plan.cn.axis,
                                                 interpret=default_interpret())
    if s1 is None:
        s1, s1c, s2c, first = snr_stats_centered_partial_ref(v32, tuple(sorted(dset)))
    # Rebase every shard's centered sums to one common shift before adding
    # them: variance is shift-invariant, but the sums are not.
    shift = jax.lax.pmean(first, axes)
    s1c, s2c = rebase_centered_stats(s1c, s2c, first, shift, n_loc)
    s1 = jax.lax.psum(s1, axes)
    s1c = jax.lax.psum(s1c, axes)
    s2c = jax.lax.psum(s2c, axes)
    return snr_from_centered_stats(s1, s1c, s2c, red_total, eps=_VAR_EPS)


@functools.lru_cache(maxsize=512)
def _sharded_snr_exec(shape: Tuple[int, ...], dtype, dims: Tuple[int, ...], spec,
                      mesh, backend: str):
    """Build (and cache) the jitted shard_map executable for one
    (shape, dtype, dims, spec, mesh, backend) signature. The trainer's
    periodic SNR pass hits the same signatures every measurement step, so
    without this cache each leaf x candidate-K would re-trace a fresh
    shard_map and run its pmean/rebase/psum epilogue op-by-op (the
    single-device path gets the same amortization from the jit-cached
    ``snr_op``)."""
    from jax.sharding import PartitionSpec as P

    from ..sharding.logical import shard_map
    from ..sharding.shardspec import even_spec, owning_axes

    ndim = len(shape)
    dset = {d % ndim for d in dims}
    kept = tuple(i for i in range(ndim) if i not in dset)
    spec_e = even_spec(shape, spec, mesh)
    red_axes = owning_axes(shape, spec, mesh, tuple(sorted(dset)))
    kept_axes = owning_axes(shape, spec, mesh, kept)
    red_total = 1
    for d in sorted(dset):
        red_total *= shape[d]

    def local_fn(v_loc):
        if red_axes:
            s = _psum_line_snr(v_loc, tuple(sorted(dset)), red_axes, red_total, backend)
        else:
            s = snr_along_dims(v_loc, tuple(sorted(dset)), backend=backend)
        # Each shard holds an equal slice of the kept lines, so the global
        # ratio mean is the mean of the per-shard means.
        if kept_axes:
            s = jax.lax.pmean(s, kept_axes)
        return s

    return jax.jit(shard_map(local_fn, mesh=mesh, in_specs=(spec_e,),
                             out_specs=P(), check_rep=False))


def _sharded_snr(v: jnp.ndarray, dims: Tuple[int, ...], spec, mesh, backend: str) -> jnp.ndarray:
    """Scalar SNR_K of a sharded moment via shard_map (see
    :func:`snr_along_dims`). The returned scalar is replicated."""
    ndim = v.ndim
    dset = {d % ndim for d in dims}
    if any(not -ndim <= d < ndim for d in dims) or len(dset) != len(dims):
        raise ValueError(f"bad reduction dims {dims} for shape {v.shape}")
    fn = _sharded_snr_exec(tuple(int(s) for s in v.shape), v.dtype,
                           tuple(sorted(dset)), spec, mesh, backend)
    out = fn(v)
    # Serialize the per-leaf executions: with the jit cache warm, successive
    # leaves' collective programs would otherwise dispatch asynchronously and
    # overlap, which can deadlock XLA's CPU all-reduce rendezvous (distinct
    # executables racing on overlapping device sets). The measurement pass is
    # off the hot path, so blocking per leaf costs nothing that matters.
    if not isinstance(out, jax.core.Tracer):
        out = jax.block_until_ready(out)
    return out


def measure_leaf_snr(v: jnp.ndarray, meta: ParamMeta, *, backend: str = "jnp",
                     mesh=None, spec=None) -> Dict[str, jnp.ndarray]:
    """Scalar SNR per candidate K ('fan_in'/'fan_out'/'both') for one tensor."""
    out: Dict[str, jnp.ndarray] = {}
    for label, axis_names in meta.candidate_ks().items():
        dims = meta.dims_of(axis_names)
        out[label] = snr_along_dims(v, dims, backend=backend, mesh=mesh, spec=spec)
    return out


def measure_leaf_snr_per_layer(v: jnp.ndarray, meta: ParamMeta) -> Dict[str, jnp.ndarray]:
    """Per-depth SNR vectors for scan-stacked tensors (axis 'layers')."""
    if "layers" not in meta.axes:
        return measure_leaf_snr(v, meta)
    layer_dim = meta.axes.index("layers")
    out: Dict[str, jnp.ndarray] = {}
    for label, axis_names in meta.candidate_ks().items():
        dims = meta.dims_of(axis_names)
        out[label] = snr_along_dims(v, dims, per_remaining_dim=layer_dim)
    return out


def measure_tree_snr(nu: Any, meta: Any, *, backend: str = "jnp",
                     mesh=None, param_specs=None, from_update: Any = None,
                     update_dims: Any = None) -> Dict[str, Dict[str, jnp.ndarray]]:
    """{param_name: {K_label: snr}} over a whole second-moment pytree.

    Leaves whose meta marks them vector-like produce an empty dict (the paper
    never compresses them). ``backend='fused'`` runs each candidate's
    mean/var through the one-pass snr_stats kernel.

    ``mesh`` + ``param_specs`` (PartitionSpec pytree mirroring the moment
    tree) measure each leaf under ``shard_map`` so SNR trajectories stay
    correct when the moments live sharded on an FSDP x TP mesh — candidate
    Ks whose dims are split across devices psum their centered stats instead
    of silently measuring per-shard slices.

    ``from_update`` + ``update_dims`` consume SNR scalars that rode the
    optimizer's update pass (``scale_by_slim_adam(emit_snr=True)`` publishes
    them on ``state.snr``; ``update_dims`` is the optimizer's per-leaf
    reduction-dims pytree): for each leaf, the candidate K whose dims equal
    the leaf's update K takes the ridden value — no nu read at all for that
    candidate — and only the remaining candidates fall back to the standard
    measurement. For a SlimAdam run this removes the measure step's extra
    pass over every compressed leaf; K = () leaves (dense-stored moments)
    always use the standard path.
    """
    nu_named, nu_def = flatten_with_names(nu)
    meta_named, _ = flatten_with_names(meta)
    spec_leaves: Any = [None] * len(nu_named)
    if mesh is not None or param_specs is not None:
        from ..sharding.shardspec import normalize_spec_leaves, sharded_pair

        mesh, param_specs = sharded_pair(mesh, param_specs, "measure_tree_snr")
        if mesh is not None:
            spec_leaves = normalize_spec_leaves(param_specs, nu_def,
                                                "measure_tree_snr")
    ridden: Dict[str, Tuple[Any, Tuple[int, ...]]] = {}
    if from_update is not None:
        if update_dims is None:
            raise ValueError("measure_tree_snr: from_update needs update_dims "
                             "(the optimizer's per-leaf reduction-dims pytree)")
        from .labels import path_str

        def named(tree, is_leaf):
            kv = jax.tree_util.tree_flatten_with_path(tree, is_leaf=is_leaf)[0]
            return [(path_str(p), v) for p, v in kv]

        dims_by_name = dict(named(update_dims, lambda x: isinstance(x, tuple)))
        for name, s in named(from_update, lambda x: x is None):
            if s is not None and name in dims_by_name:
                ridden[name] = (s, tuple(dims_by_name[name]))
    out: Dict[str, Dict[str, jnp.ndarray]] = {}
    for (name, v), (_, m), spec in zip(nu_named, meta_named, spec_leaves):
        if name in ridden:
            s_val, s_dims = ridden[name]
            leaf_out: Dict[str, jnp.ndarray] = {}
            for label, axis_names in m.candidate_ks().items():
                dims = tuple(m.dims_of(axis_names))
                if tuple(sorted(d % v.ndim for d in dims)) == \
                        tuple(sorted(d % v.ndim for d in s_dims)):
                    leaf_out[label] = s_val
                else:
                    leaf_out[label] = snr_along_dims(v, dims, backend=backend,
                                                     mesh=mesh, spec=spec)
            out[name] = leaf_out
        else:
            out[name] = measure_leaf_snr(v, m, backend=backend, mesh=mesh, spec=spec)
    return out


@dataclasses.dataclass
class SNRTracker:
    """Accumulates time-averaged SNR (paper Eq. 4) plus full trajectories.

    The paper measures every 100 steps for the first 1000 steps, then every
    1000 steps; ``should_measure`` implements that cadence.
    """

    sums: Dict[str, Dict[str, float]] = dataclasses.field(default_factory=dict)
    count: int = 0
    trajectory: Dict[str, Dict[str, list]] = dataclasses.field(default_factory=dict)
    steps: list = dataclasses.field(default_factory=list)

    @staticmethod
    def should_measure(step: int, early_every: int = 100, late_every: int = 1000, early_until: int = 1000) -> bool:
        if step <= early_until:
            return step % early_every == 0
        return step % late_every == 0

    def update(self, snr_by_param: Mapping[str, Mapping[str, jnp.ndarray]], step: int) -> None:
        self.count += 1
        self.steps.append(int(step))
        for pname, by_k in snr_by_param.items():
            psum = self.sums.setdefault(pname, {})
            ptraj = self.trajectory.setdefault(pname, {})
            for k, v in by_k.items():
                val = float(v)
                psum[k] = psum.get(k, 0.0) + val
                ptraj.setdefault(k, []).append(val)

    def averaged(self) -> Dict[str, Dict[str, float]]:
        """E_t[SNR_K] per parameter per candidate K."""
        if self.count == 0:
            return {}
        return {p: {k: s / self.count for k, s in by_k.items()} for p, by_k in self.sums.items()}


def compression_ratio(meta: ParamMeta, shape: Sequence[int], k_axes: Optional[Tuple[str, ...]]) -> float:
    """Stored-elements fraction for a given compression choice (1.0 = Adam)."""
    if not k_axes:
        return 1.0
    dims = set(meta.dims_of(k_axes))
    kept = 1
    total = 1
    for i, s in enumerate(shape):
        total *= s
        if i not in dims:
            kept *= s
    return kept / total
