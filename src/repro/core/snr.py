"""Layer-wise SNR analysis of Adam's second moments (paper Eq. 3-4).

For a second-moment tensor V and compression dims K:

    SNR_K(V) = E_{K'}[ (E_K[V])^2 / Var_K[V] ]

where the inner mean/variance run over K and the outer expectation averages
the ratio over every remaining dim K'. ``SNR_K >~ 1`` means the entries along
K are well represented by their mean -> compressible.

This module is pure-jnp and jit-safe; :class:`SNRTracker` accumulates the
paper's time-averaged SNR (Eq. 4) across measurement steps.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Mapping, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from .labels import ParamMeta, STRUCTURAL_AXES, flatten_with_names
from ..optim.base import resolve_backend

_VAR_EPS = 1e-30  # guards 0/0 for exactly-constant slices; SNR -> huge (compressible)


def snr_along_dims(v: jnp.ndarray, dims: Tuple[int, ...], *, per_remaining_dim: Optional[int] = None,
                   backend: str = "jnp") -> jnp.ndarray:
    """SNR_K for positional reduction dims.

    Returns a scalar, or — when ``per_remaining_dim`` names a remaining dim —
    a vector over that dim (used for per-depth curves on scan-stacked params).

    ``backend='fused'`` computes the scalar form through the fused snr_stats
    kernel: one pass over V yields per-row (sum, sum-sq) jointly, so the
    measurement adds a single read of V instead of XLA's separate mean and
    variance reductions. The per-remaining-dim form always runs in jnp.
    """
    if not dims:
        raise ValueError("K must be non-empty for SNR; K=None means 'no compression'")
    if resolve_backend(backend) == "fused" and per_remaining_dim is None:
        # snr_op is the jit-cached centered-stats kernel + finalization (its
        # eps equals _VAR_EPS); only the canonicalization happens here.
        from ..kernels.ops import canon_apply, default_interpret, leaf_plan, snr_op
        from ..kernels.snr_stats import CENTERED_BUFS
        # leaf_plan names whichever batched (B, R, C) layout a pure reshape
        # reaches — trailing K (minor), leading K (major), or a scan-stacked
        # kept/K/kept pattern (batched major) — and gates on VMEM. It routes
        # to jnp when the plan would transpose (an interleaved K would
        # materialize a full re-layout of V across the kernel boundary, ~3x
        # the single read this path promises) or the reduction line can't be
        # strip-tiled at all.
        plan = leaf_plan(v.shape, v.dtype, dims, n_bufs=CENTERED_BUFS,
                         allow_transpose=False)
        if plan.route == "slim":
            v2 = canon_apply(v.astype(jnp.float32), plan.cn)
            return snr_op(v2, axis=plan.cn.axis, interpret=default_interpret())
    v = v.astype(jnp.float32)
    mean = jnp.mean(v, axis=dims, keepdims=True)
    var = jnp.mean(jnp.square(v - mean), axis=dims, keepdims=True)
    ratio = jnp.square(mean) / (var + _VAR_EPS)
    ratio = jnp.squeeze(ratio, axis=dims)
    if per_remaining_dim is None:
        return jnp.mean(ratio)
    # Map the original dim index to its index after squeezing K dims.
    kept = [d for d in range(v.ndim) if d not in dims]
    if per_remaining_dim not in kept:
        raise ValueError(f"dim {per_remaining_dim} was reduced by K={dims}")
    axis_after = kept.index(per_remaining_dim)
    other = tuple(i for i in range(ratio.ndim) if i != axis_after)
    return jnp.mean(ratio, axis=other)


def measure_leaf_snr(v: jnp.ndarray, meta: ParamMeta, *, backend: str = "jnp") -> Dict[str, jnp.ndarray]:
    """Scalar SNR per candidate K ('fan_in'/'fan_out'/'both') for one tensor."""
    out: Dict[str, jnp.ndarray] = {}
    for label, axis_names in meta.candidate_ks().items():
        dims = meta.dims_of(axis_names)
        out[label] = snr_along_dims(v, dims, backend=backend)
    return out


def measure_leaf_snr_per_layer(v: jnp.ndarray, meta: ParamMeta) -> Dict[str, jnp.ndarray]:
    """Per-depth SNR vectors for scan-stacked tensors (axis 'layers')."""
    if "layers" not in meta.axes:
        return measure_leaf_snr(v, meta)
    layer_dim = meta.axes.index("layers")
    out: Dict[str, jnp.ndarray] = {}
    for label, axis_names in meta.candidate_ks().items():
        dims = meta.dims_of(axis_names)
        out[label] = snr_along_dims(v, dims, per_remaining_dim=layer_dim)
    return out


def measure_tree_snr(nu: Any, meta: Any, *, backend: str = "jnp") -> Dict[str, Dict[str, jnp.ndarray]]:
    """{param_name: {K_label: snr}} over a whole second-moment pytree.

    Leaves whose meta marks them vector-like produce an empty dict (the paper
    never compresses them). ``backend='fused'`` runs each candidate's
    mean/var through the one-pass snr_stats kernel.
    """
    nu_named, _ = flatten_with_names(nu)
    meta_named, _ = flatten_with_names(meta)
    out: Dict[str, Dict[str, jnp.ndarray]] = {}
    for (name, v), (_, m) in zip(nu_named, meta_named):
        out[name] = measure_leaf_snr(v, m, backend=backend)
    return out


@dataclasses.dataclass
class SNRTracker:
    """Accumulates time-averaged SNR (paper Eq. 4) plus full trajectories.

    The paper measures every 100 steps for the first 1000 steps, then every
    1000 steps; ``should_measure`` implements that cadence.
    """

    sums: Dict[str, Dict[str, float]] = dataclasses.field(default_factory=dict)
    count: int = 0
    trajectory: Dict[str, Dict[str, list]] = dataclasses.field(default_factory=dict)
    steps: list = dataclasses.field(default_factory=list)

    @staticmethod
    def should_measure(step: int, early_every: int = 100, late_every: int = 1000, early_until: int = 1000) -> bool:
        if step <= early_until:
            return step % early_every == 0
        return step % late_every == 0

    def update(self, snr_by_param: Mapping[str, Mapping[str, jnp.ndarray]], step: int) -> None:
        self.count += 1
        self.steps.append(int(step))
        for pname, by_k in snr_by_param.items():
            psum = self.sums.setdefault(pname, {})
            ptraj = self.trajectory.setdefault(pname, {})
            for k, v in by_k.items():
                val = float(v)
                psum[k] = psum.get(k, 0.0) + val
                ptraj.setdefault(k, []).append(val)

    def averaged(self) -> Dict[str, Dict[str, float]]:
        """E_t[SNR_K] per parameter per candidate K."""
        if self.count == 0:
            return {}
        return {p: {k: s / self.count for k, s in by_k.items()} for p, by_k in self.sums.items()}


def compression_ratio(meta: ParamMeta, shape: Sequence[int], k_axes: Optional[Tuple[str, ...]]) -> float:
    """Stored-elements fraction for a given compression choice (1.0 = Adam)."""
    if not k_axes:
        return 1.0
    dims = set(meta.dims_of(k_axes))
    kept = 1
    total = 1
    for i, s in enumerate(shape):
        total *= s
        if i not in dims:
            kept *= s
    return kept / total
