"""Baseline low-memory optimizers the paper compares against (Fig. 1, App. A).

Drop-in-Adam family (constructed from the SlimAdam machinery, since they are
all "share second moments along dims K" specializations — paper §2):
  * :func:`adalayer_rules`          — one second moment per parameter block
  * :func:`adalayer_ln_tl_rules`    — AdaLayer + uncompressed LayerNorm & tied
                                      embedding/LM-head (Zhao et al., 2024)
  * :func:`adam_mini_v1_rules` / :func:`adam_mini_v2_rules` (Zhang et al., 2024b)

Algorithmically-different family (own GradientTransformations):
  * :func:`adafactor`  (Shazeer & Stern, 2018) — factored second moments
  * :func:`sm3`        (Anil et al., 2019) — per-axis max accumulators
  * :func:`lion`       (Chen et al., 2023) — sign momentum
"""
from __future__ import annotations

from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from ..optim.base import (
    GradientTransformation,
    ScalarOrSchedule,
    add_decayed_weights,
    chain,
    clip_by_global_norm,
    scale_by_learning_rate,
)
from .labels import ParamMeta, STRUCTURAL_AXES, flatten_with_names
from .rules import Rule

PyTree = Any


# ---------------------------------------------------------------------------
# Rule-based baselines (members of the low-memory Adam family)
# ---------------------------------------------------------------------------


def _all_eligible(m: ParamMeta) -> Tuple[str, ...]:
    return tuple(a for a in m.axes if a not in STRUCTURAL_AXES)


def adalayer_rules(meta: Any) -> Dict[str, Rule]:
    """One second moment per parameter block (AdaLayer): reduce every
    non-structural axis. Scan-stacked tensors keep one moment per layer —
    matching 'per block' semantics."""
    out: Dict[str, Rule] = {}
    for name, m in flatten_with_names(meta)[0]:
        elig = _all_eligible(m)
        out[name] = elig if elig else None
    return out


def adalayer_ln_tl_rules(meta: Any) -> Dict[str, Rule]:
    """AdaLayer + per-parameter moments for norms and embedding/LM-head."""
    out = adalayer_rules(meta)
    for name, m in flatten_with_names(meta)[0]:
        if m.role in ("norm", "token_embedding", "lm_head", "head"):
            out[name] = None
    return out


def adam_mini_v1_rules(meta: Any) -> Dict[str, Rule]:
    """Adam-mini v1.0.4: one moment per default parameter block, except
    per-parameter embedding/LM-head and per-head attention K/Q."""
    out: Dict[str, Rule] = {}
    for name, m in flatten_with_names(meta)[0]:
        elig = _all_eligible(m)
        if m.role in ("token_embedding", "lm_head", "head"):
            out[name] = None
        elif m.role in ("attn_k", "attn_q"):
            # per-head: reduce everything except the 'heads'/'kv_heads' axis
            keep = {"heads", "kv_heads"}
            r = tuple(a for a in elig if a not in keep)
            out[name] = r if r else None
        else:
            out[name] = elig if elig else None
    return out


def adam_mini_v2_rules(meta: Any) -> Dict[str, Rule]:
    """Adam-mini v1.1.1: one moment per *output neuron* (reduce the input
    dim), except per-head K/Q and per-token-dim embedding/LM-head; norms
    compressed."""
    out: Dict[str, Rule] = {}
    for name, m in flatten_with_names(meta)[0]:
        elig = _all_eligible(m)
        if m.role in ("token_embedding", "lm_head", "head"):
            # one moment per token: reduce the embedding axis
            r = tuple(a for a in m.fan_in + m.fan_out if a == "embed")
            out[name] = r if r else None
        elif m.role in ("attn_k", "attn_q"):
            keep = {"heads", "kv_heads"}
            r = tuple(a for a in elig if a not in keep)
            out[name] = r if r else None
        elif m.role == "norm":
            out[name] = elig if elig else None
        elif not elig:
            out[name] = None
        elif m.fan_in:
            out[name] = tuple(m.fan_in)  # one moment per output neuron
        else:
            out[name] = elig
    return out


# ---------------------------------------------------------------------------
# Adafactor (v1: no momentum; v2: + update EMA), relative_step=False
# ---------------------------------------------------------------------------


class AdafactorState(NamedTuple):
    count: jnp.ndarray
    vr: PyTree   # row stats (factored leaves) or full v (unfactored)
    vc: PyTree   # col stats (factored leaves) or empty placeholder
    mu: PyTree   # update EMA (v2) or None


def _factored(p: jnp.ndarray) -> bool:
    return p.ndim >= 2 and p.shape[-1] > 1 and p.shape[-2] > 1


def adafactor(
    learning_rate: ScalarOrSchedule,
    *,
    decay_rate: float = 0.8,
    eps1: float = 1e-30,
    clip_threshold: float = 1.0,
    momentum: Optional[float] = None,  # v2 uses 0.9
    weight_decay: float = 0.0,
    grad_clip: Optional[float] = 1.0,
) -> GradientTransformation:
    def init_fn(params):
        def vr_init(p):
            return (
                jnp.zeros(p.shape[:-1], jnp.float32)
                if _factored(p)
                else jnp.zeros(p.shape, jnp.float32)
            )

        def vc_init(p):
            return (
                jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32)
                if _factored(p)
                else jnp.zeros((), jnp.float32)
            )

        vr = jax.tree.map(vr_init, params)
        vc = jax.tree.map(vc_init, params)
        mu = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params) if momentum else None
        return AdafactorState(count=jnp.zeros([], jnp.int32), vr=vr, vc=vc, mu=mu)

    def core_update(updates, state, params=None):
        del params
        count = state.count + 1
        t = count.astype(jnp.float32)
        beta2t = 1.0 - jnp.power(t, -decay_rate)

        def leaf(g, vr, vc, mu):
            g = g.astype(jnp.float32)
            g2 = jnp.square(g) + eps1
            if _factored(g):
                new_vr = beta2t * vr + (1 - beta2t) * jnp.mean(g2, axis=-1)
                new_vc = beta2t * vc + (1 - beta2t) * jnp.mean(g2, axis=-2)
                # v_hat = vr vc^T / mean(vr)
                denom = jnp.mean(new_vr, axis=-1, keepdims=True)
                vhat = (new_vr / denom)[..., :, None] * new_vc[..., None, :]
            else:
                new_vr = beta2t * vr + (1 - beta2t) * g2
                new_vc = vc
                vhat = new_vr
            u = g / jnp.sqrt(vhat)
            # update clipping by RMS (Shazeer & Stern eq. 6)
            rms_u = jnp.sqrt(jnp.mean(jnp.square(u))) + 1e-16
            u = u / jnp.maximum(1.0, rms_u / clip_threshold)
            if momentum is not None and mu is not None:
                new_mu = momentum * mu + (1 - momentum) * u
                return new_mu, new_vr, new_vc, new_mu
            return u, new_vr, new_vc, None

        g_leaves, treedef = jax.tree_util.tree_flatten(updates)
        vr_leaves = treedef.flatten_up_to(state.vr)
        vc_leaves = treedef.flatten_up_to(state.vc)
        mu_leaves = treedef.flatten_up_to(state.mu) if state.mu is not None else [None] * len(g_leaves)
        outs = [leaf(g, vr, vc, mu) for g, vr, vc, mu in zip(g_leaves, vr_leaves, vc_leaves, mu_leaves)]
        u = jax.tree_util.tree_unflatten(treedef, [o[0] for o in outs])
        vr = jax.tree_util.tree_unflatten(treedef, [o[1] for o in outs])
        vc = jax.tree_util.tree_unflatten(treedef, [o[2] for o in outs])
        mu = (
            jax.tree_util.tree_unflatten(treedef, [o[3] for o in outs])
            if momentum is not None
            else None
        )
        return u, AdafactorState(count=count, vr=vr, vc=vc, mu=mu)

    core = GradientTransformation(init_fn, core_update)
    parts = []
    if grad_clip is not None:
        parts.append(clip_by_global_norm(grad_clip))
    parts.append(core)
    if weight_decay:
        parts.append(add_decayed_weights(weight_decay, mask=lambda p: jax.tree.map(lambda x: x.ndim >= 2, p)))
    parts.append(scale_by_learning_rate(learning_rate))
    return chain(*parts)


# ---------------------------------------------------------------------------
# SM3 (SM3-II with optional momentum and exponential moving accumulators)
# ---------------------------------------------------------------------------


class SM3State(NamedTuple):
    accs: PyTree   # per-leaf: tuple of per-axis accumulators
    mom: PyTree


def sm3(
    learning_rate: ScalarOrSchedule,
    *,
    momentum: float = 0.9,
    beta: float = 0.95,   # paper App. A: beta=0.95 is best for GPT pre-training
    eps: float = 1e-8,
    weight_decay: float = 0.0,
    grad_clip: Optional[float] = 1.0,
) -> GradientTransformation:
    def acc_shapes(p):
        if p.ndim == 0:
            return (jnp.zeros((), jnp.float32),)
        return tuple(
            jnp.zeros(tuple(s if i == ax else 1 for i, s in enumerate(p.shape)), jnp.float32)
            for ax in range(p.ndim)
        )

    def init_fn(params):
        accs = jax.tree.map(lambda p: acc_shapes(p), params)
        mom = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
        return SM3State(accs=accs, mom=mom)

    def core_update(updates, state, params=None):
        del params

        def leaf(g, accs, m):
            g = g.astype(jnp.float32)
            if g.ndim == 0:
                nu = accs[0]
                new_nu = jnp.maximum(beta * nu, 0.0) + (1 - beta) * jnp.square(g) if beta > 0 else nu + jnp.square(g)
                new_accs = (new_nu,)
                precond = g / (jnp.sqrt(new_nu) + eps)
            else:
                # nu_hat = min over axes of broadcast accumulators
                nu_hat = accs[0]
                for a in accs[1:]:
                    nu_hat = jnp.minimum(nu_hat, a)
                if beta > 0:
                    nu = beta * nu_hat + (1 - beta) * jnp.square(g)
                else:
                    nu = nu_hat + jnp.square(g)
                new_accs = tuple(
                    jnp.max(nu, axis=tuple(i for i in range(g.ndim) if i != ax), keepdims=True)
                    for ax in range(g.ndim)
                )
                precond = g / (jnp.sqrt(nu) + eps)
            new_m = momentum * m + (1 - momentum) * precond
            return new_m, new_accs, new_m

        g_leaves, treedef = jax.tree_util.tree_flatten(updates)
        acc_leaves = treedef.flatten_up_to(state.accs)
        m_leaves = treedef.flatten_up_to(state.mom)
        outs = [leaf(g, a, m) for g, a, m in zip(g_leaves, acc_leaves, m_leaves)]
        u = jax.tree_util.tree_unflatten(treedef, [o[0] for o in outs])
        accs = jax.tree_util.tree_unflatten(treedef, [o[1] for o in outs])
        mom = jax.tree_util.tree_unflatten(treedef, [o[2] for o in outs])
        return u, SM3State(accs=accs, mom=mom)

    core = GradientTransformation(init_fn, core_update)
    parts = []
    if grad_clip is not None:
        parts.append(clip_by_global_norm(grad_clip))
    parts.append(core)
    if weight_decay:
        parts.append(add_decayed_weights(weight_decay, mask=lambda p: jax.tree.map(lambda x: x.ndim >= 2, p)))
    parts.append(scale_by_learning_rate(learning_rate))
    return chain(*parts)


# ---------------------------------------------------------------------------
# Lion
# ---------------------------------------------------------------------------


class LionState(NamedTuple):
    mu: PyTree


def lion(
    learning_rate: ScalarOrSchedule,
    b1: float = 0.9,
    b2: float = 0.95,   # paper App. A: best for the GPT-small experiment
    weight_decay: float = 0.1,
    grad_clip: Optional[float] = 1.0,
) -> GradientTransformation:
    def init_fn(params):
        return LionState(mu=jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params))

    def core_update(updates, state, params=None):
        del params
        # update direction: sign(b1 * m + (1-b1) * g); momentum: b2 EMA
        direction = jax.tree.map(
            lambda m, g: jnp.sign(b1 * m + (1 - b1) * g.astype(jnp.float32)), state.mu, updates
        )
        new_mu = jax.tree.map(lambda m, g: b2 * m + (1 - b2) * g.astype(jnp.float32), state.mu, updates)
        return direction, LionState(mu=new_mu)

    core = GradientTransformation(init_fn, core_update)
    parts = []
    if grad_clip is not None:
        parts.append(clip_by_global_norm(grad_clip))
    parts.append(core)
    if weight_decay:
        parts.append(add_decayed_weights(weight_decay, mask=lambda p: jax.tree.map(lambda x: x.ndim >= 2, p)))
    parts.append(scale_by_learning_rate(learning_rate))
    return chain(*parts)
