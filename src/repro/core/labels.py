"""Parameter metadata: logical axes + paper layer roles.

Every model in ``repro.models`` materializes, alongside its parameter pytree,
a *metadata pytree* of :class:`ParamMeta` with identical structure. One
metadata source powers three consumers:

  * ``repro.core.rules``  — which axes are compression candidates and what the
    paper calls them (token dim vs embedding dim, head-stacked dim, ...);
  * ``repro.sharding``    — logical-axis -> mesh-axis PartitionSpec rules;
  * ``repro.core.snr``    — per-depth reporting for scan-stacked tensors.

Axis-name conventions (logical axes):
  'layers'    scan-stacked depth dim            (structural: never compressed,
                                                 never sharded)
  'experts'   MoE expert dim                    (structural for compression;
                                                 sharded for EP)
  'vocab'     token dimension of embed/lm-head  (the paper's incompressible dim)
  'embed'     residual-stream width
  'heads'/'kv_heads'  attention head dims
  'head_dim'  per-head width
  'mlp'       FFN hidden width
  'qkv','conv_w','state',... arch-specific (see models)
"""
from __future__ import annotations

import dataclasses
from typing import Any, Mapping, Sequence, Tuple

import jax

# Axes that are *structural*: they enumerate independent modules (depth,
# experts), so the paper's intra-matrix mean-sharing never crosses them.
STRUCTURAL_AXES = frozenset({"layers", "experts"})

# Paper layer roles. ``rules.py`` keys its recommended-K table (paper Table 3)
# on these.
ROLES = (
    "token_embedding",
    "lm_head",
    "pos_embedding",
    "attn_q",
    "attn_k",
    "attn_v",
    "attn_o",
    "attn_qkv_bias",
    "mlp_up",
    "mlp_gate",
    "mlp_down",
    "moe_router",
    "norm",
    "bias",
    "ssm_in",        # mamba in_proj (x and z branches)
    "ssm_out",       # mamba out_proj
    "ssm_x",         # x_proj (B, C, dt low-rank)
    "ssm_dt",        # dt_proj
    "ssm_conv",      # depthwise conv1d
    "ssm_a",         # A_log (per-channel state decay)
    "ssm_d",         # D skip
    "patch_embed",   # vision first layer
    "frontend",      # stub modality frontends
    "head",          # generic classification head
    "conv",          # ResNet conv kernels (kh, kw, cin, cout)
)


@dataclasses.dataclass(frozen=True)
class ParamMeta:
    """Static metadata for one parameter tensor."""

    axes: Tuple[str, ...]            # logical axis name per dim (len == ndim)
    role: str                        # one of ROLES
    # Axis names that behave as the paper's fan_in / fan_out for this tensor
    # (in the W: fan_in -> fan_out functional sense, independent of storage
    # order). Compression candidates are fan_in, fan_out, and their union.
    fan_in: Tuple[str, ...] = ()
    fan_out: Tuple[str, ...] = ()

    def __post_init__(self):
        if self.role not in ROLES:
            raise ValueError(f"unknown role {self.role!r}")
        for ax in self.fan_in + self.fan_out:
            if ax not in self.axes:
                raise ValueError(f"candidate axis {ax!r} not in axes {self.axes}")
            if ax in STRUCTURAL_AXES:
                raise ValueError(f"structural axis {ax!r} cannot be a compression candidate")

    @property
    def ndim(self) -> int:
        return len(self.axes)

    @property
    def is_vector_like(self) -> bool:
        """Paper: vector-like moments (norm scales, biases) stay uncompressed."""
        eligible = [a for a in self.axes if a not in STRUCTURAL_AXES]
        return len(eligible) <= 1

    def dims_of(self, names: Sequence[str]) -> Tuple[int, ...]:
        """Resolve logical axis names to positional dims for this tensor."""
        return tuple(i for i, a in enumerate(self.axes) if a in set(names))

    def candidate_ks(self) -> Mapping[str, Tuple[str, ...]]:
        """Compression-candidate axis sets, keyed by the paper's K labels."""
        out: dict[str, Tuple[str, ...]] = {}
        if self.is_vector_like:
            return out
        if self.fan_in:
            out["fan_in"] = tuple(self.fan_in)
        if self.fan_out:
            out["fan_out"] = tuple(self.fan_out)
        if self.fan_in and self.fan_out:
            out["both"] = tuple(self.fan_in) + tuple(self.fan_out)
        return out


def path_str(path) -> str:
    """Human/regex-friendly rendering of a jax key path."""
    parts = []
    for k in path:
        if isinstance(k, jax.tree_util.DictKey):
            parts.append(str(k.key))
        elif isinstance(k, jax.tree_util.SequenceKey):
            parts.append(str(k.idx))
        elif isinstance(k, jax.tree_util.GetAttrKey):
            parts.append(str(k.name))
        else:
            parts.append(str(k))
    return ".".join(parts)


def flatten_with_names(tree: Any):
    """[(name, leaf)] with dotted path names, plus the treedef."""
    leaves, treedef = jax.tree_util.tree_flatten_with_path(tree)
    return [(path_str(p), v) for p, v in leaves], treedef


def validate_meta(params: Any, meta: Any) -> None:
    """Check the metadata tree matches the parameter tree leaf-for-leaf."""
    p_named, p_def = flatten_with_names(params)
    m_named, m_def = flatten_with_names(meta)
    # Meta leaves are dataclasses -> treated as leaves only if registered;
    # ParamMeta is a frozen dataclass, not a pytree, so it is a leaf. Compare
    # structure by names.
    p_names = [n for n, _ in p_named]
    m_names = [n for n, _ in m_named]
    if p_names != m_names:
        missing = set(p_names) ^ set(m_names)
        raise ValueError(f"param/meta tree mismatch; differing leaves: {sorted(missing)[:10]}")
    for (name, p), (_, m) in zip(p_named, m_named):
        if not isinstance(m, ParamMeta):
            raise TypeError(f"{name}: meta leaf is {type(m)}, want ParamMeta")
        if len(m.axes) != p.ndim:
            raise ValueError(f"{name}: meta axes {m.axes} vs param ndim {p.ndim} (shape {p.shape})")
