"""Checkpointing: atomic, sharded, keep-last-k, with mesh-resharding restore.

Layout (one directory per step):

    ckpt_dir/
      step_000100/
        manifest.json        # step, tree structure, leaf shapes/dtypes, rng
        arrays.npz           # flat leaf name -> full (unsharded) array
      step_000200/ ...
      LATEST                 # atomic pointer file

Design notes for scale:
  * arrays are written via a temp dir + atomic rename, so a preemption
    mid-save never corrupts the latest checkpoint (fault tolerance);
  * ``restore(..., shardings=...)`` re-lays arrays onto *any* mesh — a run
    checkpointed on N chips restores onto M (elastic scaling). On a real
    cluster the npz would be a per-host shard file; the manifest logic is
    identical;
  * optimizer states ride along as ordinary pytrees — SlimAdam's reduced
    second moments make the optimizer section ~50% smaller than Adam's,
    which is the paper's saving materialized on disk too.
"""
from __future__ import annotations

import atexit
import json
import os
import shutil
import threading
import weakref
from pathlib import Path
from typing import Any, Dict, List, Optional

import jax
import numpy as np

from ..core.labels import flatten_with_names


def _leaf_names(tree: Any):
    named, treedef = flatten_with_names(tree)
    return named, treedef


def save(ckpt_dir: str | Path, step: int, tree: Any, *, extra: Optional[Dict[str, Any]] = None,
         keep: int = 3) -> Path:
    """Blocking save. Returns the checkpoint path."""
    ckpt_dir = Path(ckpt_dir)
    ckpt_dir.mkdir(parents=True, exist_ok=True)
    final = ckpt_dir / f"step_{step:08d}"
    tmp = ckpt_dir / f".tmp_step_{step:08d}"
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir(parents=True)

    named, _ = _leaf_names(tree)
    arrays = {}
    manifest = {"step": step, "leaves": {}, "extra": extra or {}}
    for name, leaf in named:
        arr = np.asarray(jax.device_get(leaf))
        arrays[name] = arr
        manifest["leaves"][name] = {"shape": list(arr.shape), "dtype": str(arr.dtype)}
    np.savez(tmp / "arrays.npz", **arrays)
    (tmp / "manifest.json").write_text(json.dumps(manifest))
    if final.exists():
        shutil.rmtree(final)
    os.replace(tmp, final)
    # atomic LATEST pointer
    ptr_tmp = ckpt_dir / ".LATEST.tmp"
    ptr_tmp.write_text(final.name)
    os.replace(ptr_tmp, ckpt_dir / "LATEST")
    _gc(ckpt_dir, keep)
    return final


class AsyncCheckpointer:
    """Fire-and-forget background saves (writes serialize behind a lock —
    last writer wins on LATEST).

    Every in-flight thread is tracked: ``wait()`` joins them *all* (not just
    the newest — overlapping saves used to orphan the older thread), and a
    module-level ``atexit`` hook flushes every live checkpointer so the
    daemon threads can't be killed mid-write at interpreter exit (a WeakSet,
    so instances stay collectable)."""

    def __init__(self):
        self._io_lock = threading.Lock()       # serializes the actual writes
        self._reg_lock = threading.Lock()      # guards the in-flight list
        self._threads: List[threading.Thread] = []
        _live_checkpointers.add(self)

    def save(self, ckpt_dir, step, tree, **kw):
        host_tree = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), tree)

        def work():
            with self._io_lock:
                save(ckpt_dir, step, host_tree, **kw)

        t = threading.Thread(target=work, daemon=True)
        with self._reg_lock:
            # prune finished saves so fire-and-forget usage (no wait() until
            # exit) doesn't accumulate one dead Thread per checkpoint
            self._threads = [x for x in self._threads if x.is_alive()]
            # started under the lock so wait() can never join an
            # appended-but-unstarted thread (that raises RuntimeError)
            self._threads.append(t)
            t.start()

    def wait(self):
        """Block until every save issued so far has hit disk."""
        with self._reg_lock:
            pending = list(self._threads)
        for t in pending:
            t.join()
        with self._reg_lock:
            self._threads = [t for t in self._threads if t.is_alive()]


_live_checkpointers: "weakref.WeakSet[AsyncCheckpointer]" = weakref.WeakSet()


def _flush_live_checkpointers():
    for acp in list(_live_checkpointers):
        acp.wait()


atexit.register(_flush_live_checkpointers)


def latest_step(ckpt_dir: str | Path) -> Optional[int]:
    ptr = Path(ckpt_dir) / "LATEST"
    if not ptr.exists():
        return None
    name = ptr.read_text().strip()
    if not (Path(ckpt_dir) / name / "manifest.json").exists():
        return None
    return int(name.split("_")[1])


def restore(ckpt_dir: str | Path, like: Any, *, step: Optional[int] = None,
            shardings: Optional[Any] = None) -> tuple[Any, Dict[str, Any]]:
    """Restore into the structure of ``like`` (a pytree of arrays or
    ShapeDtypeStructs). With ``shardings`` (same-structure NamedSharding
    pytree) each leaf is jax.device_put onto the new mesh — this is the
    elastic-rescale path: the stored arrays are global, so any mesh works."""
    ckpt_dir = Path(ckpt_dir)
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no checkpoint under {ckpt_dir}")
    path = ckpt_dir / f"step_{step:08d}"
    manifest = json.loads((path / "manifest.json").read_text())
    data = np.load(path / "arrays.npz")

    named, treedef = _leaf_names(like)
    if shardings is not None:
        sh_named, _ = _leaf_names(shardings)
        sh_map = dict(sh_named)
    else:
        sh_map = {}
    leaves = []
    for name, proto in named:
        if name not in data:
            raise KeyError(f"checkpoint missing leaf {name}")
        arr = data[name]
        want_shape = tuple(proto.shape)
        if tuple(arr.shape) != want_shape:
            raise ValueError(f"{name}: checkpoint shape {arr.shape} != expected {want_shape}")
        arr = arr.astype(proto.dtype) if hasattr(proto, "dtype") else arr
        if name in sh_map:
            arr = jax.device_put(arr, sh_map[name])
        leaves.append(arr)
    tree = jax.tree_util.tree_unflatten(treedef, leaves)
    return tree, manifest.get("extra", {})


def _gc(ckpt_dir: Path, keep: int):
    steps = sorted(p for p in ckpt_dir.glob("step_*") if p.is_dir())
    for p in steps[:-keep] if keep > 0 else []:
        shutil.rmtree(p, ignore_errors=True)
