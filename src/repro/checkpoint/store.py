"""Checkpointing: atomic, checksummed, keep-last-k, with mesh-resharding
restore and torn-write fallback.

Layout (one directory per step):

    ckpt_dir/
      step_000100/
        manifest.json        # step, leaf shapes/dtypes/crc32s, extra
        arrays.npz           # flat leaf name -> full (unsharded) array
      step_000200/ ...
      LATEST                 # atomic pointer file

Design notes for scale:
  * saves stage into a ``step-<n>.tmp`` dir and ``os.replace`` into place —
    the dash keeps every ``step_*`` consumer (``_gc``, ``latest_step``'s
    fallback scan, a concurrent restore) from ever observing a half-written
    checkpoint, and a preemption mid-save leaves only the tmp dir behind;
  * every leaf carries a crc32 in the manifest; ``restore()`` verifies them
    and, when asked for the newest step, falls back to the newest *valid*
    one instead of crashing on a torn/corrupt write;
  * ``restore(..., shardings=...)`` re-lays arrays onto *any* mesh — a run
    checkpointed on N chips restores onto M (elastic scaling). On a real
    cluster the npz would be a per-host shard file; the manifest logic is
    identical. The atomic tmp-dir protocol is the groundwork for streaming
    per-owner-shard writes (ROADMAP open item 5): each host will stage its
    shard file into the same tmp dir before the single rename publishes;
  * optimizer states ride along as ordinary pytrees — SlimAdam's reduced
    second moments make the optimizer section ~50% smaller than Adam's,
    which is the paper's saving materialized on disk too.
"""
from __future__ import annotations

import atexit
import json
import os
import shutil
import threading
import time
import warnings
import weakref
import zipfile
import zlib
from pathlib import Path
from typing import Any, Dict, List, Optional

import jax
import numpy as np

from .. import injection
from ..core.labels import flatten_with_names

# Test/drill injection point (see repro.train.faults.
# inject_checkpoint_io_failure): fired with the step number at the top of
# every save() attempt through the shared registry (repro.injection), so
# train and serve drills install/uninstall IO faults the same way.
IO_FAULT_POINT = "checkpoint.io"


class ChecksumError(ValueError):
    """A stored leaf's bytes don't match its manifest crc32 (torn write or
    bit rot). Subclasses ValueError so strict callers can catch broadly."""


def _leaf_names(tree: Any):
    named, treedef = flatten_with_names(tree)
    return named, treedef


def save(ckpt_dir: str | Path, step: int, tree: Any, *, extra: Optional[Dict[str, Any]] = None,
         keep: int = 3) -> Path:
    """Blocking save. Returns the checkpoint path.

    Atomic: everything is staged under ``step-<n>.tmp`` (the dash can never
    match the ``step_*`` glob) and published with one ``os.replace``; on any
    failure the tmp dir is removed and no ``step_*`` dir was touched."""
    ckpt_dir = Path(ckpt_dir)
    ckpt_dir.mkdir(parents=True, exist_ok=True)
    injection.fire(IO_FAULT_POINT, step)
    final = ckpt_dir / f"step_{step:08d}"
    tmp = ckpt_dir / f"step-{step:08d}.tmp"
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir(parents=True)
    try:
        named, _ = _leaf_names(tree)
        arrays = {}
        manifest = {"step": step, "leaves": {}, "extra": extra or {}}
        for name, leaf in named:
            arr = np.asarray(jax.device_get(leaf))
            arrays[name] = arr
            manifest["leaves"][name] = {
                "shape": list(arr.shape), "dtype": str(arr.dtype),
                "crc32": zlib.crc32(np.ascontiguousarray(arr).tobytes()),
            }
        np.savez(tmp / "arrays.npz", **arrays)
        (tmp / "manifest.json").write_text(json.dumps(manifest))
        if final.exists():
            shutil.rmtree(final)
        os.replace(tmp, final)
    except BaseException:
        shutil.rmtree(tmp, ignore_errors=True)
        raise
    # atomic LATEST pointer
    ptr_tmp = ckpt_dir / ".LATEST.tmp"
    ptr_tmp.write_text(final.name)
    os.replace(ptr_tmp, ckpt_dir / "LATEST")
    _gc(ckpt_dir, keep)
    return final


class AsyncCheckpointer:
    """Fire-and-forget background saves (writes serialize behind a lock —
    last writer wins on LATEST).

    Every in-flight thread is tracked: ``wait()`` joins them *all* (not just
    the newest — overlapping saves used to orphan the older thread), and a
    module-level ``atexit`` hook flushes every live checkpointer so the
    daemon threads can't be killed mid-write at interpreter exit (a WeakSet,
    so instances stay collectable).

    Fault handling: retryable IO errors (``OSError``) are retried with
    exponential backoff (warning per retry); a save that still fails — or
    fails with any other exception — is *recorded*, and the **first** such
    failure is re-raised as a ``RuntimeError`` naming the failing step on
    the next ``save()``/``wait()`` call (a worker-thread exception used to
    vanish entirely)."""

    def __init__(self, max_retries: int = 2, backoff_s: float = 0.05):
        self.max_retries = max_retries
        self.backoff_s = backoff_s
        self._io_lock = threading.Lock()       # serializes the actual writes
        self._reg_lock = threading.Lock()      # guards in-flight list + failure
        self._threads: List[threading.Thread] = []
        self._failure: Optional[tuple] = None  # (step, exception)
        _live_checkpointers.add(self)

    def _record_failure(self, step, exc):
        with self._reg_lock:
            if self._failure is None:          # first failure wins
                self._failure = (step, exc)

    def _raise_pending(self):
        with self._reg_lock:
            failure, self._failure = self._failure, None
        if failure is not None:
            step, exc = failure
            raise RuntimeError(
                f"async checkpoint save for step {step} failed: {exc!r}") from exc

    def save(self, ckpt_dir, step, tree, **kw):
        self._raise_pending()
        host_tree = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), tree)

        def work():
            with self._io_lock:
                delay = self.backoff_s
                for attempt in range(self.max_retries + 1):
                    try:
                        save(ckpt_dir, step, host_tree, **kw)
                        return
                    except OSError as e:
                        if attempt == self.max_retries:
                            self._record_failure(step, e)
                            return
                        warnings.warn(
                            f"checkpoint save for step {step} hit {e!r}; "
                            f"retrying in {delay:.2f}s "
                            f"({attempt + 1}/{self.max_retries})")
                        time.sleep(delay)
                        delay *= 2
                    except Exception as e:     # non-retryable
                        self._record_failure(step, e)
                        return

        t = threading.Thread(target=work, daemon=True)
        with self._reg_lock:
            # prune finished saves so fire-and-forget usage (no wait() until
            # exit) doesn't accumulate one dead Thread per checkpoint
            self._threads = [x for x in self._threads if x.is_alive()]
            # started under the lock so wait() can never join an
            # appended-but-unstarted thread (that raises RuntimeError)
            self._threads.append(t)
            t.start()

    def wait(self):
        """Block until every save issued so far has hit disk; re-raise the
        first recorded worker failure, if any."""
        with self._reg_lock:
            pending = list(self._threads)
        for t in pending:
            t.join()
        with self._reg_lock:
            self._threads = [t for t in self._threads if t.is_alive()]
        self._raise_pending()


_live_checkpointers: "weakref.WeakSet[AsyncCheckpointer]" = weakref.WeakSet()


def _flush_live_checkpointers():
    for acp in list(_live_checkpointers):
        try:
            acp.wait()
        except RuntimeError as e:
            # interpreter exit: surface the failure without aborting the
            # remaining flushes
            warnings.warn(str(e))


atexit.register(_flush_live_checkpointers)


def _step_dirs(ckpt_dir: Path) -> List[Path]:
    """All ``step_*`` checkpoint dirs, oldest first."""
    return sorted(p for p in ckpt_dir.glob("step_*") if p.is_dir())


def _shallow_valid(path: Path) -> bool:
    return (path / "manifest.json").exists() and (path / "arrays.npz").exists()


def latest_step(ckpt_dir: str | Path) -> Optional[int]:
    """Newest step that at least *looks* complete (manifest + arrays on
    disk; ``restore`` does the deep checksum verification). Prefers the
    LATEST pointer, falls back to scanning ``step_*`` dirs newest-first when
    the pointer is missing, stale, or names a torn dir."""
    ckpt_dir = Path(ckpt_dir)
    ptr = ckpt_dir / "LATEST"
    if ptr.exists():
        name = ptr.read_text().strip()
        if _shallow_valid(ckpt_dir / name):
            return int(name.split("_")[1])
    for path in reversed(_step_dirs(ckpt_dir)):
        if _shallow_valid(path):
            return int(path.name.split("_")[1])
    return None


def _read_verified(path: Path) -> tuple[Dict[str, np.ndarray], Dict[str, Any]]:
    """Storage phase of a restore: read manifest + every array and verify
    the per-leaf crc32s. Raises OSError / BadZipFile / JSONDecodeError /
    ChecksumError on torn or corrupt data — the errors the newest-valid
    fallback treats as 'try the previous step'."""
    manifest = json.loads((path / "manifest.json").read_text())
    with np.load(path / "arrays.npz") as data:
        arrays = {name: data[name] for name in data.files}
    for name, arr in arrays.items():
        want = manifest.get("leaves", {}).get(name, {}).get("crc32")
        if want is None:
            continue  # pre-checksum checkpoint: readable == valid
        got = zlib.crc32(np.ascontiguousarray(arr).tobytes())
        if got != want:
            raise ChecksumError(
                f"{path.name}: leaf {name!r} crc32 {got:#010x} != "
                f"manifest {want:#010x} (torn write or corruption)")
    return arrays, manifest


# Errors _read_verified can raise for bad *storage* (vs a mismatched `like`
# template, which always raises through).
_STORAGE_ERRORS = (OSError, zipfile.BadZipFile, json.JSONDecodeError,
                   zlib.error, ChecksumError, EOFError)


def restore(ckpt_dir: str | Path, like: Any, *, step: Optional[int] = None,
            shardings: Optional[Any] = None) -> tuple[Any, Dict[str, Any]]:
    """Restore into the structure of ``like`` (a pytree of arrays or
    ShapeDtypeStructs). With ``shardings`` (same-structure NamedSharding
    pytree) each leaf is jax.device_put onto the new mesh — this is the
    elastic-rescale path: the stored arrays are global, so any mesh works.

    Every leaf is checksum-verified against the manifest. With ``step=None``
    a torn/corrupt newest checkpoint is *skipped with a warning* and the
    next-newest valid one restored (crash-during-save resilience); an
    explicit ``step`` raises instead. Template mismatches (wrong shape,
    missing leaf) always raise — they mean the caller's ``like`` doesn't
    match this run, not that storage is bad."""
    ckpt_dir = Path(ckpt_dir)
    if step is not None:
        path = ckpt_dir / f"step_{step:08d}"
        arrays, manifest = _read_verified(path)
        return _build_tree(arrays, manifest, like, shardings)

    candidates = list(reversed(_step_dirs(ckpt_dir)))
    if not candidates:
        raise FileNotFoundError(f"no checkpoint under {ckpt_dir}")
    last_err: Optional[Exception] = None
    for path in candidates:
        try:
            arrays, manifest = _read_verified(path)
        except _STORAGE_ERRORS as e:
            warnings.warn(f"checkpoint {path.name} unreadable ({e}); "
                          f"falling back to the previous step")
            last_err = e
            continue
        return _build_tree(arrays, manifest, like, shardings)
    raise FileNotFoundError(
        f"no valid checkpoint under {ckpt_dir} "
        f"({len(candidates)} torn/corrupt candidates; last error: {last_err!r})")


def _build_tree(arrays: Dict[str, np.ndarray], manifest: Dict[str, Any],
                like: Any, shardings: Optional[Any]) -> tuple[Any, Dict[str, Any]]:
    named, treedef = _leaf_names(like)
    if shardings is not None:
        sh_named, _ = _leaf_names(shardings)
        sh_map = dict(sh_named)
    else:
        sh_map = {}
    leaves = []
    for name, proto in named:
        if name not in arrays:
            raise KeyError(f"checkpoint missing leaf {name}")
        arr = arrays[name]
        want_shape = tuple(proto.shape)
        if tuple(arr.shape) != want_shape:
            raise ValueError(f"{name}: checkpoint shape {arr.shape} != expected {want_shape}")
        arr = arr.astype(proto.dtype) if hasattr(proto, "dtype") else arr
        if name in sh_map:
            arr = jax.device_put(arr, sh_map[name])
        leaves.append(arr)
    tree = jax.tree_util.tree_unflatten(treedef, leaves)
    return tree, manifest.get("extra", {})


def _gc(ckpt_dir: Path, keep: int):
    steps = _step_dirs(ckpt_dir)
    for p in steps[:-keep] if keep > 0 else []:
        shutil.rmtree(p, ignore_errors=True)
