"""Serving: request-level engine, paged KV pool, continuous batching.

Serving fast path
-----------------

The fast path replaces per-request dense ``(B, S_max)`` KV caches with a
shared **page pool** per attention slot:

    pool:  (n_pages, page_size, 2 * kv_heads, head_dim)   one per layer slot
    table: (max_slots, max_pages) int32                   page ids per row
    page 0 = reserved null page (padding / inactive-row scatter target)

K and V for one position are fused in one page row (K even / V odd head
indices), so the ragged Pallas decode kernel
(:mod:`repro.kernels.paged_attention`) streams each page with a single
double-buffered block DMA, walking the row's page table via scalar
prefetch. Chunked prefill pushes ``prefill_chunk`` prompt tokens through
the same kernel per step — ``ceil(S/chunk)`` launches instead of ``S``.

The scheduler loop (:mod:`repro.serve.scheduler`) keeps the fixed-shape
device state busy: admit queued requests into free slots when their pages
fit, lazily grow one page per crossed boundary, preempt the youngest
request on pool exhaustion (recompute on re-admit; sampled tokens ride
along as prompt extension), retire on eos/length/wall-budget and return
pages to the freelist *immediately* so waiting requests can join mid-batch.

Migrating from ``generate()``
-----------------------------

Old surface (still works, now a thin deprecated wrapper)::

    Engine(cfg, params, ServeConfig(temperature=0.7)).generate(prompts)

New request-level surface — sampling is per-request, completions are
ragged and carry finish reasons + latency::

    eng = Engine(cfg, params, ServeConfig(max_seq=256, page_size=16))
    rid = eng.submit(Request(prompt=toks, max_new_tokens=64,
                             eos_id=2, temperature=0.7, seed=1))
    for c in eng.run_until_drained().values():
        print(c.finish_reason, c.ttft_s, c.tokens)

Architectures the paged path does not cover (SSM/hybrid mixers, int8 KV)
transparently fall back to the legacy token-by-token batch loop; forcing
``ServeConfig(paged=False)`` turns that loop into a parity oracle for the
fast path (tests/test_serve_paged.py).

Failure handling & SLOs
-----------------------

The serving mirror of the train-side fault substrate (PR 6), at request
granularity. Three principles: *degrade before failing*, *poison one
request, not the batch*, and *every decision is a counter*.

**Deadlines.** ``Request.deadline_s`` is seconds-from-submission. The
engine checks it at the top of every scheduler step: a queued request past
deadline is dropped without ever touching the device; an active one gives
up its slot and pages immediately and completes with
``finish_reason='deadline'`` carrying whatever it generated. Higher
``Request.priority`` admits first (FIFO within a level — a preempted
request requeues by its original submission tick, so it cannot starve).

**Admission control.** With ``ServeConfig.max_queue`` /
``admit_watermark`` set, ``Engine.submit`` returns a :class:`Rejected`
verdict — ``'queue_full'`` at the queue-depth watermark,
``'pool_pressure'`` when the projected page demand of everything queued +
active + the new request exceeds the watermark fraction of pool capacity.
Backpressure is the contract, not an exception; callers shed load or
retry. ``ValueError`` remains reserved for requests that could never run.

**Degradation ladder** (most local first):

1. a failing paged-attention launch (decode step or prefill chunk) serves
   exactly that step through the dense ``paged_attention_ref`` path —
   ``degraded_steps`` counts, one warning total;
2. a non-finite logit row (on-device per-slot health tap, no host vocab
   scan) skips sampling for the poisoned slot only and retires it with
   ``finish_reason='nan'`` — the rest of the batch never notices;
3. wall-budget / deadline overruns truncate that one request
   (``'budget'`` / ``'deadline'``);
4. a no-progress scheduler step triggers deterministic backoff — freeze
   admissions for ``backoff_freeze_steps``, force-retire over-deadline
   slots — and only ``livelock_patience`` consecutive stuck steps raise
   :class:`LivelockError`, which carries the full scheduler/pool counter
   snapshot (queue, per-slot rids, freelist) in its message.

**Metrics.** ``Engine.metrics()`` snapshots a frozen
:class:`ServeMetrics`: gauges (queue depth, active slots, free/used pages,
high-water), scheduler counters (admitted/retired/preempted, step/chunk/
token counts), every fault counter above, and TTFT/TPOT aggregates.
Hot-loop conditions warn only on first occurrence (see
``ServeCounters.warn_once``); recurrence is what the counters are for.

Chaos drill: :class:`ServeFaultPlan` (``serve/faults.py``) injects kernel
failures, poisoned logits, pool squeezes and clock stalls deterministically
through the shared :mod:`repro.injection` registry;
``benchmarks/serve_drill.py`` gates CI on an injected run draining with
greedy parity on unpoisoned requests and zero page leaks.
"""
from .engine import Completion, Engine, Request, ServeConfig
from .faults import ServeFaultPlan, inject_paged_kernel_failure
from .kvpool import KVPool, PoolExhausted
from .metrics import LivelockError, Rejected, ServeCounters, ServeMetrics
from .scheduler import Scheduler

__all__ = ["Engine", "ServeConfig", "Request", "Completion",
           "KVPool", "PoolExhausted", "Scheduler",
           "ServeMetrics", "ServeCounters", "Rejected", "LivelockError",
           "ServeFaultPlan", "inject_paged_kernel_failure"]
