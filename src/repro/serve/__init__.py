"""Serving: request-level engine, paged KV pool, continuous batching.

Serving fast path
-----------------

The fast path replaces per-request dense ``(B, S_max)`` KV caches with a
shared **page pool** per attention slot:

    pool:  (n_pages, page_size, 2 * kv_heads, head_dim)   one per layer slot
    table: (max_slots, max_pages) int32                   page ids per row
    page 0 = reserved null page (padding / inactive-row scatter target)

K and V for one position are fused in one page row (K even / V odd head
indices), so the ragged Pallas decode kernel
(:mod:`repro.kernels.paged_attention`) streams each page with a single
double-buffered block DMA, walking the row's page table via scalar
prefetch. Chunked prefill pushes ``prefill_chunk`` prompt tokens through
the same kernel per step — ``ceil(S/chunk)`` launches instead of ``S``.

The scheduler loop (:mod:`repro.serve.scheduler`) keeps the fixed-shape
device state busy: admit queued requests into free slots when their pages
fit, lazily grow one page per crossed boundary, preempt the youngest
request on pool exhaustion (recompute on re-admit; sampled tokens ride
along as prompt extension), retire on eos/length/wall-budget and return
pages to the freelist *immediately* so waiting requests can join mid-batch.

Migrating from ``generate()``
-----------------------------

Old surface (still works, now a thin deprecated wrapper)::

    Engine(cfg, params, ServeConfig(temperature=0.7)).generate(prompts)

New request-level surface — sampling is per-request, completions are
ragged and carry finish reasons + latency::

    eng = Engine(cfg, params, ServeConfig(max_seq=256, page_size=16))
    rid = eng.submit(Request(prompt=toks, max_new_tokens=64,
                             eos_id=2, temperature=0.7, seed=1))
    for c in eng.run_until_drained().values():
        print(c.finish_reason, c.ttft_s, c.tokens)

Architectures the paged path does not cover (SSM/hybrid mixers, int8 KV)
transparently fall back to the legacy token-by-token batch loop; forcing
``ServeConfig(paged=False)`` turns that loop into a parity oracle for the
fast path (tests/test_serve_paged.py).
"""
from .engine import Completion, Engine, Request, ServeConfig
from .kvpool import KVPool, PoolExhausted
from .scheduler import Scheduler

__all__ = ["Engine", "ServeConfig", "Request", "Completion",
           "KVPool", "PoolExhausted", "Scheduler"]
