"""Deterministic fault injection for the serving engine.

The serving mirror of :mod:`repro.train.faults`: everything is seedless and
counter-indexed, so an injected run is exactly reproducible — which is what
lets ``benchmarks/serve_drill.py`` demand greedy parity between an injected
drain and a clean one. All hooks ride the shared registry
(:mod:`repro.injection`); the engine only *fires* named points, it never
imports this module:

* ``"serve.kernel"``     fired (kind, index) inside the try-block guarding
                         every paged decode/prefill dispatch — raising here
                         forces the engine's per-step degradation to the
                         dense ``paged_attention_ref`` path;
* ``"serve.logits"``     fired (rid, n_generated) before sampling — a
                         truthy return marks the slot's logits poisoned, so
                         the engine skips sampling and retires the request
                         with ``reason="nan"`` exactly as a genuine
                         non-finite health tap would;
* ``"serve.clock"``      fired (sched_step) once per scheduler step — a
                         float return advances the engine's virtual clock,
                         simulating a slow-step stall against deadlines
                         without sleeping in CI;
* ``"serve.step"``       fired (engine, sched_step) at the top of every
                         scheduler step — the pool-squeeze closure uses it
                         to reserve/return freelist pages on schedule.

:meth:`ServeFaultPlan.install` installs one coherent set of closures for
all four points and guarantees squeeze pages return to the freelist on
exit, so a drill can never leak pages into the post-run invariant checks.
"""
from __future__ import annotations

import contextlib
import dataclasses
from typing import List, Optional, Tuple

from .. import injection

KERNEL_POINT = "serve.kernel"
LOGITS_POINT = "serve.logits"
CLOCK_POINT = "serve.clock"
STEP_POINT = "serve.step"


@dataclasses.dataclass(frozen=True)
class ServeFaultPlan:
    """Step/rid-indexed serving fault schedule (all indices 0-based).

    kernel_fail_steps:   decode-step indices whose paged-attention launch
                         raises (the engine must degrade to the ref path
                         for exactly that step);
    prefill_fail_chunks: global prefill-chunk indices that raise likewise;
    poison_rids:         requests whose logits turn non-finite once they
                         have generated ``poison_after`` tokens (the engine
                         must retire them with ``reason='nan'`` instead of
                         emitting garbage);
    squeeze_window:      ``[lo, hi)`` scheduler-step window during which
                         ``squeeze_pages`` pages are held out of the KV
                         freelist — external pool pressure forcing
                         preemption/backoff without any misbehaving request;
    stall_steps:         scheduler steps at which the engine's virtual
                         clock jumps ``stall_s`` seconds — a slow step that
                         blows deadlines deterministically.
    """

    kernel_fail_steps: Tuple[int, ...] = ()
    prefill_fail_chunks: Tuple[int, ...] = ()
    poison_rids: Tuple[int, ...] = ()
    poison_after: int = 1
    squeeze_window: Optional[Tuple[int, int]] = None
    squeeze_pages: int = 0
    stall_steps: Tuple[int, ...] = ()
    stall_s: float = 0.0

    @contextlib.contextmanager
    def install(self, engine):
        """Arm every configured injection against ``engine`` for the scope.
        Injections are visible afterwards in ``engine.metrics()`` —
        ``degraded_steps``, ``nan_retired``/``injected_poison``,
        ``injected_stalls``, and the preemption/backoff counters the
        squeeze provokes."""
        held: List[int] = []

        def kernel_hook(kind: str, index: int) -> None:
            steps = (self.kernel_fail_steps if kind == "decode"
                     else self.prefill_fail_chunks)
            if index in steps:
                raise RuntimeError(
                    f"injected paged-attention failure ({kind} #{index})")

        def logits_hook(rid: int, n_generated: int) -> bool:
            return rid in self.poison_rids and n_generated >= self.poison_after

        def clock_hook(sched_step: int) -> float:
            return self.stall_s if sched_step in self.stall_steps else 0.0

        def step_hook(eng, sched_step: int) -> None:
            if self.squeeze_window is None or self.squeeze_pages <= 0:
                return
            lo, hi = self.squeeze_window
            if sched_step == lo and not held:
                held.extend(eng.pool.reserve(self.squeeze_pages))
            elif sched_step >= hi and held:
                eng.pool.unreserve(held)
                held.clear()

        with contextlib.ExitStack() as stack:
            stack.enter_context(injection.installed(KERNEL_POINT, kernel_hook))
            stack.enter_context(injection.installed(LOGITS_POINT, logits_hook))
            stack.enter_context(injection.installed(CLOCK_POINT, clock_hook))
            stack.enter_context(injection.installed(STEP_POINT, step_hook))
            try:
                yield self
            finally:
                if held:        # run ended inside the squeeze window
                    engine.pool.unreserve(held)
                    held.clear()


@contextlib.contextmanager
def inject_paged_kernel_failure(fail_on: Tuple[int, ...] = (1,)):
    """Make the nth guarded paged-attention dispatch(es) raise (1-based,
    decode and prefill counted together) — the serving analogue of
    :func:`repro.train.faults.inject_kernel_failure`. Yields the shared
    ``calls``/``failed`` counter dict."""
    hook, state = injection.call_counter(
        fail_on, lambda n: RuntimeError(
            f"injected paged-attention failure (dispatch #{n})"))
    with injection.installed(KERNEL_POINT, lambda _kind, _idx: hook()):
        yield state
