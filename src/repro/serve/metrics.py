"""Serving SLO metrics, admission results, and diagnosable failures.

The serving counterpart of the train-side guard counters (PR 6): every
fault-handling decision the engine makes — degraded kernel step, NaN
retirement, deadline expiry, admission rejection, livelock backoff — lands
in a counter here instead of a hot-loop ``warnings.warn`` (which Python
dedups to one line per process, hiding recurrence). The engine's
:meth:`~repro.serve.engine.Engine.metrics` snapshots everything into a
frozen :class:`ServeMetrics` so drills and dashboards read one consistent
view.

* :class:`ServeCounters` — the engine's mutable tallies, with
  :meth:`ServeCounters.warn_once` for first-occurrence-only warnings
  (the counter keeps counting after the warning stops).
* :class:`ServeMetrics` — immutable snapshot: counters + scheduler/pool
  gauges + TTFT/TPOT aggregates. ``to_dict()`` feeds the bench history.
* :class:`Rejected` — ``Engine.submit`` admission-control verdict
  (backpressure instead of unbounded queueing).
* :class:`LivelockError` — raised only after deterministic backoff fails;
  carries the full scheduler/pool counter snapshot so a field failure is
  diagnosable from the exception message alone.
"""
from __future__ import annotations

import dataclasses
import warnings
from typing import Dict, List, Optional, Set, Tuple


class ServeCounters:
    """Mutable fault/SLO tallies owned by one Engine instance."""

    __slots__ = ("degraded_steps", "nan_retired", "deadline_expired",
                 "budget_truncated", "truncated_max_new", "rejected_queue",
                 "rejected_pool", "livelock_backoffs", "injected_stalls",
                 "injected_poison", "ttft_sum_s", "ttft_n", "tpot_sum_s",
                 "tpot_n", "_warned")

    def __init__(self) -> None:
        self.degraded_steps = 0       # kernel launches degraded to the ref path
        self.nan_retired = 0          # slots retired on a non-finite logit tap
        self.deadline_expired = 0     # requests retired/dropped past deadline
        self.budget_truncated = 0     # wall-clock budget truncations
        self.truncated_max_new = 0    # submit-time max_new_tokens clamps
        self.rejected_queue = 0       # admissions rejected: queue watermark
        self.rejected_pool = 0        # admissions rejected: pool projection
        self.livelock_backoffs = 0    # no-progress backoff rounds
        self.injected_stalls = 0      # fault-plan clock skews applied
        self.injected_poison = 0      # fault-plan logit poisonings applied
        self.ttft_sum_s = 0.0         # time-to-first-token aggregate
        self.ttft_n = 0
        self.tpot_sum_s = 0.0         # time-per-output-token aggregate
        self.tpot_n = 0
        self._warned: Set[str] = set()

    def warn_once(self, code: str, message: str) -> None:
        """Warn on the *first* occurrence of ``code`` only; recurrence is
        what the counters are for. (Relying on the warnings module's own
        dedup instead silently swallowed distinct messages that shared a
        format — the old hot-loop behavior this replaces.)"""
        if code not in self._warned:
            self._warned.add(code)
            warnings.warn(message, stacklevel=3)

    @property
    def warned_codes(self) -> Tuple[str, ...]:
        return tuple(sorted(self._warned))


@dataclasses.dataclass(frozen=True)
class ServeMetrics:
    """One consistent snapshot of the engine's serving health. Gauges
    (queue depth, free pages) read the instant of the snapshot; counters
    are monotone since engine construction."""

    # gauges
    queue_depth: int
    active_slots: int
    free_pages: int
    used_pages: int
    page_high_water: int
    pool_capacity: int
    # scheduler counters
    admitted: int
    retired: int
    preempted: int
    sched_steps: int
    decode_steps: int
    prefill_chunks: int
    tokens_out: int
    # fault / SLO counters (mirrors ServeCounters)
    degraded_steps: int
    nan_retired: int
    deadline_expired: int
    budget_truncated: int
    truncated_max_new: int
    rejected_queue: int
    rejected_pool: int
    livelock_backoffs: int
    injected_stalls: int
    injected_poison: int
    # latency aggregates (None until a request has retired with the stat)
    ttft_mean_s: Optional[float]
    tpot_mean_s: Optional[float]

    @property
    def preemption_rate(self) -> float:
        """Preemptions per admission — the churn measure the admission
        watermark is meant to bound."""
        return self.preempted / max(self.admitted, 1)

    @property
    def rejected(self) -> int:
        return self.rejected_queue + self.rejected_pool

    def to_dict(self) -> Dict[str, float]:
        d = dataclasses.asdict(self)
        d["preemption_rate"] = round(self.preemption_rate, 4)
        d["rejected"] = self.rejected
        return d


@dataclasses.dataclass(frozen=True)
class Rejected:
    """Admission-control verdict from ``Engine.submit``: the request was
    *not* enqueued. ``reason`` is ``'queue_full'`` (queue depth at
    ``ServeConfig.max_queue``) or ``'pool_pressure'`` (projected page demand
    of everything queued + active + this request past the
    ``admit_watermark`` fraction of pool capacity). Callers shed load or
    retry later — backpressure is the contract, not an exception."""

    reason: str
    queue_depth: int
    projected_pages: int
    pool_capacity: int


class LivelockError(RuntimeError):
    """The scheduler made no progress for a full patience window despite
    backoff (admission freeze + forced retirement of over-deadline slots).
    Subclasses RuntimeError so pre-existing broad handlers still fire.

    Carries the complete state needed to diagnose the wedge from the
    message alone: queue depth, per-slot rids, pool freelist state, and the
    full :class:`ServeMetrics` snapshot at raise time."""

    def __init__(self, metrics: ServeMetrics,
                 slot_rids: List[Optional[int]],
                 queued_rids: Tuple[int, ...]) -> None:
        self.metrics = metrics
        self.slot_rids = list(slot_rids)
        self.queued_rids = tuple(queued_rids)
        counters = ", ".join(
            f"{k}={v}" for k, v in sorted(metrics.to_dict().items()))
        super().__init__(
            f"scheduler made no progress for {metrics.livelock_backoffs} "
            f"backoff rounds — queue={list(queued_rids)} "
            f"(depth {metrics.queue_depth}), slot_rids={self.slot_rids}, "
            f"free_pages={metrics.free_pages}/{metrics.pool_capacity}, "
            f"counters: {counters}")
