"""Continuous-batching scheduler over fixed-shape decode slots.

The device side of the fast path is shape-static: a (max_slots, 1) token
batch, a (max_slots, max_pages) page table, per-slot lengths/active flags
(:class:`repro.models.transformer.PagedState`). This module runs the host
loop that keeps those fixed shapes busy:

  * **admit** — a queued request joins the batch the moment a slot AND
    enough pages for its (recompute-extended) prompt are free; admission is
    priority-ordered (higher ``priority`` first, FIFO within a level) and
    never skips the queue head (no starvation within a priority level).
  * **grow** — each decode step lazily allocates one page per slot whose
    next write position crosses a page boundary.
  * **preempt** — when the pool is exhausted mid-decode, the *youngest*
    active request is evicted: its pages are released, its table row
    zeroed, and it re-enters the queue head for recompute (its generated
    tokens ride along as prompt extension, so no sampled token is lost).
  * **retire** — on eos / length / wall-budget the request's pages return
    to the freelist *immediately*, not at batch drain, so late admits can
    reuse an early finisher's pages while the batch keeps running (this
    used to leak until drain — see tests/test_serve_paged.py).

The scheduler never touches device memory; it edits the numpy page table
the engine ships to the jitted step. Invariants (checked by tests): a page
has exactly one owner, a slot holds at most one request, used_pages == 0
after drain.
"""
from __future__ import annotations

from collections import deque
from typing import Deque, Dict, List, Optional, Tuple

import numpy as np

from .kvpool import KVPool, PoolExhausted


class Scheduler:
    """Slot/page bookkeeping for continuous batching. ``rid`` handles are
    opaque ints owned by the engine."""

    def __init__(self, n_slots: int, max_pages: int, pool: KVPool):
        self.pool = pool
        self.n_slots = n_slots
        self.max_pages = max_pages
        self.table = np.zeros((n_slots, max_pages), np.int32)
        self.slot_rid: List[Optional[int]] = [None] * n_slots
        self._pages: Dict[int, List[int]] = {}      # rid -> owned pages
        self._admit_seq: Dict[int, int] = {}        # rid -> admission tick
        self._tick = 0
        self.queue: Deque[int] = deque()
        self._priority: Dict[int, int] = {}         # rid -> request priority
        self._submit_seq: Dict[int, int] = {}       # rid -> submission tick
        self._submit_tick = 0
        self.admitted = 0
        self.retired = 0
        self.preempted = 0

    # ------------------------------------------------------------------

    def submit(self, rid: int, priority: int = 0) -> None:
        """Enqueue ``rid``. Higher ``priority`` sorts ahead; within a level
        the queue is FIFO by submission order (and a preempted request keeps
        its original submission tick, so requeueing puts it back ahead of
        every same-priority request that arrived after it)."""
        self._priority[rid] = priority
        self._submit_seq[rid] = self._submit_tick
        self._submit_tick += 1
        self._enqueue(rid)

    def _qkey(self, rid: int) -> Tuple[int, int]:
        return (-self._priority[rid], self._submit_seq[rid])

    def _enqueue(self, rid: int) -> None:
        key = self._qkey(rid)
        idx = len(self.queue)
        for i, other in enumerate(self.queue):
            if self._qkey(other) > key:
                idx = i
                break
        self.queue.insert(idx, rid)

    def drop_queued(self, rid: int) -> None:
        """Remove a queued (never-admitted or preempted) request outright —
        the deadline-expiry path for requests that never reached a slot.
        Holds no pages by construction, so nothing to release."""
        self.queue.remove(rid)
        self._priority.pop(rid, None)
        self._submit_seq.pop(rid, None)

    def active_slots(self) -> List[Tuple[int, int]]:
        """[(slot, rid)] currently in the batch."""
        return [(i, r) for i, r in enumerate(self.slot_rid) if r is not None]

    def _free_slot(self) -> Optional[int]:
        for i, r in enumerate(self.slot_rid):
            if r is None:
                return i
        return None

    def try_admit(self, rid: int, n_prompt_tokens: int) -> Optional[int]:
        """Admit the queue head into a free slot if the pool can hold its
        prompt plus one decode page of headroom (the headroom avoids the
        admit-then-immediately-preempt churn of a perfectly full pool).
        Returns the slot index, or None if it cannot join yet."""
        assert self.queue and self.queue[0] == rid, \
            "admission never skips the queue head"
        slot = self._free_slot()
        if slot is None:
            return None
        need = self.pool.pages_for(n_prompt_tokens)
        if self.pool.free_pages < min(need + 1, self.pool.capacity):
            return None
        self.queue.popleft()
        pages = self.pool.alloc(need, rid)
        self._pages[rid] = pages
        self.table[slot, :] = 0
        self.table[slot, :len(pages)] = pages
        self.slot_rid[slot] = rid
        self._admit_seq[rid] = self._tick
        self._tick += 1
        self.admitted += 1
        return slot

    # ------------------------------------------------------------------

    def ensure_capacity(self, slot: int, position: int) -> bool:
        """Make sure the page holding ``position`` (the next write index) is
        mapped in this slot's table row; lazily allocates one page at the
        boundary. Returns False when the pool is exhausted (caller decides
        whom to preempt)."""
        rid = self.slot_rid[slot]
        assert rid is not None
        pidx = position // self.pool.page_size
        if pidx >= self.max_pages:
            raise RuntimeError(
                f"request {rid} position {position} exceeds the "
                f"{self.max_pages}-page table row — max_seq validation bug")
        if self.table[slot, pidx] != 0:
            return True
        try:
            (page,) = self.pool.alloc(1, rid)
        except PoolExhausted:
            return False
        self._pages[rid].append(page)
        self.table[slot, pidx] = page
        return True

    def youngest_other(self, slot: int,
                       protected: Tuple[int, ...] = ()) -> Optional[int]:
        """Latest-admitted active slot other than ``slot`` and the protected
        set — the preemption victim policy (evicting the youngest wastes the
        least completed work)."""
        best, best_seq = None, -1
        for i, rid in self.active_slots():
            if i == slot or i in protected:
                continue
            if self._admit_seq[rid] > best_seq:
                best, best_seq = i, self._admit_seq[rid]
        return best

    def preempt(self, slot: int) -> int:
        """Evict the request in ``slot``: release every page, zero the table
        row, requeue by its *original* submission tick (admitted before
        anything still queued at its priority, so it lands ahead of those).
        Returns the rid so the engine can reset its decode state."""
        rid = self.slot_rid[slot]
        assert rid is not None
        self._release(slot, rid)
        self._enqueue(rid)
        self.preempted += 1
        return rid

    def retire(self, slot: int) -> int:
        """Remove a finished request and return its pages to the freelist
        immediately — the freed pages are admissible in this same step."""
        rid = self.slot_rid[slot]
        assert rid is not None
        self._release(slot, rid)
        self._priority.pop(rid, None)
        self._submit_seq.pop(rid, None)
        self.retired += 1
        return rid

    def _release(self, slot: int, rid: int) -> None:
        self.pool.release(self._pages.pop(rid), rid)
        self._admit_seq.pop(rid, None)
        self.table[slot, :] = 0
        self.slot_rid[slot] = None

    # ------------------------------------------------------------------

    def metrics(self) -> Dict[str, float]:
        return {
            "active": float(len(self.active_slots())),
            "queued": float(len(self.queue)),
            "page_utilization": self.pool.utilization(),
            "free_pages": float(self.pool.free_pages),
            "admitted": float(self.admitted),
            "retired": float(self.retired),
            "preempted": float(self.preempted),
            "page_high_water": float(self.pool.high_water),
        }
