"""Host-side paged KV pool: fixed pages, per-request tables, freelist.

Device layout (one pool per attention slot, stacked over periods by
:func:`repro.models.transformer.init_paged_pools`):

    (n_pages, page_size, 2 * kv_heads, head_dim)

K and V for one position live *fused* in one page row — K on even head
indices, V on odd — so the decode kernel streams a whole page (both halves)
with a single block DMA per grid step instead of two. Page 0 is the
**reserved null page**: padded table entries and inactive-row scatter
writes are routed there, and it is never read because those rows report
length 0 (the kernel's ragged mask skips them), so it can hold arbitrary
garbage forever.

This module owns only the *accounting*: which physical pages belong to
which request, what is free, and high-water/churn counters the scheduler
exports as serving metrics. All device mutation happens in the jitted
decode/prefill steps through the table this class maintains.
"""
from __future__ import annotations

from typing import Dict, List, Sequence

NULL_PAGE = 0
# Sentinel owner for pages held out of circulation by reserve() — never a
# real request id (engine rids count up from 0).
RESERVED_RID = -1


class PoolExhausted(RuntimeError):
    """Raised by :meth:`KVPool.alloc` when the freelist cannot satisfy a
    request — the scheduler catches this and preempts instead."""


class KVPool:
    """Freelist allocator over ``n_pages`` physical pages of ``page_size``
    token positions each. Page 0 is reserved (null page) and never leaves
    the allocator."""

    def __init__(self, n_pages: int, page_size: int):
        if n_pages < 2:
            raise ValueError(f"pool needs >= 2 pages (null + 1), got {n_pages}")
        if page_size < 1:
            raise ValueError(f"page_size must be >= 1, got {page_size}")
        self.n_pages = n_pages
        self.page_size = page_size
        # pop() from the tail -> pages hand out in ascending id order, which
        # keeps small repro cases readable in dumps
        self._free: List[int] = list(range(n_pages - 1, 0, -1))
        self._owner: Dict[int, int] = {}     # page id -> request id
        self.alloc_count = 0
        self.free_count = 0
        self.high_water = 0

    # ------------------------------------------------------------------
    # capacity queries
    # ------------------------------------------------------------------

    @property
    def capacity(self) -> int:
        """Allocatable pages (the null page is not one)."""
        return self.n_pages - 1

    @property
    def free_pages(self) -> int:
        return len(self._free)

    @property
    def used_pages(self) -> int:
        return len(self._owner)

    def utilization(self) -> float:
        return self.used_pages / self.capacity

    def pages_for(self, n_tokens: int) -> int:
        """Pages needed to store ``n_tokens`` positions (0 tokens -> 0)."""
        return -(-n_tokens // self.page_size)

    # ------------------------------------------------------------------
    # alloc / release
    # ------------------------------------------------------------------

    def alloc(self, n: int, rid: int) -> List[int]:
        """Take ``n`` pages for request ``rid``; raises :class:`PoolExhausted`
        (allocating nothing) when fewer than ``n`` are free."""
        if n > len(self._free):
            raise PoolExhausted(
                f"request {rid} needs {n} pages, only {len(self._free)} of "
                f"{self.capacity} free")
        pages = [self._free.pop() for _ in range(n)]
        for pg in pages:
            self._owner[pg] = rid
        self.alloc_count += n
        self.high_water = max(self.high_water, self.used_pages)
        return pages

    def release(self, pages: Sequence[int], rid: int) -> None:
        """Return a request's pages to the freelist. Double-free and
        foreign-page release raise — a leak here silently serves one
        request's KV to another, so fail loudly."""
        for pg in pages:
            owner = self._owner.get(pg)
            if owner is None:
                raise ValueError(f"release of unowned page {pg} (rid {rid})")
            if owner != rid:
                raise ValueError(
                    f"request {rid} releasing page {pg} owned by {owner}")
            del self._owner[pg]
            self._free.append(pg)
        self.free_count += len(pages)

    def owner(self, page: int):
        return self._owner.get(page)

    # ------------------------------------------------------------------
    # external pressure (chaos drills, future maintenance windows)
    # ------------------------------------------------------------------

    def reserve(self, n: int) -> List[int]:
        """Take up to ``n`` pages out of circulation under the sentinel
        owner ``RESERVED_RID`` — external pool pressure (a chaos-drill
        squeeze, a future defrag/maintenance window) that the scheduler
        experiences exactly like real demand. Never raises: reserves what
        is free and returns the page list for :meth:`unreserve`."""
        n = min(n, len(self._free))
        return self.alloc(n, RESERVED_RID) if n > 0 else []

    def unreserve(self, pages: Sequence[int]) -> None:
        """Return pages taken by :meth:`reserve` to the freelist."""
        if pages:
            self.release(pages, RESERVED_RID)


def pool_shape(n_pages: int, page_size: int, n_kv_heads: int,
               head_dim: int) -> tuple:
    """Device array shape of one (unstacked) pool in the fused layout."""
    return (n_pages, page_size, 2 * n_kv_heads, head_dim)
