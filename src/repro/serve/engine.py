"""Request-level serving engine: paged fast path + legacy fallback loop.

The public surface is request-oriented:

    eng = Engine(cfg, params, ServeConfig(max_seq=256))
    rid = eng.submit(Request(prompt=tokens, max_new_tokens=64, eos_id=2))
    completions = eng.run_until_drained()       # {rid: Completion}

``submit`` enqueues (or returns :class:`~repro.serve.metrics.Rejected`
under admission control); ``step`` runs one scheduler iteration (expire
deadlines, admit queued requests into free slots, chunk-prefill them, one
batched paged decode for every active slot, retire finished ones);
``run_until_drained`` loops step until nothing is queued or active,
backing off deterministically on no-progress before raising a diagnosable
:class:`~repro.serve.metrics.LivelockError`. Per-request sampling
(temperature, seed) and SLOs (deadline_s, priority) live on the
:class:`Request`; :class:`ServeConfig` keeps the engine-wide geometry
(max_seq, page/pool sizing, slot count, wall budget, admission
watermarks).

Fault handling (see ``repro.serve`` package docs for the full ladder): a
failing paged-attention launch degrades that one step to the dense
reference path; a non-finite logit tap retires the poisoned slot with
``reason="nan"`` instead of sampling garbage; every such decision lands in
:class:`~repro.serve.metrics.ServeCounters` (snapshot via
:meth:`Engine.metrics`) rather than a hot-loop warning.

Architectures outside the paged fast path's coverage (SSM/hybrid mixers,
int8 KV) fall back to the legacy batch loop transparently;
:meth:`Engine.generate` is kept as a thin compatibility wrapper over the
request API (deprecated for new code — it hides per-request raggedness by
padding).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

from .. import injection
from ..models import transformer
from .faults import CLOCK_POINT, KERNEL_POINT, LOGITS_POINT, STEP_POINT
from .kvpool import KVPool
from .metrics import LivelockError, Rejected, ServeCounters, ServeMetrics
from .scheduler import Scheduler


@dataclasses.dataclass
class ServeConfig:
    """Engine-wide serving geometry. ``temperature``/``seed`` remain only
    as defaults for requests that don't set their own (the pre-request-API
    surface); new code should put sampling on the :class:`Request`."""
    max_new_tokens: int = 32
    max_seq: int = 512
    temperature: float = 0.0   # deprecated default; see Request.temperature
    seed: int = 0              # deprecated default; see Request.seed
    # Per-request wall-clock budget (seconds). A pathological decode loop —
    # a recompile storm, an overloaded host — degrades to a *truncated*
    # response (finish_reason='budget', counted) instead of hanging the
    # caller. None = no cap.
    max_wall_s: Optional[float] = None
    # Paged fast path geometry
    page_size: int = 16        # token positions per KV page
    pool_pages: Optional[int] = None   # None -> max_slots * pages(max_seq) + 1
    max_slots: int = 8         # fixed decode batch width
    prefill_chunk: int = 8     # prompt tokens per chunked-prefill step
    # None -> auto (paged when the arch supports it); False forces the
    # legacy token-by-token loop (the parity oracle in tests)
    paged: Optional[bool] = None
    # --- admission control / backpressure (None = accept everything) ---
    # submit() returns Rejected('queue_full') once this many requests are
    # queued (admitted-and-running requests don't count).
    max_queue: Optional[int] = None
    # submit() returns Rejected('pool_pressure') when the projected page
    # demand of everything queued + active + the new request exceeds this
    # fraction of pool capacity. 1.0 = reject only guaranteed-thrash loads;
    # lower values keep preemption-churn headroom.
    admit_watermark: Optional[float] = None
    # --- livelock handling -------------------------------------------
    # Consecutive no-progress scheduler steps tolerated (with backoff)
    # before run_until_drained raises LivelockError. Must exceed any
    # transient external pressure window (e.g. a chaos-drill squeeze).
    livelock_patience: int = 16
    # Admissions frozen for this many steps at the start of a no-progress
    # burst — stops admit->preempt churn from masking a wedged pool.
    backoff_freeze_steps: int = 2


@dataclasses.dataclass
class Request:
    """One generation request. ``temperature``/``seed`` default to the
    engine's ServeConfig when None. ``deadline_s`` is an SLO relative to
    submission: once exceeded the request retires with
    ``finish_reason='deadline'`` (whatever was generated so far) instead of
    occupying a slot; queued requests past deadline are dropped without
    ever touching the device. Higher ``priority`` admits first (FIFO
    within a level)."""
    prompt: object                       # (S,) int tokens (list/np/jnp)
    max_new_tokens: Optional[int] = None
    eos_id: Optional[int] = None
    temperature: Optional[float] = None
    seed: Optional[int] = None
    deadline_s: Optional[float] = None
    priority: int = 0


@dataclasses.dataclass
class Completion:
    """Result of one request. ``tokens`` holds only the *generated* suffix
    (including the eos token when one was emitted). ``finish_reason``:
    'eos' | 'length' | 'budget' | 'deadline' | 'nan'."""
    id: int
    prompt: np.ndarray
    tokens: np.ndarray
    finish_reason: str
    ttft_s: Optional[float]              # submit -> first token
    wall_s: float                        # submit -> retirement
    preemptions: int = 0
    tpot_s: Optional[float] = None       # mean time per token after the first


class _ReqState:
    """Host-side decode state for one in-flight request."""

    __slots__ = ("rid", "request", "prompt", "max_new", "generated",
                 "ctx_len", "t_submit", "t_first", "preemptions",
                 "deadline_s", "priority")

    def __init__(self, rid: int, request: Request, prompt: np.ndarray,
                 max_new: int, t_submit: float):
        self.rid = rid
        self.request = request
        self.prompt = prompt
        self.max_new = max_new
        self.generated: List[int] = []
        self.ctx_len = 0          # KV positions written on device
        self.t_submit = t_submit
        self.t_first: Optional[float] = None
        self.preemptions = 0
        self.deadline_s = request.deadline_s
        self.priority = request.priority

    def ctx_tokens(self) -> np.ndarray:
        """Tokens whose KV must exist before decoding can continue — the
        prompt plus everything generated so far (preemption recompute
        prefills this whole extended prompt, losing no sampled token)."""
        return np.concatenate(
            [self.prompt, np.asarray(self.generated, np.int32)])


class Engine:
    def __init__(self, model_cfg, params, sc: Optional[ServeConfig] = None):
        self.cfg = model_cfg
        self.params = params
        self.sc = sc if sc is not None else ServeConfig()
        self._paged = (self.sc.paged if self.sc.paged is not None
                       else transformer.supports_paged(model_cfg))
        self._next_rid = 0
        self._reqs: Dict[int, _ReqState] = {}
        self._done: Dict[int, Completion] = {}
        self.counters = ServeCounters()
        self.decode_steps = 0
        self.prefill_chunks = 0
        self.tokens_out = 0
        self.sched_steps = 0        # scheduler iterations, incl. no-progress
        self._completed_total = 0
        self._no_progress = 0       # consecutive no-progress steps
        self._admit_freeze = 0      # steps with admissions suspended
        # Virtual-clock skew (seconds) added to every monotonic read — the
        # deterministic stall injection advances it so deadline logic can be
        # driven without sleeping in CI.
        self._clock_skew = 0.0
        if self._paged:
            p = self.sc.page_size
            max_pages = -(-self.sc.max_seq // p)
            n_pages = (self.sc.pool_pages if self.sc.pool_pages is not None
                       else self.sc.max_slots * max_pages + 1)
            self.pool = KVPool(n_pages, p)
            self.scheduler = Scheduler(self.sc.max_slots, max_pages, self.pool)
            self._pools = None     # device pools, created on first use
            self._decode = jax.jit(
                lambda pr, st, t: transformer.paged_decode_step(model_cfg, pr, st, t))
            self._decode_fallback = jax.jit(
                lambda pr, st, t: transformer.paged_decode_step(
                    model_cfg, pr, st, t, attn_impl="ref"))
            self._prefill = jax.jit(
                lambda pr, pools, row, pos0, nv, tok:
                transformer.paged_prefill_chunk(model_cfg, pr, pools, row,
                                                pos0, nv, tok))
            self._prefill_fallback = jax.jit(
                lambda pr, pools, row, pos0, nv, tok:
                transformer.paged_prefill_chunk(model_cfg, pr, pools, row,
                                                pos0, nv, tok,
                                                attn_impl="ref"))
        else:
            self._decode = jax.jit(
                lambda pr, c, t: transformer.decode_step(model_cfg, pr, c, t))

    def _now(self) -> float:
        return time.monotonic() + self._clock_skew

    # ------------------------------------------------------------------
    # Request API
    # ------------------------------------------------------------------

    def submit(self, request: Request) -> Union[int, Rejected]:
        """Validate, admission-check and enqueue one request; returns its id
        or a :class:`Rejected` verdict (backpressure — never an exception).
        Raises ValueError only for requests that could never run: a prompt
        that cannot fit ``max_seq``, or a footprint exceeding the whole
        page pool even alone."""
        if not self._paged:
            raise NotImplementedError(
                f"the request API needs the paged fast path, which does not "
                f"cover arch '{self.cfg.name}' — use generate()")
        prompt = np.asarray(request.prompt, np.int32).reshape(-1)
        n_prompt = prompt.shape[0]
        budget = self.sc.max_seq - n_prompt
        if budget <= 0:
            raise ValueError(
                f"prompt length {n_prompt} leaves no room to generate within "
                f"max_seq={self.sc.max_seq}")
        max_new = (request.max_new_tokens if request.max_new_tokens is not None
                   else self.sc.max_new_tokens)
        if max_new > budget:
            self.counters.truncated_max_new += 1
            self.counters.warn_once(
                "truncate_max_new",
                f"truncating max_new_tokens {max_new} -> {budget}: prompt "
                f"length {n_prompt} + requested tokens would overrun the "
                f"max_seq={self.sc.max_seq} cache (counted in "
                f"ServeMetrics.truncated_max_new; warning not repeated)")
            max_new = budget
        need = self.pool.pages_for(n_prompt + max_new)
        if need > self.pool.capacity:
            raise ValueError(
                f"request needs {need} KV pages but the pool holds only "
                f"{self.pool.capacity} — raise pool_pages or shrink the "
                f"request")
        sched = self.scheduler
        if (self.sc.max_queue is not None
                and len(sched.queue) >= self.sc.max_queue):
            self.counters.rejected_queue += 1
            return Rejected(reason="queue_full",
                            queue_depth=len(sched.queue),
                            projected_pages=need,
                            pool_capacity=self.pool.capacity)
        if self.sc.admit_watermark is not None:
            projected = self.pool.used_pages + self._queued_pages() + need
            if projected > self.sc.admit_watermark * self.pool.capacity:
                self.counters.rejected_pool += 1
                return Rejected(reason="pool_pressure",
                                queue_depth=len(sched.queue),
                                projected_pages=projected,
                                pool_capacity=self.pool.capacity)
        rid = self._next_rid
        self._next_rid += 1
        self._reqs[rid] = _ReqState(rid, request, prompt, max_new,
                                    t_submit=self._now())
        sched.submit(rid, priority=request.priority)
        return rid

    def _queued_pages(self) -> int:
        """Projected lifetime page demand of everything still queued (each
        request's full prompt + generation footprint — recompute extensions
        never exceed it)."""
        return sum(
            self.pool.pages_for(self._reqs[rid].prompt.shape[0]
                                + self._reqs[rid].max_new)
            for rid in self.scheduler.queue)

    def step(self) -> Dict[str, float]:
        """One scheduler iteration: expire deadlines, admit + prefill,
        grow/preempt, one batched decode, retire. Returns per-step
        metrics."""
        if not self._paged:
            raise NotImplementedError(
                f"the request API needs the paged fast path, which does not "
                f"cover arch '{self.cfg.name}' — use generate()")
        sched = self.scheduler
        step_idx = self.sched_steps
        self.sched_steps += 1
        injection.fire(STEP_POINT, self, step_idx)
        skew = injection.fire(CLOCK_POINT, step_idx)
        if skew:
            self._clock_skew += float(skew)
            self.counters.injected_stalls += 1
        self._expire_deadlines()

        prefills = 0
        if self._admit_freeze > 0:
            self._admit_freeze -= 1      # backoff: no admissions this step
        else:
            # --- admit as many queue heads as slots/pages allow
            while sched.queue:
                rid = sched.queue[0]
                st = self._reqs[rid]
                slot = sched.try_admit(rid, len(st.ctx_tokens()))
                if slot is None:
                    break
                prefills += 1
                self._prefill_into(slot, st)

        # --- make room for every active row's next write position
        ensured: List[int] = []
        for slot, rid in list(sched.active_slots()):
            if sched.slot_rid[slot] != rid:
                continue               # evicted by an earlier row's preempt
            st = self._reqs[rid]
            while True:
                if sched.ensure_capacity(slot, st.ctx_len):
                    ensured.append(slot)
                    break
                victim = sched.youngest_other(slot, tuple(ensured))
                vrid = sched.preempt(victim if victim is not None else slot)
                self._reqs[vrid].preemptions += 1
                if victim is None:
                    break              # self-preempted; skip decode this step

        # --- one fixed-shape decode over all active slots
        step_tokens = 0
        active = sched.active_slots()
        if active:
            n = self.sc.max_slots
            tokens = np.zeros((n, 1), np.int32)
            lengths = np.zeros((n,), np.int32)
            mask = np.zeros((n,), bool)
            for slot, rid in active:
                st = self._reqs[rid]
                tokens[slot, 0] = st.generated[-1]
                lengths[slot] = st.ctx_len
                mask[slot] = True
            state = transformer.PagedState(
                pools=self._device_pools(), table=jnp.asarray(sched.table),
                lengths=jnp.asarray(lengths), active=jnp.asarray(mask))
            logits, ok_dev, new_state = self._decode_call(
                state, jnp.asarray(tokens))
            self._pools = new_state.pools
            self.decode_steps += 1
            last = np.asarray(logits[:, -1].astype(jnp.float32))
            ok = np.asarray(ok_dev)
            now = self._now()
            for slot, rid in active:
                st = self._reqs[rid]
                st.ctx_len += 1        # this step wrote generated[-1]'s KV
                poisoned = bool(injection.fire(
                    LOGITS_POINT, rid, len(st.generated)))
                if poisoned:
                    self.counters.injected_poison += 1
                if poisoned or not ok[slot]:
                    self._retire_nan(slot, st)
                    continue
                tok = self._sample_one(st, last[slot])
                st.generated.append(tok)
                step_tokens += 1
                eos = st.request.eos_id
                if eos is not None and tok == eos:
                    self._retire(slot, st, "eos")
                elif len(st.generated) >= st.max_new:
                    self._retire(slot, st, "length")
                elif (self.sc.max_wall_s is not None
                      and now - st.t_submit > self.sc.max_wall_s):
                    self.counters.budget_truncated += 1
                    self.counters.warn_once(
                        "wall_budget",
                        f"serve request {rid} exceeded wall-clock budget "
                        f"max_wall_s={self.sc.max_wall_s} after "
                        f"{len(st.generated)}/{st.max_new} tokens; returning "
                        f"truncated response (counted in "
                        f"ServeMetrics.budget_truncated; warning not "
                        f"repeated)")
                    self._retire(slot, st, "budget")
        self.tokens_out += step_tokens
        m = sched.metrics()
        m.update(step_tokens=float(step_tokens), prefills=float(prefills))
        return m

    def run_until_drained(self) -> Dict[int, Completion]:
        """Step until every admitted request has retired; returns and
        clears the accumulated completions. On a no-progress step the
        engine backs off deterministically (freeze admissions, force-retire
        over-deadline slots); only after ``livelock_patience`` consecutive
        stuck steps does it raise :class:`LivelockError` carrying the full
        scheduler/pool counter snapshot."""
        sched = self.scheduler
        self._no_progress = 0
        while sched.queue or sched.active_slots():
            before = self._progress_sig()
            self.step()
            if self._progress_sig() == before:
                self._no_progress += 1
                self._backoff()
                if self._no_progress >= self.sc.livelock_patience:
                    raise LivelockError(self.metrics(), sched.slot_rid,
                                        tuple(sched.queue))
            else:
                self._no_progress = 0
        done, self._done = self._done, {}
        return done

    def completions(self) -> Dict[int, Completion]:
        """Completions retired so far (without draining the batch)."""
        done, self._done = self._done, {}
        return done

    def metrics(self) -> ServeMetrics:
        """One consistent snapshot of serving health: scheduler/pool gauges
        plus every fault/SLO counter. Cheap — no device sync."""
        c = self.counters
        if self._paged:
            sched, pool = self.scheduler, self.pool
            gauges = dict(queue_depth=len(sched.queue),
                          active_slots=len(sched.active_slots()),
                          free_pages=pool.free_pages,
                          used_pages=pool.used_pages,
                          page_high_water=pool.high_water,
                          pool_capacity=pool.capacity,
                          admitted=sched.admitted, retired=sched.retired,
                          preempted=sched.preempted)
        else:
            gauges = dict(queue_depth=0, active_slots=0, free_pages=0,
                          used_pages=0, page_high_water=0, pool_capacity=0,
                          admitted=0, retired=0, preempted=0)
        return ServeMetrics(
            sched_steps=self.sched_steps, decode_steps=self.decode_steps,
            prefill_chunks=self.prefill_chunks, tokens_out=self.tokens_out,
            degraded_steps=c.degraded_steps, nan_retired=c.nan_retired,
            deadline_expired=c.deadline_expired,
            budget_truncated=c.budget_truncated,
            truncated_max_new=c.truncated_max_new,
            rejected_queue=c.rejected_queue, rejected_pool=c.rejected_pool,
            livelock_backoffs=c.livelock_backoffs,
            injected_stalls=c.injected_stalls,
            injected_poison=c.injected_poison,
            ttft_mean_s=c.ttft_sum_s / c.ttft_n if c.ttft_n else None,
            tpot_mean_s=c.tpot_sum_s / c.tpot_n if c.tpot_n else None,
            **gauges)

    # ------------------------------------------------------------------
    # Progress / livelock handling
    # ------------------------------------------------------------------

    def _progress_sig(self) -> Tuple[int, ...]:
        sched = self.scheduler
        return (self.tokens_out, sched.admitted, sched.retired,
                sched.preempted, self._completed_total)

    def _backoff(self) -> None:
        """Deterministic no-progress backoff: count the round, force-retire
        anything past its deadline right now, and freeze admissions at the
        start of a burst (stops admit->preempt churn from hiding a wedged
        pool while transient pressure — e.g. a chaos squeeze — drains)."""
        self.counters.livelock_backoffs += 1
        self._expire_deadlines()
        if self._no_progress == 1:
            self._admit_freeze = self.sc.backoff_freeze_steps

    def _expire_deadlines(self) -> None:
        """Retire every request past its deadline: queued ones are dropped
        without touching the device; active ones give up their slot and
        pages immediately, returning whatever they generated."""
        now = self._now()
        sched = self.scheduler

        def expired(st: _ReqState) -> bool:
            return (st.deadline_s is not None
                    and now - st.t_submit > st.deadline_s)

        for rid in [r for r in sched.queue if expired(self._reqs[r])]:
            st = self._reqs[rid]
            sched.drop_queued(rid)
            self._count_deadline(st)
            self._finish(st, "deadline")
        for slot, rid in list(sched.active_slots()):
            st = self._reqs[rid]
            if expired(st):
                self._count_deadline(st)
                self._retire(slot, st, "deadline")

    def _count_deadline(self, st: _ReqState) -> None:
        self.counters.deadline_expired += 1
        self.counters.warn_once(
            "deadline",
            f"serve request {st.rid} exceeded its deadline_s="
            f"{st.deadline_s} after {len(st.generated)}/{st.max_new} "
            f"tokens; retiring with reason='deadline' (counted in "
            f"ServeMetrics.deadline_expired; warning not repeated)")

    # ------------------------------------------------------------------
    # Paged internals
    # ------------------------------------------------------------------

    def _pool_dtype(self):
        return jnp.float32 if self.cfg.dtype == jnp.float32 else jnp.bfloat16

    def _device_pools(self):
        if self._pools is None:
            self._pools = transformer.init_paged_pools(
                self.cfg, self.pool.n_pages, self.pool.page_size,
                self._pool_dtype())
        return self._pools

    def _decode_call(self, state, tokens):
        """Dispatch one batched decode, degrading to the dense reference
        attention for exactly this step when the kernel launch fails (an
        injected fault or a real trace/compile regression). Mirrors the
        fused optimizer's per-leaf ``_guarded`` ladder at step granularity."""
        try:
            injection.fire(KERNEL_POINT, "decode", self.decode_steps)
            return self._decode(self.params, state, tokens)
        except Exception as e:  # noqa: BLE001 — any kernel failure degrades
            self.counters.degraded_steps += 1
            self.counters.warn_once(
                "kernel_degraded",
                f"paged decode launch failed ({type(e).__name__}: {e}); "
                f"serving this step through the dense reference path "
                f"(counted in ServeMetrics.degraded_steps; warning not "
                f"repeated)")
            return self._decode_fallback(self.params, state, tokens)

    def _prefill_call(self, row, pos0, n_valid, buf):
        """Prefill-chunk dispatch with the same degradation ladder as
        :meth:`_decode_call` (chunks indexed globally across requests)."""
        try:
            injection.fire(KERNEL_POINT, "prefill", self.prefill_chunks)
            return self._prefill(self.params, self._device_pools(), row,
                                 pos0, n_valid, buf)
        except Exception as e:  # noqa: BLE001 — any kernel failure degrades
            self.counters.degraded_steps += 1
            self.counters.warn_once(
                "kernel_degraded",
                f"paged prefill launch failed ({type(e).__name__}: {e}); "
                f"serving this chunk through the dense reference path "
                f"(counted in ServeMetrics.degraded_steps; warning not "
                f"repeated)")
            return self._prefill_fallback(self.params, self._device_pools(),
                                          row, pos0, n_valid, buf)

    def _prefill_into(self, slot: int, st: _ReqState) -> None:
        """Chunk-prefill a freshly admitted request's whole known context
        (prompt + any pre-preemption tokens) and sample its next token."""
        ctx = st.ctx_tokens()
        n_ctx = ctx.shape[0]
        chunk = self.sc.prefill_chunk
        n_chunks = -(-n_ctx // chunk)
        row = jnp.asarray(self.scheduler.table[slot:slot + 1])
        logits = None
        ok_dev = None
        n_valid = chunk
        for k in range(n_chunks):
            lo = k * chunk
            n_valid = min(chunk, n_ctx - lo)
            buf = np.zeros((1, chunk), np.int32)
            buf[0, :n_valid] = ctx[lo:lo + n_valid]
            logits, ok_dev, pools = self._prefill_call(
                row, np.int32(lo), np.int32(n_valid), jnp.asarray(buf))
            self._pools = pools
            self.prefill_chunks += 1
            if (self.sc.max_wall_s is not None
                    and self._now() - st.t_submit > self.sc.max_wall_s):
                self.counters.budget_truncated += 1
                self.counters.warn_once(
                    "wall_budget",
                    f"serve request {st.rid} exceeded wall-clock budget "
                    f"max_wall_s={self.sc.max_wall_s} during prefill "
                    f"({k + 1}/{n_chunks} chunks); returning prompt only "
                    f"(counted in ServeMetrics.budget_truncated; warning "
                    f"not repeated)")
                st.ctx_len = lo + n_valid
                self._retire(slot, st, "budget")
                return
        st.ctx_len = n_ctx
        poisoned = bool(injection.fire(LOGITS_POINT, st.rid,
                                       len(st.generated)))
        if poisoned:
            self.counters.injected_poison += 1
        if poisoned or not bool(np.asarray(ok_dev)):
            self._retire_nan(slot, st)
            return
        row_logits = np.asarray(logits[0, n_valid - 1].astype(jnp.float32))
        tok = self._sample_one(st, row_logits)
        st.generated.append(tok)
        self.tokens_out += 1
        eos = st.request.eos_id
        if eos is not None and tok == eos:
            self._retire(slot, st, "eos")
        elif len(st.generated) >= st.max_new:
            self._retire(slot, st, "length")

    def _sample_one(self, st: _ReqState, logits_row: np.ndarray) -> int:
        if st.t_first is None:
            st.t_first = self._now()
        temp = (st.request.temperature if st.request.temperature is not None
                else self.sc.temperature)
        if temp <= 0.0:
            return int(np.argmax(logits_row))
        seed = st.request.seed if st.request.seed is not None else self.sc.seed
        # fold the token index into the request's key: resampling the same
        # index after a preemption recompute reproduces the same token
        key = jax.random.fold_in(jax.random.PRNGKey(seed), len(st.generated))
        return int(jax.random.categorical(
            key, jnp.asarray(logits_row, jnp.float32) / temp))

    def _retire_nan(self, slot: int, st: _ReqState) -> None:
        """Poisoned slot: skip sampling entirely (no garbage token escapes)
        and retire with whatever was generated before the poison."""
        self.counters.nan_retired += 1
        self.counters.warn_once(
            "nan_logits",
            f"non-finite logits for serve request {st.rid} after "
            f"{len(st.generated)} tokens; skipping sampling and retiring "
            f"with reason='nan' (counted in ServeMetrics.nan_retired; "
            f"warning not repeated)")
        self._retire(slot, st, "nan")

    def _retire(self, slot: int, st: _ReqState, reason: str) -> None:
        self.scheduler.retire(slot)
        self._finish(st, reason)

    def _finish(self, st: _ReqState, reason: str) -> None:
        """Build the Completion and fold its latency stats into the
        engine-level TTFT/TPOT aggregates."""
        now = self._now()
        ttft = None if st.t_first is None else st.t_first - st.t_submit
        wall = now - st.t_submit
        tpot = None
        if ttft is not None and len(st.generated) > 1:
            tpot = (wall - ttft) / (len(st.generated) - 1)
        if ttft is not None:
            self.counters.ttft_sum_s += ttft
            self.counters.ttft_n += 1
        if tpot is not None:
            self.counters.tpot_sum_s += tpot
            self.counters.tpot_n += 1
        self._done[st.rid] = Completion(
            id=st.rid, prompt=st.prompt,
            tokens=np.asarray(st.generated, np.int32),
            finish_reason=reason, ttft_s=ttft, wall_s=wall,
            preemptions=st.preemptions, tpot_s=tpot)
        del self._reqs[st.rid]
        self._completed_total += 1

    # ------------------------------------------------------------------
    # Compatibility wrapper (pre-request-API surface)
    # ------------------------------------------------------------------

    def generate(self, prompts: jnp.ndarray, *, eos_id: Optional[int] = None) -> jnp.ndarray:
        """prompts: (B, S_prompt) int32 -> (B, S_prompt + new) tokens.

        Deprecated compatibility wrapper: submits one :class:`Request` per
        row and pads the ragged completions back into a rectangle (eos_id —
        or 0 — as filler), which is what the old batch loop produced. New
        code should use submit/step/run_until_drained directly.
        """
        if not self._paged:
            return self._generate_legacy(prompts, eos_id=eos_id)
        prompts = jnp.asarray(prompts)
        b, s_prompt = prompts.shape
        host_prompts = np.asarray(prompts)
        rids = []
        for i in range(b):
            rid = self.submit(Request(prompt=host_prompts[i], eos_id=eos_id))
            if isinstance(rid, Rejected):
                raise RuntimeError(
                    f"generate() row {i} rejected by admission control "
                    f"({rid.reason}) — the batch wrapper cannot shed load; "
                    f"use submit() directly under backpressure")
            rids.append(rid)
        done = self.run_until_drained()
        rows = [np.concatenate([host_prompts[i], done[rid].tokens])
                for i, rid in enumerate(rids)]
        width = max(len(r) for r in rows)
        fill = eos_id if eos_id is not None else 0
        out = np.full((b, width), fill, np.int32)
        for i, r in enumerate(rows):
            out[i, :len(r)] = r
        return jnp.asarray(out)

    # ------------------------------------------------------------------
    # Legacy batch loop (SSM/hybrid archs; paged=False parity oracle)
    # ------------------------------------------------------------------

    def _sample(self, logits: jnp.ndarray, key) -> jnp.ndarray:
        if self.sc.temperature <= 0.0:
            return jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)[:, None]
        probs = jax.nn.softmax(logits[:, -1].astype(jnp.float32) / self.sc.temperature, axis=-1)
        return jax.random.categorical(key, jnp.log(probs + 1e-9), axis=-1).astype(jnp.int32)[:, None]

    def _generate_legacy(self, prompts: jnp.ndarray, *, eos_id: Optional[int] = None) -> jnp.ndarray:
        """Token-by-token batch loop over the dense per-request caches
        (correct for every arch in the zoo, incl. SSM state builds)."""
        b, s_prompt = prompts.shape
        # The KV/SSM caches hold max_seq positions; dynamic_update_slice
        # *clamps* out-of-range writes, so an unchecked overrun would
        # silently overwrite the last cache slot instead of failing.
        budget = self.sc.max_seq - s_prompt
        if budget <= 0:
            raise ValueError(
                f"prompt length {s_prompt} leaves no room to generate within "
                f"max_seq={self.sc.max_seq}")
        max_new = self.sc.max_new_tokens
        if max_new > budget:
            self.counters.truncated_max_new += 1
            self.counters.warn_once(
                "truncate_max_new",
                f"truncating max_new_tokens {max_new} -> {budget}: prompt "
                f"length {s_prompt} + requested tokens would overrun the "
                f"max_seq={self.sc.max_seq} cache (counted in "
                f"ServeMetrics.truncated_max_new; warning not repeated)")
            max_new = budget
        cache = transformer.init_decode_cache(
            self.cfg, b, self.sc.max_seq,
            dtype=jnp.float32 if self.cfg.dtype == jnp.float32 else jnp.bfloat16)
        key = jax.random.PRNGKey(self.sc.seed)

        t0 = self._now()

        def over_budget() -> bool:
            return (self.sc.max_wall_s is not None
                    and self._now() - t0 > self.sc.max_wall_s)

        tokens = prompts
        logits = None
        for i in range(s_prompt):                      # prefill
            logits, cache = self._decode(self.params, cache, prompts[:, i:i + 1])
            if over_budget():
                # Can't emit anything sensible without a full prefill — the
                # degraded response is the prompt unchanged.
                self.counters.budget_truncated += 1
                self.counters.warn_once(
                    "wall_budget",
                    f"serve batch exceeded wall-clock budget "
                    f"max_wall_s={self.sc.max_wall_s} during prefill "
                    f"({i + 1}/{s_prompt} tokens); returning prompt only "
                    f"(counted in ServeMetrics.budget_truncated; warning "
                    f"not repeated)")
                return prompts
        out: List[jnp.ndarray] = [tokens]
        done = jnp.zeros((b, 1), bool)
        for n in range(max_new):                       # decode
            key, sub = jax.random.split(key)
            nxt = self._sample(logits, sub)
            if eos_id is not None:
                done = done | (nxt == eos_id)
                nxt = jnp.where(done, eos_id, nxt)
            out.append(nxt)
            if eos_id is not None and bool(done.all()):
                break                                  # every row finished
            if over_budget():
                self.counters.budget_truncated += 1
                self.counters.warn_once(
                    "wall_budget",
                    f"serve batch exceeded wall-clock budget "
                    f"max_wall_s={self.sc.max_wall_s} after {n + 1}/"
                    f"{max_new} tokens; returning truncated response "
                    f"(counted in ServeMetrics.budget_truncated; warning "
                    f"not repeated)")
                break
            logits, cache = self._decode(self.params, cache, nxt)
        return jnp.concatenate(out, axis=1)
