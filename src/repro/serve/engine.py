"""Batched serving: prefill + decode loop over the unified model zoo.

Greedy/temperature sampling, continuous batch of requests, sharded KV/SSM
caches (the decode_32k / long_500k dry-run cells lower exactly this step).
"""
from __future__ import annotations

import dataclasses
import time
import warnings
from typing import List, Optional

import jax
import jax.numpy as jnp

from ..models import transformer


@dataclasses.dataclass
class ServeConfig:
    max_new_tokens: int = 32
    max_seq: int = 512
    temperature: float = 0.0   # 0 = greedy
    seed: int = 0
    # Per-request wall-clock budget (seconds). A pathological decode loop —
    # a recompile storm, an overloaded host — degrades to a *truncated*
    # response with a warning instead of hanging the caller. None = no cap.
    max_wall_s: Optional[float] = None


class Engine:
    def __init__(self, model_cfg, params, sc: ServeConfig = ServeConfig()):
        self.cfg = model_cfg
        self.params = params
        self.sc = sc
        self._decode = jax.jit(lambda p, c, t: transformer.decode_step(model_cfg, p, c, t))

    def _sample(self, logits: jnp.ndarray, key) -> jnp.ndarray:
        if self.sc.temperature <= 0.0:
            return jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)[:, None]
        probs = jax.nn.softmax(logits[:, -1].astype(jnp.float32) / self.sc.temperature, axis=-1)
        return jax.random.categorical(key, jnp.log(probs + 1e-9), axis=-1).astype(jnp.int32)[:, None]

    def generate(self, prompts: jnp.ndarray, *, eos_id: Optional[int] = None) -> jnp.ndarray:
        """prompts: (B, S_prompt) int32 -> (B, S_prompt + new) tokens.

        Prefill is decode-stepped token by token (correct for every arch in
        the zoo, incl. SSM state builds); a fused chunk-prefill is the serving
        fast path on real hardware.
        """
        b, s_prompt = prompts.shape
        # The KV/SSM caches hold max_seq positions; dynamic_update_slice
        # *clamps* out-of-range writes, so an unchecked overrun would
        # silently overwrite the last cache slot instead of failing.
        budget = self.sc.max_seq - s_prompt
        if budget <= 0:
            raise ValueError(
                f"prompt length {s_prompt} leaves no room to generate within "
                f"max_seq={self.sc.max_seq}")
        max_new = self.sc.max_new_tokens
        if max_new > budget:
            warnings.warn(
                f"truncating max_new_tokens {max_new} -> {budget}: "
                f"prompt length {s_prompt} + requested tokens would overrun "
                f"the max_seq={self.sc.max_seq} cache")
            max_new = budget
        cache = transformer.init_decode_cache(
            self.cfg, b, self.sc.max_seq,
            dtype=jnp.float32 if self.cfg.dtype == jnp.float32 else jnp.bfloat16)
        key = jax.random.PRNGKey(self.sc.seed)

        t0 = time.monotonic()

        def over_budget() -> bool:
            return (self.sc.max_wall_s is not None
                    and time.monotonic() - t0 > self.sc.max_wall_s)

        tokens = prompts
        logits = None
        for i in range(s_prompt):                      # prefill
            logits, cache = self._decode(self.params, cache, prompts[:, i:i + 1])
            if over_budget():
                # Can't emit anything sensible without a full prefill — the
                # degraded response is the prompt unchanged.
                warnings.warn(
                    f"serve request exceeded wall-clock budget "
                    f"max_wall_s={self.sc.max_wall_s} during prefill "
                    f"({i + 1}/{s_prompt} tokens); returning prompt only")
                return prompts
        out: List[jnp.ndarray] = [tokens]
        done = jnp.zeros((b, 1), bool)
        for n in range(max_new):                       # decode
            key, sub = jax.random.split(key)
            nxt = self._sample(logits, sub)
            if eos_id is not None:
                done = done | (nxt == eos_id)
                nxt = jnp.where(done, eos_id, nxt)
            out.append(nxt)
            if eos_id is not None and bool(done.all()):
                break                                  # every row finished
            if over_budget():
                warnings.warn(
                    f"serve request exceeded wall-clock budget "
                    f"max_wall_s={self.sc.max_wall_s} after {n + 1}/{max_new} "
                    f"tokens; returning truncated response")
                break
            logits, cache = self._decode(self.params, cache, nxt)
        return jnp.concatenate(out, axis=1)
