"""Request-level serving engine: paged fast path + legacy fallback loop.

The public surface is request-oriented:

    eng = Engine(cfg, params, ServeConfig(max_seq=256))
    rid = eng.submit(Request(prompt=tokens, max_new_tokens=64, eos_id=2))
    completions = eng.run_until_drained()       # {rid: Completion}

``submit`` enqueues; ``step`` runs one scheduler iteration (admit queued
requests into free slots, chunk-prefill them, one batched paged decode for
every active slot, retire finished ones); ``run_until_drained`` loops step
until nothing is queued or active. Per-request sampling (temperature,
seed) lives on the :class:`Request`; :class:`ServeConfig` keeps the
engine-wide geometry (max_seq, page/pool sizing, slot count, wall budget).

Architectures outside the paged fast path's coverage (SSM/hybrid mixers,
int8 KV) fall back to the legacy batch loop transparently;
:meth:`Engine.generate` is kept as a thin compatibility wrapper over the
request API (deprecated for new code — it hides per-request raggedness by
padding).
"""
from __future__ import annotations

import dataclasses
import time
import warnings
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..models import transformer
from .kvpool import KVPool
from .scheduler import Scheduler


@dataclasses.dataclass
class ServeConfig:
    """Engine-wide serving geometry. ``temperature``/``seed`` remain only
    as defaults for requests that don't set their own (the pre-request-API
    surface); new code should put sampling on the :class:`Request`."""
    max_new_tokens: int = 32
    max_seq: int = 512
    temperature: float = 0.0   # deprecated default; see Request.temperature
    seed: int = 0              # deprecated default; see Request.seed
    # Per-request wall-clock budget (seconds). A pathological decode loop —
    # a recompile storm, an overloaded host — degrades to a *truncated*
    # response with a warning instead of hanging the caller. None = no cap.
    max_wall_s: Optional[float] = None
    # Paged fast path geometry
    page_size: int = 16        # token positions per KV page
    pool_pages: Optional[int] = None   # None -> max_slots * pages(max_seq) + 1
    max_slots: int = 8         # fixed decode batch width
    prefill_chunk: int = 8     # prompt tokens per chunked-prefill step
    # None -> auto (paged when the arch supports it); False forces the
    # legacy token-by-token loop (the parity oracle in tests)
    paged: Optional[bool] = None


@dataclasses.dataclass
class Request:
    """One generation request. ``temperature``/``seed`` default to the
    engine's ServeConfig when None."""
    prompt: object                       # (S,) int tokens (list/np/jnp)
    max_new_tokens: Optional[int] = None
    eos_id: Optional[int] = None
    temperature: Optional[float] = None
    seed: Optional[int] = None


@dataclasses.dataclass
class Completion:
    """Result of one request. ``tokens`` holds only the *generated* suffix
    (including the eos token when one was emitted)."""
    id: int
    prompt: np.ndarray
    tokens: np.ndarray
    finish_reason: str                   # 'eos' | 'length' | 'budget'
    ttft_s: Optional[float]              # submit -> first token
    wall_s: float                        # submit -> retirement
    preemptions: int = 0


class _ReqState:
    """Host-side decode state for one in-flight request."""

    __slots__ = ("rid", "request", "prompt", "max_new", "generated",
                 "ctx_len", "t_submit", "t_first", "preemptions")

    def __init__(self, rid: int, request: Request, prompt: np.ndarray,
                 max_new: int):
        self.rid = rid
        self.request = request
        self.prompt = prompt
        self.max_new = max_new
        self.generated: List[int] = []
        self.ctx_len = 0          # KV positions written on device
        self.t_submit = time.monotonic()
        self.t_first: Optional[float] = None
        self.preemptions = 0

    def ctx_tokens(self) -> np.ndarray:
        """Tokens whose KV must exist before decoding can continue — the
        prompt plus everything generated so far (preemption recompute
        prefills this whole extended prompt, losing no sampled token)."""
        return np.concatenate(
            [self.prompt, np.asarray(self.generated, np.int32)])


class Engine:
    def __init__(self, model_cfg, params, sc: Optional[ServeConfig] = None):
        self.cfg = model_cfg
        self.params = params
        self.sc = sc if sc is not None else ServeConfig()
        self._paged = (self.sc.paged if self.sc.paged is not None
                       else transformer.supports_paged(model_cfg))
        self._next_rid = 0
        self._reqs: Dict[int, _ReqState] = {}
        self._done: Dict[int, Completion] = {}
        self.decode_steps = 0
        self.prefill_chunks = 0
        self.tokens_out = 0
        if self._paged:
            p = self.sc.page_size
            max_pages = -(-self.sc.max_seq // p)
            n_pages = (self.sc.pool_pages if self.sc.pool_pages is not None
                       else self.sc.max_slots * max_pages + 1)
            self.pool = KVPool(n_pages, p)
            self.scheduler = Scheduler(self.sc.max_slots, max_pages, self.pool)
            self._pools = None     # device pools, created on first use
            self._decode = jax.jit(
                lambda pr, st, t: transformer.paged_decode_step(model_cfg, pr, st, t))
            self._prefill = jax.jit(
                lambda pr, pools, row, pos0, nv, tok:
                transformer.paged_prefill_chunk(model_cfg, pr, pools, row,
                                                pos0, nv, tok))
        else:
            self._decode = jax.jit(
                lambda pr, c, t: transformer.decode_step(model_cfg, pr, c, t))

    # ------------------------------------------------------------------
    # Request API
    # ------------------------------------------------------------------

    def submit(self, request: Request) -> int:
        """Validate and enqueue one request; returns its id. Raises
        ValueError when the prompt cannot fit ``max_seq`` or the whole
        request could never fit the page pool even alone."""
        if not self._paged:
            raise NotImplementedError(
                f"the request API needs the paged fast path, which does not "
                f"cover arch '{self.cfg.name}' — use generate()")
        prompt = np.asarray(request.prompt, np.int32).reshape(-1)
        n_prompt = prompt.shape[0]
        budget = self.sc.max_seq - n_prompt
        if budget <= 0:
            raise ValueError(
                f"prompt length {n_prompt} leaves no room to generate within "
                f"max_seq={self.sc.max_seq}")
        max_new = (request.max_new_tokens if request.max_new_tokens is not None
                   else self.sc.max_new_tokens)
        if max_new > budget:
            warnings.warn(
                f"truncating max_new_tokens {max_new} -> {budget}: "
                f"prompt length {n_prompt} + requested tokens would overrun "
                f"the max_seq={self.sc.max_seq} cache")
            max_new = budget
        need = self.pool.pages_for(n_prompt + max_new)
        if need > self.pool.capacity:
            raise ValueError(
                f"request needs {need} KV pages but the pool holds only "
                f"{self.pool.capacity} — raise pool_pages or shrink the "
                f"request")
        rid = self._next_rid
        self._next_rid += 1
        self._reqs[rid] = _ReqState(rid, request, prompt, max_new)
        self.scheduler.submit(rid)
        return rid

    def step(self) -> Dict[str, float]:
        """One scheduler iteration: admit + prefill, grow/preempt, one
        batched decode, retire. Returns per-step metrics."""
        if not self._paged:
            raise NotImplementedError(
                f"the request API needs the paged fast path, which does not "
                f"cover arch '{self.cfg.name}' — use generate()")
        sched = self.scheduler
        prefills = 0
        # --- admit as many queue heads as slots/pages allow
        while sched.queue:
            rid = sched.queue[0]
            st = self._reqs[rid]
            slot = sched.try_admit(rid, len(st.ctx_tokens()))
            if slot is None:
                break
            prefills += 1
            self._prefill_into(slot, st)

        # --- make room for every active row's next write position
        ensured: List[int] = []
        for slot, rid in list(sched.active_slots()):
            if sched.slot_rid[slot] != rid:
                continue               # evicted by an earlier row's preempt
            st = self._reqs[rid]
            while True:
                if sched.ensure_capacity(slot, st.ctx_len):
                    ensured.append(slot)
                    break
                victim = sched.youngest_other(slot, tuple(ensured))
                vrid = sched.preempt(victim if victim is not None else slot)
                self._reqs[vrid].preemptions += 1
                if victim is None:
                    break              # self-preempted; skip decode this step

        # --- one fixed-shape decode over all active slots
        step_tokens = 0
        active = sched.active_slots()
        if active:
            n = self.sc.max_slots
            tokens = np.zeros((n, 1), np.int32)
            lengths = np.zeros((n,), np.int32)
            mask = np.zeros((n,), bool)
            for slot, rid in active:
                st = self._reqs[rid]
                tokens[slot, 0] = st.generated[-1]
                lengths[slot] = st.ctx_len
                mask[slot] = True
            state = transformer.PagedState(
                pools=self._device_pools(), table=jnp.asarray(sched.table),
                lengths=jnp.asarray(lengths), active=jnp.asarray(mask))
            logits, new_state = self._decode(self.params, state,
                                             jnp.asarray(tokens))
            self._pools = new_state.pools
            self.decode_steps += 1
            last = np.asarray(logits[:, -1].astype(jnp.float32))
            now = time.monotonic()
            for slot, rid in active:
                st = self._reqs[rid]
                st.ctx_len += 1        # this step wrote generated[-1]'s KV
                tok = self._sample_one(st, last[slot])
                st.generated.append(tok)
                step_tokens += 1
                eos = st.request.eos_id
                if eos is not None and tok == eos:
                    self._retire(slot, st, "eos")
                elif len(st.generated) >= st.max_new:
                    self._retire(slot, st, "length")
                elif (self.sc.max_wall_s is not None
                      and now - st.t_submit > self.sc.max_wall_s):
                    warnings.warn(
                        f"serve request exceeded wall-clock budget "
                        f"max_wall_s={self.sc.max_wall_s} after "
                        f"{len(st.generated)}/{st.max_new} tokens; returning "
                        f"truncated response")
                    self._retire(slot, st, "budget")
        self.tokens_out += step_tokens
        m = sched.metrics()
        m.update(step_tokens=float(step_tokens), prefills=float(prefills))
        return m

    def run_until_drained(self) -> Dict[int, Completion]:
        """Step until every submitted request has retired; returns and
        clears the accumulated completions."""
        sched = self.scheduler
        while sched.queue or sched.active_slots():
            before = (self.tokens_out, sched.admitted, sched.retired,
                      sched.preempted)
            self.step()
            after = (self.tokens_out, sched.admitted, sched.retired,
                     sched.preempted)
            if before == after:
                raise RuntimeError(
                    "scheduler made no progress — slot/page accounting bug "
                    f"(queue={len(sched.queue)}, "
                    f"active={len(sched.active_slots())}, "
                    f"free_pages={self.pool.free_pages})")
        done, self._done = self._done, {}
        return done

    def completions(self) -> Dict[int, Completion]:
        """Completions retired so far (without draining the batch)."""
        done, self._done = self._done, {}
        return done

    # ------------------------------------------------------------------
    # Paged internals
    # ------------------------------------------------------------------

    def _pool_dtype(self):
        return jnp.float32 if self.cfg.dtype == jnp.float32 else jnp.bfloat16

    def _device_pools(self):
        if self._pools is None:
            self._pools = transformer.init_paged_pools(
                self.cfg, self.pool.n_pages, self.pool.page_size,
                self._pool_dtype())
        return self._pools

    def _prefill_into(self, slot: int, st: _ReqState) -> None:
        """Chunk-prefill a freshly admitted request's whole known context
        (prompt + any pre-preemption tokens) and sample its next token."""
        ctx = st.ctx_tokens()
        n_ctx = ctx.shape[0]
        chunk = self.sc.prefill_chunk
        n_chunks = -(-n_ctx // chunk)
        row = jnp.asarray(self.scheduler.table[slot:slot + 1])
        logits = None
        n_valid = chunk
        for k in range(n_chunks):
            lo = k * chunk
            n_valid = min(chunk, n_ctx - lo)
            buf = np.zeros((1, chunk), np.int32)
            buf[0, :n_valid] = ctx[lo:lo + n_valid]
            logits, pools = self._prefill(
                self.params, self._device_pools(), row,
                np.int32(lo), np.int32(n_valid), jnp.asarray(buf))
            self._pools = pools
            self.prefill_chunks += 1
            if (self.sc.max_wall_s is not None
                    and time.monotonic() - st.t_submit > self.sc.max_wall_s):
                warnings.warn(
                    f"serve request exceeded wall-clock budget "
                    f"max_wall_s={self.sc.max_wall_s} during prefill "
                    f"({k + 1}/{n_chunks} chunks); returning prompt only")
                st.ctx_len = lo + n_valid
                self._retire(slot, st, "budget")
                return
        st.ctx_len = n_ctx
        row_logits = np.asarray(logits[0, n_valid - 1].astype(jnp.float32))
        tok = self._sample_one(st, row_logits)
        st.generated.append(tok)
        self.tokens_out += 1
        eos = st.request.eos_id
        if eos is not None and tok == eos:
            self._retire(slot, st, "eos")
        elif len(st.generated) >= st.max_new:
            self._retire(slot, st, "length")

    def _sample_one(self, st: _ReqState, logits_row: np.ndarray) -> int:
        if st.t_first is None:
            st.t_first = time.monotonic()
        temp = (st.request.temperature if st.request.temperature is not None
                else self.sc.temperature)
        if temp <= 0.0:
            return int(np.argmax(logits_row))
        seed = st.request.seed if st.request.seed is not None else self.sc.seed
        # fold the token index into the request's key: resampling the same
        # index after a preemption recompute reproduces the same token
        key = jax.random.fold_in(jax.random.PRNGKey(seed), len(st.generated))
        return int(jax.random.categorical(
            key, jnp.asarray(logits_row, jnp.float32) / temp))

    def _retire(self, slot: int, st: _ReqState, reason: str) -> None:
        self.scheduler.retire(slot)
        now = time.monotonic()
        self._done[st.rid] = Completion(
            id=st.rid, prompt=st.prompt,
            tokens=np.asarray(st.generated, np.int32),
            finish_reason=reason,
            ttft_s=None if st.t_first is None else st.t_first - st.t_submit,
            wall_s=now - st.t_submit, preemptions=st.preemptions)
        del self._reqs[st.rid]

    # ------------------------------------------------------------------
    # Compatibility wrapper (pre-request-API surface)
    # ------------------------------------------------------------------

    def generate(self, prompts: jnp.ndarray, *, eos_id: Optional[int] = None) -> jnp.ndarray:
        """prompts: (B, S_prompt) int32 -> (B, S_prompt + new) tokens.

        Deprecated compatibility wrapper: submits one :class:`Request` per
        row and pads the ragged completions back into a rectangle (eos_id —
        or 0 — as filler), which is what the old batch loop produced. New
        code should use submit/step/run_until_drained directly.
        """
        if not self._paged:
            return self._generate_legacy(prompts, eos_id=eos_id)
        prompts = jnp.asarray(prompts)
        b, s_prompt = prompts.shape
        host_prompts = np.asarray(prompts)
        rids = [self.submit(Request(prompt=host_prompts[i], eos_id=eos_id))
                for i in range(b)]
        done = self.run_until_drained()
        rows = [np.concatenate([host_prompts[i], done[rid].tokens])
                for i, rid in enumerate(rids)]
        width = max(len(r) for r in rows)
        fill = eos_id if eos_id is not None else 0
        out = np.full((b, width), fill, np.int32)
        for i, r in enumerate(rows):
            out[i, :len(r)] = r
        return jnp.asarray(out)

    # ------------------------------------------------------------------
    # Legacy batch loop (SSM/hybrid archs; paged=False parity oracle)
    # ------------------------------------------------------------------

    def _sample(self, logits: jnp.ndarray, key) -> jnp.ndarray:
        if self.sc.temperature <= 0.0:
            return jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)[:, None]
        probs = jax.nn.softmax(logits[:, -1].astype(jnp.float32) / self.sc.temperature, axis=-1)
        return jax.random.categorical(key, jnp.log(probs + 1e-9), axis=-1).astype(jnp.int32)[:, None]

    def _generate_legacy(self, prompts: jnp.ndarray, *, eos_id: Optional[int] = None) -> jnp.ndarray:
        """Token-by-token batch loop over the dense per-request caches
        (correct for every arch in the zoo, incl. SSM state builds)."""
        b, s_prompt = prompts.shape
        # The KV/SSM caches hold max_seq positions; dynamic_update_slice
        # *clamps* out-of-range writes, so an unchecked overrun would
        # silently overwrite the last cache slot instead of failing.
        budget = self.sc.max_seq - s_prompt
        if budget <= 0:
            raise ValueError(
                f"prompt length {s_prompt} leaves no room to generate within "
                f"max_seq={self.sc.max_seq}")
        max_new = self.sc.max_new_tokens
        if max_new > budget:
            warnings.warn(
                f"truncating max_new_tokens {max_new} -> {budget}: "
                f"prompt length {s_prompt} + requested tokens would overrun "
                f"the max_seq={self.sc.max_seq} cache")
            max_new = budget
        cache = transformer.init_decode_cache(
            self.cfg, b, self.sc.max_seq,
            dtype=jnp.float32 if self.cfg.dtype == jnp.float32 else jnp.bfloat16)
        key = jax.random.PRNGKey(self.sc.seed)

        t0 = time.monotonic()

        def over_budget() -> bool:
            return (self.sc.max_wall_s is not None
                    and time.monotonic() - t0 > self.sc.max_wall_s)

        tokens = prompts
        logits = None
        for i in range(s_prompt):                      # prefill
            logits, cache = self._decode(self.params, cache, prompts[:, i:i + 1])
            if over_budget():
                # Can't emit anything sensible without a full prefill — the
                # degraded response is the prompt unchanged.
                warnings.warn(
                    f"serve request exceeded wall-clock budget "
                    f"max_wall_s={self.sc.max_wall_s} during prefill "
                    f"({i + 1}/{s_prompt} tokens); returning prompt only")
                return prompts
        out: List[jnp.ndarray] = [tokens]
        done = jnp.zeros((b, 1), bool)
        for n in range(max_new):                       # decode
            key, sub = jax.random.split(key)
            nxt = self._sample(logits, sub)
            if eos_id is not None:
                done = done | (nxt == eos_id)
                nxt = jnp.where(done, eos_id, nxt)
            out.append(nxt)
            if eos_id is not None and bool(done.all()):
                break                                  # every row finished
            if over_budget():
                warnings.warn(
                    f"serve request exceeded wall-clock budget "
                    f"max_wall_s={self.sc.max_wall_s} after {n + 1}/{max_new} "
                    f"tokens; returning truncated response")
                break
            logits, cache = self._decode(self.params, cache, nxt)
        return jnp.concatenate(out, axis=1)
