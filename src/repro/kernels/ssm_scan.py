"""Mamba selective-scan — Pallas TPU kernel.

The jnp chunked scan (repro.models.ssm.selective_scan, this kernel's oracle)
materializes (B, chunk, d_inner, d_state) fp32 tensors in HBM — a 16x
(d_state) expansion of every activation it touches; the falcon-mamba
train_4k roofline shows it as a ~50 s/step memory term. This kernel keeps
the expansion entirely in VMEM: the hidden state (d_tile, N) lives in
scratch across sequence chunks, and HBM sees only the x/dt/B/C input streams
and the y output — ~5 fp32 passes of (S, d_inner) per layer, ~N times less
traffic.

Grid: (batch, d_tiles, seq_chunks); the last (minor) grid dim is sequential
on TPU, so the scratch state carries across the chunk steps of one
(b, d_tile) program — the standard revisiting pattern. VMEM working set at
(chunk=512, d_tile=256, N=16): x/dt/y blocks 0.5 MB each + B/C 32 KB + state
16 KB ≈ 1.6 MB.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssm_kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, dskip_ref, h0_ref,
                y_ref, hout_ref, h_scratch, *, chunk: int, n_chunks: int):
    ck = pl.program_id(2)

    @pl.when(ck == 0)
    def _init():
        h_scratch[...] = h0_ref[0]                      # (d_tile, N)

    x = x_ref[0].astype(jnp.float32)                     # (chunk, d_tile)
    dt = dt_ref[0].astype(jnp.float32)
    bc = b_ref[0].astype(jnp.float32)                    # (chunk, N)
    cc = c_ref[0].astype(jnp.float32)
    a = a_ref[...].astype(jnp.float32)                   # (d_tile, N)
    dskip = dskip_ref[...].astype(jnp.float32)           # (d_tile,)

    def step(t, h):
        decay = jnp.exp(dt[t][:, None] * a)              # (d_tile, N)
        h = decay * h + (dt[t] * x[t])[:, None] * bc[t][None, :]
        y_ref[0, t, :] = (jnp.sum(h * cc[t][None, :], axis=1)
                          + dskip * x[t]).astype(y_ref.dtype)
        return h

    h = jax.lax.fori_loop(0, chunk, step, h_scratch[...])
    h_scratch[...] = h

    @pl.when(ck == n_chunks - 1)
    def _out():
        hout_ref[0] = h


def ssm_scan(x, dt, a, b_t, c_t, d_skip, h0, *, chunk: int = 512,
             d_tile: int = 256, interpret: bool = True):
    """x, dt: (B, S, D); a: (D, N); b_t, c_t: (B, S, N); h0: (B, D, N).

    Returns (y (B, S, D) fp32, h_final (B, D, N) fp32). Semantics match
    ``repro.models.ssm.selective_scan`` (the oracle).
    """
    bsz, s, d = x.shape
    n = a.shape[-1]
    chunk = min(chunk, s)
    while s % chunk:
        chunk -= 1
    d_tile = min(d_tile, d)
    while d % d_tile:
        d_tile -= 1
    n_chunks = s // chunk
    grid = (bsz, d // d_tile, n_chunks)

    kernel = functools.partial(_ssm_kernel, chunk=chunk, n_chunks=n_chunks)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, chunk, d_tile), lambda b, dd, cc_: (b, cc_, dd)),   # x
            pl.BlockSpec((1, chunk, d_tile), lambda b, dd, cc_: (b, cc_, dd)),   # dt
            pl.BlockSpec((d_tile, n), lambda b, dd, cc_: (dd, 0)),               # a
            pl.BlockSpec((1, chunk, n), lambda b, dd, cc_: (b, cc_, 0)),         # b_t
            pl.BlockSpec((1, chunk, n), lambda b, dd, cc_: (b, cc_, 0)),         # c_t
            pl.BlockSpec((d_tile,), lambda b, dd, cc_: (dd,)),                   # d_skip
            pl.BlockSpec((1, d_tile, n), lambda b, dd, cc_: (b, dd, 0)),         # h0
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, d_tile), lambda b, dd, cc_: (b, cc_, dd)),   # y
            pl.BlockSpec((1, d_tile, n), lambda b, dd, cc_: (b, dd, 0)),         # h_final
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bsz, s, d), jnp.float32),
            jax.ShapeDtypeStruct((bsz, d, n), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((d_tile, n), jnp.float32)],
        interpret=interpret,
    )(x, dt, a, b_t, c_t, d_skip, h0)
