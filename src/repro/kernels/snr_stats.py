"""Per-row (sum, sum-sq) reduction for the SNR analysis — Pallas TPU kernel.

SNR_K(V) needs mean and variance along K; a single fused pass computes both
first moments of V per row, so the measurement adds one read of V (and O(R)
writes) to a training step instead of XLA's separate mean/var reductions.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _snr_kernel(v_ref, s1_out, s2_out):
    v = v_ref[...].astype(jnp.float32)        # (TR, C)
    s1_out[...] = jnp.sum(v, axis=1)
    s2_out[...] = jnp.sum(v * v, axis=1)


def snr_stats(v, *, row_block: int = 64, interpret: bool = True):
    """v: (R, C) -> (row_sum (R,), row_sumsq (R,))."""
    r, c = v.shape
    tr = min(row_block, r)
    if r % tr:
        rp = -(-r // tr) * tr
        s1, s2 = snr_stats(jnp.pad(v, ((0, rp - r), (0, 0))), row_block=row_block,
                           interpret=interpret)
        return s1[:r], s2[:r]
    return pl.pallas_call(
        _snr_kernel,
        grid=(r // tr,),
        in_specs=[pl.BlockSpec((tr, c), lambda i: (i, 0))],
        out_specs=[pl.BlockSpec((tr,), lambda i: (i,)),
                   pl.BlockSpec((tr,), lambda i: (i,))],
        out_shape=[jax.ShapeDtypeStruct((r,), jnp.float32),
                   jax.ShapeDtypeStruct((r,), jnp.float32)],
        interpret=interpret,
    )(v)
