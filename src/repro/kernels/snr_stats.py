"""Per-row (sum, sum-sq) reduction for the SNR analysis — Pallas TPU kernel.

SNR_K(V) needs mean and variance along K; a single fused pass computes both
first moments of V per row, so the measurement adds one read of V (and O(R)
writes) to a training step instead of XLA's separate mean/var reductions.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .tiling import fit_col_block, fit_row_block


def _snr_kernel(v_ref, s1_out, s2_out):
    v = v_ref[...].astype(jnp.float32)        # (TR, C)
    s1_out[...] = jnp.sum(v, axis=1)
    s2_out[...] = jnp.sum(v * v, axis=1)


def snr_stats(v, *, row_block: int = 64, interpret: bool = True):
    """v: (R, C) -> (row_sum (R,), row_sumsq (R,))."""
    r, c = v.shape
    tr = fit_row_block(c, row_block, r, 2)  # one full-width input + cast copy
    if r % tr:
        rp = -(-r // tr) * tr
        s1, s2 = snr_stats(jnp.pad(v, ((0, rp - r), (0, 0))), row_block=row_block,
                           interpret=interpret)
        return s1[:r], s2[:r]
    return pl.pallas_call(
        _snr_kernel,
        grid=(r // tr,),
        in_specs=[pl.BlockSpec((tr, c), lambda i: (i, 0))],
        out_specs=[pl.BlockSpec((tr,), lambda i: (i,)),
                   pl.BlockSpec((tr,), lambda i: (i,))],
        out_shape=[jax.ShapeDtypeStruct((r,), jnp.float32),
                   jax.ShapeDtypeStruct((r,), jnp.float32)],
        interpret=interpret,
    )(v)


def _snr_centered_kernel(v_ref, s1_out, s1c_out, s2c_out):
    v = v_ref[...].astype(jnp.float32)        # (TR, C)
    d = v - v[:, 0:1]                         # shift by the row's first entry
    s1_out[...] = jnp.sum(v, axis=1)
    s1c_out[...] = jnp.sum(d, axis=1)
    s2c_out[...] = jnp.sum(d * d, axis=1)


def snr_stats_centered(v, *, row_block: int = 64, interpret: bool = True):
    """v: (R, C) -> (row_sum, shifted_row_sum, shifted_row_sumsq), all (R,).

    The naive one-pass E[v^2] - E[v]^2 variance cancels catastrophically in
    fp32 for near-constant rows (the high-SNR regime the analysis exists to
    detect): abs error ~ eps * mean^2 swamps a true variance orders of
    magnitude smaller. Shifting each row by its first entry makes both sums
    O(spread) instead of O(magnitude) — variance is shift-invariant, so
    ``var = s2c/n - (s1c/n)^2`` is accurate to the spread's own precision,
    still in a single pass over V. The unshifted row sum rides along for the
    mean (V >= 0, so its summation is stable).
    """
    r, c = v.shape
    tr = fit_row_block(c, row_block, r, 3)  # input + shifted copy + cast
    if r % tr:
        rp = -(-r // tr) * tr
        s1, s1c, s2c = snr_stats_centered(jnp.pad(v, ((0, rp - r), (0, 0))),
                                          row_block=row_block, interpret=interpret)
        return s1[:r], s1c[:r], s2c[:r]
    return pl.pallas_call(
        _snr_centered_kernel,
        grid=(r // tr,),
        in_specs=[pl.BlockSpec((tr, c), lambda i: (i, 0))],
        out_specs=[pl.BlockSpec((tr,), lambda i: (i,))] * 3,
        out_shape=[jax.ShapeDtypeStruct((r,), jnp.float32)] * 3,
        interpret=interpret,
    )(v)


def _snr_centered_major_kernel(v_ref, s1_out, s1c_out, s2c_out):
    v = v_ref[...].astype(jnp.float32)        # (R, TC)
    d = v - v[0:1, :]                         # shift by the column's first entry
    s1_out[...] = jnp.sum(v, axis=0)
    s1c_out[...] = jnp.sum(d, axis=0)
    s2c_out[...] = jnp.sum(d * d, axis=0)


def snr_stats_centered_major(v, *, col_block: int = 256, interpret: bool = True):
    """v: (R, C) -> (col_sum, shifted_col_sum, shifted_col_sumsq), all (C,).

    Major-axis twin of :func:`snr_stats_centered`: the reduction runs over
    sublanes (axis 0), so a moment tensor whose compression dims are leading
    gets its one-pass centered stats without a boundary transpose. Same
    shift-centering argument — variance is shift-invariant, so subtracting
    each column's first entry keeps the sums O(spread) in the near-constant
    high-SNR regime."""
    r, c = v.shape
    tc = fit_col_block(r, col_block, c, 3)  # input + shifted copy + cast
    if c % tc:
        cp = -(-c // tc) * tc
        s1, s1c, s2c = snr_stats_centered_major(jnp.pad(v, ((0, 0), (0, cp - c))),
                                                col_block=col_block,
                                                interpret=interpret)
        return s1[:c], s1c[:c], s2c[:c]
    return pl.pallas_call(
        _snr_centered_major_kernel,
        grid=(c // tc,),
        in_specs=[pl.BlockSpec((r, tc), lambda j: (0, j))],
        out_specs=[pl.BlockSpec((tc,), lambda j: (j,))] * 3,
        out_shape=[jax.ShapeDtypeStruct((c,), jnp.float32)] * 3,
        interpret=interpret,
    )(v)
