"""Fused per-line reductions for the SNR analysis — Pallas TPU kernels.

SNR_K(V) needs mean and variance along K; a single fused pass computes the
moments of V per reduction line, so the measurement adds one read of V (and
O(kept) writes) to a training step instead of XLA's separate mean/var
reductions.

Like the slim-update kernels, everything runs on the batched canonical form
``(B, R, C)`` (see ``repro.kernels.ops.canon_nd``) through the shared
grid/BlockSpec builder (``repro.kernels.tiling.strip_grid``), with one
kernel body per stats flavor parameterized by the in-block reduction axis:
minor (``axis=1``, stats per row) or major (``axis=0``, stats per column —
the transpose-free pass for moments whose compression dims are leading or
batch-interleaved). The 2-D entries (``snr_stats`` /
``snr_stats_centered`` / ``snr_stats_centered_major``) are B=1 wrappers.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .tiling import pad_kept, strip_grid

# Live full-size fp32 buffers per instance (the n_bufs VMEM-fitting
# argument): input + cast copy (+ shifted copy for the centered form).
STATS_BUFS = 2
CENTERED_BUFS = 3

_DEFAULT_BLOCK = {1: 64, 0: 256}


def _first_along(v: jnp.ndarray, red_axis: int) -> jnp.ndarray:
    """The reduction line's first entry, kept broadcastable (the centered
    kernels' shift)."""
    return jax.lax.slice_in_dim(v, 0, 1, axis=red_axis)


def centered_line_stats(v: jnp.ndarray, red_axis: int):
    """Shift-centered per-line sums of an in-VMEM block: (s1c, s2c, first),
    each keepdims along ``red_axis``. The shared body the snr_stats kernels
    and the slim partial-stats kernel (``repro.kernels.slim_update``, which
    rides these sums on the update pass's strip loop) both inline, so the
    centering semantics — shift by the line's local first entry, making both
    sums O(spread) instead of O(magnitude) — have one definition."""
    f = _first_along(v, red_axis)
    d = v - f
    return (jnp.sum(d, axis=red_axis, keepdims=True),
            jnp.sum(d * d, axis=red_axis, keepdims=True), f)


def snr_update_stats_finalize(v_new: jnp.ndarray, s1c: jnp.ndarray, s2c: jnp.ndarray,
                              n: int, one_minus_b2: float,
                              eps: float = 1e-30) -> jnp.ndarray:
    """Finalize the from-update SNR of one leaf (scalar).

    ``s1c``/``s2c`` are the completed centered line sums of g^2 along the
    leaf's compression dims K (from the update kernels' ``with_snr`` outputs,
    psum-completed for sharded lines); ``v_new`` the completed reduced moment
    (same layout). The measured quantity is SNR_K of the step's dense
    reconstruction ``V_dense = b2 * V_red + (1 - b2) * g^2`` — the second
    moment dense Adam would hold this step given the compressed history:
    ``E_K[V_dense]`` is exactly ``v_new`` and ``Var_K[V_dense] =
    (1 - b2)^2 * Var_K[g^2]``, so the whole diagnostic costs O(kept) on top
    of the update pass. High SNR -> the compression rule is still valid."""
    mean_c = s1c / n
    var = s2c / n - jnp.square(mean_c)
    var = jnp.maximum(var, 0.0) * (one_minus_b2 * one_minus_b2)
    return jnp.mean(jnp.square(v_new) / (var + eps))


def _snr_kernel(v_ref, s1_out, s2_out, *, red_axis: int):
    v = v_ref[...].astype(jnp.float32)        # (1, TR, C) | (1, R, TC)
    s1_out[...] = jnp.sum(v, axis=red_axis)
    s2_out[...] = jnp.sum(v * v, axis=red_axis)


def _snr_centered_kernel(v_ref, s1_out, s1c_out, s2c_out, *, red_axis: int):
    v = v_ref[...].astype(jnp.float32)        # (1, TR, C) | (1, R, TC)
    s1c, s2c, _ = centered_line_stats(v, red_axis)
    s1_out[...] = jnp.sum(v, axis=red_axis)
    s1c_out[...] = jnp.squeeze(s1c, axis=red_axis)
    s2c_out[...] = jnp.squeeze(s2c, axis=red_axis)


def _snr_centered_partial_kernel(v_ref, s1_out, s1c_out, s2c_out, f_out, *, red_axis: int):
    """Centered stats + the shift itself (the line's local first entry), the
    partial-sums form a cross-shard reduction composes: shards rebase their
    sums to a common shift (exact O(spread) algebra, see
    ``repro.kernels.ref.rebase_centered_stats``) and ``lax.psum`` them."""
    v = v_ref[...].astype(jnp.float32)        # (1, TR, C) | (1, R, TC)
    s1c, s2c, f = centered_line_stats(v, red_axis)
    s1_out[...] = jnp.sum(v, axis=red_axis)
    s1c_out[...] = jnp.squeeze(s1c, axis=red_axis)
    s2c_out[...] = jnp.squeeze(s2c, axis=red_axis)
    f_out[...] = jnp.squeeze(f, axis=red_axis)


def _stats_call(v, *, axis: int, n_bufs: int, n_outs: int, kernel_body,
                block: Optional[int], interpret: bool):
    """Shared pad-fit-launch path for both stats flavors. Returns ``n_outs``
    arrays of shape (B, kept)."""
    assert v.ndim == 3 and axis in (0, 1)
    b, r, c = v.shape
    block = _DEFAULT_BLOCK[axis] if block is None else block
    sg = strip_grid(b, r, c, axis=axis, n_bufs=n_bufs, block=block)
    if sg.kept % sg.tile:
        outs = _stats_call(pad_kept(v, sg), axis=axis, n_bufs=n_bufs,
                           n_outs=n_outs, kernel_body=kernel_body,
                           block=block, interpret=interpret)
        return tuple(o[:, :sg.kept] for o in outs)  # stats are (B, kept)
    kernel = functools.partial(kernel_body, red_axis=sg.red_axis)
    return pl.pallas_call(
        kernel,
        grid=sg.grid,
        in_specs=[sg.full],
        out_specs=[sg.stat] * n_outs,
        out_shape=[jax.ShapeDtypeStruct((b, sg.kept), jnp.float32)] * n_outs,
        interpret=interpret,
    )(v)


def snr_stats_batched(v, *, axis: int, block: Optional[int] = None,
                      interpret: bool = True):
    """v: (B, R, C) -> (line_sum, line_sumsq), each (B, kept)."""
    return _stats_call(v, axis=axis, n_bufs=STATS_BUFS, n_outs=2,
                       kernel_body=_snr_kernel, block=block, interpret=interpret)


def snr_stats_centered_batched(v, *, axis: int, block: Optional[int] = None,
                               interpret: bool = True):
    """v: (B, R, C) -> (line_sum, shifted_line_sum, shifted_line_sumsq),
    each (B, kept).

    The naive one-pass E[v^2] - E[v]^2 variance cancels catastrophically in
    fp32 for near-constant lines (the high-SNR regime the analysis exists to
    detect): abs error ~ eps * mean^2 swamps a true variance orders of
    magnitude smaller. Shifting each line by its first entry makes both sums
    O(spread) instead of O(magnitude) — variance is shift-invariant, so
    ``var = s2c/n - (s1c/n)^2`` is accurate to the spread's own precision,
    still in a single pass over V. The unshifted line sum rides along for
    the mean (V >= 0, so its summation is stable).
    """
    return _stats_call(v, axis=axis, n_bufs=CENTERED_BUFS, n_outs=3,
                       kernel_body=_snr_centered_kernel, block=block,
                       interpret=interpret)


def snr_stats_centered_partial_batched(v, *, axis: int, block: Optional[int] = None,
                                       interpret: bool = True):
    """v: (B, R, C) -> (line_sum, shifted_line_sum, shifted_line_sumsq,
    line_first), each (B, kept) — the partial-sums entry point for sharded
    reduction lines.

    Same one-pass centered trick as :func:`snr_stats_centered_batched`, but
    when the reduction dim is split across devices each shard shifts by its
    *own* first entry, so the sums cannot be added directly. Emitting the
    shift alongside lets callers rebase every shard to a mesh-common shift
    (``shift = lax.pmean(first, axes)``; the rebase is exact algebra whose
    terms are all O(spread), see ``repro.kernels.ref.rebase_centered_stats``)
    and *then* ``lax.psum`` the three sums — preserving the catastrophic-
    cancellation protection across the shard boundary. The working set is
    identical to the centered kernel (the shift is a reused register line),
    hence the shared ``CENTERED_BUFS``."""
    return _stats_call(v, axis=axis, n_bufs=CENTERED_BUFS, n_outs=4,
                       kernel_body=_snr_centered_partial_kernel, block=block,
                       interpret=interpret)


# ---------------------------------------------------------------------------
# 2-D entry points: B=1 wrappers over the batched canonical form.
# ---------------------------------------------------------------------------


def snr_stats(v, *, row_block: int = 64, interpret: bool = True):
    """v: (R, C) -> (row_sum (R,), row_sumsq (R,))."""
    s1, s2 = snr_stats_batched(v[None], axis=1, block=row_block, interpret=interpret)
    return s1[0], s2[0]


def snr_stats_centered(v, *, row_block: int = 64, interpret: bool = True):
    """v: (R, C) -> (row_sum, shifted_row_sum, shifted_row_sumsq), all (R,).
    See :func:`snr_stats_centered_batched` for the shift-centering argument."""
    s1, s1c, s2c = snr_stats_centered_batched(v[None], axis=1, block=row_block,
                                              interpret=interpret)
    return s1[0], s1c[0], s2c[0]


def snr_stats_centered_partial(v, *, row_block: int = 64, interpret: bool = True):
    """v: (R, C) -> (row_sum, shifted_row_sum, shifted_row_sumsq, row_first),
    all (R,). B=1 wrapper over the partial-sums entry point."""
    s1, s1c, s2c, f = snr_stats_centered_partial_batched(
        v[None], axis=1, block=row_block, interpret=interpret)
    return s1[0], s1c[0], s2c[0], f[0]


def snr_stats_centered_major(v, *, col_block: int = 256, interpret: bool = True):
    """v: (R, C) -> (col_sum, shifted_col_sum, shifted_col_sumsq), all (C,).
    Major-axis twin of :func:`snr_stats_centered` — the reduction runs over
    sublanes, so a moment whose compression dims are leading gets its
    one-pass centered stats without a boundary transpose."""
    s1, s1c, s2c = snr_stats_centered_batched(v[None], axis=0, block=col_block,
                                              interpret=interpret)
    return s1[0], s1c[0], s2c[0]
