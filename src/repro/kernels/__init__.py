from .ops import fused_adam_op, slim_update_op, snr_op
from . import ref

__all__ = ["fused_adam_op", "slim_update_op", "snr_op", "ref"]
