from .ops import (
    Canon2D,
    adam_precond,
    canon2d,
    canon_apply,
    canon_restore,
    default_interpret,
    fused_adam_op,
    slim_precond,
    slim_precond_major,
    slim_update_major,
    slim_update_nd,
    slim_update_op,
    snr_op,
)
from . import ref

__all__ = ["fused_adam_op", "slim_update_op", "slim_update_nd", "snr_op",
           "adam_precond", "slim_precond", "slim_precond_major",
           "slim_update_major", "Canon2D", "canon2d", "canon_apply",
           "canon_restore", "default_interpret", "ref"]
