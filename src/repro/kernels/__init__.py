from .ops import (
    Canon2D,
    CanonND,
    LeafPlan,
    adam_precond,
    canon2d,
    canon_apply,
    canon_nd,
    canon_restore,
    default_interpret,
    fused_adam_op,
    leaf_plan,
    slim_precond,
    slim_precond_batched,
    slim_precond_major,
    slim_update_batched,
    slim_update_major,
    slim_update_nd,
    slim_update_op,
    snr_op,
)
from . import ref

__all__ = ["fused_adam_op", "slim_update_op", "slim_update_nd", "snr_op",
           "adam_precond", "slim_precond", "slim_precond_major",
           "slim_precond_batched", "slim_update_major", "slim_update_batched",
           "CanonND", "Canon2D", "canon_nd", "canon2d", "LeafPlan",
           "leaf_plan", "canon_apply", "canon_restore", "default_interpret",
           "ref"]
