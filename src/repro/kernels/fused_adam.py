"""Fused dense AdamW update — Pallas TPU kernel.

The optimizer step is pure HBM bandwidth: XLA:CPU materializes ~9 fp32
temporaries per tensor (measured in the dry-run buffer dump: 6-9 copies of
each (95, 512, 1376) stacked moment). This kernel streams each tile of
(p, g, m, v) through VMEM exactly once and writes (p', m', v') — 7 tensor
passes total, the bandwidth floor for Adam.

Grid: (rows/TR, cols/TC) tiles; every operand uses the same BlockSpec, so
the working set is 7 * TR * TC * 4 B (fp32) — TR=256, TC=512 -> 3.5 MiB,
comfortably inside the ~16 MiB VMEM with double buffering.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Default (sublane, lane) tile for the dense kernels. The lane width is the
# single source of truth for any layout folded to match these tiles
# (repro.optim.fused lane-folds 1-D/bucketed leaves to LANES-wide rows);
# deriving from one constant keeps a block change from desyncing them.
BLOCK = (256, 512)
LANES = BLOCK[1]


def bias_corrections(b1, b2, count) -> jnp.ndarray:
    """(1-b1^t, 1-b2^t) as a length-2 fp32 operand vector.

    ``count`` may be a Python int or a traced int array — inside a
    GradientTransformation's jitted update the step counter is state, so the
    corrections ride in through the scalar operand instead of being baked
    into the kernel as compile-time constants.
    """
    c = jnp.asarray(count, jnp.float32)
    return jnp.stack([1.0 - jnp.asarray(b1, jnp.float32) ** c,
                      1.0 - jnp.asarray(b2, jnp.float32) ** c])


def _adam_kernel(p_ref, g_ref, m_ref, v_ref, scal_ref,
                 p_out, m_out, v_out, *, b1: float, b2: float, eps: float, wd: float):
    lr = scal_ref[0]
    bc1 = scal_ref[1]
    bc2 = scal_ref[2]
    g = g_ref[...].astype(jnp.float32)
    m_new = b1 * m_ref[...] + (1.0 - b1) * g
    v_new = b2 * v_ref[...] + (1.0 - b2) * g * g
    update = (m_new / bc1) / (jnp.sqrt(v_new / bc2) + eps)
    if wd:
        update = update + wd * p_ref[...].astype(jnp.float32)
    p_out[...] = (p_ref[...].astype(jnp.float32) - lr * update).astype(p_out.dtype)
    m_out[...] = m_new
    v_out[...] = v_new


def fused_adam(p, g, m, v, *, lr: float, b1: float = 0.9, b2: float = 0.95,
               eps: float = 1e-8, wd: float = 0.0, count: int = 1,
               block: tuple = BLOCK, interpret: bool = True):
    """p, g: (R, C) any float dtype; m, v: (R, C) fp32. Returns (p', m', v')."""
    r, c = p.shape
    tr = min(block[0], r)
    tc = min(block[1], c)
    if r % tr or c % tc:
        # pad to tile multiples (pallas grids need exact tiling)
        rp, cp = -(-r // tr) * tr, -(-c // tc) * tc
        pad = lambda x: jnp.pad(x, ((0, rp - r), (0, cp - c)))
        p2, g2, m2, v2 = pad(p), pad(g), pad(m), pad(v)
        po, mo, vo = fused_adam(p2, g2, m2, v2, lr=lr, b1=b1, b2=b2, eps=eps,
                                wd=wd, count=count, block=block, interpret=interpret)
        return po[:r, :c], mo[:r, :c], vo[:r, :c]

    scal = jnp.concatenate([jnp.full((1,), lr, jnp.float32),
                            bias_corrections(b1, b2, count)])
    spec = pl.BlockSpec((tr, tc), lambda i, j: (i, j))
    grid = (r // tr, c // tc)
    kernel = functools.partial(_adam_kernel, b1=b1, b2=b2, eps=eps, wd=wd)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[spec, spec, spec, spec,
                  pl.BlockSpec((3,), lambda i, j: (0,))],
        out_specs=[pl.BlockSpec((tr, tc), lambda i, j: (i, j))] * 3,
        out_shape=[
            jax.ShapeDtypeStruct((r, c), p.dtype),
            jax.ShapeDtypeStruct((r, c), jnp.float32),
            jax.ShapeDtypeStruct((r, c), jnp.float32),
        ],
        interpret=interpret,
    )(p, g, m, v, scal)


def health_terms(g32) -> jnp.ndarray:
    """``[nonfinite_count, finite_masked_sumsq]`` of one gradient block.

    The sum-of-squares is masked to the finite entries so the global grad
    norm stays usable even on a step where some entries are NaN/Inf — the
    guard layer reports both "how many entries were poisoned" and "how big
    was the rest of the gradient".
    """
    fin = jnp.isfinite(g32)
    nf = jnp.sum(jnp.where(fin, 0.0, 1.0))
    ss = jnp.sum(jnp.where(fin, g32 * g32, 0.0))
    return jnp.stack([nf, ss])


def _adam_precond_kernel(g_ref, m_ref, v_ref, scal_ref, u_out, m_out, v_out,
                         *h_out, b1: float, b2: float, eps: float):
    bc1 = scal_ref[0]
    bc2 = scal_ref[1]
    g = g_ref[...].astype(jnp.float32)
    m_new = b1 * m_ref[...] + (1.0 - b1) * g
    v_new = b2 * v_ref[...] + (1.0 - b2) * g * g
    u_out[...] = (m_new / bc1) / (jnp.sqrt(v_new / bc2) + eps)
    m_out[...] = m_new
    v_out[...] = v_new
    if h_out:
        # (2,) accumulator shared by every grid instance: the TPU grid is
        # sequential, so zero on the first instance, then add each tile's
        # contribution. Costs one O(1) output — no extra tensor pass.
        @pl.when((pl.program_id(0) == 0) & (pl.program_id(1) == 0))
        def _zero():
            h_out[0][...] = jnp.zeros((2,), jnp.float32)

        h_out[0][...] = h_out[0][...] + health_terms(g)


def adam_precond(g, m, v, *, b1: float = 0.9, b2: float = 0.999, eps: float = 1e-8,
                 count=1, block: tuple = BLOCK, interpret: bool = True,
                 with_health: bool = False):
    """Preconditioned Adam update only: (g, m, v) -> (u, m', v'), all fp32.

    The GradientTransformation form of the fused step — lr / weight decay /
    the parameter write happen downstream in the chain, so this streams 6
    tensor passes (g, m, v read + u, m', v' write) and never touches p.
    ``count`` may be a traced int array (see :func:`bias_corrections`).

    ``with_health=True`` appends one ``(2,)`` fp32 output
    ``[nonfinite_count, finite_sumsq]`` of ``g``, accumulated in-pass by the
    same kernel (see :func:`health_terms`) — the anomaly guard's per-leaf
    stats ride the update's existing HBM traffic.
    """
    r, c = g.shape
    tr = min(block[0], r)
    tc = min(block[1], c)
    if r % tr or c % tc:
        rp, cp = -(-r // tr) * tr, -(-c // tc) * tc
        pad = lambda x: jnp.pad(x, ((0, rp - r), (0, cp - c)))
        outs = adam_precond(pad(g), pad(m), pad(v), b1=b1, b2=b2, eps=eps,
                            count=count, block=block, interpret=interpret,
                            with_health=with_health)
        trimmed = tuple(o[:r, :c] for o in outs[:3])
        # zero padding is finite and contributes 0 to both health terms, so
        # the accumulator needs no trimming
        return trimmed + tuple(outs[3:])

    scal = bias_corrections(b1, b2, count)
    spec = pl.BlockSpec((tr, tc), lambda i, j: (i, j))
    kernel = functools.partial(_adam_precond_kernel, b1=b1, b2=b2, eps=eps)
    out_specs = [spec] * 3
    out_shape = [jax.ShapeDtypeStruct((r, c), jnp.float32)] * 3
    if with_health:
        out_specs = out_specs + [pl.BlockSpec((2,), lambda i, j: (0,))]
        out_shape = out_shape + [jax.ShapeDtypeStruct((2,), jnp.float32)]
    return pl.pallas_call(
        kernel,
        grid=(r // tr, c // tc),
        in_specs=[spec, spec, spec, pl.BlockSpec((2,), lambda i, j: (0,))],
        out_specs=out_specs,
        out_shape=out_shape,
        interpret=interpret,
    )(g, m, v, scal)
