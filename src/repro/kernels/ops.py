"""jit'd public wrappers for the Pallas kernels.

``slim_update_any_axis`` history: the fan_in kernel used to serve fan_out
compression by transposing at the boundary — but a pallas_call is an
optimization barrier, so that transpose *materializes* (XLA cannot fuse it
into the kernel). The planner (:func:`canon2d`) now emits whichever 2-D
orientation — reduced-minor (lane reduction) or reduced-major (sublane
reduction) — is reachable by pure reshape, and only falls back to a real
transpose when neither is; dispatchers pick the matching kernel variant.
"""
from __future__ import annotations

import functools
from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

from .fused_adam import adam_precond, fused_adam
from .slim_update import (
    slim_precond,
    slim_precond_major,
    slim_update,
    slim_update_major,
)
from .snr_stats import snr_stats, snr_stats_centered, snr_stats_centered_major
from .ref import snr_from_centered_stats, snr_from_stats

__all__ = ["fused_adam_op", "slim_update_op", "slim_update_nd", "snr_op",
           "fused_adam", "slim_update", "slim_update_major", "adam_precond",
           "slim_precond", "slim_precond_major", "snr_stats",
           "snr_stats_centered", "snr_stats_centered_major", "Canon2D",
           "canon2d", "canon_apply", "canon_restore", "default_interpret"]


def default_interpret() -> bool:
    """Pallas interpret mode everywhere except a real TPU backend (where the
    compiled kernel is the point; elsewhere the interpreter is the
    correctness harness)."""
    return jax.default_backend() != "tpu"


class Canon2D(NamedTuple):
    """Plan for canonicalizing an n-D reduction to the kernels' 2-D layouts.

    The slim/SNR kernels come in two orientations: reduced-minor (reduce
    along lanes, axis 1) and reduced-major (reduce along sublanes, axis 0).
    The planner emits whichever orientation is reachable by *pure reshape* —
    reduced dims trailing -> minor (fan_in of a standard fan_in-minor
    weight), reduced dims leading -> major (fan_out, conv fan_in) — with
    size-1 axes ignored, since moving them never changes memory order. Only
    when neither orientation is reshape-reachable (a genuinely interleaved
    multi-dim K) does the plan fall back to a kept-dims-major transpose,
    which *materializes* — a pallas_call is an optimization barrier, so XLA
    cannot fuse a transpose into the kernel — costing extra HBM passes per
    transposed operand (``is_transpose`` exposes this so byte models can
    account for it).
    """

    perm: Tuple[int, ...]       # permutation applied before the 2-D reshape
    inv: Tuple[int, ...]        # inverse permutation
    rows: int                   # 2-D view leading extent
    cols: int                   # 2-D view trailing extent
    axis: int                   # reduction axis of the 2-D view: 1 | 0
    reshape_only: bool          # True -> canon_apply is a pure reshape

    @property
    def orientation(self) -> str:
        return "minor" if self.axis == 1 else "major"

    @property
    def kept_size(self) -> int:
        """Stored reduced-moment extent (the O(kept) side channel)."""
        return self.rows if self.axis == 1 else self.cols

    @property
    def red_size(self) -> int:
        """Reduction extent — the axis a kernel instance must hold whole."""
        return self.cols if self.axis == 1 else self.rows

    @property
    def is_transpose(self) -> bool:
        return not self.reshape_only


def canon2d(shape: Tuple[int, ...], dims: Tuple[int, ...]) -> Canon2D:
    """Plan a 2-D view of ``shape`` for reduction dims ``dims`` (any
    non-empty subset of axes), preferring a transpose-free orientation."""
    ndim = len(shape)
    if not dims:
        raise ValueError("canon2d needs a non-empty reduction dim set")
    for d in dims:
        if not -ndim <= d < ndim:
            # Match the jnp path's behavior (jnp.mean raises) — a silent
            # d % ndim wrap would reduce the wrong axis.
            raise ValueError(f"reduction dim {d} out of range for shape {shape}")
    dset = {d % ndim for d in dims}
    if len(dset) != len(dims):
        # jnp.mean also rejects aliased axes like (1, -1); keep parity.
        raise ValueError(f"duplicate reduction dims in {dims} for shape {shape}")
    red = tuple(sorted(dset))
    kept = tuple(i for i in range(ndim) if i not in dset)
    red_size = kept_size = 1
    for i in red:
        red_size *= shape[i]
    for i in kept:
        kept_size *= shape[i]

    # Reshape-reachability ignores size-1 axes: shuffling them around never
    # changes memory order, so only the relative order of the non-trivial
    # reduced vs kept axes matters.
    nt_red = [i for i in red if shape[i] > 1]
    nt_kept = [i for i in kept if shape[i] > 1]
    minor_ok = not nt_red or not nt_kept or max(nt_kept) < min(nt_red)
    major_ok = not nt_red or not nt_kept or max(nt_red) < min(nt_kept)

    def _plan(perm, rows, cols, axis, reshape_only):
        inv = [0] * ndim
        for newpos, old in enumerate(perm):
            inv[old] = newpos
        return Canon2D(perm=perm, inv=tuple(inv), rows=rows, cols=cols,
                       axis=axis, reshape_only=reshape_only)

    if minor_ok:
        return _plan(kept + red, kept_size, red_size, 1, True)
    if major_ok:
        return _plan(red + kept, red_size, kept_size, 0, True)
    return _plan(kept + red, kept_size, red_size, 1, False)


def canon_apply(x: jnp.ndarray, cn: Canon2D, *, reduced_cols: bool = False) -> jnp.ndarray:
    """Bring a full tensor (or a size-1-reduced-dims reduced moment, with
    ``reduced_cols=True``) into the kernel's (rows, cols) layout. The
    reduced moment collapses the reduction axis of the 2-D view to 1."""
    if reduced_cols:
        target = (cn.rows, 1) if cn.axis == 1 else (1, cn.cols)
    else:
        target = (cn.rows, cn.cols)
    if cn.reshape_only:
        return x.reshape(target)
    return jnp.transpose(x, cn.perm).reshape(target)


def canon_restore(y2: jnp.ndarray, cn: Canon2D, shape: Tuple[int, ...]) -> jnp.ndarray:
    """Inverse of :func:`canon_apply` back to the original layout ``shape``
    (pass the reduced/stored shape for reduced moments)."""
    if cn.reshape_only:
        return y2.reshape(shape)
    permuted = tuple(shape[i] for i in cn.perm)
    return jnp.transpose(y2.reshape(permuted), cn.inv)


@functools.partial(jax.jit, static_argnames=("lr", "b1", "b2", "eps", "wd", "count", "interpret"))
def fused_adam_op(p, g, m, v, *, lr, b1=0.9, b2=0.95, eps=1e-8, wd=0.0, count=1,
                  interpret=True):
    shape = p.shape
    p2 = p.reshape(-1, shape[-1]) if p.ndim != 2 else p
    g2 = g.reshape(p2.shape)
    m2 = m.reshape(p2.shape)
    v2 = v.reshape(p2.shape)
    po, mo, vo = fused_adam(p2, g2, m2, v2, lr=lr, b1=b1, b2=b2, eps=eps, wd=wd,
                            count=count, interpret=interpret)
    return po.reshape(shape), mo.reshape(shape), vo.reshape(shape)


@functools.partial(jax.jit, static_argnames=("axis", "lr", "b1", "b2", "eps", "wd", "count", "interpret"))
def slim_update_op(p, g, m, v_red, *, axis: int, lr, b1=0.9, b2=0.95, eps=1e-8,
                   wd=0.0, count=1, interpret=True):
    """2-D params; ``axis`` is the compressed (reduced) dim. v_red keeps the
    reduced dim as size 1 (matching repro.core.slim_adam state layout).
    axis=0 runs the major-axis (sublane-reduction) kernel — no transpose."""
    assert p.ndim == 2 and axis in (0, 1)
    if axis == 0:
        return slim_update_major(p, g, m, v_red, lr=lr, b1=b1, b2=b2, eps=eps,
                                 wd=wd, count=count, interpret=interpret)
    return slim_update(p, g, m, v_red, lr=lr, b1=b1, b2=b2, eps=eps, wd=wd,
                       count=count, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("dims", "lr", "b1", "b2", "eps", "wd", "count", "interpret"))
def slim_update_nd(p, g, m, v_red, *, dims: Tuple[int, ...], lr, b1=0.9, b2=0.95,
                   eps=1e-8, wd=0.0, count=1, interpret=True):
    """n-D params, any reduction-dims subset (the general SlimAdam spec).

    ``v_red`` keeps the reduced axes as size 1, matching
    ``repro.core.slim_adam`` state layout. Canonicalizes via :func:`canon2d`
    to whichever 2-D orientation avoids a transpose and dispatches to the
    matching kernel variant, restoring the original layout after.
    """
    cn = canon2d(p.shape, dims)
    fn = slim_update if cn.axis == 1 else slim_update_major
    p2 = canon_apply(p, cn)
    g2 = canon_apply(g, cn)
    m2 = canon_apply(m, cn)
    v2 = canon_apply(v_red, cn, reduced_cols=True)
    po, mo, vo = fn(p2, g2, m2, v2, lr=lr, b1=b1, b2=b2, eps=eps,
                    wd=wd, count=count, interpret=interpret)
    return (canon_restore(po, cn, p.shape), canon_restore(mo, cn, m.shape),
            canon_restore(vo, cn, v_red.shape))


@functools.partial(jax.jit, static_argnames=("axis", "interpret"))
def snr_op(v, *, axis: int = 1, interpret=True) -> jnp.ndarray:
    """Scalar SNR along ``axis`` of a 2-D moment tensor via the fused kernels
    (centered stats — accurate for near-constant, high-SNR slices). axis=1
    reduces along lanes; axis=0 along sublanes (transpose-free for moments
    whose compression dims are leading)."""
    if axis == 0:
        s1, s1c, s2c = snr_stats_centered_major(v, interpret=interpret)
        return snr_from_centered_stats(s1, s1c, s2c, v.shape[0])
    s1, s1c, s2c = snr_stats_centered(v, interpret=interpret)
    return snr_from_centered_stats(s1, s1c, s2c, v.shape[1])
