"""jit'd public wrappers for the Pallas kernels.

``slim_update_any_axis`` generalizes the fan_in kernel to fan_out compression
by transposing at the boundary (XLA fuses the transpose into the surrounding
copy; on TPU the kernel itself always reduces along the minor axis, which is
the lane-friendly direction).
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp

from .fused_adam import fused_adam
from .slim_update import slim_update
from .snr_stats import snr_stats
from .ref import snr_from_stats

__all__ = ["fused_adam_op", "slim_update_op", "snr_op", "fused_adam", "slim_update", "snr_stats"]


@functools.partial(jax.jit, static_argnames=("lr", "b1", "b2", "eps", "wd", "count", "interpret"))
def fused_adam_op(p, g, m, v, *, lr, b1=0.9, b2=0.95, eps=1e-8, wd=0.0, count=1,
                  interpret=True):
    shape = p.shape
    p2 = p.reshape(-1, shape[-1]) if p.ndim != 2 else p
    g2 = g.reshape(p2.shape)
    m2 = m.reshape(p2.shape)
    v2 = v.reshape(p2.shape)
    po, mo, vo = fused_adam(p2, g2, m2, v2, lr=lr, b1=b1, b2=b2, eps=eps, wd=wd,
                            count=count, interpret=interpret)
    return po.reshape(shape), mo.reshape(shape), vo.reshape(shape)


@functools.partial(jax.jit, static_argnames=("axis", "lr", "b1", "b2", "eps", "wd", "count", "interpret"))
def slim_update_op(p, g, m, v_red, *, axis: int, lr, b1=0.9, b2=0.95, eps=1e-8,
                   wd=0.0, count=1, interpret=True):
    """2-D params; ``axis`` is the compressed (reduced) dim. v_red keeps the
    reduced dim as size 1 (matching repro.core.slim_adam state layout)."""
    assert p.ndim == 2 and axis in (0, 1)
    if axis == 0:
        po, mo, vo = slim_update(p.T, g.T, m.T, v_red.T, lr=lr, b1=b1, b2=b2,
                                 eps=eps, wd=wd, count=count, interpret=interpret)
        return po.T, mo.T, vo.T
    return slim_update(p, g, m, v_red, lr=lr, b1=b1, b2=b2, eps=eps, wd=wd,
                       count=count, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("interpret",))
def snr_op(v, *, interpret=True) -> jnp.ndarray:
    """Scalar SNR along axis=1 of a 2-D moment tensor via the fused kernel."""
    s1, s2 = snr_stats(v, interpret=interpret)
    return snr_from_stats(s1, s2, v.shape[1])
