"""jit'd public wrappers for the Pallas kernels.

``slim_update_any_axis`` generalizes the fan_in kernel to fan_out compression
by transposing at the boundary (XLA fuses the transpose into the surrounding
copy; on TPU the kernel itself always reduces along the minor axis, which is
the lane-friendly direction).
"""
from __future__ import annotations

import functools
from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

from .fused_adam import adam_precond, fused_adam
from .slim_update import slim_precond, slim_update
from .snr_stats import snr_stats, snr_stats_centered
from .ref import snr_from_centered_stats, snr_from_stats

__all__ = ["fused_adam_op", "slim_update_op", "slim_update_nd", "snr_op",
           "fused_adam", "slim_update", "adam_precond", "slim_precond",
           "snr_stats", "snr_stats_centered", "Canon2D", "canon2d",
           "canon_apply", "canon_restore", "default_interpret"]


def default_interpret() -> bool:
    """Pallas interpret mode everywhere except a real TPU backend (where the
    compiled kernel is the point; elsewhere the interpreter is the
    correctness harness)."""
    return jax.default_backend() != "tpu"


class Canon2D(NamedTuple):
    """Plan for canonicalizing an n-D reduction to the kernels' 2-D layout.

    The kernels always reduce along the minor axis (the lane-friendly
    direction on TPU); an arbitrary dims-subset reduction becomes a
    kept-dims-major transpose followed by a reshape to (prod(kept),
    prod(reduced)). The transpose is a no-op whenever the reduced dims are
    already trailing (fan_in of a standard (fan_in-minor) weight). When it
    is not, the re-layout *materializes* — a pallas_call is an optimization
    barrier, so XLA cannot fuse a transpose into the kernel — costing extra
    HBM passes per transposed operand (``is_transpose`` exposes this so
    byte models can account for it).
    """

    perm: Tuple[int, ...]       # kept dims first, reduced dims last
    inv: Tuple[int, ...]        # inverse permutation
    rows: int                   # prod of kept dim sizes (>= 1)
    cols: int                   # prod of reduced dim sizes (>= 1)

    @property
    def is_transpose(self) -> bool:
        return self.perm != tuple(range(len(self.perm)))


def canon2d(shape: Tuple[int, ...], dims: Tuple[int, ...]) -> Canon2D:
    """Plan a (rows=kept, cols=reduced) 2-D view of ``shape`` for reduction
    dims ``dims`` (any non-empty subset of axes)."""
    ndim = len(shape)
    if not dims:
        raise ValueError("canon2d needs a non-empty reduction dim set")
    for d in dims:
        if not -ndim <= d < ndim:
            # Match the jnp path's behavior (jnp.mean raises) — a silent
            # d % ndim wrap would reduce the wrong axis.
            raise ValueError(f"reduction dim {d} out of range for shape {shape}")
    dset = {d % ndim for d in dims}
    if len(dset) != len(dims):
        # jnp.mean also rejects aliased axes like (1, -1); keep parity.
        raise ValueError(f"duplicate reduction dims in {dims} for shape {shape}")
    kept = tuple(i for i in range(ndim) if i not in dset)
    perm = kept + tuple(sorted(dset))
    inv = [0] * ndim
    for newpos, old in enumerate(perm):
        inv[old] = newpos
    rows = 1
    for i in kept:
        rows *= shape[i]
    cols = 1
    for i in sorted(dset):
        cols *= shape[i]
    return Canon2D(perm=perm, inv=tuple(inv), rows=rows, cols=cols)


def canon_apply(x: jnp.ndarray, cn: Canon2D, *, reduced_cols: bool = False) -> jnp.ndarray:
    """Bring a full tensor (or a size-1-kept-dims reduced moment, with
    ``reduced_cols=True``) into the kernel's (rows, cols) layout."""
    xt = jnp.transpose(x, cn.perm) if cn.is_transpose else x
    return xt.reshape(cn.rows, 1 if reduced_cols else cn.cols)


def canon_restore(y2: jnp.ndarray, cn: Canon2D, shape: Tuple[int, ...]) -> jnp.ndarray:
    """Inverse of :func:`canon_apply` back to the original layout ``shape``
    (pass the reduced/stored shape for reduced moments)."""
    permuted = tuple(shape[i] for i in cn.perm)
    y = y2.reshape(permuted)
    return jnp.transpose(y, cn.inv) if cn.is_transpose else y


@functools.partial(jax.jit, static_argnames=("lr", "b1", "b2", "eps", "wd", "count", "interpret"))
def fused_adam_op(p, g, m, v, *, lr, b1=0.9, b2=0.95, eps=1e-8, wd=0.0, count=1,
                  interpret=True):
    shape = p.shape
    p2 = p.reshape(-1, shape[-1]) if p.ndim != 2 else p
    g2 = g.reshape(p2.shape)
    m2 = m.reshape(p2.shape)
    v2 = v.reshape(p2.shape)
    po, mo, vo = fused_adam(p2, g2, m2, v2, lr=lr, b1=b1, b2=b2, eps=eps, wd=wd,
                            count=count, interpret=interpret)
    return po.reshape(shape), mo.reshape(shape), vo.reshape(shape)


@functools.partial(jax.jit, static_argnames=("axis", "lr", "b1", "b2", "eps", "wd", "count", "interpret"))
def slim_update_op(p, g, m, v_red, *, axis: int, lr, b1=0.9, b2=0.95, eps=1e-8,
                   wd=0.0, count=1, interpret=True):
    """2-D params; ``axis`` is the compressed (reduced) dim. v_red keeps the
    reduced dim as size 1 (matching repro.core.slim_adam state layout)."""
    assert p.ndim == 2 and axis in (0, 1)
    if axis == 0:
        po, mo, vo = slim_update(p.T, g.T, m.T, v_red.T, lr=lr, b1=b1, b2=b2,
                                 eps=eps, wd=wd, count=count, interpret=interpret)
        return po.T, mo.T, vo.T
    return slim_update(p, g, m, v_red, lr=lr, b1=b1, b2=b2, eps=eps, wd=wd,
                       count=count, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("dims", "lr", "b1", "b2", "eps", "wd", "count", "interpret"))
def slim_update_nd(p, g, m, v_red, *, dims: Tuple[int, ...], lr, b1=0.9, b2=0.95,
                   eps=1e-8, wd=0.0, count=1, interpret=True):
    """n-D params, any reduction-dims subset (the general SlimAdam spec).

    ``v_red`` keeps the reduced axes as size 1, matching
    ``repro.core.slim_adam`` state layout. Canonicalizes to the 2-D
    minor-axis kernel via :func:`canon2d` and restores the original layout.
    """
    cn = canon2d(p.shape, dims)
    p2 = canon_apply(p, cn)
    g2 = canon_apply(g, cn)
    m2 = canon_apply(m, cn)
    v2 = canon_apply(v_red, cn, reduced_cols=True)
    po, mo, vo = slim_update(p2, g2, m2, v2, lr=lr, b1=b1, b2=b2, eps=eps,
                             wd=wd, count=count, interpret=interpret)
    return (canon_restore(po, cn, p.shape), canon_restore(mo, cn, m.shape),
            canon_restore(vo, cn, v_red.shape))


@functools.partial(jax.jit, static_argnames=("interpret",))
def snr_op(v, *, interpret=True) -> jnp.ndarray:
    """Scalar SNR along axis=1 of a 2-D moment tensor via the fused kernel
    (centered stats — accurate for near-constant, high-SNR rows)."""
    s1, s1c, s2c = snr_stats_centered(v, interpret=interpret)
    return snr_from_centered_stats(s1, s1c, s2c, v.shape[1])
