"""jit'd public wrappers + the canonicalization planner for the Pallas kernels.

The slim/SNR kernels operate on one batched canonical form: ``(B, R, C)``
with the reduction confined to a single trailing-ish axis of the per-batch
2-D problem — lanes (minor, reduce C) or sublanes (major, reduce R). The
planner (:func:`canon_nd`) maps any leaf shape and any reduction-dims
subset onto that form by *pure reshape* whenever memory order allows:

  * reduced dims trailing                  -> (1, kept, red), minor;
  * reduced dims leading                   -> (1, red, kept), major;
  * kept prefix / reduced block / kept suffix
    (scan-stacked leaves: ``(layers, embed, heads, hd)`` reducing embed)
                                           -> (B, red, kept), batched major
    — the kept prefix splits off as a batch axis walked by the kernel grid,
    so each batch slice is a transpose-free major-axis 2-D problem.

Size-1 axes never affect reachability (moving them never changes memory
order). Only a genuinely interleaved K — the non-trivial reduced dims not
forming one contiguous block that is trailing, leading, or kept-flanked on
both sides (e.g. a kept dim inside the reduced span, or reduced blocks on
both ends of a kept dim) — falls back to a kept-dims-major transpose,
which *materializes*: a pallas_call is an optimization barrier, so XLA
cannot fuse a re-layout into the kernel, costing extra HBM passes per
transposed operand (``is_transpose`` exposes this so byte models can
account for it).

:func:`leaf_plan` is the single per-leaf dispatch decision built on top:
plan -> VMEM fits-gate -> route (dense kernel / slim kernel / jnp
fallback), consumed by ``repro.optim.fused``, ``repro.core.snr``, and
:func:`slim_update_nd`; the opt_speed roofline byte model consumes the raw
:func:`canon_nd` plans (it charges bytes per layout, not per route).
"""
from __future__ import annotations

import functools
import math
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from .fused_adam import adam_precond, bias_corrections, fused_adam
from .ref import snr_from_centered_stats
from .slim_update import (
    PRECOND_BUFS,
    UPDATE_BUFS,
    slim_finalize,
    slim_finalize_batched,
    slim_partial_stats,
    slim_partial_stats_batched,
    slim_precond,
    slim_precond_batched,
    slim_precond_major,
    slim_update,
    slim_update_batched,
    slim_update_major,
)
from .snr_stats import (
    snr_stats,
    snr_stats_centered,
    snr_stats_centered_batched,
    snr_stats_centered_major,
    snr_stats_centered_partial,
    snr_stats_centered_partial_batched,
)
from .tiling import strip_fits

__all__ = ["fused_adam_op", "slim_update_op", "slim_update_nd", "snr_op",
           "snr_partial_op", "fused_adam", "slim_update", "slim_update_major",
           "slim_update_batched", "adam_precond", "slim_precond",
           "slim_precond_major", "slim_precond_batched",
           "slim_partial_stats", "slim_partial_stats_batched",
           "slim_finalize", "slim_finalize_batched", "snr_stats",
           "snr_stats_centered", "snr_stats_centered_major",
           "snr_stats_centered_batched", "snr_stats_centered_partial",
           "snr_stats_centered_partial_batched", "CanonND", "Canon2D",
           "canon_nd", "canon2d", "canon_apply", "canon_restore", "LeafPlan",
           "leaf_plan", "default_interpret"]


def default_interpret() -> bool:
    """Pallas interpret mode everywhere except a real TPU backend (where the
    compiled kernel is the point; elsewhere the interpreter is the
    correctness harness)."""
    return jax.default_backend() != "tpu"


class CanonND(NamedTuple):
    """Plan for canonicalizing an n-D reduction to the kernels' batched
    (B, R, C) layouts.

    ``axis`` is the reduction axis of the *per-batch 2-D problem* (1 = minor
    / lanes, 0 = major / sublanes), matching the kernel orientations. The
    canonical view is 2-D ``(rows, cols)`` when ``batch == 1`` and 3-D
    ``(batch, rows, cols)`` otherwise; batched plans are always major-axis
    (a trailing reduction folds every kept prefix into rows instead, so
    minor never needs a batch dim) and always reshape-only.
    """

    perm: Tuple[int, ...]       # permutation applied before the reshape
    inv: Tuple[int, ...]        # inverse permutation
    batch: int                  # kept-prefix batch extent (1 = plain 2-D)
    rows: int                   # per-batch leading extent
    cols: int                   # per-batch trailing extent
    axis: int                   # per-batch 2-D reduction axis: 1 | 0
    reshape_only: bool          # True -> canon_apply is a pure reshape

    @property
    def orientation(self) -> str:
        return "minor" if self.axis == 1 else "major"

    @property
    def kept_size(self) -> int:
        """Stored reduced-moment extent (the O(kept) side channel),
        including the batch dim."""
        return self.batch * (self.rows if self.axis == 1 else self.cols)

    @property
    def red_size(self) -> int:
        """Reduction extent — the line a kernel instance must hold whole
        (batch-independent: batch rides on the grid, not in VMEM)."""
        return self.cols if self.axis == 1 else self.rows

    @property
    def view(self) -> Tuple[int, ...]:
        """Shape of the canonical view ``canon_apply`` produces."""
        if self.batch > 1:
            return (self.batch, self.rows, self.cols)
        return (self.rows, self.cols)

    @property
    def red_axis(self) -> int:
        """Reduction axis within :attr:`view` (for jnp means over it)."""
        return self.axis + 1 if self.batch > 1 else self.axis

    @property
    def is_transpose(self) -> bool:
        return not self.reshape_only


# Back-compat alias: pre-batched callers imported the 2-D plan class.
Canon2D = CanonND


def canon_nd(shape: Tuple[int, ...], dims: Tuple[int, ...]) -> CanonND:
    """Plan a batched canonical view of ``shape`` for reduction dims ``dims``
    (any non-empty subset of axes), preferring a transpose-free plan."""
    ndim = len(shape)
    if not dims:
        raise ValueError("canon_nd needs a non-empty reduction dim set")
    for d in dims:
        if not -ndim <= d < ndim:
            # Match the jnp path's behavior (jnp.mean raises) — a silent
            # d % ndim wrap would reduce the wrong axis.
            raise ValueError(f"reduction dim {d} out of range for shape {shape}")
    dset = {d % ndim for d in dims}
    if len(dset) != len(dims):
        # jnp.mean also rejects aliased axes like (1, -1); keep parity.
        raise ValueError(f"duplicate reduction dims in {dims} for shape {shape}")
    red = tuple(sorted(dset))
    kept = tuple(i for i in range(ndim) if i not in dset)
    red_size = kept_size = 1
    for i in red:
        red_size *= shape[i]
    for i in kept:
        kept_size *= shape[i]

    # Reshape-reachability ignores size-1 axes: shuffling them around never
    # changes memory order, so only the relative order of the non-trivial
    # reduced vs kept axes matters.
    nt_red = [i for i in red if shape[i] > 1]
    nt_kept = [i for i in kept if shape[i] > 1]
    minor_ok = not nt_red or not nt_kept or max(nt_kept) < min(nt_red)
    major_ok = not nt_red or not nt_kept or max(nt_red) < min(nt_kept)

    def _plan(perm, batch, rows, cols, axis, reshape_only):
        inv = [0] * ndim
        for newpos, old in enumerate(perm):
            inv[old] = newpos
        return CanonND(perm=perm, inv=tuple(inv), batch=batch, rows=rows,
                       cols=cols, axis=axis, reshape_only=reshape_only)

    if minor_ok:
        return _plan(kept + red, 1, kept_size, red_size, 1, True)
    if major_ok:
        return _plan(red + kept, 1, red_size, kept_size, 0, True)
    # Batched major: a contiguous non-trivial reduced block with kept axes
    # on both sides — split the kept prefix off as the batch dim, leaving
    # each batch slice a pure-reshape major-axis 2-D problem. Covers every
    # scan-stacked leaf (layers leading, reduction inner).
    lo, hi = min(nt_red), max(nt_red)
    if all(k < lo or k > hi for k in nt_kept):
        batch = math.prod(shape[:lo])
        rows = math.prod(shape[lo:hi + 1])      # == red_size (interior kept are size-1)
        cols = math.prod(shape[hi + 1:])
        return _plan(tuple(range(ndim)), batch, rows, cols, 0, True)
    return _plan(kept + red, 1, kept_size, red_size, 1, False)


# Back-compat alias: ``canon_nd`` subsumes the 2-D planner (batch-free
# shapes get identical plans with batch == 1).
canon2d = canon_nd


def canon_apply(x: jnp.ndarray, cn: CanonND, *, reduced_cols: bool = False) -> jnp.ndarray:
    """Bring a full tensor (or a size-1-reduced-dims reduced moment, with
    ``reduced_cols=True``) into the kernel's canonical layout — 2-D
    (rows, cols) for batch-free plans, 3-D (batch, rows, cols) for batched
    ones. The reduced moment collapses the plan's reduction axis to 1."""
    if cn.batch > 1:
        # Batched plans are always reshape-only major (reduce rows).
        target = (cn.batch, 1, cn.cols) if reduced_cols else cn.view
        return x.reshape(target)
    if reduced_cols:
        target = (cn.rows, 1) if cn.axis == 1 else (1, cn.cols)
    else:
        target = (cn.rows, cn.cols)
    if cn.reshape_only:
        return x.reshape(target)
    return jnp.transpose(x, cn.perm).reshape(target)


def canon_restore(y2: jnp.ndarray, cn: CanonND, shape: Tuple[int, ...]) -> jnp.ndarray:
    """Inverse of :func:`canon_apply` back to the original layout ``shape``
    (pass the reduced/stored shape for reduced moments)."""
    if cn.reshape_only:
        return y2.reshape(shape)
    permuted = tuple(shape[i] for i in cn.perm)
    return jnp.transpose(y2.reshape(permuted), cn.inv)


class LeafPlan(NamedTuple):
    """Precomputed per-leaf dispatch decision: plan -> fits-gate -> route,
    in one place. ``route`` is 'dense' (K = (), dense kernels), 'slim'
    (compressed, ``cn`` holds the canonical plan), or 'jnp' (the per-leaf
    fallback: scalar/empty/non-float leaves, reduction lines that outrun
    VMEM, or transposing plans when the caller forbids them)."""

    route: str                  # 'dense' | 'slim' | 'jnp'
    cn: Optional[CanonND]       # set iff route == 'slim'


def leaf_plan(shape: Tuple[int, ...], dtype, dims: Tuple[int, ...], *,
              n_bufs: int = PRECOND_BUFS, allow_transpose: bool = True) -> LeafPlan:
    """Plan one leaf's kernel dispatch.

    ``n_bufs`` is the consuming kernel's live full-size fp32 buffer count
    per instance (``slim_update.PRECOND_BUFS`` / ``UPDATE_BUFS``,
    ``snr_stats.CENTERED_BUFS``) — the VMEM fits-gate is orientation-aware
    through the plan's ``red_size`` and batch-independent (batch rides on
    the grid). ``allow_transpose=False`` routes genuinely interleaved-K
    leaves to jnp instead — right for consumers whose single-pass win a
    materialized boundary transpose would forfeit (SNR stats).
    """
    if not (len(shape) >= 1 and math.prod(shape) > 0
            and jnp.issubdtype(dtype, jnp.floating)):
        return LeafPlan("jnp", None)
    dims = tuple(dims)
    if not dims:
        return LeafPlan("dense", None)
    cn = canon_nd(shape, dims)
    if not strip_fits(cn.red_size, n_bufs):
        # A single canonical reduction line outruns VMEM (full-reduction K
        # on a big tensor) — no strip kernel can serve it on a real TPU.
        return LeafPlan("jnp", None)
    if cn.is_transpose and not allow_transpose:
        return LeafPlan("jnp", None)
    return LeafPlan("slim", cn)


@functools.partial(jax.jit, static_argnames=("lr", "b1", "b2", "eps", "wd", "count", "interpret"))
def fused_adam_op(p, g, m, v, *, lr, b1=0.9, b2=0.95, eps=1e-8, wd=0.0, count=1,
                  interpret=True):
    shape = p.shape
    p2 = p.reshape(-1, shape[-1]) if p.ndim != 2 else p
    g2 = g.reshape(p2.shape)
    m2 = m.reshape(p2.shape)
    v2 = v.reshape(p2.shape)
    po, mo, vo = fused_adam(p2, g2, m2, v2, lr=lr, b1=b1, b2=b2, eps=eps, wd=wd,
                            count=count, interpret=interpret)
    return po.reshape(shape), mo.reshape(shape), vo.reshape(shape)


@functools.partial(jax.jit, static_argnames=("axis", "lr", "b1", "b2", "eps", "wd", "count", "interpret"))
def slim_update_op(p, g, m, v_red, *, axis: int, lr, b1=0.9, b2=0.95, eps=1e-8,
                   wd=0.0, count=1, interpret=True):
    """2-D params; ``axis`` is the compressed (reduced) dim. v_red keeps the
    reduced dim as size 1 (matching repro.core.slim_adam state layout).
    axis=0 runs the major-axis (sublane-reduction) kernel — no transpose."""
    assert p.ndim == 2 and axis in (0, 1)
    if axis == 0:
        return slim_update_major(p, g, m, v_red, lr=lr, b1=b1, b2=b2, eps=eps,
                                 wd=wd, count=count, interpret=interpret)
    return slim_update(p, g, m, v_red, lr=lr, b1=b1, b2=b2, eps=eps, wd=wd,
                       count=count, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("dims", "lr", "b1", "b2", "eps", "wd", "count", "interpret"))
def slim_update_nd(p, g, m, v_red, *, dims: Tuple[int, ...], lr, b1=0.9, b2=0.95,
                   eps=1e-8, wd=0.0, count=1, interpret=True):
    """n-D params, any reduction-dims subset (the general SlimAdam spec).

    ``v_red`` keeps the reduced axes as size 1, matching
    ``repro.core.slim_adam`` state layout. :func:`leaf_plan` picks whichever
    batched (B, R, C) layout avoids a transpose — including the
    batched-major form for scan-stacked leaves — and this dispatches to the
    matching kernel, restoring the original layout after. Leaves the strip
    kernels can't serve (a reduction line that outruns VMEM, odd dtypes)
    run the same semantics in plain jnp.
    """
    plan = leaf_plan(p.shape, p.dtype, dims, n_bufs=UPDATE_BUFS)
    if plan.route != "slim":
        g32 = g.astype(jnp.float32)
        m_new = b1 * m + (1 - b1) * g32
        ek = jnp.mean(jnp.square(g32), axis=dims, keepdims=True)
        v_new = b2 * v_red + (1 - b2) * ek
        bc1, bc2 = bias_corrections(b1, b2, count)
        update = (m_new / bc1) / (jnp.sqrt(v_new / bc2) + eps)
        if wd:
            update = update + wd * p.astype(jnp.float32)
        p_new = (p.astype(jnp.float32) - lr * update).astype(p.dtype)
        return p_new, m_new, v_new
    cn = plan.cn
    p2 = canon_apply(p, cn)
    g2 = canon_apply(g, cn)
    m2 = canon_apply(m, cn)
    v2 = canon_apply(v_red, cn, reduced_cols=True)
    kw = dict(lr=lr, b1=b1, b2=b2, eps=eps, wd=wd, count=count, interpret=interpret)
    if cn.batch > 1:
        po, mo, vo = slim_update_batched(p2, g2, m2, v2, axis=cn.axis, **kw)
    else:
        fn = slim_update if cn.axis == 1 else slim_update_major
        po, mo, vo = fn(p2, g2, m2, v2, **kw)
    return (canon_restore(po, cn, p.shape), canon_restore(mo, cn, m.shape),
            canon_restore(vo, cn, v_red.shape))


@functools.partial(jax.jit, static_argnames=("axis", "interpret"))
def snr_partial_op(v, *, axis: int = 1, interpret=True):
    """Per-line partial centered stats of a canonical moment view, flattened
    to 1-D: (line_sum, shifted_line_sum, shifted_line_sumsq, line_first).

    The sharded-SNR building block: each device runs this on its local shard
    of the canonical (rows, cols) / (batch, rows, cols) view, rebases the
    shifted sums to a mesh-common shift
    (:func:`repro.kernels.ref.rebase_centered_stats`), and ``lax.psum``-s
    them over the mesh axes owning the reduction dim before the
    :func:`repro.kernels.ref.snr_from_centered_stats` finalization."""
    if v.ndim == 2:
        v = v[None]
    s1, s1c, s2c, f = snr_stats_centered_partial_batched(v, axis=axis, interpret=interpret)
    return s1.ravel(), s1c.ravel(), s2c.ravel(), f.ravel()


@functools.partial(jax.jit, static_argnames=("axis", "interpret"))
def snr_op(v, *, axis: int = 1, interpret=True) -> jnp.ndarray:
    """Scalar SNR over a canonical moment view via the fused centered-stats
    kernels (accurate for near-constant, high-SNR lines). ``v`` is 2-D
    (rows, cols) or batched 3-D (batch, rows, cols); ``axis`` is the
    per-batch 2-D reduction axis (1 = lanes, 0 = sublanes)."""
    n = v.shape[-1] if axis == 1 else v.shape[-2]
    if v.ndim == 3:
        s1, s1c, s2c = snr_stats_centered_batched(v, axis=axis, interpret=interpret)
    elif axis == 0:
        s1, s1c, s2c = snr_stats_centered_major(v, interpret=interpret)
    else:
        s1, s1c, s2c = snr_stats_centered(v, interpret=interpret)
    return snr_from_centered_stats(s1, s1c, s2c, n)
