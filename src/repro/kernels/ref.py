"""Pure-jnp oracles for every Pallas kernel (the allclose targets).

Semantics match ``repro.optim.adam`` / ``repro.core.slim_adam`` exactly —
property tests in tests/test_kernels.py also assert kernel == optimizer-path.
"""
from __future__ import annotations

from typing import Tuple

import jax.numpy as jnp


def adam_update_ref(p, g, m, v, *, lr: float, b1: float, b2: float, eps: float,
                    wd: float, count: int) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Dense fused AdamW step: returns (new_p, new_m, new_v). fp32 state."""
    g32 = g.astype(jnp.float32)
    m_new = b1 * m + (1 - b1) * g32
    v_new = b2 * v + (1 - b2) * jnp.square(g32)
    bc1 = 1.0 - b1 ** count
    bc2 = 1.0 - b2 ** count
    update = (m_new / bc1) / (jnp.sqrt(v_new / bc2) + eps)
    if wd:
        update = update + wd * p.astype(jnp.float32)
    p_new = (p.astype(jnp.float32) - lr * update).astype(p.dtype)
    return p_new, m_new, v_new


def slim_update_ref(p, g, m, v_row, *, lr: float, b1: float, b2: float, eps: float,
                    wd: float, count: int) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """SlimAdam step with the second moment compressed along axis=1 (fan_in).

    p, g, m: (R, C); v_row: (R, 1) reduced second moment.
    V <- b2 V + (1-b2) * mean_C[g^2]  (Eq. 2), broadcast in the preconditioner.
    """
    g32 = g.astype(jnp.float32)
    m_new = b1 * m + (1 - b1) * g32
    ek = jnp.mean(jnp.square(g32), axis=1, keepdims=True)
    v_new = b2 * v_row + (1 - b2) * ek
    bc1 = 1.0 - b1 ** count
    bc2 = 1.0 - b2 ** count
    update = (m_new / bc1) / (jnp.sqrt(v_new / bc2) + eps)
    if wd:
        update = update + wd * p.astype(jnp.float32)
    p_new = (p.astype(jnp.float32) - lr * update).astype(p.dtype)
    return p_new, m_new, v_new


def snr_stats_ref(v: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Per-row (sum, sum of squares) over axis=1 — the reduction SNR_K needs.

    SNR finalization (mean^2 / var, averaged over rows) is O(R) host math.
    """
    v32 = v.astype(jnp.float32)
    return jnp.sum(v32, axis=1), jnp.sum(jnp.square(v32), axis=1)


def snr_from_stats(s1: jnp.ndarray, s2: jnp.ndarray, n: int, eps: float = 1e-30) -> jnp.ndarray:
    mean = s1 / n
    var = s2 / n - jnp.square(mean)
    return jnp.mean(jnp.square(mean) / (jnp.maximum(var, 0.0) + eps))


def snr_from_centered_stats(s1: jnp.ndarray, s1c: jnp.ndarray, s2c: jnp.ndarray,
                            n: int, eps: float = 1e-30) -> jnp.ndarray:
    """Finalize ``snr_stats_centered`` output: variance from the shifted sums
    (shift-invariant, no magnitude-scale cancellation), mean from the raw sum."""
    mean = s1 / n
    mean_c = s1c / n
    var = s2c / n - jnp.square(mean_c)
    return jnp.mean(jnp.square(mean) / (jnp.maximum(var, 0.0) + eps))


def snr_stats_centered_partial_ref(v: jnp.ndarray, dims: Tuple[int, ...]):
    """Oracle for ``snr_stats_centered_partial*``: per-line (sum, shifted
    sum, shifted sumsq, first entry) over arbitrary reduction ``dims``,
    keepdims layout (the jnp fallback the sharded SNR path uses when no
    kernel serves the local shard)."""
    v32 = v.astype(jnp.float32)
    idx = tuple(slice(0, 1) if d in {x % v.ndim for x in dims} else slice(None)
                for d in range(v.ndim))
    first = v32[idx]
    d = v32 - first
    s1 = jnp.sum(v32, axis=dims, keepdims=True)
    s1c = jnp.sum(d, axis=dims, keepdims=True)
    s2c = jnp.sum(d * d, axis=dims, keepdims=True)
    return s1, s1c, s2c, first


def rebase_centered_stats(s1c: jnp.ndarray, s2c: jnp.ndarray, first: jnp.ndarray,
                          shift: jnp.ndarray, n: int):
    """Re-express per-shard centered sums (local shift ``first``) under a
    common ``shift``:

        s1c' = s1c + n * (first - shift)
        s2c' = s2c + 2 * (first - shift) * s1c + n * (first - shift)^2

    Exact algebra, and — unlike recomputing from the raw sums — every term
    stays O(spread): ``first - shift`` is a difference of near-equal line
    entries (Sterbenz-exact in the near-constant high-SNR regime the
    centered kernels exist for), so the cross-shard composition keeps the
    one-pass variance's precision. After rebasing, the sums from different
    shards of one line simply add (``lax.psum``)."""
    d = first - shift
    return s1c + n * d, s2c + 2.0 * d * s1c + n * d * d
