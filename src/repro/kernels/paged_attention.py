"""Ragged paged-attention Pallas kernel (decode + chunked prefill).

The serving fast path stores each attention layer's KV cache as a pool of
fixed-size *pages* in the fused layout ``(n_pages, page_size, 2 * kv_heads,
head_dim)`` — K and V interleaved on even/odd head indices so one page DMA
streams both (see :mod:`repro.serve.kvpool`). A request owns a row of a
page *table* (``(B, max_pages) int32``; entry 0 is the reserved null page)
and a ``lengths`` entry saying how many positions are live.

One kernel serves both serving phases:

  * **decode** — ``q`` is ``(B, 1, H, hd)``: each grid row walks its page
    list and reduces an online softmax across pages;
  * **chunked prefill** — ``q`` is ``(1, C, H, hd)`` holding C prompt
    tokens at absolute positions ``length - C .. length - 1``; the causal
    in-kernel mask (``k_abs <= q_abs``) subsumes the ragged length mask, so
    prefill costs ``ceil(S/C)`` steps instead of S decode steps.

Grid is ``(B, max_pages)`` with the page dim innermost and *sequential*, so
Pallas double-buffers the page stream: the next page's DMA (its block index
comes from the scalar-prefetched table, ``tbl[b, p]``) overlaps the current
page's compute. The accumulator outputs (acc, m, l) alias one block per
batch row across the page dim — the same zero-on-first-instance + RMW
pattern as the health accumulators in :mod:`repro.kernels.slim_update`,
which the :mod:`repro.analysis.races` pass verifies (including that the
pool block's index map really is the page-table lookup).

Rows past their page count are skipped (``pl.when``), padded table entries
point at the null page and are masked by construction, and the final
``acc / l`` normalization happens outside the kernel, so no blind output
write exists anywhere.
"""
from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .ops import default_interpret
from .tiling import COMPUTE_ITEMSIZE, VMEM_BUDGET

NEG_INF = -1e30

# Full-size VMEM blocks the kernel keeps live per instance: the current KV
# page, the next page's double-buffer in flight, and one compute copy
# (cast / exp scratch) — q and the accumulator are O(C * H * hd) and charged
# separately by paged_fits.
PAGED_ATTN_BUFS = 3


def paged_fits(chunk: int, n_heads: int, head_dim: int, page_size: int,
               kv2: int, *, itemsize: int = COMPUTE_ITEMSIZE) -> bool:
    """Whether one grid instance's working set fits :data:`VMEM_BUDGET`:
    ``PAGED_ATTN_BUFS`` page lines (current + prefetched + compute copy)
    plus the q block, the acc block and the two (C, H) softmax stats, all
    charged at the f32 compute itemsize (same policy as
    :func:`repro.kernels.tiling.strip_fits`)."""
    page_line = page_size * kv2 * head_dim
    qacc = chunk * n_heads * head_dim
    stats = chunk * n_heads
    working = PAGED_ATTN_BUFS * page_line + 2 * qacc + 2 * stats
    return working * itemsize <= VMEM_BUDGET


def _paged_kernel(tbl_ref, len_ref, q_ref, pool_ref, acc_ref, m_ref, l_ref,
                  *, page: int, kv: int, rep: int):
    b = pl.program_id(0)
    p = pl.program_id(1)
    c = q_ref.shape[1]
    h = kv * rep
    hd = q_ref.shape[3]
    scale = 1.0 / math.sqrt(hd)

    @pl.when(p == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    length = len_ref[b]
    n_pages = (length + page - 1) // page

    @pl.when(p < n_pages)
    def _page():
        kv_blk = pool_ref[...].astype(jnp.float32)   # (1, page, 2KV, hd)
        k = kv_blk[0, :, 0::2, :]                    # (page, KV, hd)
        v = kv_blk[0, :, 1::2, :]
        q = q_ref[...].astype(jnp.float32)           # (1, C, H, hd)
        qg = q[0].reshape(c, kv, rep, hd) * scale
        s = jnp.einsum("ckrd,pkd->ckrp", qg, k)      # (C, KV, rep, page)

        # Causal/ragged mask in absolute positions: queries sit at the last
        # C positions of the row, so k <= q also bounds k < length.
        k_abs = p * page + jnp.arange(page)
        q_abs = length - c + jnp.arange(c)
        mask = k_abs[None, :] <= q_abs[:, None]      # (C, page)
        s = jnp.where(mask[:, None, None, :], s, NEG_INF)

        s_flat = s.reshape(1, c, h, page)
        m_old = m_ref[...]
        m_new = jnp.maximum(m_old, jnp.max(s_flat, axis=-1))
        pexp = jnp.exp(s_flat - m_new[..., None])
        pexp = jnp.where(mask[None, :, None, :], pexp, 0.0)
        corr = jnp.exp(m_old - m_new)
        l_ref[...] = l_ref[...] * corr + jnp.sum(pexp, axis=-1)
        pv = jnp.einsum("ckrp,pkd->ckrd",
                        pexp[0].reshape(c, kv, rep, page), v)
        acc_ref[...] = acc_ref[...] * corr[..., None] + pv.reshape(1, c, h, hd)
        m_ref[...] = m_new


def paged_attention_ref(q: jnp.ndarray, pool: jnp.ndarray, table: jnp.ndarray,
                        lengths: jnp.ndarray) -> jnp.ndarray:
    """jnp oracle / fallback: gather every table page densely and run a full
    masked softmax. O(max_pages * page) memory per row — correct everywhere,
    used when :func:`paged_fits` rejects the geometry and as the parity
    oracle in tests."""
    b, c, h, hd = q.shape
    _, page, kv2, _ = pool.shape
    kv = kv2 // 2
    rep = h // kv
    gathered = pool[table].astype(jnp.float32)   # (B, max_pages, page, 2KV, hd)
    s_max = table.shape[1] * page
    k = gathered[:, :, :, 0::2, :].reshape(b, s_max, kv, hd)
    v = gathered[:, :, :, 1::2, :].reshape(b, s_max, kv, hd)
    qg = q.astype(jnp.float32).reshape(b, c, kv, rep, hd) / math.sqrt(hd)
    s = jnp.einsum("bckrd,bpkd->bckrp", qg, k)
    q_abs = lengths[:, None] - c + jnp.arange(c)[None, :]      # (B, C)
    mask = jnp.arange(s_max)[None, None, :] <= q_abs[:, :, None]
    s = jnp.where(mask[:, :, None, None, :], s, NEG_INF)
    s = s - jnp.max(s, axis=-1, keepdims=True)
    pexp = jnp.where(mask[:, :, None, None, :], jnp.exp(s), 0.0)
    num = jnp.einsum("bckrp,bpkd->bckrd", pexp, v)
    den = jnp.maximum(jnp.sum(pexp, axis=-1), 1e-30)
    return (num / den[..., None]).reshape(b, c, h, hd).astype(q.dtype)


def paged_attention(q: jnp.ndarray, pool: jnp.ndarray, table: jnp.ndarray,
                    lengths: jnp.ndarray, *,
                    interpret: Optional[bool] = None,
                    use_ref: bool = False) -> jnp.ndarray:
    """Ragged paged attention over a fused-layout page pool.

    q: (B, C, H, hd) — C == 1 for decode, C == chunk for chunked prefill
    (queries at absolute positions ``lengths - C .. lengths - 1``).
    pool: (n_pages, page, 2 * KV, hd), K/V on even/odd head indices.
    table: (B, max_pages) int32 page ids (0 = null page for padding).
    lengths: (B,) int32 live positions per row (0 = inactive row -> zeros).

    Returns (B, C, H, hd) in q.dtype. Falls back to the dense jnp reference
    when the geometry exceeds the VMEM fit gate, or unconditionally with
    ``use_ref=True`` — the serving engine's graceful-degradation path
    retraces through the reference when a kernel launch fails mid-serve.
    """
    b, c, h, hd = q.shape
    _, page, kv2, hd2 = pool.shape
    assert hd2 == hd and kv2 % 2 == 0, (pool.shape, q.shape)
    kv = kv2 // 2
    assert h % kv == 0, (h, kv)
    rep = h // kv
    max_pages = table.shape[1]
    if use_ref or not paged_fits(c, h, hd, page, kv2):
        return paged_attention_ref(q, pool, table, lengths)

    kernel = functools.partial(_paged_kernel, page=page, kv=kv, rep=rep)
    f32 = jnp.float32
    acc, m, l = pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=(b, max_pages),
            in_specs=[
                pl.BlockSpec((1, c, h, hd), lambda bi, p, tbl, ln: (bi, 0, 0, 0)),
                pl.BlockSpec((1, page, kv2, hd),
                             lambda bi, p, tbl, ln: (tbl[bi, p], 0, 0, 0)),
            ],
            out_specs=[
                pl.BlockSpec((1, c, h, hd), lambda bi, p, tbl, ln: (bi, 0, 0, 0)),
                pl.BlockSpec((1, c, h), lambda bi, p, tbl, ln: (bi, 0, 0)),
                pl.BlockSpec((1, c, h), lambda bi, p, tbl, ln: (bi, 0, 0)),
            ],
        ),
        out_shape=[
            jax.ShapeDtypeStruct((b, c, h, hd), f32),
            jax.ShapeDtypeStruct((b, c, h), f32),
            jax.ShapeDtypeStruct((b, c, h), f32),
        ],
        interpret=default_interpret() if interpret is None else interpret,
    )(table, lengths, q, pool)
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out.astype(q.dtype)
