"""Shared tiling policy + grid/BlockSpec builder for strip kernels.

The slim-update and snr-stats kernels all share one canonical layout: a
``(B, R, C)`` tensor whose reduction axis is held *whole* inside each kernel
instance while a grid walks the batch dim and strips of the kept axis. Two
orientations cover every reshape-reachable reduction:

  * **minor** (reduce lanes, per-batch 2-D axis 1): blocks are
    ``(1, tile, C)``, the grid is ``(B, R / tile)``;
  * **major** (reduce sublanes, per-batch 2-D axis 0): blocks are
    ``(1, R, tile)``, the grid is ``(B, C / tile)``.

:func:`strip_grid` builds the grid and every BlockSpec a kernel in that
layout needs (full-tensor strips, the reduced O(kept) line, and per-line
stat outputs), so the kernel modules declare *what* they stream, not how it
tiles.

VMEM fitting is batch-aware in the sense that matters: the batch dim rides
on the *grid* (one batch slice per instance), so the per-instance working
set depends only on the reduction extent — a vocab-width reduction line
(50k+) at the default block would blow VMEM on TPU regardless of B. Never
seen in interpret mode, so the bound lives here rather than in CI.
"""
from __future__ import annotations

from typing import Any, NamedTuple, Tuple

import jax.numpy as jnp
from jax.experimental import pallas as pl

# Per-call VMEM working-set budget: conservative slice of the ~16 MiB/core,
# leaving room for double buffering.
VMEM_BUDGET = 8 << 20

# In-VMEM itemsize the fit math charges per element. The strip kernels cast
# every operand to f32 on load and do all arithmetic in f32 (bf16/f16 inputs
# included — the stored dtype only matters at the final cast-back), so 4 bytes
# is the *actual* working-set cost per element, not a guess: gating bf16
# strips on their 2-byte storage width would under-count VMEM by 2x. Callers
# that ever keep a genuinely wider compute copy must pass ``itemsize``
# explicitly; `repro.analysis.kernelcheck` verifies the f32-compute contract
# (and the resulting footprint bound) statically for every registered kernel.
COMPUTE_ITEMSIZE = 4


def fit_strip_block(red_size: int, block: int, kept_size: int, n_bufs: int,
                    *, itemsize: int = COMPUTE_ITEMSIZE) -> int:
    """Shrink a strip tile so ``n_bufs`` (tile, red_size) compute buffers of
    ``itemsize`` bytes/element fit in :data:`VMEM_BUDGET`. Callers must gate
    on :func:`strip_fits` first — when a single reduction line already
    exceeds the budget (full-reduction K on a big tensor), no tile count can
    enforce it."""
    cap = max(1, VMEM_BUDGET // (red_size * itemsize * n_bufs))
    return max(1, min(block, cap, kept_size))


def strip_fits(red_size: int, n_bufs: int, *, itemsize: int = COMPUTE_ITEMSIZE) -> bool:
    """Whether a single reduction line's working set (``n_bufs`` compute
    copies at ``itemsize`` bytes/element — f32 by default, see
    :data:`COMPUTE_ITEMSIZE`) fits the budget. When it doesn't, the strip
    kernels can't serve the tensor on a real TPU (interpret mode wouldn't
    notice) — dispatchers fall back to jnp. Independent of the batch extent:
    batch rides on the grid, not in VMEM."""
    return red_size * itemsize * n_bufs <= VMEM_BUDGET


class StripGrid(NamedTuple):
    """Grid + BlockSpecs for one batched strip kernel launch over (B, R, C).

    ``axis`` is the per-batch 2-D reduction axis (1 = minor/lanes,
    0 = major/sublanes); ``red_axis`` is the same axis inside a 3-D block
    (2 or 1), which is what kernel bodies reduce over.
    """

    grid: Tuple[int, int]   # (B, kept / tile)
    axis: int               # per-batch 2-D reduction axis: 1 | 0
    red_axis: int           # reduction axis of a (1, ., .) block: 2 | 1
    kept_axis: int          # grid-tiled kept axis of the (B, R, C) view: 1 | 2
    n_red: int              # reduction extent (held whole per instance)
    kept: int               # kept extent per batch (must divide by tile)
    tile: int               # strip width along the kept axis
    full: Any               # BlockSpec for full (B, R, C) operands
    line: Any               # BlockSpec for the reduced O(kept) operand
    stat: Any               # BlockSpec for (B, kept) per-line stat outputs


def strip_grid(b: int, r: int, c: int, *, axis: int, n_bufs: int, block: int,
               itemsize: int = COMPUTE_ITEMSIZE) -> StripGrid:
    """Plan the grid and BlockSpecs for a (B, R, C) strip kernel.

    ``axis=1`` reduces the trailing axis (minor): grid over row strips, each
    instance holds a (1, tile, C) block. ``axis=0`` reduces the middle axis
    (major): grid over column strips, each instance holds a (1, R, tile)
    block. ``n_bufs`` is the caller's live full-size compute buffer count per
    instance (``itemsize`` bytes/element, f32 by default — see
    :data:`COMPUTE_ITEMSIZE`); the tile shrinks until they fit
    :data:`VMEM_BUDGET`. The kept extent must already be a multiple of the
    returned tile — callers pad first (see the kernel modules'
    pad-and-recurse entries).
    """
    assert axis in (0, 1)
    if axis == 1:
        n_red, kept = c, r
        tile = fit_strip_block(n_red, block, kept, n_bufs, itemsize=itemsize)
        full = pl.BlockSpec((1, tile, c), lambda bi, i: (bi, i, 0))
        line = pl.BlockSpec((1, tile, 1), lambda bi, i: (bi, i, 0))
        red_axis, kept_axis = 2, 1
    else:
        n_red, kept = r, c
        tile = fit_strip_block(n_red, block, kept, n_bufs, itemsize=itemsize)
        full = pl.BlockSpec((1, r, tile), lambda bi, j: (bi, 0, j))
        line = pl.BlockSpec((1, 1, tile), lambda bi, j: (bi, 0, j))
        red_axis, kept_axis = 1, 2
    stat = pl.BlockSpec((1, tile), lambda bi, i: (bi, i))
    return StripGrid(grid=(b, kept // tile), axis=axis, red_axis=red_axis,
                     kept_axis=kept_axis, n_red=n_red, kept=kept, tile=tile,
                     full=full, line=line, stat=stat)


def pad_kept(x: jnp.ndarray, sg: StripGrid) -> jnp.ndarray:
    """Pad ``x``'s kept axis up to the plan's tile multiple (the reduction
    axis is never padded, so padded lines cannot contaminate real ones;
    callers slice the padding back off with :func:`trim_kept`)."""
    cfg = [(0, 0)] * x.ndim
    cfg[sg.kept_axis] = (0, -(-sg.kept // sg.tile) * sg.tile - sg.kept)
    return jnp.pad(x, cfg)


def trim_kept(x: jnp.ndarray, sg: StripGrid) -> jnp.ndarray:
    """Inverse of :func:`pad_kept` on a kernel output."""
    idx = [slice(None)] * x.ndim
    idx[sg.kept_axis] = slice(sg.kept)
    return x[tuple(idx)]
