"""Shared tiling policy for kernels whose blocks span a full reduction axis.

Full-row strips are the right layout for minor-axis reductions
(slim_update / slim_precond / snr_stats*) and full-column strips for the
major-axis (sublane-reduction) twins, but a vocab-width reduction extent
(50k+) at the default block would blow VMEM on TPU — never seen in interpret
mode, so the bound lives here rather than in CI.
"""
from __future__ import annotations

# Per-call VMEM working-set budget: conservative slice of the ~16 MiB/core,
# leaving room for double buffering.
VMEM_BUDGET = 8 << 20


def fit_row_block(n_cols: int, row_block: int, n_rows: int, n_full_width_bufs: int) -> int:
    """Shrink a row-strip tile so ``n_full_width_bufs`` fp32 (tr, n_cols)
    buffers fit in :data:`VMEM_BUDGET`. Callers must gate on
    :func:`row_fits` first — when a single row already exceeds the budget
    (full-reduction K on a large tensor), no row count can enforce it."""
    cap = max(1, VMEM_BUDGET // (n_cols * 4 * n_full_width_bufs))
    return max(1, min(row_block, cap, n_rows))


def row_fits(n_cols: int, n_full_width_bufs: int) -> bool:
    """Whether even a single (1, n_cols) strip's working set fits the budget.
    When it doesn't, the row-strip kernels can't serve the tensor on a real
    TPU (interpret mode wouldn't notice) — dispatchers fall back to jnp."""
    return n_cols * 4 * n_full_width_bufs <= VMEM_BUDGET


def fit_col_block(n_rows: int, col_block: int, n_cols: int, n_full_height_bufs: int) -> int:
    """:func:`fit_row_block` twin for the major-axis kernels: shrink a
    column-strip tile so ``n_full_height_bufs`` fp32 (n_rows, tc) buffers fit
    in :data:`VMEM_BUDGET`. Callers must gate on :func:`col_fits` first —
    when a single column already exceeds the budget, no column count can
    enforce it."""
    cap = max(1, VMEM_BUDGET // (n_rows * 4 * n_full_height_bufs))
    return max(1, min(col_block, cap, n_cols))


def col_fits(n_rows: int, n_full_height_bufs: int) -> bool:
    """Whether a single (n_rows, 1) strip's working set fits the budget —
    the major-axis analogue of :func:`row_fits`."""
    return n_rows * 4 * n_full_height_bufs <= VMEM_BUDGET
