"""Fused SlimAdam update (compressed second moment) — Pallas TPU kernels.

The paper's memory saving becomes a *bandwidth* saving here: the second
moment is reduced over the compression dims K, so one optimizer step streams
p, g, m (read) + p', m' (write) + O(kept) for V — 5 tensor passes vs dense
Adam's 7, and the squared gradient / E_K[g^2] reduction never touches HBM.

Two orientations, so either reduction layout runs without a boundary
transpose (a pallas_call is an optimization barrier — XLA can't fuse a
re-layout into the kernel, so a transpose would materialize extra HBM
passes):

  * minor (``slim_update`` / ``slim_precond``): V is (R, 1); grid over row
    strips, each instance holds a full (TR, C) strip in VMEM (fan_in up to
    22k fits at TR<=32 in fp32) and reduces along lanes;
  * major (``slim_update_major`` / ``slim_precond_major``): V is (1, C);
    grid over column strips, each instance holds a full (R, TC) strip and
    reduces along sublanes — the transpose-free path for leaves whose
    reduced dims are *leading* (fan_out of a standard weight, conv fan_in).

Both compute the strip's E_K[g^2] on the VPU, update the reduced moment,
and apply the preconditioned update in the same pass.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .fused_adam import bias_corrections
from .tiling import fit_col_block, fit_row_block


def _slim_kernel(p_ref, g_ref, m_ref, v_ref, scal_ref,
                 p_out, m_out, v_out, *, b1: float, b2: float, eps: float,
                 wd: float, n_cols: int):
    lr = scal_ref[0]
    bc1 = scal_ref[1]
    bc2 = scal_ref[2]
    g = g_ref[...].astype(jnp.float32)                   # (TR, C)
    m_new = b1 * m_ref[...] + (1.0 - b1) * g
    ek = jnp.sum(g * g, axis=1, keepdims=True) * (1.0 / n_cols)
    v_new = b2 * v_ref[...] + (1.0 - b2) * ek            # (TR, 1)
    update = (m_new / bc1) / (jnp.sqrt(v_new / bc2) + eps)
    if wd:
        update = update + wd * p_ref[...].astype(jnp.float32)
    p_out[...] = (p_ref[...].astype(jnp.float32) - lr * update).astype(p_out.dtype)
    m_out[...] = m_new
    v_out[...] = v_new


def slim_update(p, g, m, v_row, *, lr: float, b1: float = 0.9, b2: float = 0.95,
                eps: float = 1e-8, wd: float = 0.0, count: int = 1,
                row_block: int = 32, interpret: bool = True):
    """p, g, m: (R, C); v_row: (R, 1) fp32 reduced moment. Returns (p', m', v')."""
    r, c = p.shape
    # 6 full-width fp32 buffers live per instance (p, g, m in + p', m' out,
    # plus cast headroom); shrink the strip for wide reduced dims.
    tr = fit_row_block(c, row_block, r, 6)
    if r % tr:
        rp = -(-r // tr) * tr
        pad2 = lambda x: jnp.pad(x, ((0, rp - r), (0, 0)))
        po, mo, vo = slim_update(pad2(p), pad2(g), pad2(m), pad2(v_row), lr=lr, b1=b1,
                                 b2=b2, eps=eps, wd=wd, count=count,
                                 row_block=row_block, interpret=interpret)
        return po[:r], mo[:r], vo[:r]

    bc1 = 1.0 - b1 ** count
    bc2 = 1.0 - b2 ** count
    scal = jnp.array([lr, bc1, bc2], jnp.float32)

    strip = pl.BlockSpec((tr, c), lambda i: (i, 0))
    vspec = pl.BlockSpec((tr, 1), lambda i: (i, 0))
    kernel = functools.partial(_slim_kernel, b1=b1, b2=b2, eps=eps, wd=wd, n_cols=c)
    return pl.pallas_call(
        kernel,
        grid=(r // tr,),
        in_specs=[strip, strip, strip, vspec, pl.BlockSpec((3,), lambda i: (0,))],
        out_specs=[pl.BlockSpec((tr, c), lambda i: (i, 0)),
                   pl.BlockSpec((tr, c), lambda i: (i, 0)), vspec],
        out_shape=[
            jax.ShapeDtypeStruct((r, c), p.dtype),
            jax.ShapeDtypeStruct((r, c), jnp.float32),
            jax.ShapeDtypeStruct((r, 1), jnp.float32),
        ],
        interpret=interpret,
    )(p, g, m, v_row, scal)


def _slim_precond_kernel(g_ref, m_ref, v_ref, scal_ref, u_out, m_out, v_out,
                         *, b1: float, b2: float, eps: float, n_cols: int):
    bc1 = scal_ref[0]
    bc2 = scal_ref[1]
    g = g_ref[...].astype(jnp.float32)                   # (TR, C)
    m_new = b1 * m_ref[...] + (1.0 - b1) * g
    ek = jnp.sum(g * g, axis=1, keepdims=True) * (1.0 / n_cols)
    v_new = b2 * v_ref[...] + (1.0 - b2) * ek            # (TR, 1)
    u_out[...] = (m_new / bc1) / (jnp.sqrt(v_new / bc2) + eps)
    m_out[...] = m_new
    v_out[...] = v_new


def slim_precond(g, m, v_row, *, b1: float = 0.9, b2: float = 0.95,
                 eps: float = 1e-8, count=1, row_block: int = 32,
                 interpret: bool = True):
    """Preconditioned SlimAdam update only: (g, m, v_row) -> (u, m', v_row').

    g, m: (R, C); v_row: (R, 1) fp32 reduced moment; u is fp32 full-shape.
    Like :func:`repro.kernels.fused_adam.adam_precond` this is the
    GradientTransformation form — no parameter read/write, and ``count`` may
    be traced. Streams 4 full passes (g, m read + u, m' write) plus O(R).
    """
    r, c = g.shape
    # 5 full-width fp32 buffers per instance (g, m in + u, m' out + cast
    # headroom); shrink the strip for wide reduced dims.
    tr = fit_row_block(c, row_block, r, 5)
    if r % tr:
        rp = -(-r // tr) * tr
        pad2 = lambda x: jnp.pad(x, ((0, rp - r), (0, 0)))
        uo, mo, vo = slim_precond(pad2(g), pad2(m), pad2(v_row), b1=b1, b2=b2,
                                  eps=eps, count=count, row_block=row_block,
                                  interpret=interpret)
        return uo[:r], mo[:r], vo[:r]

    scal = bias_corrections(b1, b2, count)
    strip = pl.BlockSpec((tr, c), lambda i: (i, 0))
    vspec = pl.BlockSpec((tr, 1), lambda i: (i, 0))
    kernel = functools.partial(_slim_precond_kernel, b1=b1, b2=b2, eps=eps, n_cols=c)
    return pl.pallas_call(
        kernel,
        grid=(r // tr,),
        in_specs=[strip, strip, vspec, pl.BlockSpec((2,), lambda i: (0,))],
        out_specs=[pl.BlockSpec((tr, c), lambda i: (i, 0)),
                   pl.BlockSpec((tr, c), lambda i: (i, 0)), vspec],
        out_shape=[
            jax.ShapeDtypeStruct((r, c), jnp.float32),
            jax.ShapeDtypeStruct((r, c), jnp.float32),
            jax.ShapeDtypeStruct((r, 1), jnp.float32),
        ],
        interpret=interpret,
    )(g, m, v_row, scal)


# ---------------------------------------------------------------------------
# Major-axis (sublane-reduction) variants: V reduced over the *leading* dim.
# ---------------------------------------------------------------------------


def _slim_major_kernel(p_ref, g_ref, m_ref, v_ref, scal_ref,
                       p_out, m_out, v_out, *, b1: float, b2: float, eps: float,
                       wd: float, n_rows: int):
    lr = scal_ref[0]
    bc1 = scal_ref[1]
    bc2 = scal_ref[2]
    g = g_ref[...].astype(jnp.float32)                   # (R, TC)
    m_new = b1 * m_ref[...] + (1.0 - b1) * g
    ek = jnp.sum(g * g, axis=0, keepdims=True) * (1.0 / n_rows)
    v_new = b2 * v_ref[...] + (1.0 - b2) * ek            # (1, TC)
    update = (m_new / bc1) / (jnp.sqrt(v_new / bc2) + eps)
    if wd:
        update = update + wd * p_ref[...].astype(jnp.float32)
    p_out[...] = (p_ref[...].astype(jnp.float32) - lr * update).astype(p_out.dtype)
    m_out[...] = m_new
    v_out[...] = v_new


def slim_update_major(p, g, m, v_col, *, lr: float, b1: float = 0.9, b2: float = 0.95,
                      eps: float = 1e-8, wd: float = 0.0, count: int = 1,
                      col_block: int = 256, interpret: bool = True):
    """p, g, m: (R, C); v_col: (1, C) fp32 moment reduced over rows.
    Returns (p', m', v'). Mirrors :func:`slim_update` with the grid over
    column strips and the reduction over sublanes — transpose-free for
    leading reduced dims."""
    r, c = p.shape
    # 6 full-height fp32 buffers live per instance (p, g, m in + p', m' out,
    # plus cast headroom); shrink the strip for tall reduced dims.
    tc = fit_col_block(r, col_block, c, 6)
    if c % tc:
        cp = -(-c // tc) * tc
        pad2 = lambda x: jnp.pad(x, ((0, 0), (0, cp - c)))
        po, mo, vo = slim_update_major(pad2(p), pad2(g), pad2(m), pad2(v_col),
                                       lr=lr, b1=b1, b2=b2, eps=eps, wd=wd,
                                       count=count, col_block=col_block,
                                       interpret=interpret)
        return po[:, :c], mo[:, :c], vo[:, :c]

    bc1 = 1.0 - b1 ** count
    bc2 = 1.0 - b2 ** count
    scal = jnp.array([lr, bc1, bc2], jnp.float32)

    strip = pl.BlockSpec((r, tc), lambda j: (0, j))
    vspec = pl.BlockSpec((1, tc), lambda j: (0, j))
    kernel = functools.partial(_slim_major_kernel, b1=b1, b2=b2, eps=eps, wd=wd,
                               n_rows=r)
    return pl.pallas_call(
        kernel,
        grid=(c // tc,),
        in_specs=[strip, strip, strip, vspec, pl.BlockSpec((3,), lambda j: (0,))],
        out_specs=[strip, strip, vspec],
        out_shape=[
            jax.ShapeDtypeStruct((r, c), p.dtype),
            jax.ShapeDtypeStruct((r, c), jnp.float32),
            jax.ShapeDtypeStruct((1, c), jnp.float32),
        ],
        interpret=interpret,
    )(p, g, m, v_col, scal)


def _slim_precond_major_kernel(g_ref, m_ref, v_ref, scal_ref, u_out, m_out, v_out,
                               *, b1: float, b2: float, eps: float, n_rows: int):
    bc1 = scal_ref[0]
    bc2 = scal_ref[1]
    g = g_ref[...].astype(jnp.float32)                   # (R, TC)
    m_new = b1 * m_ref[...] + (1.0 - b1) * g
    ek = jnp.sum(g * g, axis=0, keepdims=True) * (1.0 / n_rows)
    v_new = b2 * v_ref[...] + (1.0 - b2) * ek            # (1, TC)
    u_out[...] = (m_new / bc1) / (jnp.sqrt(v_new / bc2) + eps)
    m_out[...] = m_new
    v_out[...] = v_new


def slim_precond_major(g, m, v_col, *, b1: float = 0.9, b2: float = 0.95,
                       eps: float = 1e-8, count=1, col_block: int = 256,
                       interpret: bool = True):
    """Preconditioned major-axis SlimAdam update: (g, m, v_col) -> (u, m', v').

    g, m: (R, C); v_col: (1, C) fp32 moment reduced over rows; u is fp32
    full-shape. The GradientTransformation form of :func:`slim_update_major`
    — no parameter read/write, traced ``count`` fine. Streams 4 full passes
    (g, m read + u, m' write) plus O(C)."""
    r, c = g.shape
    # 5 full-height fp32 buffers per instance (g, m in + u, m' out + cast
    # headroom); shrink the strip for tall reduced dims.
    tc = fit_col_block(r, col_block, c, 5)
    if c % tc:
        cp = -(-c // tc) * tc
        pad2 = lambda x: jnp.pad(x, ((0, 0), (0, cp - c)))
        uo, mo, vo = slim_precond_major(pad2(g), pad2(m), pad2(v_col), b1=b1,
                                        b2=b2, eps=eps, count=count,
                                        col_block=col_block, interpret=interpret)
        return uo[:, :c], mo[:, :c], vo[:, :c]

    scal = bias_corrections(b1, b2, count)
    strip = pl.BlockSpec((r, tc), lambda j: (0, j))
    vspec = pl.BlockSpec((1, tc), lambda j: (0, j))
    kernel = functools.partial(_slim_precond_major_kernel, b1=b1, b2=b2, eps=eps,
                               n_rows=r)
    return pl.pallas_call(
        kernel,
        grid=(c // tc,),
        in_specs=[strip, strip, vspec, pl.BlockSpec((2,), lambda j: (0,))],
        out_specs=[strip, strip, vspec],
        out_shape=[
            jax.ShapeDtypeStruct((r, c), jnp.float32),
            jax.ShapeDtypeStruct((r, c), jnp.float32),
            jax.ShapeDtypeStruct((1, c), jnp.float32),
        ],
        interpret=interpret,
    )(g, m, v_col, scal)
