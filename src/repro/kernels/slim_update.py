"""Fused SlimAdam update (compressed second moment) — Pallas TPU kernels.

The paper's memory saving becomes a *bandwidth* saving here: the second
moment is reduced over the compression dims K, so one optimizer step streams
p, g, m (read) + p', m' (write) + O(kept) for V — 5 tensor passes vs dense
Adam's 7, and the squared gradient / E_K[g^2] reduction never touches HBM.

All kernels operate on the batched canonical form ``(B, R, C)`` planned by
``repro.kernels.ops.canon_nd`` (B = 1 for plain 2-D leaves; B = layers for
scan-stacked leaves whose reduction sits between kept axes), in one of two
orientations so every reshape-reachable reduction layout runs without a
boundary transpose (a pallas_call is an optimization barrier — XLA can't
fuse a re-layout into the kernel, so a transpose would materialize extra
HBM passes):

  * minor (``axis=1``): V is (B, R, 1); grid over (batch, row strips), each
    instance holds a full (1, TR, C) strip in VMEM and reduces along lanes;
  * major (``axis=0``): V is (B, 1, C); grid over (batch, column strips),
    each instance holds a full (1, R, TC) strip and reduces along sublanes
    — the transpose-free path for leading *or* batch-interleaved reduced
    dims (fan_out, conv fan_in, scan-stacked fan_in).

Both orientations share one kernel body per form (update / precond),
parameterized by the in-block reduction axis, and one grid/BlockSpec
builder (``repro.kernels.tiling.strip_grid``). Each instance computes the
strip's E_K[g^2] on the VPU, updates the reduced moment, and applies the
preconditioned update in the same pass. The 2-D entry points
(``slim_update`` / ``slim_update_major`` / ``slim_precond`` /
``slim_precond_major``) are B=1 wrappers kept for callers that speak 2-D.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .fused_adam import bias_corrections, health_terms
from .snr_stats import centered_line_stats
from .tiling import pad_kept, strip_grid, trim_kept

# Live full-size fp32 buffers per kernel instance (inputs + outputs + cast
# headroom) — the n_bufs VMEM-fitting argument for each form. Dispatchers
# gate un-servable leaves with ``tiling.strip_fits(red_size, *_BUFS)``.
UPDATE_BUFS = 6    # p, g, m in + p', m' out + cast headroom
PRECOND_BUFS = 5   # g, m in + u, m' out + cast headroom
PRECOND_SNR_BUFS = 6   # + the shifted g^2 copy for the centered SNR sums
PARTIAL_BUFS = 5   # g, m in + m' out + g^2 / shifted-copy headroom (with_snr)
FINALIZE_BUFS = 3  # m' in + u out + cast headroom (v/ek lines are O(kept))

_DEFAULT_BLOCK = {1: 32, 0: 256}  # kept-axis strip width per orientation


def _slim_kernel(p_ref, g_ref, m_ref, v_ref, scal_ref, p_out, m_out, v_out,
                 *, b1: float, b2: float, eps: float, wd: float,
                 red_axis: int, n_red: int):
    lr = scal_ref[0]
    bc1 = scal_ref[1]
    bc2 = scal_ref[2]
    g = g_ref[...].astype(jnp.float32)                   # (1, TR, C) | (1, R, TC)
    m_new = b1 * m_ref[...] + (1.0 - b1) * g
    ek = jnp.sum(g * g, axis=red_axis, keepdims=True) * (1.0 / n_red)
    v_new = b2 * v_ref[...] + (1.0 - b2) * ek            # reduced line
    update = (m_new / bc1) / (jnp.sqrt(v_new / bc2) + eps)
    if wd:
        update = update + wd * p_ref[...].astype(jnp.float32)
    p_out[...] = (p_ref[...].astype(jnp.float32) - lr * update).astype(p_out.dtype)
    m_out[...] = m_new
    v_out[...] = v_new


def slim_update_batched(p, g, m, v_line, *, axis: int, lr: float, b1: float = 0.9,
                        b2: float = 0.95, eps: float = 1e-8, wd: float = 0.0,
                        count=1, block: Optional[int] = None,
                        interpret: bool = True):
    """Batched SlimAdam step on the (B, R, C) canonical form.

    p, g, m: (B, R, C); v_line: (B, R, 1) fp32 (axis=1, reduce over C) or
    (B, 1, C) fp32 (axis=0, reduce over R). Returns (p', m', v'). ``count``
    may be a traced int array (the corrections ride in via the scalar
    operand — see :func:`repro.kernels.fused_adam.bias_corrections`, the one
    definition of the bias-correction semantics for every kernel entry).
    """
    assert p.ndim == 3 and axis in (0, 1)
    b, r, c = p.shape
    block = _DEFAULT_BLOCK[axis] if block is None else block
    sg = strip_grid(b, r, c, axis=axis, n_bufs=UPDATE_BUFS, block=block)
    if sg.kept % sg.tile:
        po, mo, vo = slim_update_batched(pad_kept(p, sg), pad_kept(g, sg),
                                         pad_kept(m, sg), pad_kept(v_line, sg),
                                         axis=axis, lr=lr, b1=b1, b2=b2, eps=eps,
                                         wd=wd, count=count, block=block,
                                         interpret=interpret)
        return trim_kept(po, sg), trim_kept(mo, sg), trim_kept(vo, sg)

    scal = jnp.concatenate([jnp.full((1,), lr, jnp.float32),
                            bias_corrections(b1, b2, count)])
    kernel = functools.partial(_slim_kernel, b1=b1, b2=b2, eps=eps, wd=wd,
                               red_axis=sg.red_axis, n_red=sg.n_red)
    v_shape = (b, r, 1) if axis == 1 else (b, 1, c)
    return pl.pallas_call(
        kernel,
        grid=sg.grid,
        in_specs=[sg.full, sg.full, sg.full, sg.line,
                  pl.BlockSpec((3,), lambda bi, i: (0,))],
        out_specs=[sg.full, sg.full, sg.line],
        out_shape=[
            jax.ShapeDtypeStruct((b, r, c), p.dtype),
            jax.ShapeDtypeStruct((b, r, c), jnp.float32),
            jax.ShapeDtypeStruct(v_shape, jnp.float32),
        ],
        interpret=interpret,
    )(p, g, m, v_line, scal)


def _accumulate_health(h_ref, g):
    """Fold one strip's health terms into the shared (2,) accumulator.

    Every grid instance maps to the same output block; the TPU grid is
    sequential, so zeroing on the first instance then adding per-strip
    contributions is race-free (and interpret mode preserves the order).
    """
    @pl.when((pl.program_id(0) == 0) & (pl.program_id(1) == 0))
    def _zero():
        h_ref[...] = jnp.zeros((2,), jnp.float32)

    h_ref[...] = h_ref[...] + health_terms(g)


def _slim_precond_kernel(g_ref, m_ref, v_ref, scal_ref, u_out, m_out, v_out,
                         *extra_outs, b1: float, b2: float, eps: float,
                         red_axis: int, n_red: int, with_snr: bool = False,
                         with_health: bool = False):
    bc1 = scal_ref[0]
    bc2 = scal_ref[1]
    g = g_ref[...].astype(jnp.float32)                   # (1, TR, C) | (1, R, TC)
    m_new = b1 * m_ref[...] + (1.0 - b1) * g
    g2 = g * g
    ek = jnp.sum(g2, axis=red_axis, keepdims=True) * (1.0 / n_red)
    v_new = b2 * v_ref[...] + (1.0 - b2) * ek            # reduced line
    u_out[...] = (m_new / bc1) / (jnp.sqrt(v_new / bc2) + eps)
    m_out[...] = m_new
    v_out[...] = v_new
    if with_snr:
        s1c, s2c, _ = centered_line_stats(g2, red_axis)
        extra_outs[0][...] = s1c
        extra_outs[1][...] = s2c
    if with_health:
        _accumulate_health(extra_outs[-1], g)


def slim_precond_batched(g, m, v_line, *, axis: int, b1: float = 0.9,
                         b2: float = 0.95, eps: float = 1e-8, count=1,
                         with_snr: bool = False, with_health: bool = False,
                         block: Optional[int] = None, interpret: bool = True):
    """Preconditioned batched SlimAdam update: (g, m, v_line) -> (u, m', v').

    The GradientTransformation form of :func:`slim_update_batched` — no
    parameter read/write, lr / weight decay applied downstream, traced
    ``count`` fine. Streams 4 full passes (g, m read + u, m' write) plus
    O(B * kept) for the reduced moment.

    ``with_snr=True`` appends ``(s1c, s2c)`` — shift-centered sums of g^2
    per reduction line (reduced-line layout), computed in the same strip
    loop — so a from-update SNR measurement (see
    ``repro.kernels.snr_stats.snr_update_stats_finalize``) costs O(kept)
    extra writes and zero extra full-size passes.

    ``with_health=True`` appends one ``(2,)`` fp32 accumulator
    ``[nonfinite_count, finite_sumsq]`` of ``g`` (always the *last* output),
    folded in by the same strip loop — the anomaly guard's per-leaf stats
    cost O(1) output bytes and zero extra tensor passes.
    """
    assert g.ndim == 3 and axis in (0, 1)
    b, r, c = g.shape
    block = _DEFAULT_BLOCK[axis] if block is None else block
    n_bufs = PRECOND_SNR_BUFS if with_snr else PRECOND_BUFS
    sg = strip_grid(b, r, c, axis=axis, n_bufs=n_bufs, block=block)
    if sg.kept % sg.tile:
        outs = slim_precond_batched(pad_kept(g, sg), pad_kept(m, sg),
                                    pad_kept(v_line, sg), axis=axis,
                                    b1=b1, b2=b2, eps=eps, count=count,
                                    with_snr=with_snr, with_health=with_health,
                                    block=block, interpret=interpret)
        # zero padding is finite and contributes 0 to both health terms, so
        # the trailing (2,) accumulator passes through untrimmed
        n_t = 3 + (2 if with_snr else 0)
        return tuple(trim_kept(o, sg) for o in outs[:n_t]) + tuple(outs[n_t:])

    scal = bias_corrections(b1, b2, count)
    kernel = functools.partial(_slim_precond_kernel, b1=b1, b2=b2, eps=eps,
                               red_axis=sg.red_axis, n_red=sg.n_red,
                               with_snr=with_snr, with_health=with_health)
    v_shape = (b, r, 1) if axis == 1 else (b, 1, c)
    n_snr = 2 if with_snr else 0
    out_specs = [sg.full, sg.full, sg.line] + [sg.line] * n_snr
    out_shape = [
        jax.ShapeDtypeStruct((b, r, c), jnp.float32),
        jax.ShapeDtypeStruct((b, r, c), jnp.float32),
        jax.ShapeDtypeStruct(v_shape, jnp.float32),
    ] + [jax.ShapeDtypeStruct(v_shape, jnp.float32)] * n_snr
    if with_health:
        out_specs = out_specs + [pl.BlockSpec((2,), lambda bi, i: (0,))]
        out_shape = out_shape + [jax.ShapeDtypeStruct((2,), jnp.float32)]
    return pl.pallas_call(
        kernel,
        grid=sg.grid,
        in_specs=[sg.full, sg.full, sg.line,
                  pl.BlockSpec((2,), lambda bi, i: (0,))],
        out_specs=out_specs,
        out_shape=out_shape,
        interpret=interpret,
    )(g, m, v_line, scal)


# ---------------------------------------------------------------------------
# Sharded psum regime: partial-stats / finalize kernel pair
# ---------------------------------------------------------------------------
#
# When a leaf's reduction dims are split across mesh shards, the E_K[g^2]
# line mean needs a cross-shard ``lax.psum`` between reading g and applying
# the preconditioner — a collective cannot run inside a pallas_call, so the
# leaf becomes two kernels around it:
#
#   pass 1  slim_partial_stats: read g, m -> write m_new + the per-line
#           partial g^2 sums (O(kept)); with ``with_snr`` the same strip
#           loop also emits shift-centered partial sums of g^2 (the
#           from-update SNR diagnostic, see
#           ``repro.kernels.snr_stats.snr_update_stats_finalize``) — three
#           more O(kept) lines, zero extra full-size traffic;
#   (psum)  the collective completes the line sums — and, for owner-sharded
#           reduced moments, simultaneously broadcasts v_new: each shard
#           folds ``b2 * v`` for the lines it owns into the payload, so the
#           moment's broadcast rides the all-reduce instead of adding ICI;
#   pass 2  slim_finalize: read m_new (+ the completed line mean / moment)
#           -> write u (+ v_new when the kernel owns the moment update).
#
# Full-size traffic stays at the psum regime's 5-pass floor (g, m read;
# m' write; m' read; u write); everything else is O(kept).


def _slim_partial_kernel(g_ref, m_ref, m_out, part_out, *extra_outs, b1: float,
                         red_axis: int, with_snr: bool = False,
                         with_health: bool = False):
    g = g_ref[...].astype(jnp.float32)                   # (1, TR, C) | (1, R, TC)
    m_out[...] = b1 * m_ref[...] + (1.0 - b1) * g
    g2 = g * g
    part_out[...] = jnp.sum(g2, axis=red_axis, keepdims=True)
    if with_snr:
        s1c, s2c, f = centered_line_stats(g2, red_axis)
        extra_outs[0][...] = s1c
        extra_outs[1][...] = s2c
        extra_outs[2][...] = f
    if with_health:
        _accumulate_health(extra_outs[-1], g)


def slim_partial_stats_batched(g, m, *, axis: int, b1: float = 0.9,
                               with_snr: bool = False, with_health: bool = False,
                               block: Optional[int] = None,
                               interpret: bool = True):
    """Pass 1 of the sharded psum regime on the (B, R, C) canonical form.

    g, m: (B, R, C). Returns ``(m_new, part)`` — m_new fp32 full shape, part
    the per-line partial sum of g^2 in the reduced-line layout ((B, R, 1) for
    axis=1, (B, 1, C) for axis=0) ready for a ``lax.psum`` over the owning
    mesh axes. With ``with_snr=True`` also returns ``(s1c, s2c, first)``:
    shift-centered partial sums of g^2 per line (same layout), which compose
    across shards via ``repro.kernels.ref.rebase_centered_stats`` exactly
    like the snr_stats partial entries — the SNR measurement rides the
    update's strip loop for free.

    ``with_health=True`` appends one ``(2,)`` fp32 accumulator
    ``[nonfinite_count, finite_sumsq]`` of the *local* g shard (always the
    last output). Health composes across shards by summation, so psum-regime
    leaves fold it into the same all-reduce that completes the line sums —
    no extra collective, no extra pass.
    """
    assert g.ndim == 3 and axis in (0, 1)
    b, r, c = g.shape
    block = _DEFAULT_BLOCK[axis] if block is None else block
    sg = strip_grid(b, r, c, axis=axis, n_bufs=PARTIAL_BUFS, block=block)
    if sg.kept % sg.tile:
        outs = slim_partial_stats_batched(pad_kept(g, sg), pad_kept(m, sg),
                                          axis=axis, b1=b1, with_snr=with_snr,
                                          with_health=with_health,
                                          block=block, interpret=interpret)
        # the (2,) health accumulator is padding-invariant — no trim
        n_t = 2 + (3 if with_snr else 0)
        return tuple(trim_kept(o, sg) for o in outs[:n_t]) + tuple(outs[n_t:])

    kernel = functools.partial(_slim_partial_kernel, b1=b1, red_axis=sg.red_axis,
                               with_snr=with_snr, with_health=with_health)
    line_shape = (b, r, 1) if axis == 1 else (b, 1, c)
    n_lines = 1 + (3 if with_snr else 0)
    out_specs = [sg.full] + [sg.line] * n_lines
    out_shape = [jax.ShapeDtypeStruct((b, r, c), jnp.float32)] \
                + [jax.ShapeDtypeStruct(line_shape, jnp.float32)] * n_lines
    if with_health:
        out_specs = out_specs + [pl.BlockSpec((2,), lambda bi, i: (0,))]
        out_shape = out_shape + [jax.ShapeDtypeStruct((2,), jnp.float32)]
    return pl.pallas_call(
        kernel,
        grid=sg.grid,
        in_specs=[sg.full, sg.full],
        out_specs=out_specs,
        out_shape=out_shape,
        interpret=interpret,
    )(g, m)


def _slim_finalize_kernel(m_ref, v_ref, ek_ref, scal_ref, u_out, v_out,
                          *, b2: float, eps: float):
    bc1 = scal_ref[0]
    bc2 = scal_ref[1]
    v_new = b2 * v_ref[...] + (1.0 - b2) * ek_ref[...]   # reduced line
    u_out[...] = (m_ref[...] / bc1) / (jnp.sqrt(v_new / bc2) + eps)
    v_out[...] = v_new


def _slim_apply_line_kernel(m_ref, v_ref, scal_ref, u_out, *, eps: float):
    bc1 = scal_ref[0]
    bc2 = scal_ref[1]
    u_out[...] = (m_ref[...] / bc1) / (jnp.sqrt(v_ref[...] / bc2) + eps)


def slim_finalize_batched(m_new, v_line, *, axis: int, ek=None, b1: float = 0.9,
                          b2: float = 0.95, eps: float = 1e-8, count=1,
                          block: Optional[int] = None, interpret: bool = True):
    """Pass 2 of the sharded psum regime (post-psum finalize).

    m_new: (B, R, C) fp32 from :func:`slim_partial_stats_batched`. With
    ``ek`` (the psum-completed line *mean* of g^2, reduced-line layout) this
    is the ISSUE-form finalize: reads m_new + v_line (the stored reduced
    moment) and returns ``(u, v_new)``. With ``ek=None``, ``v_line`` is the
    *already-completed* new moment — the owner-sharded flow, where each
    shard's ``b2 * v`` contribution rode the partial-sums psum and the
    collective delivered v_new directly — and only ``u`` is returned (the
    moment's O(kept) store is the caller's owner-slice, not a full
    replicated kernel write).
    """
    assert m_new.ndim == 3 and axis in (0, 1)
    b, r, c = m_new.shape
    block = _DEFAULT_BLOCK[axis] if block is None else block
    sg = strip_grid(b, r, c, axis=axis, n_bufs=FINALIZE_BUFS, block=block)
    if sg.kept % sg.tile:
        pads = (pad_kept(m_new, sg), pad_kept(v_line, sg))
        if ek is not None:
            uo, vo = slim_finalize_batched(pads[0], pads[1], axis=axis,
                                           ek=pad_kept(ek, sg), b1=b1, b2=b2,
                                           eps=eps, count=count, block=block,
                                           interpret=interpret)
            return trim_kept(uo, sg), trim_kept(vo, sg)
        uo = slim_finalize_batched(pads[0], pads[1], axis=axis, ek=None, b1=b1,
                                   b2=b2, eps=eps, count=count, block=block,
                                   interpret=interpret)
        return trim_kept(uo, sg)

    scal = bias_corrections(b1, b2, count)
    line_shape = (b, r, 1) if axis == 1 else (b, 1, c)
    if ek is None:
        kernel = functools.partial(_slim_apply_line_kernel, eps=eps)
        return pl.pallas_call(
            kernel,
            grid=sg.grid,
            in_specs=[sg.full, sg.line, pl.BlockSpec((2,), lambda bi, i: (0,))],
            out_specs=[sg.full],
            out_shape=[jax.ShapeDtypeStruct((b, r, c), jnp.float32)],
            interpret=interpret,
        )(m_new, v_line, scal)[0]
    kernel = functools.partial(_slim_finalize_kernel, b2=b2, eps=eps)
    return pl.pallas_call(
        kernel,
        grid=sg.grid,
        in_specs=[sg.full, sg.line, sg.line,
                  pl.BlockSpec((2,), lambda bi, i: (0,))],
        out_specs=[sg.full, sg.line],
        out_shape=[jax.ShapeDtypeStruct((b, r, c), jnp.float32),
                   jax.ShapeDtypeStruct(line_shape, jnp.float32)],
        interpret=interpret,
    )(m_new, v_line, ek, scal)


# ---------------------------------------------------------------------------
# 2-D entry points: B=1 wrappers over the batched canonical form.
# ---------------------------------------------------------------------------


def _b1(*xs):
    return tuple(x[None] for x in xs)


def _unb1(outs):
    return tuple(o[0] for o in outs)


def slim_update(p, g, m, v_row, *, lr: float, b1: float = 0.9, b2: float = 0.95,
                eps: float = 1e-8, wd: float = 0.0, count=1,
                row_block: int = 32, interpret: bool = True):
    """p, g, m: (R, C); v_row: (R, 1) fp32 reduced moment. Returns (p', m', v')."""
    return _unb1(slim_update_batched(*_b1(p, g, m, v_row), axis=1, lr=lr, b1=b1,
                                     b2=b2, eps=eps, wd=wd, count=count,
                                     block=row_block, interpret=interpret))


def slim_update_major(p, g, m, v_col, *, lr: float, b1: float = 0.9, b2: float = 0.95,
                      eps: float = 1e-8, wd: float = 0.0, count=1,
                      col_block: int = 256, interpret: bool = True):
    """p, g, m: (R, C); v_col: (1, C) fp32 moment reduced over rows.
    Returns (p', m', v')."""
    return _unb1(slim_update_batched(*_b1(p, g, m, v_col), axis=0, lr=lr, b1=b1,
                                     b2=b2, eps=eps, wd=wd, count=count,
                                     block=col_block, interpret=interpret))


def slim_precond(g, m, v_row, *, b1: float = 0.9, b2: float = 0.95,
                 eps: float = 1e-8, count=1, row_block: int = 32,
                 interpret: bool = True):
    """Preconditioned SlimAdam update only: (g, m, v_row) -> (u, m', v_row').

    g, m: (R, C); v_row: (R, 1) fp32 reduced moment; u is fp32 full-shape.
    """
    return _unb1(slim_precond_batched(*_b1(g, m, v_row), axis=1, b1=b1, b2=b2,
                                      eps=eps, count=count, block=row_block,
                                      interpret=interpret))


def slim_precond_major(g, m, v_col, *, b1: float = 0.9, b2: float = 0.95,
                       eps: float = 1e-8, count=1, col_block: int = 256,
                       interpret: bool = True):
    """Preconditioned major-axis SlimAdam update: (g, m, v_col) -> (u, m', v').

    g, m: (R, C); v_col: (1, C) fp32 moment reduced over rows; u is fp32
    full-shape.
    """
    return _unb1(slim_precond_batched(*_b1(g, m, v_col), axis=0, b1=b1, b2=b2,
                                      eps=eps, count=count, block=col_block,
                                      interpret=interpret))


def slim_partial_stats(g, m, *, axis: int = 1, b1: float = 0.9,
                       with_snr: bool = False, block: Optional[int] = None,
                       interpret: bool = True):
    """2-D wrapper of :func:`slim_partial_stats_batched`: g, m (R, C) ->
    (m_new, part[, s1c, s2c, first]); lines are (R, 1) (axis=1) / (1, C)
    (axis=0)."""
    return _unb1(slim_partial_stats_batched(*_b1(g, m), axis=axis, b1=b1,
                                            with_snr=with_snr, block=block,
                                            interpret=interpret))


def slim_finalize(m_new, v_line, *, axis: int = 1, ek=None, b1: float = 0.9,
                  b2: float = 0.95, eps: float = 1e-8, count=1,
                  block: Optional[int] = None, interpret: bool = True):
    """2-D wrapper of :func:`slim_finalize_batched`: m_new (R, C) + lines ->
    (u, v_new) with ``ek``, or just u when ``v_line`` is already the
    completed new moment (owner-sharded flow)."""
    if ek is None:
        out = slim_finalize_batched(*_b1(m_new, v_line), axis=axis, ek=None,
                                    b1=b1, b2=b2, eps=eps, count=count,
                                    block=block, interpret=interpret)
        return out[0]
    return _unb1(slim_finalize_batched(*_b1(m_new, v_line), axis=axis,
                                       ek=ek[None], b1=b1, b2=b2, eps=eps,
                                       count=count, block=block,
                                       interpret=interpret))
