"""Whole-tree megaplan: O(groups) Pallas launches per optimizer step.

The per-leaf dispatch in ``repro.optim.fused`` pays one ``pallas_call`` per
leaf (or small-leaf bucket) — the byte roofline is at its floor, but launch
count is O(leaves) and dominates the wall clock of a whole-tree update.
SlimAdam's update is elementwise-after-canonicalization per regime, so
same-regime canonical leaves are concatenation-compatible by construction.
This module generalizes the lane-folded small-leaf bucketing into a plan
over the *entire* tree:

  * :func:`plan_megagroups` runs :func:`repro.kernels.ops.leaf_plan` per
    leaf and groups every kernel-eligible leaf by regime key —

      - ``dense``   — K = () leaves, lane-folded flat (elementwise, so any
        concatenation order is exact); one group for the whole tree;
      - ``minor``   — 2-D canonical plans reducing lanes, keyed by the
        reduction extent (lines must share geometry); concatenated along
        the kept rows;
      - ``major``   — 2-D canonical plans reducing sublanes, keyed by the
        reduction extent; concatenated along the kept columns;
      - ``batched`` — 3-D scan-stacked plans, keyed by (batch, reduction
        extent); concatenated along the kept columns.

    Concatenation always runs along the *kept* axis, so no reduction line
    ever crosses a segment boundary — each group is one bigger instance of
    exactly the per-leaf problem, and results are bit-identical to the
    per-leaf kernels (per-line math never sees the neighbors). dtype does
    not split groups: every gather casts to the f32 compute form the
    kernels would build internally anyway (the stored dtype only matters
    at the caller's cast-back).

  * Each group carries a segment table (:class:`MegaSegment` per leaf:
    leaf id, offset and extent along the concat axis, the K-line geometry
    via the group key, and the leaf's bias-correction slot). The table
    must tile the super-tensor injectively — offsets contiguous from 0,
    lengths positive, indices a partition — which
    ``repro.analysis.races`` verifies statically. Per-leaf bias
    corrections enter the kernels as O(kept) *lines* built by
    :func:`segment_lines` (slot value repeated over the segment's extent),
    so a future per-leaf step count needs no kernel change.

  * The mega kernel entries walk the shared strip grid once per group:
    :func:`mega_adam_update` (lane-folded dense 2-D),
    :func:`mega_slim_update_batched` (fused precondition),
    :func:`mega_slim_partial_stats_batched` /
    :func:`mega_slim_finalize_batched` (the sharded psum pair — the
    cross-shard ``lax.psum`` stays per-leaf between the two launches, only
    the kernel launches amortize). ``with_health`` emits per-*line*
    counts instead of the per-leaf kernels' shared (2,) accumulator — the
    caller sums each segment's lines at scatter time, so every output
    block keeps an injective index map (nothing for the race pass to vet).

  * :func:`gather_group` / :func:`scatter_group` round-trip leaves through
    the super-tensor by offset (:func:`scatter_lines` for O(kept) stat
    outputs). Zero padding (dense lane-fold tails, ragged kept strips) is
    trimmed before scatter; bias-correction lines pad with ones so padded
    lanes never divide by zero.

Leaves :func:`leaf_plan` routes to jnp (scalars, non-float dtypes,
VMEM-exceeding reduction lines) are excluded from grouping and reported in
:attr:`MegaPlan.jnp_idx` — they keep their per-leaf reference path.
"""
from __future__ import annotations

import functools
import math
from typing import Any, Dict, List, NamedTuple, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from .fused_adam import LANES
from .ops import CanonND, canon_apply, canon_restore, leaf_plan
from .slim_update import (FINALIZE_BUFS, PARTIAL_BUFS, PRECOND_BUFS,
                          PRECOND_SNR_BUFS)
from .snr_stats import centered_line_stats
from .tiling import pad_kept, strip_grid, trim_kept

# Live full-size fp32 buffers per instance (the n_bufs VMEM-fitting
# argument / the kernelcheck BUFS bracket). The slim mega kernels hold the
# same working sets as their per-leaf twins — only the grid extent grows —
# so they share the constants. The dense mega kernel streams all six Adam
# tensors (g, m, v in; u, m', v' out) plus the cast copy; its bias lines
# are O(rows).
MEGA_ADAM_BUFS = 7
MEGA_PRECOND_BUFS = PRECOND_BUFS
MEGA_PRECOND_SNR_BUFS = PRECOND_SNR_BUFS
MEGA_PARTIAL_BUFS = PARTIAL_BUFS
MEGA_FINALIZE_BUFS = FINALIZE_BUFS

_DEFAULT_BLOCK = {1: 32, 0: 256}
_ADAM_BLOCK = 256

Dims = Tuple[int, ...]


class MegaSegment(NamedTuple):
    """One leaf's slot in a group's super-tensor (the segment table row)."""

    index: int                  # leaf index in the caller's tree order
    shape: Tuple[int, ...]      # original leaf shape
    red_shape: Tuple[int, ...]  # reduced-moment shape (size-1 reduced dims)
    dims: Dims                  # reduction dims (for the jnp fallback)
    cn: Optional[CanonND]       # canonical plan (None for dense segments)
    offset: int                 # start along the group's concat axis
    length: int                 # extent along the concat axis


class MegaGroup(NamedTuple):
    """One concatenation-compatible leaf group = one kernel launch.

    ``(batch, rows, cols)`` is the super-tensor's canonical view (2-D with
    ``batch == 1``); ``axis`` the per-batch 2-D reduction axis (1 minor /
    0 major, -1 for the elementwise dense group).
    """

    kind: str                   # 'dense' | 'minor' | 'major' | 'batched'
    batch: int
    rows: int
    cols: int
    axis: int
    segments: Tuple[MegaSegment, ...]

    @property
    def concat_axis(self) -> int:
        """Kept axis the segments stack along, in the canonical view."""
        return {"dense": 0, "minor": 0, "major": 1, "batched": 2}[self.kind]

    @property
    def red(self) -> int:
        """Shared reduction extent (1 for the elementwise dense group)."""
        if self.kind == "dense":
            return 1
        return self.cols if self.axis == 1 else self.rows

    @property
    def extent(self) -> int:
        """Total kept extent — what the segment table must tile exactly."""
        return sum(s.length for s in self.segments)

    @property
    def view(self) -> Tuple[int, ...]:
        if self.kind == "batched":
            return (self.batch, self.rows, self.cols)
        return (self.rows, self.cols)


class MegaPlan(NamedTuple):
    groups: Tuple[MegaGroup, ...]
    jnp_idx: Tuple[int, ...]    # leaves excluded from grouping (jnp route)


def _slim_key(cn: CanonND) -> Tuple[str, int, int]:
    """Group key of a canonical plan: orientation + the line geometry that
    must be uniform within a launch (lines of different extents cannot
    share a strip grid)."""
    if cn.batch > 1:
        return ("batched", cn.batch, cn.rows)
    if cn.axis == 1:
        return ("minor", 1, cn.cols)
    return ("major", 1, cn.rows)


def _dense_group(items: Sequence[Tuple[int, Tuple[int, ...], Tuple[int, ...],
                                       Dims, Optional[CanonND]]]) -> MegaGroup:
    segs: List[MegaSegment] = []
    off = 0
    for i, shape, red_shape, dims, cn in items:
        length = -(-math.prod(shape) // LANES)   # lane-folded row count
        segs.append(MegaSegment(i, shape, red_shape, dims, cn, off, length))
        off += length
    return MegaGroup("dense", 1, off, LANES, -1, tuple(segs))


def _slim_group(key: Tuple[str, int, int],
                items: Sequence[Tuple[int, Tuple[int, ...], Tuple[int, ...],
                                      Dims, CanonND]]) -> MegaGroup:
    kind, batch, red = key
    segs: List[MegaSegment] = []
    off = 0
    for i, shape, red_shape, dims, cn in items:
        length = cn.rows if kind == "minor" else cn.cols
        segs.append(MegaSegment(i, shape, red_shape, dims, cn, off, length))
        off += length
    if kind == "minor":
        return MegaGroup("minor", 1, off, red, 1, tuple(segs))
    if kind == "major":
        return MegaGroup("major", 1, red, off, 0, tuple(segs))
    return MegaGroup("batched", batch, red, off, 0, tuple(segs))


def groups_from_plans(items: Sequence[Tuple[int, Tuple[int, ...], Tuple[int, ...],
                                            Dims, CanonND]]) -> Tuple[MegaGroup, ...]:
    """Group pre-planned canonical leaves ``(index, shape, red_shape, dims,
    cn)`` — the sharded psum dispatcher's entry point, whose local plans
    come from ``ShardLeafPlan.cn`` rather than :func:`leaf_plan`."""
    by_key: Dict[Tuple[str, int, int], list] = {}
    for it in items:
        by_key.setdefault(_slim_key(it[4]), []).append(it)
    return tuple(_slim_group(k, by_key[k]) for k in sorted(by_key))


@functools.lru_cache(maxsize=512)
def _plan_cached(shapes: Tuple[Tuple[int, ...], ...], dtype_names: Tuple[str, ...],
                 dims_leaves: Tuple[Dims, ...], n_bufs: int) -> MegaPlan:
    dense_items: List[tuple] = []
    slim_items: Dict[Tuple[str, int, int], list] = {}
    jnp_idx: List[int] = []
    for i, (shape, dname, dims) in enumerate(zip(shapes, dtype_names, dims_leaves)):
        plan = leaf_plan(shape, jnp.dtype(dname), dims, n_bufs=n_bufs)
        if plan.route == "jnp":
            jnp_idx.append(i)
        elif plan.route == "dense":
            dense_items.append((i, shape, shape, (), None))
        else:
            dset = {d % len(shape) for d in dims}
            red_shape = tuple(1 if j in dset else s for j, s in enumerate(shape))
            slim_items.setdefault(_slim_key(plan.cn), []).append(
                (i, shape, red_shape, dims, plan.cn))
    groups: List[MegaGroup] = []
    if dense_items:
        groups.append(_dense_group(dense_items))
    for key in sorted(slim_items):
        groups.append(_slim_group(key, slim_items[key]))
    return MegaPlan(tuple(groups), tuple(jnp_idx))


def plan_megagroups(shapes: Sequence[Tuple[int, ...]], dtypes: Sequence[Any],
                    dims_leaves: Sequence[Dims], *,
                    n_bufs: int = PRECOND_BUFS) -> MegaPlan:
    """Plan the whole-tree grouping (cached — pure function of the static
    leaf geometry). ``n_bufs`` is the consuming kernel's buffer count,
    forwarded to the per-leaf VMEM fits-gate exactly as the per-leaf
    dispatch would."""
    return _plan_cached(tuple(tuple(int(d) for d in s) for s in shapes),
                        tuple(jnp.dtype(dt).name for dt in dtypes),
                        tuple(tuple(int(d) for d in ds) for ds in dims_leaves),
                        int(n_bufs))


def segment_table(group: MegaGroup) -> np.ndarray:
    """The declarative per-row segment table of one group: ``(extent, 4)``
    int64 rows ``[leaf_index, position_within_leaf, line_extent, bc_slot]``
    — one row per kept line of the super-tensor (per lane-folded row for
    the dense group). Static metadata: the race pass checks it tiles the
    super-tensor injectively, and the CI artifact dumps it on gate
    failure; the kernels themselves consume only its reductions (offsets
    for scatter, bc slots expanded to lines by :func:`segment_lines`)."""
    line = group.cols if group.kind == "dense" else group.red
    rows = [(seg.index, p, line, slot)
            for slot, seg in enumerate(group.segments)
            for p in range(seg.length)]
    return np.asarray(rows, np.int64).reshape(-1, 4)


# ---------------------------------------------------------------------------
# Gather / scatter: leaf lists <-> super-tensors, by segment offset
# ---------------------------------------------------------------------------


def gather_group(group: MegaGroup, xs: Sequence[Any], *,
                 reduced: bool = False) -> jnp.ndarray:
    """Concatenate the group's leaves (indexed by segment) into the f32
    super-tensor: lane-folded flat for dense, canonical views stacked along
    the kept axis otherwise (``reduced=True`` gathers size-1-reduced moment
    lines into the O(kept) line operand)."""
    if group.kind == "dense":
        parts = []
        for seg in group.segments:
            flat = xs[seg.index].astype(jnp.float32).ravel()
            parts.append(jnp.pad(flat, (0, seg.length * LANES - flat.size)))
        return jnp.concatenate(parts).reshape(group.rows, LANES)
    return jnp.concatenate(
        [canon_apply(xs[seg.index].astype(jnp.float32), seg.cn, reduced_cols=reduced)
         for seg in group.segments], axis=group.concat_axis)


def scatter_group(group: MegaGroup, y: jnp.ndarray, *,
                  reduced: bool = False) -> List[jnp.ndarray]:
    """Slice a super-tensor output back into per-leaf arrays (original
    layouts), aligned with ``group.segments``."""
    out: List[jnp.ndarray] = []
    if group.kind == "dense":
        for seg in group.segments:
            rows = jax.lax.slice_in_dim(y, seg.offset, seg.offset + seg.length,
                                        axis=0)
            out.append(rows.ravel()[:math.prod(seg.shape)].reshape(seg.shape))
        return out
    for seg in group.segments:
        sl = jax.lax.slice_in_dim(y, seg.offset, seg.offset + seg.length,
                                  axis=group.concat_axis)
        out.append(canon_restore(sl, seg.cn,
                                 seg.red_shape if reduced else seg.shape))
    return out


def scatter_lines(group: MegaGroup, y: jnp.ndarray) -> List[jnp.ndarray]:
    """Slice an O(kept) line output into raw per-segment line arrays (no
    layout restore) — for per-segment stat sums (health) and per-leaf SNR
    finalization, which are layout-independent."""
    return [jax.lax.slice_in_dim(y, seg.offset, seg.offset + seg.length,
                                 axis=group.concat_axis)
            for seg in group.segments]


def segment_lines(group: MegaGroup, values: Sequence[Any]) -> jnp.ndarray:
    """Expand one per-leaf scalar slot (e.g. a bias correction) into the
    group's line operand: value repeated over each segment's kept extent,
    shaped like the reduced-moment line."""
    lens = np.asarray([seg.length for seg in group.segments])
    flat = jnp.repeat(jnp.stack([jnp.asarray(v, jnp.float32) for v in values]),
                      lens, total_repeat_length=int(lens.sum()))
    if group.kind in ("dense", "minor"):
        return flat[:, None]
    if group.kind == "major":
        return flat[None, :]
    return jnp.broadcast_to(flat[None, None, :], (group.batch, 1, flat.size))


# ---------------------------------------------------------------------------
# Mega kernels
# ---------------------------------------------------------------------------


def _line_health(g, g2, red_axis: int):
    """Per-line health terms (non-finite count, finite-masked sumsq),
    keepdims — the megakernels' injective replacement for the per-leaf
    kernels' shared (2,) accumulator; callers sum each segment's lines."""
    fin = jnp.isfinite(g)
    nf = jnp.sum(jnp.where(fin, 0.0, 1.0), axis=red_axis, keepdims=True)
    ss = jnp.sum(jnp.where(fin, g2, 0.0), axis=red_axis, keepdims=True)
    return nf, ss


def _mega_adam_kernel(g_ref, m_ref, v_ref, bc1_ref, bc2_ref, u_out, m_out,
                      v_out, *h_outs, b1, b2, eps, with_health):
    g = g_ref[...].astype(jnp.float32)
    m_new = b1 * m_ref[...] + (1 - b1) * g
    v_new = b2 * v_ref[...] + (1 - b2) * g * g
    u_out[...] = (m_new / bc1_ref[...]) / (jnp.sqrt(v_new / bc2_ref[...]) + eps)
    m_out[...] = m_new
    v_out[...] = v_new
    if with_health:
        nf, ss = _line_health(g, g * g, 1)
        h_outs[0][...] = nf
        h_outs[1][...] = ss


def mega_adam_update(g, m, v, bc1, bc2, *, b1=0.9, b2=0.999, eps=1e-8,
                     with_health: bool = False, block: int = _ADAM_BLOCK,
                     interpret: bool = True):
    """Dense Adam over a lane-folded (rows, LANES) super-tensor with per-row
    bias-correction lines ``bc1`` / ``bc2`` (rows, 1). Returns
    ``(u, m', v')`` (+ per-row ``(nf, ss)`` health lines with
    ``with_health``), all f32. Ragged row counts pad-and-recurse; the bias
    lines pad with ones so padded rows never divide by zero."""
    assert g.ndim == 2 and bc1.shape == (g.shape[0], 1)
    r, c = g.shape
    tr = min(block, r)
    if r % tr:
        rp = -(-r // tr) * tr
        padz = lambda x: jnp.pad(x, ((0, rp - r), (0, 0)))
        pad1 = lambda x: jnp.pad(x, ((0, rp - r), (0, 0)), constant_values=1.0)
        outs = mega_adam_update(padz(g), padz(m), padz(v), pad1(bc1), pad1(bc2),
                                b1=b1, b2=b2, eps=eps, with_health=with_health,
                                block=block, interpret=interpret)
        return tuple(o[:r] for o in outs)
    kernel = functools.partial(_mega_adam_kernel, b1=b1, b2=b2, eps=eps,
                               with_health=with_health)
    full = pl.BlockSpec((tr, c), lambda i: (i, 0))
    line = pl.BlockSpec((tr, 1), lambda i: (i, 0))
    n_h = 2 if with_health else 0
    return pl.pallas_call(
        kernel,
        grid=(r // tr,),
        in_specs=[full, full, full, line, line],
        out_specs=[full] * 3 + [line] * n_h,
        out_shape=([jax.ShapeDtypeStruct((r, c), jnp.float32)] * 3
                   + [jax.ShapeDtypeStruct((r, 1), jnp.float32)] * n_h),
        interpret=interpret,
    )(g, m, v, bc1, bc2)


def _mega_slim_kernel(g_ref, m_ref, v_ref, bc1_ref, bc2_ref, u_out, m_out,
                      v_out, *extra_outs, b1, b2, eps, red_axis, n_red,
                      with_snr, with_health):
    g = g_ref[...].astype(jnp.float32)
    m_new = b1 * m_ref[...] + (1 - b1) * g
    g2 = g * g
    ek = jnp.sum(g2, axis=red_axis, keepdims=True) * (1.0 / n_red)
    v_new = b2 * v_ref[...] + (1 - b2) * ek
    u_out[...] = (m_new / bc1_ref[...]) / (jnp.sqrt(v_new / bc2_ref[...]) + eps)
    m_out[...] = m_new
    v_out[...] = v_new
    k = 0
    if with_snr:
        s1c, s2c, _ = centered_line_stats(g2, red_axis)
        extra_outs[0][...] = s1c
        extra_outs[1][...] = s2c
        k = 2
    if with_health:
        nf, ss = _line_health(g, g2, red_axis)
        extra_outs[k][...] = nf
        extra_outs[k + 1][...] = ss


def _pad_kept_ones(x, sg):
    """`tiling.pad_kept` with ones — for bias-correction line operands,
    whose padded lanes must stay division-safe."""
    cfg = [(0, 0)] * x.ndim
    cfg[sg.kept_axis] = (0, -(-sg.kept // sg.tile) * sg.tile - sg.kept)
    return jnp.pad(x, cfg, constant_values=1.0)


def mega_slim_update_batched(g, m, v_line, bc1, bc2, *, axis: int, b1=0.9,
                             b2=0.95, eps=1e-8, with_snr: bool = False,
                             with_health: bool = False,
                             block: Optional[int] = None,
                             interpret: bool = True):
    """Fused SlimAdam precondition over a (B, R, C) super-tensor whose kept
    axis concatenates same-line-geometry leaves; ``bc1`` / ``bc2`` are
    per-line bias-correction operands (:func:`segment_lines`). Per line the
    math is exactly ``repro.kernels.slim_update._slim_precond_kernel`` —
    concatenation only moves kept positions, so results are bit-identical
    to the per-leaf launches. Returns ``(u, m', v_line')`` + 2 centered-g^2
    stat lines with ``with_snr`` + 2 per-line health lines with
    ``with_health``, all f32."""
    assert g.ndim == 3 and axis in (0, 1)
    b, r, c = g.shape
    block = _DEFAULT_BLOCK[axis] if block is None else block
    n_bufs = MEGA_PRECOND_SNR_BUFS if with_snr else MEGA_PRECOND_BUFS
    sg = strip_grid(b, r, c, axis=axis, n_bufs=n_bufs, block=block)
    if sg.kept % sg.tile:
        pz = lambda x: pad_kept(x, sg)
        outs = mega_slim_update_batched(
            pz(g), pz(m), pz(v_line), _pad_kept_ones(bc1, sg),
            _pad_kept_ones(bc2, sg), axis=axis, b1=b1, b2=b2, eps=eps,
            with_snr=with_snr, with_health=with_health, block=block,
            interpret=interpret)
        return tuple(trim_kept(o, sg) for o in outs)
    kernel = functools.partial(_mega_slim_kernel, b1=b1, b2=b2, eps=eps,
                               red_axis=sg.red_axis, n_red=sg.n_red,
                               with_snr=with_snr, with_health=with_health)
    n_extra = (2 if with_snr else 0) + (2 if with_health else 0)
    line_shape = (b, r, 1) if axis == 1 else (b, 1, c)
    return pl.pallas_call(
        kernel,
        grid=sg.grid,
        in_specs=[sg.full, sg.full, sg.line, sg.line, sg.line],
        out_specs=[sg.full, sg.full] + [sg.line] * (1 + n_extra),
        out_shape=([jax.ShapeDtypeStruct((b, r, c), jnp.float32)] * 2
                   + [jax.ShapeDtypeStruct(line_shape, jnp.float32)]
                   * (1 + n_extra)),
        interpret=interpret,
    )(g, m, v_line, bc1, bc2)


def mega_slim_update(g, m, v_line, bc1, bc2, *, axis: int, **kw):
    """2-D (batch-free) wrapper of :func:`mega_slim_update_batched`."""
    outs = mega_slim_update_batched(g[None], m[None], v_line[None], bc1[None],
                                    bc2[None], axis=axis, **kw)
    return tuple(o[0] for o in outs)


def _mega_slim_partial_kernel(g_ref, m_ref, m_out, part_out, *extra_outs, b1,
                              red_axis, with_snr, with_health):
    g = g_ref[...].astype(jnp.float32)
    m_out[...] = b1 * m_ref[...] + (1 - b1) * g
    g2 = g * g
    part_out[...] = jnp.sum(g2, axis=red_axis, keepdims=True)
    k = 0
    if with_snr:
        s1c, s2c, f = centered_line_stats(g2, red_axis)
        extra_outs[0][...] = s1c
        extra_outs[1][...] = s2c
        extra_outs[2][...] = f
        k = 3
    if with_health:
        nf, ss = _line_health(g, g2, red_axis)
        extra_outs[k][...] = nf
        extra_outs[k + 1][...] = ss


def mega_slim_partial_stats_batched(g, m, *, axis: int, b1=0.9,
                                    with_snr: bool = False,
                                    with_health: bool = False,
                                    block: Optional[int] = None,
                                    interpret: bool = True):
    """Pass 1 of the grouped psum pair: m' plus per-line partial g^2 sums
    (un-normalized — the caller's cross-shard ``lax.psum`` completes them
    per leaf). ``with_snr`` adds the 3 centered partial-stat lines,
    ``with_health`` the 2 per-line health lines."""
    assert g.ndim == 3 and axis in (0, 1)
    b, r, c = g.shape
    block = _DEFAULT_BLOCK[axis] if block is None else block
    sg = strip_grid(b, r, c, axis=axis, n_bufs=MEGA_PARTIAL_BUFS, block=block)
    if sg.kept % sg.tile:
        pz = lambda x: pad_kept(x, sg)
        outs = mega_slim_partial_stats_batched(
            pz(g), pz(m), axis=axis, b1=b1, with_snr=with_snr,
            with_health=with_health, block=block, interpret=interpret)
        return tuple(trim_kept(o, sg) for o in outs)
    kernel = functools.partial(_mega_slim_partial_kernel, b1=b1,
                               red_axis=sg.red_axis, with_snr=with_snr,
                               with_health=with_health)
    n_extra = (3 if with_snr else 0) + (2 if with_health else 0)
    line_shape = (b, r, 1) if axis == 1 else (b, 1, c)
    return pl.pallas_call(
        kernel,
        grid=sg.grid,
        in_specs=[sg.full, sg.full],
        out_specs=[sg.full] + [sg.line] * (1 + n_extra),
        out_shape=([jax.ShapeDtypeStruct((b, r, c), jnp.float32)]
                   + [jax.ShapeDtypeStruct(line_shape, jnp.float32)]
                   * (1 + n_extra)),
        interpret=interpret,
    )(g, m)


def _mega_finalize_ek_kernel(m_ref, v_ref, bc1_ref, bc2_ref, ek_ref, u_out,
                             v_out, *, b2, eps):
    m_new = m_ref[...].astype(jnp.float32)
    v_new = b2 * v_ref[...] + (1 - b2) * ek_ref[...]
    u_out[...] = (m_new / bc1_ref[...]) / (jnp.sqrt(v_new / bc2_ref[...]) + eps)
    v_out[...] = v_new


def _mega_finalize_owner_kernel(m_ref, v_ref, bc1_ref, bc2_ref, u_out, *, eps):
    m_new = m_ref[...].astype(jnp.float32)
    u_out[...] = (m_new / bc1_ref[...]) / (jnp.sqrt(v_ref[...] / bc2_ref[...])
                                           + eps)


def mega_slim_finalize_batched(m_new, v_line, bc1, bc2, *, axis: int, ek=None,
                               b2=0.95, eps=1e-8, block: Optional[int] = None,
                               interpret: bool = True):
    """Pass 2 of the grouped psum pair. With completed per-leaf mean lines
    ``ek`` returns ``(u, v_line')``; with ``ek=None`` (owner-write form,
    ``v_line`` already the psum-completed moment) returns ``u`` alone."""
    assert m_new.ndim == 3 and axis in (0, 1)
    b, r, c = m_new.shape
    block = _DEFAULT_BLOCK[axis] if block is None else block
    sg = strip_grid(b, r, c, axis=axis, n_bufs=MEGA_FINALIZE_BUFS, block=block)
    if sg.kept % sg.tile:
        pz = lambda x: pad_kept(x, sg)
        outs = mega_slim_finalize_batched(
            pz(m_new), pz(v_line), _pad_kept_ones(bc1, sg),
            _pad_kept_ones(bc2, sg), axis=axis,
            ek=pz(ek) if ek is not None else None, b2=b2, eps=eps,
            block=block, interpret=interpret)
        if ek is None:
            return trim_kept(outs, sg)
        return tuple(trim_kept(o, sg) for o in outs)
    line_shape = (b, r, 1) if axis == 1 else (b, 1, c)
    if ek is None:
        kernel = functools.partial(_mega_finalize_owner_kernel, eps=eps)
        return pl.pallas_call(
            kernel,
            grid=sg.grid,
            in_specs=[sg.full, sg.line, sg.line, sg.line],
            out_specs=[sg.full],
            out_shape=[jax.ShapeDtypeStruct((b, r, c), jnp.float32)],
            interpret=interpret,
        )(m_new, v_line, bc1, bc2)[0]
    kernel = functools.partial(_mega_finalize_ek_kernel, b2=b2, eps=eps)
    return pl.pallas_call(
        kernel,
        grid=sg.grid,
        in_specs=[sg.full, sg.line, sg.line, sg.line, sg.line],
        out_specs=[sg.full, sg.line],
        out_shape=[jax.ShapeDtypeStruct((b, r, c), jnp.float32),
                   jax.ShapeDtypeStruct(line_shape, jnp.float32)],
        interpret=interpret,
    )(m_new, v_line, bc1, bc2, ek)
