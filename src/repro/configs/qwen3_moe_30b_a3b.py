"""qwen3-moe-30b-a3b [moe]: 48L d_model=2048 32H (GQA kv=4, head_dim=128)
expert d_ff=768, vocab=151936, MoE 128e top-8. [hf:Qwen/Qwen3-30B-A3B]"""
import jax.numpy as jnp
from repro.models import LayerSlot, ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="qwen3_moe_30b_a3b", n_layers=48, d_model=2048,
        n_heads=32, n_kv_heads=4, head_dim=128,
        d_ff=768, vocab_size=151936,
        n_experts=128, top_k=8,
        pattern=(LayerSlot("attn", "moe"),),
        pos="rope", norm="rmsnorm", tie_embeddings=False,
    )


def reduced() -> ModelConfig:
    return ModelConfig(
        name="qwen3_moe_30b_a3b_reduced", n_layers=4, d_model=64,
        n_heads=4, n_kv_heads=2, head_dim=16, d_ff=48, vocab_size=211,
        n_experts=8, top_k=2, pattern=(LayerSlot("attn", "moe"),),
        pos="rope", norm="rmsnorm", tie_embeddings=False,
        dtype=jnp.float32, remat=False,
    )
