"""olmoe-1b-7b [moe]: 16L d_model=2048 16H (kv=16) expert d_ff=1024
vocab=50304, MoE 64e top-8. [arXiv:2409.02060]"""
import jax.numpy as jnp
from repro.models import LayerSlot, ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="olmoe_1b_7b", n_layers=16, d_model=2048,
        n_heads=16, n_kv_heads=16,
        d_ff=1024, vocab_size=50304,
        n_experts=64, top_k=8,
        pattern=(LayerSlot("attn", "moe"),),
        pos="rope", norm="rmsnorm", tie_embeddings=False,
    )


def reduced() -> ModelConfig:
    return ModelConfig(
        name="olmoe_1b_7b_reduced", n_layers=2, d_model=64,
        n_heads=4, n_kv_heads=4, d_ff=48, vocab_size=211,
        n_experts=8, top_k=2, pattern=(LayerSlot("attn", "moe"),),
        pos="rope", norm="rmsnorm", tie_embeddings=False,
        dtype=jnp.float32, remat=False,
    )
