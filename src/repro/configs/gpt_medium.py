"""GPT-medium (paper App. B.1): 24L 16H d_model=1024."""
import jax.numpy as jnp
from repro.models import LayerSlot, ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="gpt_medium", n_layers=24, d_model=1024,
        n_heads=16, n_kv_heads=16, d_ff=4096, vocab_size=50304,
        gated_mlp=False, pattern=(LayerSlot("attn", "dense"),),
        pos="learned", max_position=1024, norm="layernorm",
        tie_embeddings=True, init_scheme="mitchell",
    )


def reduced() -> ModelConfig:
    return ModelConfig(
        name="gpt_medium_reduced", n_layers=4, d_model=128,
        n_heads=4, n_kv_heads=4, d_ff=512, vocab_size=211,
        gated_mlp=False, pattern=(LayerSlot("attn", "dense"),),
        pos="learned", max_position=256, norm="layernorm",
        tie_embeddings=True, init_scheme="mitchell",
        dtype=jnp.float32, remat=False,
    )
