"""deepseek-67b [dense]: 95L d_model=8192 64H (GQA kv=8) d_ff=22016
vocab=102400, llama-arch. [arXiv:2401.02954]"""
import jax.numpy as jnp
from repro.models import LayerSlot, ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="deepseek_67b", n_layers=95, d_model=8192,
        n_heads=64, n_kv_heads=8, head_dim=128,
        d_ff=22016, vocab_size=102400,
        pattern=(LayerSlot("attn", "dense"),),
        pos="rope", norm="rmsnorm", tie_embeddings=False,
    )


def reduced() -> ModelConfig:
    return ModelConfig(
        name="deepseek_67b_reduced", n_layers=3, d_model=64,
        n_heads=4, n_kv_heads=2, head_dim=16, d_ff=128, vocab_size=211,
        pattern=(LayerSlot("attn", "dense"),),
        pos="rope", norm="rmsnorm", tie_embeddings=False,
        dtype=jnp.float32, remat=False,
    )
