"""falcon-mamba-7b [ssm]: 64L d_model=4096, attention-free Mamba-1,
vocab=65024, ssm_state=16. [arXiv:2410.05355]"""
import jax.numpy as jnp
from repro.models import LayerSlot, ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="falcon_mamba_7b", n_layers=64, d_model=4096,
        n_heads=1, n_kv_heads=1,  # attention-free
        d_ff=0, vocab_size=65024,
        pattern=(LayerSlot("mamba", None),),
        pos="none", norm="rmsnorm", tie_embeddings=True,
        ssm_state=16, ssm_expand=2, ssm_conv=4, ssm_chunk=512,
    )


def reduced() -> ModelConfig:
    return ModelConfig(
        name="falcon_mamba_7b_reduced", n_layers=4, d_model=64,
        n_heads=1, n_kv_heads=1, d_ff=0, vocab_size=211,
        pattern=(LayerSlot("mamba", None),),
        pos="none", norm="rmsnorm", tie_embeddings=True,
        ssm_state=4, ssm_expand=2, ssm_conv=4, ssm_chunk=8,
        dtype=jnp.float32, remat=False,
    )
