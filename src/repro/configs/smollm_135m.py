"""smollm-135m [dense]: 30L d_model=576 9H (GQA kv=3) d_ff=1536
vocab=49152, llama-arch small, tied. [hf:HuggingFaceTB/SmolLM-135M]"""
import jax.numpy as jnp
from repro.models import LayerSlot, ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="smollm_135m", n_layers=30, d_model=576,
        n_heads=9, n_kv_heads=3, head_dim=64,
        d_ff=1536, vocab_size=49152,
        pattern=(LayerSlot("attn", "dense"),),
        pos="rope", norm="rmsnorm", tie_embeddings=True,
    )


def reduced() -> ModelConfig:
    return ModelConfig(
        name="smollm_135m_reduced", n_layers=3, d_model=48,
        n_heads=3, n_kv_heads=1, head_dim=16, d_ff=128, vocab_size=211,
        pattern=(LayerSlot("attn", "dense"),),
        pos="rope", norm="rmsnorm", tie_embeddings=True,
        dtype=jnp.float32, remat=False,
    )


def optimized() -> ModelConfig:
    """Perf-pass variant (EXPERIMENTS.md §Perf iter A1): a 135M model cannot
    use a 16-way TP axis (9 heads don't divide it; attention would replicate
    16x) — repurpose 'model' as extra data parallelism: pure 256-way DP."""
    import dataclasses
    return dataclasses.replace(config(), sharding_overrides=(
        ("batch", ("pod", "data", "model")), ("vocab", None), ("mlp", None),
        ("heads", None), ("kv_heads", None), ("act_mlp", None),
        ("act_heads", None), ("seq_sp", None), ("embed", None), ("d_inner", None),
    ))
