"""internvl2-26b [vlm]: InternLM2-20B language backbone — 48L
d_model=6144 48H (GQA kv=8) d_ff=16384 vocab=92553. The InternViT vision
tower is a STUB: input_specs() provides 256 precomputed patch embeddings
prepended to the text sequence. [arXiv:2404.16821]"""
import jax.numpy as jnp
from repro.models import LayerSlot, ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="internvl2_26b", n_layers=48, d_model=6144,
        n_heads=48, n_kv_heads=8, head_dim=128,
        d_ff=16384, vocab_size=92553,
        extra_embed_len=256,
        pattern=(LayerSlot("attn", "dense"),),
        pos="rope", norm="rmsnorm", tie_embeddings=False,
    )


def reduced() -> ModelConfig:
    return ModelConfig(
        name="internvl2_26b_reduced", n_layers=2, d_model=64,
        n_heads=4, n_kv_heads=2, head_dim=16, d_ff=128, vocab_size=211,
        extra_embed_len=4, pattern=(LayerSlot("attn", "dense"),),
        pos="rope", norm="rmsnorm", tie_embeddings=False,
        dtype=jnp.float32, remat=False,
    )
