"""GPT-small (paper App. B.1): 12L 12H d_model=768, MLP x4, learned
positions, weight tying, no biases, LayerNorm, GELU. The paper's primary
SNR-analysis model."""
import jax.numpy as jnp
from repro.models import LayerSlot, ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="gpt_small", n_layers=12, d_model=768,
        n_heads=12, n_kv_heads=12, d_ff=3072, vocab_size=50304,
        gated_mlp=False, pattern=(LayerSlot("attn", "dense"),),
        pos="learned", max_position=1024, norm="layernorm",
        tie_embeddings=True, init_scheme="mitchell",
    )


def reduced() -> ModelConfig:
    return ModelConfig(
        name="gpt_small_reduced", n_layers=3, d_model=96,
        n_heads=3, n_kv_heads=3, d_ff=384, vocab_size=211,
        gated_mlp=False, pattern=(LayerSlot("attn", "dense"),),
        pos="learned", max_position=256, norm="layernorm",
        tie_embeddings=True, init_scheme="mitchell",
        dtype=jnp.float32, remat=False,
    )
