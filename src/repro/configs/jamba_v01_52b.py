"""jamba-v0.1-52b [hybrid]: 32L d_model=4096 32H (GQA kv=8) d_ff=14336
vocab=65536, MoE 16e top-2, Mamba:attn 7:1 interleave (attn at in-period
index 4), MoE FFN on odd layers. No positional encoding (Mamba provides
position). [arXiv:2403.19887]"""
import jax.numpy as jnp
from repro.models import LayerSlot, ModelConfig

_PATTERN = tuple(
    LayerSlot("attn" if i == 4 else "mamba", "moe" if i % 2 == 1 else "dense")
    for i in range(8)
)


def config() -> ModelConfig:
    return ModelConfig(
        name="jamba_v01_52b", n_layers=32, d_model=4096,
        n_heads=32, n_kv_heads=8, head_dim=128,
        d_ff=14336, vocab_size=65536,
        n_experts=16, top_k=2,
        pattern=_PATTERN,
        pos="none", norm="rmsnorm", tie_embeddings=False,
        ssm_state=16, ssm_expand=2, ssm_conv=4, ssm_chunk=512,
    )


def reduced() -> ModelConfig:
    pat = tuple(
        LayerSlot("attn" if i == 1 else "mamba", "moe" if i % 2 == 1 else "dense")
        for i in range(4)
    )
    return ModelConfig(
        name="jamba_v01_52b_reduced", n_layers=4, d_model=64,
        n_heads=4, n_kv_heads=2, head_dim=16, d_ff=96, vocab_size=211,
        n_experts=4, top_k=2, pattern=pat,
        pos="none", norm="rmsnorm", tie_embeddings=False,
        ssm_state=4, ssm_chunk=8, dtype=jnp.float32, remat=False,
    )
