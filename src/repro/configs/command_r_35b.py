"""command-r-35b [dense]: 40L d_model=8192 64H (GQA kv=8) d_ff=22528
vocab=256000, LayerNorm (no bias), tied embeddings.
[hf:CohereForAI/c4ai-command-r-v01]"""
import jax.numpy as jnp
from repro.models import LayerSlot, ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="command_r_35b", n_layers=40, d_model=8192,
        n_heads=64, n_kv_heads=8, head_dim=128,
        d_ff=22528, vocab_size=256000,
        pattern=(LayerSlot("attn", "dense"),),
        pos="rope", norm="layernorm", tie_embeddings=True,
    )


def reduced() -> ModelConfig:
    return ModelConfig(
        name="command_r_35b_reduced", n_layers=2, d_model=64,
        n_heads=4, n_kv_heads=2, head_dim=16, d_ff=128, vocab_size=211,
        pattern=(LayerSlot("attn", "dense"),),
        pos="rope", norm="layernorm", tie_embeddings=True,
        dtype=jnp.float32, remat=False,
    )
