"""hubert-xlarge [audio]: 48L d_model=1280 16H d_ff=5120 vocab=504,
encoder-only (non-causal), GELU MLP, LayerNorm. The conv waveform
frontend is a STUB — input_specs() provides precomputed frame embeddings
per the assignment. [arXiv:2106.07447]"""
import jax.numpy as jnp
from repro.models import LayerSlot, ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="hubert_xlarge", n_layers=48, d_model=1280,
        n_heads=16, n_kv_heads=16, head_dim=80,
        d_ff=5120, vocab_size=504,
        causal=False, embed_inputs=False, tie_embeddings=False,
        gated_mlp=False,
        pattern=(LayerSlot("attn", "dense"),),
        pos="none", norm="layernorm",
    )


def reduced() -> ModelConfig:
    return ModelConfig(
        name="hubert_xlarge_reduced", n_layers=2, d_model=64,
        n_heads=4, n_kv_heads=4, head_dim=16, d_ff=128, vocab_size=59,
        causal=False, embed_inputs=False, tie_embeddings=False,
        gated_mlp=False, pattern=(LayerSlot("attn", "dense"),),
        pos="none", norm="layernorm", dtype=jnp.float32, remat=False,
    )
