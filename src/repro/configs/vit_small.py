"""ViT-small (paper App. B.4): 12L 12H d_model=768, GPT-like trunk for
image classification, patch size 2 on CIFAR (patch dim = 2*2*3)."""
import jax.numpy as jnp
from repro.models import LayerSlot, ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="vit_small", n_layers=12, d_model=768,
        n_heads=12, n_kv_heads=12, d_ff=3072, vocab_size=100,
        causal=False, embed_inputs=False, tie_embeddings=False,
        input_proj_dim=12, gated_mlp=False,
        pattern=(LayerSlot("attn", "dense"),),
        pos="learned", max_position=257, norm="layernorm",
    )


def reduced() -> ModelConfig:
    return ModelConfig(
        name="vit_small_reduced", n_layers=2, d_model=64,
        n_heads=4, n_kv_heads=4, d_ff=128, vocab_size=10,
        causal=False, embed_inputs=False, tie_embeddings=False,
        input_proj_dim=12, gated_mlp=False,
        pattern=(LayerSlot("attn", "dense"),),
        pos="learned", max_position=257, norm="layernorm",
        dtype=jnp.float32, remat=False,
    )
