"""Architecture registry: 10 assigned archs + the paper's own models.

Each ``<arch>.py`` exposes ``config()`` (full-scale, dry-run only) and
``reduced()`` (CPU-smoke scale, same family). ``input_specs(cfg, shape)``
builds ShapeDtypeStruct stand-ins per shape cell.
"""
from __future__ import annotations

import dataclasses
import importlib
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

ARCH_IDS = (
    "falcon_mamba_7b",
    "jamba_v01_52b",
    "qwen3_moe_30b_a3b",
    "olmoe_1b_7b",
    "command_r_35b",
    "deepseek_67b",
    "smollm_135m",
    "qwen15_32b",
    "hubert_xlarge",
    "internvl2_26b",
    # paper's own models
    "gpt_small",
    "gpt_medium",
    "vit_small",
)

# ---------------------------------------------------------------------------
# Shape cells (assignment): name -> (seq_len, global_batch, kind)
# ---------------------------------------------------------------------------

SHAPES: Dict[str, Tuple[int, int, str]] = {
    "train_4k": (4096, 256, "train"),
    "prefill_32k": (32768, 32, "prefill"),
    "decode_32k": (32768, 128, "decode"),
    "long_500k": (524288, 1, "decode"),
}

# Families for skip rules
SSM_OR_HYBRID = {"falcon_mamba_7b", "jamba_v01_52b"}
ENCODER_ONLY = {"hubert_xlarge", "vit_small"}


def cell_supported(arch: str, shape: str) -> Tuple[bool, str]:
    """(runnable, reason-if-skipped) per the assignment's skip rules."""
    kind = SHAPES[shape][2]
    if arch in ENCODER_ONLY and kind == "decode":
        return False, "encoder-only: no decode step"
    if shape == "long_500k" and arch not in SSM_OR_HYBRID:
        return False, "full-attention arch: 500k decode needs sub-quadratic attention"
    return True, ""


def get_config(arch: str, **overrides):
    mod = importlib.import_module(f"repro.configs.{arch}")
    cfg = mod.config()
    if overrides:
        cfg = dataclasses.replace(cfg, **overrides)
    return cfg


def get_reduced(arch: str):
    mod = importlib.import_module(f"repro.configs.{arch}")
    return mod.reduced()


def input_specs(cfg, shape: str, *, dtype=jnp.int32) -> Dict[str, Any]:
    """ShapeDtypeStruct stand-ins for the train/prefill step of one cell."""
    seq, gb, kind = SHAPES[shape]
    if kind == "decode":
        raise ValueError("decode cells use decode_input_specs")
    batch: Dict[str, Any] = {}
    if cfg.embed_inputs:
        batch["tokens"] = jax.ShapeDtypeStruct((gb, seq), jnp.int32)
        batch["labels"] = jax.ShapeDtypeStruct((gb, seq), jnp.int32)
        if cfg.extra_embed_len:
            batch["frontend_embeds"] = jax.ShapeDtypeStruct(
                (gb, cfg.extra_embed_len, cfg.d_model), jnp.bfloat16)
    elif cfg.input_proj_dim:
        batch["patches"] = jax.ShapeDtypeStruct((gb, seq, cfg.input_proj_dim), jnp.bfloat16)
        batch["labels"] = jax.ShapeDtypeStruct((gb, seq), jnp.int32)
    else:
        batch["frontend_embeds"] = jax.ShapeDtypeStruct((gb, seq, cfg.d_model), jnp.bfloat16)
        batch["labels"] = jax.ShapeDtypeStruct((gb, seq), jnp.int32)
    return batch


def decode_input_specs(cfg, shape: str) -> Dict[str, Any]:
    """Stand-ins for one decode step: new tokens + a seq_len KV/SSM cache."""
    seq, gb, kind = SHAPES[shape]
    assert kind == "decode"
    from ..models.transformer import abstract_decode_cache

    return {
        "tokens": jax.ShapeDtypeStruct((gb, 1), jnp.int32),
        "cache": abstract_decode_cache(cfg, gb, seq),
    }
