"""qwen1.5-32b [dense]: 64L d_model=5120 40H (kv=40) d_ff=27392
vocab=152064, QKV bias. [hf:Qwen/Qwen1.5-32B]"""
import jax.numpy as jnp
from repro.models import LayerSlot, ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="qwen15_32b", n_layers=64, d_model=5120,
        n_heads=40, n_kv_heads=40, head_dim=128,
        d_ff=27392, vocab_size=152064,
        qkv_bias=True,
        pattern=(LayerSlot("attn", "dense"),),
        pos="rope", norm="rmsnorm", tie_embeddings=False,
    )


def reduced() -> ModelConfig:
    return ModelConfig(
        name="qwen15_32b_reduced", n_layers=2, d_model=64,
        n_heads=4, n_kv_heads=4, head_dim=16, d_ff=128, vocab_size=211,
        qkv_bias=True, pattern=(LayerSlot("attn", "dense"),),
        pos="rope", norm="rmsnorm", tie_embeddings=False,
        dtype=jnp.float32, remat=False,
    )


def optimized() -> ModelConfig:
    """Perf/capacity variant: int8 KV cache. The bf16 decode_32k cache of
    this 64-layer MHA model (kv=40) is 5.5 TB — over a single pod's HBM;
    int8 halves it (EXPERIMENTS.md §Dry-run)."""
    import dataclasses
    return dataclasses.replace(config(), kv_quant=True)
