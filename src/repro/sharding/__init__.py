from . import shardspec
from .logical import (
    ShardingContext,
    constrain,
    current,
    default_rules,
    param_specs,
    shardings_for_tree,
    use_sharding,
)
from .state_shardings import opt_state_specs, shardings_from_specs

__all__ = [
    "shardspec",
    "ShardingContext",
    "constrain",
    "current",
    "default_rules",
    "param_specs",
    "shardings_for_tree",
    "use_sharding",
    "opt_state_specs",
    "shardings_from_specs",
]
