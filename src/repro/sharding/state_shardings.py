"""PartitionSpecs for optimizer state pytrees.

Optimizer states mirror the parameter tree (mu/nu/trace/accumulators), so
their shardings derive from the parameter specs:

  * full-shape moments (mu, trace, acc) inherit the parameter spec verbatim;
  * SlimAdam's reduced second moments (size-1 along compressed dims) inherit
    the spec with collapsed dims replicated — which means a fan_in-compressed
    moment of a TP-sharded matrix keeps only its FSDP axis: compressing the
    moment also deletes its TP collective traffic (DESIGN.md §3);
  * counts/scalars are fully replicated.

The walker dispatches on the optimizer state *types* (all NamedTuples from
repro.optim / repro.core), falling back to shape-matching for robustness.
"""
from __future__ import annotations

from typing import Any

import jax
from jax.sharding import PartitionSpec as P

from ..core.baselines import AdafactorState, LionState, SM3State
from ..core.slim_adam import ScaleBySlimAdamState
from ..optim.adam import ScaleByAdamState
from ..optim.base import ChainState, MultiStepsState, ScaleByScheduleState, TraceState
from .logical import current


def _like_params(spec_tree: Any) -> Any:
    return spec_tree


def _check_mirrors(state_tree: Any, params_abstract: Any, what: str) -> None:
    """Optimizer states derive their specs by walking the param tree in
    lock-step; a structure mismatch (state built from a different param
    tree, a stale checkpoint layout, a hand-rolled state) would otherwise
    surface as a cryptic tree_map arity error deep inside jax. Raise the
    diagnosis instead."""
    s_def = jax.tree_util.tree_structure(state_tree)
    p_def = jax.tree_util.tree_structure(params_abstract)
    if s_def != p_def:
        hint = ("the spec tree must be derived from the same parameter tree "
                "(e.g. via repro.sharding.logical.param_specs)"
                if what == "param_spec_tree" else
                "the optimizer state must come from tx.init on the same "
                "parameter tree the specs were derived for")
        raise ValueError(
            f"opt_state_specs: {what} does not mirror the parameter tree "
            f"({s_def} vs params {p_def}) — {hint}.")


def _masked_like_params(spec_tree: Any, abstract_tree: Any, params_abstract: Any,
                        owner_mesh: Any = None) -> Any:
    """Param specs with entries dropped where the state dim collapsed to 1.

    With ``owner_mesh`` (the fused sharded backend's mesh), a reduced moment
    whose reduction dims are split across mesh shards ('psum' regime) gets
    the plan's *owner* storage spec instead: the fused update stores v as a
    1/A owner slice per shard and re-broadcasts it by riding the
    partial-sums all-reduce (``repro.sharding.shardspec.owner_placement``).
    Pinning the launcher-visible state to the same layout keeps the dedupe
    real end to end — the masked (psum-group-replicated) spec would force an
    O(kept) gather on every step's pjit output boundary, silently un-doing
    the owner-write saving."""

    def leaf(spec: P, state_leaf, param_leaf):
        entries = list(spec) + [None] * (param_leaf.ndim - len(spec))
        dims = tuple(i for i in range(param_leaf.ndim)
                     if state_leaf.shape[i] != param_leaf.shape[i])
        out = [None if i in dims else entries[i] for i in range(param_leaf.ndim)]
        base = P(*out)
        if owner_mesh is None or not dims:
            return base
        from ..kernels.slim_update import PRECOND_BUFS
        from .shardspec import plan_sharded_leaf

        pl = plan_sharded_leaf(param_leaf.shape, param_leaf.dtype, dims, spec,
                               owner_mesh, n_bufs=PRECOND_BUFS)
        if pl.regime == "psum" and pl.owner:
            return pl.nu_spec
        return base

    return jax.tree.map(leaf, spec_tree, abstract_tree, params_abstract)


def _replicated(tree: Any) -> Any:
    return jax.tree.map(lambda _: P(), tree)


def opt_state_specs(abstract_state: Any, params_abstract: Any, param_spec_tree: Any,
                    *, owner_mesh: Any = None) -> Any:
    """PartitionSpec pytree matching ``abstract_state``.

    ``owner_mesh``: pass the mesh when the optimizer runs the *fused sharded
    backend* — SlimAdam's psum-regime reduced moments then get their
    owner-slice storage specs (see :func:`_masked_like_params`) so the pjit
    state boundary matches the shard_map layout instead of gathering the
    owner slices back to psum-group-replicated every step. Leave ``None``
    for the jnp backend, which partitions natively under pjit and expects
    the masked specs.

    Raises ``ValueError`` (not a cryptic tree_map arity failure) when a
    state subtree that must mirror the parameter tree does not — e.g. the
    state was initialized from different params than the specs describe."""
    # None is the standard pjit 'replicated' idiom — count such entries as
    # spec leaves, not empty subtrees, when comparing structures.
    _check_mirrors(jax.tree.map(lambda _: 0, param_spec_tree,
                                is_leaf=lambda x: x is None or isinstance(x, P)),
                   jax.tree.map(lambda _: 0, params_abstract),
                   "param_spec_tree")

    def walk(node: Any) -> Any:
        if isinstance(node, ChainState):
            return ChainState(tuple(walk(s) for s in node.inner_states))
        if isinstance(node, ScaleBySlimAdamState):
            if node.mu is not None:
                _check_mirrors(node.mu, params_abstract, "ScaleBySlimAdamState.mu")
            _check_mirrors(node.nu, params_abstract, "ScaleBySlimAdamState.nu")
            return ScaleBySlimAdamState(
                count=P(),
                mu=_like_params(param_spec_tree) if node.mu is not None else None,
                nu=_masked_like_params(param_spec_tree, node.nu, params_abstract,
                                       owner_mesh),
                # from-update SNR scalars (emit_snr states only): replicated
                snr=_replicated(node.snr) if node.snr is not None else None,
                # StepHealth scalars (emit_health states only): replicated
                health=_replicated(node.health) if node.health is not None else None,
            )
        if isinstance(node, ScaleByAdamState):
            _check_mirrors(node.mu, params_abstract, "ScaleByAdamState.mu")
            _check_mirrors(node.nu, params_abstract, "ScaleByAdamState.nu")
            return ScaleByAdamState(
                count=P(), mu=_like_params(param_spec_tree),
                nu=_like_params(param_spec_tree),
                health=_replicated(node.health) if node.health is not None else None,
            )
        if isinstance(node, TraceState):
            _check_mirrors(node.trace, params_abstract, "TraceState.trace")
            return TraceState(trace=_like_params(param_spec_tree))
        if isinstance(node, MultiStepsState):
            _check_mirrors(node.acc_grads, params_abstract, "MultiStepsState.acc_grads")
            return MultiStepsState(
                mini_step=P(), inner_state=walk(node.inner_state), acc_grads=_like_params(param_spec_tree)
            )
        if isinstance(node, AdafactorState):
            _check_mirrors(node.vr, params_abstract, "AdafactorState.vr")
            _check_mirrors(node.vc, params_abstract, "AdafactorState.vc")
            return AdafactorState(
                count=P(),
                vr=_masked_like_params_partial(param_spec_tree, node.vr, params_abstract),
                vc=_masked_like_params_partial(param_spec_tree, node.vc, params_abstract),
                mu=_like_params(param_spec_tree) if node.mu is not None else None,
            )
        if isinstance(node, SM3State):
            return SM3State(
                accs=jax.tree.map(lambda _: P(), node.accs),
                mom=_like_params(param_spec_tree),
            )
        if isinstance(node, LionState):
            _check_mirrors(node.mu, params_abstract, "LionState.mu")
            return LionState(mu=_like_params(param_spec_tree))
        if isinstance(node, ScaleByScheduleState):
            return ScaleByScheduleState(count=P())
        # EmptyState / ClipState / unknown leaves -> replicate
        return _replicated(node)

    return walk(abstract_state)


def _masked_like_params_partial(spec_tree: Any, abstract_tree: Any, params_abstract: Any) -> Any:
    """Adafactor row/col stats: fewer dims than the param — keep the spec
    entries of the surviving leading dims."""

    def leaf(spec: P, state_leaf, param_leaf):
        entries = list(spec) + [None] * (param_leaf.ndim - len(spec))
        if state_leaf.ndim == param_leaf.ndim:
            return P(*entries)
        if state_leaf.ndim == 0:
            return P()
        # row stats: drop last dim; col stats: drop second-to-last dim
        if state_leaf.shape == param_leaf.shape[:-1]:
            return P(*entries[:-1])
        if state_leaf.shape == param_leaf.shape[:-2] + param_leaf.shape[-1:]:
            return P(*(entries[:-2] + entries[-1:]))
        return P()

    return jax.tree.map(leaf, spec_tree, abstract_tree, params_abstract)


def shardings_from_specs(spec_tree: Any, mesh=None) -> Any:
    from jax.sharding import NamedSharding

    ctx = current()
    mesh = mesh or (ctx.mesh if ctx else None)
    if mesh is None:
        raise RuntimeError("no mesh available")
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                        is_leaf=lambda x: isinstance(x, P))
