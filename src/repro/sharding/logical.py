"""Logical-axis sharding: one rule table maps model axis names -> mesh axes.

Models annotate parameters (via ParamSpec.axes) and activations (via
:func:`constrain`) with *logical* names ('embed', 'mlp', 'heads', 'batch',
'seq_kv', ...). A :class:`ShardingContext` installed around tracing resolves
them to PartitionSpecs for the active mesh. Outside a context every
constraint is a no-op, so models run unmodified on a single CPU device
(smoke tests) and fully sharded under the production mesh (dry-run/train).

Divisibility guard: a logical axis whose dim size does not divide the mapped
mesh-axis size silently falls back to replication for that dim (e.g.
kv_heads=8 over a 16-way 'model' axis). This is what makes one rule table
serve all 10 assigned architectures.
"""
from __future__ import annotations

import contextlib
import threading
from typing import Any, Dict, Mapping, Optional, Sequence, Tuple, Union

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

MeshAxes = Union[None, str, Tuple[str, ...]]

# jax.shard_map graduated from jax.experimental in 0.4.38; import from
# whichever home this jax has so call sites stay version-agnostic.
try:
    _shard_map_impl = jax.shard_map
except AttributeError:  # jax < 0.4.38
    from jax.experimental.shard_map import shard_map as _shard_map_impl


def shard_map(f, *, mesh, in_specs, out_specs, check_rep: bool = True):
    """Version-agnostic shard_map: the stabilized ``jax.shard_map`` renamed
    ``check_rep`` to ``check_vma``; translate so call sites (the sharded
    fused optimizer / SNR paths pass ``check_rep=False``) work on both."""
    try:
        return _shard_map_impl(f, mesh=mesh, in_specs=in_specs,
                               out_specs=out_specs, check_rep=check_rep)
    except TypeError:
        return _shard_map_impl(f, mesh=mesh, in_specs=in_specs,
                               out_specs=out_specs, check_vma=check_rep)

_ctx = threading.local()


def default_rules(mesh: Mesh) -> Dict[str, MeshAxes]:
    """The production rule table (FSDP x TP x EP (+ pod DP))."""
    has_pod = "pod" in mesh.axis_names
    batch: MeshAxes = ("pod", "data") if has_pod else ("data",)
    return {
        # activations
        "batch": batch,
        "seq": None,
        "seq_sp": "model",    # Megatron-style sequence parallelism between TP
                              # regions: residual-stream activations shard S
                              # over 'model', turning TP all-reduces into
                              # reduce-scatter + all-gather and cutting saved
                              # carries by the TP degree.
        "seq_kv": "model",    # long-context KV caches: sequence-parallel (SP)
        "act_embed": None,
        "act_mlp": "model",
        "act_heads": "model",
        # parameters
        "embed": "data",      # FSDP axis
        "vocab": "model",
        "mlp": "model",
        "heads": "model",
        "kv_heads": "model",
        "head_dim": None,
        "experts": "model",   # EP
        "layers": None,
        "d_inner": "model",   # mamba inner channels
        "state": None,
        "conv_w": None,
        "dt_rank": None,
        "frame": None,
        "patch": None,
        "pos": None,
    }


class ShardingContext:
    def __init__(self, mesh: Mesh, rules: Optional[Mapping[str, MeshAxes]] = None):
        self.mesh = mesh
        self.rules = dict(default_rules(mesh))
        if rules:
            self.rules.update(rules)

    def _axis_size(self, mesh_axes: MeshAxes) -> int:
        if mesh_axes is None:
            return 1
        if isinstance(mesh_axes, str):
            mesh_axes = (mesh_axes,)
        return int(np.prod([self.mesh.shape[a] for a in mesh_axes]))

    def spec_for(self, logical_axes: Sequence[Optional[str]], shape: Optional[Sequence[int]] = None,
                 *, allow_pad: bool = False) -> P:
        """PartitionSpec for logical axes, with divisibility fallback.

        ``allow_pad``: permit uneven (padded) sharding — legal only for
        intermediate values via with_sharding_constraint; pjit argument
        shardings must divide exactly."""
        entries = []
        used: set = set()
        for i, name in enumerate(logical_axes):
            mesh_axes = self.rules.get(name) if name else None
            if mesh_axes is None:
                entries.append(None)
                continue
            axes_t = (mesh_axes,) if isinstance(mesh_axes, str) else tuple(mesh_axes)
            # a mesh axis may appear at most once in a PartitionSpec
            axes_t = tuple(a for a in axes_t if a not in used and a in self.mesh.axis_names)
            if not axes_t:
                entries.append(None)
                continue
            size = int(np.prod([self.mesh.shape[a] for a in axes_t]))
            if shape is not None and shape[i] % size != 0:
                # GSPMD supports uneven sharding via padding: worthwhile when
                # the dim exceeds the mesh axis (e.g. 40 heads over 16 chips
                # pads to 48 — 1.2x waste vs 16x for full replication), not
                # when it's smaller (e.g. 8 kv heads over 16 chips).
                if allow_pad and shape[i] >= size:
                    used.update(axes_t)
                    entries.append(axes_t if len(axes_t) > 1 else axes_t[0])
                else:
                    entries.append(None)
                continue
            used.update(axes_t)
            entries.append(axes_t if len(axes_t) > 1 else axes_t[0])
        # trim trailing Nones (cosmetic)
        return P(*entries)

    def sharding_for(self, logical_axes: Sequence[Optional[str]],
                     shape: Optional[Sequence[int]] = None) -> NamedSharding:
        return NamedSharding(self.mesh, self.spec_for(logical_axes, shape))


def current() -> Optional[ShardingContext]:
    return getattr(_ctx, "ctx", None)


@contextlib.contextmanager
def use_sharding(ctx: Optional[ShardingContext]):
    prev = getattr(_ctx, "ctx", None)
    _ctx.ctx = ctx
    try:
        yield ctx
    finally:
        _ctx.ctx = prev


def constrain(x: jax.Array, *logical_axes: Optional[str]) -> jax.Array:
    """with_sharding_constraint by logical axis names; no-op without context."""
    ctx = current()
    if ctx is None:
        return x
    if len(logical_axes) != x.ndim:
        raise ValueError(f"constrain: {len(logical_axes)} axes for ndim {x.ndim}")
    spec = ctx.spec_for(logical_axes, x.shape, allow_pad=True)
    return jax.lax.with_sharding_constraint(x, NamedSharding(ctx.mesh, spec))


def param_specs(meta_tree: Any, params_or_abstract: Any) -> Any:
    """PartitionSpec pytree for a parameter tree from its ParamMeta tree."""
    ctx = current()

    def leaf(m, p):
        if ctx is None:
            return P()
        return ctx.spec_for(m.axes, p.shape)

    return jax.tree.map(leaf, meta_tree, params_or_abstract)


def shardings_for_tree(meta_tree: Any, params_or_abstract: Any) -> Any:
    ctx = current()
    if ctx is None:
        raise RuntimeError("shardings_for_tree requires an active ShardingContext")

    def leaf(m, p):
        return NamedSharding(ctx.mesh, ctx.spec_for(m.axes, p.shape))

    return jax.tree.map(leaf, meta_tree, params_or_abstract)
