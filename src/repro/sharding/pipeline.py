"""GPipe-style pipeline parallelism over a 'pipe' mesh axis.

The production 512-chip mesh covers its memory budget with FSDP x TP x SP
(EXPERIMENTS.md §Dry-run), so PP is not part of the 40-cell matrix; this
module provides the stage wrapper for deeper-than-memory models or meshes
with a dedicated 'pipe' axis (e.g. (pipe=4, data=8, model=16) at 512 chips).

Schedule: synchronous GPipe. M microbatches flow through P stages in
M + P - 1 ticks; each tick every device runs its stage on its current
activation and ppermutes the result to the next stage. Bubble fraction
(P-1)/(M+P-1) — the caller picks M >> P.
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from .logical import shard_map


def gpipe(stage_fn: Callable, stage_params, x_micro: jnp.ndarray, *, mesh: Mesh,
          axis: str = "pipe"):
    """Run ``stage_fn(params_i, x)`` as a P-stage pipeline.

    stage_params: pytree whose leaves have a leading stage dim (P, ...).
    x_micro: (M, micro_batch, ...) microbatched input.
    Returns (M, micro_batch, ...) outputs of the final stage.
    """
    n_stages = mesh.shape[axis]
    m = x_micro.shape[0]

    pspec = jax.tree.map(lambda _: P(axis), stage_params)

    def body(params_local, xs):
        params_i = jax.tree.map(lambda a: a[0], params_local)  # this stage's params
        idx = jax.lax.axis_index(axis)
        xs = xs[0]                                             # (M, mb, ...) replicated payload
        zero = jnp.zeros_like(xs[0])

        def tick(carry, t):
            buf, outs = carry
            # stage 0 ingests microbatch t (while t < M); others take the
            # activation handed over from the previous stage last tick
            feed = jnp.where(t < m, xs[jnp.minimum(t, m - 1)], zero)
            inp = jnp.where(idx == 0, feed, buf)
            act = stage_fn(params_i, inp)
            # hand to the next stage
            nxt = jax.lax.ppermute(act, axis, [(i, i + 1) for i in range(n_stages - 1)])
            # last stage emits microbatch t-(P-1) at tick t
            emit_t = t - (n_stages - 1)
            is_emit = (emit_t >= 0) & (idx == n_stages - 1)
            outs = jax.lax.cond(
                is_emit,
                lambda o: o.at[jnp.maximum(emit_t, 0)].set(act),
                lambda o: o,
                outs,
            )
            return (nxt, outs), None

        outs0 = jnp.zeros((m,) + xs.shape[1:], xs.dtype) + zero[None] * 0
        (_, outs), _ = jax.lax.scan(tick, (zero, outs0), jnp.arange(m + n_stages - 1))
        # broadcast the last stage's outputs to every pipe rank
        outs = jnp.where(idx == n_stages - 1, outs, jnp.zeros_like(outs))
        return jax.lax.psum(outs, axis)[None]

    in_specs = (pspec, P(axis))  # payload replicated via leading fake stage dim
    xs_tiled = jnp.broadcast_to(x_micro[None], (n_stages,) + x_micro.shape)
    out = shard_map(body, mesh=mesh, in_specs=in_specs, out_specs=P(axis))(
        stage_params, xs_tiled)
    return out[0]


def sequential_reference(stage_fn: Callable, stage_params, x_micro: jnp.ndarray):
    """Oracle: apply the P stages in sequence to each microbatch."""
    n_stages = jax.tree.leaves(stage_params)[0].shape[0]

    def one(x):
        for i in range(n_stages):
            params_i = jax.tree.map(lambda a: a[i], stage_params)
            x = stage_fn(params_i, x)
        return x

    return jax.vmap(one)(x_micro)
