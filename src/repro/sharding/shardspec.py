"""Local-shard geometry: (PartitionSpec, mesh) -> per-leaf shard facts.

The fused optimizer backend and the SNR measurement run Pallas kernels,
and a ``pallas_call`` is a GSPMD optimization barrier: under plain pjit the
partitioner either replicates the call or gathers full operands around it.
``shard_map`` fixes that — each device runs the kernel on its *local shard*
— but then every per-leaf decision (canonicalization plan, VMEM fits-gate,
kernel pick) must be made from the local shard shape, and any reduction
whose dims are split across devices needs a cross-shard ``lax.psum``.

This module derives those facts from a leaf's PartitionSpec plus the mesh
axis sizes, classifying each leaf into one of three regimes (plus the
trivially replicated case):

  * ``'local'``  — no reduced dim is sharded: the reduction line is whole on
    every shard, so the existing kernels run unchanged on the shard
    (``repro.kernels.leaf_plan`` / ``canon_nd`` applied to the local shape);
  * ``'psum'``   — at least one reduced dim is sharded: each shard computes
    partial sums over its local slice of the reduction line, then a
    ``lax.psum`` over the owning mesh axes completes the mean / SNR stats
    before the O(kept) finalization;
  * ``'jnp'``    — the *local* plan cannot be served transpose-free by a
    kernel (genuinely interleaved K after sharding, VMEM-exceeding lines,
    odd dtypes): the leaf runs the reference jnp math on its shard.
    Dispatchers count these so regressions are visible
    (:func:`regime_counts`).

Only geometry lives here — the actual ``shard_map`` wrapping is in
``repro.optim.fused`` (tree updates) and ``repro.core.snr`` (SNR stats).
Everything is pure Python over static shapes; :class:`SpecMesh` is a
device-free mesh stand-in so specs and plans can be derived for meshes far
bigger than the current process (the analytic sharded roofline in
``benchmarks/opt_speed.py`` plans for the production (data=16, model=16)
mesh from a single CPU).
"""
from __future__ import annotations

import math
from typing import Any, Dict, List, Mapping, NamedTuple, Optional, Sequence, Tuple

import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

Dims = Tuple[int, ...]


class SpecMesh:
    """Device-free mesh stand-in: just ``shape`` + ``axis_names``, which is
    all spec/plan derivation reads. Not usable with ``shard_map`` — pass a
    real ``jax.sharding.Mesh`` for execution."""

    def __init__(self, shape: Mapping[str, int]):
        self.shape: Dict[str, int] = dict(shape)
        self.axis_names: Tuple[str, ...] = tuple(self.shape)

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return f"SpecMesh({self.shape})"


def mesh_is_trivial(mesh: Any) -> bool:
    """A mesh whose every axis has size 1 shards nothing."""
    return all(int(s) == 1 for s in dict(mesh.shape).values())


def spec_entries(spec: Optional[P], ndim: int) -> Tuple[Tuple[str, ...], ...]:
    """Normalize a PartitionSpec to one tuple of mesh-axis names per dim
    (``None`` -> ``()``, ``'x'`` -> ``('x',)``), padded/truncated to ndim."""
    entries = list(spec) if spec is not None else []
    entries = entries[:ndim] + [None] * (ndim - len(entries))
    out: List[Tuple[str, ...]] = []
    for e in entries:
        if e is None:
            out.append(())
        elif isinstance(e, str):
            out.append((e,))
        else:
            out.append(tuple(e))
    return tuple(out)


def dim_shards(shape: Sequence[int], spec: Optional[P], mesh: Any) -> Tuple[int, ...]:
    """Per-dim shard counts, defensively replicating any dim the spec cannot
    split evenly (pjit argument shardings must divide exactly; a non-dividing
    entry here means the spec came from a different shape, so replication is
    the safe reading)."""
    sizes = dict(mesh.shape)
    out = []
    for s, axes in zip(shape, spec_entries(spec, len(shape))):
        f = math.prod(int(sizes.get(a, 1)) for a in axes)
        out.append(f if f > 1 and s % f == 0 else 1)
    return tuple(out)


def even_spec(shape: Sequence[int], spec: Optional[P], mesh: Any) -> P:
    """``spec`` with entries that do not divide ``shape`` evenly dropped —
    the spec :func:`dim_shards` actually assumed, safe to hand to
    ``shard_map`` (which rejects uneven splits)."""
    factors = dim_shards(shape, spec, mesh)
    entries = spec_entries(spec, len(shape))
    out = []
    for f, axes in zip(factors, entries):
        if f == 1 or not axes:
            out.append(None)
        else:
            out.append(axes if len(axes) > 1 else axes[0])
    return P(*out)


def masked_spec(shape: Sequence[int], spec: Optional[P], mesh: Any, dims: Dims) -> P:
    """Spec for a reduced moment stored with size-1 ``dims``: the evened
    param spec with reduced-dim entries dropped (matches
    ``repro.sharding.state_shardings._masked_like_params``). This is how a
    fan_in-compressed moment of a TP-sharded matrix loses its TP axis."""
    dset = {d % len(shape) for d in dims}
    entries = list(even_spec(shape, spec, mesh))
    entries += [None] * (len(shape) - len(entries))
    return P(*[None if i in dset else e for i, e in enumerate(entries)])


def local_shape(shape: Sequence[int], spec: Optional[P], mesh: Any) -> Tuple[int, ...]:
    """Per-device shard shape under the evened spec."""
    return tuple(s // f for s, f in zip(shape, dim_shards(shape, spec, mesh)))


def owning_axes(shape: Sequence[int], spec: Optional[P], mesh: Any, dims: Dims) -> Tuple[str, ...]:
    """Mesh axes that actually shard any of ``dims`` (the ``lax.psum`` axes
    for a reduction over those dims). Empty when the dims are whole on every
    shard."""
    factors = dim_shards(shape, spec, mesh)
    entries = spec_entries(spec, len(shape))
    dset = {d % len(shape) for d in dims}
    out: List[str] = []
    for i in sorted(dset):
        if factors[i] > 1:
            out.extend(a for a in entries[i] if a not in out)
    return tuple(out)


class ShardLeafPlan(NamedTuple):
    """Per-leaf sharding regime + the shard_map specs to run it under.

    ``regime`` is 'local' | 'psum' | 'jnp' (see module docstring; dense
    K = () leaves are always 'local' — elementwise math never crosses
    shards). ``spec`` / ``red_spec`` are the evened full-leaf and reduced-
    moment specs; ``psum_axes`` the mesh axes owning sharded reduced dims
    ('psum' only); ``red_total`` the *global* reduction extent (the mean's
    divisor after the psum).

    Psum-regime extras:

    ``finalize`` ('kernel' | 'jnp') records whether the *local* canonical
    plan is servable by the partial-stats/finalize Pallas pair — 'jnp' is
    the fallback the roofline gate counts (:func:`regime_counts` reports it
    as 'psum_jnp') — and ``cn`` carries that local plan's
    :class:`repro.kernels.ops.CanonND` (set iff ``finalize == 'kernel'``),
    so the dispatcher runs exactly the plan the planner gated instead of
    re-deriving one under a second buffer-count constant. ``owner`` is the owner-shard dedupe placement for the
    reduced moment: ``((mesh_axis, dim), ...)`` assigning psum-group axes
    onto kept dims they divide evenly, and ``nu_spec`` the corresponding
    storage spec — v_new is written only as each shard's owner slice and
    re-broadcast by riding the next step's partial-sums psum (zero extra
    ICI; see ``repro.optim.fused._psum_slim_leaf``). Empty/``red_spec``
    when no kept dim divides (the moment stays replicated, PR-4 style).
    ``kept_axes`` are the mesh axes sharding kept dims (the ``lax.pmean``
    axes for from-update SNR ratio means)."""

    regime: str
    spec: P
    red_spec: P
    psum_axes: Tuple[str, ...]
    local_shape: Tuple[int, ...]
    red_total: int
    finalize: str = "kernel"
    owner: Tuple[Tuple[str, int], ...] = ()
    nu_spec: Optional[P] = None
    kept_axes: Tuple[str, ...] = ()
    cn: Optional[Any] = None    # local CanonND, set iff finalize == 'kernel'


def owner_factor(pl: ShardLeafPlan, mesh: Any) -> int:
    """Dedupe factor the owner placement achieves for the stored reduced
    moment (1 = fully replicated across the psum group, PR-4 behavior)."""
    sizes = dict(mesh.shape)
    return math.prod(int(sizes.get(a, 1)) for a, _ in pl.owner)


def psum_kernel_eligible(pl: ShardLeafPlan, use_first_moment: bool) -> bool:
    """Whether a psum-regime leaf can run the Pallas partial-stats/finalize
    pair (vs the jnp reference math on its shard): the planner must have
    gated the local canonical plan servable (``finalize == 'kernel'``, with
    the plan recorded in ``cn``), and the caller must carry a first moment —
    the m-less form has no fused pair. One predicate shared by the per-leaf
    dispatcher and the megaplan psum grouping so they can never disagree on
    which leaves the kernels own."""
    return bool(use_first_moment and pl.finalize == "kernel"
                and pl.cn is not None)


def owner_placement(red_shape: Sequence[int], red_spec: P, psum_axes: Sequence[str],
                    mesh: Any) -> Tuple[Tuple[Tuple[str, int], ...], P]:
    """Greedy owner-shard placement for a psum leaf's reduced moment.

    The moment is replicated across ``psum_axes`` (masking the reduced dims
    deleted exactly those spec entries). Assign each psum axis onto a kept
    dim whose *local* extent it divides evenly — the storage then holds a
    1/A owner slice per shard, and the broadcast back to full lines rides
    the partial-sums all-reduce (which already moves every line over those
    axes), costing zero additional ICI.

    All-or-nothing: if *any* psum axis finds no dim (e.g. gpt_small's vocab
    50304, which 256 does not divide), the whole placement is dropped and
    the moment stays replicated. A partial placement would be wrong, not
    merely weaker — the ``b2 * v`` payload contribution is keyed to the
    owner slice, so shards along an *unplaced* psum axis would each add an
    identical copy into the all-reduce, inflating the moment by that axis's
    size. Returns ``(placement, nu_spec)``."""
    sizes = dict(mesh.shape)
    entries = [list(e) for e in spec_entries(red_spec, len(red_shape))]
    local = [s // math.prod(int(sizes.get(a, 1)) for a in e)
             for s, e in zip(red_shape, entries)]
    placement: List[Tuple[str, int]] = []
    for a in psum_axes:
        f = int(sizes.get(a, 1))
        if f <= 1:
            continue
        # Largest local extent first: keeps slices tile-friendly.
        for i in sorted(range(len(red_shape)), key=lambda j: -local[j]):
            if local[i] > 1 and local[i] % f == 0:
                entries[i].append(a)
                local[i] //= f
                placement.append((a, i))
                break
        else:
            return (), red_spec
    nu_spec = P(*[None if not e else (e[0] if len(e) == 1 else tuple(e))
                  for e in entries])
    return tuple(placement), nu_spec


def plan_sharded_leaf(shape: Sequence[int], dtype: Any, dims: Dims, spec: Optional[P],
                      mesh: Any, *, n_bufs: int) -> ShardLeafPlan:
    """Classify one leaf's sharding regime and derive its shard_map specs.

    ``n_bufs`` is the consuming kernel's VMEM buffer count, forwarded to the
    local-shape :func:`repro.kernels.leaf_plan` fits-gate — a reduction line
    that outruns VMEM globally can still fit once the *kept* dims are
    sharded, and vice versa never (sharding only shrinks shards).
    """
    from ..kernels.ops import leaf_plan  # local import: kernels is heavy

    shape = tuple(int(s) for s in shape)
    dims = tuple(dims)
    spec_e = even_spec(shape, spec, mesh)
    lshape = local_shape(shape, spec, mesh)
    if not dims:
        # Dense Adam: elementwise, every shard independent.
        return ShardLeafPlan("local", spec_e, spec_e, (), lshape, 1)
    dset = {d % len(shape) for d in dims}
    red_spec = masked_spec(shape, spec, mesh, dims)
    red_total = math.prod(shape[i] for i in sorted(dset))
    psum_axes = owning_axes(shape, spec, mesh, dims)
    kept = tuple(i for i in range(len(shape)) if i not in dset)
    kept_axes = owning_axes(shape, spec, mesh, kept)
    if psum_axes:
        from ..kernels.slim_update import FINALIZE_BUFS, PARTIAL_BUFS

        red_shape = tuple(1 if i in dset else s for i, s in enumerate(shape))
        owner, nu_spec = owner_placement(red_shape, red_spec, psum_axes, mesh)
        # The psum route runs the partial-stats + finalize pair: the line
        # must fit the hungriest stage's working set (PARTIAL_BUFS also
        # covers the with_snr variant), not just the caller's single-kernel
        # buffer count.
        lplan = leaf_plan(lshape, dtype, dims,
                          n_bufs=max(n_bufs, PARTIAL_BUFS, FINALIZE_BUFS),
                          allow_transpose=False)
        finalize = "kernel" if lplan.route == "slim" else "jnp"
        return ShardLeafPlan("psum", spec_e, red_spec, psum_axes, lshape,
                             red_total, finalize=finalize, owner=owner,
                             nu_spec=nu_spec, kept_axes=kept_axes,
                             cn=lplan.cn)
    plan = leaf_plan(lshape, dtype, dims, n_bufs=n_bufs, allow_transpose=False)
    regime = "local" if plan.route in ("dense", "slim") else "jnp"
    return ShardLeafPlan(regime, spec_e, red_spec, (), lshape, red_total,
                         kept_axes=kept_axes)


def plan_sharded_tree(shapes: Sequence[Tuple[int, ...]], dtypes: Sequence[Any],
                      dims_leaves: Sequence[Dims], spec_leaves: Sequence[Optional[P]],
                      mesh: Any, *, n_bufs: int) -> List[ShardLeafPlan]:
    """:func:`plan_sharded_leaf` over aligned leaf lists."""
    return [plan_sharded_leaf(s, dt, tuple(d), sp, mesh, n_bufs=n_bufs)
            for s, dt, d, sp in zip(shapes, dtypes, dims_leaves, spec_leaves)]


def regime_counts(plans: Sequence[ShardLeafPlan], *, degraded: int = 0) -> Dict[str, int]:
    """{'local': n, 'psum': n, 'psum_jnp': n, 'jnp': n, 'degraded': n} over a
    planned tree — the report the dispatchers and the sharded roofline print,
    so a planner regression that silently demotes kernel leaves to a jnp
    fallback is visible. 'psum' counts only Pallas-resident psum leaves
    (partial-stats + finalize kernels); 'psum_jnp' counts psum leaves whose
    local canonical plan the kernel pair cannot serve (interleaved K after
    sharding, VMEM-exceeding lines) — the CI roofline gate holds this at zero
    for gpt_small. 'degraded' is the runtime complement to the static plan:
    leaf calls that fell from a kernel to the jnp reference because the
    Pallas path raised (pass
    ``repro.optim.fused.kernel_degraded_leaves()``); it defaults to 0 so a
    plain planning report stays purely static."""
    out = {"local": 0, "psum": 0, "psum_jnp": 0, "jnp": 0, "degraded": int(degraded)}
    for pl in plans:
        if pl.regime == "psum" and pl.finalize != "kernel":
            out["psum_jnp"] += 1
        else:
            out[pl.regime] += 1
    return out


def sharded_pair(mesh: Any, param_specs: Any, what: str):
    """Validate the (mesh, param_specs) pair the shard-aware fused backend
    needs: both -> sharded path, neither -> plain path, exactly one -> warn
    loudly and run unsharded. A silently half-specified pair would quietly
    re-create the GSPMD-gathers-around-pallas_call perf cliff the sharded
    path exists to remove, with no signal."""
    import warnings

    if (mesh is None) != (param_specs is None):
        missing = "param_specs" if param_specs is None else "mesh"
        warnings.warn(
            f"{what}: got only one of mesh/param_specs ({missing} is None); "
            f"the fused backend will run UNSHARDED, letting GSPMD gather "
            f"full leaves around the Pallas kernels. Pass both to enable "
            f"the shard_map path.", stacklevel=3)
        return None, None
    return mesh, param_specs


def normalize_spec_leaves(param_specs: Any, treedef: Any, what: str) -> List[Optional[P]]:
    """Flatten a PartitionSpec pytree (or a pre-flattened leaf-aligned
    sequence) to a per-leaf list, validating its *structure* against
    ``treedef`` (the flattened tree it must mirror) — a same-count but
    differently-structured spec tree would otherwise silently pair wrong
    specs with leaves and compute wrong sharded math."""
    import jax

    n_leaves = treedef.num_leaves
    if param_specs is None:
        return [None] * n_leaves
    # None is the standard pjit idiom for 'replicated' — treat such entries
    # as leaves (tree flattening would silently drop them as empty subtrees,
    # turning a valid mirror into a spurious mismatch).
    is_leaf = lambda x: x is None or isinstance(x, P)
    leaves = jax.tree_util.tree_leaves(param_specs, is_leaf=is_leaf)
    spec_def = jax.tree_util.tree_structure(
        jax.tree.map(lambda _: 0, param_specs, is_leaf=is_leaf))
    if spec_def == treedef:
        return list(leaves)
    # A flat leaf-aligned list/tuple is accepted as already normalized.
    if isinstance(param_specs, (list, tuple)) and len(param_specs) == n_leaves \
            and all(is_leaf(s) for s in param_specs):
        return list(param_specs)
    raise ValueError(
        f"{what}: param_specs structure {spec_def} does not mirror the "
        f"tree being updated ({treedef}) — build the specs with "
        f"repro.sharding.logical.param_specs from the same parameter tree")


def spec_dtype(x: Any) -> Any:
    """dtype of an array or ShapeDtypeStruct leaf (fp32 fallback)."""
    return getattr(x, "dtype", jnp.float32)
