"""Deterministic fault-injection hook registry shared by train and serve.

PR 6 grew one-off module globals for each injection point
(``checkpoint.store._io_fault_hook``, ``optim.fused._KERNEL_FAULT_HOOK``);
the serving fault layer needs several more, so the pattern lives here once:
a named registry of hook callables that production code *fires* at its
instrumentation points and test/drill code *installs* around a scope.

Conventions:

* Hook points are dotted strings owned by the firing module
  (``"checkpoint.io"``, ``"optim.kernel"``, ``"serve.kernel"``,
  ``"serve.logits"``, ``"serve.clock"``, ``"serve.step"``).
* :func:`fire` is a no-op (returns ``None``) when nothing is installed, so
  instrumentation costs one dict lookup on the hot path.
* A hook simulates a fault either by **raising** (IO failure, kernel
  failure — the caller's normal exception handling is what's under test) or
  by **returning** a value the call site interprets (a clock skew, a
  poison verdict).
* Everything is deterministic: hooks key off the step/call counters their
  installer closes over, never wall clock or global RNG —
  :func:`call_counter` is the shared "fail on the nth call" helper.
* Install/uninstall nests: :func:`installed` restores whatever hook was
  previously registered, so drills can stack injections.
"""
from __future__ import annotations

import contextlib
from typing import Any, Callable, Dict, Optional, Tuple

_REGISTRY: Dict[str, Callable[..., Any]] = {}


def install(point: str, hook: Optional[Callable[..., Any]]) -> None:
    """Register ``hook`` at ``point`` (``None`` uninstalls). Prefer the
    :func:`installed` context manager, which restores the previous hook."""
    if hook is None:
        _REGISTRY.pop(point, None)
    else:
        _REGISTRY[point] = hook


def get(point: str) -> Optional[Callable[..., Any]]:
    return _REGISTRY.get(point)


def fire(point: str, *args: Any, **kwargs: Any) -> Any:
    """Call the hook installed at ``point`` (if any) and return its value.
    Exceptions propagate to the firing site — that is the injection."""
    hook = _REGISTRY.get(point)
    if hook is None:
        return None
    return hook(*args, **kwargs)


@contextlib.contextmanager
def installed(point: str, hook: Callable[..., Any]):
    """Install ``hook`` at ``point`` for the scope, restoring the previously
    installed hook (or the empty slot) on exit."""
    prev = _REGISTRY.get(point)
    _REGISTRY[point] = hook
    try:
        yield hook
    finally:
        if prev is None:
            _REGISTRY.pop(point, None)
        else:
            _REGISTRY[point] = prev


def call_counter(fail_on: Tuple[int, ...],
                 make_exc: Callable[[int], BaseException]):
    """Build a (hook, state) pair that raises ``make_exc(n)`` on the nth
    call (1-based) for n in ``fail_on`` — the deterministic "fail the nth
    write/launch" schedule both train and serve injections use. ``state``
    exposes ``calls``/``failed`` counters so drills can assert the
    injection actually happened."""
    state = {"calls": 0, "failed": 0}

    def hook(*_args: Any, **_kwargs: Any) -> None:
        state["calls"] += 1
        if state["calls"] in fail_on:
            state["failed"] += 1
            raise make_exc(state["calls"])

    return hook, state
