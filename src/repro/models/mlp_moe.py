"""Dense MLP blocks (gated SiLU / GELU) and top-k MoE with expert parallelism.

MoE dispatch is gather/scatter-based (no (tokens, experts, capacity) one-hot
dispatch tensor): per expert we build a (capacity,) token-index list from a
cumsum over the routing mask, gather the rows, run the expert FFN batched
over the (sharded) expert dim, and scatter-add back weighted by the gate.
Under the production mesh the expert dim is sharded over 'model' (EP) and the
token rows move through an XLA-inserted all-gather — the collective the
roofline analysis attributes to MoE.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Tuple

import jax
import jax.numpy as jnp

from ..sharding.logical import constrain, shard_map
from .common import ParamSpec


def gelu(x):
    return jax.nn.gelu(x, approximate=True)


def mlp_specs(d_model: int, d_ff: int, *, gated: bool, w_init, down_init):
    specs = {
        "w_up": ParamSpec((d_model, d_ff), ("embed", "mlp"), "mlp_up", w_init,
                          fan_in=("embed",), fan_out=("mlp",)),
        "w_down": ParamSpec((d_ff, d_model), ("mlp", "embed"), "mlp_down", down_init,
                            fan_in=("mlp",), fan_out=("embed",)),
    }
    if gated:
        specs["w_gate"] = ParamSpec((d_model, d_ff), ("embed", "mlp"), "mlp_gate", w_init,
                                    fan_in=("embed",), fan_out=("mlp",))
    return specs


def mlp_forward(p, x: jnp.ndarray, *, gated: bool) -> jnp.ndarray:
    y = _mlp_explicit_tp(p, x, gated=gated)
    if y is not None:
        return y
    h = jnp.einsum("...d,df->...f", x, p["w_up"].astype(x.dtype))
    if gated:
        g = jnp.einsum("...d,df->...f", x, p["w_gate"].astype(x.dtype))
        h = jax.nn.silu(g) * h
    else:
        h = gelu(h)
    if h.ndim == 3:
        h = constrain(h, "batch", "seq", "act_mlp")
    y = jnp.einsum("...f,fd->...d", h, p["w_down"].astype(x.dtype))
    if y.ndim == 3:
        # reduce-scatter the TP partial sums straight into the SP layout
        y = constrain(y, "batch", "seq_sp", "act_embed")
    return y


def _mlp_explicit_tp(p, x: jnp.ndarray, *, gated: bool):
    """Explicit Megatron-SP tensor parallelism for the dense MLP.

    GSPMD resolves the TP reduction as a *full fp32 all-reduce* followed by a
    slice (measured on deepseek-67b: 6 x 512 MB fp32 ARs per layer per
    microbatch, 1.2 TB/device/step). This shard_map takes explicit control:
    one bf16 all-gather of the SP-sharded activations in, local matmuls, one
    bf16 reduce-scatter of the partial sums out — 4x fewer ICI bytes (2x
    RS-vs-AR, 2x bf16-vs-fp32). Returns None when the mesh/shape don't allow
    it (falls back to the GSPMD path).
    """
    from ..sharding.logical import current
    from jax.sharding import PartitionSpec as P

    ctx = current()
    if ctx is None or x.ndim != 3 or "model" not in ctx.mesh.axis_names:
        return None
    mesh = ctx.mesh
    tp = mesh.shape["model"]
    b, s, d = x.shape
    f = p["w_up"].shape[1]
    if tp == 1 or s % tp or f % tp:
        return None
    batch_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    if b % math.prod(mesh.shape[a] for a in batch_axes):
        return None

    xspec = P(batch_axes, "model", None)          # SP layout between blocks
    wspec_col = P(None, "model")                   # column-parallel up/gate
    wspec_row = P("model", None)                   # row-parallel down
    dtype = x.dtype

    def body(x_l, wu, wd, wg):
        x_full = jax.lax.all_gather(x_l, "model", axis=1, tiled=True)
        h = jnp.einsum("bsd,df->bsf", x_full, wu.astype(dtype))
        if gated:
            h = jax.nn.silu(jnp.einsum("bsd,df->bsf", x_full, wg.astype(dtype))) * h
        else:
            h = gelu(h)
        y_part = jnp.einsum("bsf,fd->bsd", h, wd.astype(dtype)).astype(dtype)
        return jax.lax.psum_scatter(y_part, "model", scatter_dimension=1, tiled=True)

    wg = p.get("w_gate", p["w_up"])
    return shard_map(
        body, mesh=mesh,
        in_specs=(xspec, wspec_col, wspec_row, wspec_col),
        out_specs=xspec,
    )(x, p["w_up"], p["w_down"], wg)


# ---------------------------------------------------------------------------
# Mixture of Experts
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_model: int
    d_ff: int
    gated: bool = True
    capacity_factor: float = 1.25
    router_z_coef: float = 1e-3
    aux_coef: float = 1e-2


def moe_specs(cfg: MoEConfig, *, w_init, down_init):
    e, d, f = cfg.n_experts, cfg.d_model, cfg.d_ff
    specs = {
        "router": ParamSpec((d, e), ("embed", "experts"), "moe_router", w_init),
        "w_up": ParamSpec((e, d, f), ("experts", "embed", "mlp"), "mlp_up", w_init,
                          fan_in=("embed",), fan_out=("mlp",)),
        "w_down": ParamSpec((e, f, d), ("experts", "mlp", "embed"), "mlp_down", down_init,
                            fan_in=("mlp",), fan_out=("embed",)),
    }
    if cfg.gated:
        specs["w_gate"] = ParamSpec((e, d, f), ("experts", "embed", "mlp"), "mlp_gate", w_init,
                                    fan_in=("embed",), fan_out=("mlp",))
    return specs


def _expert_ffn_dense(p, xg, cfg: MoEConfig, dtype):
    """Batched-over-experts FFN; local/unsharded path and shard_map body."""
    h = jnp.einsum("ecd,edf->ecf", xg, p["w_up"].astype(dtype))
    if cfg.gated:
        g = jnp.einsum("ecd,edf->ecf", xg, p["w_gate"].astype(dtype))
        h = jax.nn.silu(g) * h
    else:
        h = gelu(h)
    return jnp.einsum("ecf,efd->ecd", h, p["w_down"].astype(dtype))


def _expert_ffn_sharded(p, xg, cfg: MoEConfig, dtype):
    """Expert-parallel FFN over xg: (E, G, C, d) with E on 'model' (EP) and
    the DP-shard dim G on the batch axes.

    Runs inside shard_map so the sharding is *structural*: GSPMD propagation
    through the dispatch gather/scatter loses the expert sharding in the
    backward pass (measured: fp32 (E*C, d_ff) replicated buffers, 4.5 GiB
    each, on jamba). shard_map in_specs also perform the FSDP all-gather of
    the expert weights over 'data'."""
    from ..sharding.logical import current
    from jax.sharding import PartitionSpec as P

    ctx = current()
    e = cfg.n_experts

    def body(xg_l, w):
        el, gl, c, d = xg_l.shape
        y = _expert_ffn_dense(w, xg_l.reshape(el, gl * c, d), cfg, dtype)
        return y.reshape(el, gl, c, d)

    if ctx is None or "model" not in ctx.mesh.axis_names or e % ctx.mesh.shape["model"] != 0:
        return body(xg, p)

    mesh = ctx.mesh
    batch_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    g_spec = batch_axes if xg.shape[1] % math.prod(mesh.shape[a] for a in batch_axes) == 0 else None
    xspec = P("model", g_spec, None, None)
    wspec = P("model", None, None)
    weights = {k: p[k] for k in ("w_up", "w_down") + (("w_gate",) if cfg.gated else ())}

    return shard_map(
        body, mesh=mesh,
        in_specs=(xspec, {k: wspec for k in weights}),
        out_specs=xspec,
    )(xg, weights)


def _dispatch_group(xf, gates, eidx, e: int, k: int, capacity: int, dtype):
    """Token dispatch for one DP shard. xf: (n, d); gates/eidx: (n, k).

    Returns (xg (E, C, d), token_of (E, C), gate_of (E, C), valid (E, C, 1)).
    """
    n = xf.shape[0]
    flat_e = eidx.reshape(-1)                                   # (n*k,)
    flat_gate = gates.reshape(-1)
    onehot = jax.nn.one_hot(flat_e, e, dtype=jnp.int32)
    pos = jnp.cumsum(onehot, axis=0) - onehot                   # count of same-expert rows before me
    my_pos = jnp.take_along_axis(pos, flat_e[:, None], axis=1)[:, 0]
    keep = my_pos < capacity                                    # dropped beyond capacity

    sentinel = n * k
    dispatch = jnp.full((e, capacity), sentinel, jnp.int32)
    rows = jnp.where(keep, flat_e, e)
    cols = jnp.where(keep, my_pos, 0)
    dispatch = dispatch.at[rows, cols].set(jnp.arange(n * k, dtype=jnp.int32), mode="drop")

    token_of = jnp.where(dispatch == sentinel, 0, dispatch // k)
    valid = (dispatch != sentinel)[..., None]
    xg = jnp.take(xf, token_of.reshape(-1), axis=0).reshape(e, capacity, -1)
    xg = jnp.where(valid, xg, 0).astype(dtype)
    gate_of = jnp.where(dispatch == sentinel, 0.0,
                        jnp.take(flat_gate, jnp.where(dispatch == sentinel, 0, dispatch)))
    return xg, token_of, gate_of, valid


def moe_forward(p, x: jnp.ndarray, cfg: MoEConfig) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x: (B, S, D) -> (out, aux_loss).

    Dispatch is performed *per DP shard* (G groups = product of batch mesh
    axes): capacity then scales with local tokens, so the per-device expert
    buffer is (E/ep, C_local, d_ff) instead of (E/ep, C_global, d_ff) — the
    difference between 0.3 GiB and 4.5 GiB per MoE layer on jamba."""
    from ..sharding.logical import current

    b, s, d = x.shape
    e, k = cfg.n_experts, cfg.top_k
    ctx = current()
    g = 1
    if ctx is not None:
        g = math.prod(ctx.mesh.shape[a] for a in ("pod", "data") if a in ctx.mesh.axis_names)
        if b % g != 0:
            g = 1
    n_g = b * s // g
    xf = x.reshape(g, n_g, d)

    logits = jnp.einsum("gnd,de->gne", xf.astype(jnp.float32), p["router"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    gates, eidx = jax.lax.top_k(probs, k)                       # (G, n, k)
    gates = gates / jnp.sum(gates, axis=-1, keepdims=True)

    # aux losses (load balance + router z), standard Switch/ST-MoE form
    density = jnp.mean(jax.nn.one_hot(eidx[..., 0], e), axis=(0, 1))
    density_proxy = jnp.mean(probs, axis=(0, 1))
    aux = cfg.aux_coef * e * jnp.sum(density * density_proxy)
    zloss = cfg.router_z_coef * jnp.mean(jnp.square(jax.nn.logsumexp(logits, axis=-1)))
    aux_loss = aux + zloss

    capacity = int(max(1, round(n_g * k / e * cfg.capacity_factor)))
    if n_g * k <= 16 * e:
        # decode / tiny batches: dropless (capacity = every token could pick
        # this expert) — token drops would make decode diverge from prefill
        capacity = min(n_g, max(capacity, n_g))
    xg, token_of, gate_of, valid = jax.vmap(
        lambda xf_g, g_g, e_g: _dispatch_group(xf_g, g_g, e_g, e, k, capacity, x.dtype)
    )(xf, gates, eidx)                                          # xg: (G, E, C, d)

    xg = jnp.moveaxis(xg, 0, 1)                                 # (E, G, C, d)
    xg = constrain(xg, "experts", "batch", None, None)
    y = _expert_ffn_sharded(p, xg, cfg, x.dtype)
    y = constrain(y, "experts", "batch", None, None)
    y = jnp.moveaxis(y, 1, 0)                                   # (G, E, C, d)

    y = y * gate_of[..., None].astype(y.dtype)
    y = jnp.where(valid, y, 0)

    def combine_group(y_g, token_of_g):
        out = jnp.zeros((n_g, d), y_g.dtype)
        return out.at[token_of_g.reshape(-1)].add(y_g.reshape(-1, d), mode="drop")

    out = jax.vmap(combine_group)(y, token_of)                  # (G, n, d)
    out = out.reshape(b, s, d)
    return constrain(out, "batch", "seq", "act_embed"), aux_loss
