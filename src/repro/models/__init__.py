from . import attention, linear_lm, mlp_moe, ssm, transformer
from .common import (
    ParamSpec,
    abstract_params,
    init_params,
    meta_tree,
    mitchell_residual_init,
    normal_init,
    stack_specs,
    torch_default_init,
)
from .linear_lm import LinearLMConfig
from .transformer import (
    DecodeCache,
    LayerSlot,
    ModelConfig,
    abstract_decode_cache,
    decode_step,
    forward,
    init_decode_cache,
)

__all__ = [
    "ParamSpec",
    "abstract_params",
    "init_params",
    "meta_tree",
    "mitchell_residual_init",
    "normal_init",
    "stack_specs",
    "torch_default_init",
    "DecodeCache",
    "LayerSlot",
    "ModelConfig",
    "abstract_decode_cache",
    "decode_step",
    "forward",
    "init_decode_cache",
    "LinearLMConfig",
    "attention",
    "linear_lm",
    "mlp_moe",
    "ssm",
    "transformer",
]
