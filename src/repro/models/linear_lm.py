"""Two-layer linear LM (paper §4.1 / App. B.2): embedding + linear head.

Used for the vocabulary-size / heavy-tail compressibility experiment: the
smallest model where the token-dimension incompressibility mechanism shows.
"""
from __future__ import annotations

import dataclasses
from typing import Dict

import jax
import jax.numpy as jnp

from .common import ParamSpec, init_params, meta_tree


@dataclasses.dataclass(frozen=True)
class LinearLMConfig:
    vocab_size: int
    d_model: int = 768

    def specs(self):
        def embed_init(key, shape, dtype):
            return jax.random.truncated_normal(key, -2.0, 2.0, shape).astype(dtype)

        def head_init(key, shape, dtype):
            std = shape[0] ** -0.5
            return (jax.random.truncated_normal(key, -2.0, 2.0, shape) * std).astype(dtype)

        return {
            "embed": ParamSpec((self.vocab_size, self.d_model), ("vocab", "embed"),
                               "token_embedding", embed_init,
                               fan_in=("vocab",), fan_out=("embed",)),
            "head": ParamSpec((self.d_model, self.vocab_size), ("embed", "vocab"),
                              "lm_head", head_init,
                              fan_in=("embed",), fan_out=("vocab",)),
        }

    def init(self, key):
        spec = self.specs()
        return init_params(spec, key), meta_tree(spec)


def forward(cfg: LinearLMConfig, params, batch: Dict[str, jnp.ndarray]):
    x = jnp.take(params["embed"], batch["tokens"], axis=0)
    logits = jnp.einsum("bsd,dv->bsv", x, params["head"])
    return logits, jnp.zeros((), jnp.float32)
