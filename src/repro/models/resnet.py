"""ResNet-18 (CIFAR variant) in pure JAX — the paper's §3.1.3 regime.

The paper's most compressible setting: ResNets show high SNR across both
fan_in and fan_out almost everywhere (Fig. 5), with the first conv resisting
fan_out compression and the classifier hovering at SNR ~ 1. This module lets
``benchmarks/resnet_snr.py`` reproduce that ordering.

Conv kernels are stored (kh, kw, cin, cout) with fan_in = (kh, kw, cin) —
the paper's W ∈ R^{fan_out × fan_in·k²} view. BatchNorm uses per-batch
statistics (training mode; running stats are irrelevant to the SNR study).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from .common import ParamSpec, init_params, meta_tree, normal_init, ones_init, zeros_init


def _conv_spec(kh, kw, cin, cout, role="conv"):
    def he_init(key, shape, dtype):
        fan_in = shape[0] * shape[1] * shape[2]
        std = (2.0 / fan_in) ** 0.5
        return (jax.random.normal(key, shape) * std).astype(dtype)

    return ParamSpec((kh, kw, cin, cout), ("kh", "kw", "cin", "cout"), role,
                     he_init, fan_in=("kh", "kw", "cin"), fan_out=("cout",))


def _bn_specs(c):
    return {
        "scale": ParamSpec((c,), ("cout",), "norm", ones_init()),
        "bias": ParamSpec((c,), ("cout",), "bias", zeros_init()),
    }


@dataclasses.dataclass(frozen=True)
class ResNetConfig:
    stages: Tuple[int, ...] = (2, 2, 2, 2)   # ResNet-18
    width: int = 64
    classes: int = 100
    in_channels: int = 3

    def specs(self) -> Dict[str, Any]:
        w = self.width
        specs: Dict[str, Any] = {
            "stem": {"conv": _conv_spec(3, 3, self.in_channels, w), "bn": _bn_specs(w)},
        }
        cin = w
        for si, n_blocks in enumerate(self.stages):
            cout = w * (2 ** si)
            for bi in range(n_blocks):
                block: Dict[str, Any] = {
                    "conv1": _conv_spec(3, 3, cin, cout), "bn1": _bn_specs(cout),
                    "conv2": _conv_spec(3, 3, cout, cout), "bn2": _bn_specs(cout),
                }
                if cin != cout:
                    block["proj"] = _conv_spec(1, 1, cin, cout)
                specs[f"stage{si}_block{bi}"] = block
                cin = cout
        specs["head"] = ParamSpec((cin, self.classes), ("cin", "vocab"), "head",
                                  normal_init(0.01), fan_in=("cin",), fan_out=("vocab",))
        return specs

    def init(self, key):
        spec = self.specs()
        return init_params(spec, key), meta_tree(spec)


def _conv(x, w, stride=1):
    return jax.lax.conv_general_dilated(
        x, w, window_strides=(stride, stride), padding="SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"))


def _bn(x, p, eps=1e-5):
    mean = jnp.mean(x, axis=(0, 1, 2), keepdims=True)
    var = jnp.var(x, axis=(0, 1, 2), keepdims=True)
    xn = (x - mean) * jax.lax.rsqrt(var + eps)
    return xn * p["scale"] + p["bias"]


def forward(cfg: ResNetConfig, params, batch):
    """batch['images']: (B, H, W, C) -> (logits (B, classes), aux=0)."""
    x = batch["images"]
    x = jax.nn.relu(_bn(_conv(x, params["stem"]["conv"]), params["stem"]["bn"]))
    cin = cfg.width
    for si, n_blocks in enumerate(cfg.stages):
        cout = cfg.width * (2 ** si)
        for bi in range(n_blocks):
            p = params[f"stage{si}_block{bi}"]
            stride = 2 if (bi == 0 and si > 0) else 1
            h = jax.nn.relu(_bn(_conv(x, p["conv1"], stride), p["bn1"]))
            h = _bn(_conv(h, p["conv2"]), p["bn2"])
            skip = _conv(x, p["proj"], stride) if "proj" in p else x
            x = jax.nn.relu(h + skip)
            cin = cout
    x = jnp.mean(x, axis=(1, 2))                 # global average pool
    logits = x @ params["head"]
    return logits, jnp.zeros((), jnp.float32)


def synthetic_cifar(key, batch: int, classes: int, size: int = 32):
    """Learnable synthetic images: class-dependent channel means + noise."""
    k1, k2 = jax.random.split(key)
    labels = jax.random.randint(k1, (batch,), 0, classes)
    means = jax.random.normal(jax.random.PRNGKey(7), (classes, 3)) * 0.5
    imgs = jax.random.normal(k2, (batch, size, size, 3)) * 0.3 + means[labels][:, None, None, :]
    return {"images": imgs, "labels": labels}
