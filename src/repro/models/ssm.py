"""Mamba-1 selective-state-space block (falcon-mamba / jamba mixer).

TPU adaptation (DESIGN.md §3): the CUDA selective-scan kernel is replaced by
a *chunked* scan — an outer ``lax.scan`` over sequence chunks carrying the
(B, d_inner, d_state) hidden state, with a parallel ``associative_scan``
inside each chunk. Live memory is O(B * chunk * d_inner * d_state) instead of
O(B * S * d_inner * d_state), which is what lets the 500k-token cell compile.
``repro/kernels/ssm_scan`` provides the Pallas VMEM-resident version of the
inner chunk; this module is its oracle.
"""
from __future__ import annotations

import dataclasses
import functools
import math
from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

from ..sharding.logical import constrain, shard_map
from .common import ParamSpec, constant_init, normal_init, ones_init, zeros_init


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    d_model: int
    d_inner: int
    d_state: int = 16
    d_conv: int = 4
    dt_rank: int = 0  # 0 -> ceil(d_model / 16)
    chunk: int = 256

    @property
    def rank(self) -> int:
        return self.dt_rank or -(-self.d_model // 16)


def _a_log_init():
    def init(key, shape, dtype):
        # S4D-real init: A = -(1..d_state) per channel
        d_inner, d_state = shape
        a = jnp.broadcast_to(jnp.arange(1, d_state + 1, dtype=jnp.float32), (d_inner, d_state))
        return jnp.log(a).astype(dtype)

    return init


def _dt_proj_init(rank: int):
    def init(key, shape, dtype):
        std = rank**-0.5
        return (jax.random.uniform(key, shape, minval=-std, maxval=std)).astype(dtype)

    return init


def ssm_specs(cfg: SSMConfig, *, w_init, out_init):
    d, di, n, r = cfg.d_model, cfg.d_inner, cfg.d_state, cfg.rank
    return {
        "in_proj": ParamSpec((d, 2 * di), ("embed", "d_inner"), "ssm_in", w_init,
                             fan_in=("embed",), fan_out=("d_inner",)),
        "conv_w": ParamSpec((di, cfg.d_conv), ("d_inner", "conv_w"), "ssm_conv", normal_init(0.02)),
        "conv_b": ParamSpec((di,), ("d_inner",), "bias", zeros_init()),
        "x_proj": ParamSpec((di, r + 2 * n), ("d_inner", "dt_rank"), "ssm_x", w_init,
                            fan_in=("d_inner",), fan_out=("dt_rank",)),
        "dt_proj": ParamSpec((r, di), ("dt_rank", "d_inner"), "ssm_dt", _dt_proj_init(r),
                             fan_in=("dt_rank",), fan_out=("d_inner",)),
        "dt_bias": ParamSpec((di,), ("d_inner",), "bias", constant_init(math.log(math.e - 1) * 0.01 + 0.0)),
        "a_log": ParamSpec((di, n), ("d_inner", "state"), "ssm_a", _a_log_init()),
        "d_skip": ParamSpec((di,), ("d_inner",), "ssm_d", ones_init()),
        "out_proj": ParamSpec((di, d), ("d_inner", "embed"), "ssm_out", out_init,
                              fan_in=("d_inner",), fan_out=("embed",)),
    }


def _causal_conv(x: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray, history: jnp.ndarray | None = None):
    """Depthwise causal conv via shifted adds. x: (B, S, di); w: (di, K).

    ``history``: (B, K-1, di) previous inputs (decode); returns new history.
    """
    bsz, s, di = x.shape
    k = w.shape[1]
    if history is None:
        history = jnp.zeros((bsz, k - 1, di), x.dtype)
    xp = jnp.concatenate([history, x], axis=1)  # (B, S+K-1, di)
    out = jnp.zeros_like(x, dtype=jnp.float32)
    for i in range(k):
        out = out + xp[:, i : i + s, :].astype(jnp.float32) * w[:, i].astype(jnp.float32)
    out = out + b.astype(jnp.float32)
    new_hist = xp[:, -(k - 1) :, :] if k > 1 else history
    return out.astype(x.dtype), new_hist


def _scan_chunk(h0: jnp.ndarray, log_decay: jnp.ndarray, inp: jnp.ndarray):
    """Associative scan of h_t = exp(log_decay_t) * h_{t-1} + inp_t over a chunk.

    h0: (B, di, N); log_decay/inp: (B, c, di, N). Returns (h_last, h_all).
    """

    def combine(a, b):
        (la, ua), (lb, ub) = a, b
        return la + lb, jnp.exp(lb) * ua + ub

    ls, us = jax.lax.associative_scan(combine, (log_decay, inp), axis=1)
    h_all = jnp.exp(ls) * h0[:, None] + us  # prefix decay applied to carry-in
    return h_all[:, -1], h_all


def _chunked(t, bsz, n_chunks, chunk, extra_dims):
    return jnp.moveaxis(t.reshape(bsz, n_chunks, chunk, *extra_dims), 1, 0)


def _selective_scan_fwd_inner(x, dt, a, b_t, c_t, d_skip, h0, *, chunk: int):
    bsz, s, di = x.shape
    n = a.shape[-1]
    n_chunks = s // chunk
    xf = x.astype(jnp.float32)
    dtf = dt.astype(jnp.float32)
    x_ch = _chunked(xf, bsz, n_chunks, chunk, (di,))
    dt_ch = _chunked(dtf, bsz, n_chunks, chunk, (di,))
    b_ch = _chunked(b_t.astype(jnp.float32), bsz, n_chunks, chunk, (n,))
    c_ch = _chunked(c_t.astype(jnp.float32), bsz, n_chunks, chunk, (n,))
    af = a.astype(jnp.float32)

    def body(h, operand):
        x_i, dt_i, b_i, c_i = operand
        log_decay = dt_i[..., None] * af                                  # (B, c, di, N)
        inp = (dt_i * x_i)[..., None] * b_i[:, :, None, :]                # (B, c, di, N)
        h_last, h_all = _scan_chunk(h, log_decay, inp)
        y_i = jnp.einsum("bcdn,bcn->bcd", h_all, c_i)
        return h_last, (y_i, h)                                           # save chunk-boundary h only

    h_final, (y, h_bounds) = jax.lax.scan(body, h0.astype(jnp.float32), (x_ch, dt_ch, b_ch, c_ch))
    y = jnp.moveaxis(y, 0, 1).reshape(bsz, s, di)
    y = y + xf * d_skip.astype(jnp.float32)
    return y.astype(x.dtype), h_final, h_bounds


@functools.partial(jax.custom_vjp, nondiff_argnums=(7,))
def selective_scan(x, dt, a, b_t, c_t, d_skip, h0, chunk: int):
    """x, dt: (B, S, di); a: (di, N); b_t, c_t: (B, S, N); h0: (B, di, N).

    Returns (y: (B, S, di), h_final). Hand-written VJP: differentiating
    through the chunked associative scan makes jax save every scan-tree level
    (9 levels x 8 chunks x ~270 MB for jamba — 19 GB *per layer*). The custom
    backward stores only per-chunk boundary states and replays each chunk,
    using the reverse linear recurrence dh_t = g_t + A_{t+1} (.) dh_{t+1}.
    """
    chunk = _usable_chunk(x.shape[1], chunk)
    y, h_final, _ = _selective_scan_fwd_inner(x, dt, a, b_t, c_t, d_skip, h0, chunk=chunk)
    return y, h_final


def _usable_chunk(s: int, pref: int) -> int:
    """Largest divisor of s that is <= pref."""
    if s <= pref:
        return s
    for c in range(pref, 0, -1):
        if s % c == 0:
            return c
    return 1


def _selective_scan_fwd(x, dt, a, b_t, c_t, d_skip, h0, chunk):
    chunk = _usable_chunk(x.shape[1], chunk)
    y, h_final, h_bounds = _selective_scan_fwd_inner(x, dt, a, b_t, c_t, d_skip, h0, chunk=chunk)
    return (y, h_final), (x, dt, a, b_t, c_t, d_skip, h0, h_bounds)


def _selective_scan_bwd(chunk, res, cts):
    x, dt, a, b_t, c_t, d_skip, h0, h_bounds = res
    dy, dh_final = cts
    bsz, s, di = x.shape
    n = a.shape[-1]
    chunk = _usable_chunk(s, chunk)
    n_chunks = s // chunk
    xf = x.astype(jnp.float32)
    dtf = dt.astype(jnp.float32)
    af = a.astype(jnp.float32)
    dyf = dy.astype(jnp.float32)

    x_ch = _chunked(xf, bsz, n_chunks, chunk, (di,))
    dt_ch = _chunked(dtf, bsz, n_chunks, chunk, (di,))
    b_ch = _chunked(b_t.astype(jnp.float32), bsz, n_chunks, chunk, (n,))
    c_ch = _chunked(c_t.astype(jnp.float32), bsz, n_chunks, chunk, (n,))
    dy_ch = _chunked(dyf, bsz, n_chunks, chunk, (di,))

    def body(carry, operand):
        dh_next_scaled, da_acc = carry                                    # (B, di, N), (di, N)
        x_i, dt_i, b_i, c_i, dy_i, h_in = operand
        log_decay = dt_i[..., None] * af                                  # (B, c, di, N)
        inp = (dt_i * x_i)[..., None] * b_i[:, :, None, :]
        _, h_all = _scan_chunk(h_in, log_decay, inp)                      # replay forward
        h_prev = jnp.concatenate([h_in[:, None], h_all[:, :-1]], axis=1)  # h_{t-1}

        # reverse recurrence: dh_t = g_t + A_{t+1} (.) dh_{t+1}
        g = dy_i[..., None] * c_i[:, :, None, :]                          # (B, c, di, N)
        g_rev = g[:, ::-1]
        logA_rev = log_decay[:, ::-1]
        # coefficients: tau=0 -> already-scaled carry; tau>=1 -> logA_{c-tau}
        ltilde = jnp.concatenate(
            [jnp.zeros_like(logA_rev[:, :1]), logA_rev[:, : chunk - 1]], axis=1)
        _, dh_rev = _scan_chunk(dh_next_scaled, ltilde, g_rev)
        dh = dh_rev[:, ::-1]                                              # (B, c, di, N)

        # u_t = (dt*x) B ; A_t = exp(dt a)
        du = dh
        dA = dh * h_prev
        dlogA = dA * jnp.exp(log_decay)
        ddtx = jnp.einsum("bcdn,bcn->bcd", du, b_i)
        db_i = jnp.einsum("bcdn,bcd->bcn", du, dt_i * x_i)
        dc_i = jnp.einsum("bcdn,bcd->bcn", h_all, dy_i)
        ddt_i = ddtx * x_i + jnp.einsum("bcdn,dn->bcd", dlogA, af)
        dx_i = ddtx * dt_i
        da_acc = da_acc + jnp.einsum("bcdn,bcd->dn", dlogA, dt_i)

        new_carry = jnp.exp(log_decay[:, 0]) * dh[:, 0]                   # A_0 (.) dh_0
        return (new_carry, da_acc), (dx_i, ddt_i, db_i, dc_i)

    # varying-typed zeros (shard_map vma): union the batch-varying axes from
    # dy with the weight-varying axes from a
    carry0 = (dh_final.astype(jnp.float32), af * 0.0 + dyf.ravel()[0] * 0.0)
    # iterate chunks in reverse
    rev = lambda t: t[::-1]
    (dh0, da), (dx_c, ddt_c, db_c, dc_c) = jax.lax.scan(
        body, carry0,
        (rev(x_ch), rev(dt_ch), rev(b_ch), rev(c_ch), rev(dy_ch), rev(h_bounds)))

    def unchunk(t, extra):
        return jnp.moveaxis(t[::-1], 0, 1).reshape(bsz, s, *extra)

    dx = unchunk(dx_c, (di,)) + dyf * d_skip.astype(jnp.float32)
    ddt = unchunk(ddt_c, (di,))
    db = unchunk(db_c, (n,))
    dc = unchunk(dc_c, (n,))
    dd = jnp.einsum("bsd,bsd->d", dyf, xf)
    return (dx.astype(x.dtype), ddt.astype(dt.dtype), da.astype(a.dtype),
            db.astype(b_t.dtype), dc.astype(c_t.dtype), dd.astype(d_skip.dtype),
            dh0.astype(h0.dtype))


selective_scan.defvjp(_selective_scan_fwd, _selective_scan_bwd)


class SSMCache(NamedTuple):
    conv: jnp.ndarray  # (B, d_conv-1, d_inner)
    h: jnp.ndarray     # (B, d_inner, d_state)


def init_ssm_cache(batch: int, cfg: SSMConfig, dtype=jnp.float32) -> SSMCache:
    return SSMCache(
        conv=jnp.zeros((batch, cfg.d_conv - 1, cfg.d_inner), dtype),
        h=jnp.zeros((batch, cfg.d_inner, cfg.d_state), jnp.float32),
    )


def _ssm_inner(p, x, cfg: SSMConfig, conv_hist, h0):
    """Shared forward core. x: (B, S, D)."""
    xz = jnp.einsum("bsd,de->bse", x, p["in_proj"].astype(x.dtype))
    xb, z = jnp.split(xz, 2, axis=-1)
    xb = constrain(xb, "batch", "seq", "d_inner")
    xb, new_hist = _causal_conv(xb, p["conv_w"], p["conv_b"], conv_hist)
    xb = jax.nn.silu(xb)

    proj = jnp.einsum("bsd,dr->bsr", xb, p["x_proj"].astype(xb.dtype))
    r = cfg.rank
    dt_lr, b_t, c_t = jnp.split(proj, [r, r + cfg.d_state], axis=-1)
    dt = jnp.einsum("bsr,rd->bsd", dt_lr, p["dt_proj"].astype(xb.dtype))
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"].astype(jnp.float32))
    a = -jnp.exp(p["a_log"].astype(jnp.float32))

    y, h_final = selective_scan(xb, dt, a, b_t, c_t, p["d_skip"], h0, cfg.chunk)
    y = y * jax.nn.silu(z)
    out = jnp.einsum("bsd,de->bse", y, p["out_proj"].astype(x.dtype))
    return constrain(out, "batch", "seq", "act_embed"), new_hist, h_final


def ssm_forward(p, x: jnp.ndarray, cfg: SSMConfig) -> jnp.ndarray:
    y = _ssm_explicit_tp(p, x, cfg)
    if y is not None:
        return y
    bsz = x.shape[0]
    h0 = jnp.zeros((bsz, cfg.d_inner, cfg.d_state), jnp.float32)
    out, _, _ = _ssm_inner(p, x, cfg, None, h0)
    return out


def _ssm_explicit_tp(p, x: jnp.ndarray, cfg: SSMConfig):
    """Explicit Megatron-SP tensor parallelism for the mamba mixer.

    SSM channels are independent across d_inner, so the whole mixer —
    in-proj, conv, selective scan, gate, out-proj — runs channel-sharded
    inside one shard_map: one bf16 all-gather of the SP activations in, one
    small fp32 psum for the x_proj low-rank bottleneck (dt/B/C are shared
    across channels), one bf16 reduce-scatter of the out-proj partial sums.
    Returns None when shapes don't allow it (GSPMD fallback)."""
    import math as _math

    from ..sharding.logical import current
    from jax.sharding import PartitionSpec as P

    ctx = current()
    if ctx is None or "model" not in ctx.mesh.axis_names:
        return None
    mesh = ctx.mesh
    tp = mesh.shape["model"]
    bsz, s, d = x.shape
    di = cfg.d_inner
    if tp == 1 or s % tp or di % tp:
        return None
    batch_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    if bsz % _math.prod(mesh.shape[a] for a in batch_axes):
        return None

    di_l = di // tp
    r = cfg.rank
    n = cfg.d_state
    dtype = x.dtype
    xspec = P(batch_axes, "model", None)

    def body(x_l, w):
        x_full = jax.lax.all_gather(x_l, "model", axis=1, tiled=True)      # (B_l, S, D)
        xz = jnp.einsum("bsd,de->bse", x_full, w["in_proj"].astype(dtype)) # (B_l, S, 2*di_l)
        xb, z = jnp.split(xz, 2, axis=-1)
        xb, _ = _causal_conv(xb, w["conv_w"], w["conv_b"], None)
        xb = jax.nn.silu(xb)
        # low-rank dt/B/C bottleneck: partial over local channels -> psum
        proj = jnp.einsum("bsd,dr->bsr", xb.astype(jnp.float32),
                          w["x_proj"].astype(jnp.float32))
        proj = jax.lax.psum(proj, "model")                                 # (B_l, S, r+2N)
        dt_lr, b_t, c_t = jnp.split(proj, [r, r + n], axis=-1)
        dt = jnp.einsum("bsr,rd->bsd", dt_lr.astype(xb.dtype), w["dt_proj"].astype(xb.dtype))
        dt = jax.nn.softplus(dt.astype(jnp.float32) + w["dt_bias"].astype(jnp.float32))
        # vma plumbing: weights entering the custom-vjp scan must carry the
        # full varying axes (their cotangents inherit batch-variation; the
        # pcast-via-zero makes shard_map's transpose insert the 'data' psum)
        vz = (x_full.ravel()[0] * 0.0).astype(jnp.float32)
        a = -jnp.exp(w["a_log"].astype(jnp.float32)) + vz
        dsk = w["d_skip"].astype(jnp.float32) + vz
        h0 = (xb[:, :1, :, None] * 0.0).astype(jnp.float32) * jnp.zeros((1, 1, 1, n))
        h0 = jnp.squeeze(h0, 1)                                            # varying zeros (B_l, di_l, N)
        bt = b_t.astype(xb.dtype) + vz.astype(xb.dtype)   # vma: see `a` above
        ct = c_t.astype(xb.dtype) + vz.astype(xb.dtype)
        y, _ = selective_scan(xb, dt, a, bt, ct, dsk, h0, cfg.chunk)
        y = y * jax.nn.silu(z)
        out_part = jnp.einsum("bsd,de->bse", y, w["out_proj"].astype(dtype)).astype(dtype)
        return jax.lax.psum_scatter(out_part, "model", scatter_dimension=1, tiled=True)

    # weight specs: channel-sharded over 'model' on the d_inner dim; the
    # shard_map entry performs the (bf16) FSDP gather over 'data' where needed
    wspecs = {
        "in_proj": P(None, "model"),        # (d, 2*di): split gives both halves local
        "conv_w": P("model", None),
        "conv_b": P("model"),
        "x_proj": P("model", None),
        "dt_proj": P(None, "model"),
        "dt_bias": P("model"),
        "a_log": P("model", None),
        "d_skip": P("model"),
        "out_proj": P("model", None),
    }
    # in_proj columns: (x | z) halves must each be channel-sharded — the
    # natural layout (d, 2*di) sharded on dim 1 splits into x-half and z-half
    # only if each half is contiguous per shard; reorder columns so shard k
    # holds [x_k | z_k].
    w = dict(p)
    ip = p["in_proj"]
    xw, zw = ip[:, :di], ip[:, di:]
    xw = xw.reshape(d, tp, di_l)
    zw = zw.reshape(d, tp, di_l)
    w["in_proj"] = jnp.concatenate([xw, zw], axis=2).reshape(d, 2 * di)

    return shard_map(
        body, mesh=mesh,
        in_specs=(xspec, {k: wspecs[k] for k in w}),
        out_specs=xspec,
    )(x, w)


def ssm_decode(p, x: jnp.ndarray, cache: SSMCache, cfg: SSMConfig) -> Tuple[jnp.ndarray, SSMCache]:
    """x: (B, 1, D) — O(1) state-space decode step."""
    out, new_hist, h_final = _ssm_inner(p, x, cfg, cache.conv, cache.h)
    return out, SSMCache(conv=new_hist, h=h_final)
