"""Unified decoder/encoder backbone covering all 10 assigned architectures.

One config describes a *period* of heterogeneous layer slots (attention or
mamba mixer x dense/MoE/absent FFN); the model scans over ``n_layers /
period`` repetitions with per-slot parameters stacked along a leading
'layers' axis — keeping the HLO O(period), not O(depth), which is what makes
95-layer dry-runs compile fast and cheap.

Covers: dense GQA (command-r, deepseek, smollm, qwen1.5), MoE (qwen3-moe,
olmoe), SSM (falcon-mamba), hybrid SSM+attn+MoE (jamba), encoder-only
(hubert), VLM backbone (internvl2), plus the paper's own GPT-small/medium and
ViT variants.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from ..sharding.logical import constrain
from .attention import (
    AttnConfig,
    attention_decode,
    attention_forward,
    attention_specs,
    init_kv_cache,
)
from .common import (
    ParamSpec,
    init_params,
    abstract_params,
    layer_norm,
    meta_tree,
    mitchell_residual_init,
    normal_init,
    ones_init,
    rms_norm,
    stack_specs,
    torch_default_init,
)
from .mlp_moe import MoEConfig, mlp_forward, mlp_specs, moe_forward, moe_specs
from .ssm import SSMConfig, init_ssm_cache, ssm_decode, ssm_forward, ssm_specs


@dataclasses.dataclass(frozen=True)
class LayerSlot:
    mixer: Optional[str]  # 'attn' | 'mamba' | None
    ffn: Optional[str]    # 'dense' | 'moe' | None


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0                    # 0 -> d_model // n_heads
    pattern: Tuple[LayerSlot, ...] = (LayerSlot("attn", "dense"),)
    causal: bool = True
    # embeddings / head
    tie_embeddings: bool = True
    pos: str = "rope"                    # 'rope' | 'learned' | 'none'
    max_position: int = 8192             # learned-pos table size
    embed_inputs: bool = True            # False: model consumes (B, S, D) embeddings (audio stub)
    extra_embed_len: int = 0             # VLM: prepended frontend embeddings
    input_proj_dim: int = 0              # >0: learned projection from raw patch/frame features
    # norms / mlp flavor
    norm: str = "rmsnorm"                # 'rmsnorm' | 'layernorm'
    gated_mlp: bool = True
    qkv_bias: bool = False
    # MoE
    n_experts: int = 0
    top_k: int = 0
    # SSM
    ssm_state: int = 16
    ssm_expand: int = 2
    ssm_conv: int = 4
    ssm_chunk: int = 256
    # numerics
    dtype: Any = jnp.bfloat16            # activation/compute dtype
    param_dtype: Any = jnp.float32
    remat: bool = True
    init_scheme: str = "mitchell"        # 'mitchell' | 'torch_default'
    attn_kv_block: int = 1024
    attn_dense_threshold: int = 2048
    kv_quant: bool = False               # int8 KV cache (serving): halves cache HBM
    logical_batch_axes: Tuple[str, ...] = ("batch",)
    # per-arch logical->mesh rule overrides as (name, axes) pairs; e.g. small
    # models repurpose the 'model' axis as extra data parallelism
    sharding_overrides: Tuple[Tuple[str, Any], ...] = ()

    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def n_periods(self) -> int:
        assert self.n_layers % len(self.pattern) == 0, (self.n_layers, len(self.pattern))
        return self.n_layers // len(self.pattern)

    def attn_cfg(self) -> AttnConfig:
        return AttnConfig(
            d_model=self.d_model, n_heads=self.n_heads, n_kv_heads=self.n_kv_heads,
            head_dim=self.hd, causal=self.causal, rope=(self.pos == "rope"),
            qkv_bias=self.qkv_bias, kv_block=self.attn_kv_block,
            dense_threshold=self.attn_dense_threshold,
        )

    def ssm_cfg(self) -> SSMConfig:
        return SSMConfig(
            d_model=self.d_model, d_inner=self.ssm_expand * self.d_model,
            d_state=self.ssm_state, d_conv=self.ssm_conv, chunk=self.ssm_chunk,
        )

    def moe_cfg(self) -> MoEConfig:
        return MoEConfig(
            n_experts=self.n_experts, top_k=self.top_k, d_model=self.d_model,
            d_ff=self.d_ff, gated=self.gated_mlp,
        )

    def param_count(self, params=None) -> int:
        tree = params if params is not None else abstract_params(self.specs())
        return sum(int(jnp.size(jax.ShapeDtypeStruct(p.shape, p.dtype))) if hasattr(p, "shape") else 0
                   for p in jax.tree.leaves(tree))

    # ------------------------------------------------------------------
    # Parameter specification
    # ------------------------------------------------------------------

    def _inits(self):
        if self.init_scheme == "torch_default":
            w = torch_default_init()
            return w, w, w
        w = normal_init(0.02)
        if self.init_scheme == "normal":
            # mitchell minus the 1/depth residual scaling (ablation)
            return w, w, normal_init(0.02)
        resid = mitchell_residual_init(0.02, self.n_layers)
        return w, resid, normal_init(0.02)

    def _norm_specs(self, prefix_role: str = "norm"):
        d = self.d_model
        specs = {"scale": ParamSpec((d,), ("embed",), "norm",
                                    ones_init(), dtype=self.param_dtype)}
        return specs

    def slot_specs(self, slot: LayerSlot) -> Dict[str, Any]:
        w_init, resid_init, emb_init = self._inits()
        dt = self.param_dtype
        specs: Dict[str, Any] = {}

        def with_dtype(tree):
            return jax.tree.map(
                lambda s: dataclasses.replace(s, dtype=dt),
                tree, is_leaf=lambda x: isinstance(x, ParamSpec),
            )

        if slot.mixer == "attn":
            specs["mixer_norm"] = self._norm_specs()
            specs["attn"] = with_dtype(attention_specs(
                self.d_model, self.n_heads, self.n_kv_heads, self.hd,
                qkv_bias=self.qkv_bias, o_init=resid_init, w_init=w_init))
        elif slot.mixer == "mamba":
            specs["mixer_norm"] = self._norm_specs()
            specs["ssm"] = with_dtype(ssm_specs(self.ssm_cfg(), w_init=w_init, out_init=resid_init))
        if slot.ffn == "dense":
            specs["ffn_norm"] = self._norm_specs()
            specs["mlp"] = with_dtype(mlp_specs(self.d_model, self.d_ff,
                                                gated=self.gated_mlp, w_init=w_init, down_init=resid_init))
        elif slot.ffn == "moe":
            specs["ffn_norm"] = self._norm_specs()
            specs["moe"] = with_dtype(moe_specs(self.moe_cfg(), w_init=w_init, down_init=resid_init))
        return specs

    def specs(self) -> Dict[str, Any]:
        w_init, resid_init, emb_init = self._inits()
        dt = self.param_dtype
        specs: Dict[str, Any] = {}
        if self.embed_inputs:
            specs["embed"] = ParamSpec((self.vocab_size, self.d_model), ("vocab", "embed"),
                                       "token_embedding", emb_init,
                                       fan_in=("vocab",), fan_out=("embed",), dtype=dt)
        if self.pos == "learned":
            specs["pos_embed"] = ParamSpec((self.max_position, self.d_model), ("pos", "embed"),
                                           "pos_embedding", emb_init, dtype=dt)
        if self.input_proj_dim:
            specs["input_proj"] = ParamSpec((self.input_proj_dim, self.d_model), ("patch", "embed"),
                                            "patch_embed", w_init,
                                            fan_in=("patch",), fan_out=("embed",), dtype=dt)
        blocks = {}
        for i, slot in enumerate(self.pattern):
            blocks[f"slot_{i}"] = stack_specs(self.slot_specs(slot), self.n_periods)
        specs["blocks"] = blocks
        specs["final_norm"] = self._norm_specs()
        if not self.tie_embeddings or not self.embed_inputs:
            specs["lm_head"] = ParamSpec((self.d_model, self.vocab_size), ("embed", "vocab"),
                                         "lm_head", w_init,
                                         fan_in=("embed",), fan_out=("vocab",), dtype=dt)
        return specs

    def init(self, key: jax.Array):
        spec = self.specs()
        return init_params(spec, key), meta_tree(spec)

    def abstract(self):
        spec = self.specs()
        return abstract_params(spec), meta_tree(spec)


# ---------------------------------------------------------------------------
# Forward passes
# ---------------------------------------------------------------------------


def _norm(cfg: ModelConfig, p, x):
    if cfg.norm == "rmsnorm":
        return rms_norm(x, p["scale"])
    return layer_norm(x, p["scale"], None)


def _slot_forward(cfg: ModelConfig, slot: LayerSlot, p, x):
    """One layer slot (mixer + ffn residual blocks). Returns (x, aux_loss)."""
    aux = jnp.zeros((), jnp.float32)
    if slot.mixer == "attn":
        x = x + attention_forward(p["attn"], _norm(cfg, p["mixer_norm"], x), cfg.attn_cfg())
    elif slot.mixer == "mamba":
        x = x + ssm_forward(p["ssm"], _norm(cfg, p["mixer_norm"], x), cfg.ssm_cfg())
    if slot.ffn == "dense":
        x = x + mlp_forward(p["mlp"], _norm(cfg, p["ffn_norm"], x), gated=cfg.gated_mlp)
    elif slot.ffn == "moe":
        y, a = moe_forward(p["moe"], _norm(cfg, p["ffn_norm"], x), cfg.moe_cfg())
        x = x + y
        aux = aux + a
    return constrain(x, "batch", "seq_sp", "act_embed"), aux


def _embed(cfg: ModelConfig, params, batch: Dict[str, jnp.ndarray]) -> jnp.ndarray:
    if cfg.embed_inputs:
        tokens = batch["tokens"]
        x = jnp.take(params["embed"], tokens, axis=0).astype(cfg.dtype)
        if cfg.pos == "learned":
            s = tokens.shape[1]
            x = x + params["pos_embed"][:s][None].astype(cfg.dtype)
        if cfg.extra_embed_len:
            ve = batch["frontend_embeds"].astype(cfg.dtype)  # (B, P, D) from stub frontend
            x = jnp.concatenate([ve, x], axis=1)
    elif cfg.input_proj_dim:
        x = jnp.einsum("bsp,pd->bsd", batch["patches"].astype(cfg.dtype),
                       params["input_proj"].astype(cfg.dtype))
        if cfg.pos == "learned":
            s = x.shape[1]
            x = x + params["pos_embed"][:s][None].astype(cfg.dtype)
    else:
        x = batch["frontend_embeds"].astype(cfg.dtype)
    return constrain(x, "batch", "seq_sp", "act_embed")


def _unembed(cfg: ModelConfig, params, x: jnp.ndarray) -> jnp.ndarray:
    # Keep the head weight vocab-sharded (TP) and gather x's sequence dim
    # instead: constraining logits along seq_sp would force GSPMD to fully
    # replicate the (vocab, embed) table in fp32 — measured 3x3.2 GiB/device
    # for deepseek-67b. With vocab@model, CE's logsumexp runs on sharded
    # logits and the tied-embedding gradient reduces to a reduce-scatter.
    x = constrain(x, "batch", "seq", "act_embed")
    if cfg.tie_embeddings and cfg.embed_inputs:
        logits = jnp.einsum("bsd,vd->bsv", x, params["embed"].astype(cfg.dtype))
    else:
        logits = jnp.einsum("bsd,dv->bsv", x, params["lm_head"].astype(cfg.dtype))
    return constrain(logits, "batch", "seq", "vocab")


def forward(cfg: ModelConfig, params, batch: Dict[str, jnp.ndarray]):
    """Training/prefill forward. batch: {'tokens': (B,S) int32, ...}.

    Returns (logits (B, S_total, vocab) in cfg.dtype, aux_loss scalar).
    """
    x = _embed(cfg, params, batch)

    period = len(cfg.pattern)

    def period_body(carry, period_params):
        x, aux = carry
        for i, slot in enumerate(cfg.pattern):
            f = functools.partial(_slot_forward, cfg, slot)
            if cfg.remat:
                f = jax.checkpoint(f, policy=jax.checkpoint_policies.nothing_saveable)
            x, a = f(period_params[f"slot_{i}"], x)
            aux = aux + a
        return (x, aux), None

    (x, aux), _ = jax.lax.scan(period_body, (x, jnp.zeros((), jnp.float32)), params["blocks"])
    x = _norm(cfg, params["final_norm"], x)
    logits = _unembed(cfg, params, x)
    return logits, aux


# ---------------------------------------------------------------------------
# Decode (serving) path
# ---------------------------------------------------------------------------


class DecodeCache(NamedTuple):
    slots: Dict[str, Any]   # per-slot stacked caches (KVCache | SSMCache)
    step: jnp.ndarray       # tokens generated so far (int32)


def init_decode_cache(cfg: ModelConfig, batch: int, max_seq: int, dtype=jnp.bfloat16) -> DecodeCache:
    slots: Dict[str, Any] = {}
    for i, slot in enumerate(cfg.pattern):
        if slot.mixer == "attn":
            c = init_kv_cache(batch, max_seq, cfg.n_kv_heads, cfg.hd, dtype, quant=cfg.kv_quant)
        elif slot.mixer == "mamba":
            c = init_ssm_cache(batch, cfg.ssm_cfg(), dtype)
        else:
            continue
        # stack over periods
        slots[f"slot_{i}"] = jax.tree.map(
            lambda a: jnp.broadcast_to(a[None], (cfg.n_periods,) + a.shape), c
        )
    return DecodeCache(slots=slots, step=jnp.zeros((), jnp.int32))


def abstract_decode_cache(cfg: ModelConfig, batch: int, max_seq: int, dtype=jnp.bfloat16):
    return jax.eval_shape(lambda: init_decode_cache(cfg, batch, max_seq, dtype))


def decode_step(cfg: ModelConfig, params, cache: DecodeCache, tokens: jnp.ndarray):
    """One new token per sequence. tokens: (B, 1) int32.

    The caches were pre-filled to ``cache.step`` positions (for the dry-run
    cells the cache is abstract at its full seq_len). Returns (logits (B, 1,
    vocab), new cache).
    """
    x = jnp.take(params["embed"], tokens, axis=0).astype(cfg.dtype) if cfg.embed_inputs else tokens
    if cfg.pos == "learned":
        x = x + jax.lax.dynamic_slice_in_dim(params["pos_embed"], cache.step, 1, 0)[None].astype(cfg.dtype)
    x = constrain(x, "batch", "seq", "act_embed")

    # Decode caches ride in the scan CARRY, updated in place with
    # dynamic_update_index: carried buffers alias across loop iterations.
    # Alternatives measured on the qwen1.5-32b decode_32k cell (CPU-backend
    # buffer assignment): xs->ys scan = 61 GiB, fully unrolled layer loop =
    # 147 GiB, carry = best (deepseek-67b decode fits at 7.6 GiB).
    def period_body(carry, operand):
        x, slot_caches = carry
        period_params, idx = operand
        for i, slot in enumerate(cfg.pattern):
            key = f"slot_{i}"
            p = period_params[key]
            if slot.mixer in ("attn", "mamba"):
                c = jax.tree.map(lambda buf: jax.lax.dynamic_index_in_dim(buf, idx, 0, keepdims=False),
                                 slot_caches[key])
                if slot.mixer == "attn":
                    y, nc = attention_decode(p["attn"], _norm(cfg, p["mixer_norm"], x), c, cfg.attn_cfg())
                else:
                    y, nc = ssm_decode(p["ssm"], _norm(cfg, p["mixer_norm"], x), c, cfg.ssm_cfg())
                x = x + y
                slot_caches = dict(slot_caches)
                slot_caches[key] = jax.tree.map(
                    lambda buf, new: jax.lax.dynamic_update_index_in_dim(
                        buf, new.astype(buf.dtype), idx, 0),
                    slot_caches[key], nc)
            if slot.ffn == "dense":
                x = x + mlp_forward(p["mlp"], _norm(cfg, p["ffn_norm"], x), gated=cfg.gated_mlp)
            elif slot.ffn == "moe":
                y, _ = moe_forward(p["moe"], _norm(cfg, p["ffn_norm"], x), cfg.moe_cfg())
                x = x + y
        return (x, slot_caches), None

    idxs = jnp.arange(cfg.n_periods)
    (x, new_slot_caches), _ = jax.lax.scan(period_body, (x, cache.slots), (params["blocks"], idxs))
    x = _norm(cfg, params["final_norm"], x)
    logits = _unembed(cfg, params, x)
    return logits, DecodeCache(slots=new_slot_caches, step=cache.step + 1)


# ---------------------------------------------------------------------------
# Paged decode (serving fast path)
#
# KV lives in per-slot page *pools* shared by every in-flight request and
# addressed through a per-slot-row page table (repro.serve.kvpool owns the
# host-side allocation; repro.kernels.paged_attention does the ragged
# reduction). Unlike DecodeCache there is no per-request (B, S_max) buffer —
# admitting or retiring a request costs zero device reallocation, which is
# what makes continuous batching (repro.serve.scheduler) a pure host-side
# bookkeeping loop over fixed-shape jit calls.
# ---------------------------------------------------------------------------


class PagedState(NamedTuple):
    """Device state for the paged decode path.

    pools:   {'slot_i': (n_periods, n_pages, page, 2*KV, hd)} per attn slot
    table:   (B, max_pages) int32 page ids; entry 0 = reserved null page
    lengths: (B,) int32 positions already stored per batch row
    active:  (B,) bool — inactive rows write to the null page and attend
             over 0 positions (their logits are garbage nobody samples)
    """

    pools: Dict[str, jnp.ndarray]
    table: jnp.ndarray
    lengths: jnp.ndarray
    active: jnp.ndarray


def supports_paged(cfg: ModelConfig) -> bool:
    """The paged fast path covers token-in/token-out attention-only stacks.
    SSM/hybrid mixers carry recurrent (not positional) state and int8 KV
    pages are future work, so those fall back to the legacy decode loop."""
    return (cfg.embed_inputs and not cfg.kv_quant
            and all(s.mixer in ("attn", None) for s in cfg.pattern)
            and any(s.mixer == "attn" for s in cfg.pattern))


def init_paged_pools(cfg: ModelConfig, n_pages: int, page_size: int,
                     dtype=jnp.bfloat16) -> Dict[str, jnp.ndarray]:
    """One fused-layout page pool per attention slot, stacked over periods.
    Page 0 of every pool is the reserved null page (scatter target for
    inactive/padded writes; never read because those rows report length 0)."""
    pools: Dict[str, jnp.ndarray] = {}
    for i, slot in enumerate(cfg.pattern):
        if slot.mixer == "attn":
            pools[f"slot_{i}"] = jnp.zeros(
                (cfg.n_periods, n_pages, page_size, 2 * cfg.n_kv_heads, cfg.hd),
                dtype)
    return pools


def paged_decode_step(cfg: ModelConfig, params, state: PagedState,
                      tokens: jnp.ndarray, *, attn_impl: str = "kernel"):
    """One new token for every active batch row. tokens: (B, 1) int32.

    Returns (logits (B, 1, vocab), ok (B,) bool, new PagedState) — lengths
    advance only on active rows, so a freshly-retired slot can sit idle at
    no cost. Pools ride in the scan carry exactly like DecodeCache buffers
    (aliasing across periods keeps live memory at one pool set, not one per
    period).

    ``ok`` is the **logit health tap**: per-row all-finite flags computed
    on-device, so the serving engine can detect a poisoned slot (NaN/Inf
    logits) without ever scanning the vocab axis on the host — the same
    in-pass health-stat discipline the guarded train step uses. A row that
    taps False is retired with ``reason="nan"`` instead of sampling garbage.

    ``attn_impl="ref"`` routes attention through the dense
    :func:`repro.kernels.paged_attention.paged_attention_ref` path — the
    engine's per-step graceful degradation when the Pallas launch fails.
    """
    from .attention import attention_paged_decode

    x = jnp.take(params["embed"], tokens, axis=0).astype(cfg.dtype)
    if cfg.pos == "learned":
        posv = jnp.clip(state.lengths, 0, cfg.max_position - 1)
        x = x + jnp.take(params["pos_embed"], posv, axis=0)[:, None].astype(cfg.dtype)
    x = constrain(x, "batch", "seq", "act_embed")

    def period_body(carry, operand):
        x, pools = carry
        period_params, idx = operand
        for i, slot in enumerate(cfg.pattern):
            key = f"slot_{i}"
            p = period_params[key]
            if slot.mixer == "attn":
                pool = jax.lax.dynamic_index_in_dim(pools[key], idx, 0, keepdims=False)
                y, pool = attention_paged_decode(
                    p["attn"], _norm(cfg, p["mixer_norm"], x), pool,
                    state.table, state.lengths, state.active, cfg.attn_cfg(),
                    use_ref=attn_impl == "ref")
                x = x + y
                pools = dict(pools)
                pools[key] = jax.lax.dynamic_update_index_in_dim(pools[key], pool, idx, 0)
            if slot.ffn == "dense":
                x = x + mlp_forward(p["mlp"], _norm(cfg, p["ffn_norm"], x), gated=cfg.gated_mlp)
            elif slot.ffn == "moe":
                y, _ = moe_forward(p["moe"], _norm(cfg, p["ffn_norm"], x), cfg.moe_cfg())
                x = x + y
        return (x, pools), None

    idxs = jnp.arange(cfg.n_periods)
    (x, pools), _ = jax.lax.scan(period_body, (x, state.pools), (params["blocks"], idxs))
    x = _norm(cfg, params["final_norm"], x)
    logits = _unembed(cfg, params, x)
    ok = jnp.all(jnp.isfinite(logits.astype(jnp.float32)), axis=(1, 2))
    return logits, ok, PagedState(
        pools=pools, table=state.table,
        lengths=state.lengths + state.active.astype(jnp.int32),
        active=state.active)


def paged_prefill_chunk(cfg: ModelConfig, params, pools: Dict[str, jnp.ndarray],
                        table_row: jnp.ndarray, pos0, n_valid,
                        tokens: jnp.ndarray, *, attn_impl: str = "kernel"):
    """Prefill one chunk of one request's prompt through the paged kernel.

    tokens: (1, C) int32 at absolute positions ``pos0 .. pos0 + C - 1``;
    chunk indices >= ``n_valid`` are padding (K/V routed to the null page).
    ``pos0`` / ``n_valid`` are traced scalars, so every chunk of every
    request reuses one jit executable. Returns
    (logits (1, C, vocab), ok () bool, pools); the caller samples the first
    generated token at chunk index ``n_valid - 1`` of the final chunk, and
    ``ok`` is the logit health tap for exactly that row (padding rows carry
    garbage nobody reads, so only the sampled row's finiteness matters).
    ``attn_impl="ref"`` degrades to the dense reference attention, as in
    :func:`paged_decode_step`.
    """
    from .attention import attention_paged_prefill

    c = tokens.shape[1]
    x = jnp.take(params["embed"], tokens, axis=0).astype(cfg.dtype)
    if cfg.pos == "learned":
        posv = jnp.clip(pos0 + jnp.arange(c), 0, cfg.max_position - 1)
        x = x + jnp.take(params["pos_embed"], posv, axis=0)[None].astype(cfg.dtype)
    x = constrain(x, "batch", "seq", "act_embed")

    def period_body(carry, operand):
        x, pools = carry
        period_params, idx = operand
        for i, slot in enumerate(cfg.pattern):
            key = f"slot_{i}"
            p = period_params[key]
            if slot.mixer == "attn":
                pool = jax.lax.dynamic_index_in_dim(pools[key], idx, 0, keepdims=False)
                y, pool = attention_paged_prefill(
                    p["attn"], _norm(cfg, p["mixer_norm"], x), pool,
                    table_row, pos0, n_valid, cfg.attn_cfg(),
                    use_ref=attn_impl == "ref")
                x = x + y
                pools = dict(pools)
                pools[key] = jax.lax.dynamic_update_index_in_dim(pools[key], pool, idx, 0)
            if slot.ffn == "dense":
                x = x + mlp_forward(p["mlp"], _norm(cfg, p["ffn_norm"], x), gated=cfg.gated_mlp)
            elif slot.ffn == "moe":
                y, _ = moe_forward(p["moe"], _norm(cfg, p["ffn_norm"], x), cfg.moe_cfg())
                x = x + y
        return (x, pools), None

    idxs = jnp.arange(cfg.n_periods)
    (x, pools), _ = jax.lax.scan(period_body, (x, pools), (params["blocks"], idxs))
    x = _norm(cfg, params["final_norm"], x)
    logits = _unembed(cfg, params, x)
    sampled = jax.lax.dynamic_index_in_dim(logits[0], n_valid - 1, 0,
                                           keepdims=False)
    ok = jnp.all(jnp.isfinite(sampled.astype(jnp.float32)))
    return logits, ok, pools
