"""GQA attention: training forward (chunked online-softmax), prefill, decode.

Long sequences (prefill_32k) cannot materialize (S, S) score matrices — at
32k that is 4 GB fp32 *per (batch, head)*. ``chunked_attention`` is a
flash-attention-style jnp formulation: lax.scan over KV blocks with a running
(max, sum, acc) online softmax, O(S * block) memory. XLA fuses it well on
TPU; a Pallas kernel would go here if attention were the paper's hot spot —
the paper's hot spot is the optimizer, which does get kernels
(``repro.kernels``).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

from ..sharding.logical import constrain, shard_map
from .common import ParamSpec, apply_rotary, rotary_embedding, zeros_init

NEG_INF = -1e30


def attention_specs(
    d_model: int,
    n_heads: int,
    n_kv_heads: int,
    head_dim: int,
    *,
    qkv_bias: bool = False,
    o_init,
    w_init,
):
    """Projection params stored 3-D: (embed, heads, head_dim) so per-head
    moment partitioning (Adam-mini) and head-stacked SNR dims are first-class."""
    specs = {
        "wq": ParamSpec((d_model, n_heads, head_dim), ("embed", "heads", "head_dim"), "attn_q",
                        w_init, fan_in=("embed",), fan_out=("heads", "head_dim")),
        "wk": ParamSpec((d_model, n_kv_heads, head_dim), ("embed", "kv_heads", "head_dim"), "attn_k",
                        w_init, fan_in=("embed",), fan_out=("kv_heads", "head_dim")),
        "wv": ParamSpec((d_model, n_kv_heads, head_dim), ("embed", "kv_heads", "head_dim"), "attn_v",
                        w_init, fan_in=("embed",), fan_out=("kv_heads", "head_dim")),
        "wo": ParamSpec((n_heads, head_dim, d_model), ("heads", "head_dim", "embed"), "attn_o",
                        o_init, fan_in=("heads", "head_dim"), fan_out=("embed",)),
    }
    if qkv_bias:
        specs["bq"] = ParamSpec((n_heads, head_dim), ("heads", "head_dim"), "attn_qkv_bias", zeros_init())
        specs["bk"] = ParamSpec((n_kv_heads, head_dim), ("kv_heads", "head_dim"), "attn_qkv_bias", zeros_init())
        specs["bv"] = ParamSpec((n_kv_heads, head_dim), ("kv_heads", "head_dim"), "attn_qkv_bias", zeros_init())
    return specs


def _project_qkv(p, x, rope_sincos, positions):
    """x: (B, S, D) -> q (B,S,H,hd), k/v (B,S,KV,hd)."""
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(x.dtype))
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"].astype(x.dtype))
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"].astype(x.dtype))
    if "bq" in p:
        q = q + p["bq"].astype(x.dtype)
        k = k + p["bk"].astype(x.dtype)
        v = v + p["bv"].astype(x.dtype)
    if rope_sincos is not None:
        sin, cos = rope_sincos
        q = apply_rotary(q, sin, cos)
        k = apply_rotary(k, sin, cos)
    q = constrain(q, "batch", "seq", "act_heads", None)
    k = constrain(k, "batch", "seq", "act_heads", None)
    v = constrain(v, "batch", "seq", "act_heads", None)
    return q, k, v


def _repeat_kv(k: jnp.ndarray, n_rep: int) -> jnp.ndarray:
    if n_rep == 1:
        return k
    b, s, kv, hd = k.shape
    return jnp.broadcast_to(k[:, :, :, None, :], (b, s, kv, n_rep, hd)).reshape(b, s, kv * n_rep, hd)


def dense_attention(q, k, v, *, causal: bool) -> jnp.ndarray:
    """Reference O(S^2)-memory attention (small S / oracle for tests)."""
    b, sq, h, hd = q.shape
    sk = k.shape[1]
    scale = 1.0 / jnp.sqrt(jnp.asarray(hd, jnp.float32))
    scores = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32), k.astype(jnp.float32)) * scale
    if causal:
        mask = jnp.tril(jnp.ones((sq, sk), bool), k=sk - sq)
        scores = jnp.where(mask[None, None], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", probs.astype(v.dtype), v)
    return out


def chunked_attention(q, k, v, *, causal: bool, kv_block: int = 1024) -> jnp.ndarray:
    """Online-softmax attention, O(S * kv_block) live memory.

    q: (B, Sq, H, hd); k, v: (B, Sk, H, hd). Scans KV blocks carrying
    (running max, running denom, running numerator).
    """
    b, sq, h, hd = q.shape
    sk = k.shape[1]
    kv_block = min(kv_block, sk)
    if sk % kv_block != 0:
        raise ValueError(f"seq {sk} not divisible by kv_block {kv_block}")
    n_blocks = sk // kv_block
    scale = 1.0 / jnp.sqrt(jnp.asarray(hd, jnp.float32))

    qf = q.astype(jnp.float32) * scale
    kb = k.reshape(b, n_blocks, kv_block, h, hd)
    vb = v.reshape(b, n_blocks, kv_block, h, hd)
    # scan over kv blocks: put block dim first
    kb = jnp.moveaxis(kb, 1, 0)  # (n, B, kv_block, H, hd)
    vb = jnp.moveaxis(vb, 1, 0)

    q_pos = jnp.arange(sq)[:, None]  # query positions (offset = sk - sq for self-attn suffix)
    q_abs = q_pos + (sk - sq)

    def body(carry, blk):
        m, l, acc = carry
        k_i, v_i, i = blk
        s = jnp.einsum("bqhd,bkhd->bhqk", qf, k_i.astype(jnp.float32))
        if causal:
            k_abs = i * kv_block + jnp.arange(kv_block)[None, :]
            mask = q_abs >= k_abs  # (Sq, kv_block)
            s = jnp.where(mask[None, None], s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + jnp.sum(p, axis=-1)
        acc_new = acc * corr[..., None] + jnp.einsum("bhqk,bkhd->bhqd", p, v_i.astype(jnp.float32))
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((b, h, sq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, h, sq), jnp.float32)
    acc0 = jnp.zeros((b, h, sq, hd), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(body, (m0, l0, acc0), (kb, vb, jnp.arange(n_blocks)))
    out = acc / jnp.maximum(l[..., None], 1e-30)
    return jnp.moveaxis(out, 1, 2).astype(q.dtype)  # (B, Sq, H, hd)


def _largest_block(s: int, pref: int) -> int:
    """Largest divisor of s that is <= pref (VLM cells have S = text + patches,
    e.g. 4352, which plain power-of-two blocks don't divide)."""
    if s <= pref:
        return s
    for b in range(min(pref, s), 0, -1):
        if s % b == 0:
            return b
    return 1


# ---------------------------------------------------------------------------
# Flash attention with a hand-written VJP.
#
# Differentiating the chunked scan above makes jax save the per-block softmax
# probabilities (B, H, Sq, block) for backward — ~600 MB/layer/sample at 4k —
# which is exactly the memory wall flash attention exists to break. The
# custom VJP saves only (out, lse) and *recomputes* each probability block in
# the backward scan, so live attention memory is O(S * d) per layer.
# ---------------------------------------------------------------------------


def _flash_fwd_inner(q, k, v, *, causal: bool, kv_block: int):
    b, sq, h, hd = q.shape
    sk = k.shape[1]
    n_blocks = sk // kv_block
    scale = 1.0 / jnp.sqrt(jnp.asarray(hd, jnp.float32))
    qf = q.astype(jnp.float32) * scale
    kb = jnp.moveaxis(k.reshape(b, n_blocks, kv_block, h, hd), 1, 0)
    vb = jnp.moveaxis(v.reshape(b, n_blocks, kv_block, h, hd), 1, 0)
    q_abs = jnp.arange(sq)[:, None] + (sk - sq)

    def body(carry, blk):
        m, l, acc = carry
        k_i, v_i, i = blk
        s = jnp.einsum("bqhd,bkhd->bhqk", qf, k_i.astype(jnp.float32))
        if causal:
            k_abs = i * kv_block + jnp.arange(kv_block)[None, :]
            s = jnp.where((q_abs >= k_abs)[None, None], s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + jnp.sum(p, axis=-1)
        acc_new = acc * corr[..., None] + jnp.einsum("bhqk,bkhd->bhqd", p, v_i.astype(jnp.float32))
        return (m_new, l_new, acc_new), None

    # derive init carries from q so they inherit its varying-manual-axes type
    # (required when this runs inside shard_map; free otherwise)
    zero = jnp.moveaxis(qf, 1, 2) * 0.0                    # (B,H,Sq,hd)
    m0 = zero[..., 0] + NEG_INF
    l0 = zero[..., 0]
    acc0 = zero
    (m, l, acc), _ = jax.lax.scan(body, (m0, l0, acc0), (kb, vb, jnp.arange(n_blocks)))
    out = acc / jnp.maximum(l[..., None], 1e-30)
    lse = m + jnp.log(jnp.maximum(l, 1e-30))
    return jnp.moveaxis(out, 1, 2).astype(q.dtype), lse  # out (B,Sq,H,hd); lse (B,H,Sq)


@partial(jax.custom_vjp, nondiff_argnums=(3, 4))
def flash_attention(q, k, v, causal: bool = True, kv_block: int = 1024):
    out, _ = _flash_fwd_inner(q, k, v, causal=causal, kv_block=kv_block)
    return out


def _flash_fwd(q, k, v, causal, kv_block):
    out, lse = _flash_fwd_inner(q, k, v, causal=causal, kv_block=kv_block)
    return out, (q, k, v, out, lse)


def _flash_bwd(causal, kv_block, res, d_out):
    q, k, v, out, lse = res
    b, sq, h, hd = q.shape
    sk = k.shape[1]
    n_blocks = sk // kv_block
    scale = 1.0 / jnp.sqrt(jnp.asarray(hd, jnp.float32))
    qf = q.astype(jnp.float32) * scale
    do = jnp.moveaxis(d_out.astype(jnp.float32), 2, 1)   # (B,H,Sq,hd)
    of = jnp.moveaxis(out.astype(jnp.float32), 2, 1)
    delta = jnp.sum(do * of, axis=-1)                     # (B,H,Sq)
    kb = jnp.moveaxis(k.reshape(b, n_blocks, kv_block, h, hd), 1, 0)
    vb = jnp.moveaxis(v.reshape(b, n_blocks, kv_block, h, hd), 1, 0)
    q_abs = jnp.arange(sq)[:, None] + (sk - sq)

    def body(dq, blk):
        k_i, v_i, i = blk
        s = jnp.einsum("bqhd,bkhd->bhqk", qf, k_i.astype(jnp.float32))
        if causal:
            k_abs = i * kv_block + jnp.arange(kv_block)[None, :]
            s = jnp.where((q_abs >= k_abs)[None, None], s, NEG_INF)
        p = jnp.exp(s - lse[..., None])                   # recomputed, O(block)
        dv_i = jnp.einsum("bhqk,bhqd->bkhd", p, do)
        dp = jnp.einsum("bhqd,bkhd->bhqk", do, v_i.astype(jnp.float32))
        ds = p * (dp - delta[..., None])
        dq = dq + jnp.einsum("bhqk,bkhd->bqhd", ds, k_i.astype(jnp.float32)) * scale
        dk_i = jnp.einsum("bhqk,bqhd->bkhd", ds, qf)
        return dq, (dk_i, dv_i)

    dq0 = qf * 0.0  # varying-typed zeros (see fwd)
    dq, (dk_b, dv_b) = jax.lax.scan(body, dq0, (kb, vb, jnp.arange(n_blocks)))
    dk = jnp.moveaxis(dk_b, 0, 1).reshape(b, sk, h, hd)
    dv = jnp.moveaxis(dv_b, 0, 1).reshape(b, sk, h, hd)
    return dq.astype(q.dtype), dk.astype(k.dtype), dv.astype(v.dtype)


flash_attention.defvjp(_flash_fwd, _flash_bwd)


@dataclasses.dataclass(frozen=True)
class AttnConfig:
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    causal: bool = True
    rope: bool = True
    rope_base: float = 10000.0
    qkv_bias: bool = False
    kv_block: int = 1024
    dense_threshold: int = 2048  # use O(S^2) path only below this seq length


def _attention_explicit_tp(p, x: jnp.ndarray, cfg: AttnConfig):
    """Explicit Megatron-SP tensor parallelism for attention (see
    ``_mlp_explicit_tp``): one bf16 all-gather of the SP activations in, local
    flash attention over this shard's query heads, one bf16 reduce-scatter of
    the out-projection partial sums. GQA with kv_heads < tp keeps K/V compute
    replicated (it is ~kv/heads of the work) and gathers each shard's kv
    group by index. Returns None when shapes don't allow it."""
    import math as _math

    from ..sharding.logical import current
    from jax.sharding import PartitionSpec as P

    ctx = current()
    if ctx is None or "model" not in ctx.mesh.axis_names:
        return None
    mesh = ctx.mesh
    tp = mesh.shape["model"]
    b, s, d = x.shape
    h, kv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    if tp == 1 or s % tp or h % tp or cfg.qkv_bias:
        return None
    batch_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    if b % _math.prod(mesh.shape[a] for a in batch_axes):
        return None

    h_l = h // tp
    dtype = x.dtype
    xspec = P(batch_axes, "model", None)
    kv_sharded = kv % tp == 0

    def body(x_l, wq, wk, wv, wo):
        x_full = jax.lax.all_gather(x_l, "model", axis=1, tiled=True)
        q = jnp.einsum("bsd,dhk->bshk", x_full, wq.astype(dtype))
        k = jnp.einsum("bsd,dhk->bshk", x_full, wk.astype(dtype))
        v = jnp.einsum("bsd,dhk->bshk", x_full, wv.astype(dtype))
        if cfg.rope:
            sin, cos = rotary_embedding(jnp.arange(s), hd, cfg.rope_base)
            q = apply_rotary(q, sin, cos)
            k = apply_rotary(k, sin, cos)
        idx = jax.lax.axis_index("model")
        if kv_sharded:
            # each shard already holds its kv slice; expand to local q heads
            k_l = _repeat_kv(k, h_l // k.shape[2])
            v_l = _repeat_kv(v, h_l // v.shape[2])
        else:
            groups = (idx * h_l + jnp.arange(h_l)) * kv // h
            k_l = jnp.take(k, groups, axis=2)
            v_l = jnp.take(v, groups, axis=2)
        if s <= cfg.dense_threshold:
            out = dense_attention(q, k_l, v_l, causal=cfg.causal)
        else:
            out = flash_attention(q, k_l, v_l, cfg.causal, _largest_block(s, cfg.kv_block))
        y_part = jnp.einsum("bshk,hkd->bsd", out, wo.astype(dtype)).astype(dtype)
        return jax.lax.psum_scatter(y_part, "model", scatter_dimension=1, tiled=True)

    kvspec = P(None, "model", None) if kv_sharded else P(None, None, None)
    return shard_map(
        body, mesh=mesh,
        in_specs=(xspec, P(None, "model", None), kvspec, kvspec, P("model", None, None)),
        out_specs=xspec,
    )(x, p["wq"], p["wk"], p["wv"], p["wo"])


def attention_forward(p, x: jnp.ndarray, cfg: AttnConfig) -> jnp.ndarray:
    """Full-sequence forward (training / prefill)."""
    y = _attention_explicit_tp(p, x, cfg)
    if y is not None:
        return y
    b, s, d = x.shape
    rope_sincos = None
    if cfg.rope:
        rope_sincos = rotary_embedding(jnp.arange(s), cfg.head_dim, cfg.rope_base)
    q, k, v = _project_qkv(p, x, rope_sincos, None)
    n_rep = cfg.n_heads // cfg.n_kv_heads
    k, v = _repeat_kv(k, n_rep), _repeat_kv(v, n_rep)
    if s <= cfg.dense_threshold:
        out = dense_attention(q, k, v, causal=cfg.causal)
    else:
        out = flash_attention(q, k, v, cfg.causal, _largest_block(s, cfg.kv_block))
    out = constrain(out, "batch", "seq", "act_heads", None)
    y = jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(x.dtype))
    # constrain the partial-sum output directly to the sequence-parallel spec:
    # GSPMD then lowers the TP reduction as reduce-scatter instead of
    # all-reduce + slice (half the ICI bytes)
    return constrain(y, "batch", "seq_sp", "act_embed")


class KVCache(NamedTuple):
    """Per-attention-layer decode cache. k/v: (B, S_max, KV, hd); index: ().

    With ``quant=True`` k/v are int8 with per-(batch, position, head) fp32
    scales — halving cache HBM vs bf16. This is what makes the qwen1.5-32b
    decode_32k cell (64L MHA kv=40: a 5.5 TB bf16 cache) fit a single pod.
    """

    k: jnp.ndarray
    v: jnp.ndarray
    k_scale: jnp.ndarray  # (B, S_max, KV) fp32 for int8; (1,) placeholder otherwise
    v_scale: jnp.ndarray
    index: jnp.ndarray    # current fill length (int32 scalar)

    @property
    def quantized(self) -> bool:
        return self.k.dtype == jnp.int8


def init_kv_cache(batch: int, max_seq: int, n_kv: int, head_dim: int,
                  dtype=jnp.bfloat16, *, quant: bool = False) -> KVCache:
    if quant:
        return KVCache(
            k=jnp.zeros((batch, max_seq, n_kv, head_dim), jnp.int8),
            v=jnp.zeros((batch, max_seq, n_kv, head_dim), jnp.int8),
            k_scale=jnp.zeros((batch, max_seq, n_kv), jnp.float32),
            v_scale=jnp.zeros((batch, max_seq, n_kv), jnp.float32),
            index=jnp.zeros((), jnp.int32),
        )
    return KVCache(
        k=jnp.zeros((batch, max_seq, n_kv, head_dim), dtype),
        v=jnp.zeros((batch, max_seq, n_kv, head_dim), dtype),
        k_scale=jnp.zeros((1,), jnp.float32),
        v_scale=jnp.zeros((1,), jnp.float32),
        index=jnp.zeros((), jnp.int32),
    )


def _quantize_kv(x: jnp.ndarray):
    """x: (B, S, KV, hd) -> (int8 values, (B, S, KV) scales)."""
    amax = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1)
    scale = jnp.maximum(amax / 127.0, 1e-8)
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale[..., None]), -127, 127)
    return q.astype(jnp.int8), scale


# ---------------------------------------------------------------------------
# Paged serving path: KV lives in a page pool (repro.serve.kvpool layout:
# (n_pages, page_size, 2*KV, hd), K/V interleaved on even/odd head indices),
# addressed through a per-request page table. Decode and chunked prefill both
# reduce through the same ragged Pallas kernel.
# ---------------------------------------------------------------------------


def _fused_kv_rows(k: jnp.ndarray, v: jnp.ndarray) -> jnp.ndarray:
    """k, v: (N, KV, hd) -> (N, 2*KV, hd) with K on even / V on odd head
    indices — one scatter writes both halves of a page row."""
    n, kv, hd = k.shape
    return jnp.stack([k, v], axis=2).reshape(n, 2 * kv, hd)


def attention_paged_decode(p, x: jnp.ndarray, pool: jnp.ndarray,
                           table: jnp.ndarray, lengths: jnp.ndarray,
                           active: jnp.ndarray, cfg: AttnConfig,
                           *, interpret=None,
                           use_ref=False) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """One-token paged decode. x: (B, 1, D); pool: (pages, P, 2KV, hd);
    table: (B, max_pages); lengths: (B,) positions already stored. Writes the
    new token's K/V at position ``lengths`` (inactive rows are routed to the
    reserved null page 0, which no table entry of a live row ever points at),
    then attends over ``lengths + 1`` positions. Returns (y (B, 1, D), pool).
    """
    from ..kernels.paged_attention import paged_attention

    b = x.shape[0]
    pos = lengths
    rope_sincos = None
    if cfg.rope:
        rope_sincos = rotary_embedding(pos[:, None], cfg.head_dim, cfg.rope_base)
    q, k_new, v_new = _project_qkv(p, x, rope_sincos, None)

    page_size = pool.shape[1]
    page = jnp.where(active, table[jnp.arange(b), pos // page_size], 0)
    kv_rows = _fused_kv_rows(k_new[:, 0], v_new[:, 0])
    pool = pool.at[page, pos % page_size].set(kv_rows.astype(pool.dtype))

    kv_len = jnp.where(active, pos + 1, 0).astype(jnp.int32)
    out = paged_attention(q, pool, table, kv_len, interpret=interpret,
                          use_ref=use_ref)
    y = jnp.einsum("bshk,hkd->bsd", out.astype(x.dtype), p["wo"].astype(x.dtype))
    return constrain(y, "batch", "seq", "act_embed"), pool


def attention_paged_prefill(p, x: jnp.ndarray, pool: jnp.ndarray,
                            table_row: jnp.ndarray, pos0, n_valid,
                            cfg: AttnConfig,
                            *, interpret=None,
                            use_ref=False) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """One chunk of paged prefill for a single request. x: (1, C, D) holding
    the prompt tokens at absolute positions ``pos0 .. pos0 + C - 1``;
    positions at chunk index >= ``n_valid`` are padding — their K/V writes are
    routed to the null page and their outputs are garbage nobody reads (the
    caller samples at chunk index ``n_valid - 1``). The in-kernel causal mask
    ``k_abs <= q_abs`` keeps every *valid* query's reduction inside the
    row's live pages. Returns (y (1, C, D), pool)."""
    from ..kernels.paged_attention import paged_attention

    c = x.shape[1]
    positions = pos0 + jnp.arange(c)
    rope_sincos = None
    if cfg.rope:
        rope_sincos = rotary_embedding(positions, cfg.head_dim, cfg.rope_base)
    q, k_new, v_new = _project_qkv(p, x, rope_sincos, None)

    page_size = pool.shape[1]
    max_pages = table_row.shape[1]
    pidx = jnp.clip(positions // page_size, 0, max_pages - 1)
    valid = jnp.arange(c) < n_valid
    page = jnp.where(valid, table_row[0, pidx], 0)
    kv_rows = _fused_kv_rows(k_new[0], v_new[0])
    pool = pool.at[page, positions % page_size].set(kv_rows.astype(pool.dtype))

    kv_len = jnp.asarray(pos0 + c, jnp.int32)[None]
    out = paged_attention(q, pool, table_row, kv_len, interpret=interpret,
                          use_ref=use_ref)
    y = jnp.einsum("bshk,hkd->bsd", out.astype(x.dtype), p["wo"].astype(x.dtype))
    return constrain(y, "batch", "seq", "act_embed"), pool


def attention_decode(p, x: jnp.ndarray, cache: KVCache, cfg: AttnConfig) -> Tuple[jnp.ndarray, KVCache]:
    """One-token decode: x (B, 1, D), cache holds `index` previous positions."""
    b, s1, d = x.shape
    assert s1 == 1
    pos = cache.index
    rope_sincos = None
    if cfg.rope:
        rope_sincos = rotary_embedding(pos[None], cfg.head_dim, cfg.rope_base)
    q, k_new, v_new = _project_qkv(p, x, rope_sincos, None)

    if cache.quantized:
        k_q, k_s = _quantize_kv(k_new)
        v_q, v_s = _quantize_kv(v_new)
        k_cache = jax.lax.dynamic_update_slice(cache.k, k_q, (0, pos, 0, 0))
        v_cache = jax.lax.dynamic_update_slice(cache.v, v_q, (0, pos, 0, 0))
        k_scale = jax.lax.dynamic_update_slice(cache.k_scale, k_s, (0, pos, 0))
        v_scale = jax.lax.dynamic_update_slice(cache.v_scale, v_s, (0, pos, 0))
    else:
        k_cache = jax.lax.dynamic_update_slice(cache.k, k_new.astype(cache.k.dtype), (0, pos, 0, 0))
        v_cache = jax.lax.dynamic_update_slice(cache.v, v_new.astype(cache.v.dtype), (0, pos, 0, 0))
        k_scale, v_scale = cache.k_scale, cache.v_scale
    k_cache = constrain(k_cache, "batch", "seq_kv", None, None)
    v_cache = constrain(v_cache, "batch", "seq_kv", None, None)

    n_rep = cfg.n_heads // cfg.n_kv_heads
    s_max = k_cache.shape[1]
    scale = 1.0 / jnp.sqrt(jnp.asarray(cfg.head_dim, jnp.float32))
    # grouped-query scores against the whole cache, masked beyond `index`
    qg = q.reshape(b, 1, cfg.n_kv_heads, n_rep, cfg.head_dim).astype(jnp.float32) * scale
    scores = jnp.einsum("bqgrd,bkgd->bgrqk", qg, k_cache.astype(jnp.float32))
    if cache.quantized:
        # fold the int8 dequant scale into the (b, k, g) score/value terms
        scores = scores * jnp.moveaxis(k_scale, 1, 2)[:, :, None, None, :]
    valid = (jnp.arange(s_max) <= pos)[None, None, None, None, :]
    scores = jnp.where(valid, scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    if cache.quantized:
        probs = probs * jnp.moveaxis(v_scale, 1, 2)[:, :, None, None, :]
    out = jnp.einsum("bgrqk,bkgd->bqgrd", probs, v_cache.astype(jnp.float32))
    out = out.reshape(b, 1, cfg.n_heads, cfg.head_dim).astype(x.dtype)
    y = jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(x.dtype))
    return constrain(y, "batch", "seq", "act_embed"), KVCache(
        k=k_cache, v=v_cache, k_scale=k_scale, v_scale=v_scale, index=pos + 1)
