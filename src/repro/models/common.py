"""Functional module system: parameter specs, initializers, norms, rotary.

No flax in this environment — models are (init, apply) pairs over plain
nested-dict pytrees. Every parameter is declared via :class:`ParamSpec`,
which carries the logical axes + paper role that feed ``repro.core`` (rules)
and ``repro.sharding`` (PartitionSpecs).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable, Optional, Tuple

import jax
import jax.numpy as jnp

from ..core.labels import ParamMeta

Initializer = Callable[[jax.Array, Tuple[int, ...], jnp.dtype], jnp.ndarray]


# ---------------------------------------------------------------------------
# Initializers (paper §4.3: Mitchell vs torch-default matter for SNR)
# ---------------------------------------------------------------------------


def normal_init(std: float = 0.02) -> Initializer:
    def init(key, shape, dtype):
        return (jax.random.normal(key, shape) * std).astype(dtype)

    return init


def mitchell_residual_init(std: float, n_layers: int) -> Initializer:
    """Mitchell init for residual-stream writers: std / sqrt(2 * n_layers)."""
    scaled = std / math.sqrt(2.0 * max(n_layers, 1))
    return normal_init(scaled)


def torch_default_init() -> Initializer:
    """PyTorch nn.Linear default: U(-1/sqrt(fan_in), 1/sqrt(fan_in)).

    fan_in is taken as the product of all dims but the last (our matrices are
    stored (in..., out)).
    """

    def init(key, shape, dtype):
        fan_in = int(max(1, math.prod(shape[:-1]))) if len(shape) > 1 else shape[0]
        bound = 1.0 / math.sqrt(fan_in)
        return jax.random.uniform(key, shape, minval=-bound, maxval=bound).astype(dtype)

    return init


def zeros_init() -> Initializer:
    return lambda key, shape, dtype: jnp.zeros(shape, dtype)


def ones_init() -> Initializer:
    return lambda key, shape, dtype: jnp.ones(shape, dtype)


def constant_init(v: float) -> Initializer:
    return lambda key, shape, dtype: jnp.full(shape, v, dtype)


# ---------------------------------------------------------------------------
# Param spec tree -> (params, meta)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ParamSpec:
    shape: Tuple[int, ...]
    axes: Tuple[str, ...]
    role: str
    init: Initializer
    fan_in: Tuple[str, ...] = ()
    fan_out: Tuple[str, ...] = ()
    dtype: Any = jnp.float32

    def meta(self) -> ParamMeta:
        return ParamMeta(axes=self.axes, role=self.role, fan_in=self.fan_in, fan_out=self.fan_out)


def _is_spec(x) -> bool:
    return isinstance(x, ParamSpec)


def init_params(spec_tree: Any, key: jax.Array) -> Any:
    """Materialize a params pytree from a ParamSpec pytree (leaf-unique keys)."""
    leaves, treedef = jax.tree_util.tree_flatten(spec_tree, is_leaf=_is_spec)
    keys = jax.random.split(key, len(leaves))
    params = [s.init(k, s.shape, s.dtype) for s, k in zip(leaves, keys)]
    return jax.tree_util.tree_unflatten(treedef, params)


def abstract_params(spec_tree: Any) -> Any:
    """ShapeDtypeStruct pytree — used by the dry-run (no allocation)."""
    return jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(s.shape, s.dtype), spec_tree, is_leaf=_is_spec
    )


def meta_tree(spec_tree: Any) -> Any:
    return jax.tree.map(lambda s: s.meta(), spec_tree, is_leaf=_is_spec)


def stack_specs(spec_tree: Any, n: int) -> Any:
    """Prepend a scan-stacked 'layers' axis of size n to every spec."""

    def stack(s: ParamSpec) -> ParamSpec:
        def init(key, shape, dtype):
            keys = jax.random.split(key, n)
            return jnp.stack([s.init(k, s.shape, dtype) for k in keys])

        return ParamSpec(
            shape=(n,) + s.shape,
            axes=("layers",) + s.axes,
            role=s.role,
            init=init,
            fan_in=s.fan_in,
            fan_out=s.fan_out,
            dtype=s.dtype,
        )

    return jax.tree.map(stack, spec_tree, is_leaf=_is_spec)


# ---------------------------------------------------------------------------
# Norms / activations / rotary
# ---------------------------------------------------------------------------


def rms_norm(x: jnp.ndarray, scale: jnp.ndarray, eps: float = 1e-5) -> jnp.ndarray:
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    x = x * jax.lax.rsqrt(var + eps)
    return (x * scale.astype(jnp.float32)).astype(dtype)


def layer_norm(x: jnp.ndarray, scale: jnp.ndarray, bias: Optional[jnp.ndarray], eps: float = 1e-5) -> jnp.ndarray:
    dtype = x.dtype
    x = x.astype(jnp.float32)
    mean = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x - mean), axis=-1, keepdims=True)
    x = (x - mean) * jax.lax.rsqrt(var + eps)
    x = x * scale.astype(jnp.float32)
    if bias is not None:
        x = x + bias.astype(jnp.float32)
    return x.astype(dtype)


def rotary_embedding(positions: jnp.ndarray, head_dim: int, base: float = 10000.0):
    """Returns (sin, cos) of shape (..., head_dim/2)."""
    inv_freq = 1.0 / (base ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))
    angles = positions.astype(jnp.float32)[..., None] * inv_freq  # (..., hd/2)
    return jnp.sin(angles), jnp.cos(angles)


def apply_rotary(x: jnp.ndarray, sin: jnp.ndarray, cos: jnp.ndarray) -> jnp.ndarray:
    """x: (..., S, H, D); sin/cos: (S, D/2) broadcast over batch/heads."""
    dtype = x.dtype
    x = x.astype(jnp.float32)
    x1, x2 = jnp.split(x, 2, axis=-1)
    sin = sin[..., :, None, :]  # (S, 1, D/2)
    cos = cos[..., :, None, :]
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(dtype)
