from .faults import FaultPlan, inject_checkpoint_io_failure, inject_kernel_failure, tear_checkpoint
from .guard import Guard, GuardConfig, find_step_health, strip_step_health
from .loss import cross_entropy, lm_loss
from .step import make_eval_step, make_serve_step, make_train_step
from .trainer import OPTIMIZERS, Trainer, TrainerConfig, find_adam_nu, make_optimizer

__all__ = ["cross_entropy", "lm_loss", "make_eval_step", "make_serve_step", "make_train_step",
           "OPTIMIZERS", "Trainer", "TrainerConfig", "find_adam_nu", "make_optimizer",
           "Guard", "GuardConfig", "find_step_health", "strip_step_health",
           "FaultPlan", "inject_checkpoint_io_failure", "inject_kernel_failure",
           "tear_checkpoint"]
