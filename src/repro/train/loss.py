"""Losses: causal-LM cross entropy (fp32 logsumexp) + encoder CE."""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp


def cross_entropy(logits: jnp.ndarray, labels: jnp.ndarray, *, z_coef: float = 0.0) -> jnp.ndarray:
    """Token-mean CE. logits: (B, S, V) any dtype; labels: (B, S) int32.

    Computed in fp32; optional z-loss regularizes logsumexp magnitude (kept 0
    by default — the paper does not use it)."""
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = lse - gold
    loss = jnp.mean(nll)
    if z_coef:
        loss = loss + z_coef * jnp.mean(jnp.square(lse))
    return loss


def lm_loss(cfg, params, batch: Dict[str, jnp.ndarray], forward_fn) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
    """Forward + CE (+ MoE aux). For causal LMs, labels are next-token ids
    supplied by the data pipeline; for encoders, per-frame targets."""
    logits, aux = forward_fn(cfg, params, batch)
    labels = batch["labels"]
    if logits.shape[1] != labels.shape[1]:
        # VLM: frontend embeddings prepended — score only the text positions.
        logits = logits[:, logits.shape[1] - labels.shape[1]:]
    ce = cross_entropy(logits, labels)
    loss = ce + aux
    return loss, {"loss": loss, "ce": ce, "aux": aux}
