"""Guarded training policy: skip / backoff / rollback on anomalous steps.

The division of labor with the rest of the stack:

* the **kernels** accumulate per-leaf ``[nonfinite_count, finite_sumsq]``
  inside the update's own HBM pass (``repro.kernels.*`` with_health outputs,
  surfaced as :class:`repro.optim.fused.StepHealth` on the optimizer state
  when built with ``emit_health=True``);
* the **jitted step** (``make_train_step(..., guard=True)``) reads that
  health and *selects* the pre-step params/optimizer state when the step is
  poisoned — a non-finite gradient can never advance moments or count;
* this module holds the **host-side policy**: a rolling loss window with a
  z-score spike detector, multiplicative lr backoff/recovery, and a
  consecutive-bad-step counter that escalates to a rollback to the last
  good checkpoint (``Trainer.run`` executes the rollback + data re-seed).

Everything here is plain Python on host scalars — one float per step leaves
the device, so the policy adds no compiled-graph or HBM cost.
"""
from __future__ import annotations

import dataclasses
import math
from collections import deque
from typing import Any, Deque, Dict, Optional

# Step outcomes observe() can report. 'skip' = the jitted step already
# discarded the update (non-finite health); 'backoff' = finite but spiking
# loss, lr scaled down; 'rollback' = enough consecutive bad steps that the
# trainer should restore the last good checkpoint.
OK, SKIP, BACKOFF, ROLLBACK = "ok", "skip", "backoff", "rollback"


@dataclasses.dataclass
class GuardConfig:
    """Policy knobs for :class:`Guard`.

    The defaults are deliberately loose: a z-score of 6 over a 32-step
    window fires on genuine divergence (or an injected spike) but not on
    ordinary early-training loss noise."""
    window: int = 32           # rolling loss window length
    min_history: int = 8       # no spike verdicts until this many good steps
    spike_z: float = 6.0       # z-score above which a loss counts as a spike
    spike_min_std: float = 1e-6  # std floor so a flat window can't divide by ~0
    lr_backoff: float = 0.5    # lr_scale *= this on a spike
    lr_recover: float = 1.25   # lr_scale *= this on a good step (capped at 1)
    min_lr_scale: float = 0.05
    max_bad_steps: int = 3     # consecutive bad steps before rollback
    max_rollbacks: int = 3     # stop escalating after this many restores
    reseed_bump: int = 1009    # data seed += rollbacks * this after a restore


class Guard:
    """Host-side anomaly policy over per-step (loss, health) observations.

    Feed it one :meth:`observe` per optimizer step; it returns the action
    the trainer should take. Counters are cheap plain ints — merge
    :meth:`stats` into the metrics dict when logging.
    """

    def __init__(self, cfg: Optional[GuardConfig] = None):
        self.cfg = cfg or GuardConfig()
        self._window: Deque[float] = deque(maxlen=self.cfg.window)
        self.lr_scale: float = 1.0
        self.consecutive_bad: int = 0
        self.counters: Dict[str, int] = {
            "skipped": 0, "spikes": 0, "backoffs": 0, "rollbacks": 0,
            "nonfinite_total": 0,
        }

    # -- policy ------------------------------------------------------------

    def _is_spike(self, loss: float) -> bool:
        if not math.isfinite(loss):
            return True
        if len(self._window) < self.cfg.min_history:
            return False
        mean = sum(self._window) / len(self._window)
        var = sum((x - mean) ** 2 for x in self._window) / len(self._window)
        std = max(math.sqrt(var), self.cfg.spike_min_std)
        return (loss - mean) / std > self.cfg.spike_z

    def _escalate(self) -> str:
        self.consecutive_bad += 1
        if (self.consecutive_bad >= self.cfg.max_bad_steps
                and self.counters["rollbacks"] < self.cfg.max_rollbacks):
            return ROLLBACK
        return ""

    def observe(self, loss: float, *, skipped: bool = False,
                nonfinite: float = 0.0) -> str:
        """Record one step's outcome; return OK / SKIP / BACKOFF / ROLLBACK.

        ``skipped``: the jitted step discarded the update (non-finite
        health) — the loss is untrusted and is kept out of the window.
        A finite loss that z-scores past ``spike_z`` triggers a backoff
        (multiplicative lr_scale cut) and is also kept out of the window so
        one spike can't inflate the baseline. Good steps recover lr_scale
        multiplicatively back toward 1.
        """
        if skipped:
            self.counters["skipped"] += 1
            self.counters["nonfinite_total"] += int(nonfinite)
            return self._escalate() or SKIP
        if self._is_spike(loss):
            self.counters["spikes"] += 1
            self.counters["backoffs"] += 1
            self.lr_scale = max(self.lr_scale * self.cfg.lr_backoff,
                                self.cfg.min_lr_scale)
            return self._escalate() or BACKOFF
        self._window.append(float(loss))
        self.consecutive_bad = 0
        self.lr_scale = min(self.lr_scale * self.cfg.lr_recover, 1.0)
        return OK

    def note_rollback(self):
        """Trainer callback after a checkpoint restore: the loss window no
        longer describes the restored trajectory, so clear it (lr_scale is
        kept backed-off — the restored run re-earns it on good steps)."""
        self.counters["rollbacks"] += 1
        self.consecutive_bad = 0
        self._window.clear()

    def stats(self) -> Dict[str, float]:
        out: Dict[str, float] = {f"guard_{k}": float(v)
                                 for k, v in self.counters.items()}
        out["guard_lr_scale"] = float(self.lr_scale)
        return out


# -- optimizer-state walkers ----------------------------------------------
# Generic over chained states; live here (not trainer.py) so the jitted
# step can use them without importing the orchestration layer.


def find_step_health(opt_state) -> Optional[Any]:
    """First non-None ``StepHealth`` published on a (possibly chained)
    optimizer state by an ``emit_health`` transformation, else None."""
    from ..core.slim_adam import ScaleBySlimAdamState
    from ..optim.adam import ScaleByAdamState
    from ..optim.base import ChainState, MultiStepsState

    def walk(node):
        if isinstance(node, (ScaleByAdamState, ScaleBySlimAdamState)):
            return node.health
        if isinstance(node, ChainState):
            for s in node.inner_states:
                out = walk(s)
                if out is not None:
                    return out
        if isinstance(node, MultiStepsState):
            return walk(node.inner_state)
        return None

    return walk(opt_state)


def strip_step_health(opt_state):
    """Return ``opt_state`` with any published StepHealth cleared, restoring
    the health-less pytree layout (checkpoint templates and the unguarded
    step's jit signature both expect it)."""
    from ..core.slim_adam import ScaleBySlimAdamState
    from ..optim.adam import ScaleByAdamState
    from ..optim.base import ChainState, MultiStepsState

    def walk(node):
        if isinstance(node, (ScaleByAdamState, ScaleBySlimAdamState)):
            return node._replace(health=None) if node.health is not None else node
        if isinstance(node, ChainState):
            return ChainState(tuple(walk(s) for s in node.inner_states))
        if isinstance(node, MultiStepsState):
            return node._replace(inner_state=walk(node.inner_state))
        return node

    return walk(opt_state)


def find_slim_snr(opt_state) -> Optional[Any]:
    """Extract the from-update SNR pytree a measure-step ``emit_snr``
    update published on the (possibly chained) SlimAdam state, if any."""
    from ..core.slim_adam import ScaleBySlimAdamState
    from ..optim.base import ChainState, MultiStepsState

    def walk(node):
        if isinstance(node, ScaleBySlimAdamState):
            return node.snr
        if isinstance(node, ChainState):
            for s in node.inner_states:
                out = walk(s)
                if out is not None:
                    return out
        if isinstance(node, MultiStepsState):
            return walk(node.inner_state)
        return None

    return walk(opt_state)


def strip_slim_snr(opt_state):
    """Return ``opt_state`` with any published from-update SNR snapshot
    cleared — restores the snr-less pytree layout after the trainer has
    consumed a measure step's snapshot (checkpoint templates and the normal
    step's jit signature both expect it)."""
    from ..core.slim_adam import ScaleBySlimAdamState
    from ..optim.base import ChainState, MultiStepsState

    def walk(node):
        if isinstance(node, ScaleBySlimAdamState):
            return node._replace(snr=None) if node.snr is not None else node
        if isinstance(node, ChainState):
            return ChainState(tuple(walk(s) for s in node.inner_states))
        if isinstance(node, MultiStepsState):
            return node._replace(inner_state=walk(node.inner_state))
        return node

    return walk(opt_state)


def attach_slim_snr(opt_state, snr):
    """Re-attach a from-update SNR snapshot onto the first SlimAdam state in
    a chain — the guarded step strips snr (and health) before the
    skip-select so old/new layouts match, then puts the measurement back on
    the selected state for the trainer to consume."""
    if snr is None:
        return opt_state
    from ..core.slim_adam import ScaleBySlimAdamState
    from ..optim.base import ChainState, MultiStepsState

    done = [False]

    def walk(node):
        if isinstance(node, ScaleBySlimAdamState) and not done[0]:
            done[0] = True
            return node._replace(snr=snr)
        if isinstance(node, ChainState):
            return ChainState(tuple(walk(s) for s in node.inner_states))
        if isinstance(node, MultiStepsState):
            return node._replace(inner_state=walk(node.inner_state))
        return node

    return walk(opt_state)
