"""Deterministic fault injection for the resilience substrate.

Everything here is seedless and step-indexed — an injected run is exactly
reproducible, which is what lets ``benchmarks/fault_drill.py`` compare an
injected trajectory against a clean one and what keeps the guard tests
deterministic. Injection points:

* **gradients** — :class:`FaultPlan.grad_scale` returns NaN/Inf multipliers
  for the guarded step's ``controls['grad_scale']`` on the chosen steps
  (the poisoning happens inside the jitted step, so the kernels' in-pass
  health stats see it exactly as a real non-finite gradient);
* **loss spikes** — :meth:`FaultPlan.corrupt_loss` scales the host-side
  loss the :class:`repro.train.guard.Guard` observes, driving the
  backoff/rollback policy without touching device state;
* **checkpoint IO** — :func:`inject_checkpoint_io_failure` raises OSError
  from inside ``checkpoint.store.save`` on selected writes;
* **kernel failures** — :func:`inject_kernel_failure` makes the fused
  backend's pallas_call raise, exercising the per-leaf graceful
  degradation to the jnp reference path (counted by
  ``optim.fused.kernel_degraded_leaves``);
* **torn checkpoints** — :func:`tear_checkpoint` truncates a written step
  on disk the way a preemption mid-write would.
"""
from __future__ import annotations

import contextlib
import dataclasses
import json
from pathlib import Path
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class FaultPlan:
    """Step-indexed gradient/loss fault schedule (0-based step numbers,
    matching ``Trainer.step`` *before* the step runs)."""
    nan_grad_steps: Tuple[int, ...] = ()
    inf_grad_steps: Tuple[int, ...] = ()
    spike_steps: Tuple[int, ...] = ()
    spike_scale: float = 1e3

    def grad_scale(self, step: int) -> float:
        """Multiplier for the gradient tree at ``step`` (1.0 = clean).
        NaN/Inf multipliers poison every gradient entry, which the in-pass
        health stats then count."""
        if step in self.nan_grad_steps:
            return float("nan")
        if step in self.inf_grad_steps:
            return float("inf")
        return 1.0

    def corrupt_loss(self, step: int, loss: float) -> float:
        """Host-side loss as the guard should observe it at ``step``."""
        if step in self.spike_steps:
            return loss * self.spike_scale
        return loss

    @property
    def fault_steps(self) -> Tuple[int, ...]:
        return tuple(sorted(set(self.nan_grad_steps) | set(self.inf_grad_steps)
                            | set(self.spike_steps)))


@contextlib.contextmanager
def inject_checkpoint_io_failure(fail_on: Tuple[int, ...] = (1,)):
    """Make ``checkpoint.store.save`` raise OSError on its nth call(s)
    within this context (1-based). Yields the counter dict so callers can
    assert how many writes were attempted. Installed at the shared
    ``"checkpoint.io"`` registry point (:mod:`repro.injection`), the same
    mechanism the serve-side drills use."""
    from .. import injection
    from ..checkpoint import store

    hook, state = injection.call_counter(
        fail_on, lambda n: OSError(f"injected checkpoint IO failure "
                                   f"(write #{n})"))
    with injection.installed(store.IO_FAULT_POINT, hook):
        yield state


@contextlib.contextmanager
def inject_kernel_failure(match: Optional[str] = None):
    """Make every fused-backend kernel launch (or only those whose label
    contains ``match``) raise inside this context, forcing the per-leaf
    degradation to the jnp reference path. Degradation counters are reset
    on entry; read ``optim.fused.kernel_degraded_leaves()`` before exit."""
    from .. import injection
    from ..optim import fused

    def hook(label):
        if match is None or match in label:
            raise RuntimeError(f"injected kernel failure at {label}")

    fused.reset_kernel_degradation()
    with injection.installed(fused.KERNEL_FAULT_POINT, hook):
        yield


def tear_checkpoint(ckpt_dir, step: Optional[int] = None) -> int:
    """Corrupt the checkpoint at ``step`` (default: newest on disk) the way
    a preemption mid-write would: truncate ``arrays.npz`` and scramble the
    manifest's checksums. Returns the torn step number."""
    from ..checkpoint import store

    ckpt_dir = Path(ckpt_dir)
    if step is None:
        dirs = sorted(ckpt_dir.glob("step_*"))
        if not dirs:
            raise FileNotFoundError(f"no checkpoints under {ckpt_dir}")
        step = int(dirs[-1].name.split("_")[1])
    path = ckpt_dir / f"step_{step:08d}"
    npz = path / "arrays.npz"
    raw = npz.read_bytes()
    npz.write_bytes(raw[: max(len(raw) // 2, 1)])
    mpath = path / "manifest.json"
    manifest = json.loads(mpath.read_text())
    for entry in manifest.get("leaves", {}).values():
        if "crc32" in entry:
            entry["crc32"] = (entry["crc32"] + 1) % (1 << 32)
    mpath.write_text(json.dumps(manifest))
    return step
