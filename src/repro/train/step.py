"""pjit-able train / serve step factories.

``make_train_step`` closes over (model config, optimizer) and returns the
pure function lowered by both the real trainer and the dry-run:

    train_step(params, opt_state, batch) -> (params, opt_state, metrics)
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp

from ..models import transformer
from ..optim.base import GradientTransformation, apply_updates, global_norm
from .loss import lm_loss


def make_train_step(cfg, tx: GradientTransformation, *, forward_fn=None,
                    grad_accum: int = 1, grad_shardings=None,
                    guard: bool = False) -> Callable:
    """One optimizer step. With ``grad_accum > 1`` the global batch is split
    into microbatches scanned with fp32 gradient accumulation (the paper's
    own recipe: micro-batch 32 x 40 accumulation steps), which is also what
    bounds saved-activation memory for the large dry-run cells.

    ``grad_shardings``: optional NamedSharding pytree (like params) pinned
    onto the gradient tree — without it GSPMD may propagate gradients
    replicated over the TP axis (measured: 12 GiB/device vs 0.5 GiB for a
    67B model on a 256-chip mesh).

    Sharded fused backend: when ``tx`` was built with ``backend='fused'``
    plus ``mesh``/``param_specs`` (see ``repro.train.trainer.make_optimizer``
    and the launchers), the ``tx.update`` inside this step runs under
    ``shard_map`` — pin ``grad_shardings`` to the same specs so the gradient
    tree arrives already laid out for the per-shard kernels and the
    shard_map boundary inserts no resharding collectives.

    ``guard=True`` returns the 4-arg fault-tolerant variant

        train_step(params, opt_state, batch, controls)

    where ``controls`` is ``{'lr_scale': f32, 'grad_scale': f32}`` (jnp
    scalars — traced operands, so host-side policy changes never recompile).
    The step reads the in-pass :class:`repro.optim.fused.StepHealth` the
    optimizer published (build ``tx`` with ``emit_health=True``; without it
    the step falls back to the finiteness of the grad norm), and on a bad
    step *selects the pre-step params/opt state* — a poisoned gradient can
    never advance moments or the count. Extra metrics: ``nonfinite_count``,
    ``step_skipped``, ``health_grad_norm``. The returned opt state always
    has ``health=None`` so the input/output jit layouts match."""
    fwd = forward_fn or transformer.forward

    def pin(tree):
        if grad_shardings is None:
            return tree
        return jax.tree.map(jax.lax.with_sharding_constraint, tree, grad_shardings)

    def grads_of(params, batch):
        def loss_fn(p):
            loss, metrics = lm_loss(cfg, p, batch, fwd)
            return loss, metrics

        g, metrics = jax.grad(loss_fn, has_aux=True)(params)
        return pin(g), metrics

    def compute_grads(params, batch):
        if grad_accum == 1:
            return grads_of(params, batch)
        from ..sharding.logical import constrain, current

        def split(a):
            a = a.reshape((grad_accum, a.shape[0] // grad_accum) + a.shape[1:])
            if current() is not None:
                a = constrain(a, None, "batch", *([None] * (a.ndim - 2)))
            return a

        micro = jax.tree.map(split, batch)

        def body(acc, mb):
            g, m = grads_of(params, mb)
            acc = jax.tree.map(lambda a, x: a + x.astype(jnp.float32) / grad_accum, acc, g)
            return pin(acc), m

        zeros = pin(jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params))
        grads, ms = jax.lax.scan(body, zeros, micro)
        metrics = jax.tree.map(lambda x: jnp.mean(x, axis=0), ms)
        return grads, metrics

    def train_step(params, opt_state, batch):
        grads, metrics = compute_grads(params, batch)
        updates, new_opt_state = tx.update(grads, opt_state, params)
        new_params = apply_updates(params, updates)
        metrics = dict(metrics)
        metrics["grad_norm"] = global_norm(grads)
        return new_params, new_opt_state, metrics

    def guarded_train_step(params, opt_state, batch, controls):
        from .guard import (attach_slim_snr, find_slim_snr, find_step_health,
                            strip_slim_snr, strip_step_health)

        grads, metrics = compute_grads(params, batch)
        g_scale = jnp.asarray(controls["grad_scale"], jnp.float32)
        grads = jax.tree.map(lambda g: g * g_scale.astype(g.dtype), grads)
        updates, new_opt_state = tx.update(grads, opt_state, params)
        lr_scale = jnp.asarray(controls["lr_scale"], jnp.float32)
        updates = jax.tree.map(lambda u: u * lr_scale.astype(u.dtype), updates)
        new_params = apply_updates(params, updates)

        gn = global_norm(grads)
        health = find_step_health(new_opt_state)
        if health is not None:
            bad = health.bad
            nonfinite = jnp.sum(health.nonfinite)
            health_gn = health.grad_norm
        else:
            # No emit_health transformation in the chain: fall back to the
            # finiteness of the (already computed) global grad norm.
            bad = ~jnp.isfinite(gn)
            nonfinite = jnp.where(bad, 1.0, 0.0)
            health_gn = gn
        # Strip health (and any ridden SNR snapshot) so old/new state
        # layouts match, select the pre-step state wherever the step is
        # bad — moments and count never advance on a poisoned gradient —
        # then put the SNR measurement back for the trainer to consume.
        snr = find_slim_snr(new_opt_state)
        new_clean = strip_slim_snr(strip_step_health(new_opt_state))
        keep_old = lambda n, o: jnp.where(bad, o, n)
        new_params = jax.tree.map(keep_old, new_params, params)
        new_clean = jax.tree.map(keep_old, new_clean, opt_state)
        new_clean = attach_slim_snr(new_clean, snr)

        metrics = dict(metrics)
        metrics["grad_norm"] = gn
        metrics["nonfinite_count"] = nonfinite
        metrics["step_skipped"] = bad.astype(jnp.float32)
        metrics["health_grad_norm"] = health_gn
        return new_params, new_clean, metrics

    return guarded_train_step if guard else train_step


def make_eval_step(cfg, *, forward_fn=None) -> Callable:
    fwd = forward_fn or transformer.forward

    def eval_step(params, batch):
        _, metrics = lm_loss(cfg, params, batch, fwd)
        return metrics

    return eval_step


def make_serve_step(cfg) -> Callable:
    """One batched decode step: (params, cache, tokens (B,1)) -> (next_tokens,
    logits, cache). Greedy argmax sampling (serving example adds temperature)."""

    def serve_step(params, cache, tokens):
        logits, new_cache = transformer.decode_step(cfg, params, cache, tokens)
        next_tokens = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)[:, None]
        return next_tokens, logits, new_cache

    return serve_step
