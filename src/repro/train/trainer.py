"""Trainer: optimizer registry, SNR measurement hooks, checkpoint/restart.

This is the orchestration layer the examples and benchmarks drive. It runs
unsharded on one CPU device (paper-scale experiments) and under a mesh via
the same code path (the launcher supplies shardings).
"""
from __future__ import annotations

import dataclasses
import time
import warnings
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from ..checkpoint import store
from ..core import (
    SNRTracker,
    derive_rules,
    measure_tree_snr,
    rules_as_tree,
    table3_rules,
)
from ..core.baselines import (
    adafactor,
    adalayer_ln_tl_rules,
    adalayer_rules,
    adam_mini_v1_rules,
    adam_mini_v2_rules,
    lion,
    sm3,
)
from ..core.slim_adam import slim_adam
from ..data.pipeline import ZipfLM
from ..optim.adam import adamw, sgdm
from .guard import (
    ROLLBACK,
    Guard,
    GuardConfig,
    find_slim_snr,
    strip_slim_snr as _strip_slim_snr,
)
from .step import make_eval_step, make_train_step

OPTIMIZERS = ("adam", "slim", "slim_snr", "adalayer", "adalayer_ln_tl",
              "adam_mini_v1", "adam_mini_v2", "adafactor", "adafactor_v2",
              "sm3", "lion", "sgdm")


_SLIM_FAMILY = ("slim", "slim_snr", "adalayer", "adalayer_ln_tl",
                "adam_mini_v1", "adam_mini_v2")


def slim_rule_dims(name: str, params, meta, rules: Optional[Dict[str, Any]] = None):
    """Per-leaf reduction-dims pytree the slim-family optimizer ``name``
    compresses with (None for optimizers without compressed moments). One
    derivation shared by :func:`make_optimizer` and the trainer's
    from-update SNR consumer, so the measurement pairs ridden stats with
    exactly the K the update reduced."""
    if name not in _SLIM_FAMILY:
        return None
    if name == "slim":
        r = table3_rules(meta)
    elif name == "slim_snr":
        if rules is None:
            raise ValueError("slim_snr requires derived rules")
        r = rules
    elif name == "adalayer":
        r = adalayer_rules(meta)
    elif name == "adalayer_ln_tl":
        r = adalayer_ln_tl_rules(meta)
    elif name == "adam_mini_v1":
        r = adam_mini_v1_rules(meta)
    else:
        r = adam_mini_v2_rules(meta)
    return rules_as_tree(r, params, meta)


def make_optimizer(name: str, lr, params, meta, *, weight_decay: float = 0.1,
                   b1: float = 0.9, b2: float = 0.95, grad_clip: float = 1.0,
                   rules: Optional[Dict[str, Any]] = None, backend: str = "jnp",
                   mesh=None, param_specs=None, emit_snr: bool = False,
                   emit_health: bool = False):
    """Build any of the paper's optimizers. ``rules`` overrides the rule set
    for 'slim_snr' (derived from a measured SNR pass). ``backend`` selects
    the execution path for the Adam/SlimAdam family ('jnp' | 'fused' |
    'auto', see repro.optim.base.BACKENDS); other optimizers ignore it.
    ``mesh``/``param_specs`` make the fused backend shard-aware (the tree
    update runs under shard_map on the local shards); only the Adam/SlimAdam
    family consumes them. ``emit_snr`` (slim family only) builds the
    measure-step variant whose update publishes from-update SNR scalars on
    the optimizer state (see ``repro.core.slim_adam.scale_by_slim_adam``).
    ``emit_health`` (Adam/slim family) publishes the in-pass StepHealth
    anomaly stats the guarded train step consumes (``repro.train.guard``)."""
    if emit_snr and name not in _SLIM_FAMILY:
        raise ValueError(f"emit_snr is only supported by the slim family "
                         f"{_SLIM_FAMILY}, not {name!r}")
    if emit_health and name not in ("adam",) + _SLIM_FAMILY:
        raise ValueError(f"emit_health is only supported by the Adam/slim "
                         f"family {('adam',) + _SLIM_FAMILY}, not {name!r}")
    if name == "adam":
        return adamw(lr, b1=b1, b2=b2, weight_decay=weight_decay, grad_clip=grad_clip,
                     backend=backend, mesh=mesh, param_specs=param_specs,
                     emit_health=emit_health)
    if name in _SLIM_FAMILY:
        dims = slim_rule_dims(name, params, meta, rules)
        return slim_adam(lr, dims, b1=b1, b2=b2, weight_decay=weight_decay,
                         grad_clip=grad_clip, backend=backend, mesh=mesh,
                         param_specs=param_specs, emit_snr=emit_snr,
                         emit_health=emit_health)
    if name == "adafactor":
        return adafactor(lr, weight_decay=weight_decay, grad_clip=grad_clip)
    if name == "adafactor_v2":
        return adafactor(lr, momentum=0.9, weight_decay=weight_decay, grad_clip=grad_clip)
    if name == "sm3":
        return sm3(lr, beta=0.95, weight_decay=weight_decay, grad_clip=grad_clip)
    if name == "lion":
        return lion(lr, weight_decay=weight_decay, grad_clip=grad_clip)
    if name == "sgdm":
        return sgdm(lr, weight_decay=weight_decay, grad_clip=grad_clip)
    raise ValueError(f"unknown optimizer {name!r}; choose from {OPTIMIZERS}")


def find_adam_nu(opt_state) -> Optional[Any]:
    """Extract the second-moment pytree from a (possibly chained) optimizer
    state — the tensor the paper's SNR analysis runs on."""
    from ..optim.adam import ScaleByAdamState
    from ..core.slim_adam import ScaleBySlimAdamState
    from ..optim.base import ChainState, MultiStepsState

    def walk(node):
        if isinstance(node, (ScaleByAdamState, ScaleBySlimAdamState)):
            return node.nu
        if isinstance(node, ChainState):
            for s in node.inner_states:
                out = walk(s)
                if out is not None:
                    return out
        if isinstance(node, MultiStepsState):
            return walk(node.inner_state)
        return None

    return walk(opt_state)


@dataclasses.dataclass
class TrainerConfig:
    total_steps: int = 1000
    log_every: int = 50
    ckpt_every: int = 0              # 0 = disabled
    ckpt_dir: Optional[str] = None
    ckpt_keep: int = 3
    measure_snr: bool = False
    snr_early_every: int = 100
    snr_late_every: int = 1000
    # Ride the SNR measurement on the update pass: measure steps run a
    # second jitted train step whose optimizer update also emits per-leaf
    # from-update SNR scalars (slim family only; O(kept) extra traffic on
    # the fused backend), and measure_tree_snr consumes them instead of
    # re-reading nu for the candidate K the optimizer already reduces.
    snr_from_update: bool = False
    seed: int = 0
    # Execution backend for the Adam/SlimAdam update and the SNR measurement
    # pass: 'jnp' | 'fused' | 'auto' (fused kernels on TPU, jnp elsewhere).
    # An explicit optimizer_kw['backend'] passed to Trainer wins.
    backend: str = "jnp"
    # Fault-tolerance policy: a GuardConfig turns on the guarded train step
    # (in-pass anomaly health + skip/backoff/rollback, see repro.train.guard);
    # None keeps the plain step with an unchanged jit signature.
    guard: Optional[GuardConfig] = None


class Trainer:
    def __init__(self, model_cfg, optimizer_name: str, lr, data: ZipfLM,
                 tc: TrainerConfig = TrainerConfig(), *, optimizer_kw: Optional[dict] = None,
                 rules: Optional[dict] = None, grad_accum: int = 1, faults=None):
        self.model_cfg = model_cfg
        self.tc = tc
        self.data = data
        # Host-side anomaly policy + (test/drill-only) fault injection plan.
        self.guard = Guard(tc.guard) if tc.guard is not None else None
        self.faults = faults
        self.ckpt_failures = 0
        key = jax.random.PRNGKey(tc.seed)
        self.params, self.meta = model_cfg.init(key)
        okw = dict(optimizer_kw or {})
        okw.setdefault("backend", tc.backend)
        self.backend = okw["backend"]  # one backend for update + SNR pass
        # Under an active ShardingContext the optimizer and the SNR pass get
        # the mesh + param specs, so the fused backend and the SNR
        # measurement run shard-aware (shard_map) instead of letting GSPMD
        # gather leaves around the Pallas optimization barriers.
        from ..sharding.logical import current as current_sharding, param_specs

        ctx = current_sharding()
        self.mesh = ctx.mesh if ctx is not None else None
        self.param_specs = param_specs(self.meta, self.params) if ctx is not None else None
        okw.setdefault("mesh", self.mesh)
        okw.setdefault("param_specs", self.param_specs)
        guarded = self.guard is not None
        # In-pass kernel health only exists on the Adam/slim family; other
        # optimizers still run guarded via the step's grad-norm fallback.
        emit_health = guarded and optimizer_name in ("adam",) + _SLIM_FAMILY
        self.tx = make_optimizer(optimizer_name, lr, self.params, self.meta,
                                 rules=rules, emit_health=emit_health, **okw)
        self.opt_state = self.tx.init(self.params)
        self.step = 0
        self.snr = SNRTracker()
        self.metrics_log: list = []
        self._train_step = jax.jit(make_train_step(
            model_cfg, self.tx, grad_accum=grad_accum, guard=guarded))
        # Measure-step variant: same optimizer built with emit_snr=True, so
        # on SNR cadence steps the update pass itself measures SNR_K along
        # each compressed leaf's own K (state.snr) and maybe_measure_snr
        # skips the extra nu read for that candidate.
        self._train_step_snr = None
        self._update_dims = None
        if tc.measure_snr and tc.snr_from_update and optimizer_name in _SLIM_FAMILY:
            self._update_dims = slim_rule_dims(optimizer_name, self.params,
                                               self.meta, rules)
            tx_snr = make_optimizer(optimizer_name, lr, self.params, self.meta,
                                    rules=rules, emit_snr=True,
                                    emit_health=emit_health, **okw)
            self._train_step_snr = jax.jit(make_train_step(
                model_cfg, tx_snr, grad_accum=grad_accum, guard=guarded))
        self._restored = False
        if tc.ckpt_dir and store.latest_step(tc.ckpt_dir) is not None:
            self.restore()

    # -- fault tolerance ---------------------------------------------------

    def restore(self):
        state = {"params": self.params, "opt": self.opt_state}
        state, extra = store.restore(self.tc.ckpt_dir, state)
        self.params, self.opt_state = state["params"], state["opt"]
        self.step = int(extra.get("step", 0))
        self._restored = True

    def checkpoint(self):
        if not self.tc.ckpt_dir:
            return
        try:
            store.save(self.tc.ckpt_dir, self.step,
                       {"params": self.params, "opt": self.opt_state},
                       extra={"step": self.step}, keep=self.tc.ckpt_keep)
        except OSError as e:
            # A failed save must not kill the run — the atomic tmp-dir
            # protocol guarantees no torn step_* dir was left behind, so we
            # log, count, and train on to the next checkpoint cadence.
            self.ckpt_failures += 1
            warnings.warn(f"checkpoint save failed at step {self.step} "
                          f"({e}); continuing without it")

    def _rollback(self):
        """Guard escalation: restore the last *valid* checkpoint and re-seed
        the data pipeline so the restored trajectory doesn't replay the
        exact batch sequence that diverged."""
        self.guard.note_rollback()
        restored = False
        if self.tc.ckpt_dir and store.latest_step(self.tc.ckpt_dir) is not None:
            try:
                self.restore()
                restored = True
            except FileNotFoundError:
                pass
        if not restored:
            warnings.warn("guard requested rollback but no valid checkpoint "
                          "is available; continuing with backed-off lr")
        bump = self.guard.counters["rollbacks"] * self.tc.guard.reseed_bump
        self.data = ZipfLM(dataclasses.replace(
            self.data.cfg, seed=self.data.cfg.seed + bump))

    # -- SNR hook ------------------------------------------------------------

    def maybe_measure_snr(self):
        if not self.tc.measure_snr:
            return
        if not SNRTracker.should_measure(self.step, self.tc.snr_early_every,
                                         self.tc.snr_late_every):
            return
        nu = find_adam_nu(self.opt_state)
        if nu is None:
            return
        from_upd = (find_slim_snr(self.opt_state)
                    if self._train_step_snr is not None else None)
        snapshot = measure_tree_snr(
            nu, self.meta, backend=self.backend,
            mesh=self.mesh, param_specs=self.param_specs,
            from_update=from_upd,
            update_dims=self._update_dims if from_upd is not None else None)
        self.snr.update(snapshot, self.step)
        if from_upd is not None:
            # Strip the consumed snapshot so checkpoints and the normal
            # step's jit signature keep the snr-less state layout.
            self.opt_state = _strip_slim_snr(self.opt_state)

    # -- main loop -----------------------------------------------------------

    def run(self, steps: Optional[int] = None) -> Dict[str, float]:
        steps = steps if steps is not None else self.tc.total_steps
        t0 = time.time()
        if self.step >= steps:
            # A restored checkpoint can already be at/past the target step.
            # Returning {} here crashed callers that index last["loss"]; run
            # a forward-only eval instead so the no-op still yields the full
            # metrics dict (grad_norm 0: no update happened).
            batch = {k: jnp.asarray(v) for k, v in self.data.batch(self.step).items()}
            metrics = jax.jit(make_eval_step(self.model_cfg))(self.params, batch)
            last = {k: float(v) for k, v in metrics.items()}
            last.update(grad_norm=0.0, step=self.step,
                        wall_s=round(time.time() - t0, 2))
            self.metrics_log.append(last)
            return last
        last = {}
        while self.step < steps:
            batch = self.data.batch(self.step)
            batch = {k: jnp.asarray(v) for k, v in batch.items()}
            # On SNR-cadence steps, run the emit_snr step variant so the
            # measurement rides the update pass (state.snr) instead of
            # paying a separate nu read in maybe_measure_snr.
            step_fn = self._train_step
            if self._train_step_snr is not None and SNRTracker.should_measure(
                    self.step + 1, self.tc.snr_early_every, self.tc.snr_late_every):
                step_fn = self._train_step_snr
            if self.guard is not None:
                # Controls are traced jnp scalars: host policy (lr backoff)
                # and fault injection change them without a recompile.
                g_scale = (self.faults.grad_scale(self.step)
                           if self.faults is not None else 1.0)
                controls = {"lr_scale": jnp.asarray(self.guard.lr_scale, jnp.float32),
                            "grad_scale": jnp.asarray(g_scale, jnp.float32)}
                self.params, self.opt_state, metrics = step_fn(
                    self.params, self.opt_state, batch, controls)
                self.step += 1
                loss = float(metrics["loss"])
                if self.faults is not None:
                    loss = self.faults.corrupt_loss(self.step - 1, loss)
                skipped = bool(metrics["step_skipped"] > 0)
                action = self.guard.observe(
                    loss, skipped=skipped,
                    nonfinite=float(metrics["nonfinite_count"]))
                if skipped:
                    # A measure step that got skipped published SNR from the
                    # discarded update — drop it without consuming.
                    self.opt_state = _strip_slim_snr(self.opt_state)
                else:
                    self.maybe_measure_snr()
                if action == ROLLBACK:
                    self._rollback()
                    continue
            else:
                self.params, self.opt_state, metrics = step_fn(
                    self.params, self.opt_state, batch)
                self.step += 1
                self.maybe_measure_snr()
            if self.step % self.tc.log_every == 0 or self.step == steps:
                last = {k: float(v) for k, v in metrics.items()}
                last.update(step=self.step, wall_s=round(time.time() - t0, 2))
                if self.guard is not None:
                    last.update(self.guard.stats(),
                                ckpt_failures=float(self.ckpt_failures))
                self.metrics_log.append(last)
            if self.tc.ckpt_every and self.step % self.tc.ckpt_every == 0:
                self.checkpoint()
        return last

    def derive_slim_rules(self, cutoff: float = 1.0):
        """Paper §5: turn the tracked SNR averages into SlimAdam rules."""
        return derive_rules(self.snr.averaged(), self.meta, cutoff=cutoff)
