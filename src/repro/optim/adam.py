"""Reference Adam / AdamW / SGD-M built on the transformation API.

This is the *uncompressed* baseline the paper measures against; SlimAdam
(repro.core.slim_adam) must coincide with it exactly when every layer's
compression spec is K = None.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from . import fused
from .base import (
    GradientTransformation,
    ScalarOrSchedule,
    add_decayed_weights,
    chain,
    clip_by_global_norm,
    resolve_backend,
    scale_by_learning_rate,
    trace,
)

PyTree = jax.Array  # loose alias for docs


class ScaleByAdamState(NamedTuple):
    count: jnp.ndarray
    mu: object  # first moments, pytree like params (fp32)
    nu: object  # second moments, pytree like params (fp32)
    # In-pass gradient health (emit_health states only; None otherwise — a
    # None field contributes no pytree leaves, so checkpoints/jit layouts of
    # plain states are unchanged). See repro.optim.fused.StepHealth.
    health: object = None


def scale_by_adam(b1: float = 0.9, b2: float = 0.999, eps: float = 1e-8, *,
                  backend: str = "jnp",
                  bucket_min_size: int = fused.DEFAULT_BUCKET_MIN,
                  mesh=None, param_specs=None,
                  emit_health: bool = False,
                  megakernel: bool = True) -> GradientTransformation:
    """Adam preconditioner. ``backend`` selects the execution path
    (see ``repro.optim.base.BACKENDS``): 'fused' streams eligible leaves
    through the Pallas kernels — by default grouped into megaplan
    super-tensors (O(1) launches per tree update; ``megakernel=False``
    restores the per-leaf dispatch with small-leaf bucketing); state layout
    and results are identical to 'jnp' up to fp32 rounding.

    ``mesh`` + ``param_specs`` (a PartitionSpec pytree mirroring params)
    make the fused backend shard-aware: the tree update runs under
    ``shard_map`` on each device's local shards instead of letting GSPMD
    gather full leaves around the pallas_call optimization barrier. Ignored
    by the jnp backend — plain jax.numpy partitions natively under pjit.

    ``emit_health=True`` publishes a :class:`repro.optim.fused.StepHealth`
    on ``state.health`` each update — per-leaf non-finite counts + the
    finite-masked grad sumsq, accumulated by the kernels' own passes (the
    guarded train step reads it to skip poisoned steps; see
    ``repro.train.guard``)."""
    backend = resolve_backend(backend)
    if backend == "fused" and (mesh is not None or param_specs is not None):
        from ..sharding.shardspec import normalize_spec_leaves, sharded_pair

        mesh, param_specs = sharded_pair(mesh, param_specs, "scale_by_adam")
    else:
        mesh = None

    def init_fn(params):
        mu = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
        nu = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
        return ScaleByAdamState(count=jnp.zeros([], jnp.int32), mu=mu, nu=nu)

    def update_fn(updates, state, params=None):
        del params
        count = state.count + 1
        g_leaves, treedef = jax.tree_util.tree_flatten(updates)
        mu_leaves = treedef.flatten_up_to(state.mu)
        nu_leaves = treedef.flatten_up_to(state.nu)
        health = None
        if backend == "fused":
            spec_leaves = (None if mesh is None else normalize_spec_leaves(
                param_specs, treedef, "scale_by_adam"))
            out = fused.adam_tree_update(
                g_leaves, mu_leaves, nu_leaves, b1=b1, b2=b2, eps=eps,
                count=count, bucket_min_size=bucket_min_size,
                mesh=mesh, spec_leaves=spec_leaves, with_health=emit_health,
                megakernel=megakernel)
            u, mu_l, nu_l = out[:3]
            if emit_health:
                health = out[3]
        else:
            # Per-leaf reference math shared with the fused backend's
            # fallback leaves — one definition of the semantics oracle.
            outs = [fused.jnp_adam_leaf(g, m, v, b1=b1, b2=b2, eps=eps, count=count)
                    for g, m, v in zip(g_leaves, mu_leaves, nu_leaves)]
            u = [o[0] for o in outs]
            mu_l = [o[1] for o in outs]
            nu_l = [o[2] for o in outs]
            if emit_health:
                health = fused._health_from_rows(
                    [fused.leaf_health(g) for g in g_leaves])
        unflat = lambda leaves: jax.tree_util.tree_unflatten(treedef, leaves)
        return unflat(u), ScaleByAdamState(count=count, mu=unflat(mu_l),
                                           nu=unflat(nu_l), health=health)

    return GradientTransformation(init_fn, update_fn)


def adamw(
    learning_rate: ScalarOrSchedule,
    b1: float = 0.9,
    b2: float = 0.95,
    eps: float = 1e-8,
    weight_decay: float = 0.1,
    grad_clip: Optional[float] = 1.0,
    backend: str = "jnp",
    mesh=None,
    param_specs=None,
    emit_health: bool = False,
    megakernel: bool = True,
) -> GradientTransformation:
    """The paper's training recipe: clip(1.0) -> Adam -> decoupled wd -> -lr.

    ``mesh``/``param_specs`` thread to :func:`scale_by_adam` so the fused
    backend runs shard-aware under a production mesh; ``emit_health`` and
    ``megakernel`` thread there too."""
    parts = []
    if grad_clip is not None:
        parts.append(clip_by_global_norm(grad_clip))
    parts.append(scale_by_adam(b1=b1, b2=b2, eps=eps, backend=backend,
                               mesh=mesh, param_specs=param_specs,
                               emit_health=emit_health, megakernel=megakernel))
    if weight_decay:
        parts.append(add_decayed_weights(weight_decay, mask=lambda p: jax.tree.map(lambda x: x.ndim >= 2, p)))
    parts.append(scale_by_learning_rate(learning_rate))
    return chain(*parts)


def sgdm(
    learning_rate: ScalarOrSchedule,
    momentum: float = 0.9,
    nesterov: bool = False,
    weight_decay: float = 0.0,
    grad_clip: Optional[float] = 1.0,
) -> GradientTransformation:
    parts = []
    if grad_clip is not None:
        parts.append(clip_by_global_norm(grad_clip))
    if weight_decay:
        parts.append(add_decayed_weights(weight_decay, mask=lambda p: jax.tree.map(lambda x: x.ndim >= 2, p)))
    parts.append(trace(momentum, nesterov=nesterov))
    parts.append(scale_by_learning_rate(learning_rate))
    return chain(*parts)
