"""Reference Adam / AdamW / SGD-M built on the transformation API.

This is the *uncompressed* baseline the paper measures against; SlimAdam
(repro.core.slim_adam) must coincide with it exactly when every layer's
compression spec is K = None.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from .base import (
    GradientTransformation,
    ScalarOrSchedule,
    add_decayed_weights,
    chain,
    clip_by_global_norm,
    scale_by_learning_rate,
    trace,
)

PyTree = jax.Array  # loose alias for docs


class ScaleByAdamState(NamedTuple):
    count: jnp.ndarray
    mu: object  # first moments, pytree like params (fp32)
    nu: object  # second moments, pytree like params (fp32)


def bias_correction(decay: float, count: jnp.ndarray) -> jnp.ndarray:
    return 1.0 - jnp.power(jnp.asarray(decay, jnp.float32), count.astype(jnp.float32))


def scale_by_adam(b1: float = 0.9, b2: float = 0.999, eps: float = 1e-8) -> GradientTransformation:
    def init_fn(params):
        mu = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
        nu = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
        return ScaleByAdamState(count=jnp.zeros([], jnp.int32), mu=mu, nu=nu)

    def update_fn(updates, state, params=None):
        del params
        count = state.count + 1
        mu = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g.astype(jnp.float32), state.mu, updates)
        nu = jax.tree.map(
            lambda v, g: b2 * v + (1 - b2) * jnp.square(g.astype(jnp.float32)), state.nu, updates
        )
        bc1 = bias_correction(b1, count)
        bc2 = bias_correction(b2, count)

        def precond(m, v):
            m_hat = m / bc1
            v_hat = v / bc2
            return m_hat / (jnp.sqrt(v_hat) + eps)

        new_updates = jax.tree.map(precond, mu, nu)
        return new_updates, ScaleByAdamState(count=count, mu=mu, nu=nu)

    return GradientTransformation(init_fn, update_fn)


def adamw(
    learning_rate: ScalarOrSchedule,
    b1: float = 0.9,
    b2: float = 0.95,
    eps: float = 1e-8,
    weight_decay: float = 0.1,
    grad_clip: Optional[float] = 1.0,
) -> GradientTransformation:
    """The paper's training recipe: clip(1.0) -> Adam -> decoupled wd -> -lr."""
    parts = []
    if grad_clip is not None:
        parts.append(clip_by_global_norm(grad_clip))
    parts.append(scale_by_adam(b1=b1, b2=b2, eps=eps))
    if weight_decay:
        parts.append(add_decayed_weights(weight_decay, mask=lambda p: jax.tree.map(lambda x: x.ndim >= 2, p)))
    parts.append(scale_by_learning_rate(learning_rate))
    return chain(*parts)


def sgdm(
    learning_rate: ScalarOrSchedule,
    momentum: float = 0.9,
    nesterov: bool = False,
    weight_decay: float = 0.0,
    grad_clip: Optional[float] = 1.0,
) -> GradientTransformation:
    parts = []
    if grad_clip is not None:
        parts.append(clip_by_global_norm(grad_clip))
    if weight_decay:
        parts.append(add_decayed_weights(weight_decay, mask=lambda p: jax.tree.map(lambda x: x.ndim >= 2, p)))
    parts.append(trace(momentum, nesterov=nesterov))
    parts.append(scale_by_learning_rate(learning_rate))
    return chain(*parts)
