from .base import (
    GradientTransformation,
    apply_updates,
    add_decayed_weights,
    chain,
    clip_by_global_norm,
    global_norm,
    identity,
    multi_steps,
    scale,
    scale_by_learning_rate,
    scale_by_schedule,
    trace,
)
from .adam import adamw, scale_by_adam, sgdm, ScaleByAdamState, bias_correction
from . import schedules

__all__ = [
    "GradientTransformation",
    "apply_updates",
    "add_decayed_weights",
    "chain",
    "clip_by_global_norm",
    "global_norm",
    "identity",
    "multi_steps",
    "scale",
    "scale_by_learning_rate",
    "scale_by_schedule",
    "trace",
    "adamw",
    "scale_by_adam",
    "sgdm",
    "ScaleByAdamState",
    "bias_correction",
    "schedules",
]
