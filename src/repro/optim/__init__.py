"""Optimizer substrate: optax-style transformations + the fused Pallas backend.

Execution backends
------------------
``scale_by_adam``, ``adamw`` (here), and ``scale_by_slim_adam`` / ``slim_adam``
(repro.core.slim_adam) take a ``backend`` argument, threaded from the trainer
layer via ``TrainerConfig.backend`` / ``make_optimizer(backend=...)``:

``backend="jnp"`` (default)
    The reference per-leaf ``jax.numpy`` tree-map. Runs on any platform and
    is the semantics oracle for everything below.

``backend="fused"``
    Per-leaf routing through the Pallas kernels (``repro.optim.fused``):

    Every leaf's route is one precomputed ``repro.kernels.leaf_plan`` lookup
    (canonicalization plan -> VMEM fits-gate -> kernel pick):

    * dense leaves (Adam, or SlimAdam K = ()) are canonicalized to 2-D and
      dispatched to the fused dense kernel; leaves smaller than
      ``bucket_min_size`` (default 16k elements) are *bucketed* — flattened,
      concatenated, updated in one kernel call, and scattered back — to
      amortize per-call launch and tile-padding overhead;
    * compressed leaves (SlimAdam K != ()) are planned by
      ``repro.kernels.canon_nd`` onto the batched canonical form
      ``(B, R, C)`` — whichever layout a *pure reshape* reaches — and
      dispatched to the matching slim kernel: reduced dims trailing ->
      minor orientation (lane reduction, ``slim_precond``; fan_in of a
      standard fan_in-minor weight); reduced dims leading -> major
      orientation (sublane reduction, ``slim_precond_major``; fan_out,
      conv fan_in); reduced dims *between* kept axes -> batched major
      (``slim_precond_batched``: the kept prefix splits off as a batch
      axis walked by the kernel grid, so a scan-stacked (layers, embed,
      heads, head_dim) tensor reducing embed runs as ``layers``
      independent transpose-free 2-D problems — exactly the paper's / Adam-
      mini's treatment of stacked layers as independent slices). Size-1
      axes never force a transpose. Only a genuinely *interleaved* K —
      the reduced dims not forming one contiguous block with kept dims
      only outside it (a kept dim inside the reduced span, or reduced
      blocks on both ends of a kept dim) — still materializes a boundary
      transpose (a pallas_call is an optimization barrier, so
      XLA cannot fuse the re-layout into the kernel; the opt_speed
      roofline charges those leaves the extra passes, and `make
      bench-roofline` fails if any GPT-small leaf regresses into that
      class);
    * leaves the kernels can't serve fall back to the jnp path per leaf:
      scalar (0-d) leaves, non-float dtypes, empty tensors, leaves whose
      canonical reduction line outruns VMEM in either orientation, and the
      ``use_first_moment=False`` variant (the kernels stream a first
      moment; serving it would forfeit the bandwidth win).

    Off-TPU the kernels run in Pallas interpret mode (a correctness harness,
    not a speedup); state layout and results match ``"jnp"`` to fp32
    rounding (tests assert 1e-5 over a full GPT-small param tree).

    **Megakernel (the default dispatch).** Per-leaf launches price the tree
    update at O(leaves) kernel dispatches — grid setup, operand plumbing,
    and an XLA fusion barrier per leaf, on a step whose arithmetic is pure
    bandwidth. ``repro.kernels.megaplan`` collapses that to O(groups) ≈
    O(1): every kernel-served leaf is keyed by regime and line geometry
    (``dense`` lane-folds any shape flat; ``minor``/``major``/``batched``
    key on the canonical reduced extent), same-key leaves are concatenated
    along the *kept* axis into one padded super-tensor (so no reduction
    line ever crosses a leaf boundary — per-line arithmetic is unchanged),
    and one segment-aware kernel (``mega_adam_update``,
    ``mega_slim_update_batched``, the partial/finalize pair for the psum
    regime) updates the whole group in a single launch. dtype never splits
    a group: the gather casts to the f32 compute type, so a bf16 leaf
    rides with its f32 neighbours. Per-leaf scalars (bias corrections)
    enter as O(kept) line operands expanded from the static segment table
    (``segment_table``: one ``[leaf, position, line_extent, bc_slot]`` row
    per kept line, checked injective by ``repro.analysis`` races pass);
    updates scatter back by segment offset. GPT-small's whole tree updates
    in 1 dense-Adam launch or 4 SlimAdam group launches (vs 11 per-leaf) —
    the ``--check-launches`` CI gate holds it ≤ 8 on the traced jaxpr, and
    on real TPU backends additionally requires fused wall-clock ≤ jnp.
    Excluded from grouping: the per-leaf jnp fallbacks (0-d, non-float,
    VMEM-outrun leaves) — unchanged; and the health/SNR stats, which the
    mega kernels emit per *line* (injective outputs, no shared
    accumulator) and the caller sums per segment, trading the per-leaf
    kernels' O(1) accumulator for race-freedom across segments.
    ``megakernel=False`` on any transformation restores the per-leaf
    dispatch (with small-leaf bucketing) as the parity oracle — state
    matches the grouped path bit-for-bit; updates to a couple of fp32 ULP
    (XLA clones the moment recurrences into the update fusion and makes
    per-fusion FMA contraction choices that differ across shapes).

``backend="auto"``
    Resolves to ``"fused"`` on TPU and ``"jnp"`` everywhere else, so the
    interpreter is never on a production hot path.

Shard-aware execution (mesh + param_specs)
------------------------------------------
A ``pallas_call`` is a GSPMD optimization barrier: under plain pjit on a
mesh, the partitioner must gather full leaves around the fused kernels (or
replicate the call), forfeiting the bandwidth win exactly where it matters.
Passing ``mesh`` + ``param_specs`` (a PartitionSpec pytree mirroring params,
from ``repro.sharding.logical.param_specs``) to ``scale_by_adam`` /
``adamw`` / ``scale_by_slim_adam`` / ``slim_adam`` — threaded from
``make_optimizer`` / ``TrainerConfig`` at the trainer layer and from
``--backend fused`` in ``repro.launch.train`` / ``repro.launch.dryrun`` —
wraps the fused tree update in ``shard_map`` so each device streams only its
local shards. Every leaf is classified by one
``repro.sharding.shardspec.plan_sharded_leaf`` lookup into three regimes
(the megaplan grouping composes inside the shard_map body: local and dense
leaves group on their *local* shard geometry, psum leaves group per
collective form — owner-placed and replicated-write separately — with the
per-leaf ``lax.psum`` between the two grouped passes):

  * **reduced dims unsharded ('local')** — the reduction line is whole on
    every shard, so the unchanged kernels (dense, slim minor/major/batched,
    bucketing included) run per shard with plans re-derived from the *local*
    shard shape. Bit-identical to the single-device fused path.
  * **reduced dims sharded ('psum')** — Pallas-resident end to end: pass 1
    (``slim_partial_stats``, the strip-grid kernel pair in
    ``repro.kernels.slim_update``) reads g and m and writes m_new plus the
    per-line partial g^2 sums; a ``lax.psum`` over the owning mesh axes
    completes the lines; pass 2 (``slim_finalize``) reads m_new and writes
    the preconditioned update — 5 full-size passes total, nothing left to
    XLA fusion. The collective carries only the O(kept) compressed moment —
    deleting the moment's TP axis also deleted its collective traffic
    (``state_shardings``), and this is the payoff. Local plans the kernel
    pair cannot serve fall back to jnp and are counted separately
    ('psum_jnp' in ``regime_counts``; the CI gate holds it at zero for
    GPT-small). Matches single-device to fp32 reassociation (<= 1e-6).

    **Owner-shard moment writes**: the reduced moment of a psum leaf is
    replicated across the psum group, so PR 4 wrote the same O(kept) v_new
    on every shard. Now each plan carries an owner placement
    (``repro.sharding.shardspec.owner_placement``: psum axes assigned onto
    kept dims they divide evenly) and v is *stored* as a 1/A owner slice:
    each shard folds ``b2 * v`` for the lines it owns into the partial-sums
    payload, so the same all-reduce that completes E_K[g^2] also broadcasts
    the completed v_new — the moment's read and write shrink by A with
    **zero** extra ICI (an explicit gather would cost ~16x more wall time
    per byte than the HBM it saves; riding the collective costs nothing).
    Leaves with no evenly-dividing kept dim (GPT-small: only embed's
    50304-vocab vs a 256-way group) keep the replicated write. Moments are
    cast back to their stored dtype at the boundary, so bf16 states stay
    bf16 through the psum path.
  * **interleaved K after sharding ('jnp')** — plans that would need a
    materialized boundary transpose on the shard run the reference jnp math
    locally instead; ``repro.sharding.shardspec.regime_counts`` reports how
    many leaves fell here so a planner regression is visible (none in
    GPT-small).

The SNR measurement composes the same way: ``measure_tree_snr(mesh=...,
param_specs=...)`` runs per-leaf under shard_map, completing sharded
reduction lines via the snr_stats kernels' partial-sums entry point — each
shard's shift-centered sums are rebased to a mesh-common shift (exact
O(spread) algebra, ``repro.kernels.ref.rebase_centered_stats``) and then
psummed, preserving the one-pass centered-variance accuracy across the
shard boundary.

**From-update SNR (the measurement rides the update pass).** Built with
``emit_snr=True``, ``scale_by_slim_adam`` / ``slim_adam`` publish a per-leaf
SNR scalar on ``state.snr``: the update kernels' strip loops also emit
shift-centered sums of g^2 per reduction line (``with_snr`` outputs of
``slim_precond_batched`` / ``slim_partial_stats``), finalized against the
new moment as SNR_K of the dense reconstruction ``b2*V + (1-b2)*g^2`` — the
second moment dense Adam would hold this step given the compressed history.
A measure step therefore adds only O(kept) stat lines over a plain update
step (asserted by the sharded roofline gate); under shard_map the stats
rebase + psum exactly like the snr_stats partial entries.
``measure_tree_snr(from_update=..., update_dims=...)`` consumes the ridden
scalars for each leaf's own K and falls back to the standard nu measurement
for the other candidates; ``TrainerConfig.snr_from_update`` wires the whole
path (measure-cadence steps run a second jitted step variant).

``benchmarks/opt_speed.py --sharded`` reports the per-shard byte model on
the production (data=16, model=16) mesh: GPT-small's *compressed leaves*
stream ~0.7150x of per-shard dense-Adam bytes (5/7 = 0.7143 floor + the
O(kept) terms the owner dedupe cannot remove, chiefly embed), ~0.7216x over
the full tree (dense K = () leaves weigh ~3.5x more per shard than on one
device: embed shards 256x, pos_embed only 16x), plus ~247 KiB/step of ICI
for the psum lines. The ``--check-roofline --sharded`` CI gate holds every
transpose-free leaf to per-shard bytes <= single-device bytes / min(shard
counts), the psum regime to zero jnp-finalize fallbacks, the compressed
ratio to <= 0.716, and the fused-SNR measure-step delta to O(kept).

Guards & degradation (the fault-tolerant substrate)
---------------------------------------------------
Three independent safety layers, cheapest first:

**In-pass anomaly health (``emit_health=True``).** Built with
``emit_health=True``, ``scale_by_adam`` / ``adamw`` / ``scale_by_slim_adam``
/ ``slim_adam`` publish a ``repro.optim.fused.StepHealth`` on
``state.health``: a per-leaf non-finite-entry count plus the global
finite-masked grad sum-of-squares (the norm stays meaningful on a poisoned
step). Kernel-served leaves accumulate both terms *inside* the update
kernels (the ``with_health`` outputs of ``adam_precond`` /
``slim_precond_batched`` / ``slim_partial_stats_batched``): every grid
instance maps to one shared (2,) accumulator block, so the health stats ride
the update's existing HBM traffic — one O(1) scalar output per kernel, no
extra tensor pass (the sharded roofline gate asserts exactly one extra
output of <= 2 elements per kernel). jnp-fallback leaves use the
``leaf_health`` twin; under shard_map the per-leaf rows are de-duplicated by
replication factor and completed with the same ``lax.psum`` that carries the
moments. ``health=None`` states contribute no pytree leaves, so non-guarded
checkpoints and jit signatures are unchanged.

**Guarded step + policy (``repro.train.guard``).** ``make_train_step(...,
guard=True)`` returns a 4-arg step taking a ``controls`` dict
(``lr_scale`` / ``grad_scale`` as traced scalars — no recompiles): a step
whose health says *bad* is skipped functionally (``jnp.where`` keeps params,
moments, and count bit-identical; the skip is visible as
``metrics["step_skipped"]``). The host-side ``Guard`` policy layers on top:
loss-spike detection (z-score over a rolling window) backs off the lr
multiplicatively; K consecutive bad steps escalate to a rollback onto the
last valid checkpoint with a deterministic data re-seed
(``Trainer(..., TrainerConfig(guard=GuardConfig(...)))`` or
``repro.launch.train --guard``).

**Graceful kernel degradation.** Every Pallas leaf launch in
``repro.optim.fused`` runs under a guard: if the kernel path raises, the
leaf degrades to the jnp reference math (same numbers, one warning), and
``kernel_degraded_leaves()`` / the ``'degraded'`` key of
``repro.sharding.shardspec.regime_counts`` make the demotion visible instead
of silent. ``repro.train.faults`` provides deterministic injectors (NaN/Inf
grads, loss spikes, checkpoint IO failures, kernel failures, torn
checkpoints) and ``benchmarks/fault_drill.py`` is the CI gate: an injected
gpt_small run must complete within 2% of the clean run's eval loss with
every injection visible in the counters (``scripts/ci.sh fault-drill``).

Static contracts (``repro.analysis`` — the device-free CI gate)
---------------------------------------------------------------
Everything above rests on invariants that only fail visibly on real TPUs —
where CI has none. ``python -m repro.analysis`` (``scripts/ci.sh analyze``,
between lint and test-fast) re-derives them from jaxprs, ``eval_shape``
signatures, and source ASTs in a few seconds with zero devices:

  * **kernelcheck** — every registered kernel entry
    (``repro.analysis.registry``: the dense/slim/partial/finalize/snr
    families over a shape x dtype x K-pattern matrix) is abstractly traced;
    the declared ``*_BUFS`` constants must bracket the live full-size blocks
    in the jaxpr, cases admitted by the ``strip_fits`` gate must fit
    ``VMEM_BUDGET`` at the f32 compute itemsize, bf16/f16 blocks must be
    read through an immediate cast to f32 and written through a cast back
    (the f32-compute contract behind ``COMPUTE_ITEMSIZE``), variant extras
    must stay O(kept), and the full output-signature matrix must match
    ``analysis/golden_signatures.json`` (accept intentional changes with
    ``python -m repro.analysis --update-golden`` and commit the file).
  * **races** — any output block shared across grid instances (the (2,)
    health accumulators) must ride only sequential grid dims and be
    read-modify-write in the kernel body.
  * **shardcheck** — ``plan_sharded_leaf`` geometry over the whole config
    zoo x mesh matrix: owner placements all-or-nothing and evenly dividing,
    ``nu_spec`` realizing the claimed dedupe factor, ``psum_jnp == 0`` on
    the production mesh, and ``opt_state_specs`` accepting every triple.
  * **tracecheck** — the guarded 4-arg step traces identically across
    differing control values and actually consumes them, and the
    Guard/trainer controls keep stable avals across a backoff: the
    "no recompiles" promise, checked without compiling.
  * **lint** — AST rules: kernels only under ``repro/kernels`` (RPR001), no
    host numpy / traced-value branching in kernel bodies or jitted
    functions (RPR002), optional ``*State`` fields default ``None``
    (RPR003), checkpoint publishes stay atomic (RPR004).

The roofline gates in ``benchmarks/opt_speed.py`` read their kernel
signature facts (``snr_stat_lines`` / ``health_stat_outputs``) from the
same registry, so the byte model and the static checker cannot drift apart.

Why fused is the hot path (bytes-streamed model)
------------------------------------------------
The optimizer step is pure HBM bandwidth. Per leaf of n fp32 elements and r
kept rows, one fused step streams:

    dense Adam     7n * 4 B      (p, g, m, v read + p', m', v' write)
    SlimAdam (K)   5n * 4 B + O(r)   (V is (r, 1); E_K[g^2] never hits HBM)

i.e. compressed leaves stream 5/7 ≈ 0.71 of dense-Adam bytes — the paper's
memory saving is also a step-time saving. With the batched (B, R, C)
canonical form, fan_in-, fan_out-, *and* scan-stacked-middle-K leaves all
hit that floor transpose-free; only genuinely interleaved-K leaves (none in
GPT-small) pay re-layout traffic. ``benchmarks/opt_speed.py`` reports
measured interpret-mode times next to the roofline projection
(bytes / 819 GB/s, TPU v5e): ~25.6 us vs ~35.8 us per 1024x1024 fp32 tensor,
and a tree-level column for the whole GPT-small parameter tree, whose
compressed-tree bytes now sit at ~0.72x of dense Adam (the 5/7 floor plus
O(kept) moments — down from 0.88x when the stacked wq/wk leaves still
transposed). The GradientTransformation form used here (update emitted,
params untouched) streams 6n (dense) / 4n + O(kept) (slim) instead.
"""
from . import fused, schedules
from .adam import ScaleByAdamState, adamw, scale_by_adam, sgdm
from .base import (
    BACKENDS,
    GradientTransformation,
    add_decayed_weights,
    apply_updates,
    chain,
    clip_by_global_norm,
    global_norm,
    identity,
    multi_steps,
    resolve_backend,
    scale,
    scale_by_learning_rate,
    scale_by_schedule,
    trace,
)

__all__ = [
    "BACKENDS",
    "GradientTransformation",
    "apply_updates",
    "add_decayed_weights",
    "chain",
    "clip_by_global_norm",
    "global_norm",
    "identity",
    "multi_steps",
    "resolve_backend",
    "scale",
    "scale_by_learning_rate",
    "scale_by_schedule",
    "trace",
    "adamw",
    "scale_by_adam",
    "sgdm",
    "ScaleByAdamState",
    "fused",
    "schedules",
]
