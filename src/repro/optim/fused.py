"""Fused optimizer backend: route Adam/SlimAdam pytree updates through the
Pallas kernels.

The jnp tree-map path materializes every intermediate (g^2, m_hat, v_hat, ...)
in HBM; the fused kernels stream each tensor exactly once. Per optimizer step
and leaf the bandwidth model is

    dense Adam       7 passes   (p, g, m, v read + p', m', v' write)
    SlimAdam (K)     5 passes + O(kept)   (V reduced over K never leaves VMEM)

and in GradientTransformation form (this module: update emitted, p untouched)

    dense precond    6 passes   (g, m, v read + u, m', v' write)
    slim precond     4 passes + O(kept)

This module implements the per-leaf routing used by
``repro.optim.adam.scale_by_adam`` and ``repro.core.slim_adam.scale_by_slim_adam``
when constructed with ``backend="fused"`` (or ``"auto"`` on TPU). Every
dispatch decision is one precomputed :func:`repro.kernels.leaf_plan` lookup —
canonicalization plan, VMEM fits-gate, and route in a single place:

  * canonicalization — compressed leaves go to the batched (B, R, C)
    canonical form via :func:`repro.kernels.canon_nd`: trailing K -> minor,
    leading K -> major, kept-prefix/K/kept-suffix (scan-stacked leaves) ->
    batched major, each reachable by pure reshape; only a genuinely
    interleaved K transposes. Dense leaves reshape to (rows, minor);
  * dispatch — dense leaves -> ``adam_precond``; compressed leaves ->
    ``slim_precond`` / ``slim_precond_major`` / ``slim_precond_batched``
    per the plan, with a per-leaf jnp fallback for anything the kernels
    can't serve (scalar leaves, non-float dtypes, empty tensors, reduction
    lines that outrun VMEM, the moment-less ``use_first_moment=False``
    variant);
  * bucketing — small dense-treated leaves (elementwise treatment, so
    flattening is exact) are concatenated into one flat super-tensor per
    bucket, updated in a single kernel call to amortize launch + padding
    overhead, and scattered back to the original leaves by an offset map.

All public entry points accept a traced ``count`` (the optimizer step is
jitted state), and every returned moment/update is fp32, matching the jnp
path bit-for-bit up to fp32 reassociation.
"""
from __future__ import annotations

import math
import warnings
from typing import Any, Callable, Dict, List, NamedTuple, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from .. import injection
from ..kernels import megaplan
from ..kernels.fused_adam import LANES, bias_corrections
from ..kernels.ops import (
    CanonND,
    adam_precond,
    canon_apply,
    canon_restore,
    default_interpret,
    leaf_plan,
    slim_finalize_batched,
    slim_partial_stats_batched,
    slim_precond,
    slim_precond_batched,
    slim_precond_major,
)
from ..kernels.slim_update import PRECOND_BUFS, PRECOND_SNR_BUFS
from ..kernels.snr_stats import snr_update_stats_finalize

# 0/0 guard for exactly-constant lines in the from-update SNR (matches
# repro.core.snr._VAR_EPS so both measurement paths agree on the limit).
_SNR_EPS = 1e-30

Dims = Tuple[int, ...]

# Leaves below this element count get bucketed (one kernel call per bucket
# instead of per leaf). 16k elements ~ 64 KiB fp32: far below the per-call
# tile, so launch/pad overhead dominates any per-leaf call at this size.
DEFAULT_BUCKET_MIN = 1 << 14


def _bucket_eligible(size: int, bucket_min_size: int) -> bool:
    """Single definition of the small-leaf boundary: strictly below the
    threshold buckets, exactly at it runs per-leaf. Every site must call
    this — the bucketing decision and the flush path once disagreed at
    ``size == bucket_min_size``, splitting threshold-sized leaves between
    two dispatch shapes."""
    return bool(bucket_min_size) and size < bucket_min_size


class StepHealth(NamedTuple):
    """In-pass gradient health of one tree update.

    ``nonfinite``: (n_leaves,) fp32 — per-leaf count of non-finite gradient
    entries. ``grad_sumsq``: () fp32 — global sum of squares over the
    *finite* entries, so the gradient norm stays meaningful on a poisoned
    step. Kernel-served leaves accumulate both inside the update kernels
    (one O(1) output per call, zero extra tensor passes); jnp leaves fuse
    the same sums into their existing elementwise pass.
    """
    nonfinite: jnp.ndarray
    grad_sumsq: jnp.ndarray

    @property
    def bad(self) -> jnp.ndarray:
        """() bool — any non-finite gradient entry anywhere in the tree."""
        return (jnp.sum(self.nonfinite) > 0) | ~jnp.isfinite(self.grad_sumsq)

    @property
    def grad_norm(self) -> jnp.ndarray:
        """() fp32 — global norm over the finite gradient entries."""
        return jnp.sqrt(self.grad_sumsq)


def leaf_health(g) -> jnp.ndarray:
    """``[nonfinite_count, finite_masked_sumsq]`` of one leaf — the jnp
    twin of the kernels' in-pass accumulator
    (:func:`repro.kernels.fused_adam.health_terms`)."""
    g32 = g.astype(jnp.float32)
    fin = jnp.isfinite(g32)
    nf = jnp.sum(jnp.where(fin, 0.0, 1.0))
    ss = jnp.sum(jnp.where(fin, jnp.square(g32), 0.0))
    return jnp.stack([nf, ss])


def _health_from_rows(rows: Sequence[jnp.ndarray]) -> StepHealth:
    """Stack per-leaf (2,) health rows into a :class:`StepHealth`."""
    h = jnp.stack(list(rows)) if len(rows) else jnp.zeros((0, 2), jnp.float32)
    return StepHealth(nonfinite=h[:, 0], grad_sumsq=jnp.sum(h[:, 1]))


# ---------------------------------------------------------------------------
# Graceful kernel degradation
# ---------------------------------------------------------------------------
#
# A Pallas trace/compile failure on one leaf (driver regression, an exotic
# layout the backend rejects, an injected fault in tests) should cost that
# leaf its bandwidth win, not the whole run. Kernel leaf calls route through
# _guarded(): on any exception the leaf silently re-routes to the reference
# jnp math, a one-time warning names the first failure, and the count is
# queryable (and feeds regime_counts(..., degraded=...)).

_DEGRADED = {"leaves": 0, "warned": False}
KERNEL_FAULT_POINT = "optim.kernel"


def set_kernel_fault_hook(hook: Optional[Callable[[str], None]]) -> None:
    """Install a fault-injection hook called (with a leaf label) before every
    guarded kernel dispatch — raise from it to simulate a Pallas failure.
    ``None`` uninstalls. Registered at the shared ``"optim.kernel"`` point
    (:mod:`repro.injection`). Test/benchmark instrumentation only."""
    injection.install(KERNEL_FAULT_POINT, hook)


def kernel_degraded_leaves() -> int:
    """Leaf calls that degraded kernel -> jnp since the last reset."""
    return _DEGRADED["leaves"]


def reset_kernel_degradation() -> None:
    _DEGRADED["leaves"] = 0
    _DEGRADED["warned"] = False


def _guarded(label: str, kernel_fn: Callable[[], Any], jnp_fn: Callable[[], Any],
             *, leaves: int = 1):
    try:
        injection.fire(KERNEL_FAULT_POINT, label)
        return kernel_fn()
    except Exception as e:  # noqa: BLE001 — any kernel failure degrades
        _DEGRADED["leaves"] += leaves
        if not _DEGRADED["warned"]:
            _DEGRADED["warned"] = True
            warnings.warn(
                f"Pallas kernel path failed for {label} "
                f"({type(e).__name__}: {e}); degrading leaf to the jnp "
                f"reference path", stacklevel=2)
        return jnp_fn()


# ---------------------------------------------------------------------------
# Per-leaf paths
# ---------------------------------------------------------------------------


def jnp_adam_leaf(g, m, v, *, b1, b2, eps, count):
    """Reference Adam leaf update — the single jnp definition of the
    semantics; the 'jnp' backend and the fused backend's fallback leaves
    both call this, with :func:`bias_corrections` shared with the kernels."""
    g32 = g.astype(jnp.float32)
    m_new = b1 * m + (1 - b1) * g32
    v_new = b2 * v + (1 - b2) * jnp.square(g32)
    bc1, bc2 = bias_corrections(b1, b2, count)
    u = (m_new / bc1) / (jnp.sqrt(v_new / bc2) + eps)
    return u, m_new, v_new


def jnp_slim_leaf(g, m, v, dims: Dims, *, b1, b2, eps, count, use_first_moment):
    """Reference SlimAdam leaf update (see :func:`jnp_adam_leaf`)."""
    g32 = g.astype(jnp.float32)
    g2 = jnp.square(g32)
    ek = jnp.mean(g2, axis=dims, keepdims=True) if dims else g2
    v_new = b2 * v + (1 - b2) * ek
    bc1, bc2 = bias_corrections(b1, b2, count)
    if use_first_moment:
        m_new = b1 * m + (1 - b1) * g32
        num = m_new / bc1
    else:
        m_new = None
        num = g32
    u = num / (jnp.sqrt(v_new / bc2) + eps)
    return u, m_new, v_new


def jnp_update_snr_leaf(g32, v_new, dims: Dims, *, b2) -> jnp.ndarray:
    """Reference from-update SNR for one compressed leaf (scalar).

    SNR_K of the step's dense reconstruction ``b2 * V_red + (1 - b2) * g^2``
    (whose per-line mean is exactly ``v_new``), the oracle for the
    ``with_snr`` kernel outputs — see
    :func:`repro.kernels.snr_stats.snr_update_stats_finalize`."""
    g2 = jnp.square(g32.astype(jnp.float32))
    var = jnp.var(g2, axis=dims, keepdims=True)
    return jnp.mean(jnp.square(v_new) / ((1 - b2) ** 2 * var + _SNR_EPS))


# adam_precond's tile width — imported from the kernel module so a block
# change there can't desync this lane-folding layout.
_LANES = LANES


def _fold_lanes(flat: jnp.ndarray) -> jnp.ndarray:
    """Pad a flat fp32 vector to a (rows, _LANES) layout. A (1, N) shape
    would tile as single-sublane blocks on TPU, wasting ~8x vector-lane
    utilization; lane-width rows fill whole tiles. Zero padding yields zero
    updates, sliced away by the caller."""
    n = flat.size
    rows = -(-n // _LANES)
    return jnp.pad(flat, (0, rows * _LANES - n)).reshape(rows, _LANES)


def _dense_kernel_leaf(g, m, v, *, b1, b2, eps, count, interpret,
                       with_health: bool = False):
    shape = g.shape
    if g.ndim == 1:
        n = g.size
        to2d = lambda x: _fold_lanes(x.astype(jnp.float32))
        un2d = lambda y: y.ravel()[:n]
    else:
        to2d = (lambda x: x) if g.ndim == 2 else (lambda x: x.reshape(-1, shape[-1]))
        un2d = lambda y: y.reshape(shape)
    outs = adam_precond(to2d(g), to2d(m), to2d(v), b1=b1, b2=b2, eps=eps,
                        count=count, interpret=interpret, with_health=with_health)
    out = (un2d(outs[0]), un2d(outs[1]), un2d(outs[2]))
    # lane-fold zero padding is finite -> the (2,) accumulator is exact as-is
    return out + (outs[3],) if with_health else out


def _slim_kernel_leaf(g, m, v_red, cn: CanonND, *, b1, b2, eps, count, interpret,
                      with_snr: bool = False, with_health: bool = False):
    """Run one compressed leaf through the kernel its plan names: minor /
    major for 2-D-canonical plans, the batched kernel for batch > 1. With
    ``with_snr`` the kernel's strip loop also emits the centered g^2 line
    sums and a from-update SNR scalar rides along (O(kept) extra traffic).
    With ``with_health`` the same strip loop folds the leaf's (2,) health
    accumulator (appended last) — O(1) extra output, zero extra passes."""
    g2 = canon_apply(g, cn)
    m2 = canon_apply(m, cn)
    v2 = canon_apply(v_red, cn, reduced_cols=True)
    kw = dict(b1=b1, b2=b2, eps=eps, count=count, interpret=interpret)
    health = None
    if with_snr or with_health or cn.batch > 1:
        to3 = (lambda x: x) if cn.batch > 1 else (lambda x: x[None])
        un3 = (lambda x: x) if cn.batch > 1 else (lambda x: x[0])
        outs = slim_precond_batched(to3(g2), to3(m2), to3(v2), axis=cn.axis,
                                    with_snr=with_snr, with_health=with_health,
                                    **kw)
        u2, m2o, v2o = un3(outs[0]), un3(outs[1]), un3(outs[2])
        snr = (snr_update_stats_finalize(outs[2], outs[3], outs[4],
                                         cn.red_size, 1.0 - b2, eps=_SNR_EPS)
               if with_snr else None)
        if with_health:
            health = outs[-1]
    else:
        fn = slim_precond if cn.axis == 1 else slim_precond_major
        u2, m2o, v2o = fn(g2, m2, v2, **kw)
        snr = None
    out = (canon_restore(u2, cn, g.shape), canon_restore(m2o, cn, g.shape),
           canon_restore(v2o, cn, v_red.shape))
    if with_snr:
        out = out + (snr,)
    return out + (health,) if with_health else out


# ---------------------------------------------------------------------------
# Bucketing: one kernel call over many small dense-treated leaves
# ---------------------------------------------------------------------------


def _bucket_update(gs: Sequence[jnp.ndarray], ms: Sequence[jnp.ndarray],
                   vs: Sequence[jnp.ndarray], *, b1, b2, eps, count, interpret):
    """Flatten + concatenate small leaves, update as one lane-folded 2-D
    super-tensor (see :func:`_fold_lanes`), scatter results back by offset.
    Dense Adam is elementwise, so the round-trip is exact."""
    flat2d = lambda xs: _fold_lanes(
        jnp.concatenate([x.astype(jnp.float32).ravel() for x in xs]))
    ub, mo, vo = adam_precond(flat2d(gs), flat2d(ms), flat2d(vs), b1=b1, b2=b2,
                              eps=eps, count=count, interpret=interpret)
    ub, mo, vo = ub.ravel(), mo.ravel(), vo.ravel()
    out_u: List[jnp.ndarray] = []
    out_m: List[jnp.ndarray] = []
    out_v: List[jnp.ndarray] = []
    off = 0
    for g in gs:
        sl = slice(off, off + g.size)
        out_u.append(ub[sl].reshape(g.shape))
        out_m.append(mo[sl].reshape(g.shape))
        out_v.append(vo[sl].reshape(g.shape))
        off += g.size
    return out_u, out_m, out_v


def _flush_bucket(bucket, gs, ms, vs, out_u, out_m, out_v, *, interpret,
                  out_h=None, **kw):
    """Resolve the collected small-leaf indices in place: a lone leaf skips
    the concat round-trip, two or more share one kernel call.

    With ``out_h`` (per-leaf health rows) bucketed leaves compute health via
    the jnp helper — the guard needs *per-leaf* non-finite counts, and these
    leaves are below ``bucket_min_size`` elements, so the extra read is
    noise next to the bucket's own concat round-trip."""
    with_health = out_h is not None
    if len(bucket) == 1:
        i = bucket[0]
        out = _guarded(
            f"dense:{gs[i].shape}",
            lambda: _dense_kernel_leaf(gs[i], ms[i], vs[i], interpret=interpret,
                                       with_health=with_health, **kw),
            lambda: jnp_adam_leaf(gs[i], ms[i], vs[i], **kw)
                    + ((leaf_health(gs[i]),) if with_health else ()))
        out_u[i], out_m[i], out_v[i] = out[:3]
        if with_health:
            out_h[i] = out[3]
    elif bucket:
        us, mss, vss = _guarded(
            f"bucket[{len(bucket)}]",
            lambda: _bucket_update([gs[i] for i in bucket],
                                   [ms[i] for i in bucket],
                                   [vs[i] for i in bucket],
                                   interpret=interpret, **kw),
            lambda: tuple(zip(*[jnp_adam_leaf(gs[i], ms[i], vs[i], **kw)
                                for i in bucket])))
        for i, u, m, v in zip(bucket, us, mss, vss):
            out_u[i], out_m[i], out_v[i] = u, m, v
            if with_health:
                out_h[i] = leaf_health(gs[i])


# ---------------------------------------------------------------------------
# Megaplan: whole-tree grouped launches (O(groups) pallas_calls per update)
# ---------------------------------------------------------------------------
#
# The default fused tree path. plan_megagroups buckets every kernel-eligible
# leaf by regime key (dense / minor / major / batched x line geometry); each
# group gathers into one f32 super-tensor along its kept axis and runs one
# mega kernel launch, with per-leaf scatter-back by segment offset. A group
# degrades as a unit (leaves=len(segments) in the counters); jnp-routed
# leaves keep their per-leaf reference path. The per-leaf dispatch below
# stays available behind megakernel=False as the parity oracle.


def _mega_dense_group(group, gs, ms, vs, *, b1, b2, eps, count, interpret,
                      with_health: bool = False):
    """One launch over a dense group's lane-folded super-tensor. Returns
    per-segment lists (u, m', v', health_rows) aligned with
    ``group.segments``."""
    n = len(group.segments)

    def kernel_fn():
        bc1, bc2 = bias_corrections(b1, b2, count)
        l1 = megaplan.segment_lines(group, [bc1] * n)
        l2 = megaplan.segment_lines(group, [bc2] * n)
        outs = megaplan.mega_adam_update(
            megaplan.gather_group(group, gs), megaplan.gather_group(group, ms),
            megaplan.gather_group(group, vs), l1, l2, b1=b1, b2=b2, eps=eps,
            with_health=with_health, interpret=interpret)
        us = megaplan.scatter_group(group, outs[0])
        mo = megaplan.scatter_group(group, outs[1])
        vo = megaplan.scatter_group(group, outs[2])
        if with_health:
            # per-line rows sum per segment; lane-fold zero padding is
            # finite and contributes 0 to both terms.
            hs = [jnp.stack([jnp.sum(nf), jnp.sum(ss)])
                  for nf, ss in zip(megaplan.scatter_lines(group, outs[3]),
                                    megaplan.scatter_lines(group, outs[4]))]
        else:
            hs = [None] * n
        return us, mo, vo, hs

    def jnp_fn():
        outs = [jnp_adam_leaf(gs[seg.index], ms[seg.index], vs[seg.index],
                              b1=b1, b2=b2, eps=eps, count=count)
                for seg in group.segments]
        hs = ([leaf_health(gs[seg.index]) for seg in group.segments]
              if with_health else [None] * n)
        return [o[0] for o in outs], [o[1] for o in outs], [o[2] for o in outs], hs

    return _guarded(f"mega:dense[{n}]", kernel_fn, jnp_fn, leaves=n)


def _mega_slim_group(group, gs, ms, vs, *, b1, b2, eps, count, interpret,
                     emit_snr: bool = False, with_health: bool = False):
    """One launch over a slim group's canonical super-tensor. Returns
    per-segment lists (u, m', v_red', snr, health_rows)."""
    n = len(group.segments)
    batched = group.kind == "batched"
    to3 = (lambda x: x) if batched else (lambda x: x[None])
    un3 = (lambda x: x) if batched else (lambda x: x[0])

    def kernel_fn():
        bc1, bc2 = bias_corrections(b1, b2, count)
        l1 = megaplan.segment_lines(group, [bc1] * n)
        l2 = megaplan.segment_lines(group, [bc2] * n)
        outs = megaplan.mega_slim_update_batched(
            to3(megaplan.gather_group(group, gs)),
            to3(megaplan.gather_group(group, ms)),
            to3(megaplan.gather_group(group, vs, reduced=True)),
            to3(l1), to3(l2), axis=group.axis, b1=b1, b2=b2, eps=eps,
            with_snr=emit_snr, with_health=with_health, interpret=interpret)
        us = megaplan.scatter_group(group, un3(outs[0]))
        mo = megaplan.scatter_group(group, un3(outs[1]))
        vo = megaplan.scatter_group(group, un3(outs[2]), reduced=True)
        k = 3
        snrs: List[Any] = [None] * n
        if emit_snr:
            snrs = [snr_update_stats_finalize(vl, s1, s2, group.red, 1.0 - b2,
                                              eps=_SNR_EPS)
                    for vl, s1, s2 in zip(
                        megaplan.scatter_lines(group, un3(outs[2])),
                        megaplan.scatter_lines(group, un3(outs[3])),
                        megaplan.scatter_lines(group, un3(outs[4])))]
            k = 5
        hs: List[Any] = [None] * n
        if with_health:
            hs = [jnp.stack([jnp.sum(nf), jnp.sum(ss)])
                  for nf, ss in zip(megaplan.scatter_lines(group, un3(outs[k])),
                                    megaplan.scatter_lines(group, un3(outs[k + 1])))]
        return us, mo, vo, snrs, hs

    def jnp_fn():
        us, mo, vo, snrs, hs = [], [], [], [], []
        for seg in group.segments:
            i = seg.index
            u, m_new, v_new = jnp_slim_leaf(gs[i], ms[i], vs[i], seg.dims,
                                            b1=b1, b2=b2, eps=eps, count=count,
                                            use_first_moment=True)
            us.append(u)
            mo.append(m_new)
            vo.append(v_new)
            snrs.append(jnp_update_snr_leaf(gs[i], v_new, seg.dims, b2=b2)
                        if emit_snr else None)
            hs.append(leaf_health(gs[i]) if with_health else None)
        return us, mo, vo, snrs, hs

    return _guarded(f"mega:{group.kind}[{n}]", kernel_fn, jnp_fn, leaves=n)


def _adam_tree_mega(g_leaves, mu_leaves, nu_leaves, *, b1, b2, eps, count,
                    interpret, with_health: bool = False):
    """Dense Adam over the whole tree in O(groups) launches (one dense group
    plus the per-leaf jnp fallbacks). Return shape matches
    :func:`_adam_tree_local`."""
    kw = dict(b1=b1, b2=b2, eps=eps, count=count)
    n = len(g_leaves)
    plan = megaplan.plan_megagroups([g.shape for g in g_leaves],
                                    [g.dtype for g in g_leaves], [()] * n)
    out_u: List[Any] = [None] * n
    out_m: List[Any] = [None] * n
    out_v: List[Any] = [None] * n
    out_h: List[Any] = [None] * n
    for i in plan.jnp_idx:
        out_u[i], out_m[i], out_v[i] = jnp_adam_leaf(
            g_leaves[i], mu_leaves[i], nu_leaves[i], **kw)
        if with_health:
            out_h[i] = leaf_health(g_leaves[i])
    for group in plan.groups:
        us, mo, vo, hs = _mega_dense_group(group, g_leaves, mu_leaves, nu_leaves,
                                           interpret=interpret,
                                           with_health=with_health, **kw)
        for seg, u, m, v, h in zip(group.segments, us, mo, vo, hs):
            out_u[seg.index], out_m[seg.index], out_v[seg.index] = u, m, v
            out_h[seg.index] = h
    if with_health:
        return out_u, out_m, out_v, out_h
    return out_u, out_m, out_v


def _slim_tree_mega(g_leaves, mu_leaves, nu_leaves, dims_leaves, *, b1, b2, eps,
                    count, interpret, emit_snr: bool = False,
                    with_health: bool = False):
    """SlimAdam over the whole tree in O(groups) launches. Return shape
    matches :func:`_slim_tree_local` (``use_first_moment=True`` form — the
    moment-less variant never reaches the kernels)."""
    kw = dict(b1=b1, b2=b2, eps=eps, count=count)
    n = len(g_leaves)
    n_bufs = PRECOND_SNR_BUFS if emit_snr else PRECOND_BUFS
    plan = megaplan.plan_megagroups([g.shape for g in g_leaves],
                                    [g.dtype for g in g_leaves],
                                    [tuple(d) for d in dims_leaves],
                                    n_bufs=n_bufs)
    out_u: List[Any] = [None] * n
    out_m: List[Any] = [None] * n
    out_v: List[Any] = [None] * n
    out_s: List[Any] = [None] * n
    out_h: List[Any] = [None] * n
    for i in plan.jnp_idx:
        dims = tuple(dims_leaves[i])
        out_u[i], out_m[i], out_v[i] = jnp_slim_leaf(
            g_leaves[i], mu_leaves[i], nu_leaves[i], dims,
            use_first_moment=True, **kw)
        if emit_snr and dims:
            out_s[i] = jnp_update_snr_leaf(g_leaves[i], out_v[i], dims, b2=b2)
        if with_health:
            out_h[i] = leaf_health(g_leaves[i])
    for group in plan.groups:
        if group.kind == "dense":
            us, mo, vo, hs = _mega_dense_group(
                group, g_leaves, mu_leaves, nu_leaves, interpret=interpret,
                with_health=with_health, **kw)
            snrs: List[Any] = [None] * len(group.segments)
        else:
            us, mo, vo, snrs, hs = _mega_slim_group(
                group, g_leaves, mu_leaves, nu_leaves, interpret=interpret,
                emit_snr=emit_snr, with_health=with_health, **kw)
        for seg, u, m, v, s, h in zip(group.segments, us, mo, vo, snrs, hs):
            out_u[seg.index], out_m[seg.index], out_v[seg.index] = u, m, v
            out_s[seg.index], out_h[seg.index] = s, h
    out = (out_u, out_m, out_v, out_s)
    return out + (out_h,) if with_health else out


# ---------------------------------------------------------------------------
# Sharded execution: shard_map wrapping with per-leaf regime plans
# ---------------------------------------------------------------------------


def _use_sharded(mesh, spec_leaves) -> bool:
    """The sharded path engages only when both a mesh and specs are supplied
    and the mesh actually shards something — a trivial mesh runs the plain
    per-leaf path so single-device traces stay byte-identical."""
    if mesh is None or spec_leaves is None:
        return False
    from ..sharding.shardspec import mesh_is_trivial

    return not mesh_is_trivial(mesh)


def sharded_tree_plans(g_leaves: Sequence[Any], dims_leaves: Sequence[Dims],
                       spec_leaves: Sequence[Any], mesh, *, n_bufs: int = PRECOND_BUFS):
    """Per-leaf :class:`repro.sharding.shardspec.ShardLeafPlan` list for a
    tree update — the single planning step the sharded dispatchers below
    run, exposed so callers (tests, the sharded roofline) can inspect and
    count the regimes (`repro.sharding.shardspec.regime_counts`)."""
    from ..sharding.shardspec import plan_sharded_tree, spec_dtype

    return plan_sharded_tree([tuple(g.shape) for g in g_leaves],
                             [spec_dtype(g) for g in g_leaves],
                             [tuple(d) for d in dims_leaves],
                             list(spec_leaves), mesh, n_bufs=n_bufs)


def _owner_scatter(v_slice, owner, sizes):
    """Embed this shard's owner slice of the reduced moment into a zeros
    full-line buffer at its owned offset — the additive ``b2 * v`` term of
    the combined psum payload. Inverse of :func:`_owner_slice`."""
    out = v_slice
    for ax, dim in reversed(owner):
        blk = out.shape[dim]
        full = list(out.shape)
        full[dim] = blk * int(sizes[ax])
        out = jax.lax.dynamic_update_slice_in_dim(
            jnp.zeros(full, out.dtype), out, jax.lax.axis_index(ax) * blk, axis=dim)
    return out


def _owner_slice(v_full, owner, sizes):
    """This shard's owner slice of a completed full-line reduced moment."""
    for ax, dim in owner:
        blk = v_full.shape[dim] // int(sizes[ax])
        v_full = jax.lax.dynamic_slice_in_dim(
            v_full, jax.lax.axis_index(ax) * blk, blk, axis=dim)
    return v_full


def _psum_snr(s1c, s2c, first, v_new, pl, *, n_loc, red_total, b2):
    """Complete from-update SNR stats across the psum group: rebase each
    shard's centered g^2 sums to a mesh-common shift (exact O(spread)
    algebra), psum, finalize against the completed moment, and average the
    ratio over the kept-line shards."""
    from ..kernels.ref import rebase_centered_stats

    shift = jax.lax.pmean(first, pl.psum_axes)
    s1c, s2c = rebase_centered_stats(s1c, s2c, first, shift, n_loc)
    s1c = jax.lax.psum(s1c, pl.psum_axes)
    s2c = jax.lax.psum(s2c, pl.psum_axes)
    snr = snr_update_stats_finalize(v_new, s1c, s2c, red_total, 1.0 - b2,
                                    eps=_SNR_EPS)
    if pl.kept_axes:
        snr = jax.lax.pmean(snr, pl.kept_axes)
    return snr


def _psum_slim_leaf(g, m, v_red, dims: Dims, *, pl, sizes, b1, b2, eps, count,
                    use_first_moment: bool, interpret: bool,
                    emit_snr: bool = False, with_health: bool = False):
    """SlimAdam leaf whose reduced dims are split across ``pl.psum_axes``,
    Pallas-resident: pass 1 (``slim_partial_stats``) reads g, m and writes
    m_new plus per-line partial g^2 sums; a ``lax.psum`` over the owning
    mesh axes completes the lines; pass 2 (``slim_finalize``) reads m_new
    and writes the preconditioned update. The collective carries O(kept)
    bytes over ICI — the compressed moment's tininess is exactly what keeps
    the cross-shard completion cheap — and the leaf streams the slim path's
    5 full-size passes (g, m read; m' write; m' read; u write), charged
    exactly so by the sharded roofline.

    Owner-shard moment writes (``pl.owner``): instead of every shard in the
    psum group redundantly writing the same O(kept) v_new, each shard folds
    ``b2 * v`` for the kept lines it *owns* into the partial-sums payload —
    the all-reduce then delivers the completed v_new to every shard (the
    broadcast rides the collective, zero extra ICI) while the persistent
    store is each shard's 1/A owner slice. Leaves with no evenly-dividing
    kept dim (``pl.owner == ()``) keep PR-4's replicated write.

    ``emit_snr``: the partial-stats strip loop also emits centered g^2 line
    sums; the completed from-update SNR scalar (see
    :func:`jnp_update_snr_leaf`) is appended to the return.

    ``with_health``: the partial-stats strip loop also folds this shard's
    (2,) health accumulator (appended last, *local* — the caller completes
    it in the tree-wide stacked psum) — no extra pass over g, no extra
    collective on this leaf.

    Moments are computed in fp32 and cast back to the *stored* dtypes at the
    boundary, so bf16 optimizer states stay bf16 across the psum path
    (states/checkpoints used to silently promote to fp32 here).
    """
    m_dtype = m.dtype if m is not None else None
    v_dtype = v_red.dtype
    g32 = g.astype(jnp.float32)
    v32 = v_red.astype(jnp.float32)
    dset = {d % g.ndim for d in dims}
    red_local_shape = tuple(1 if i in dset else s for i, s in enumerate(g.shape))
    n_loc = 1
    for i in sorted(dset):
        n_loc *= g.shape[i]
    scale = (1.0 - b2) / pl.red_total

    def kernel_branch():
        cn = pl.cn
        to3 = (lambda x: x) if cn.batch > 1 else (lambda x: x[None])
        un3 = (lambda x: x) if cn.batch > 1 else (lambda x: x[0])
        outs = slim_partial_stats_batched(
            to3(canon_apply(g32, cn)), to3(canon_apply(m.astype(jnp.float32), cn)),
            axis=cn.axis, b1=b1, with_snr=emit_snr, with_health=with_health,
            interpret=interpret)
        m_new2, part2 = outs[0], outs[1]
        part = canon_restore(un3(part2), cn, red_local_shape)
        if pl.owner:
            payload = scale * part + b2 * _owner_scatter(v32, pl.owner, sizes)
            v_new = jax.lax.psum(payload, pl.psum_axes)
            u2 = slim_finalize_batched(
                m_new2, to3(canon_apply(v_new, cn, reduced_cols=True)),
                axis=cn.axis, ek=None, b1=b1, b2=b2, eps=eps, count=count,
                interpret=interpret)
            v_out = _owner_slice(v_new, pl.owner, sizes).astype(v_dtype)
        else:
            ek = jax.lax.psum(part, pl.psum_axes) / pl.red_total
            u2, v_new2 = slim_finalize_batched(
                m_new2, to3(canon_apply(v32, cn, reduced_cols=True)),
                axis=cn.axis, ek=to3(canon_apply(ek, cn, reduced_cols=True)),
                b1=b1, b2=b2, eps=eps, count=count, interpret=interpret)
            v_new = canon_restore(un3(v_new2), cn, red_local_shape)
            v_out = v_new.astype(v_dtype)
        u = canon_restore(un3(u2), cn, g.shape)
        m_new = canon_restore(un3(m_new2), cn, g.shape).astype(m_dtype)
        out = (u, m_new, v_out)
        if emit_snr:
            s1c, s2c, first = (canon_restore(un3(o), cn, red_local_shape)
                               for o in outs[2:5])
            out = out + (_psum_snr(s1c, s2c, first, v_new, pl, n_loc=n_loc,
                                   red_total=pl.red_total, b2=b2),)
        return out + (outs[-1],) if with_health else out

    def jnp_branch():
        # moment-less variant, a local plan the kernel pair cannot serve
        # ('psum_jnp' in regime_counts), or a degraded kernel leaf. Same
        # psum/owner algebra as the kernel pair.
        part = jnp.sum(g32 * g32, axis=tuple(sorted(dset)), keepdims=True)
        bc1, bc2 = bias_corrections(b1, b2, count)
        m_new = b1 * m.astype(jnp.float32) + (1 - b1) * g32 if use_first_moment else None
        if pl.owner:
            payload = scale * part + b2 * _owner_scatter(v32, pl.owner, sizes)
            v_new = jax.lax.psum(payload, pl.psum_axes)
            v_out = _owner_slice(v_new, pl.owner, sizes).astype(v_dtype)
        else:
            ek = jax.lax.psum(part, pl.psum_axes) / pl.red_total
            v_new = b2 * v32 + (1 - b2) * ek
            v_out = v_new.astype(v_dtype)
        num = m_new / bc1 if use_first_moment else g32
        u = num / (jnp.sqrt(v_new / bc2) + eps)
        m_out = m_new.astype(m_dtype) if use_first_moment else None
        out = (u, m_out, v_out)
        if emit_snr:
            from ..kernels.ref import snr_stats_centered_partial_ref

            _, s1c, s2c, first = snr_stats_centered_partial_ref(
                g32 * g32, tuple(sorted(dset)))
            out = out + (_psum_snr(s1c, s2c, first, v_new, pl, n_loc=n_loc,
                                   red_total=pl.red_total, b2=b2),)
        return out + (leaf_health(g32),) if with_health else out

    # The plan's local CanonND was gated by plan_sharded_leaf on the
    # partial/finalize pair's working sets — run exactly that plan (the
    # moment-less variant streams a discarded m, so it stays on jnp).
    from ..sharding.shardspec import psum_kernel_eligible

    if psum_kernel_eligible(pl, use_first_moment):
        return _guarded(f"psum:{g.shape}", kernel_branch, jnp_branch)
    return jnp_branch()


def _psum_mega_group(group, form: str, plans, gs, ms, vs, *, sizes, b1, b2,
                     eps, count, interpret, emit_snr: bool,
                     with_health: bool) -> Dict[int, tuple]:
    """One partial-stats launch + one finalize launch over a grouped psum
    super-tensor; the per-leaf cross-shard algebra (psum over each leaf's
    own ``psum_axes``, owner scatter/slice) runs between the two on the
    O(kept) lines, exactly as :func:`_psum_slim_leaf` does per leaf. The
    finalize pass consumes the partial pass's canonical m_new output
    directly — no re-gather. ``form`` is 'owner' or 'plain': the two
    finalize kernel signatures differ, so the caller partitions before
    grouping. Returns ``{leaf_index: _psum_slim_leaf-format tuple}``."""
    n = len(group.segments)
    batched = group.kind == "batched"
    to3 = (lambda x: x) if batched else (lambda x: x[None])
    un3 = (lambda x: x) if batched else (lambda x: x[0])
    cat = lambda lines: to3(jnp.concatenate(lines, axis=group.concat_axis))

    outs = megaplan.mega_slim_partial_stats_batched(
        to3(megaplan.gather_group(group, gs)),
        to3(megaplan.gather_group(group, ms)),
        axis=group.axis, b1=b1, with_snr=emit_snr, with_health=with_health,
        interpret=interpret)
    parts = megaplan.scatter_group(group, un3(outs[1]), reduced=True)

    v_lines: List[Any] = []
    ek_lines: List[Any] = []
    v_news: List[Any] = []   # per-leaf completed full-line moment (SNR)
    v_outs: List[Any] = [None] * n
    for j, seg in enumerate(group.segments):
        i = seg.index
        pl = plans[i]
        v32 = vs[i].astype(jnp.float32)
        scale = (1.0 - b2) / pl.red_total
        if form == "owner":
            payload = scale * parts[j] + b2 * _owner_scatter(v32, pl.owner, sizes)
            v_new = jax.lax.psum(payload, pl.psum_axes)
            v_lines.append(canon_apply(v_new, seg.cn, reduced_cols=True))
            v_outs[j] = _owner_slice(v_new, pl.owner, sizes).astype(vs[i].dtype)
        else:
            ek = jax.lax.psum(parts[j], pl.psum_axes) / pl.red_total
            v_lines.append(canon_apply(v32, seg.cn, reduced_cols=True))
            ek_lines.append(canon_apply(ek, seg.cn, reduced_cols=True))
            # same elementwise form the finalize kernel applies — kept full-
            # line for the SNR rebase; the stored slice comes from the kernel.
            v_new = b2 * v32 + (1 - b2) * ek
        v_news.append(v_new)

    bc1, bc2 = bias_corrections(b1, b2, count)
    l1 = to3(megaplan.segment_lines(group, [bc1] * n))
    l2 = to3(megaplan.segment_lines(group, [bc2] * n))
    if form == "owner":
        u_cat = megaplan.mega_slim_finalize_batched(
            outs[0], cat(v_lines), l1, l2, axis=group.axis, ek=None, b2=b2,
            eps=eps, interpret=interpret)
    else:
        u_cat, v_new_cat = megaplan.mega_slim_finalize_batched(
            outs[0], cat(v_lines), l1, l2, axis=group.axis, ek=cat(ek_lines),
            b2=b2, eps=eps, interpret=interpret)
        for j, (seg, v_red) in enumerate(zip(
                group.segments, megaplan.scatter_group(group, un3(v_new_cat),
                                                       reduced=True))):
            v_outs[j] = v_red.astype(vs[seg.index].dtype)
    us = megaplan.scatter_group(group, un3(u_cat))
    m_news = megaplan.scatter_group(group, un3(outs[0]))

    snrs: List[Any] = [None] * n
    if emit_snr:
        s1s = megaplan.scatter_group(group, un3(outs[2]), reduced=True)
        s2s = megaplan.scatter_group(group, un3(outs[3]), reduced=True)
        firsts = megaplan.scatter_group(group, un3(outs[4]), reduced=True)
        for j, seg in enumerate(group.segments):
            pl = plans[seg.index]
            dset = {d % len(seg.shape) for d in seg.dims}
            n_loc = math.prod(seg.shape[k] for k in sorted(dset))
            snrs[j] = _psum_snr(s1s[j], s2s[j], firsts[j], v_news[j], pl,
                                n_loc=n_loc, red_total=pl.red_total, b2=b2)
    hs: List[Any] = [None] * n
    if with_health:
        k = 5 if emit_snr else 2
        hs = [jnp.stack([jnp.sum(nf), jnp.sum(ss)])
              for nf, ss in zip(megaplan.scatter_lines(group, un3(outs[k])),
                                megaplan.scatter_lines(group, un3(outs[k + 1])))]

    res: Dict[int, tuple] = {}
    for j, seg in enumerate(group.segments):
        out = (us[j], m_news[j].astype(ms[seg.index].dtype), v_outs[j])
        if emit_snr:
            out = out + (snrs[j],)
        if with_health:
            out = out + (hs[j],)
        res[seg.index] = out
    return res


def _psum_mega_leaves(idx, plans, gs, ms, vs, dims_leaves, *, sizes, b1, b2,
                      eps, count, interpret, emit_snr: bool,
                      with_health: bool) -> Dict[int, tuple]:
    """Group the kernel-eligible psum leaves (``idx``) and run each group
    through the two-launch :func:`_psum_mega_group` pipeline. Owner-write
    and plain leaves partition first (different finalize forms); within a
    form, differing ``psum_axes`` don't split a group — each leaf's
    collective stays its own between the launches. A failing group degrades
    to per-leaf :func:`_psum_slim_leaf` calls."""
    owner_items: List[tuple] = []
    plain_items: List[tuple] = []
    for i in idx:
        pl = plans[i]
        dims = tuple(dims_leaves[i])
        shape = tuple(gs[i].shape)
        dset = {d % len(shape) for d in dims}
        red_shape = tuple(1 if j in dset else s for j, s in enumerate(shape))
        item = (i, shape, red_shape, dims, pl.cn)
        (owner_items if pl.owner else plain_items).append(item)
    out: Dict[int, tuple] = {}
    for form, items in (("owner", owner_items), ("plain", plain_items)):
        for group in megaplan.groups_from_plans(items):
            n = len(group.segments)

            def per_leaf(group=group):
                return {seg.index: _psum_slim_leaf(
                            gs[seg.index], ms[seg.index], vs[seg.index],
                            seg.dims, pl=plans[seg.index], sizes=sizes, b1=b1,
                            b2=b2, eps=eps, count=count, use_first_moment=True,
                            interpret=interpret, emit_snr=emit_snr,
                            with_health=with_health)
                        for seg in group.segments}

            out.update(_guarded(
                f"mega:psum:{group.kind}[{n}]",
                lambda group=group, form=form: _psum_mega_group(
                    group, form, plans, gs, ms, vs, sizes=sizes, b1=b1, b2=b2,
                    eps=eps, count=count, interpret=interpret,
                    emit_snr=emit_snr, with_health=with_health),
                per_leaf, leaves=n))
    return out


def _repl_factors(g_leaves, spec_leaves, mesh) -> jnp.ndarray:
    """(n, 1) fp32 — how many mesh devices hold a replica of each leaf's
    shard. Dividing a per-shard additive stat by this before a psum over
    *all* mesh axes yields the exact global total (replicas contribute
    duplicates; genuinely sharded leaves have factor mesh.size / n_shards)."""
    import math

    from ..sharding.shardspec import dim_shards

    total = math.prod(mesh.shape.values())
    repl = [total / math.prod(dim_shards(g.shape, s, mesh))
            for g, s in zip(g_leaves, spec_leaves)]
    return jnp.asarray(repl, jnp.float32)[:, None]


def _psum_health_rows(rows, repl, axes) -> jnp.ndarray:
    """Complete per-shard health rows across the mesh: one tiny (n, 2)
    psum for the whole tree — O(leaves) scalars over ICI, nothing per-leaf."""
    return jax.lax.psum(jnp.stack(list(rows)) / repl, axes)


def _sharded_adam_tree(g_leaves, mu_leaves, nu_leaves, spec_leaves, mesh, *,
                       b1, b2, eps, count, interpret, bucket_min_size,
                       with_health: bool = False, megakernel: bool = True):
    """Dense Adam under shard_map: elementwise math never crosses shards, so
    every device just runs the plain per-leaf path on its local shards (the
    leaf plans and bucketing decisions re-derive from local shapes). With
    ``with_health`` each shard's in-pass rows are completed by one stacked
    (n, 2) psum and returned as a replicated :class:`StepHealth`."""
    from ..sharding.logical import shard_map
    from ..sharding.shardspec import even_spec
    from jax.sharding import PartitionSpec as P

    specs = [even_spec(g.shape, s, mesh) for g, s in zip(g_leaves, spec_leaves)]
    axes = tuple(mesh.shape.keys())
    repl = _repl_factors(g_leaves, spec_leaves, mesh) if with_health else None

    def local_fn(count, gs, ms, vs):
        out = _adam_tree_local(gs, ms, vs, b1=b1, b2=b2, eps=eps, count=count,
                               interpret=interpret, bucket_min_size=bucket_min_size,
                               with_health=with_health, megakernel=megakernel)
        if not with_health:
            return out
        return out[:3] + (_psum_health_rows(out[3], repl, axes),)

    out_specs = (specs, specs, specs) + ((P(),) if with_health else ())
    fn = shard_map(local_fn, mesh=mesh,
                   in_specs=(P(), specs, specs, specs),
                   out_specs=out_specs, check_rep=False)
    out = fn(count, list(g_leaves), list(mu_leaves), list(nu_leaves))
    if not with_health:
        return out
    h = out[3]
    return out[:3] + (StepHealth(nonfinite=h[:, 0], grad_sumsq=jnp.sum(h[:, 1])),)


def _sharded_slim_tree(g_leaves, mu_leaves, nu_leaves, dims_leaves, spec_leaves, mesh, *,
                       b1, b2, eps, count, use_first_moment, interpret,
                       bucket_min_size, emit_snr: bool = False,
                       with_health: bool = False, megakernel: bool = True):
    """SlimAdam under shard_map, three regimes per leaf (see
    ``repro.sharding.shardspec``): 'local' leaves run the unchanged kernel
    dispatch on their shard (kernels, bucketing, jnp fits-gate fallback all
    re-derived from local shapes); 'psum' leaves run the Pallas-resident
    partial-stats/finalize pair around a cross-shard ``lax.psum`` (with
    owner-shard moment storage where the plan found a placement); 'jnp'
    leaves (interleaved K after sharding) run the reference math on their
    shard. ``emit_snr`` appends a per-leaf from-update SNR scalar (None for
    K = () leaves) — the stats ride the update kernels' strip loops, psum-
    completed for sharded lines, so a measure step adds O(kept) traffic.
    ``with_health`` appends a replicated :class:`StepHealth`: every regime's
    local rows come from its own in-pass accumulator (psum leaves from the
    partial-stats kernel), completed by one stacked (n, 2) psum."""
    from ..sharding.logical import shard_map
    from jax.sharding import PartitionSpec as P

    plans = sharded_tree_plans(g_leaves, dims_leaves, spec_leaves, mesh,
                               n_bufs=PRECOND_SNR_BUFS if emit_snr else PRECOND_BUFS)
    sizes = dict(mesh.shape)
    g_specs = [pl.spec for pl in plans]
    v_specs = [pl.nu_spec if pl.nu_spec is not None else pl.red_spec
               for pl in plans]
    n = len(g_leaves)
    snr_idx = [i for i in range(n) if tuple(dims_leaves[i])] if emit_snr else []
    axes = tuple(mesh.shape.keys())
    repl = (_repl_factors(g_leaves, [pl.spec for pl in plans], mesh)
            if with_health else None)
    kw = dict(b1=b1, b2=b2, eps=eps)

    def dispatch(count, gs, ms, vs):
        out_u: List[Any] = [None] * n
        out_m: List[Any] = [None] * n
        out_v: List[Any] = [None] * n
        out_s: List[Any] = [None] * n
        out_h: List[Any] = [None] * n
        # Grouped psum launches: kernel-eligible psum leaves share one
        # partial-stats + one finalize launch per (form, regime key) group;
        # each leaf's cross-shard collective stays its own in between.
        mega_psum: Dict[int, tuple] = {}
        if megakernel and use_first_moment:
            from ..sharding.shardspec import psum_kernel_eligible

            elig = [i for i, pl in enumerate(plans)
                    if pl.regime == "psum"
                    and psum_kernel_eligible(pl, use_first_moment)]
            if elig:
                mega_psum = _psum_mega_leaves(
                    elig, plans, gs, ms, vs, dims_leaves, sizes=sizes,
                    count=count, interpret=interpret, emit_snr=emit_snr,
                    with_health=with_health, **kw)
        local_idx = [i for i, pl in enumerate(plans) if pl.regime == "local"]
        if local_idx:
            out = _slim_tree_local(
                [gs[i] for i in local_idx],
                [ms[i] for i in local_idx] if use_first_moment else None,
                [vs[i] for i in local_idx],
                [tuple(dims_leaves[i]) for i in local_idx],
                count=count, use_first_moment=use_first_moment,
                interpret=interpret, bucket_min_size=bucket_min_size,
                emit_snr=emit_snr, with_health=with_health,
                megakernel=megakernel, **kw)
            u, mo, vo = out[:3]
            for j, i in enumerate(local_idx):
                out_u[i] = u[j]
                out_m[i] = mo[j] if use_first_moment else None
                out_v[i] = vo[j]
                if with_health:
                    out_h[i] = out[4][j]
                if emit_snr and out[3][j] is not None:
                    s = out[3][j]
                    pl = plans[i]
                    # lines are sharded over the kept axes: the global ratio
                    # mean is the mean of the equal-count per-shard means.
                    out_s[i] = jax.lax.pmean(s, pl.kept_axes) if pl.kept_axes else s
        for i, pl in enumerate(plans):
            if pl.regime == "local":
                continue
            dims = tuple(dims_leaves[i])
            m_i = ms[i] if use_first_moment else None
            if pl.regime == "psum":
                if i in mega_psum:
                    out = mega_psum[i]
                else:
                    out = _psum_slim_leaf(gs[i], m_i, vs[i], dims, pl=pl, sizes=sizes,
                                          count=count, use_first_moment=use_first_moment,
                                          interpret=interpret, emit_snr=emit_snr,
                                          with_health=with_health, **kw)
            else:  # 'jnp': reduced dims whole on the shard, reference math
                out = jnp_slim_leaf(gs[i], m_i, vs[i], dims, count=count,
                                    use_first_moment=use_first_moment, **kw)
                if emit_snr:
                    s = jnp_update_snr_leaf(gs[i], out[2], dims, b2=b2)
                    s = jax.lax.pmean(s, pl.kept_axes) if pl.kept_axes else s
                    out = out + (s,)
                if with_health:
                    out = out + (leaf_health(gs[i]),)
            out_u[i], out_m[i], out_v[i] = out[:3]
            if with_health:
                out_h[i] = out[-1]
            if emit_snr:
                out_s[i] = out[3]
        res = (out_u, out_m, out_v)
        if emit_snr:
            res = res + ([out_s[i] for i in snr_idx],)
        if with_health:
            res = res + (_psum_health_rows(out_h, repl, axes),)
        return res

    snr_specs = [P() for _ in snr_idx]

    def unpack(res):
        """Normalize dispatch's variadic return to (u, m, v, snr_list_or_None,
        health_rows_or_None) with snr scattered back to all-leaves indexing."""
        h = res[-1] if with_health else None
        if emit_snr:
            snr = res[3]
            out_s: List[Any] = [None] * n
            for j, i in enumerate(snr_idx):
                out_s[i] = snr[j]
        else:
            out_s = None
        return res[0], res[1], res[2], out_s, h

    health_spec = (P(),) if with_health else ()
    if use_first_moment:
        def local_fn(count, gs, ms, vs):
            return dispatch(count, gs, ms, vs)

        out_specs = ((g_specs, g_specs, v_specs)
                     + ((snr_specs,) if emit_snr else ()) + health_spec)
        fn = shard_map(local_fn, mesh=mesh,
                       in_specs=(P(), g_specs, g_specs, v_specs),
                       out_specs=out_specs, check_rep=False)
        res = fn(count, list(g_leaves), list(mu_leaves), list(nu_leaves))
        u, mo, vo, out_s, h = unpack(res)
    else:
        def local_fn_no_mu(count, gs, vs):
            out = dispatch(count, gs, None, vs)
            return (out[0],) + out[2:]

        out_specs = ((g_specs, v_specs)
                     + ((snr_specs,) if emit_snr else ()) + health_spec)
        fn = shard_map(local_fn_no_mu, mesh=mesh,
                       in_specs=(P(), g_specs, v_specs),
                       out_specs=out_specs, check_rep=False)
        res = fn(count, list(g_leaves), list(nu_leaves))
        u, _, vo, out_s, h = unpack((res[0], None) + res[1:])
        mo = None
    out = (u, mo, vo)
    if emit_snr:
        out = out + (out_s,)
    if with_health:
        out = out + (StepHealth(nonfinite=h[:, 0], grad_sumsq=jnp.sum(h[:, 1])),)
    return out


# ---------------------------------------------------------------------------
# Tree-level entry points (operate on flat leaf lists; the transformations
# own flatten/unflatten so pytree structure stays their concern)
# ---------------------------------------------------------------------------


def _adam_tree_local(g_leaves, mu_leaves, nu_leaves, *, b1, b2, eps, count,
                     interpret, bucket_min_size, with_health: bool = False,
                     megakernel: bool = True):
    """Unsharded dense-Adam dispatch; with ``with_health`` also returns the
    per-leaf (2,) health rows (kernel accumulators for kernel leaves, the
    fused jnp sums otherwise). The default is the megaplan path (one grouped
    launch for the whole tree — ``bucket_min_size`` is moot there, every
    kernel leaf joins the dense group); ``megakernel=False`` keeps the
    per-leaf/bucketed loop as the parity oracle."""
    if megakernel:
        return _adam_tree_mega(g_leaves, mu_leaves, nu_leaves, b1=b1, b2=b2,
                               eps=eps, count=count, interpret=interpret,
                               with_health=with_health)
    kw = dict(b1=b1, b2=b2, eps=eps, count=count)
    n = len(g_leaves)
    out_u: List[Any] = [None] * n
    out_m: List[Any] = [None] * n
    out_v: List[Any] = [None] * n
    out_h: List[Any] = [None] * n
    bucket: List[int] = []
    for i, (g, m, v) in enumerate(zip(g_leaves, mu_leaves, nu_leaves)):
        if leaf_plan(g.shape, g.dtype, ()).route == "jnp":
            out_u[i], out_m[i], out_v[i] = jnp_adam_leaf(g, m, v, **kw)
            if with_health:
                out_h[i] = leaf_health(g)
        elif _bucket_eligible(g.size, bucket_min_size):
            bucket.append(i)
        else:
            out = _guarded(
                f"dense:{g.shape}",
                lambda g=g, m=m, v=v: _dense_kernel_leaf(
                    g, m, v, interpret=interpret, with_health=with_health, **kw),
                lambda g=g, m=m, v=v: jnp_adam_leaf(g, m, v, **kw)
                    + ((leaf_health(g),) if with_health else ()))
            out_u[i], out_m[i], out_v[i] = out[:3]
            if with_health:
                out_h[i] = out[3]
    _flush_bucket(bucket, g_leaves, mu_leaves, nu_leaves, out_u, out_m, out_v,
                  interpret=interpret, out_h=out_h if with_health else None, **kw)
    if with_health:
        return out_u, out_m, out_v, out_h
    return out_u, out_m, out_v


def adam_tree_update(g_leaves: Sequence[jnp.ndarray], mu_leaves: Sequence[jnp.ndarray],
                     nu_leaves: Sequence[jnp.ndarray], *, b1: float, b2: float,
                     eps: float, count, interpret: Optional[bool] = None,
                     bucket_min_size: int = DEFAULT_BUCKET_MIN,
                     mesh=None, spec_leaves=None, with_health: bool = False,
                     megakernel: bool = True):
    """Dense Adam over a leaf list: by default one megaplan group launch for
    every kernel-eligible leaf (O(1) pallas_calls per update), jnp fallback
    per excluded leaf. ``megakernel=False`` restores the per-leaf dispatch
    (small leaves bucketed) — the parity oracle the megaplan tests diff
    against. Returns (updates, new_mu, new_nu).

    With ``mesh`` + ``spec_leaves`` (one PartitionSpec per leaf) the whole
    update runs under ``shard_map`` — each device updates its local shards —
    instead of letting GSPMD gather full leaves around the pallas_call
    optimization barrier.

    ``with_health=True`` appends a :class:`StepHealth` — per-leaf non-finite
    counts and the finite-masked global grad sumsq, accumulated in the same
    kernel/XLA passes that stream the update (O(leaves) scalar outputs, no
    extra tensor traffic; under a mesh, one stacked (n, 2) psum)."""
    interpret = default_interpret() if interpret is None else interpret
    if _use_sharded(mesh, spec_leaves) and len(g_leaves):
        return _sharded_adam_tree(g_leaves, mu_leaves, nu_leaves, spec_leaves, mesh,
                                  b1=b1, b2=b2, eps=eps, count=count,
                                  interpret=interpret, bucket_min_size=bucket_min_size,
                                  with_health=with_health, megakernel=megakernel)
    out = _adam_tree_local(g_leaves, mu_leaves, nu_leaves, b1=b1, b2=b2, eps=eps,
                           count=count, interpret=interpret,
                           bucket_min_size=bucket_min_size, with_health=with_health,
                           megakernel=megakernel)
    if with_health:
        return out[:3] + (_health_from_rows(out[3]),)
    return out


def _slim_tree_local(g_leaves, mu_leaves, nu_leaves, dims_leaves, *, b1, b2, eps,
                     count, use_first_moment, interpret, bucket_min_size,
                     emit_snr: bool = False, with_health: bool = False,
                     megakernel: bool = True):
    """Unsharded SlimAdam dispatch. Returns ``(u, m, v, snr_list)`` plus,
    with ``with_health``, the per-leaf (2,) health rows as a fifth element.
    Default is the megaplan path (O(groups) launches); ``megakernel=False``
    keeps the per-leaf/bucketed loop as the parity oracle. The moment-less
    variant runs entirely on jnp either way."""
    kw = dict(b1=b1, b2=b2, eps=eps, count=count)
    n = len(g_leaves)
    out_s: List[Any] = [None] * n
    out_h: List[Any] = [None] * n
    if not use_first_moment:
        outs = [jnp_slim_leaf(g, None, v, tuple(d), use_first_moment=False, **kw)
                for g, v, d in zip(g_leaves, nu_leaves, dims_leaves)]
        if emit_snr:
            out_s = [jnp_update_snr_leaf(g, o[2], tuple(d), b2=b2) if tuple(d) else None
                     for g, o, d in zip(g_leaves, outs, dims_leaves)]
        if with_health:
            out_h = [leaf_health(g) for g in g_leaves]
        out = ([o[0] for o in outs], None, [o[2] for o in outs], out_s)
        return out + (out_h,) if with_health else out
    if megakernel:
        return _slim_tree_mega(g_leaves, mu_leaves, nu_leaves, dims_leaves,
                               interpret=interpret, emit_snr=emit_snr,
                               with_health=with_health, **kw)
    out_u: List[Any] = [None] * n
    out_m: List[Any] = [None] * n
    out_v: List[Any] = [None] * n
    bucket: List[int] = []
    # The with_snr kernel variant keeps an extra shifted-g^2 copy live, so
    # measure steps gate the VMEM fit on its larger working set (a leaf near
    # the budget may route jnp on measure steps while staying fused on
    # plain steps — different jitted executables anyway).
    n_bufs = PRECOND_SNR_BUFS if emit_snr else PRECOND_BUFS
    for i, (g, v, dims) in enumerate(zip(g_leaves, nu_leaves, dims_leaves)):
        dims = tuple(dims)
        plan = leaf_plan(g.shape, g.dtype, dims, n_bufs=n_bufs)
        if plan.route == "jnp":
            out_u[i], out_m[i], out_v[i] = jnp_slim_leaf(
                g, mu_leaves[i], v, dims, use_first_moment=True, **kw)
            if emit_snr and dims:
                out_s[i] = jnp_update_snr_leaf(g, out_v[i], dims, b2=b2)
            if with_health:
                out_h[i] = leaf_health(g)
        elif plan.route == "dense":
            if _bucket_eligible(g.size, bucket_min_size):
                bucket.append(i)
            else:
                out = _guarded(
                    f"dense:{g.shape}",
                    lambda g=g, m=mu_leaves[i], v=v: _dense_kernel_leaf(
                        g, m, v, interpret=interpret, with_health=with_health, **kw),
                    lambda g=g, m=mu_leaves[i], v=v: jnp_adam_leaf(g, m, v, **kw)
                        + ((leaf_health(g),) if with_health else ()))
                out_u[i], out_m[i], out_v[i] = out[:3]
                if with_health:
                    out_h[i] = out[3]
        else:
            def slim_jnp_fallback(g=g, m=mu_leaves[i], v=v, dims=dims):
                out = jnp_slim_leaf(g, m, v, dims, use_first_moment=True, **kw)
                if emit_snr:
                    out = out + (jnp_update_snr_leaf(g, out[2], dims, b2=b2)
                                 if dims else None,)
                return out + ((leaf_health(g),) if with_health else ())

            out = _guarded(
                f"slim:{g.shape}",
                lambda g=g, m=mu_leaves[i], v=v, cn=plan.cn: _slim_kernel_leaf(
                    g, m, v, cn, interpret=interpret, with_snr=emit_snr,
                    with_health=with_health, **kw),
                slim_jnp_fallback)
            out_u[i], out_m[i], out_v[i] = out[:3]
            if with_health:
                out_h[i] = out[-1]
            if emit_snr:
                out_s[i] = out[3]
    _flush_bucket(bucket, g_leaves, mu_leaves, nu_leaves, out_u, out_m, out_v,
                  interpret=interpret, out_h=out_h if with_health else None, **kw)
    out = (out_u, out_m, out_v, out_s)
    return out + (out_h,) if with_health else out


def slim_tree_update(g_leaves: Sequence[jnp.ndarray], mu_leaves: Optional[Sequence[jnp.ndarray]],
                     nu_leaves: Sequence[jnp.ndarray], dims_leaves: Sequence[Dims], *,
                     b1: float, b2: float, eps: float, count,
                     use_first_moment: bool = True, interpret: Optional[bool] = None,
                     bucket_min_size: int = DEFAULT_BUCKET_MIN,
                     mesh=None, spec_leaves=None, emit_snr: bool = False,
                     with_health: bool = False, megakernel: bool = True):
    """SlimAdam over a leaf list with per-leaf reduction-dim tuples.

    Each leaf's route comes from one :func:`leaf_plan` lookup: K = () leaves
    take the dense route; K != () leaves the slim kernel named by their
    canonical plan; leaves no kernel can serve fall back to jnp. By default
    kernel leaves run through the megaplan (same-regime leaves concatenated,
    O(groups) launches per tree — see ``repro.kernels.megaplan``);
    ``megakernel=False`` restores the per-leaf dispatch (small dense leaves
    bucketed), the parity oracle. ``use_first_moment=False`` runs entirely
    on the jnp path — the kernels read/write a first moment, so serving the
    moment-less variant would stream a discarded full-size m and forfeit the
    bandwidth win. Returns (updates, new_mu_or_None, new_nu).

    ``emit_snr=True`` appends a fourth element: a per-leaf list of
    from-update SNR scalars (None for K = () leaves) — SNR_K of the step's
    dense reconstruction ``b2 * V + (1 - b2) * g^2``, the paper's
    compressibility diagnostic riding the update pass. Kernel-served leaves
    emit the centered g^2 line sums from the same strip loop (O(kept) extra
    traffic, zero extra full-size passes); jnp-fallback leaves compute the
    same quantity in the already-fused XLA pass.

    With ``mesh`` + ``spec_leaves`` the update runs under ``shard_map`` with
    per-leaf regime plans (``repro.sharding.shardspec``): leaves whose
    reduced dims are whole per shard run the kernels locally on the shard,
    leaves whose reduced dims are split run the Pallas partial-stats /
    finalize pair around a ``lax.psum`` over the owning mesh axes (with
    owner-shard moment storage riding the collective), and interleaved-K-
    after-sharding leaves run the reference jnp math per shard.

    ``with_health=True`` appends a :class:`StepHealth` (always the last
    element): per-leaf non-finite counts + finite-masked global grad sumsq,
    accumulated by the update kernels' own strip loops (O(leaves) scalar
    outputs, no new tensor traffic; under a mesh, one stacked (n, 2) psum).

    Kernel-ineligible or Pallas-failing leaves degrade to the reference jnp
    math per leaf (see :func:`set_kernel_fault_hook` /
    :func:`kernel_degraded_leaves`) — a compile regression costs bandwidth,
    not the run."""
    interpret = default_interpret() if interpret is None else interpret
    if _use_sharded(mesh, spec_leaves) and len(g_leaves):
        return _sharded_slim_tree(g_leaves, mu_leaves, nu_leaves, dims_leaves,
                                  spec_leaves, mesh, b1=b1, b2=b2, eps=eps,
                                  count=count, use_first_moment=use_first_moment,
                                  interpret=interpret, bucket_min_size=bucket_min_size,
                                  emit_snr=emit_snr, with_health=with_health,
                                  megakernel=megakernel)
    res = _slim_tree_local(g_leaves, mu_leaves, nu_leaves, dims_leaves,
                           b1=b1, b2=b2, eps=eps, count=count,
                           use_first_moment=use_first_moment, interpret=interpret,
                           bucket_min_size=bucket_min_size, emit_snr=emit_snr,
                           with_health=with_health, megakernel=megakernel)
    out = res[:3] + ((res[3],) if emit_snr else ())
    if with_health:
        out = out + (_health_from_rows(res[4]),)
    return out
