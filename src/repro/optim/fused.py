"""Fused optimizer backend: route Adam/SlimAdam pytree updates through the
Pallas kernels.

The jnp tree-map path materializes every intermediate (g^2, m_hat, v_hat, ...)
in HBM; the fused kernels stream each tensor exactly once. Per optimizer step
and leaf the bandwidth model is

    dense Adam       7 passes   (p, g, m, v read + p', m', v' write)
    SlimAdam (K)     5 passes + O(kept)   (V reduced over K never leaves VMEM)

and in GradientTransformation form (this module: update emitted, p untouched)

    dense precond    6 passes   (g, m, v read + u, m', v' write)
    slim precond     4 passes + O(kept)

This module implements the per-leaf routing used by
``repro.optim.adam.scale_by_adam`` and ``repro.core.slim_adam.scale_by_slim_adam``
when constructed with ``backend="fused"`` (or ``"auto"`` on TPU). Every
dispatch decision is one precomputed :func:`repro.kernels.leaf_plan` lookup —
canonicalization plan, VMEM fits-gate, and route in a single place:

  * canonicalization — compressed leaves go to the batched (B, R, C)
    canonical form via :func:`repro.kernels.canon_nd`: trailing K -> minor,
    leading K -> major, kept-prefix/K/kept-suffix (scan-stacked leaves) ->
    batched major, each reachable by pure reshape; only a genuinely
    interleaved K transposes. Dense leaves reshape to (rows, minor);
  * dispatch — dense leaves -> ``adam_precond``; compressed leaves ->
    ``slim_precond`` / ``slim_precond_major`` / ``slim_precond_batched``
    per the plan, with a per-leaf jnp fallback for anything the kernels
    can't serve (scalar leaves, non-float dtypes, empty tensors, reduction
    lines that outrun VMEM, the moment-less ``use_first_moment=False``
    variant);
  * bucketing — small dense-treated leaves (elementwise treatment, so
    flattening is exact) are concatenated into one flat super-tensor per
    bucket, updated in a single kernel call to amortize launch + padding
    overhead, and scattered back to the original leaves by an offset map.

All public entry points accept a traced ``count`` (the optimizer step is
jitted state), and every returned moment/update is fp32, matching the jnp
path bit-for-bit up to fp32 reassociation.
"""
from __future__ import annotations

from typing import Any, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from ..kernels.fused_adam import LANES, bias_corrections
from ..kernels.ops import (
    CanonND,
    adam_precond,
    canon_apply,
    canon_restore,
    default_interpret,
    leaf_plan,
    slim_precond,
    slim_precond_batched,
    slim_precond_major,
)
from ..kernels.slim_update import PRECOND_BUFS

Dims = Tuple[int, ...]

# Leaves below this element count get bucketed (one kernel call per bucket
# instead of per leaf). 16k elements ~ 64 KiB fp32: far below the per-call
# tile, so launch/pad overhead dominates any per-leaf call at this size.
DEFAULT_BUCKET_MIN = 1 << 14


# ---------------------------------------------------------------------------
# Per-leaf paths
# ---------------------------------------------------------------------------


def jnp_adam_leaf(g, m, v, *, b1, b2, eps, count):
    """Reference Adam leaf update — the single jnp definition of the
    semantics; the 'jnp' backend and the fused backend's fallback leaves
    both call this, with :func:`bias_corrections` shared with the kernels."""
    g32 = g.astype(jnp.float32)
    m_new = b1 * m + (1 - b1) * g32
    v_new = b2 * v + (1 - b2) * jnp.square(g32)
    bc1, bc2 = bias_corrections(b1, b2, count)
    u = (m_new / bc1) / (jnp.sqrt(v_new / bc2) + eps)
    return u, m_new, v_new


def jnp_slim_leaf(g, m, v, dims: Dims, *, b1, b2, eps, count, use_first_moment):
    """Reference SlimAdam leaf update (see :func:`jnp_adam_leaf`)."""
    g32 = g.astype(jnp.float32)
    g2 = jnp.square(g32)
    ek = jnp.mean(g2, axis=dims, keepdims=True) if dims else g2
    v_new = b2 * v + (1 - b2) * ek
    bc1, bc2 = bias_corrections(b1, b2, count)
    if use_first_moment:
        m_new = b1 * m + (1 - b1) * g32
        num = m_new / bc1
    else:
        m_new = None
        num = g32
    u = num / (jnp.sqrt(v_new / bc2) + eps)
    return u, m_new, v_new


# adam_precond's tile width — imported from the kernel module so a block
# change there can't desync this lane-folding layout.
_LANES = LANES


def _fold_lanes(flat: jnp.ndarray) -> jnp.ndarray:
    """Pad a flat fp32 vector to a (rows, _LANES) layout. A (1, N) shape
    would tile as single-sublane blocks on TPU, wasting ~8x vector-lane
    utilization; lane-width rows fill whole tiles. Zero padding yields zero
    updates, sliced away by the caller."""
    n = flat.size
    rows = -(-n // _LANES)
    return jnp.pad(flat, (0, rows * _LANES - n)).reshape(rows, _LANES)


def _dense_kernel_leaf(g, m, v, *, b1, b2, eps, count, interpret):
    shape = g.shape
    if g.ndim == 1:
        n = g.size
        to2d = lambda x: _fold_lanes(x.astype(jnp.float32))
        un2d = lambda y: y.ravel()[:n]
    else:
        to2d = (lambda x: x) if g.ndim == 2 else (lambda x: x.reshape(-1, shape[-1]))
        un2d = lambda y: y.reshape(shape)
    u2, m2, v2 = adam_precond(to2d(g), to2d(m), to2d(v), b1=b1, b2=b2, eps=eps,
                              count=count, interpret=interpret)
    return un2d(u2), un2d(m2), un2d(v2)


def _slim_kernel_leaf(g, m, v_red, cn: CanonND, *, b1, b2, eps, count, interpret):
    """Run one compressed leaf through the kernel its plan names: minor /
    major for 2-D-canonical plans, the batched kernel for batch > 1."""
    g2 = canon_apply(g, cn)
    m2 = canon_apply(m, cn)
    v2 = canon_apply(v_red, cn, reduced_cols=True)
    kw = dict(b1=b1, b2=b2, eps=eps, count=count, interpret=interpret)
    if cn.batch > 1:
        u2, m2o, v2o = slim_precond_batched(g2, m2, v2, axis=cn.axis, **kw)
    else:
        fn = slim_precond if cn.axis == 1 else slim_precond_major
        u2, m2o, v2o = fn(g2, m2, v2, **kw)
    return (canon_restore(u2, cn, g.shape), canon_restore(m2o, cn, g.shape),
            canon_restore(v2o, cn, v_red.shape))


# ---------------------------------------------------------------------------
# Bucketing: one kernel call over many small dense-treated leaves
# ---------------------------------------------------------------------------


def _bucket_update(gs: Sequence[jnp.ndarray], ms: Sequence[jnp.ndarray],
                   vs: Sequence[jnp.ndarray], *, b1, b2, eps, count, interpret):
    """Flatten + concatenate small leaves, update as one lane-folded 2-D
    super-tensor (see :func:`_fold_lanes`), scatter results back by offset.
    Dense Adam is elementwise, so the round-trip is exact."""
    flat2d = lambda xs: _fold_lanes(
        jnp.concatenate([x.astype(jnp.float32).ravel() for x in xs]))
    ub, mo, vo = adam_precond(flat2d(gs), flat2d(ms), flat2d(vs), b1=b1, b2=b2,
                              eps=eps, count=count, interpret=interpret)
    ub, mo, vo = ub.ravel(), mo.ravel(), vo.ravel()
    out_u: List[jnp.ndarray] = []
    out_m: List[jnp.ndarray] = []
    out_v: List[jnp.ndarray] = []
    off = 0
    for g in gs:
        sl = slice(off, off + g.size)
        out_u.append(ub[sl].reshape(g.shape))
        out_m.append(mo[sl].reshape(g.shape))
        out_v.append(vo[sl].reshape(g.shape))
        off += g.size
    return out_u, out_m, out_v


def _flush_bucket(bucket, gs, ms, vs, out_u, out_m, out_v, *, interpret, **kw):
    """Resolve the collected small-leaf indices in place: a lone leaf skips
    the concat round-trip, two or more share one kernel call."""
    if len(bucket) == 1:
        i = bucket[0]
        out_u[i], out_m[i], out_v[i] = _dense_kernel_leaf(
            gs[i], ms[i], vs[i], interpret=interpret, **kw)
    elif bucket:
        us, mss, vss = _bucket_update([gs[i] for i in bucket],
                                      [ms[i] for i in bucket],
                                      [vs[i] for i in bucket],
                                      interpret=interpret, **kw)
        for i, u, m, v in zip(bucket, us, mss, vss):
            out_u[i], out_m[i], out_v[i] = u, m, v


# ---------------------------------------------------------------------------
# Sharded execution: shard_map wrapping with per-leaf regime plans
# ---------------------------------------------------------------------------


def _use_sharded(mesh, spec_leaves) -> bool:
    """The sharded path engages only when both a mesh and specs are supplied
    and the mesh actually shards something — a trivial mesh runs the plain
    per-leaf path so single-device traces stay byte-identical."""
    if mesh is None or spec_leaves is None:
        return False
    from ..sharding.shardspec import mesh_is_trivial

    return not mesh_is_trivial(mesh)


def sharded_tree_plans(g_leaves: Sequence[Any], dims_leaves: Sequence[Dims],
                       spec_leaves: Sequence[Any], mesh, *, n_bufs: int = PRECOND_BUFS):
    """Per-leaf :class:`repro.sharding.shardspec.ShardLeafPlan` list for a
    tree update — the single planning step the sharded dispatchers below
    run, exposed so callers (tests, the sharded roofline) can inspect and
    count the regimes (`repro.sharding.shardspec.regime_counts`)."""
    from ..sharding.shardspec import plan_sharded_tree, spec_dtype

    return plan_sharded_tree([tuple(g.shape) for g in g_leaves],
                             [spec_dtype(g) for g in g_leaves],
                             [tuple(d) for d in dims_leaves],
                             list(spec_leaves), mesh, n_bufs=n_bufs)


def _psum_slim_leaf(g, m, v_red, dims: Dims, *, axes: Tuple[str, ...], red_total: int,
                    b1, b2, eps, count, use_first_moment: bool):
    """SlimAdam leaf whose reduced dims are split across ``axes``: local
    partial sums of g^2 per reduction line, ``lax.psum`` to complete them,
    then the elementwise preconditioner on the local shard. The psum carries
    O(kept_local) bytes over ICI — the compressed moment's tininess is
    exactly what keeps the cross-shard completion cheap.

    Scheduling note: the first-moment update is computed *before* the psum
    on purpose. The collective splits the leaf into two passes, but m_new
    shares pass one with the partial sums (read g, m; write m_new) and the
    post-psum finalize reads m_new instead of g — so the leaf still streams
    the slim path's 5 full-size passes, not 6 (the sharded roofline charges
    exactly that)."""
    g32 = g.astype(jnp.float32)
    part = jnp.sum(g32 * g32, axis=dims, keepdims=True)
    bc1, bc2 = bias_corrections(b1, b2, count)
    if use_first_moment:
        m_new = b1 * m + (1 - b1) * g32
    else:
        m_new = None
    ek = jax.lax.psum(part, axes) / red_total
    v_new = b2 * v_red + (1 - b2) * ek
    num = m_new / bc1 if use_first_moment else g32
    u = num / (jnp.sqrt(v_new / bc2) + eps)
    return u, m_new, v_new


def _sharded_adam_tree(g_leaves, mu_leaves, nu_leaves, spec_leaves, mesh, *,
                       b1, b2, eps, count, interpret, bucket_min_size):
    """Dense Adam under shard_map: elementwise math never crosses shards, so
    every device just runs the plain per-leaf path on its local shards (the
    leaf plans and bucketing decisions re-derive from local shapes)."""
    from ..sharding.logical import shard_map
    from ..sharding.shardspec import even_spec
    from jax.sharding import PartitionSpec as P

    specs = [even_spec(g.shape, s, mesh) for g, s in zip(g_leaves, spec_leaves)]

    def local_fn(count, gs, ms, vs):
        return adam_tree_update(gs, ms, vs, b1=b1, b2=b2, eps=eps, count=count,
                                interpret=interpret, bucket_min_size=bucket_min_size)

    fn = shard_map(local_fn, mesh=mesh,
                   in_specs=(P(), specs, specs, specs),
                   out_specs=(specs, specs, specs), check_rep=False)
    return fn(count, list(g_leaves), list(mu_leaves), list(nu_leaves))


def _sharded_slim_tree(g_leaves, mu_leaves, nu_leaves, dims_leaves, spec_leaves, mesh, *,
                       b1, b2, eps, count, use_first_moment, interpret, bucket_min_size):
    """SlimAdam under shard_map, three regimes per leaf (see
    ``repro.sharding.shardspec``): 'local' leaves run the unchanged kernel
    dispatch on their shard (kernels, bucketing, jnp fits-gate fallback all
    re-derived from local shapes); 'psum' leaves complete their reduction
    lines with a cross-shard ``lax.psum``; 'jnp' leaves (interleaved K after
    sharding) run the reference math on their shard."""
    from ..sharding.logical import shard_map
    from jax.sharding import PartitionSpec as P

    plans = sharded_tree_plans(g_leaves, dims_leaves, spec_leaves, mesh,
                               n_bufs=PRECOND_BUFS)
    g_specs = [pl.spec for pl in plans]
    v_specs = [pl.red_spec for pl in plans]
    n = len(g_leaves)
    kw = dict(b1=b1, b2=b2, eps=eps)

    def dispatch(count, gs, ms, vs):
        out_u: List[Any] = [None] * n
        out_m: List[Any] = [None] * n
        out_v: List[Any] = [None] * n
        local_idx = [i for i, pl in enumerate(plans) if pl.regime == "local"]
        if local_idx:
            u, mo, vo = slim_tree_update(
                [gs[i] for i in local_idx],
                [ms[i] for i in local_idx] if use_first_moment else None,
                [vs[i] for i in local_idx],
                [tuple(dims_leaves[i]) for i in local_idx],
                count=count, use_first_moment=use_first_moment,
                interpret=interpret, bucket_min_size=bucket_min_size, **kw)
            for j, i in enumerate(local_idx):
                out_u[i] = u[j]
                out_m[i] = mo[j] if use_first_moment else None
                out_v[i] = vo[j]
        for i, pl in enumerate(plans):
            if pl.regime == "local":
                continue
            dims = tuple(dims_leaves[i])
            m_i = ms[i] if use_first_moment else None
            if pl.regime == "psum":
                out = _psum_slim_leaf(gs[i], m_i, vs[i], dims, axes=pl.psum_axes,
                                      red_total=pl.red_total, count=count,
                                      use_first_moment=use_first_moment, **kw)
            else:  # 'jnp': reduced dims whole on the shard, reference math
                out = jnp_slim_leaf(gs[i], m_i, vs[i], dims, count=count,
                                    use_first_moment=use_first_moment, **kw)
            out_u[i], out_m[i], out_v[i] = out
        return out_u, out_m, out_v

    if use_first_moment:
        def local_fn(count, gs, ms, vs):
            return dispatch(count, gs, ms, vs)

        fn = shard_map(local_fn, mesh=mesh,
                       in_specs=(P(), g_specs, g_specs, v_specs),
                       out_specs=(g_specs, g_specs, v_specs), check_rep=False)
        return fn(count, list(g_leaves), list(mu_leaves), list(nu_leaves))

    def local_fn_no_mu(count, gs, vs):
        u, _, v = dispatch(count, gs, None, vs)
        return u, v

    fn = shard_map(local_fn_no_mu, mesh=mesh,
                   in_specs=(P(), g_specs, v_specs),
                   out_specs=(g_specs, v_specs), check_rep=False)
    u, v = fn(count, list(g_leaves), list(nu_leaves))
    return u, None, v


# ---------------------------------------------------------------------------
# Tree-level entry points (operate on flat leaf lists; the transformations
# own flatten/unflatten so pytree structure stays their concern)
# ---------------------------------------------------------------------------


def adam_tree_update(g_leaves: Sequence[jnp.ndarray], mu_leaves: Sequence[jnp.ndarray],
                     nu_leaves: Sequence[jnp.ndarray], *, b1: float, b2: float,
                     eps: float, count, interpret: Optional[bool] = None,
                     bucket_min_size: int = DEFAULT_BUCKET_MIN,
                     mesh=None, spec_leaves=None):
    """Dense Adam over a leaf list: kernels for eligible leaves (small ones
    bucketed), jnp fallback otherwise. Returns (updates, new_mu, new_nu).

    With ``mesh`` + ``spec_leaves`` (one PartitionSpec per leaf) the whole
    update runs under ``shard_map`` — each device updates its local shards —
    instead of letting GSPMD gather full leaves around the pallas_call
    optimization barrier."""
    interpret = default_interpret() if interpret is None else interpret
    if _use_sharded(mesh, spec_leaves) and len(g_leaves):
        return _sharded_adam_tree(g_leaves, mu_leaves, nu_leaves, spec_leaves, mesh,
                                  b1=b1, b2=b2, eps=eps, count=count,
                                  interpret=interpret, bucket_min_size=bucket_min_size)
    kw = dict(b1=b1, b2=b2, eps=eps, count=count)
    n = len(g_leaves)
    out_u: List[Any] = [None] * n
    out_m: List[Any] = [None] * n
    out_v: List[Any] = [None] * n
    bucket: List[int] = []
    for i, (g, m, v) in enumerate(zip(g_leaves, mu_leaves, nu_leaves)):
        if leaf_plan(g.shape, g.dtype, ()).route == "jnp":
            out_u[i], out_m[i], out_v[i] = jnp_adam_leaf(g, m, v, **kw)
        elif bucket_min_size and g.size < bucket_min_size:
            bucket.append(i)
        else:
            out_u[i], out_m[i], out_v[i] = _dense_kernel_leaf(
                g, m, v, interpret=interpret, **kw)
    _flush_bucket(bucket, g_leaves, mu_leaves, nu_leaves, out_u, out_m, out_v,
                  interpret=interpret, **kw)
    return out_u, out_m, out_v


def slim_tree_update(g_leaves: Sequence[jnp.ndarray], mu_leaves: Optional[Sequence[jnp.ndarray]],
                     nu_leaves: Sequence[jnp.ndarray], dims_leaves: Sequence[Dims], *,
                     b1: float, b2: float, eps: float, count,
                     use_first_moment: bool = True, interpret: Optional[bool] = None,
                     bucket_min_size: int = DEFAULT_BUCKET_MIN,
                     mesh=None, spec_leaves=None):
    """SlimAdam over a leaf list with per-leaf reduction-dim tuples.

    Each leaf's route comes from one :func:`leaf_plan` lookup: K = () leaves
    take the dense route (and join the dense bucket when small); K != ()
    leaves dispatch to the slim kernel named by their canonical plan; leaves
    no kernel can serve fall back to jnp. ``use_first_moment=False`` runs
    entirely on the jnp path — the kernels read/write a first moment, so
    serving the moment-less variant would stream a discarded full-size m and
    forfeit the bandwidth win. Returns (updates, new_mu_or_None, new_nu).

    With ``mesh`` + ``spec_leaves`` the update runs under ``shard_map`` with
    per-leaf regime plans (``repro.sharding.shardspec``): leaves whose
    reduced dims are whole per shard run the kernels locally on the shard,
    leaves whose reduced dims are split complete their reduction lines with
    a ``lax.psum`` over the owning mesh axes, and interleaved-K-after-
    sharding leaves run the reference jnp math per shard."""
    interpret = default_interpret() if interpret is None else interpret
    if _use_sharded(mesh, spec_leaves) and len(g_leaves):
        return _sharded_slim_tree(g_leaves, mu_leaves, nu_leaves, dims_leaves,
                                  spec_leaves, mesh, b1=b1, b2=b2, eps=eps,
                                  count=count, use_first_moment=use_first_moment,
                                  interpret=interpret, bucket_min_size=bucket_min_size)
    kw = dict(b1=b1, b2=b2, eps=eps, count=count)
    n = len(g_leaves)
    if not use_first_moment:
        outs = [jnp_slim_leaf(g, None, v, tuple(d), use_first_moment=False, **kw)
                for g, v, d in zip(g_leaves, nu_leaves, dims_leaves)]
        return [o[0] for o in outs], None, [o[2] for o in outs]
    out_u: List[Any] = [None] * n
    out_m: List[Any] = [None] * n
    out_v: List[Any] = [None] * n
    bucket: List[int] = []
    for i, (g, v, dims) in enumerate(zip(g_leaves, nu_leaves, dims_leaves)):
        dims = tuple(dims)
        plan = leaf_plan(g.shape, g.dtype, dims, n_bufs=PRECOND_BUFS)
        if plan.route == "jnp":
            out_u[i], out_m[i], out_v[i] = jnp_slim_leaf(
                g, mu_leaves[i], v, dims, use_first_moment=True, **kw)
        elif plan.route == "dense":
            if bucket_min_size and g.size < bucket_min_size:
                bucket.append(i)
            else:
                out_u[i], out_m[i], out_v[i] = _dense_kernel_leaf(
                    g, mu_leaves[i], v, interpret=interpret, **kw)
        else:
            out_u[i], out_m[i], out_v[i] = _slim_kernel_leaf(
                g, mu_leaves[i], v, plan.cn, interpret=interpret, **kw)
    _flush_bucket(bucket, g_leaves, mu_leaves, nu_leaves, out_u, out_m, out_v,
                  interpret=interpret, **kw)
    return out_u, out_m, out_v
