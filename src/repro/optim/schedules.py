"""Learning-rate schedules (paper: linear warmup -> cosine decay to eta/10)."""
from __future__ import annotations

import jax.numpy as jnp


def constant(value: float):
    def schedule(count):
        return jnp.asarray(value, jnp.float32)

    return schedule


def linear_warmup(peak: float, warmup_steps: int):
    def schedule(count):
        frac = jnp.minimum(count.astype(jnp.float32) / max(warmup_steps, 1), 1.0)
        return peak * frac

    return schedule


def cosine_decay(init_value: float, decay_steps: int, alpha: float = 0.0):
    def schedule(count):
        frac = jnp.clip(count.astype(jnp.float32) / max(decay_steps, 1), 0.0, 1.0)
        cosine = 0.5 * (1.0 + jnp.cos(jnp.pi * frac))
        return init_value * ((1 - alpha) * cosine + alpha)

    return schedule


def warmup_cosine(peak: float, warmup_steps: int, total_steps: int, end_value: float | None = None):
    """The paper's schedule: linear 0 -> peak over warmup, cosine to peak/10.

    ``end_value`` defaults to peak / 10 per the paper (eta_min = eta / 10).
    """
    if end_value is None:
        end_value = peak / 10.0
    alpha = end_value / peak if peak > 0 else 0.0
    decay_steps = max(total_steps - warmup_steps, 1)

    def schedule(count):
        count_f = count.astype(jnp.float32)
        warm = peak * jnp.minimum(count_f / max(warmup_steps, 1), 1.0)
        frac = jnp.clip((count_f - warmup_steps) / decay_steps, 0.0, 1.0)
        cosine = 0.5 * (1.0 + jnp.cos(jnp.pi * frac))
        decayed = peak * ((1 - alpha) * cosine + alpha)
        return jnp.where(count_f < warmup_steps, warm, decayed)

    return schedule
