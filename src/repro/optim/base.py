"""Minimal optax-style gradient-transformation API.

optax is not available in this environment; the paper's contribution is an
optimizer, so we own the whole substrate. The API mirrors optax closely so
that `repro.core.slim_adam` composes like any other transformation:

    tx = chain(clip_by_global_norm(1.0), slim_adam(...), add_decayed_weights(0.1),
               scale_by_schedule(warmup_cosine(...)), scale(-1.0))

All states are pytrees of jax arrays so they pjit/checkpoint/reshard like
parameters.
"""
from __future__ import annotations

from typing import Any, Callable, NamedTuple, Optional, Tuple, Union

import jax
import jax.numpy as jnp

PyTree = Any
Schedule = Callable[[jnp.ndarray], jnp.ndarray]
ScalarOrSchedule = Union[float, Schedule]

# Optimizer execution backends (see repro.optim.fused):
#   'jnp'   — per-leaf jax.numpy tree-map (the reference path, runs anywhere)
#   'fused' — route eligible leaves through the fused Pallas kernels
#             (interpret mode off-TPU), jnp fallback for the rest
#   'auto'  — 'fused' on TPU, 'jnp' elsewhere (the Pallas interpreter would
#             be slower than XLA on CPU/GPU, so auto never pays it)
BACKENDS = ("jnp", "fused", "auto")


def resolve_backend(backend: str) -> str:
    """Collapse 'auto' to a concrete backend for the current jax platform."""
    if backend not in BACKENDS:
        raise ValueError(f"unknown backend {backend!r}; choose from {BACKENDS}")
    if backend == "auto":
        return "fused" if jax.default_backend() == "tpu" else "jnp"
    return backend


class GradientTransformation(NamedTuple):
    """A pair of pure functions (init, update).

    update(grads, state, params) -> (updates, new_state). ``updates`` are to
    be *added* to params (sign conventions handled by ``scale(-lr)`` at the
    end of a chain, exactly like optax).
    """

    init: Callable[[PyTree], PyTree]
    update: Callable[[PyTree, PyTree, Optional[PyTree]], Tuple[PyTree, PyTree]]


class EmptyState(NamedTuple):
    pass


def identity() -> GradientTransformation:
    def init_fn(params):
        del params
        return EmptyState()

    def update_fn(updates, state, params=None):
        del params
        return updates, state

    return GradientTransformation(init_fn, update_fn)


class ChainState(NamedTuple):
    inner_states: Tuple[PyTree, ...]


def chain(*transforms: GradientTransformation) -> GradientTransformation:
    """Compose transformations left-to-right (like optax.chain)."""

    def init_fn(params):
        return ChainState(tuple(t.init(params) for t in transforms))

    def update_fn(updates, state, params=None):
        new_states = []
        for t, s in zip(transforms, state.inner_states):
            updates, new_s = t.update(updates, s, params)
            new_states.append(new_s)
        return updates, ChainState(tuple(new_states))

    return GradientTransformation(init_fn, update_fn)


class ScaleState(NamedTuple):
    pass


def scale(factor: float) -> GradientTransformation:
    def init_fn(params):
        del params
        return ScaleState()

    def update_fn(updates, state, params=None):
        del params
        return jax.tree.map(lambda u: u * factor, updates), state

    return GradientTransformation(init_fn, update_fn)


class ScaleByScheduleState(NamedTuple):
    count: jnp.ndarray  # int32 scalar


def scale_by_schedule(schedule: Schedule) -> GradientTransformation:
    def init_fn(params):
        del params
        return ScaleByScheduleState(count=jnp.zeros([], jnp.int32))

    def update_fn(updates, state, params=None):
        del params
        step_size = schedule(state.count)
        updates = jax.tree.map(lambda u: u * step_size.astype(u.dtype), updates)
        return updates, ScaleByScheduleState(count=state.count + 1)

    return GradientTransformation(init_fn, update_fn)


def scale_by_learning_rate(lr: ScalarOrSchedule, *, flip_sign: bool = True) -> GradientTransformation:
    m = -1.0 if flip_sign else 1.0
    if callable(lr):
        return scale_by_schedule(lambda count: m * lr(count))
    return scale(m * lr)


def global_norm(tree: PyTree) -> jnp.ndarray:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in leaves))


class ClipByGlobalNormState(NamedTuple):
    pass


def clip_by_global_norm(max_norm: float) -> GradientTransformation:
    def init_fn(params):
        del params
        return ClipByGlobalNormState()

    def update_fn(updates, state, params=None):
        del params
        g_norm = global_norm(updates)
        # Match optax/torch semantics: rescale only when the norm exceeds the
        # threshold; never amplify.
        trigger = jnp.squeeze(g_norm <= max_norm)
        scale_factor = jnp.where(trigger, 1.0, max_norm / (g_norm + 1e-16))
        updates = jax.tree.map(lambda u: u * scale_factor.astype(u.dtype), updates)
        return updates, state

    return GradientTransformation(init_fn, update_fn)


class AddDecayedWeightsState(NamedTuple):
    pass


def _default_wd_mask(params: PyTree) -> PyTree:
    """Decay matrices, skip vectors (norm scales / biases) — the standard LM recipe."""
    return jax.tree.map(lambda p: jnp.ndim(p) >= 2, params)


def add_decayed_weights(
    weight_decay: float, mask: Optional[Union[PyTree, Callable[[PyTree], PyTree]]] = None
) -> GradientTransformation:
    """Decoupled weight decay (AdamW): adds wd * p to the *updates*.

    Placed after the preconditioner and before the learning-rate scale, this
    reproduces Loshchilov & Hutter's decoupled decay.
    """

    def init_fn(params):
        del params
        return AddDecayedWeightsState()

    def update_fn(updates, state, params=None):
        if params is None:
            raise ValueError("add_decayed_weights requires params")
        m = mask(params) if callable(mask) else mask
        if m is None:
            m_tree = jax.tree.map(lambda _: True, params)
        else:
            m_tree = m

        def leaf(u, p, use):
            return u + weight_decay * p.astype(u.dtype) if use else u

        updates = jax.tree.map(leaf, updates, params, m_tree)
        return updates, state

    return GradientTransformation(init_fn, update_fn)


class TraceState(NamedTuple):
    trace: PyTree


def trace(decay: float, nesterov: bool = False) -> GradientTransformation:
    """SGD momentum buffer."""

    def init_fn(params):
        return TraceState(trace=jax.tree.map(jnp.zeros_like, params))

    def update_fn(updates, state, params=None):
        del params
        new_trace = jax.tree.map(lambda t, u: decay * t + u, state.trace, updates)
        if nesterov:
            updates = jax.tree.map(lambda t, u: decay * t + u, new_trace, updates)
        else:
            updates = new_trace
        return updates, TraceState(trace=new_trace)

    return GradientTransformation(init_fn, update_fn)


def apply_updates(params: PyTree, updates: PyTree) -> PyTree:
    """p <- p + u, preserving the parameter dtype (updates may be fp32)."""
    return jax.tree.map(lambda p, u: (p.astype(jnp.float32) + u.astype(jnp.float32)).astype(p.dtype), params, updates)


# ---------------------------------------------------------------------------
# Gradient accumulation (multi-step) wrapper
# ---------------------------------------------------------------------------


class MultiStepsState(NamedTuple):
    mini_step: jnp.ndarray
    inner_state: PyTree
    acc_grads: PyTree


def multi_steps(inner: GradientTransformation, every_k: int) -> GradientTransformation:
    """Accumulate gradients for ``every_k`` micro-steps, then apply ``inner``.

    Between applications the emitted updates are zeros, so the caller can
    unconditionally ``apply_updates`` each micro-step.
    """

    def init_fn(params):
        return MultiStepsState(
            mini_step=jnp.zeros([], jnp.int32),
            inner_state=inner.init(params),
            acc_grads=jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params),
        )

    def update_fn(updates, state, params=None):
        acc = jax.tree.map(lambda a, u: a + u.astype(jnp.float32) / every_k, state.acc_grads, updates)
        is_last = state.mini_step == every_k - 1

        def do_apply(operand):
            acc_, inner_state_ = operand
            out, new_inner = inner.update(acc_, inner_state_, params)
            zeros = jax.tree.map(jnp.zeros_like, acc_)
            return out, new_inner, zeros

        def do_skip(operand):
            acc_, inner_state_ = operand
            zeros_out = jax.tree.map(jnp.zeros_like, acc_)
            return zeros_out, inner_state_, acc_

        out, new_inner, new_acc = jax.lax.cond(is_last, do_apply, do_skip, (acc, state.inner_state))
        return out, MultiStepsState(
            mini_step=(state.mini_step + 1) % every_k, inner_state=new_inner, acc_grads=new_acc
        )

    return GradientTransformation(init_fn, update_fn)
