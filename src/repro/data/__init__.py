from .pipeline import DataConfig, ZipfLM, linear_model_batches

__all__ = ["DataConfig", "ZipfLM", "linear_model_batches"]
