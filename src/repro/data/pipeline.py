"""Deterministic synthetic LM data with a controllable heavy tail.

The container is offline, so OpenWebText/FineWeb-Edu are replaced by a
Zipfian Markov stream: token frequencies follow p(t) ∝ 1/(t+1)^alpha with a
bigram structure so the model has something learnable. The tail exponent
directly drives the paper's §4.1 mechanism (heavy-tailed token distributions
make embedding/LM-head second moments incompressible along the token dim),
so the vocab-size experiments reproduce on this stream.

Sharded loading: each host materializes only its slice of the global batch
(``host_slice``) — the per-host pattern a real multi-host launcher uses.
Determinism: batch content is a pure function of (seed, step), so restarts
resume mid-stream without data loss or repetition (fault tolerance).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Tuple

import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    alpha: float = 1.2           # Zipf tail exponent (larger = lighter tail)
    n_states: int = 512          # Markov bigram states for learnable structure
    seed: int = 0


class ZipfLM:
    """Stateless batch generator: ``batch(step)`` is deterministic."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        rng = np.random.default_rng(cfg.seed)
        v = cfg.vocab_size
        ranks = np.arange(1, v + 1, dtype=np.float64)
        base = 1.0 / ranks ** cfg.alpha
        base /= base.sum()
        self.base = base
        # per-state preferred continuation: mixture of the Zipf base and a
        # state-specific boost so P(next | state) is learnable
        k = min(cfg.n_states, v)
        self.state_boost = rng.integers(0, v, size=(k, 8))
        self.n_states = k

    def _tokens(self, rng: np.random.Generator, n: int) -> np.ndarray:
        cfg = self.cfg
        out = np.empty(n, dtype=np.int32)
        state = int(rng.integers(0, self.n_states))
        # vectorized-ish: draw base tokens, then overwrite a learnable fraction
        # with the state-dependent continuation
        base_draw = rng.choice(cfg.vocab_size, size=n, p=self.base)
        mix = rng.random(n) < 0.5
        for i in range(n):
            if mix[i]:
                out[i] = self.state_boost[state, int(rng.integers(0, 8))]
            else:
                out[i] = base_draw[i]
            state = out[i] % self.n_states
        return out

    def batch(self, step: int, *, host_id: int = 0, host_count: int = 1) -> Dict[str, np.ndarray]:
        cfg = self.cfg
        assert cfg.global_batch % host_count == 0
        per_host = cfg.global_batch // host_count
        rng = np.random.default_rng((cfg.seed, step, host_id))
        toks = self._tokens(rng, per_host * (cfg.seq_len + 1)).reshape(per_host, cfg.seq_len + 1)
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:].astype(np.int32)}

    def iterate(self, start_step: int = 0, *, host_id: int = 0, host_count: int = 1) -> Iterator[Dict[str, np.ndarray]]:
        step = start_step
        while True:
            yield self.batch(step, host_id=host_id, host_count=host_count)
            step += 1


# ---------------------------------------------------------------------------
# Tiny real-text corpus for the two-layer linear-model experiment (§4.1):
# byte-pair-free word/byte tokenization over an embedded sample so the token
# distribution has a *natural* heavy tail.
# ---------------------------------------------------------------------------

_SAMPLE = (
    "the quick brown fox jumps over the lazy dog . the dog sleeps . "
    "a model of language must learn the long tail of rare words . "
    "optimization of deep networks with adaptive methods is the standard . "
    "the second moments of the gradients concentrate along certain dimensions . "
    "rare tokens receive rare gradient updates and so their moments evolve slowly . "
    "frequent tokens receive frequent updates and their moments grow quickly . "
    "this difference in time scale is why the token dimension resists compression . "
    "signal to noise ratios quantify when a mean can stand in for the many . "
) * 64


def byte_corpus(vocab_size: int, seq_len: int, *, seed: int = 0) -> Tuple[np.ndarray, int]:
    """Greedy frequency-truncated word tokenizer: maps the sample text onto
    ``vocab_size`` ids (rare words -> hash buckets, preserving a heavy tail).
    Returns (token stream, effective vocab)."""
    words = _SAMPLE.split()
    uniq, counts = np.unique(words, return_counts=True)
    order = np.argsort(-counts)
    vocab = {w: i for i, w in enumerate(uniq[order][: vocab_size - 1])}
    ids = np.array([vocab.get(w, (hash(w) % 1) + vocab_size - 1) for w in words], dtype=np.int32)
    return ids, vocab_size


def linear_model_batches(vocab_size: int, seq_len: int, batch: int, *, seed: int = 0):
    """Batches for the §4.1 two-layer model: Zipf stream at the requested
    vocabulary size (progressively truncating the tail, like the paper's BPE
    vocab sweep)."""
    gen = ZipfLM(DataConfig(vocab_size=vocab_size, seq_len=seq_len, global_batch=batch,
                            alpha=1.1, seed=seed))
    return gen
