"""Repo lint — AST rules for the contracts grep can't check.

Four rules, each an invariant some earlier PR paid for in debugging time:

  * **RPR001** — ``pallas_call`` is referenced only under
    ``src/repro/kernels/``. Every call site outside the kernel package would
    dodge the registry (and so kernelcheck, the race detector, and the
    golden signature matrix).
  * **RPR002** — no host-side ``np.`` and no Python branching on traced
    values where a tracer would hit them: inside kernel bodies (functions
    taking ``*_ref``/``*_out`` refs) and inside ``@jax.jit``-decorated
    functions. Static mode flags (``if with_snr:``) stay legal — only
    ``If``/``While`` tests tainted by a ref read are flagged.
  * **RPR003** — optional fields of ``*State`` NamedTuples must default to
    ``None``: a None leaf contributes nothing to the pytree, so plain
    states keep their checkpoint layout and jit cache keys (the contract
    ``ScaleBySlimAdamState.snr``/``health`` rely on).
  * **RPR004** — checkpoint modules publish atomically: ``os.rename`` and
    ``shutil.move`` are banned, ``os.replace`` must move *from* a staged
    tmp path, and nothing writes the ``LATEST`` pointer in place.

``lint_source(text, path)`` lints one buffer (used by the seeded-regression
tests); ``run()`` walks ``src/repro``.
"""
from __future__ import annotations

import ast
import time
from pathlib import Path
from typing import List, Optional, Set, Tuple

from .report import PassResult

SRC_ROOT = Path(__file__).resolve().parents[2]  # .../src

LintHit = Tuple[str, int, str]  # (rule, lineno, message)


def _is_kernel_path(path: str) -> bool:
    return "kernels" in Path(path).parts


def _is_checkpoint_path(path: str) -> bool:
    return "checkpoint" in Path(path).parts or "checkpoint" in Path(path).stem


def _call_name(node: ast.Call) -> str:
    return ast.unparse(node.func)


def _jit_decorated(fn: ast.FunctionDef) -> bool:
    for dec in fn.decorator_list:
        src = ast.unparse(dec)
        if "jit" in src.split("(")[0].split(".")[-1] or "jax.jit" in src:
            return True
    return False


def _kernel_refs(fn: ast.FunctionDef) -> Set[str]:
    names = {a.arg for a in fn.args.args + fn.args.posonlyargs}
    return {n for n in names if n.endswith("_ref") or n.endswith("_out")}


def _ref_read(node: ast.AST, refs: Set[str]) -> bool:
    """True for a subscript read out of a ref (``g_ref[...]``, ``h_out[0]``).
    A *bare* ref name is not a read — ``if h_out:`` on a varargs ref tuple
    is static arity, not traced data."""
    return (isinstance(node, ast.Subscript)
            and any(isinstance(n, ast.Name) and n.id in refs
                    for n in ast.walk(node.value)))


def _tainted_names(fn: ast.FunctionDef, refs: Set[str]) -> Set[str]:
    """Names holding values read out of a ref (one propagation pass per
    assignment, in source order — enough for straight-line kernel bodies)."""
    tainted: Set[str] = set()

    def expr_tainted(e: ast.AST) -> bool:
        return any(_ref_read(n, refs)
                   or (isinstance(n, ast.Name) and n.id in tainted)
                   for n in ast.walk(e))

    for node in ast.walk(fn):
        if isinstance(node, ast.Assign) and expr_tainted(node.value):
            # Only plain-name bindings: a subscripted target is a store INTO
            # a ref, not a host binding of traced data.
            for tgt in node.targets:
                elts = tgt.elts if isinstance(tgt, ast.Tuple) else [tgt]
                for n in elts:
                    if isinstance(n, ast.Name):
                        tainted.add(n.id)
    return tainted


def _check_traced_host_code(fn: ast.FunctionDef, refs: Set[str],
                            ctx: str) -> List[LintHit]:
    hits: List[LintHit] = []
    tainted = _tainted_names(fn, refs) if refs else set()
    for node in ast.walk(fn):
        if (isinstance(node, ast.Attribute) and isinstance(node.value, ast.Name)
                and node.value.id == "np"):
            hits.append(("RPR002", node.lineno,
                         f"host `np.{node.attr}` inside {ctx} `{fn.name}` — "
                         f"numpy ops on traced values concretize the tracer; "
                         f"use jnp"))
        elif isinstance(node, (ast.If, ast.While)) and refs:
            test_names = {n.id for n in ast.walk(node.test)
                          if isinstance(n, ast.Name)}
            reads = any(_ref_read(n, refs) for n in ast.walk(node.test))
            if reads or test_names & tainted:
                hits.append(("RPR002", node.lineno,
                             f"Python `{type(node).__name__.lower()}` on a "
                             f"ref-derived value in kernel body `{fn.name}` — "
                             f"branch with jnp.where/pl.when, not host control "
                             f"flow"))
    return hits


def _check_state_defaults(cls: ast.ClassDef) -> List[LintHit]:
    hits: List[LintHit] = []
    for st in cls.body:
        if not isinstance(st, ast.AnnAssign):
            continue
        ann = ast.unparse(st.annotation)
        if "Optional" not in ann:
            continue
        ok = (st.value is not None
              and isinstance(st.value, ast.Constant) and st.value.value is None)
        if not ok:
            hits.append(("RPR003", st.lineno,
                         f"optional field `{ast.unparse(st.target)}` of "
                         f"`{cls.name}` must default to None so plain states "
                         f"keep their pytree layout"))
    return hits


def _check_checkpoint_calls(tree: ast.AST) -> List[LintHit]:
    hits: List[LintHit] = []
    for node in ast.walk(tree):
        if not isinstance(node, ast.Call):
            continue
        name = _call_name(node)
        if name == "os.rename":
            hits.append(("RPR004", node.lineno,
                         "os.rename in a checkpoint module — publish with "
                         "os.replace (atomic overwrite semantics)"))
        elif name == "shutil.move":
            hits.append(("RPR004", node.lineno,
                         "shutil.move in a checkpoint module — can degrade to "
                         "copy+delete across filesystems; stage and "
                         "os.replace instead"))
        elif name == "os.replace" and node.args:
            src = ast.unparse(node.args[0])
            if "tmp" not in src.lower():
                hits.append(("RPR004", node.lineno,
                             f"os.replace from `{src}` — the source of a "
                             f"publish must be a staged tmp path"))
        elif name == "open" or name.endswith((".write_text", ".write_bytes")):
            src = ast.unparse(node)
            writes = name != "open" or any(
                isinstance(a, ast.Constant) and isinstance(a.value, str)
                and any(m in a.value for m in "wax")
                for a in list(node.args[1:2]) + [
                    kw.value for kw in node.keywords if kw.arg == "mode"])
            if writes and "'LATEST'" in src.replace('"', "'") \
                    and "tmp" not in src.lower():
                hits.append(("RPR004", node.lineno,
                             "in-place write to the LATEST pointer — write a "
                             ".tmp sibling and os.replace it into place"))
    return hits


def lint_source(text: str, path: str) -> List[LintHit]:
    """Lint one source buffer; returns (rule, lineno, message) hits."""
    tree = ast.parse(text, filename=path)
    hits: List[LintHit] = []
    in_kernels = _is_kernel_path(path)

    for node in ast.walk(tree):
        if (not in_kernels
                and ((isinstance(node, ast.Attribute)
                      and node.attr == "pallas_call")
                     or (isinstance(node, ast.Name)
                         and node.id == "pallas_call"))):
            hits.append(("RPR001", node.lineno,
                         "pallas_call referenced outside repro/kernels/ — "
                         "kernels live in the kernel package so the analysis "
                         "registry covers them"))
        elif isinstance(node, ast.FunctionDef):
            refs = _kernel_refs(node)
            if refs:
                hits.extend(_check_traced_host_code(node, refs, "kernel body"))
            elif _jit_decorated(node):
                hits.extend(_check_traced_host_code(node, set(),
                                                    "jitted function"))
        elif isinstance(node, ast.ClassDef) and node.name.endswith("State"):
            hits.extend(_check_state_defaults(node))

    if _is_checkpoint_path(path):
        hits.extend(_check_checkpoint_calls(tree))
    return hits


def run(root: Optional[Path] = None) -> PassResult:
    t0 = time.monotonic()
    result = PassResult("lint")
    root = root or (SRC_ROOT / "repro")
    files = sorted(root.rglob("*.py"))
    for f in files:
        result.checks += 1
        rel = f.relative_to(root.parent)
        try:
            hits = lint_source(f.read_text(), str(rel))
        except SyntaxError as e:
            result.add("parse", str(rel), f"does not parse: {e}")
            continue
        for rule, lineno, message in hits:
            result.add(rule, f"{rel}:{lineno}", message)
    result.detail = f"{len(files)} files"
    result.seconds = time.monotonic() - t0
    return result
