"""Device-free jaxpr introspection for the kernel passes.

Everything here operates on traces of kernel *entry points* over
``jax.ShapeDtypeStruct`` arguments — no kernel body ever executes and no
array is materialized. A traced entry contains one (or more) ``pallas_call``
equations; :func:`find_pallas_eqns` digs them out of any wrapping structure
(the pad-and-recurse entries trace straight through: padding happens in
Python, so the trace holds a single aligned call), and :func:`pallas_info`
normalizes each into a :class:`PallasInfo` the checks can interrogate:

  * block geometry per operand/output (shape, backing array, index_map as a
    callable evaluated through ``jaxpr_as_fun`` — still device-free);
  * grid + per-dim semantics (``mosaic.dimension_semantics``; absent means
    every dim is sequential/"arbitrary");
  * the kernel body jaxpr, with ref reads (``get``) and writes (``swap``)
    collected per *root* ref through nested sub-jaxprs (``pl.when`` lowers
    to ``cond``), so RMW and compute-dtype contracts see conditional
    accesses too.
"""
from __future__ import annotations

import functools
import itertools
import math
from dataclasses import dataclass
from typing import Any, Callable, Dict, List, Optional, Sequence, Set, Tuple

import jax
import jax.numpy as jnp

try:  # jax.core is the public home in 0.4.x; _src is the fallback spelling
    from jax.core import ClosedJaxpr, Jaxpr, Literal, Var, jaxpr_as_fun
except ImportError:  # pragma: no cover
    from jax._src.core import ClosedJaxpr, Jaxpr, Literal, Var, jaxpr_as_fun


def trace_entry(fn: Callable, *args, **kwargs) -> ClosedJaxpr:
    """``make_jaxpr`` of ``fn(*args, **kwargs)`` — args may (and should) be
    ``ShapeDtypeStruct``s; keyword arguments are bound statically."""
    return jax.make_jaxpr(functools.partial(fn, **kwargs))(*args)


def count_pallas_launches(fn: Callable, *args, **kwargs) -> int:
    """Number of ``pallas_call`` equations in one trace of ``fn`` — the
    launch count a jitted call pays per step. Device-free (make_jaxpr over
    whatever abstract/concrete args are given); the megakernel benches gate
    on this so the O(leaves) -> O(groups) claim doesn't ride on interp-mode
    wall clocks."""
    return len(find_pallas_eqns(trace_entry(fn, *args, **kwargs).jaxpr))


def entry_signature(fn: Callable, *args, **kwargs) -> List[Any]:
    """Flat list of output ``ShapeDtypeStruct``s of an entry (eval_shape)."""
    out = jax.eval_shape(functools.partial(fn, **kwargs), *args)
    return list(jax.tree_util.tree_leaves(out))


def _iter_sub_jaxprs(params: Dict[str, Any]):
    for v in params.values():
        if isinstance(v, ClosedJaxpr):
            yield v.jaxpr
        elif isinstance(v, Jaxpr):
            yield v
        elif isinstance(v, (tuple, list)):
            for x in v:
                if isinstance(x, ClosedJaxpr):
                    yield x.jaxpr
                elif isinstance(x, Jaxpr):
                    yield x


def find_pallas_eqns(jaxpr: Jaxpr) -> List[Any]:
    """All ``pallas_call`` equations in ``jaxpr``, recursing through control
    flow / call primitives (kernel bodies cannot nest pallas calls, so their
    params are not walked)."""
    out = []
    for eqn in jaxpr.eqns:
        if eqn.primitive.name == "pallas_call":
            out.append(eqn)
            continue
        for sub in _iter_sub_jaxprs(eqn.params):
            out.extend(find_pallas_eqns(sub))
    return out


@dataclass
class BlockInfo:
    """One operand/output block of a pallas_call."""

    role: str                      # "in" | "out"
    slot: int                      # index within the role
    block_shape: Tuple[int, ...]   # block dims (mapped/None dims -> 1)
    array_shape: Tuple[int, ...]
    array_dtype: Any
    index_map: Callable[..., Tuple[int, ...]]

    @property
    def elems(self) -> int:
        return math.prod(self.block_shape) if self.block_shape else 1

    def bytes_at(self, itemsize: int) -> int:
        return self.elems * itemsize


@dataclass
class PallasInfo:
    """Normalized view of one pallas_call equation."""

    grid: Tuple[int, ...]
    dimension_semantics: Tuple[str, ...]   # per grid dim; "arbitrary" default
    blocks_in: List[BlockInfo]
    blocks_out: List[BlockInfo]
    body: Jaxpr                            # kernel body jaxpr
    num_index_operands: int

    @property
    def blocks(self) -> List[BlockInfo]:
        return self.blocks_in + self.blocks_out

    def body_ref(self, block: BlockInfo) -> Var:
        """The body jaxpr invar (MemRef) backing ``block`` — body invars are
        ordered [index operands, inputs, outputs, scratch]."""
        off = self.num_index_operands
        if block.role == "out":
            off += len(self.blocks_in)
        return self.body.invars[off + block.slot]

    def footprint_bytes(self, itemsize: int = 4) -> int:
        """Per-instance VMEM block footprint. Charged at ``itemsize`` (f32 by
        default) for every block — the kernels cast all operands to f32 for
        compute, so 4 B/elem is the live cost regardless of storage dtype."""
        return sum(b.bytes_at(itemsize) for b in self.blocks)

    def full_block_count(self) -> int:
        """Number of full-size (largest) blocks per instance — the quantity
        the declared ``*_BUFS`` constants budget for (lines, stats and
        scalar operands are O(kept)/O(1) and don't count)."""
        top = max(b.elems for b in self.blocks)
        return sum(1 for b in self.blocks if b.elems == top)


def _norm_block_shape(shape) -> Tuple[int, ...]:
    return tuple(1 if d is None else int(d) for d in tuple(shape))


def _index_map_fn(index_map_jaxpr: ClosedJaxpr,
                  scalar_samples: Optional[Sequence[Any]] = None
                  ) -> Callable[..., Tuple[int, ...]]:
    """Evaluate an index-map jaxpr at concrete grid indices. Scalar-prefetch
    kernels (``PrefetchScalarGridSpec``) hand every index map the prefetched
    operands (page tables, lengths) as extra invars after the grid indices;
    ``scalar_samples`` supplies concrete sample values for them so the maps
    stay evaluable device-free. Samples default to zeros of the invar avals
    — registry entries that alias through a lookup table provide real
    samples (see ``KernelEntry.scalar_args``) so collision analysis sees
    representative table contents."""
    invars = index_map_jaxpr.jaxpr.invars
    n_out = len(index_map_jaxpr.jaxpr.outvars)
    try:
        from jax._src.state.types import AbstractRef as _AbstractRef
    except ImportError:  # pragma: no cover
        _AbstractRef = ()
    if any(isinstance(v.aval, _AbstractRef) for v in invars):
        # Scalar-prefetch operands arrive as (S)MEM refs whose reads are
        # stateful `get`s; discharge turns them into plain array inputs
        # (appending the final ref values to the outputs, truncated below).
        from jax._src.state.discharge import discharge_state
        dj, dconsts = discharge_state(index_map_jaxpr.jaxpr,
                                      index_map_jaxpr.consts)
        f = jaxpr_as_fun(ClosedJaxpr(dj, dconsts))
    else:
        f = jaxpr_as_fun(index_map_jaxpr)
    extras = tuple(jnp.asarray(s) for s in (scalar_samples or ()))

    def call(*idx: int) -> Tuple[int, ...]:
        args = [jnp.int32(i) for i in idx]
        # invars = [grid indices..., scalar operands...]; fill any operand
        # slot not covered by a provided sample with aval-shaped zeros
        for v in invars[len(args):len(invars) - len(extras)]:
            args.append(jnp.zeros(v.aval.shape, v.aval.dtype))
        return tuple(int(x) for x in f(*args, *extras)[:n_out])

    return call


def pallas_info(eqn, scalar_samples: Optional[Sequence[Any]] = None) -> PallasInfo:
    gm = eqn.params["grid_mapping"]
    body = eqn.params["jaxpr"]
    grid = tuple(int(g) for g in gm.grid)
    n_idx = int(getattr(gm, "num_index_operands", 0))
    n_out = len(eqn.outvars)
    n_in = len(eqn.invars) - n_idx

    cp = eqn.params.get("compiler_params") or {}
    sem = None
    if isinstance(cp, dict):
        mosaic = cp.get("mosaic") or {}
        sem = mosaic.get("dimension_semantics") if isinstance(mosaic, dict) else None
    if sem is None:
        sem = ("arbitrary",) * len(grid)
    sem = tuple(str(s) for s in sem)

    mappings = list(gm.block_mappings)
    assert len(mappings) == n_in + n_out, (
        f"block_mappings ({len(mappings)}) != inputs ({n_in}) + outputs ({n_out})")

    def mk(role: str, slot: int, bm) -> BlockInfo:
        sds = bm.array_shape_dtype
        return BlockInfo(
            role=role, slot=slot,
            block_shape=_norm_block_shape(bm.block_shape),
            array_shape=tuple(sds.shape), array_dtype=sds.dtype,
            index_map=_index_map_fn(bm.index_map_jaxpr, scalar_samples),
        )

    blocks_in = [mk("in", i, mappings[i]) for i in range(n_in)]
    blocks_out = [mk("out", i, mappings[n_in + i]) for i in range(n_out)]
    return PallasInfo(grid=grid, dimension_semantics=sem,
                      blocks_in=blocks_in, blocks_out=blocks_out,
                      body=body, num_index_operands=n_idx)


# ---------------------------------------------------------------------------
# Ref access collection (get/swap through nested sub-jaxprs)
# ---------------------------------------------------------------------------


@dataclass
class RefOp:
    """One ``get`` or ``swap`` on a root ref, wherever it occurs."""

    kind: str    # "get" | "swap"
    root: Var    # the body invar the accessed ref aliases
    eqn: Any
    jaxpr: Jaxpr  # the (sub-)jaxpr the access lives in


def _sub_jaxpr_bindings(eqn):
    """(sub_jaxpr, [(inner_var, outer_var), ...]) pairs for primitives whose
    sub-jaxprs rebind the outer operands — enough for the structures kernel
    bodies contain (``cond`` from ``pl.when``; generic 1:1 call wrappers)."""
    name = eqn.primitive.name
    if name == "cond":
        ops = eqn.invars[1:]  # invars[0] is the branch index
        for closed in eqn.params.get("branches", ()):
            sub = closed.jaxpr if isinstance(closed, ClosedJaxpr) else closed
            yield sub, list(zip(sub.invars, ops))
        return
    for sub in _iter_sub_jaxprs(eqn.params):
        if len(sub.invars) == len(eqn.invars):
            yield sub, list(zip(sub.invars, eqn.invars))


def collect_ref_ops(jaxpr: Jaxpr, env: Dict[Var, Var]) -> List[RefOp]:
    """All get/swap accesses in ``jaxpr`` (recursively) whose ref resolves —
    through ``env`` — to one of the root vars env maps to."""
    ops: List[RefOp] = []
    for eqn in jaxpr.eqns:
        name = eqn.primitive.name
        if name in ("get", "swap"):
            ref = eqn.invars[0]
            if isinstance(ref, Var) and ref in env:
                ops.append(RefOp(name, env[ref], eqn, jaxpr))
        for sub, binds in _sub_jaxpr_bindings(eqn):
            sub_env = {inner: env[outer]
                       for inner, outer in binds
                       if isinstance(outer, Var) and outer in env}
            if sub_env:
                ops.extend(collect_ref_ops(sub, sub_env))
    return ops


def ref_ops_for(info: PallasInfo) -> List[RefOp]:
    env = {v: v for v in info.body.invars if isinstance(v, Var)}
    return collect_ref_ops(info.body, env)


def var_consumers(jaxpr: Jaxpr, var: Var) -> List[Any]:
    """Equations in ``jaxpr`` (same level) that read ``var``."""
    return [e for e in jaxpr.eqns
            if any(isinstance(v, Var) and v is var for v in e.invars)]


def var_producer(jaxpr: Jaxpr, var: Var) -> Optional[Any]:
    """The equation in ``jaxpr`` (same level) that defines ``var``, if any."""
    for e in jaxpr.eqns:
        if any(v is var for v in e.outvars):
            return e
    return None


# ---------------------------------------------------------------------------
# Grid aliasing (non-injective index maps)
# ---------------------------------------------------------------------------


def _grid_points(grid: Sequence[int], per_dim: int = 4):
    """Representative grid points: every point for small grids; for large
    dims the first/last ``per_dim`` indices (constant and striding maps both
    collide within that sample)."""
    axes = []
    for n in grid:
        if n <= 2 * per_dim:
            axes.append(range(n))
        else:
            axes.append(sorted(set(range(per_dim)) | set(range(n - per_dim, n))))
    return itertools.product(*axes)


def aliased_grid_dims(block: BlockInfo, grid: Sequence[int]) -> Set[int]:
    """Grid dims along which ``block``'s index_map collides: dims in which two
    sampled grid points that map to the same block index differ. Empty set =
    injective over the sample (one block instance per grid point)."""
    seen: Dict[Tuple[int, ...], List[Tuple[int, ...]]] = {}
    for pt in _grid_points(grid):
        seen.setdefault(block.index_map(*pt), []).append(pt)
    dims: Set[int] = set()
    for pts in seen.values():
        if len(pts) < 2:
            continue
        base = pts[0]
        for other in pts[1:]:
            dims.update(d for d in range(len(grid)) if base[d] != other[d])
    return dims
