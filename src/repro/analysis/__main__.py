"""``python -m repro.analysis`` — run the static contract passes as a gate.

Prints one table row per pass and exits non-zero if any pass reports a
finding (or crashes — a crashed pass is a failed pass, not a skipped one).
On a golden-signature mismatch the freshly computed matrix is written to
``--diff-out`` so CI can upload it as an artifact; to accept an intentional
signature change, run with ``--update-golden`` and commit the new
``golden_signatures.json``.
"""
from __future__ import annotations

import argparse
import json
import sys
import time
from pathlib import Path

from . import PASS_NAMES
from .report import PassResult


def _run_pass(name: str, update_golden: bool, diff_out: Path) -> PassResult:
    t0 = time.monotonic()
    try:
        if name == "kernelcheck":
            from . import kernelcheck
            result, computed = kernelcheck.run(update_golden=update_golden)
            if any(f.check == "golden" for f in result.findings):
                diff_out.write_text(json.dumps(computed, indent=1,
                                               sort_keys=True) + "\n")
                result.detail = ((result.detail + "; ") if result.detail
                                 else "") + f"computed matrix -> {diff_out}"
            return result
        if name == "races":
            from . import races
            return races.run()
        if name == "shardcheck":
            from . import shardcheck
            return shardcheck.run()
        if name == "tracecheck":
            from . import tracecheck
            return tracecheck.run()
        if name == "lint":
            from . import lint
            return lint.run()
        raise ValueError(f"unknown pass {name!r}")
    except Exception as e:  # noqa: BLE001 - a crashed pass is a failed pass
        result = PassResult(name, seconds=time.monotonic() - t0)
        result.checks += 1
        result.add("crash", name, f"{type(e).__name__}: {e}")
        return result


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(prog="python -m repro.analysis",
                                 description=__doc__)
    ap.add_argument("--only", default=None,
                    help="comma-separated subset of passes "
                         f"(default: all of {', '.join(PASS_NAMES)})")
    ap.add_argument("--update-golden", action="store_true",
                    help="rewrite golden_signatures.json from this run")
    ap.add_argument("--diff-out", type=Path,
                    default=Path("golden_signatures.diff.json"),
                    help="where to dump the computed signature matrix on a "
                         "golden mismatch")
    args = ap.parse_args(argv)

    names = list(PASS_NAMES)
    if args.only:
        chosen = [p.strip() for p in args.only.split(",") if p.strip()]
        bad = [p for p in chosen if p not in PASS_NAMES]
        if bad:
            ap.error(f"unknown pass(es) {bad}; valid: {', '.join(PASS_NAMES)}")
        names = chosen

    results = [_run_pass(n, args.update_golden, args.diff_out) for n in names]

    widths = (12, 8, 9, 8, 6)
    header = ("pass", "checks", "findings", "time", "status")
    print(" ".join(h.ljust(w) for h, w in zip(header, widths)))
    print(" ".join("-" * w for w in widths))
    for r in results:
        status = "PASS" if r.ok else "FAIL"
        row = (r.name, str(r.checks), str(len(r.findings)),
               f"{r.seconds:.1f}s", status)
        print(" ".join(c.ljust(w) for c, w in zip(row, widths)))
        if r.detail:
            print(f"{'':12} {r.detail}")
    total = sum(len(r.findings) for r in results)
    if total:
        print(f"\n{total} finding(s):")
        for r in results:
            for f in r.findings:
                print(f"  {f}")
        return 1
    print(f"\nall {sum(r.checks for r in results)} checks green "
          f"in {sum(r.seconds for r in results):.1f}s")
    return 0


if __name__ == "__main__":
    sys.exit(main())
