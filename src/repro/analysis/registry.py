"""Registry of kernel entry points for the static checks.

One declarative table of every Pallas kernel entry, the declared VMEM
buffer constant its dispatcher gates with, its output-signature variants
(``with_snr`` / ``with_health``), and a shape x dtype x orientation case
matrix. The kernel passes (:mod:`repro.analysis.kernelcheck`,
:mod:`repro.analysis.races`) iterate this table; consumers that need a
kernel's *signature* rather than its execution — the roofline gates in
``benchmarks/opt_speed.py`` — read it from here too
(:func:`snr_stat_lines`, :func:`health_stat_outputs`), so "what does this
kernel output" has exactly one definition.

Everything is ``ShapeDtypeStruct``-driven: building args, tracing, and
signatures never materialize an array.
"""
from __future__ import annotations

import math
from typing import Callable, Dict, List, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels import fused_adam as _fa
from repro.kernels import megaplan as _mp
from repro.kernels import paged_attention as _pa
from repro.kernels import slim_update as _su
from repro.kernels import snr_stats as _ss
from repro.kernels.megaplan import (MEGA_ADAM_BUFS, MEGA_FINALIZE_BUFS,
                                    MEGA_PARTIAL_BUFS, MEGA_PRECOND_BUFS,
                                    MEGA_PRECOND_SNR_BUFS)
from repro.kernels.paged_attention import PAGED_ATTN_BUFS
from repro.kernels.slim_update import (FINALIZE_BUFS, PARTIAL_BUFS,
                                       PRECOND_BUFS, PRECOND_SNR_BUFS,
                                       UPDATE_BUFS)
from repro.kernels.snr_stats import CENTERED_BUFS, STATS_BUFS

from .jaxpr_tools import (entry_signature, find_pallas_eqns, pallas_info,
                          trace_entry)

f32 = jnp.float32
bf16 = jnp.bfloat16
i32 = jnp.int32


class Case(NamedTuple):
    """One abstract invocation shape for an entry."""

    label: str
    shape: Tuple[int, ...]          # (B, R, C) for strip entries, (R, C) for 2-D
    axis: Optional[int]             # strip reduction axis (None for 2-D tiles)
    dtypes: Tuple                   # dtype per positional arg
    kwargs: dict                    # static kwargs (block size etc.)
    kept: int                       # kept extent (for O(kept) classification)
    red: int                        # reduction extent (strip_fits input)


class Variant(NamedTuple):
    """One output-signature variant of an entry (appends extra outputs)."""

    name: str                       # "base" | "snr" | "health" | "snr+health"
    kwargs: dict
    bufs: Optional[int]             # declared strip n_bufs gate (None = 2-D tile)
    bufs_name: str


class KernelEntry(NamedTuple):
    name: str
    fn: Callable
    kind: str                       # "strip" | "tile2d" | "paged"
    arg_roles: Tuple[str, ...]      # "full" | "line" (strip), "full2d" (tile),
                                    # "q" | "pool" | "table" | "lengths" (paged)
    variants: Tuple[Variant, ...]   # variants[0] is the base signature
    cases: Tuple[Case, ...]
    # Concrete sample values for scalar-prefetch operands (page tables,
    # lengths) — index maps that read them can't be evaluated from grid
    # indices alone, so the race/aliasing analysis binds these samples.
    scalar_args: Optional[Callable[[Case], Tuple]] = None


def _dts(n: int, **over):
    """n float32 dtypes with per-slot overrides: _dts(3, s0=bf16)."""
    out = [f32] * n
    for key, dt in over.items():
        out[int(key[1:])] = dt
    return tuple(out)


def _strip_cases(n_args: int, *, bf16_slots: Tuple[int, ...],
                 fit_edge_bufs: Optional[int] = None) -> Tuple[Case, ...]:
    """The standard strip case matrix: minor/major orientation, a bf16
    storage case, a ragged (pad-and-recurse) kept extent, and optionally a
    reduction extent that lands exactly on the VMEM fit boundary for the
    entry's base buffer count."""
    over = {f"s{i}": bf16 for i in bf16_slots}
    cases = [
        Case("minor", (2, 8, 128), 1, _dts(n_args), {"block": 4}, kept=8, red=128),
        Case("major", (2, 128, 8), 0, _dts(n_args), {"block": 4}, kept=8, red=128),
        Case("minor-bf16", (2, 8, 128), 1, _dts(n_args, **over), {"block": 4},
             kept=8, red=128),
        Case("ragged", (1, 13, 128), 1, _dts(n_args), {"block": 4}, kept=13, red=128),
    ]
    if fit_edge_bufs is not None:
        from repro.kernels.tiling import VMEM_BUDGET
        red = VMEM_BUDGET // (4 * fit_edge_bufs)
        cases.append(Case("fit-edge", (1, 2, red), 1, _dts(n_args), {"block": 4},
                          kept=2, red=red))
    return tuple(cases)


def _finalize_with_ek(m_new, v_line, ek, **kw):
    return _su.slim_finalize_batched(m_new, v_line, ek=ek, **kw)


def _mega_finalize_with_ek(m_new, v_line, bc1, bc2, ek, **kw):
    return _mp.mega_slim_finalize_batched(m_new, v_line, bc1, bc2, ek=ek, **kw)


_TILE2D_CASES = (
    Case("aligned", (256, 512), None, _dts(4), {}, kept=256, red=512),
    Case("ragged-bf16", (300, 700), None, _dts(4, s0=bf16, s1=bf16), {},
         kept=300, red=700),
)

# Paged-attention case geometry rides in Case.kwargs (pool pages, page size,
# kv heads, table width) — static *shape* inputs, not kwargs of the entry;
# case_kwargs strips them before invocation.
_PAGED_GEOM = ("pages", "page", "kv", "max_pages")


def _paged_case(label: str, b: int, c: int, h: int, kv: int, hd: int,
                page: int, max_pages: int, *, qdt=f32, pooldt=f32) -> Case:
    # pool sized so the sample table below can hold b*max_pages distinct
    # non-null page ids — the page-table index-map check needs injective
    # samples to be meaningful
    pages = b * max_pages + 1
    return Case(label, (b, c, h, hd), None, (qdt, pooldt, i32, i32),
                {"pages": pages, "page": page, "kv": kv,
                 "max_pages": max_pages},
                kept=c * h, red=page * 2 * kv * hd)


def _paged_scalar_samples(case: Case):
    """(table, lengths) samples for the scalar-prefetch index maps: distinct
    non-null page ids per (row, slot) so aliasing/identity analysis sees a
    representative table, and ragged lengths including an inactive row."""
    import numpy as np

    b = case.shape[0]
    kw = case.kwargs
    mp, page = kw["max_pages"], kw["page"]
    table = (1 + np.arange(b * mp, dtype=np.int32)).reshape(b, mp)
    table %= np.int32(kw["pages"])
    lengths = np.asarray([(i * (mp * page)) // max(b, 1) for i in range(b)],
                         np.int32)
    return table, lengths


_PAGED_CASES = (
    _paged_case("decode", 3, 1, 4, 2, 8, 4, 4),
    _paged_case("decode-ragged", 2, 1, 4, 2, 8, 4, 5),
    _paged_case("decode-bf16", 3, 1, 4, 2, 8, 4, 4, qdt=bf16, pooldt=bf16),
    _paged_case("chunk", 1, 4, 4, 2, 8, 8, 4),
    _paged_case("chunk-bf16", 1, 4, 4, 2, 8, 8, 4, qdt=bf16, pooldt=bf16),
)

ENTRIES: Tuple[KernelEntry, ...] = (
    KernelEntry(
        "fused_adam", _fa.fused_adam, "tile2d", ("full2d",) * 4,
        (Variant("base", {"lr": 1e-3}, None, "-"),),
        _TILE2D_CASES,
    ),
    KernelEntry(
        "adam_precond", _fa.adam_precond, "tile2d", ("full2d",) * 3,
        (Variant("base", {}, None, "-"),
         Variant("health", {"with_health": True}, None, "-")),
        (Case("aligned", (256, 512), None, _dts(3), {}, kept=256, red=512),
         Case("ragged-bf16", (300, 700), None, _dts(3, s0=bf16), {},
              kept=300, red=700)),
    ),
    KernelEntry(
        "slim_update_batched", _su.slim_update_batched, "strip",
        ("full", "full", "full", "line"),
        (Variant("base", {"lr": 1e-3}, UPDATE_BUFS, "UPDATE_BUFS"),),
        _strip_cases(4, bf16_slots=(0, 1)),
    ),
    KernelEntry(
        "slim_precond_batched", _su.slim_precond_batched, "strip",
        ("full", "full", "line"),
        (Variant("base", {}, PRECOND_BUFS, "PRECOND_BUFS"),
         Variant("snr", {"with_snr": True}, PRECOND_SNR_BUFS, "PRECOND_SNR_BUFS"),
         Variant("health", {"with_health": True}, PRECOND_BUFS, "PRECOND_BUFS"),
         Variant("snr+health", {"with_snr": True, "with_health": True},
                 PRECOND_SNR_BUFS, "PRECOND_SNR_BUFS")),
        _strip_cases(3, bf16_slots=(0,), fit_edge_bufs=PRECOND_BUFS),
    ),
    KernelEntry(
        "slim_partial_stats_batched", _su.slim_partial_stats_batched, "strip",
        ("full", "full"),
        (Variant("base", {}, PARTIAL_BUFS, "PARTIAL_BUFS"),
         Variant("snr", {"with_snr": True}, PARTIAL_BUFS, "PARTIAL_BUFS"),
         Variant("health", {"with_health": True}, PARTIAL_BUFS, "PARTIAL_BUFS"),
         Variant("snr+health", {"with_snr": True, "with_health": True},
                 PARTIAL_BUFS, "PARTIAL_BUFS")),
        _strip_cases(2, bf16_slots=(0,)),
    ),
    KernelEntry(
        "slim_finalize_batched[ek]", _finalize_with_ek, "strip",
        ("full", "line", "line"),
        (Variant("base", {}, FINALIZE_BUFS, "FINALIZE_BUFS"),),
        _strip_cases(3, bf16_slots=()),
    ),
    KernelEntry(
        "slim_finalize_batched[owner]", _su.slim_finalize_batched, "strip",
        ("full", "line"),
        (Variant("base", {"ek": None}, FINALIZE_BUFS, "FINALIZE_BUFS"),),
        _strip_cases(2, bf16_slots=()),
    ),
    # Megaplan entries: inputs are always f32 (gather_group casts every
    # segment to the compute dtype before concatenation), so there are no
    # bf16 cases — the f32-compute contract is enforced structurally at the
    # gather, not inside the kernel body.
    KernelEntry(
        "mega_adam_update", _mp.mega_adam_update, "tile2d",
        ("full2d", "full2d", "full2d", "line2d", "line2d"),
        (Variant("base", {}, MEGA_ADAM_BUFS, "MEGA_ADAM_BUFS"),
         Variant("health", {"with_health": True}, MEGA_ADAM_BUFS,
                 "MEGA_ADAM_BUFS")),
        (Case("aligned", (256, 512), None, _dts(5), {}, kept=256, red=512),
         Case("ragged", (300, 512), None, _dts(5), {}, kept=300, red=512)),
    ),
    KernelEntry(
        "mega_slim_update_batched", _mp.mega_slim_update_batched, "strip",
        ("full", "full", "line", "line", "line"),
        (Variant("base", {}, MEGA_PRECOND_BUFS, "MEGA_PRECOND_BUFS"),
         Variant("snr", {"with_snr": True}, MEGA_PRECOND_SNR_BUFS,
                 "MEGA_PRECOND_SNR_BUFS"),
         Variant("health", {"with_health": True}, MEGA_PRECOND_BUFS,
                 "MEGA_PRECOND_BUFS"),
         Variant("snr+health", {"with_snr": True, "with_health": True},
                 MEGA_PRECOND_SNR_BUFS, "MEGA_PRECOND_SNR_BUFS")),
        _strip_cases(5, bf16_slots=(), fit_edge_bufs=MEGA_PRECOND_BUFS),
    ),
    KernelEntry(
        "mega_slim_partial_stats_batched", _mp.mega_slim_partial_stats_batched,
        "strip", ("full", "full"),
        (Variant("base", {}, MEGA_PARTIAL_BUFS, "MEGA_PARTIAL_BUFS"),
         Variant("snr", {"with_snr": True}, MEGA_PARTIAL_BUFS,
                 "MEGA_PARTIAL_BUFS"),
         Variant("health", {"with_health": True}, MEGA_PARTIAL_BUFS,
                 "MEGA_PARTIAL_BUFS"),
         Variant("snr+health", {"with_snr": True, "with_health": True},
                 MEGA_PARTIAL_BUFS, "MEGA_PARTIAL_BUFS")),
        _strip_cases(2, bf16_slots=()),
    ),
    KernelEntry(
        "mega_slim_finalize_batched[ek]", _mega_finalize_with_ek, "strip",
        ("full", "line", "line", "line", "line"),
        (Variant("base", {}, MEGA_FINALIZE_BUFS, "MEGA_FINALIZE_BUFS"),),
        _strip_cases(5, bf16_slots=()),
    ),
    KernelEntry(
        "mega_slim_finalize_batched[owner]", _mp.mega_slim_finalize_batched,
        "strip", ("full", "line", "line", "line"),
        (Variant("base", {"ek": None}, MEGA_FINALIZE_BUFS,
                 "MEGA_FINALIZE_BUFS"),),
        _strip_cases(4, bf16_slots=()),
    ),
    KernelEntry(
        "snr_stats_batched", _ss.snr_stats_batched, "strip", ("full",),
        (Variant("base", {}, STATS_BUFS, "STATS_BUFS"),),
        _strip_cases(1, bf16_slots=(0,)),
    ),
    KernelEntry(
        "snr_stats_centered_batched", _ss.snr_stats_centered_batched, "strip",
        ("full",),
        (Variant("base", {}, CENTERED_BUFS, "CENTERED_BUFS"),),
        _strip_cases(1, bf16_slots=(0,)),
    ),
    KernelEntry(
        "snr_stats_centered_partial_batched",
        _ss.snr_stats_centered_partial_batched, "strip", ("full",),
        (Variant("base", {}, CENTERED_BUFS, "CENTERED_BUFS"),),
        _strip_cases(1, bf16_slots=(0,)),
    ),
    KernelEntry(
        "paged_attention", _pa.paged_attention, "paged",
        ("q", "pool", "table", "lengths"),
        (Variant("base", {}, PAGED_ATTN_BUFS, "PAGED_ATTN_BUFS"),),
        _PAGED_CASES,
        scalar_args=_paged_scalar_samples,
    ),
)

ENTRY_MAP: Dict[str, KernelEntry] = {e.name: e for e in ENTRIES}


def case_args(entry: KernelEntry, case: Case) -> Tuple[jax.ShapeDtypeStruct, ...]:
    out = []
    for role, dt in zip(entry.arg_roles, case.dtypes):
        if role == "line":
            b, r, c = case.shape
            shape = (b, r, 1) if case.axis == 1 else (b, 1, c)
        elif role == "line2d":   # per-row operand of a 2-D tile entry
            shape = (case.shape[0], 1)
        elif role == "pool":
            kw = case.kwargs
            shape = (kw["pages"], kw["page"], 2 * kw["kv"], case.shape[3])
        elif role == "table":
            shape = (case.shape[0], case.kwargs["max_pages"])
        elif role == "lengths":
            shape = (case.shape[0],)
        else:  # "full" (B, R, C), "full2d" (R, C), "q" (B, C, H, hd)
            shape = case.shape
        out.append(jax.ShapeDtypeStruct(shape, dt))
    return tuple(out)


def case_kwargs(entry: KernelEntry, case: Case, variant: Variant) -> dict:
    kw = dict(case.kwargs)
    kw.update(variant.kwargs)
    if entry.kind == "strip":
        kw["axis"] = case.axis
    elif entry.kind == "paged":
        for k in _PAGED_GEOM:
            kw.pop(k, None)
    return kw


def signature(entry: KernelEntry, case: Case, variant: Variant):
    """Flat output ShapeDtypeStructs of (entry, case, variant) — eval_shape."""
    return entry_signature(entry.fn, *case_args(entry, case),
                           **case_kwargs(entry, case, variant))


def signature_key(entry: KernelEntry, case: Case, variant: Variant) -> str:
    return f"{entry.name}::{case.label}::{variant.name}"


def encode_signature(sig) -> List[List[str]]:
    return [["x".join(str(d) for d in s.shape), jnp.dtype(s.dtype).name]
            for s in sig]


def all_signatures() -> Dict[str, List[List[str]]]:
    """Every registered (entry, case, variant) signature, golden-file form."""
    out = {}
    for entry in ENTRIES:
        for case in entry.cases:
            for variant in entry.variants:
                out[signature_key(entry, case, variant)] = encode_signature(
                    signature(entry, case, variant))
    return out


_TRACE_CACHE: Dict[str, list] = {}


def traced_infos(entry: KernelEntry, case: Case, variant: Variant) -> list:
    """PallasInfo list for (entry, case, variant), traced once per process —
    kernelcheck and the race detector share the same traces."""
    key = signature_key(entry, case, variant)
    if key not in _TRACE_CACHE:
        cj = trace_entry(entry.fn, *case_args(entry, case),
                         **case_kwargs(entry, case, variant))
        samples = entry.scalar_args(case) if entry.scalar_args else None
        _TRACE_CACHE[key] = [pallas_info(e, scalar_samples=samples)
                             for e in find_pallas_eqns(cj.jaxpr)]
    return _TRACE_CACHE[key]


def variant_extra_outputs(entry_name: str, case_label: str, variant_name: str):
    """The outputs a variant appends beyond the entry's base signature."""
    entry = ENTRY_MAP[entry_name]
    case = next(c for c in entry.cases if c.label == case_label)
    variant = next(v for v in entry.variants if v.name == variant_name)
    base = signature(entry, case, entry.variants[0])
    var = signature(entry, case, variant)
    return var[len(base):]


# ---------------------------------------------------------------------------
# Signature consumers (the opt_speed roofline gates read these)
# ---------------------------------------------------------------------------


def snr_stat_lines():
    """Per-regime extra-output counts of the ``with_snr`` kernel variants,
    read from the registry's eval_shape signatures, plus the shapes of any
    extra output that is *not* line-shaped — the fused-SNR claim is that a
    measure step adds O(kept) stat lines and zero full-size passes, so the
    gate observes the kernels' actual signatures rather than a constant that
    restates the model's own assumption.

    Returns ``({'psum': n, 'local': n, 'jnp': n}, full_size_outputs)``; a
    non-empty second element means a with_snr variant grew a full-size
    output. The jnp-fallback regime fuses the same centered sums into the
    XLA pass, so it is charged like the single-kernel (local) form.
    """
    case = "minor"
    full = math.prod(ENTRY_MAP["slim_partial_stats_batched"]
                     .cases[0].shape)
    partial = variant_extra_outputs("slim_partial_stats_batched", case, "snr")
    precond = variant_extra_outputs("slim_precond_batched", case, "snr")
    oversize = [tuple(o.shape) for o in list(partial) + list(precond)
                if math.prod(o.shape) >= full]
    return ({"psum": len(partial), "local": len(precond),
             "jnp": len(precond)}, oversize)


def health_stat_outputs():
    """Extra-output shapes of every kernel's ``with_health`` variant, read
    from the registry's signatures — the anomaly-guard claim is O(1) scalars
    per leaf riding the existing update pass, so each entry must append
    exactly one tiny accumulator.

    Returns a list of ``(kernel_name, extra_output_shapes)``.
    """
    out = []
    for name in ("adam_precond", "slim_precond_batched",
                 "slim_partial_stats_batched"):
        entry = ENTRY_MAP[name]
        case = entry.cases[0].label
        extras = variant_extra_outputs(name, case, "health")
        out.append((name, [tuple(o.shape) for o in extras]))
    return out
