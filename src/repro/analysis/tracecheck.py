"""tracecheck — the guarded train step's no-recompile contract, statically.

PR 6's fault-tolerant step takes its guard policy as *traced* operands
(``controls = {'lr_scale': f32, 'grad_scale': f32}``) precisely so the
host-side Guard can back lr off after a spike without triggering a
recompile. That promise has three statically checkable halves:

  * **trace-stable** — ``make_jaxpr`` of the guarded 4-arg step over two
    *different* concrete control values yields the identical jaxpr: no
    control value leaks into the trace as a constant. (A step that calls
    ``float(controls[...])`` doesn't even trace — also a finding.)
  * **controls-used** — the control leaves are live invars of the jaxpr: a
    step that accepts the dict but ignores it (reading a closed-over Python
    float instead) would pass the stability check vacuously while baking
    policy into the executable.
  * **aval-stable** — the controls dict the Guard/trainer protocol emits has
    identical avals (shape/dtype/weak_type) before and after the guard
    reacts to a spike — jit's cache key is the aval, so this is the actual
    "compiles once" condition across guard state changes.

Runs on a reduced gpt_small (3 layers) with abstract params/batch — tracing
only, nothing executes.
"""
from __future__ import annotations

import time
from typing import Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs import get_reduced
from repro.core import rules_as_tree, table3_rules
from repro.core.slim_adam import slim_adam
from repro.train.guard import Guard, GuardConfig
from repro.train.step import make_train_step

try:
    from jax.core import Var, get_aval
except ImportError:  # pragma: no cover
    from jax._src.core import Var, get_aval

from .report import PassResult


def build_guarded_step() -> Tuple[Callable, tuple]:
    """(guarded 4-arg step, (params_abs, opt_abs, batch_abs)) on the reduced
    gpt_small — everything abstract."""
    cfg = get_reduced("gpt_small")
    params_abs, meta = cfg.abstract()
    dims_tree = rules_as_tree(table3_rules(meta), params_abs, meta)
    tx = slim_adam(3e-4, dims_tree, emit_health=True)
    opt_abs = jax.eval_shape(tx.init, params_abs)
    batch_abs = {"tokens": jax.ShapeDtypeStruct((2, 16), jnp.int32),
                 "labels": jax.ShapeDtypeStruct((2, 16), jnp.int32)}
    step = make_train_step(cfg, tx, guard=True)
    return step, (params_abs, opt_abs, batch_abs)


def trainer_controls(guard: Guard) -> Dict[str, jnp.ndarray]:
    """The controls dict exactly as the trainer builds it from guard state
    (see ``repro.train.trainer``) — the protocol whose aval stability the
    no-recompile promise rides on."""
    return {"lr_scale": jnp.asarray(guard.lr_scale, jnp.float32),
            "grad_scale": jnp.asarray(1.0, jnp.float32)}


def controls_like(lr: float, gs: float) -> Dict[str, jnp.ndarray]:
    return {"lr_scale": jnp.asarray(lr, jnp.float32),
            "grad_scale": jnp.asarray(gs, jnp.float32)}


def check_step_trace(step: Callable, abstract_args: tuple,
                     result: PassResult, where: str = "guarded_train_step",
                     controls_a: Optional[dict] = None,
                     controls_b: Optional[dict] = None) -> None:
    """trace-stable + controls-used on one 4-arg step (reusable against
    seeded bad steps in the regression tests)."""
    ca = controls_a if controls_a is not None else controls_like(1.0, 1.0)
    cb = controls_b if controls_b is not None else controls_like(0.25, 0.5)

    result.checks += 1
    try:
        # Fresh wrapper per trace: make_jaxpr rides jit's trace cache (keyed
        # on function identity + avals), which would silently reuse trace A
        # for trace B and mask any trace-time impurity.
        jx_a = jax.make_jaxpr(lambda *a: step(*a))(*abstract_args, ca)
        jx_b = jax.make_jaxpr(lambda *a: step(*a))(*abstract_args, cb)
    except Exception as e:  # noqa: BLE001 - a non-tracing step is the finding
        result.add("trace-stable", where,
                   f"step does not trace over abstract controls "
                   f"({type(e).__name__}: {e}) — it concretizes a traced "
                   f"control and would recompile (or crash) per policy change")
        return
    if str(jx_a) != str(jx_b):
        result.add("trace-stable", where,
                   "jaxprs differ across control values — a control leaked "
                   "into the trace as a constant, so every guard backoff "
                   "recompiles the step")

    # Control leaves are the trailing invars (args flatten in order); each
    # must be read by at least one equation.
    result.checks += 1
    n_controls = len(jax.tree_util.tree_leaves(ca))
    control_vars = jx_a.jaxpr.invars[-n_controls:]
    used = set()
    for eqn in jx_a.jaxpr.eqns:
        for v in eqn.invars:
            if isinstance(v, Var):
                used.add(id(v))
    outs = {id(v) for v in jx_a.jaxpr.outvars if isinstance(v, Var)}
    dead = [v for v in control_vars if id(v) not in used and id(v) not in outs]
    if dead:
        result.add("controls-used", where,
                   f"{len(dead)} control operand(s) are dead in the jaxpr — "
                   f"the step ignores the traced controls (policy must be "
                   f"baked in somewhere else, defeating the protocol)")


def check_guard_aval_stability(result: PassResult,
                               where: str = "Guard/trainer controls") -> None:
    """aval-stable across an actual guard state transition."""
    result.checks += 1
    guard = Guard(GuardConfig(min_history=2))
    before = trainer_controls(guard)
    for loss in (1.0, 1.01, 0.99, 1.0, 50.0):  # the last one is a spike
        guard.observe(loss)
    after = trainer_controls(guard)
    if guard.lr_scale >= 1.0:
        result.add("aval-stable", where,
                   "guard did not react to a 50x loss spike — the transition "
                   "this check exercises no longer exists; update tracecheck")
        return
    avals_before = [str(get_aval(x)) for x in jax.tree_util.tree_leaves(before)]
    avals_after = [str(get_aval(x)) for x in jax.tree_util.tree_leaves(after)]
    if avals_before != avals_after:
        result.add("aval-stable", where,
                   f"controls avals changed across a guard backoff "
                   f"({avals_before} -> {avals_after}) — jit would recompile "
                   f"on the first bad step")


def run() -> PassResult:
    t0 = time.monotonic()
    result = PassResult("tracecheck")
    step, abstract_args = build_guarded_step()
    check_step_trace(step, abstract_args, result)
    check_guard_aval_stability(result)
    result.seconds = time.monotonic() - t0
    return result
