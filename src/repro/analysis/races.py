"""Grid-race detector for pallas outputs with non-injective index maps.

A pallas output whose index_map sends multiple grid points to the same
block (the shared ``(2,)`` health accumulator; any future cross-strip
reduction output) is only correct when

  * every grid dim the aliasing rides is *sequential* — ``mosaic``
    ``dimension_semantics`` must not mark an aliased dim ``parallel``
    (absent semantics means all dims are sequential/"arbitrary"); and
  * the kernel body treats the block as read-modify-write: at least one
    ``get`` of the output ref must exist (the zero-on-first-instance +
    accumulate pattern), since a blind overwrite would drop every earlier
    instance's contribution even on a sequential grid.

Both conditions are decidable from the jaxpr alone: the index maps are
evaluated symbolically over (a sample of) the grid, and ref reads are
collected through nested sub-jaxprs (``pl.when`` lowers to ``cond``).
"""
from __future__ import annotations

import time
from typing import List

from . import registry
from .jaxpr_tools import PallasInfo, aliased_grid_dims, ref_ops_for
from .report import PassResult


def check_output_races(info: PallasInfo, result: PassResult, where: str) -> None:
    """Apply both race rules to every output block of one pallas_call."""
    ops = ref_ops_for(info)
    for block in info.blocks_out:
        result.checks += 1
        dims = aliased_grid_dims(block, info.grid)
        if not dims:
            continue  # injective: one block per grid point, nothing to race
        bad = [d for d in sorted(dims)
               if d < len(info.dimension_semantics)
               and info.dimension_semantics[d] == "parallel"]
        if bad:
            result.add("race-parallel", where,
                       f"out[{block.slot}] block {block.block_shape} is shared "
                       f"across grid dim(s) {bad} marked 'parallel' in "
                       f"dimension_semantics — concurrent instances would "
                       f"race on the block")
        ref = info.body_ref(block)
        reads = [op for op in ops if op.root is ref and op.kind == "get"]
        if not reads:
            result.add("race-rmw", where,
                       f"out[{block.slot}] block {block.block_shape} is shared "
                       f"across grid dim(s) {sorted(dims)} but the body never "
                       f"reads the ref — a blind overwrite drops earlier "
                       f"instances' contributions")


def run() -> PassResult:
    """Race-check every registered (entry, case, variant) trace."""
    t0 = time.monotonic()
    result = PassResult("races")
    for entry in registry.ENTRIES:
        for case in entry.cases:
            for variant in entry.variants:
                where = registry.signature_key(entry, case, variant)
                for info in registry.traced_infos(entry, case, variant):
                    check_output_races(info, result, where)
    result.seconds = time.monotonic() - t0
    return result
