"""Grid-race detector for pallas outputs with non-injective index maps.

A pallas output whose index_map sends multiple grid points to the same
block (the shared ``(2,)`` health accumulator; any future cross-strip
reduction output) is only correct when

  * every grid dim the aliasing rides is *sequential* — ``mosaic``
    ``dimension_semantics`` must not mark an aliased dim ``parallel``
    (absent semantics means all dims are sequential/"arbitrary"); and
  * the kernel body treats the block as read-modify-write: at least one
    ``get`` of the output ref must exist (the zero-on-first-instance +
    accumulate pattern), since a blind overwrite would drop every earlier
    instance's contribution even on a sequential grid.

Both conditions are decidable from the jaxpr alone: the index maps are
evaluated symbolically over (a sample of) the grid, and ref reads are
collected through nested sub-jaxprs (``pl.when`` lowers to ``cond``).
"""
from __future__ import annotations

import time
from typing import List

from . import registry
from .jaxpr_tools import PallasInfo, aliased_grid_dims, ref_ops_for
from .report import PassResult


def check_output_races(info: PallasInfo, result: PassResult, where: str) -> None:
    """Apply both race rules to every output block of one pallas_call."""
    ops = ref_ops_for(info)
    for block in info.blocks_out:
        result.checks += 1
        dims = aliased_grid_dims(block, info.grid)
        if not dims:
            continue  # injective: one block per grid point, nothing to race
        bad = [d for d in sorted(dims)
               if d < len(info.dimension_semantics)
               and info.dimension_semantics[d] == "parallel"]
        if bad:
            result.add("race-parallel", where,
                       f"out[{block.slot}] block {block.block_shape} is shared "
                       f"across grid dim(s) {bad} marked 'parallel' in "
                       f"dimension_semantics — concurrent instances would "
                       f"race on the block")
        ref = info.body_ref(block)
        reads = [op for op in ops if op.root is ref and op.kind == "get"]
        if not reads:
            result.add("race-rmw", where,
                       f"out[{block.slot}] block {block.block_shape} is shared "
                       f"across grid dim(s) {sorted(dims)} but the body never "
                       f"reads the ref — a blind overwrite drops earlier "
                       f"instances' contributions")


def check_page_table_maps(entry, case, info: PallasInfo, result: PassResult,
                          where: str) -> None:
    """Page-table index-map check for the paged-attention family: the pool
    operand's block index must be *exactly* the scalar-prefetched table
    lookup ``tbl[b, p]`` (rest of the block index pinned at 0) — anything
    else (an off-by-one on the page dim, reading the wrong scalar operand,
    dropping the batch row) silently serves another request's KV pages.
    Decided by evaluating the map's jaxpr over the full grid against a
    distinct-valued sample table, the same binding the aliasing analysis
    uses."""
    import itertools

    import numpy as np

    table = np.asarray(entry.scalar_args(case)[0])
    block = info.blocks_in[1]   # arg order: q, pool (scalars precede both)
    result.checks += 1
    for pt in itertools.product(*(range(g) for g in info.grid)):
        got = block.index_map(*pt)
        want = (int(table[pt[0], pt[1]]),) + (0,) * (len(got) - 1)
        if got != want:
            result.add("page-table", where,
                       f"pool block index at grid {pt} is {got}, expected "
                       f"the page-table lookup {want} — the kernel would "
                       f"stream the wrong page")
            return


def _gpt_small_leaf_geometry():
    """(shapes, dtype names, dims) of the full GPT-small param tree — shapes
    via eval_shape (no 124M materialization), dims from the production rule
    table, the same derivation the opt_speed roofline gates use."""
    import jax

    from repro.configs import gpt_small
    from repro.core import rules_as_tree, table3_rules

    _, meta = gpt_small.reduced().init(jax.random.PRNGKey(0))
    full = gpt_small.config()
    params = jax.eval_shape(lambda k: full.init(k)[0], jax.random.PRNGKey(0))
    dims = rules_as_tree(table3_rules(meta), params, meta)
    treedef = jax.tree_util.tree_flatten(params)[1]
    dfl = tuple(tuple(d) for d in treedef.flatten_up_to(dims))
    leaves = jax.tree.leaves(params)
    return (tuple(tuple(p.shape) for p in leaves),
            tuple(str(p.dtype) for p in leaves), dfl)


# Synthetic mixed tree: every regime (minor/major/batched/dense), ragged +
# size-1 + full-reduce leaves, a bf16 leaf sharing a group with an f32 one.
_SYNTH_TREE = (
    ((128, 256), "float32", (1,)),
    ((64, 256), "bfloat16", (1,)),       # same cols -> same minor group
    ((256, 96), "float32", (0,)),
    ((4, 32, 64, 16), "float32", (1,)),  # middle-K -> batched major
    ((7,), "float32", ()),
    ((33, 5), "float32", ()),
    ((3, 3), "float32", (0, 1)),         # AdaLayer-style full reduce
    ((1, 2), "float32", (1,)),
)

# The launch bound the CI --check-launches gate enforces for GPT-small.
_GPT_SMALL_GROUPS_BOUND = 8


def check_segment_tables(result: PassResult) -> None:
    """Megaplan segment-table invariants — the grouped launches' correctness
    rests on the tables tiling each super-tensor injectively (offsets
    contiguous, every leaf in exactly one slot, uniform line geometry per
    group), which is static metadata this pass can decide without running a
    kernel. Also pins the GPT-small group count under the CI launch bound,
    so a planner regression fails here before the bench gate sees it."""
    import numpy as np

    from repro.kernels.megaplan import (_slim_key, plan_megagroups,
                                        segment_table)
    from repro.kernels.slim_update import PRECOND_BUFS

    shapes_g, dts_g, dims_g = _gpt_small_leaf_geometry()
    suites = [
        ("gpt_small[slim]", shapes_g, dts_g, dims_g),
        ("gpt_small[adam]", shapes_g, dts_g, tuple(() for _ in shapes_g)),
        ("synthetic", tuple(s for s, _, _ in _SYNTH_TREE),
         tuple(d for _, d, _ in _SYNTH_TREE),
         tuple(k for _, _, k in _SYNTH_TREE)),
    ]
    for name, shapes, dts, dims_leaves in suites:
        plan = plan_megagroups(shapes, dts, dims_leaves, n_bufs=PRECOND_BUFS)
        covered = list(plan.jnp_idx)
        for gi, group in enumerate(plan.groups):
            where = f"megaplan::{name}::group{gi}[{group.kind}]"
            result.checks += 1
            bad = []
            if not group.segments:
                bad.append("group holds no segments")
            off = 0
            for seg in group.segments:
                if seg.length <= 0:
                    bad.append(f"leaf {seg.index} has non-positive kept "
                               f"extent {seg.length}")
                if seg.offset != off:
                    bad.append(f"leaf {seg.index} offset {seg.offset} != "
                               f"running offset {off} — segments overlap or "
                               f"leave a gap")
                off += seg.length
                if group.kind != "dense" and _slim_key(seg.cn) != (
                        group.kind, group.batch, group.red):
                    bad.append(f"leaf {seg.index} line geometry "
                               f"{_slim_key(seg.cn)} differs from the "
                               f"group's {(group.kind, group.batch, group.red)}")
            if off != group.extent:
                bad.append(f"segment lengths sum to {off} != group extent "
                           f"{group.extent}")
            tbl = segment_table(group)
            if tbl.shape != (group.extent, 4):
                bad.append(f"segment table shape {tbl.shape} != "
                           f"({group.extent}, 4)")
            elif group.segments:
                exp = np.repeat(np.asarray([s.index for s in group.segments]),
                                np.asarray([s.length for s in group.segments]))
                if not np.array_equal(tbl[:, 0], exp):
                    bad.append("table leaf-index column does not tile the "
                               "segments in offset order")
                if (tbl[:, 2] <= 0).any():
                    bad.append("table holds a non-positive line extent")
            covered.extend(seg.index for seg in group.segments)
            for msg in bad:
                result.add("segment-table", where, msg)
        result.checks += 1
        if sorted(covered) != list(range(len(shapes))):
            result.add("segment-table", f"megaplan::{name}",
                       f"groups + jnp fallback do not partition the "
                       f"{len(shapes)} leaves exactly once "
                       f"(covered {sorted(covered)})")
        result.checks += 1
        if name.startswith("gpt_small") and \
                len(plan.groups) > _GPT_SMALL_GROUPS_BOUND:
            result.add("segment-table", f"megaplan::{name}",
                       f"{len(plan.groups)} groups > the GPT-small launch "
                       f"bound {_GPT_SMALL_GROUPS_BOUND} gated in CI")


def run() -> PassResult:
    """Race-check every registered (entry, case, variant) trace, then the
    megaplan segment tables (grouped launches are race-free only if the
    tables tile each super-tensor injectively)."""
    t0 = time.monotonic()
    result = PassResult("races")
    for entry in registry.ENTRIES:
        for case in entry.cases:
            for variant in entry.variants:
                where = registry.signature_key(entry, case, variant)
                for info in registry.traced_infos(entry, case, variant):
                    check_output_races(info, result, where)
                    if entry.kind == "paged" and entry.scalar_args:
                        check_page_table_maps(entry, case, info, result, where)
    check_segment_tables(result)
    result.seconds = time.monotonic() - t0
    return result
