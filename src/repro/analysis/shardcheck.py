"""shardcheck — ShardLeafPlan geometry over the config zoo x mesh matrix.

All on the device-free :class:`repro.sharding.shardspec.SpecMesh`: every
arch in the zoo is abstracted (``cfg.abstract()`` — no materialization),
its Table-3 dims and logical param specs derived, and every leaf planned on
every mesh in the matrix. Checked contracts:

  * **owner-all-or-nothing** — a psum leaf's owner placement either covers
    every non-trivial psum axis or is empty. A partial placement is *wrong*
    (shards along an unplaced axis each add an identical ``b2 * v`` copy
    into the all-reduce, inflating the moment), so this is the invariant
    that keeps the owner-write dedupe correct, not a preference.
  * **owner-even** — each placed axis divides its target dim's remaining
    local extent evenly, replayed step-by-step in placement order, and
    ``nu_spec`` actually realizes the full ``owner_factor`` (an entry that
    silently dropped to replicated would claim dedupe bytes it doesn't
    save).
  * **psum-jnp-zero** — ``regime_counts(...)['psum_jnp'] == 0`` on the
    production (data=16, model=16) mesh for *every* arch: no leaf's local
    canonical plan falls off the Pallas partial-stats/finalize pair.
  * **plan-cn** — ``finalize == 'kernel'`` iff the plan carries the local
    ``CanonND`` the dispatcher replays (the planner/dispatcher handshake).
  * **state-mirror** — ``opt_state_specs`` accepts the (opt state, params,
    specs) triple with owner-mesh resolution on, i.e. optimizer state
    mirrors params on every mesh (it raises on any structural mismatch).
"""
from __future__ import annotations

import math
import time
from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp

from repro.configs import ARCH_IDS, get_config
from repro.core import rules_as_tree, table3_rules
from repro.core.slim_adam import slim_adam
from repro.kernels.slim_update import PRECOND_BUFS
from repro.sharding.logical import ShardingContext, param_specs, use_sharding
from repro.sharding.shardspec import (ShardLeafPlan, SpecMesh,
                                      normalize_spec_leaves, owner_factor,
                                      plan_sharded_leaf, regime_counts,
                                      spec_entries)
from repro.sharding.state_shardings import opt_state_specs

from .report import PassResult

# Device-free mesh matrix: the production 16x16 mesh (the psum_jnp == 0
# promise), pure FSDP, and an asymmetric FSDP x TP shape that exercises
# non-square owner factors.
MESHES: Tuple[Tuple[str, Dict[str, int]], ...] = (
    ("prod-16x16", {"data": 16, "model": 16}),
    ("fsdp-8", {"data": 8}),
    ("asym-4x8", {"data": 4, "model": 8}),
)

PROD_MESH = MESHES[0][0]


def arch_leaves(arch: str):
    """(named abstract leaves, spec leaves, dims leaves, params_abs, meta,
    cfg) for one arch — abstract() only, no arrays."""
    cfg = get_config(arch, param_dtype=jnp.bfloat16)
    params_abs, meta = cfg.abstract()
    rules = table3_rules(meta)
    dims_tree = rules_as_tree(rules, params_abs, meta)
    p_leaves, treedef = jax.tree_util.tree_flatten(params_abs)
    dims_flat = jax.tree_util.tree_leaves(
        dims_tree, is_leaf=lambda x: isinstance(x, tuple))
    return cfg, params_abs, meta, treedef, p_leaves, dims_flat


def check_leaf_plan(plan: ShardLeafPlan, shape, dims, mesh,
                    result: PassResult, where: str) -> None:
    """The per-leaf geometry contracts (reusable on hand-built plans in the
    seeded regression tests)."""
    sizes = dict(mesh.shape)
    dset = {d % len(shape) for d in dims}
    red_shape = tuple(1 if i in dset else s for i, s in enumerate(shape))

    # finalize == 'kernel' iff the local CanonND rode along.
    result.checks += 1
    if plan.regime == "psum" and (plan.finalize == "kernel") != (plan.cn is not None):
        result.add("plan-cn", where,
                   f"finalize={plan.finalize!r} but cn is "
                   f"{'set' if plan.cn is not None else 'missing'} — the "
                   f"dispatcher would replay a plan the gate never approved")

    if plan.regime != "psum":
        return

    nontrivial = {a for a in plan.psum_axes if int(sizes.get(a, 1)) > 1}
    placed = {a for a, _ in plan.owner}

    # All-or-nothing: cover every non-trivial psum axis, or place nothing.
    result.checks += 1
    if plan.owner and placed != nontrivial:
        result.add("owner-all-or-nothing", where,
                   f"owner placement covers axes {sorted(placed)} but the "
                   f"psum group is {sorted(nontrivial)} — a partial placement "
                   f"inflates the moment by each unplaced axis's size")

    if not plan.owner:
        return

    # Even division, replayed in placement order over the local extents.
    result.checks += 1
    entries = spec_entries(plan.red_spec, len(red_shape))
    local = [s // math.prod(int(sizes.get(a, 1)) for a in e)
             for s, e in zip(red_shape, entries)]
    for a, d in plan.owner:
        f = int(sizes.get(a, 1))
        if local[d] <= 1 or local[d] % f:
            result.add("owner-even", where,
                       f"owner axis {a!r} (size {f}) placed on dim {d} whose "
                       f"remaining local extent {local[d]} it does not divide")
            return
        local[d] //= f

    # nu_spec must realize the whole claimed factor: the owner-sharded local
    # nu shape is the replicated red line shrunk by exactly owner_factor.
    result.checks += 1
    from repro.sharding.shardspec import local_shape

    a_factor = owner_factor(plan, mesh)
    red_local = local_shape(red_shape, plan.red_spec, mesh)
    nu_local = local_shape(red_shape, plan.nu_spec, mesh)
    if math.prod(nu_local) * a_factor != math.prod(red_local):
        result.add("owner-even", where,
                   f"nu_spec realizes a {math.prod(red_local) // max(1, math.prod(nu_local))}x "
                   f"dedupe but owner placement claims {a_factor}x — a spec "
                   f"entry silently fell back to replicated")


def run() -> PassResult:
    t0 = time.monotonic()
    result = PassResult("shardcheck")
    counts_by_mesh: Dict[str, Dict[str, int]] = {}

    for arch in ARCH_IDS:
        cfg, params_abs, meta, treedef, p_leaves, dims_flat = arch_leaves(arch)
        names = [str(jax.tree_util.keystr(kp)) for kp, _ in
                 jax.tree_util.tree_flatten_with_path(params_abs)[0]]
        dims_tree = rules_as_tree(table3_rules(meta), params_abs, meta)
        tx = slim_adam(3e-4, dims_tree)
        opt_abs = jax.eval_shape(tx.init, params_abs)

        for mesh_name, mesh_shape in MESHES:
            mesh = SpecMesh(mesh_shape)
            ctx = ShardingContext(mesh, rules=dict(cfg.sharding_overrides) or None)
            with use_sharding(ctx):
                p_specs = param_specs(meta, params_abs)
            spec_flat = normalize_spec_leaves(p_specs, treedef, "shardcheck")

            plans: List[ShardLeafPlan] = []
            for name, leaf, spec, dims in zip(names, p_leaves, spec_flat,
                                              dims_flat):
                where = f"{arch}/{mesh_name}{name}"
                plan = plan_sharded_leaf(tuple(leaf.shape), leaf.dtype,
                                         tuple(dims), spec, mesh,
                                         n_bufs=PRECOND_BUFS)
                plans.append(plan)
                check_leaf_plan(plan, tuple(leaf.shape), tuple(dims), mesh,
                                result, where)

            counts = regime_counts(plans)
            agg = counts_by_mesh.setdefault(mesh_name, {})
            for k, v in counts.items():
                agg[k] = agg.get(k, 0) + v
            result.checks += 1
            if mesh_name == PROD_MESH and counts["psum_jnp"]:
                result.add("psum-jnp-zero", f"{arch}/{mesh_name}",
                           f"{counts['psum_jnp']} psum leaf/leaves fell off "
                           f"the Pallas partial-stats/finalize pair on the "
                           f"production mesh (counts: {counts})")

            # Opt state mirrors params (opt_state_specs raises on mismatch).
            result.checks += 1
            try:
                opt_state_specs(opt_abs, params_abs, p_specs, owner_mesh=mesh)
            except Exception as e:  # noqa: BLE001 - any failure is a finding
                result.add("state-mirror", f"{arch}/{mesh_name}",
                           f"opt_state_specs rejected the state/param/spec "
                           f"triple: {e}")

    result.detail = "; ".join(
        f"{m}: " + " ".join(f"{k}={v}" for k, v in sorted(c.items()) if v)
        for m, c in counts_by_mesh.items())
    result.seconds = time.monotonic() - t0
    return result
