"""kernelcheck — abstract evaluation of every registered kernel entry.

For each (entry, case, variant) in :mod:`repro.analysis.registry`, traces
the entry over ``ShapeDtypeStruct`` args (device-free) and verifies:

  * **bufs** — the declared ``*_BUFS`` constant brackets the live full-size
    blocks actually present in the pallas jaxpr: ``full + 1 <= declared <=
    full + 2`` (the +1/+2 window is cast/shift headroom, the documented
    meaning of every constant). A kernel gaining a full-size operand without
    bumping its constant — or a constant silently inflated — both fail.
  * **vmem** — whenever the ``strip_fits`` gate admits the case, the *real*
    per-instance block footprint (every block charged at the f32 compute
    itemsize) fits ``VMEM_BUDGET``; 2-D tile kernels must fit
    unconditionally.
  * **dtype** — bf16/f16 input blocks are only ever read into an immediate
    ``convert_element_type`` to f32, and writes into low-precision output
    blocks come from a convert back to the stored dtype: the f32-compute
    contract (a real PR-5 bug class) checked in the jaxpr, not at runtime.
  * **okept** — variant extra outputs (SNR stat lines, health accumulators)
    stay O(kept)/O(1); a variant growing a full-size output fails.
  * **golden** — the full output signature matrix matches
    ``golden_signatures.json`` (regenerate with
    ``python -m repro.analysis --update-golden``).
"""
from __future__ import annotations

import json
import time
from pathlib import Path
from typing import Dict, List, Optional, Tuple

import jax.numpy as jnp

from repro.kernels.tiling import VMEM_BUDGET, strip_fits

from . import registry
from .jaxpr_tools import (PallasInfo, find_pallas_eqns, pallas_info,
                          ref_ops_for, trace_entry, var_consumers,
                          var_producer)
from .report import PassResult

GOLDEN_PATH = Path(__file__).parent / "golden_signatures.json"

_LOW_PRECISION = (jnp.dtype(jnp.bfloat16), jnp.dtype(jnp.float16))
# Documented headroom window of the *_BUFS constants: +1 for the cast copy,
# +2 when the body also holds a g^2 / shifted line copy.
_BUFS_HEADROOM = (1, 2)


def trace_infos(fn, args, kwargs) -> List[PallasInfo]:
    cj = trace_entry(fn, *args, **kwargs)
    return [pallas_info(e) for e in find_pallas_eqns(cj.jaxpr)]


def check_bufs(info: PallasInfo, declared: int, bufs_name: str,
               result: PassResult, where: str) -> None:
    """Declared full-size buffer budget vs live full-size blocks."""
    result.checks += 1
    full = info.full_block_count()
    lo, hi = full + _BUFS_HEADROOM[0], full + _BUFS_HEADROOM[1]
    if not (lo <= declared <= hi):
        result.add("bufs", where,
                   f"{bufs_name}={declared} but the jaxpr holds {full} live "
                   f"full-size blocks (expected declared in [{lo}, {hi}])")


def check_vmem(info: PallasInfo, result: PassResult, where: str,
               *, gated: bool = True) -> None:
    """Per-instance block footprint vs the VMEM budget (when admitted)."""
    result.checks += 1
    if not gated:
        return
    fp = info.footprint_bytes(itemsize=4)
    if fp > VMEM_BUDGET:
        result.add("vmem", where,
                   f"per-instance block footprint {fp} B exceeds "
                   f"VMEM_BUDGET {VMEM_BUDGET} B despite the fits-gate "
                   f"admitting the case")


def check_compute_dtype(info: PallasInfo, result: PassResult, where: str) -> None:
    """bf16/f16 blocks must be read into f32 and written from a cast back."""
    ops = ref_ops_for(info)
    by_root: Dict = {}
    for op in ops:
        by_root.setdefault(op.root, []).append(op)
    for block in info.blocks:
        if jnp.dtype(block.array_dtype) not in _LOW_PRECISION:
            continue
        result.checks += 1
        ref = info.body_ref(block)
        for op in by_root.get(ref, []):
            if op.kind == "get" and block.role == "in":
                out = op.eqn.outvars[0]
                consumers = var_consumers(op.jaxpr, out)
                bad = [c for c in consumers
                       if not (c.primitive.name == "convert_element_type"
                               and jnp.dtype(c.params.get("new_dtype"))
                               == jnp.dtype(jnp.float32))]
                if bad or not consumers:
                    result.add("dtype", where,
                               f"{block.role}[{block.slot}] is "
                               f"{jnp.dtype(block.array_dtype).name} but a read "
                               f"is consumed by {[c.primitive.name for c in bad] or 'nothing'} "
                               f"instead of an immediate cast to float32")
            elif op.kind == "swap" and block.role == "out":
                val = op.eqn.invars[1]
                prod_eqn = var_producer(op.jaxpr, val)
                ok = (prod_eqn is not None
                      and prod_eqn.primitive.name == "convert_element_type"
                      and jnp.dtype(prod_eqn.params.get("new_dtype"))
                      == jnp.dtype(block.array_dtype))
                if not ok:
                    result.add("dtype", where,
                               f"out[{block.slot}] is "
                               f"{jnp.dtype(block.array_dtype).name} but a write "
                               f"is not produced by a cast back to the stored "
                               f"dtype (f32 compute contract)")


def check_extra_outputs(entry: registry.KernelEntry, case: registry.Case,
                        variant: registry.Variant, result: PassResult,
                        where: str) -> None:
    """Variant extras must be O(kept) lines or the O(1) accumulator."""
    if variant is entry.variants[0]:
        return
    extras = registry.variant_extra_outputs(entry.name, case.label, variant.name)
    b = case.shape[0] if entry.kind == "strip" else 1
    bound = max(b * case.kept, 2)
    for sds in extras:
        result.checks += 1
        elems = 1
        for d in sds.shape:
            elems *= d
        if elems > bound:
            result.add("okept", where,
                       f"variant '{variant.name}' extra output {tuple(sds.shape)} "
                       f"has {elems} elems > O(kept) bound {bound} — a "
                       f"signature silently grew a full-size output")


def load_golden(path: Path = GOLDEN_PATH) -> Optional[Dict[str, List[List[str]]]]:
    if not path.exists():
        return None
    return json.loads(path.read_text())


def run(update_golden: bool = False,
        golden_path: Path = GOLDEN_PATH) -> Tuple[PassResult, Dict[str, List[List[str]]]]:
    """Run the full kernelcheck pass. Returns (result, computed signatures);
    the runner writes the computed dict out as the golden diff on mismatch."""
    t0 = time.monotonic()
    result = PassResult("kernelcheck")
    computed: Dict[str, List[List[str]]] = {}

    for entry in registry.ENTRIES:
        for case in entry.cases:
            for variant in entry.variants:
                where = registry.signature_key(entry, case, variant)
                computed[where] = registry.encode_signature(
                    registry.signature(entry, case, variant))

                infos = registry.traced_infos(entry, case, variant)
                result.checks += 1
                if not infos:
                    result.add("trace", where, "no pallas_call in the trace")
                    continue
                gated = (entry.kind == "tile2d"
                         or strip_fits(case.red, variant.bufs))
                for info in infos:
                    if variant.bufs is not None:
                        check_bufs(info, variant.bufs, variant.bufs_name,
                                   result, where)
                    check_vmem(info, result, where, gated=gated)
                    check_compute_dtype(info, result, where)
                check_extra_outputs(entry, case, variant, result, where)

    golden = load_golden(golden_path)
    if update_golden or golden is None:
        golden_path.write_text(json.dumps(computed, indent=1, sort_keys=True)
                               + "\n")
        result.detail = f"golden signatures written to {golden_path}"
    else:
        for key in sorted(set(computed) | set(golden)):
            result.checks += 1
            if key not in golden:
                result.add("golden", key, "signature missing from golden file "
                           "(regenerate with --update-golden)")
            elif key not in computed:
                result.add("golden", key, "stale golden entry: case no longer "
                           "in the registry (regenerate with --update-golden)")
            elif computed[key] != golden[key]:
                result.add("golden", key,
                           f"signature drifted: golden {golden[key]} != "
                           f"computed {computed[key]}")

    result.seconds = time.monotonic() - t0
    return result, computed
