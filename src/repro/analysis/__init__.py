"""slimcheck — static contract checking for kernels, sharding plans, traces.

Four device-free passes (eval_shape / jaxpr / AST only; no kernel ever
executes, no accelerator is touched):

  * :mod:`repro.analysis.kernelcheck` — every registered kernel entry point
    abstractly evaluated over a shape x dtype x orientation matrix: declared
    ``*_BUFS`` constants vs live full-size blocks in the jaxpr, the
    ``strip_fits`` gate implying the real per-instance block footprint fits
    ``VMEM_BUDGET``, bf16/f16 inputs computing in f32 (and casting back to
    the stored dtype), and output signatures pinned to
    ``golden_signatures.json`` so a kernel silently growing a full-size
    output fails statically.
  * :mod:`repro.analysis.races` — grid-race detection: output blocks whose
    index_map is non-injective across the grid (the shared ``(2,)`` health
    accumulator, line/stat rows) must ride only sequential grid dims and be
    read-modify-write.
  * :mod:`repro.analysis.shardcheck` — ``ShardLeafPlan`` geometry over the
    entire config zoo x mesh matrix on a device-free ``SpecMesh``: owner
    placement all-or-nothing, ``owner_factor`` dividing the line evenly,
    ``psum_jnp == 0`` on the production 16x16 mesh, opt state mirroring
    params.
  * :mod:`repro.analysis.tracecheck` + :mod:`repro.analysis.lint` — the
    guarded 4-arg train step traces identically across differing control
    values (the no-recompile promise), plus AST lint rules RPR001-RPR004.

Entry point: ``python -m repro.analysis`` (see ``__main__``), wired into CI
as ``scripts/ci.sh analyze`` between lint and test-fast.
"""
from __future__ import annotations

from .report import Finding, PassResult  # noqa: F401

PASS_NAMES = ("kernelcheck", "races", "shardcheck", "tracecheck", "lint")
