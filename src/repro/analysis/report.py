"""Result types shared by the analyzer passes.

A pass runs a batch of named checks and returns a :class:`PassResult`; each
violated contract is one :class:`Finding`. Passes never raise for contract
violations — unexpected exceptions are converted to findings by the runner
so one broken pass can't mask the others' output.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import List, Optional


@dataclass
class Finding:
    """One violated contract."""

    pass_name: str   # kernelcheck | races | shardcheck | tracecheck | lint
    check: str       # stable check id, e.g. "bufs", "vmem", "RPR001"
    where: str       # kernel/case, arch/mesh/leaf, or file:line
    message: str

    def __str__(self) -> str:
        return f"[{self.pass_name}:{self.check}] {self.where}: {self.message}"


@dataclass
class PassResult:
    """Outcome of one analyzer pass."""

    name: str
    checks: int = 0                      # individual contracts evaluated
    findings: List[Finding] = field(default_factory=list)
    seconds: float = 0.0
    detail: Optional[str] = None         # extra context (e.g. golden diff path)

    @property
    def ok(self) -> bool:
        return not self.findings

    def add(self, check: str, where: str, message: str) -> None:
        self.findings.append(Finding(self.name, check, where, message))
