"""repro: SlimAdam — 'When Can You Get Away with Low Memory Adam?' — as a
production multi-pod JAX training/inference framework.

Subpackages: core (the paper), optim, models, sharding, data, checkpoint,
train, serve, kernels (Pallas), configs (assigned architectures), launch
(mesh / dry-run / sweep / train driver).
"""

__version__ = "1.0.0"
