"""Distributed training driver: mesh + sharded train loop + checkpointing.

    PYTHONPATH=src python -m repro.launch.train --arch smollm_135m \
        --steps 100 --mesh none            # single-device (this container)
    PYTHONPATH=src python -m repro.launch.train --arch deepseek_67b \
        --mesh multi --steps 1000          # on a real 2-pod v5e slice

On hardware, run one process per host (jax.distributed.initialize picks up
the TPU runtime); the data pipeline shards per host via (host_id,
host_count), and the elastic checkpoint restore re-lays state onto whatever
mesh the restarted job gets.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from ..checkpoint import store
from ..configs import ARCH_IDS, get_config, get_reduced
from ..data import DataConfig, ZipfLM
from ..sharding.logical import ShardingContext, param_specs, use_sharding
from ..sharding.state_shardings import opt_state_specs
from ..train.guard import ROLLBACK, Guard, GuardConfig
from ..train.step import make_train_step
from ..train.trainer import _SLIM_FAMILY, make_optimizer
from .mesh import make_production_mesh


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, default="smollm_135m")
    ap.add_argument("--reduced", action="store_true", help="CPU-scale config")
    ap.add_argument("--mesh", choices=("none", "single", "multi"), default="none")
    ap.add_argument("--optimizer", default="slim")
    ap.add_argument("--backend", choices=("jnp", "fused", "auto"), default="auto",
                    help="Adam/SlimAdam execution path; 'fused' + a mesh runs "
                         "the Pallas kernels per-shard under shard_map")
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--grad-accum", type=int, default=1)
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--guard", action="store_true",
                    help="fault-tolerant step: in-pass anomaly health, "
                         "skip poisoned steps, lr backoff on loss spikes, "
                         "rollback to the last checkpoint on repeated faults")
    args = ap.parse_args(argv)

    cfg = get_reduced(args.arch) if args.reduced or args.mesh == "none" else get_config(args.arch)
    mesh = None if args.mesh == "none" else make_production_mesh(multi_pod=(args.mesh == "multi"))
    ctx = ShardingContext(mesh, rules=dict(cfg.sharding_overrides) or None) if mesh else None

    with use_sharding(ctx):
        params, meta = cfg.init(jax.random.PRNGKey(0))
        # Specs first: the fused backend wants mesh + param specs at
        # construction so its tree update runs under shard_map on the shards.
        p_specs = param_specs(meta, params) if ctx is not None else None
        emit_health = args.guard and args.optimizer in ("adam",) + _SLIM_FAMILY
        tx = make_optimizer(args.optimizer, args.lr, params, meta,
                            backend=args.backend, mesh=mesh, param_specs=p_specs,
                            emit_health=emit_health)
        opt_state = tx.init(params)

        if ctx is not None:
            from ..optim.base import resolve_backend

            p_sh = jax.tree.map(lambda s: NamedSharding(mesh, s), p_specs,
                                is_leaf=lambda x: isinstance(x, P))
            # Fused backend: pin psum-regime reduced moments to their
            # owner-slice storage layout so the pjit state boundary matches
            # the shard_map output (no per-step O(kept) re-gather).
            owner_mesh = mesh if resolve_backend(args.backend) == "fused" else None
            o_specs = opt_state_specs(jax.eval_shape(lambda: opt_state), params,
                                      p_specs, owner_mesh=owner_mesh)
            o_sh = jax.tree.map(lambda s: NamedSharding(mesh, s), o_specs,
                                is_leaf=lambda x: isinstance(x, P))
            params = jax.device_put(params, p_sh)
            opt_state = jax.device_put(opt_state, o_sh)
            b_sh = NamedSharding(mesh, ctx.spec_for(("batch", None), (args.batch, args.seq)))
            batch_sh = {"tokens": b_sh, "labels": b_sh}
            in_sh = ((p_sh, o_sh, batch_sh, None) if args.guard
                     else (p_sh, o_sh, batch_sh))
            step_fn = jax.jit(make_train_step(cfg, tx, grad_accum=args.grad_accum,
                                              grad_shardings=p_sh, guard=args.guard),
                              in_shardings=in_sh,
                              out_shardings=(p_sh, o_sh, None), donate_argnums=(0, 1))
        else:
            step_fn = jax.jit(make_train_step(cfg, tx, grad_accum=args.grad_accum,
                                              guard=args.guard))

        start = 0
        if args.ckpt and store.latest_step(args.ckpt) is not None:
            state, extra = store.restore(args.ckpt, {"params": params, "opt": opt_state})
            params, opt_state = state["params"], state["opt"]
            start = int(extra.get("step", 0))
            print(f"resumed from step {start}")

        data = ZipfLM(DataConfig(vocab_size=cfg.vocab_size, seq_len=args.seq,
                                 global_batch=args.batch))
        host_id = jax.process_index()
        host_count = jax.process_count()
        acp = store.AsyncCheckpointer()
        guard = Guard(GuardConfig()) if args.guard else None
        t0 = time.time()
        for s in range(start, args.steps):
            batch = {k: jnp.asarray(v) for k, v in
                     data.batch(s, host_id=host_id, host_count=host_count).items()}
            if guard is not None:
                controls = {"lr_scale": jnp.asarray(guard.lr_scale, jnp.float32),
                            "grad_scale": jnp.asarray(1.0, jnp.float32)}
                params, opt_state, metrics = step_fn(params, opt_state, batch, controls)
                action = guard.observe(
                    float(metrics["loss"]),
                    skipped=bool(metrics["step_skipped"] > 0),
                    nonfinite=float(metrics["nonfinite_count"]))
                if action == ROLLBACK:
                    guard.note_rollback()
                    if args.ckpt and store.latest_step(args.ckpt) is not None:
                        # Restore the last valid checkpoint; the step index
                        # keeps advancing, so the data stream naturally
                        # diverges from the poisoned trajectory.
                        state, extra = store.restore(
                            args.ckpt, {"params": params, "opt": opt_state})
                        params, opt_state = state["params"], state["opt"]
                        print(f"step {s+1}: guard rolled back to checkpoint "
                              f"step {int(extra.get('step', 0))}")
            else:
                params, opt_state, metrics = step_fn(params, opt_state, batch)
            if (s + 1) % args.log_every == 0:
                tput = (s + 1 - start) * args.batch * args.seq / (time.time() - t0)
                extra_log = ""
                if guard is not None:
                    c = guard.counters
                    extra_log = (f" skipped {c['skipped']} backoffs "
                                 f"{c['backoffs']} rollbacks {c['rollbacks']}"
                                 f" lr_scale {guard.lr_scale:.2f}")
                print(f"step {s+1}: loss {float(metrics['loss']):.4f} "
                      f"grad_norm {float(metrics['grad_norm']):.3f} tok/s {tput:.0f}"
                      + extra_log)
            if args.ckpt and (s + 1) % max(args.steps // 4, 1) == 0:
                acp.save(args.ckpt, s + 1, {"params": params, "opt": opt_state},
                         extra={"step": s + 1})
        acp.wait()
        print(f"done: {args.steps - start} steps in {time.time() - t0:.1f}s")
        if guard is not None:
            print("guard counters:", guard.counters)


if __name__ == "__main__":
    main()
