"""Loop-aware roofline accounting from compiled (post-SPMD) HLO text.

``compiled.cost_analysis()`` visits each instruction exactly once, so a model
that scans over layers under-reports FLOPs/bytes/collectives by ~n_layers
(verified empirically: scan of 10 matmuls reports 1 matmul of flops). This
module re-derives the roofline terms from ``compiled.as_text()`` with
while-loop multiplicities:

  * build the computation call graph (entry -> while bodies/conditions,
    fusions, custom-calls);
  * recover each while's trip count from its condition computation
    (``compare(counter, constant), direction=LT`` pattern XLA emits for
    counted loops — i.e. every lax.scan);
  * walk with multiplicity, accumulating
      - dot FLOPs (2 * prod(result dims) * prod(contracting dims)),
      - per-type collective bytes (operand bytes of all-reduce / all-gather /
        reduce-scatter / all-to-all / collective-permute, async -start forms),
      - HBM traffic proxy: Σ (operand + output bytes) of top-level
        (non-fusion-internal) instructions — an upper bound that ignores
        on-chip reuse within a fusion but counts each fusion's boundary
        traffic once, which is how TPUs actually stream HBM.

All quantities are PER DEVICE (the HLO is the per-device SPMD program).
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1,
    "f8e4m3b11fnuz": 1, "f8e4m3fnuz": 1, "f8e5m2fnuz": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
    "token": 0, "opaque": 0,
}

COLLECTIVE_OPS = (
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all", "collective-permute",
)

_SHAPE_RE = re.compile(r"(\w+)\[([0-9,]*)\]")
_NAME_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(.*)$")
_OPCODE_RE = re.compile(r"([\w\-]+)\(")
_COMP_RE = re.compile(r"^(ENTRY\s+)?%?([\w.\-]+)\s*(?:\(.*\))?\s*->.*{\s*$")
_CONST_RE = re.compile(r"constant\((\d+)\)")


def _balanced_end(s: str, start: int) -> int:
    """Index one past the ')' matching the '(' at ``start`` (-1 if none)."""
    depth = 0
    for i in range(start, len(s)):
        if s[i] == "(":
            depth += 1
        elif s[i] == ")":
            depth -= 1
            if depth == 0:
                return i + 1
    return -1


def _split_instruction(line: str):
    """'%n = TYPE opcode(operands), attrs' -> (name, type, opcode, operands, attrs).

    Regex alone fails here: tuple types start with '(' and metadata strings
    contain parens (op_name="jit(f)/..."), so operands are extracted with a
    balanced-paren scan."""
    m = _NAME_RE.match(line)
    if not m:
        return None
    name, rest = m.group(1), m.group(2).strip()
    if rest.startswith("("):
        end = _balanced_end(rest, 0)
        if end < 0:
            return None
        type_str, rest2 = rest[:end], rest[end:].strip()
    else:
        sp = rest.find(" ")
        if sp < 0:
            return None
        type_str, rest2 = rest[:sp], rest[sp + 1:].strip()
    m2 = _OPCODE_RE.match(rest2)
    if not m2:
        return None
    opcode = m2.group(1)
    op_end = _balanced_end(rest2, m2.end() - 1)
    if op_end < 0:
        return None
    operands = rest2[m2.end(): op_end - 1]
    attrs = rest2[op_end:]
    return name, type_str, opcode, operands, attrs


def _shape_bytes(type_str: str) -> int:
    """Bytes of a result type (handles tuples)."""
    total = 0
    for m in _SHAPE_RE.finditer(type_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _result_dims(type_str: str) -> List[int]:
    m = _SHAPE_RE.search(type_str)
    if not m or not m.group(2):
        return []
    return [int(d) for d in m.group(2).split(",")]


@dataclasses.dataclass
class Instruction:
    name: str
    type_str: str
    opcode: str
    operands_str: str
    attrs: str

    def operand_names(self) -> List[str]:
        # operands are %name or name tokens before any nested parens end
        names = []
        for tok in re.findall(r"%?([\w.\-]+)", self.operands_str):
            names.append(tok)
        return names


@dataclasses.dataclass
class Computation:
    name: str
    is_entry: bool
    instructions: List[Instruction]
    by_name: Dict[str, Instruction]


def parse_hlo(text: str) -> Dict[str, Computation]:
    comps: Dict[str, Computation] = {}
    cur: Optional[Computation] = None
    for line in text.splitlines():
        if cur is None:
            m = _COMP_RE.match(line.strip()) if "{" in line else None
            if m and "->" in line:
                cur = Computation(name=m.group(2), is_entry=bool(m.group(1)), instructions=[], by_name={})
            continue
        if line.strip() == "}":
            comps[cur.name] = cur
            cur = None
            continue
        parts = _split_instruction(line)
        if parts:
            inst = Instruction(
                name=parts[0], type_str=parts[1], opcode=parts[2],
                operands_str=parts[3], attrs=parts[4],
            )
            cur.instructions.append(inst)
            cur.by_name[inst.name] = inst
    return comps


def _trip_count(cond: Computation) -> Optional[int]:
    """Recover the counted-loop bound from a while condition computation."""
    # the compare usually lives inside a wrapped fusion; the bound constant is
    # materialized at the condition's top level: %constant.4 = s32[] constant(7)
    consts = []
    for inst in cond.instructions:
        if inst.opcode == "constant" and inst.type_str.strip().startswith("s32"):
            if inst.operands_str.strip().isdigit():
                consts.append(int(inst.operands_str.strip()))
    if len(consts) == 1:
        return consts[0]
    return None


def _dot_flops(inst: Instruction, comp: Computation) -> float:
    """2 * prod(result) * prod(contracting dims)."""
    out_dims = _result_dims(inst.type_str)
    m = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", inst.attrs)
    lhs_name = inst.operand_names()[0] if inst.operand_names() else None
    lhs = comp.by_name.get(lhs_name)
    contract = 1
    if m and m.group(1):
        cdims = [int(d) for d in m.group(1).split(",")]
        if lhs is not None:
            ldims = _result_dims(lhs.type_str)
            for d in cdims:
                if d < len(ldims):
                    contract *= ldims[d]
    out = 1
    for d in out_dims:
        out *= d
    return 2.0 * out * contract


@dataclasses.dataclass
class HLOStats:
    dot_flops: float = 0.0
    traffic_bytes: float = 0.0
    collective_bytes: Dict[str, float] = dataclasses.field(default_factory=dict)
    collective_count: Dict[str, int] = dataclasses.field(default_factory=dict)
    unresolved_loops: int = 0

    @property
    def total_collective_bytes(self) -> float:
        return sum(self.collective_bytes.values())


_SKIP_TRAFFIC = {
    "parameter", "constant", "get-tuple-element", "tuple", "bitcast", "copy",
    "while", "call", "conditional", "after-all", "partition-id", "replica-id",
}


def analyze(text: str, *, default_trip: int = 1) -> HLOStats:
    comps = parse_hlo(text)
    entry = next((c for c in comps.values() if c.is_entry), None)
    if entry is None:
        raise ValueError("no ENTRY computation found")
    stats = HLOStats()
    fusion_callees: set = set()
    # computations referenced as fusion `calls=` are internal: their traffic
    # is represented by the fusion boundary.
    for comp in comps.values():
        for inst in comp.instructions:
            if inst.opcode == "fusion":
                m = re.search(r"calls=%?([\w.\-]+)", inst.attrs)
                if m:
                    fusion_callees.add(m.group(1))

    def op_bytes(inst: Instruction, comp: Computation) -> float:
        total = _shape_bytes(inst.type_str)
        for op in inst.operand_names():
            src = comp.by_name.get(op)
            if src is not None:
                total += _shape_bytes(src.type_str)
        return total

    def walk(comp: Computation, mult: float, visited: Tuple[str, ...]):
        for inst in comp.instructions:
            if inst.opcode == "while":
                m_body = re.search(r"body=%?([\w.\-]+)", inst.attrs)
                m_cond = re.search(r"condition=%?([\w.\-]+)", inst.attrs)
                trips = None
                # XLA annotates counted loops directly:
                #   backend_config={"known_trip_count":{"n":"7"}, ...}
                m_trip = re.search(r'known_trip_count\D*(\d+)', inst.attrs)
                if m_trip:
                    trips = int(m_trip.group(1))
                if trips is None and m_cond and m_cond.group(1) in comps:
                    trips = _trip_count(comps[m_cond.group(1)])
                if trips is None:
                    trips = default_trip
                    stats.unresolved_loops += 1
                if m_body and m_body.group(1) in comps and m_body.group(1) not in visited:
                    walk(comps[m_body.group(1)], mult * trips, visited + (m_body.group(1),))
                continue
            if inst.opcode in ("call", "conditional"):
                for m in re.finditer(r"(?:to_apply|calls)=%?([\w.\-]+)", inst.attrs):
                    callee = m.group(1)
                    if callee in comps and callee not in visited:
                        walk(comps[callee], mult, visited + (callee,))
                continue
            # fusions: walk inside for dot flops only (traffic from boundary)
            if inst.opcode == "fusion":
                m = re.search(r"calls=%?([\w.\-]+)", inst.attrs)
                if m and m.group(1) in comps:
                    callee = comps[m.group(1)]
                    for fin in callee.instructions:
                        if fin.opcode in ("dot", "dot-general"):
                            stats.dot_flops += mult * _dot_flops(fin, callee)
                stats.traffic_bytes += mult * op_bytes(inst, comp)
                continue
            if inst.opcode in ("dot", "dot-general"):
                stats.dot_flops += mult * _dot_flops(inst, comp)
            base = inst.opcode.replace("-start", "")
            if base in COLLECTIVE_OPS and not inst.opcode.endswith("-done"):
                b = 0.0
                for op in inst.operand_names():
                    src = comp.by_name.get(op)
                    if src is not None:
                        b += _shape_bytes(src.type_str)
                if b == 0.0:  # operand defined in another computation (rare)
                    b = _shape_bytes(inst.type_str)
                stats.collective_bytes[base] = stats.collective_bytes.get(base, 0.0) + mult * b
                stats.collective_count[base] = stats.collective_count.get(base, 0) + int(mult)
            if inst.opcode not in _SKIP_TRAFFIC:
                stats.traffic_bytes += mult * op_bytes(inst, comp)

    walk(entry, 1.0, (entry.name,))
    return stats
