"""Run the full dry-run matrix: every (arch x shape x mesh) cell in a fresh
subprocess (isolates the 512-device jax runtime + compilation caches).

    PYTHONPATH=src python -m repro.launch.sweep [--mesh single multi] [--archs ...]

Writes one JSON per cell to benchmarks/results/dryrun/ and a summary CSV.
"""
from __future__ import annotations

import argparse
import csv
import json
import subprocess
import sys
import time

from ..configs import ARCH_IDS, SHAPES, cell_supported
from .dryrun import RESULTS_DIR

ASSIGNED = tuple(a for a in ARCH_IDS if a not in ("gpt_small", "gpt_medium", "vit_small"))


def run_one(arch: str, shape: str, mesh: str, optimizer: str, timeout: int = 900) -> dict:
    ok, reason = cell_supported(arch, shape)
    if not ok:
        rec = {"arch": arch, "shape": shape, "mesh": mesh, "status": "skipped", "reason": reason}
        RESULTS_DIR.mkdir(parents=True, exist_ok=True)
        (RESULTS_DIR / f"{arch}__{shape}__{mesh}.json").write_text(json.dumps(rec, indent=2))
        return rec
    cmd = [sys.executable, "-m", "repro.launch.dryrun", "--arch", arch, "--shape", shape,
           "--mesh", mesh, "--optimizer", optimizer]
    t0 = time.time()
    try:
        proc = subprocess.run(cmd, capture_output=True, text=True, timeout=timeout)
    except subprocess.TimeoutExpired:
        return {"arch": arch, "shape": shape, "mesh": mesh, "status": "timeout"}
    if proc.returncode != 0:
        return {"arch": arch, "shape": shape, "mesh": mesh, "status": "error",
                "stderr": proc.stderr[-2000:]}
    out = proc.stdout
    try:
        rec = json.loads(out[out.index("{"):])
    except Exception:
        rec = {"arch": arch, "shape": shape, "mesh": mesh, "status": "parse_error",
               "stdout": out[-2000:]}
    rec["wall_s"] = round(time.time() - t0, 1)
    return rec


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", nargs="+", default=["single", "multi"])
    ap.add_argument("--archs", nargs="+", default=list(ASSIGNED))
    ap.add_argument("--shapes", nargs="+", default=list(SHAPES))
    ap.add_argument("--optimizer", default="slim")
    args = ap.parse_args(argv)

    rows = []
    for mesh in args.mesh:
        for arch in args.archs:
            for shape in args.shapes:
                rec = run_one(arch, shape, mesh, args.optimizer)
                status = rec.get("status")
                extra = ""
                if status == "ok":
                    temp = rec.get("mem_temp_size_in_bytes", 0) / 2**30
                    dom = rec.get("roofline", {}).get("dominant", "?")
                    extra = f"temp={temp:.1f}GiB fits={rec.get('fits_hbm')} dom={dom} compile={rec.get('compile_s')}s"
                elif status == "error":
                    extra = rec.get("stderr", "")[-200:].replace("\n", " ")
                print(f"[{mesh}] {arch:20s} {shape:12s} {status:8s} {extra}", flush=True)
                rows.append({
                    "mesh": mesh, "arch": arch, "shape": shape, "status": status,
                    "fits": rec.get("fits_hbm"), "grad_accum": rec.get("grad_accum"),
                    "temp_gib": round(rec.get("mem_temp_size_in_bytes", 0) / 2**30, 2),
                    "dominant": rec.get("roofline", {}).get("dominant"),
                    "compute_s": rec.get("roofline", {}).get("compute_s"),
                    "memory_s": rec.get("roofline", {}).get("memory_s"),
                    "collective_s": rec.get("roofline", {}).get("collective_s"),
                    "useful_ratio": rec.get("useful_flops_ratio"),
                    "roofline_fraction": rec.get("roofline_fraction"),
                })
    RESULTS_DIR.mkdir(parents=True, exist_ok=True)
    with open(RESULTS_DIR / "summary.csv", "w", newline="") as f:
        w = csv.DictWriter(f, fieldnames=list(rows[0].keys()))
        w.writeheader()
        w.writerows(rows)
    n_err = sum(1 for r in rows if r["status"] not in ("ok", "skipped"))
    print(f"\n{len(rows)} cells, {n_err} failures -> {RESULTS_DIR}/summary.csv")
    return 1 if n_err else 0


if __name__ == "__main__":
    sys.exit(main())
