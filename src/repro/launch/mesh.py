"""Production meshes.

Single pod: 256 chips as (data=16, model=16).
Multi-pod:  2 pods x 256 chips as (pod=2, data=16, model=16) — the 'pod'
axis carries pure data parallelism (gradient all-reduce over DCI), 'data' is
the FSDP axis, 'model' the TP/EP axis.

Functions, not module constants: importing this module must never touch jax
device state (the dry-run sets XLA_FLAGS before first jax init).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_mesh(shape, axes):
    """Arbitrary mesh (tests use small host-device meshes, e.g. (2,4))."""
    return jax.make_mesh(tuple(shape), tuple(axes))


# TPU v5e hardware constants for the roofline analysis (per chip).
PEAK_FLOPS_BF16 = 197e12      # FLOP/s
HBM_BW = 819e9                # B/s
ICI_BW_PER_LINK = 50e9        # B/s/link (~ per-direction)
HBM_PER_CHIP = 16 * 2**30     # 16 GiB
