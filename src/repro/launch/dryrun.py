import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512 " + os.environ.get("XLA_FLAGS", "")

"""Multi-pod dry-run: prove every (arch x shape x mesh) cell lowers, SPMD-
partitions, compiles, and fits — then extract the roofline terms.

    PYTHONPATH=src python -m repro.launch.dryrun --arch deepseek_67b \
        --shape train_4k --mesh single --optimizer slim

Emits a JSON record (memory analysis, loop-corrected HLO stats, roofline
terms) to benchmarks/results/dryrun/. The 512 placeholder host devices exist
only in this process — tests and benchmarks see the real single CPU device.
"""
import argparse
import dataclasses
import json
import math
import sys
import time
from pathlib import Path
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from ..configs import (
    ARCH_IDS,
    SHAPES,
    cell_supported,
    decode_input_specs,
    get_config,
    input_specs,
)
from ..core import rules_as_tree, table3_rules
from ..core.slim_adam import slim_adam
from ..models import transformer
from ..models.attention import KVCache
from ..models.ssm import SSMCache
from ..optim.adam import adamw
from ..sharding.logical import ShardingContext, param_specs, use_sharding
from ..train.step import make_serve_step, make_train_step
from ..sharding.state_shardings import opt_state_specs
from . import hlo_analysis
from .mesh import HBM_PER_CHIP, HBM_BW, ICI_BW_PER_LINK, PEAK_FLOPS_BF16, make_production_mesh

RESULTS_DIR = Path(__file__).resolve().parents[3] / "benchmarks" / "results" / "dryrun"


# ---------------------------------------------------------------------------
# Sharding assignment
# ---------------------------------------------------------------------------


def batch_specs(ctx: ShardingContext, batch_abstract: Dict[str, Any]) -> Dict[str, Any]:
    out = {}
    for k, v in batch_abstract.items():
        names = ["batch"] + [None] * (v.ndim - 1)
        out[k] = ctx.spec_for(names, v.shape)
    return out


def decode_cache_specs(ctx: ShardingContext, cache_abstract) -> Any:
    """KV caches: batch over DP axes, sequence over 'model' (SP); SSM states:
    d_inner over 'model'."""

    def kv(c: KVCache) -> KVCache:
        scale_spec = (ctx.spec_for(("layers", "batch", "seq_kv", None), c.k_scale.shape)
                      if c.k_scale.ndim == 4 else P())
        return KVCache(
            k=ctx.spec_for(("layers", "batch", "seq_kv", None, None), c.k.shape),
            v=ctx.spec_for(("layers", "batch", "seq_kv", None, None), c.v.shape),
            k_scale=scale_spec, v_scale=scale_spec,
            index=P(),
        )

    def ssm(c: SSMCache) -> SSMCache:
        return SSMCache(
            conv=ctx.spec_for(("layers", "batch", None, "d_inner"), c.conv.shape),
            h=ctx.spec_for(("layers", "batch", "d_inner", None), c.h.shape),
        )

    slots = {}
    for key, c in cache_abstract.slots.items():
        if isinstance(c, KVCache) or (hasattr(c, "index") and hasattr(c, "k")):
            slots[key] = kv(c)
        else:
            slots[key] = ssm(c)
    return transformer.DecodeCache(slots=slots, step=P())


def pick_grad_accum(cfg, shape_name: str, mesh) -> int:
    """Choose microbatch count so per-microbatch memory fits HBM.

    Two dominant terms (measured on the compiled HLO, see EXPERIMENTS.md):
      * scan carries saved for backward: n_layers * B_local * S * d_model * 2 B
      * fp32 CE/logits buffers: ~3 live copies of B_local * S * vocab_local * 4 B
    Budget ~9 GiB for these (params/moments/grads/workspace take the rest)."""
    seq, gb, kind = SHAPES[shape_name]
    if kind != "train":
        return 1
    n_dp = mesh.shape.get("data", 1) * mesh.shape.get("pod", 1)
    n_tp = mesh.shape.get("model", 1)
    # calibrated against measured CPU-backend temp arenas (deepseek-67b:
    # estimate 2.9 GiB @ accum=4 -> measured 11.1 GiB incl. fp32 transients
    # and optimizer temps) — a 4 GiB estimate keeps total under 16 GiB HBM
    budget = 3 * 2**30
    extra = 2.0 if any(s.mixer == "mamba" for s in cfg.pattern) else 1.0
    # sequence parallelism shards the residual carries (and the seq dim of
    # the CE logits) over the TP axis when S divides it
    sp = n_tp if seq % n_tp == 0 else 1
    # mamba layers keep full-S fp32 residuals (the scan is sequential in S, so
    # SP cannot shard them); all mamba slots of one period are live together
    # during the period's backward (measured: jamba 7-slot period ~6x falcon's
    # 1-slot period at equal width)
    mamba_slots = sum(1 for s_ in cfg.pattern if s_.mixer == "mamba")
    d_inner_local = (cfg.ssm_expand * cfg.d_model) // n_tp if (cfg.ssm_expand * cfg.d_model) % n_tp == 0 \
        else cfg.ssm_expand * cfg.d_model
    for accum in (1, 2, 4, 8, 16, 32, 64, 128, 256):
        b_local = max(gb // accum // n_dp, 1)
        carries = cfg.n_layers * b_local * (seq // sp) * cfg.d_model * 2 * extra
        ce = 3 * b_local * (seq // sp) * cfg.vocab_size * 4
        ssm_live = mamba_slots * b_local * seq * d_inner_local * 64
        if carries + ce + ssm_live <= budget and gb % accum == 0 and (gb // accum) >= n_dp:
            return accum
    return 256


# ---------------------------------------------------------------------------
# Cell construction
# ---------------------------------------------------------------------------


def build_cell(arch: str, shape: str, mesh, *, optimizer: str = "slim", grad_accum: Optional[int] = None,
               variant: str = "default", backend: str = "jnp"):
    """Returns (jitted, abstract_args, ctx, info, cfg). ``backend`` selects the
    Adam/SlimAdam execution path; 'fused' lowers the optimizer step as
    shard_map'd Pallas kernels on the production mesh (mesh + param specs
    are threaded into the transformation), so the dry-run proves the
    shard-aware kernels partition/compile alongside the model."""
    seq, gb, kind = SHAPES[shape]
    if variant == "optimized":
        import importlib
        mod = importlib.import_module(f"repro.configs.{arch}")
        if not hasattr(mod, "optimized"):
            raise ValueError(f"{arch} has no optimized() variant")
        cfg = dataclasses.replace(mod.optimized(), param_dtype=jnp.bfloat16)
    else:
        cfg = get_config(arch, param_dtype=jnp.bfloat16)
    if cfg.pos == "learned" and cfg.max_position < seq + 1:
        # the paper's GPT uses a 1024-position table; the assigned shape cells
        # need longer tables (noted as a deviation only for the extra archs)
        cfg = dataclasses.replace(cfg, max_position=seq + 1)
    ctx = ShardingContext(mesh, rules=dict(cfg.sharding_overrides) or None)
    info: Dict[str, Any] = {"arch": arch, "shape": shape, "kind": kind,
                            "seq": seq, "global_batch": gb,
                            "sharding_overrides": dict(cfg.sharding_overrides)}

    with use_sharding(ctx):
        params_abs, meta = cfg.abstract()
        p_specs = param_specs(meta, params_abs)
        p_shardings = jax.tree.map(lambda s: NamedSharding(mesh, s), p_specs,
                                   is_leaf=lambda x: isinstance(x, P))

        if kind in ("train", "prefill"):
            batch_abs = input_specs(cfg, shape)
            b_specs = batch_specs(ctx, batch_abs)
            b_shardings = {k: NamedSharding(mesh, s) for k, s in b_specs.items()}
            if kind == "train":
                if optimizer == "slim":
                    rules = table3_rules(meta)
                    dims_tree = rules_as_tree(rules, params_abs, meta)
                    tx = slim_adam(3e-4, dims_tree, backend=backend,
                                   mesh=mesh, param_specs=p_specs)
                    info["optimizer"] = "slim_adam(table3)"
                else:
                    tx = adamw(3e-4, backend=backend, mesh=mesh, param_specs=p_specs)
                    info["optimizer"] = "adamw"
                info["opt_backend"] = backend
                accum = grad_accum or pick_grad_accum(cfg, shape, mesh)
                info["grad_accum"] = accum
                opt_abs = jax.eval_shape(tx.init, params_abs)
                from ..optim.base import resolve_backend
                o_specs = opt_state_specs(
                    opt_abs, params_abs, p_specs,
                    owner_mesh=mesh if resolve_backend(backend) == "fused" else None)
                o_shardings = jax.tree.map(lambda s: NamedSharding(mesh, s), o_specs,
                                           is_leaf=lambda x: isinstance(x, P))
                step = make_train_step(cfg, tx, grad_accum=accum, grad_shardings=p_shardings)
                jitted = jax.jit(
                    step,
                    in_shardings=(p_shardings, o_shardings, b_shardings),
                    out_shardings=(p_shardings, o_shardings, None),
                    donate_argnums=(0, 1),
                )
                args = (params_abs, opt_abs, batch_abs)
            else:  # prefill: forward only (inference)
                def prefill(params, batch):
                    logits, _ = transformer.forward(cfg, params, batch)
                    return jnp.argmax(logits, axis=-1).astype(jnp.int32)

                jitted = jax.jit(prefill, in_shardings=(p_shardings, b_shardings))
                args = (params_abs, batch_abs)
        else:  # decode
            dspec = decode_input_specs(cfg, shape)
            cache_abs = dspec["cache"]
            c_specs = decode_cache_specs(ctx, cache_abs)
            c_shardings = jax.tree.map(lambda s: NamedSharding(mesh, s), c_specs,
                                       is_leaf=lambda x: isinstance(x, P))
            t_sharding = NamedSharding(mesh, ctx.spec_for(("batch", None), dspec["tokens"].shape))
            serve = make_serve_step(cfg)
            jitted = jax.jit(
                serve,
                in_shardings=(p_shardings, c_shardings, t_sharding),
                out_shardings=(NamedSharding(mesh, ctx.spec_for(("batch", None), dspec["tokens"].shape)),
                               None, c_shardings),
                donate_argnums=(1,),
            )
            args = (params_abs, cache_abs, dspec["tokens"])

    info["n_params"] = sum(math.prod(p.shape) for p in jax.tree.leaves(params_abs))
    return jitted, args, ctx, info, cfg


def model_flops_estimate(cfg, info) -> float:
    """MODEL_FLOPS (global): 6*N*D train / 2*N_active*D inference-ish."""
    n = info["n_params"]
    seq, gb, kind = SHAPES[info["shape"]]
    # active params for MoE: experts scaled by top_k / n_experts
    if cfg.n_experts:
        params_abs, meta = cfg.abstract()
        from ..core.labels import flatten_with_names
        total, expert = 0, 0
        for (name, p), (_, m) in zip(flatten_with_names(params_abs)[0], flatten_with_names(meta)[0]):
            sz = math.prod(p.shape)
            total += sz
            if "experts" in m.axes and m.role != "moe_router":
                expert += sz
        n = total - expert + expert * cfg.top_k / cfg.n_experts
    tokens = seq * gb if kind != "decode" else gb  # decode: one token per seq
    if kind == "train":
        return 6.0 * n * tokens
    return 2.0 * n * tokens


def run_cell(arch: str, shape: str, mesh_kind: str, *, optimizer: str = "slim",
             grad_accum: Optional[int] = None, out_dir: Path = RESULTS_DIR,
             variant: str = "default", backend: str = "jnp") -> Dict[str, Any]:
    ok, reason = cell_supported(arch, shape)
    record: Dict[str, Any] = {"arch": arch, "shape": shape, "mesh": mesh_kind}
    if not ok:
        record["status"] = "skipped"
        record["reason"] = reason
        return record

    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    n_chips = math.prod(mesh.devices.shape)
    t0 = time.time()
    jitted, args, ctx, info, cfg = build_cell(arch, shape, mesh, optimizer=optimizer,
                                              grad_accum=grad_accum, variant=variant,
                                              backend=backend)
    with use_sharding(ctx):
        lowered = jitted.lower(*args)
        t_lower = time.time() - t0
        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0

    record.update(info)
    record["status"] = "ok"
    record["n_chips"] = n_chips
    record["lower_s"] = round(t_lower, 1)
    record["compile_s"] = round(t_compile, 1)

    mem = compiled.memory_analysis()
    if mem is not None:
        for attr in ("generated_code_size_in_bytes", "argument_size_in_bytes",
                     "output_size_in_bytes", "temp_size_in_bytes"):
            v = getattr(mem, attr, None)
            if v is not None:
                record[f"mem_{attr}"] = int(v)
        args_b = record.get("mem_argument_size_in_bytes", 0)
        temp_b = record.get("mem_temp_size_in_bytes", 0)
        record["fits_hbm"] = bool(args_b + temp_b <= HBM_PER_CHIP)

    cost = compiled.cost_analysis()
    # Multi-module executables (e.g. shard_map'd pallas_call bodies under the
    # fused backend) report a list of per-module dicts; take the main module.
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else None
    if cost:
        record["xla_cost_flops_raw"] = float(cost.get("flops", -1.0))
        record["xla_cost_bytes_raw"] = float(cost.get("bytes accessed", -1.0))

    stats = hlo_analysis.analyze(compiled.as_text())
    record["hlo_dot_flops_per_dev"] = stats.dot_flops
    record["hlo_traffic_bytes_per_dev"] = stats.traffic_bytes
    record["hlo_collective_bytes_per_dev"] = stats.collective_bytes
    record["hlo_collective_counts"] = stats.collective_count
    record["hlo_unresolved_loops"] = stats.unresolved_loops

    # --- roofline terms (seconds per step, per chip)
    compute_t = stats.dot_flops / PEAK_FLOPS_BF16
    memory_t = stats.traffic_bytes / HBM_BW
    collective_t = stats.total_collective_bytes / ICI_BW_PER_LINK
    record["roofline"] = {
        "compute_s": compute_t,
        "memory_s": memory_t,
        "collective_s": collective_t,
        "dominant": max(
            (("compute", compute_t), ("memory", memory_t), ("collective", collective_t)),
            key=lambda kv: kv[1],
        )[0],
    }
    mf = model_flops_estimate(cfg, info)
    record["model_flops_global"] = mf
    record["model_flops_per_dev"] = mf / n_chips
    if stats.dot_flops > 0:
        record["useful_flops_ratio"] = (mf / n_chips) / stats.dot_flops
        bound = max(compute_t, memory_t, collective_t)
        record["roofline_fraction"] = (mf / n_chips / PEAK_FLOPS_BF16) / bound if bound > 0 else 0.0

    out_dir.mkdir(parents=True, exist_ok=True)
    suffix = "" if optimizer == "slim" else f"_{optimizer}"
    if variant != "default":
        suffix += f"_{variant}"
    if backend != "jnp":
        suffix += f"_{backend}"
    out_path = out_dir / f"{arch}__{shape}__{mesh_kind}{suffix}.json"
    out_path.write_text(json.dumps(record, indent=2, default=str))
    record["out_path"] = str(out_path)
    return record


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", choices=ARCH_IDS, required=False)
    ap.add_argument("--shape", choices=list(SHAPES), required=False)
    ap.add_argument("--mesh", choices=("single", "multi"), default="single")
    ap.add_argument("--optimizer", choices=("slim", "adam"), default="slim")
    ap.add_argument("--backend", choices=("jnp", "fused"), default="jnp",
                    help="optimizer execution path; 'fused' lowers shard_map'd "
                         "Pallas optimizer kernels into the cell")
    ap.add_argument("--grad-accum", type=int, default=None)
    ap.add_argument("--variant", default="default")
    ap.add_argument("--list", action="store_true", help="list all runnable cells")
    args = ap.parse_args(argv)

    if args.list:
        for arch in ARCH_IDS:
            for shape in SHAPES:
                ok, reason = cell_supported(arch, shape)
                print(f"{arch:22s} {shape:12s} {'RUN' if ok else 'SKIP: ' + reason}")
        return 0

    rec = run_cell(args.arch, args.shape, args.mesh, optimizer=args.optimizer,
                   grad_accum=args.grad_accum, variant=args.variant,
                   backend=args.backend)
    print(json.dumps(rec, indent=2, default=str))
    return 0 if rec["status"] in ("ok", "skipped") else 1


if __name__ == "__main__":
    sys.exit(main())
