#!/usr/bin/env bash
# Tiered CI pipeline — the single source of truth both `make ci` and
# .github/workflows/ci.yml call, so local and hosted CI cannot drift.
#
#   scripts/ci.sh lint            ruff check (skipped with a notice if ruff
#                                 is not installed — the container image does
#                                 not ship it; the GitHub lint job does)
#   scripts/ci.sh analyze         static contract checker (repro.analysis):
#                                 kernel buffer/VMEM/dtype/signature checks
#                                 against golden_signatures.json, the grid-
#                                 race detector, sharding-plan geometry over
#                                 the config zoo x mesh matrix, trace-
#                                 stability of the guarded step, and the
#                                 repo lint rules (RPR001-004) — all device-
#                                 free (eval_shape / jaxpr / AST), seconds
#                                 not minutes, so it gates before the test
#                                 tiers
#   scripts/ci.sh test-fast       pytest -m "not slow" (quick tier)
#   scripts/ci.sh test-full       full pytest suite
#   scripts/ci.sh bench-roofline  analytic roofline gates: transpose-free
#                                 planner + the sharded gate (per-shard byte
#                                 bound, zero psum-finalize jnp fallbacks,
#                                 compressed-leaf ratio <= 0.716 under the
#                                 owner-write scheme, fused-SNR measure-step
#                                 delta O(kept)) + the megakernel launch gate
#                                 (GPT-small tree update in O(groups) <= 8
#                                 pallas launches; wall-clock fused <= jnp on
#                                 real TPU backends)
#   scripts/ci.sh bench-quick     just the optimizer benches (opt_speed,
#                                 opt_speed_tree, opt_speed_sharded)
#   scripts/ci.sh bench           full quick-preset benchmark sweep
#                                 (writes benchmarks/results/*.csv and
#                                 appends the machine-readable perf
#                                 trajectory BENCH_opt_speed.json)
#   scripts/ci.sh bench-serve     serving fast-path gate: the paged KV
#                                 pool/scheduler test suite, then the
#                                 engine bench (benchmarks/serve_bench.py:
#                                 O(1) pallas launches per decode step,
#                                 chunked prefill >= 4x fewer device steps
#                                 than token-by-token, greedy paged output
#                                 token-identical to the legacy generate()
#                                 oracle; appends BENCH_serve.json)
#   scripts/ci.sh serve-drill     serving fault-tolerance gate: the serving
#                                 fault/SLO test suite (tests/
#                                 test_serve_faults.py) then the chaos drill
#                                 (benchmarks/serve_drill.py: a run injected
#                                 with kernel failures, poisoned logits, a
#                                 pool squeeze and a deadline-blowing stall
#                                 must drain with greedy parity on unpoisoned
#                                 requests, zero page leaks, every injection
#                                 visible in ServeMetrics; appends
#                                 BENCH_serve_stability.json)
#   scripts/ci.sh fault-drill     resilience gate: the fault-injection test
#                                 suite (tests/test_guard.py + the hardened
#                                 checkpoint cases) then the end-to-end drill
#                                 (benchmarks/fault_drill.py: injected
#                                 gpt_small run completes within 2% of the
#                                 clean run's eval loss, every injection
#                                 visible in the guard counters; appends
#                                 BENCH_stability.json)
#   scripts/ci.sh all  (default)  lint + analyze + test-full + bench-roofline
#                                 + the quick optimizer benches (the tier-1
#                                 gate)
#
# The suite is embarrassingly parallel, so when pytest-xdist is available
# (requirements-dev.txt) the run fans out across cores (-n auto), cutting
# ~300 s serial to well under the ~150 s budget. The slowest cases carry a
# `slow` marker so quick local loops (test-fast) can skip them; the tier-1
# gate always runs the *full* suite — parallelism, never deselection, is
# what keeps it under budget.
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

stage="${1:-all}"

require_jax() {
  # Fail fast with a diagnosis instead of a bare ImportError traceback from
  # deep inside the first collected test module.
  if ! python -c "import jax" >/dev/null 2>&1; then
    echo "error: python cannot import jax — the test suite, benchmarks and" >&2
    echo "kernels all require it. Install a CPU jax (pip install 'jax[cpu]')" >&2
    echo "or run inside the project container image, then retry." >&2
    exit 1
  fi
}

xdist_flags() {
  # Print the parallel/serial decision so CI logs show which mode ran.
  if python -c "import xdist" >/dev/null 2>&1; then
    echo "pytest-xdist available: running parallel (-n auto)" >&2
    echo "-n auto"
  else
    echo "pytest-xdist not installed: running serial (pip install -r requirements-dev.txt to parallelize)" >&2
    echo ""
  fi
}

run_lint() {
  if python -c "import ruff" >/dev/null 2>&1 || command -v ruff >/dev/null 2>&1; then
    ruff check .
  else
    echo "ruff not installed: skipping lint (the GitHub 'lint' job installs it; pip install ruff to run locally)"
  fi
}

run_analyze() {
  require_jax
  # On a golden-signature mismatch the checker writes the freshly computed
  # matrix to golden_signatures.diff.json (uploaded as a CI artifact) so the
  # drift is inspectable without re-running; intentional changes are accepted
  # with `python -m repro.analysis --update-golden` + committing the golden.
  python -m repro.analysis --diff-out golden_signatures.diff.json
}

run_test_fast() {
  require_jax
  python -m pytest -x -q $(xdist_flags) -m "not slow"
}

run_test_full() {
  require_jax
  python -m pytest -x -q $(xdist_flags)
}

run_bench_roofline() {
  require_jax
  # Single-device planner gate: every gpt_small leaf transpose-free.
  python -m benchmarks.opt_speed --check-roofline
  # Sharded gate on the production (16x16) mesh: per-shard byte bound,
  # psum regime fully Pallas-resident (regime_counts psum_jnp == 0),
  # compressed-leaf ratio <= 0.716 (owner-shard moment writes), and the
  # fused-SNR measure-step delta bounded to O(kept) stat lines.
  python -m benchmarks.opt_speed --check-roofline --sharded
  # Megakernel launch gate: the default fused tree update must trace to
  # O(groups) pallas launches (<= 8 for GPT-small; wall-clock fused <= jnp
  # gated only on a real TPU backend, interp runs record projected times;
  # on failure the megaplan group tables land in results/megaplan_groups.csv).
  python -m benchmarks.opt_speed --check-launches
}

run_bench_quick() {
  require_jax
  python -m benchmarks.run --preset quick --only opt_speed
  python -m benchmarks.run --preset quick --only opt_speed_tree
  python -m benchmarks.run --preset quick --only opt_speed_sharded
}

run_bench() {
  require_jax
  python -m benchmarks.run --preset quick
}

run_bench_serve() {
  require_jax
  # Parity/invariant suite first (pinpoints the failing layer), then the
  # engine bench whose launch/prefill/parity gates run on any backend.
  python -m pytest -x -q tests/test_serve_paged.py
  python -m benchmarks.run --preset quick --only serve_bench
}

run_serve_drill() {
  require_jax
  # Fault/SLO suite first (pinpoints the failing layer: registry, admission,
  # deadlines, degradation, chaos invariants), then the end-to-end drill.
  python -m pytest -x -q tests/test_serve_faults.py
  python -m benchmarks.run --preset quick --only serve_drill
}

run_fault_drill() {
  require_jax
  # Injection suite first (fast, pinpoints the failing layer), then the
  # end-to-end drill that exercises guard + rollback + hardened IO together.
  python -m pytest -x -q tests/test_guard.py
  python -m pytest -x -q tests/test_substrate.py -k "Hardened or wall_clock"
  python -m benchmarks.run --preset quick --only fault_drill
}

case "$stage" in
  lint)           run_lint ;;
  analyze)        run_analyze ;;
  test-fast)      run_test_fast ;;
  test-full)      run_test_full ;;
  bench-roofline) run_bench_roofline ;;
  bench-quick)    run_bench_quick ;;
  bench)          run_bench ;;
  bench-serve)    run_bench_serve ;;
  serve-drill)    run_serve_drill ;;
  fault-drill)    run_fault_drill ;;
  all)            run_lint; run_analyze; run_test_full; run_bench_roofline; run_bench_quick ;;
  *)
    echo "usage: scripts/ci.sh [lint|analyze|test-fast|test-full|bench-roofline|bench-quick|bench|bench-serve|serve-drill|fault-drill|all]" >&2
    exit 2 ;;
esac
