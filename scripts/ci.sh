#!/usr/bin/env bash
# Tier-1 gate: full test suite + the quick optimizer benchmarks in Pallas
# interpret mode (correctness harness; the roofline columns are analytic).
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

python -m pytest -x -q
python -m benchmarks.run --preset quick --only opt_speed
python -m benchmarks.run --preset quick --only opt_speed_tree
