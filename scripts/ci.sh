#!/usr/bin/env bash
# Tier-1 gate: full test suite + the quick optimizer benchmarks in Pallas
# interpret mode (correctness harness; the roofline columns are analytic).
#
# The suite is embarrassingly parallel, so when pytest-xdist is available
# (requirements-dev.txt) the run fans out across cores (-n auto), cutting
# ~300 s serial to well under the ~150 s budget. The slowest cases carry a
# `slow` marker so quick local loops (`make test-fast`) can skip them; this
# gate always runs the *full* suite — parallelism, never deselection, is
# what keeps it under budget.
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

XDIST_FLAGS=""
if python -c "import xdist" >/dev/null 2>&1; then
  XDIST_FLAGS="-n auto"
fi

python -m pytest -x -q ${XDIST_FLAGS}
python -m benchmarks.opt_speed --check-roofline
python -m benchmarks.run --preset quick --only opt_speed
python -m benchmarks.run --preset quick --only opt_speed_tree
