"""Seeded regressions for the static contract checker (`repro.analysis`).

Every check class the analyzer claims to catch gets a deliberately broken
artifact here — an inflated buffer constant, a parallel-dim write to a
shared accumulator, a blind (non-RMW) aliased write, an uncast bf16 read, a
partial owner placement, a drifted golden signature, a step that
concretizes its traced controls — plus the green path: the real repo must
produce zero findings on every pass.
"""
import json

import jax
import jax.numpy as jnp
import pytest
from jax.experimental import pallas as pl

from repro.analysis import kernelcheck, lint, races, registry, shardcheck, tracecheck
from repro.analysis.jaxpr_tools import aliased_grid_dims
from repro.analysis.report import PassResult


def _infos(fn, *args, **kwargs):
    return kernelcheck.trace_infos(fn, args, kwargs)


# ---------------------------------------------------------------------------
# Seeded pallas kernels
# ---------------------------------------------------------------------------


def _acc_body(x_ref, o_ref):
    # zero-on-first-instance + accumulate: the legal RMW pattern
    @pl.when((pl.program_id(0) == 0) & (pl.program_id(1) == 0))
    def _zero():
        o_ref[...] = jnp.zeros((2,), jnp.float32)

    o_ref[...] = o_ref[...] + jnp.stack(
        [jnp.sum(x_ref[...]), jnp.float32(1.0)])


def _blind_body(x_ref, o_ref):
    # blind overwrite of the shared block: drops earlier instances
    o_ref[...] = jnp.stack([jnp.sum(x_ref[...]), jnp.float32(1.0)])


def _acc_call(body, x, semantics):
    r, c = x.shape
    return pl.pallas_call(
        body,
        grid=(r // 8, c // 128),
        in_specs=[pl.BlockSpec((8, 128), lambda i, j: (i, j))],
        out_specs=pl.BlockSpec((2,), lambda i, j: (0,)),
        out_shape=jax.ShapeDtypeStruct((2,), jnp.float32),
        interpret=True,
        compiler_params=dict(mosaic=dict(dimension_semantics=semantics)),
    )(x)


_X = jax.ShapeDtypeStruct((16, 256), jnp.float32)


class TestRaceDetector:
    def test_parallel_dim_write_to_shared_accumulator_flagged(self):
        (info,) = _infos(
            lambda x: _acc_call(_acc_body, x, ("parallel", "arbitrary")), _X)
        result = PassResult("races")
        races.check_output_races(info, result, "seeded")
        assert any(f.check == "race-parallel" for f in result.findings)

    def test_blind_write_to_shared_accumulator_flagged(self):
        (info,) = _infos(
            lambda x: _acc_call(_blind_body, x, ("arbitrary", "arbitrary")), _X)
        result = PassResult("races")
        races.check_output_races(info, result, "seeded")
        assert any(f.check == "race-rmw" for f in result.findings)

    def test_sequential_rmw_accumulator_is_clean(self):
        (info,) = _infos(
            lambda x: _acc_call(_acc_body, x, ("arbitrary", "arbitrary")), _X)
        # the shared block really is aliased across both grid dims ...
        assert aliased_grid_dims(info.blocks_out[0], info.grid) == {0, 1}
        # ... and still legal: sequential dims + read-modify-write
        result = PassResult("races")
        races.check_output_races(info, result, "seeded")
        assert not result.findings


class TestKernelcheck:
    def _sum3(self):
        def body(a_ref, b_ref, c_ref, o_ref):
            o_ref[...] = a_ref[...] + b_ref[...] + c_ref[...]

        def call(a, b, c):
            spec = pl.BlockSpec((8, 128), lambda i: (i, 0))
            return pl.pallas_call(
                body, grid=(2,), in_specs=[spec] * 3, out_specs=spec,
                out_shape=jax.ShapeDtypeStruct((16, 128), jnp.float32),
                interpret=True)(a, b, c)

        x = jax.ShapeDtypeStruct((16, 128), jnp.float32)
        (info,) = _infos(call, x, x, x)
        return info

    def test_inflated_buffer_constant_flagged(self):
        info = self._sum3()  # 4 live full-size blocks
        result = PassResult("kernelcheck")
        kernelcheck.check_bufs(info, 10, "SEEDED_BUFS", result, "seeded")
        assert any(f.check == "bufs" for f in result.findings)

    def test_honest_buffer_constant_passes(self):
        info = self._sum3()
        result = PassResult("kernelcheck")
        kernelcheck.check_bufs(info, 5, "SEEDED_BUFS", result, "seeded")
        assert not result.findings

    def test_vmem_blowout_flagged(self):
        def call(a):
            spec = pl.BlockSpec((2048, 2048), lambda i: (i, 0))
            return pl.pallas_call(
                lambda a_ref, o_ref: o_ref.__setitem__(..., a_ref[...] * 2.0),
                grid=(1,), in_specs=[spec], out_specs=spec,
                out_shape=jax.ShapeDtypeStruct((2048, 2048), jnp.float32),
                interpret=True)(a)

        (info,) = _infos(call, jax.ShapeDtypeStruct((2048, 2048), jnp.float32))
        result = PassResult("kernelcheck")
        kernelcheck.check_vmem(info, result, "seeded", gated=True)
        assert any(f.check == "vmem" for f in result.findings)

    def test_uncast_bf16_read_flagged(self):
        def call(a):
            spec = pl.BlockSpec((8, 128), lambda i: (i, 0))
            return pl.pallas_call(
                # consumes the bf16 read directly — no cast to f32
                lambda a_ref, o_ref: o_ref.__setitem__(..., a_ref[...] + 1.0),
                grid=(1,), in_specs=[spec], out_specs=spec,
                out_shape=jax.ShapeDtypeStruct((8, 128), jnp.bfloat16),
                interpret=True)(a)

        (info,) = _infos(call, jax.ShapeDtypeStruct((8, 128), jnp.bfloat16))
        result = PassResult("kernelcheck")
        kernelcheck.check_compute_dtype(info, result, "seeded")
        assert any(f.check == "dtype" for f in result.findings)

    def test_full_size_variant_output_flagged(self, monkeypatch):
        entry = registry.ENTRY_MAP["slim_precond_batched"]
        case, variant = entry.cases[0], entry.variants[1]
        monkeypatch.setattr(
            registry, "variant_extra_outputs",
            lambda *a: [jax.ShapeDtypeStruct(case.shape, jnp.float32)])
        result = PassResult("kernelcheck")
        kernelcheck.check_extra_outputs(entry, case, variant, result, "seeded")
        assert any(f.check == "okept" for f in result.findings)

    def test_golden_signature_drift_flagged(self, tmp_path):
        golden = json.loads(kernelcheck.GOLDEN_PATH.read_text())
        key = sorted(golden)[0]
        golden[key] = [["9x9x9", "float64"]]  # a kernel output silently grew
        drifted = tmp_path / "golden.json"
        drifted.write_text(json.dumps(golden))
        result, _ = kernelcheck.run(golden_path=drifted)
        assert any(f.check == "golden" and f.where == key
                   for f in result.findings)


class TestShardcheck:
    def test_partial_owner_placement_flagged(self):
        from repro.kernels.slim_update import PRECOND_BUFS
        from repro.sharding.logical import (ShardingContext, param_specs,
                                            use_sharding)
        from repro.sharding.shardspec import (SpecMesh, normalize_spec_leaves,
                                              plan_sharded_leaf)

        cfg, params_abs, meta, treedef, p_leaves, dims_flat = \
            shardcheck.arch_leaves("gpt_small")
        mesh = SpecMesh({"data": 16, "model": 16})
        ctx = ShardingContext(mesh, rules=dict(cfg.sharding_overrides) or None)
        with use_sharding(ctx):
            p_specs = param_specs(meta, params_abs)
        spec_flat = normalize_spec_leaves(p_specs, treedef, "test")

        corrupted = 0
        for leaf, spec, dims in zip(p_leaves, spec_flat, dims_flat):
            plan = plan_sharded_leaf(tuple(leaf.shape), leaf.dtype,
                                     tuple(dims), spec, mesh,
                                     n_bufs=PRECOND_BUFS)
            if plan.regime != "psum" or not plan.owner:
                continue
            # clean plan passes ...
            ok = PassResult("shardcheck")
            shardcheck.check_leaf_plan(plan, tuple(leaf.shape), tuple(dims),
                                       mesh, ok, "clean")
            assert not ok.findings
            # ... losing part of the placement (or all of it swapped onto a
            # mesh axis outside the psum group) fails all-or-nothing
            bad_owner = (plan.owner[:-1]
                         or ((("bogus",) + plan.owner[0][1:]),))
            bad = plan._replace(owner=tuple(bad_owner))
            res = PassResult("shardcheck")
            shardcheck.check_leaf_plan(bad, tuple(leaf.shape), tuple(dims),
                                       mesh, res, "seeded")
            assert any(f.check == "owner-all-or-nothing" for f in res.findings)
            corrupted += 1
            if corrupted >= 2:
                break
        assert corrupted, "no psum-with-owner leaf found to corrupt"


class TestTracecheck:
    def _tiny(self):
        p = {"w": jax.ShapeDtypeStruct((4,), jnp.float32)}
        return p, {}, {}

    def test_concretizing_step_flagged(self):
        def bad(params, opt, batch, controls):
            lr = float(controls["lr_scale"])  # concretizes a tracer
            return jax.tree.map(lambda x: x * lr, params), opt

        result = PassResult("tracecheck")
        tracecheck.check_step_trace(bad, self._tiny(), result, "seeded")
        assert any(f.check == "trace-stable" for f in result.findings)

    def test_control_ignoring_step_flagged(self):
        def bad(params, opt, batch, controls):
            return jax.tree.map(lambda x: x * 2.0, params), opt

        result = PassResult("tracecheck")
        tracecheck.check_step_trace(bad, self._tiny(), result, "seeded")
        assert any(f.check == "controls-used" for f in result.findings)

    def test_trace_dependent_step_flagged(self):
        calls = []

        def bad(params, opt, batch, controls):
            calls.append(1)  # trace depends on call count, not operands
            bump = 1.0 if len(calls) > 1 else 0.0
            return (jax.tree.map(lambda x: x * controls["lr_scale"] + bump,
                                 params), opt)

        result = PassResult("tracecheck")
        tracecheck.check_step_trace(bad, self._tiny(), result, "seeded")
        assert any(f.check == "trace-stable" for f in result.findings)

    def test_honest_step_is_clean(self):
        def good(params, opt, batch, controls):
            return (jax.tree.map(lambda x: x * controls["lr_scale"]
                                 * controls["grad_scale"], params), opt)

        result = PassResult("tracecheck")
        tracecheck.check_step_trace(good, self._tiny(), result, "seeded")
        assert not result.findings


class TestLint:
    def test_pallas_call_outside_kernels_flagged(self):
        hits = lint.lint_source(
            "import jax.experimental.pallas as pl\n"
            "def f(x):\n"
            "    return pl.pallas_call(lambda r, o: None)(x)\n",
            "repro/optim/rogue.py")
        assert any(rule == "RPR001" for rule, _, _ in hits)

    def test_host_numpy_and_traced_branch_in_kernel_flagged(self):
        hits = lint.lint_source(
            "import numpy as np\n"
            "def _k(g_ref, u_out, *, with_snr):\n"
            "    g = g_ref[...]\n"
            "    if with_snr:\n"          # static flag: legal
            "        pass\n"
            "    if g.sum() > 0:\n"        # traced: illegal
            "        u_out[...] = np.sqrt(g)\n",
            "repro/kernels/rogue.py")
        rules = [r for r, _, _ in hits]
        assert rules.count("RPR002") == 2  # the branch and the np. call

    def test_optional_state_field_without_default_flagged(self):
        hits = lint.lint_source(
            "from typing import NamedTuple, Optional\n"
            "class FooState(NamedTuple):\n"
            "    count: object\n"
            "    snr: Optional[object]\n",
            "repro/core/rogue.py")
        assert any(rule == "RPR003" for rule, _, _ in hits)

    def test_non_atomic_checkpoint_publish_flagged(self):
        hits = lint.lint_source(
            "import os, shutil\n"
            "def save(stage, final, ptr):\n"
            "    os.rename(stage, final)\n"
            "    shutil.move(stage, final)\n"
            "    os.replace(final, ptr)\n"
            "    open(ptr / 'LATEST', 'w')\n",
            "repro/checkpoint/rogue.py")
        assert [r for r, _, _ in hits].count("RPR004") == 4

    def test_repo_is_lint_clean(self):
        result = lint.run()
        assert not result.findings, [str(f) for f in result.findings]


class TestGreenPath:
    """The analyzer against the real repo: zero findings, every pass."""

    def test_kernelcheck_and_races_clean(self):
        result, computed = kernelcheck.run()
        assert not result.findings, [str(f) for f in result.findings]
        assert computed  # signatures flowed
        r2 = races.run()
        assert not r2.findings, [str(f) for f in r2.findings]
        assert r2.checks > 100

    def test_shardcheck_clean(self):
        result = shardcheck.run()
        assert not result.findings, [str(f) for f in result.findings]
        assert result.checks > 1000

    def test_tracecheck_clean(self):
        result = tracecheck.run()
        assert not result.findings, [str(f) for f in result.findings]
        assert result.checks == 3

    def test_registry_feeds_roofline_gates(self):
        # the opt_speed gates consume these exact contracts
        lines, oversize = registry.snr_stat_lines()
        assert set(lines) == {"psum", "local", "jnp"} and not oversize
        for name, extras in registry.health_stat_outputs():
            assert extras == [(2,)], (name, extras)

    def test_cli_gate(self, capsys):
        from repro.analysis.__main__ import main

        assert main(["--only", "lint"]) == 0
        out = capsys.readouterr().out
        assert "lint" in out and "PASS" in out
        with pytest.raises(SystemExit):
            main(["--only", "nonsense"])
