"""Paged serving fast path (PR 9): kernel parity, engine parity vs the
legacy loop, request API, and scheduler/pool invariants.

The legacy token-by-token batch loop (ServeConfig(paged=False)) is the
oracle throughout: same params, same greedy sampling, dense per-request
caches — the paged path must reproduce its tokens exactly.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_reduced
from repro.kernels.paged_attention import paged_attention, paged_attention_ref
from repro.serve import Engine, Request, ServeConfig


def _rand_pool_case(key, *, b, kv, rep, hd, page, max_pages, pool_dtype):
    """Random pool + per-row distinct page tables + ragged lengths
    (one full-page row, one mid-page row, one empty row when b >= 3)."""
    k1, k2 = jax.random.split(key)
    n_pages = b * max_pages + 1
    pool = jax.random.normal(k1, (n_pages, page, 2 * kv, hd)).astype(pool_dtype)
    table = (1 + np.arange(b * max_pages, dtype=np.int32)).reshape(b, max_pages)
    lengths = np.zeros((b,), np.int32)
    lengths[0] = max_pages * page            # every page full
    if b > 1:
        lengths[1] = page + 1                # ragged: one token into page 1
    # rows >= 2 stay at 0: inactive, must come out all-zero
    return k2, pool, jnp.asarray(table), jnp.asarray(lengths)


class TestPagedKernel:
    @pytest.mark.parametrize("page", [4, 8])
    @pytest.mark.parametrize("pool_dtype", [jnp.float32, jnp.bfloat16])
    def test_decode_matches_dense_ref(self, page, pool_dtype):
        key = jax.random.PRNGKey(0)
        key, pool, table, lengths = _rand_pool_case(
            key, b=3, kv=2, rep=2, hd=8, page=page, max_pages=3,
            pool_dtype=pool_dtype)
        q = jax.random.normal(key, (3, 1, 4, 8), jnp.float32)
        got = paged_attention(q, pool, table, lengths)
        want = paged_attention_ref(q, pool, table, lengths)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   atol=3e-5, rtol=3e-5)
        assert not np.asarray(got[2]).any()   # inactive row is exact zeros

    @pytest.mark.parametrize("page", [4, 8])
    def test_chunk_matches_dense_ref(self, page):
        key = jax.random.PRNGKey(1)
        key, pool, table, lengths = _rand_pool_case(
            key, b=2, kv=2, rep=2, hd=8, page=page, max_pages=3,
            pool_dtype=jnp.float32)
        q = jax.random.normal(key, (2, 4, 4, 8), jnp.float32)
        got = paged_attention(q, pool, table, lengths)
        want = paged_attention_ref(q, pool, table, lengths)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   atol=3e-5, rtol=3e-5)

    def test_chunk_rows_equal_per_token_decode(self):
        """A C-token chunk must produce exactly what C successive one-token
        decode calls at growing lengths produce — the chunked-prefill
        correctness contract."""
        c, page = 4, 4
        key = jax.random.PRNGKey(2)
        key, pool, table, _ = _rand_pool_case(
            key, b=1, kv=2, rep=2, hd=8, page=page, max_pages=3,
            pool_dtype=jnp.float32)
        length = 2 * page + 3                 # ragged final page
        q = jax.random.normal(key, (1, c, 4, 8), jnp.float32)
        chunk = paged_attention(q, pool, table, jnp.asarray([length], jnp.int32))
        for i in range(c):
            li = length - c + 1 + i           # query i sits at position li - 1
            tok = paged_attention(q[:, i:i + 1], pool, table,
                                  jnp.asarray([li], jnp.int32))
            np.testing.assert_allclose(np.asarray(chunk[:, i]),
                                       np.asarray(tok[:, 0]),
                                       atol=3e-5, rtol=3e-5)


def _mk(arch="gpt_small", **sc_kw):
    cfg = get_reduced(arch)
    params, _ = cfg.init(jax.random.PRNGKey(0))
    return cfg, params, ServeConfig(**sc_kw)


def _invariants(eng):
    """No slot double-use, no page mapped twice, table agrees with pool
    ownership — checked live between scheduler steps."""
    sched = eng.scheduler
    seen = {}
    for slot in range(sched.n_slots):
        rid = sched.slot_rid[slot]
        row = sched.table[slot]
        if rid is None:
            assert not row.any(), f"empty slot {slot} has mapped pages"
            continue
        for pg in row[row != 0]:
            assert pg not in seen, f"page {pg} mapped by slots {seen[pg]},{slot}"
            seen[int(pg)] = slot
            assert eng.pool.owner(int(pg)) == rid


class TestEngineParity:
    @pytest.mark.parametrize("arch", ["gpt_small", "smollm_135m"])
    @pytest.mark.parametrize("page_size", [4, 16])
    def test_paged_matches_legacy_greedy(self, arch, page_size):
        cfg, params, _ = _mk(arch)
        kw = dict(max_new_tokens=8, max_seq=32, page_size=page_size)
        prompts = jax.random.randint(jax.random.PRNGKey(1), (3, 5), 0,
                                     cfg.vocab_size)
        paged = Engine(cfg, params, ServeConfig(**kw)).generate(prompts)
        legacy = Engine(cfg, params, ServeConfig(paged=False, **kw)).generate(prompts)
        np.testing.assert_array_equal(np.asarray(paged), np.asarray(legacy))

    def test_bf16_pool_matches_bf16_legacy_cache(self):
        cfg = dataclasses.replace(get_reduced("gpt_small"),
                                  dtype=jnp.bfloat16)
        params, _ = cfg.init(jax.random.PRNGKey(0))
        kw = dict(max_new_tokens=6, max_seq=32, page_size=8)
        prompts = jax.random.randint(jax.random.PRNGKey(3), (2, 4), 0,
                                     cfg.vocab_size)
        paged = Engine(cfg, params, ServeConfig(**kw)).generate(prompts)
        legacy = Engine(cfg, params, ServeConfig(paged=False, **kw)).generate(prompts)
        np.testing.assert_array_equal(np.asarray(paged), np.asarray(legacy))

    def test_chunked_prefill_matches_token_by_token(self):
        """prefill_chunk=1 degenerates to token-by-token prefill; larger
        chunks must emit identical tokens in ceil(S/C) prefill steps."""
        cfg, params, _ = _mk("gpt_small")
        prompts = jax.random.randint(jax.random.PRNGKey(2), (2, 12), 0,
                                     cfg.vocab_size)
        outs, chunks = [], []
        for c in (1, 4, 8):
            eng = Engine(cfg, params, ServeConfig(
                max_new_tokens=4, max_seq=32, prefill_chunk=c))
            outs.append(np.asarray(eng.generate(prompts)))
            chunks.append(eng.prefill_chunks)
        np.testing.assert_array_equal(outs[0], outs[1])
        np.testing.assert_array_equal(outs[0], outs[2])
        assert chunks[0] == 2 * 12            # token-by-token
        assert chunks[1] == 2 * 3             # ceil(12/4)
        assert chunks[2] == 2 * 2             # ceil(12/8)


class TestRequestAPI:
    def test_submit_run_until_drained(self):
        cfg, params, sc = _mk(max_seq=32, max_new_tokens=16)
        eng = Engine(cfg, params, sc)
        prompt = np.array([1, 2, 3, 4], np.int32)
        r_short = eng.submit(Request(prompt=prompt, max_new_tokens=2))
        r_long = eng.submit(Request(prompt=prompt, max_new_tokens=5))
        done = eng.run_until_drained()
        assert set(done) == {r_short, r_long}
        assert done[r_short].finish_reason == "length"
        assert len(done[r_short].tokens) == 2
        assert len(done[r_long].tokens) == 5
        # same prompt, same greedy -> the short completion is a prefix
        np.testing.assert_array_equal(done[r_short].tokens,
                                      done[r_long].tokens[:2])
        for c in done.values():
            assert c.ttft_s is not None and 0 <= c.ttft_s <= c.wall_s
            np.testing.assert_array_equal(c.prompt, prompt)

    def test_per_request_seed_reproducible(self):
        cfg, params, sc = _mk(max_seq=32, max_new_tokens=6)
        prompt = np.array([5, 6, 7], np.int32)

        def sample(seed):
            eng = Engine(cfg, params, ServeConfig(max_seq=32))
            rid = eng.submit(Request(prompt=prompt, max_new_tokens=6,
                                     temperature=1.0, seed=seed))
            return eng.run_until_drained()[rid].tokens

        np.testing.assert_array_equal(sample(11), sample(11))

    def test_serveconfig_default_not_shared(self):
        """Engine() used to share one mutable ServeConfig() instance across
        every engine constructed without an explicit config."""
        cfg, params, _ = _mk()
        e1 = Engine(cfg, params)
        e1.sc.max_seq = 7
        assert Engine(cfg, params).sc.max_seq == 512

    def test_request_exceeding_pool_rejected(self):
        cfg, params, _ = _mk()
        eng = Engine(cfg, params, ServeConfig(
            max_seq=64, max_new_tokens=32, page_size=4, pool_pages=4))
        with pytest.raises(ValueError, match="pages"):
            eng.submit(Request(prompt=np.arange(20, dtype=np.int32)))

    def test_request_api_unavailable_on_legacy_arch(self):
        cfg, params, _ = _mk("falcon_mamba_7b")
        eng = Engine(cfg, params, ServeConfig(max_seq=32))
        with pytest.raises(NotImplementedError, match="generate"):
            eng.submit(Request(prompt=np.array([1, 2], np.int32)))


class TestScheduler:
    def test_no_leak_after_drain_with_queueing(self):
        """More requests than slots: everything completes, no page stays
        allocated, invariants hold between steps."""
        cfg, params, _ = _mk()
        eng = Engine(cfg, params, ServeConfig(
            max_seq=32, max_new_tokens=3, max_slots=2, page_size=8))
        prompt = np.array([1, 2, 3], np.int32)
        rids = [eng.submit(Request(prompt=prompt)) for _ in range(5)]
        while eng.scheduler.queue or eng.scheduler.active_slots():
            eng.step()
            _invariants(eng)
        done = eng.completions()
        assert set(done) == set(rids)
        assert eng.pool.used_pages == 0
        assert eng.scheduler.admitted == 5 and eng.scheduler.retired == 5
        base = done[rids[0]].tokens
        for rid in rids[1:]:                  # identical work -> identical tokens
            np.testing.assert_array_equal(done[rid].tokens, base)

    def test_eos_retirement_releases_pages_for_late_admits(self):
        """The pool only holds one request's pages at a time: later requests
        can be admitted *only* because retirement frees pages immediately
        (releasing at batch drain would deadlock this workload)."""
        cfg, params, _ = _mk()
        eng = Engine(cfg, params, ServeConfig(
            max_seq=16, max_new_tokens=4, max_slots=4, page_size=4,
            pool_pages=5))                    # capacity 4 pages
        prompt = np.arange(8, dtype=np.int32) % cfg.vocab_size
        rids = [eng.submit(Request(prompt=prompt)) for _ in range(4)]
        # prompt(8) = 2 pages; +4 new tokens -> 3 pages: two requests cannot
        # coexist (2 * 2 prompt pages + headroom > 4), so progress requires
        # mid-batch page recycling
        done = eng.run_until_drained()
        assert set(done) == set(rids)
        assert all(len(c.tokens) == 4 for c in done.values())
        assert eng.pool.used_pages == 0
        assert eng.pool.high_water <= 3
        assert eng.pool.free_count == eng.pool.alloc_count

    def test_preemption_recompute_matches_solo_run(self):
        """Pool exhaustion mid-decode preempts the youngest request; after
        recompute its tokens must match an uncontended solo run exactly."""
        cfg, params, _ = _mk()
        sc = ServeConfig(max_seq=16, max_new_tokens=6, max_slots=2,
                         page_size=2, pool_pages=8)   # capacity 7
        eng = Engine(cfg, params, sc)
        p0 = np.array([1, 2, 3, 4], np.int32)
        p1 = np.array([9, 8, 7, 6], np.int32)
        r0 = eng.submit(Request(prompt=p0))
        r1 = eng.submit(Request(prompt=p1))
        done = eng.run_until_drained()
        assert eng.scheduler.preempted >= 1
        assert done[r1].preemptions >= 1
        assert eng.pool.used_pages == 0
        for rid, prompt in ((r0, p0), (r1, p1)):
            solo = Engine(cfg, params, sc)
            srid = solo.submit(Request(prompt=prompt))
            np.testing.assert_array_equal(
                done[rid].tokens, solo.run_until_drained()[srid].tokens)
