"""Batched 3-D canonicalization: planner properties, exact round-trips, and
batched-kernel parity vs the jnp oracle on scan-stacked specs.

The planner contract under test:
  * on batch-free shapes (a 2-D orientation is reshape-reachable, or the
    plan must transpose) ``canon_nd`` degrades to the 2-D plans the old
    ``canon2d`` emitted — batch == 1, same orientation, same rows/cols;
  * a kept-prefix / reduced-block / kept-suffix pattern (every scan-stacked
    leaf) plans batched major, reachable by pure reshape;
  * ``canon_apply``/``canon_restore`` round-trip *exactly* (bit-equal, incl.
    size-1 axes and bf16) for batched plans.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hyp import given, settings, st  # hypothesis, or deterministic fallback

from repro.core.slim_adam import scale_by_slim_adam
from repro.kernels import canon_nd, canon_apply, canon_restore, leaf_plan
from repro.kernels.slim_update import (
    PRECOND_BUFS,
    slim_precond_batched,
    slim_update_batched,
)
from repro.optim.fused import jnp_slim_leaf

TOL = dict(rtol=1e-5, atol=1e-6)

# Pool of (shape, dims) specs with every reachability class represented.
BATCH_FREE_SPECS = [
    ((12, 8), (1,)),            # trailing K -> minor
    ((12, 8), (0,)),            # leading K -> major
    ((3, 3, 8, 16), (0, 1, 2)),  # leading multi-dim K -> major
    ((2, 3, 4), (1, 2)),        # trailing multi-dim K -> minor
    ((37,), (0,)),              # fully reduced 1-D -> minor
    ((12, 8), (0, 1)),          # kept empty -> minor
    ((1, 6, 10), (0, 2)),       # size-1 reduced axis ignored
    ((6, 1, 10), (0, 1)),       # size-1 kept axis ignored
    ((4, 6, 10), (0, 2)),       # interleaved -> transpose fallback
    ((2, 3, 4, 5), (1, 3)),     # interleaved -> transpose fallback
]

BATCHED_SPECS = [
    ((2, 3, 4), (1,)),          # minimal kept/K/kept sandwich
    ((3, 96, 3, 32), (1,)),     # gpt_small reduced: stacked wq/wk, K=embed
    ((3, 96, 384), (1,)),       # stacked mlp w_up, K=embed (fan_in of up-proj)
    ((2, 1, 5, 7), (2,)),       # size-1 kept axis inside the batch prefix
    ((2, 5, 1, 7), (1, 2)),     # size-1 reduced axis rides in the middle block
    ((4, 3, 2, 6), (1, 2)),     # multi-dim contiguous middle K
]


def _old_canon2d_expectation(shape, dims):
    """The pre-batched 2-D planner's decision procedure, restated: the
    degradation oracle for batch-free shapes."""
    dset = {d % len(shape) for d in dims}
    nt_red = [i for i in dset if shape[i] > 1]
    nt_kept = [i for i in range(len(shape)) if i not in dset and shape[i] > 1]
    minor_ok = not nt_red or not nt_kept or max(nt_kept) < min(nt_red)
    major_ok = not nt_red or not nt_kept or max(nt_red) < min(nt_kept)
    red = kept = 1
    for i, s in enumerate(shape):
        if i in dset:
            red *= s
        else:
            kept *= s
    if minor_ok:
        return ("minor", kept, red, False)
    if major_ok:
        return ("major", red, kept, False)
    return ("minor", kept, red, True)


class TestPlannerDegradation:
    @settings(max_examples=len(BATCH_FREE_SPECS), deadline=None)
    @given(i=st.integers(min_value=0, max_value=len(BATCH_FREE_SPECS) - 1))
    def test_batch_free_plans_match_canon2d(self, i):
        shape, dims = BATCH_FREE_SPECS[i]
        cn = canon_nd(shape, dims)
        orientation, rows, cols, transposes = _old_canon2d_expectation(shape, dims)
        assert cn.batch == 1
        assert (cn.orientation, cn.rows, cn.cols, cn.is_transpose) == (
            orientation, rows, cols, transposes)
        assert cn.view == (rows, cols)

    @pytest.mark.parametrize("shape,dims", BATCHED_SPECS)
    def test_batched_plans_are_pure_reshape_major(self, shape, dims):
        cn = canon_nd(shape, dims)
        assert cn.batch > 1 and cn.axis == 0 and cn.reshape_only
        assert cn.batch * cn.rows * cn.cols == int(np.prod(shape))
        red = int(np.prod([shape[d] for d in dims]))
        assert cn.red_size == red == cn.rows
        assert cn.kept_size * red == int(np.prod(shape))

    def test_acceptance_scan_stacked_embeds(self):
        """Acceptance criterion: (layers, embed, heads, hd) reducing embed —
        the full gpt_small wq/wk shape — plans transpose-free."""
        cn = canon_nd((12, 768, 12, 64), (1,))
        assert not cn.is_transpose
        assert (cn.batch, cn.rows, cn.cols) == (12, 768, 768)

    def test_four_block_interleaving_still_transposes(self):
        # K R K R: no contiguous reduced block -> no batch split helps
        cn = canon_nd((2, 3, 4, 5), (1, 3))
        assert cn.is_transpose and cn.batch == 1


class TestBatchedRoundTrip:
    @pytest.mark.parametrize("shape,dims", BATCHED_SPECS)
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_roundtrip_bit_exact(self, shape, dims, dtype):
        x = jax.random.normal(jax.random.PRNGKey(0), shape).astype(dtype)
        cn = canon_nd(shape, dims)
        x2 = canon_apply(x, cn)
        assert x2.shape == cn.view == (cn.batch, cn.rows, cn.cols)
        back = canon_restore(x2, cn, shape)
        assert back.dtype == dtype
        np.testing.assert_array_equal(np.asarray(back, np.float32),
                                      np.asarray(x, np.float32))

    @pytest.mark.parametrize("shape,dims", BATCHED_SPECS)
    def test_reduced_moment_roundtrip(self, shape, dims):
        v_shape = tuple(1 if i in set(dims) else s for i, s in enumerate(shape))
        v = jax.random.normal(jax.random.PRNGKey(1), v_shape)
        cn = canon_nd(shape, dims)
        v2 = canon_apply(v, cn, reduced_cols=True)
        assert v2.shape == (cn.batch, 1, cn.cols)
        np.testing.assert_array_equal(canon_restore(v2, cn, v_shape), v)

    @pytest.mark.parametrize("shape,dims", BATCHED_SPECS)
    def test_canonical_mean_matches_jnp(self, shape, dims):
        x = jnp.arange(np.prod(shape), dtype=jnp.float32).reshape(shape)
        cn = canon_nd(shape, dims)
        np.testing.assert_allclose(
            jnp.mean(canon_apply(x, cn), axis=cn.red_axis).ravel(),
            jnp.mean(x, axis=dims).ravel(), rtol=1e-6)


class TestBatchedKernelParity:
    """slim_*_batched vs the jnp_slim_leaf oracle on scan-stacked specs."""

    @pytest.mark.parametrize("shape,dims", BATCHED_SPECS)
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_precond_batched_vs_jnp_leaf(self, shape, dims, dtype):
        ks = jax.random.split(jax.random.PRNGKey(shape[0]), 3)
        v_shape = tuple(1 if i in set(dims) else s for i, s in enumerate(shape))
        g = (jax.random.normal(ks[0], shape) * 0.1).astype(dtype)
        m = jax.random.normal(ks[1], shape) * 0.01
        v = jnp.abs(jax.random.normal(ks[2], v_shape)) * 1e-3
        kw = dict(b1=0.9, b2=0.95, eps=1e-8, count=3)
        u_ref, m_ref, v_ref = jnp_slim_leaf(g, m, v, dims, use_first_moment=True, **kw)
        cn = canon_nd(shape, dims)
        u2, m2, v2 = slim_precond_batched(
            canon_apply(g, cn), canon_apply(m, cn),
            canon_apply(v, cn, reduced_cols=True), axis=cn.axis, **kw)
        tol = dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 else TOL
        np.testing.assert_allclose(canon_restore(u2, cn, shape), u_ref, **tol)
        np.testing.assert_allclose(canon_restore(m2, cn, shape),
                                   np.asarray(m_ref), **tol)
        np.testing.assert_allclose(canon_restore(v2, cn, v_shape), v_ref, **tol)

    def test_update_batched_matches_unrolled_2d(self):
        """The batched update kernel == the per-batch-slice 2-D major kernel."""
        from repro.kernels.slim_update import slim_update_major

        b, r, c = 3, 37, 130  # non-tile-multiple kept extent (padding path)
        ks = jax.random.split(jax.random.PRNGKey(9), 4)
        p = jax.random.normal(ks[0], (b, r, c))
        g = jax.random.normal(ks[1], (b, r, c)) * 0.1
        m = jax.random.normal(ks[2], (b, r, c)) * 0.01
        v = jnp.abs(jax.random.normal(ks[3], (b, 1, c))) * 1e-3
        kw = dict(lr=1e-3, b1=0.9, b2=0.95, eps=1e-8, wd=0.1, count=5)
        po, mo, vo = slim_update_batched(p, g, m, v, axis=0, **kw)
        for i in range(b):
            pi, mi, vi = slim_update_major(p[i], g[i], m[i], v[i], **kw)
            np.testing.assert_allclose(po[i], pi, **TOL)
            np.testing.assert_allclose(mo[i], mi, **TOL)
            np.testing.assert_allclose(vo[i], vi, **TOL)

    @pytest.mark.slow
    def test_gpt_small_stacked_specs_backend_parity(self):
        """Fused backend == jnp over a tree of the real scan-stacked specs
        (wq/wk reducing embed, stacked mlp fan_in), multi-step."""
        key = jax.random.PRNGKey(0)
        params = {
            "wq": jax.random.normal(key, (3, 96, 3, 32)),
            "wk": jax.random.normal(key, (3, 96, 3, 32)),
            "w_up": jax.random.normal(key, (3, 96, 384)),
        }
        dims = {"wq": (1,), "wk": (1,), "w_up": (1,)}
        for name, d in dims.items():
            plan = leaf_plan(params[name].shape, jnp.float32, d, n_bufs=PRECOND_BUFS)
            assert plan.route == "slim" and plan.cn.batch > 1, name
        tx_j = scale_by_slim_adam(dims)
        tx_f = scale_by_slim_adam(dims, backend="fused")
        sj, sf = tx_j.init(params), tx_f.init(params)
        for i in range(3):
            k = jax.random.PRNGKey(i)
            g = jax.tree.map(lambda x: jax.random.normal(k, x.shape) * 0.1, params)
            uj, sj = jax.jit(tx_j.update)(g, sj)
            uf, sf = jax.jit(tx_f.update)(g, sf)
        for a, b in zip(jax.tree.leaves(uj), jax.tree.leaves(uf)):
            np.testing.assert_allclose(a, b, **TOL)
        for a, b in zip(jax.tree.leaves(sj.nu), jax.tree.leaves(sf.nu)):
            np.testing.assert_allclose(a, b, **TOL)


class TestBatchedSNR:
    @pytest.mark.parametrize("shape,dims", [
        ((3, 96, 3, 32), (1,)),   # stacked wq/wk candidate K
        ((2, 3, 4), (1,)),
        ((4, 3, 2, 6), (1, 2)),
    ])
    def test_snr_backend_parity_batched(self, shape, dims):
        from repro.core.snr import snr_along_dims
        assert canon_nd(shape, dims).batch > 1
        v = jnp.abs(jax.random.normal(jax.random.PRNGKey(7), shape)) + 0.1
        a = float(snr_along_dims(v, dims))
        b = float(snr_along_dims(v, dims, backend="fused"))
        np.testing.assert_allclose(a, b, rtol=1e-4)

    def test_high_snr_near_constant_batched(self):
        """Centered stats must survive the high-SNR regime through the
        batched kernel too."""
        from repro.core.snr import snr_along_dims
        noise = jax.random.normal(jax.random.PRNGKey(8), (4, 64, 16)) * 1e-5
        v = 1.0 + noise
        a = float(snr_along_dims(v, (1,)))
        b = float(snr_along_dims(v, (1,), backend="fused"))
        assert a > 1e8
        np.testing.assert_allclose(a, b, rtol=1e-2)


class TestLeafPlanRouting:
    def test_routes(self):
        assert leaf_plan((), jnp.float32, ()).route == "jnp"          # scalar
        assert leaf_plan((4, 4), jnp.int32, (1,)).route == "jnp"      # non-float
        assert leaf_plan((4, 0), jnp.float32, (1,)).route == "jnp"    # empty
        assert leaf_plan((8, 8), jnp.float32, ()).route == "dense"
        assert leaf_plan((8, 8), jnp.float32, (1,)).route == "slim"
        plan = leaf_plan((3, 96, 3, 32), jnp.float32, (1,))
        assert plan.route == "slim" and plan.cn.batch == 3

    def test_vmem_gate(self):
        # a 16M-wide reduction line can't be strip-tiled: 5 fp32 buffers of
        # one line alone exceed the 8 MiB budget
        assert leaf_plan((2, 1 << 24), jnp.float32, (1,)).route == "jnp"

    def test_transpose_opt_out(self):
        shape, dims = (4, 6, 10), (0, 2)  # genuinely interleaved
        assert leaf_plan(shape, jnp.float32, dims).route == "slim"
        assert leaf_plan(shape, jnp.float32, dims,
                         allow_transpose=False).route == "jnp"
