"""hypothesis, or a deterministic fallback when it isn't installed.

The container may lack hypothesis; ``pytest.importorskip`` would drop whole
modules of coverage, so instead test files import (given, settings, st) from
here. With hypothesis present they are the real thing; otherwise a minimal
shim runs each property test over a small fixed sample grid (min / midpoint /
max per strategy, zip-cycled across strategies) — deterministic, no shrinking,
but the property still executes on boundary and interior points.
"""
import functools
import inspect

# Re-exports: test modules do `from _hyp import given, settings, st`.
__all__ = ["given", "settings", "st", "HAVE_HYPOTHESIS"]

try:
    from hypothesis import given, settings, strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

    class _Strategy:
        def __init__(self, samples):
            self.samples = list(samples)

    class _Strategies:
        @staticmethod
        def integers(min_value=0, max_value=10):
            return _Strategy(dict.fromkeys(
                [min_value, (min_value + max_value) // 2, max_value]))

        @staticmethod
        def floats(min_value=0.0, max_value=1.0, **_):
            return _Strategy(dict.fromkeys(
                [min_value, (min_value + max_value) / 2.0, max_value]))

        @staticmethod
        def sampled_from(elements):
            return _Strategy(elements)

    st = _Strategies()

    def settings(*_args, **_kwargs):
        return lambda f: f

    def given(*arg_strategies, **kw_strategies):
        def decorate(f):
            sig = inspect.signature(f)
            names = list(sig.parameters)
            # hypothesis maps positional strategies onto the *rightmost*
            # parameters; keyword strategies onto their names.
            pos_names = names[len(names) - len(arg_strategies):]
            strategies = dict(zip(pos_names, arg_strategies))
            strategies.update(kw_strategies)

            @functools.wraps(f)
            def wrapper(*args, **kwargs):
                n = max(len(s.samples) for s in strategies.values())
                for i in range(n):
                    drawn = {k: s.samples[i % len(s.samples)]
                             for k, s in strategies.items()}
                    f(*args, **kwargs, **drawn)

            # Hide the drawn parameters from pytest's fixture resolution
            # (inspect.signature honors __signature__ over __wrapped__).
            wrapper.__signature__ = sig.replace(parameters=[
                p for name, p in sig.parameters.items() if name not in strategies
            ])
            return wrapper

        return decorate
