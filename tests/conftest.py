import pytest

# Markers (`slow`, `multidevice`) are registered in pyproject.toml
# [tool.pytest.ini_options] so plain `pytest` runs emit no unknown-marker
# warnings; this hook only implements the multidevice auto-skip.


def _multidevice_possible() -> bool:
    """The multidevice tests spawn a child process on the CPU backend with
    XLA_FLAGS=--xla_force_host_platform_device_count=8 (the pattern in
    tests/test_sharding.py), so they run fine in single-device environments
    — all they need is a CPU jax backend to host the forced devices, or a
    session that already has >= 8 real devices."""
    try:
        import jax

        return jax.device_count() >= 8 or any(
            d.platform == "cpu" for d in jax.devices())
    except Exception:
        return False


def pytest_collection_modifyitems(config, items):
    if _multidevice_possible():
        return
    skip = pytest.mark.skip(
        reason="needs >= 8 devices or a CPU backend to host the forced-"
               "host-device child process")
    for item in items:
        if "multidevice" in item.keywords:
            item.add_marker(skip)
