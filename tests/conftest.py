import pytest


def pytest_configure(config):
    config.addinivalue_line(
        "markers",
        "slow: slowest cases (multi-device subprocess tests, long trainer "
        "loops); deselect with -m 'not slow' for a quick local loop — CI "
        "always runs the full suite, parallelized via pytest-xdist")
