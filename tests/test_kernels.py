"""Per-kernel allclose vs pure-jnp oracles, swept over shapes and dtypes
(interpret=True executes the Pallas kernel body on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hyp import given, settings, st  # hypothesis, or deterministic fallback

from repro.core.snr import snr_along_dims
from repro.kernels import fused_adam_op, slim_update_op, snr_op
from repro.kernels.ref import adam_update_ref, slim_update_ref, snr_stats_ref
from repro.kernels.snr_stats import snr_stats

SHAPES = [(16, 128), (128, 256), (100, 300), (257, 129), (8, 1024)]
DTYPES = [jnp.float32, jnp.bfloat16]
KW = dict(lr=1e-3, b1=0.9, b2=0.95, eps=1e-8, wd=0.1, count=3)


def _operands(shape, dtype, seed=0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 4)
    p = jax.random.normal(ks[0], shape).astype(dtype)
    g = (jax.random.normal(ks[1], shape) * 0.1).astype(dtype)
    m = jax.random.normal(ks[2], shape) * 0.01
    v = jnp.abs(jax.random.normal(ks[3], shape)) * 1e-3
    return p, g, m, v


def _tol(dtype):
    return 2e-2 if dtype == jnp.bfloat16 else 1e-5


@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("dtype", DTYPES)
def test_fused_adam_allclose(shape, dtype):
    p, g, m, v = _operands(shape, dtype)
    out_k = fused_adam_op(p, g, m, v, **KW)
    out_r = adam_update_ref(p, g, m, v, **KW)
    for a, b, name in zip(out_k, out_r, ("p", "m", "v")):
        np.testing.assert_allclose(np.asarray(a, np.float32), np.asarray(b, np.float32),
                                   atol=_tol(dtype), rtol=_tol(dtype), err_msg=name)


@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("dtype", DTYPES)
@pytest.mark.parametrize("axis", [0, 1])
def test_slim_update_allclose(shape, dtype, axis):
    p, g, m, v = _operands(shape, dtype)
    v_red = jnp.mean(v, axis=axis, keepdims=True)
    out_k = slim_update_op(p, g, m, v_red, axis=axis, **KW)
    if axis == 1:
        out_r = slim_update_ref(p, g, m, v_red, **KW)
    else:
        out_r = tuple(t.T for t in slim_update_ref(p.T, g.T, m.T, v_red.T, **KW))
    for a, b, name in zip(out_k, out_r, ("p", "m", "v")):
        np.testing.assert_allclose(np.asarray(a, np.float32), np.asarray(b, np.float32),
                                   atol=_tol(dtype), rtol=_tol(dtype), err_msg=name)


def test_kernel_matches_optimizer_path():
    """The fused SlimAdam kernel reproduces repro.core.slim_adam exactly."""
    from repro.core.slim_adam import scale_by_slim_adam
    p, g, m, v = _operands((64, 96), jnp.float32)
    tx = scale_by_slim_adam({"w": (1,)}, b1=0.9, b2=0.95, eps=1e-8)
    state = tx.init({"w": p})
    u, state = tx.update({"w": g}, state, {"w": p})
    p_opt = p + (-1e-3) * u["w"]  # lr without wd
    pk, mk, vk = slim_update_op(p, g, jnp.zeros_like(p), jnp.zeros((64, 1)),
                                axis=1, lr=1e-3, b1=0.9, b2=0.95, eps=1e-8, wd=0.0, count=1)
    np.testing.assert_allclose(pk, p_opt, rtol=1e-5, atol=1e-7)
    np.testing.assert_allclose(vk, state.nu["w"], rtol=1e-6)


@pytest.mark.parametrize("shape", SHAPES)
def test_snr_stats_allclose(shape):
    v = jnp.abs(jax.random.normal(jax.random.PRNGKey(3), shape)) + 0.1
    s1, s2 = snr_stats(v)
    r1, r2 = snr_stats_ref(v)
    np.testing.assert_allclose(s1, r1, rtol=1e-5)
    np.testing.assert_allclose(s2, r2, rtol=1e-5)
    snr_k = float(snr_op(v))
    snr_ref = float(snr_along_dims(v, (1,)))
    np.testing.assert_allclose(snr_k, snr_ref, rtol=1e-4)


@settings(max_examples=15, deadline=None)
@given(r=st.integers(min_value=1, max_value=96), c=st.integers(min_value=1, max_value=200),
       count=st.integers(min_value=1, max_value=100))
def test_fused_adam_property(r, c, count):
    """Arbitrary shapes (incl. non-tile-multiples) and step counts."""
    p, g, m, v = _operands((r, c), jnp.float32, seed=r * 1000 + c)
    kw = dict(KW, count=count)
    out_k = fused_adam_op(p, g, m, v, **kw)
    out_r = adam_update_ref(p, g, m, v, **kw)
    for a, b in zip(out_k, out_r):
        np.testing.assert_allclose(a, b, atol=1e-5, rtol=1e-5)


@pytest.mark.parametrize("shape", [(2, 24, 8, 4), (1, 64, 16, 16), (2, 32, 10, 3)])
def test_ssm_scan_kernel_allclose(shape):
    """Pallas selective-scan kernel vs the jnp chunked-scan oracle."""
    from repro.kernels.ssm_scan import ssm_scan
    from repro.models.ssm import selective_scan

    B, S, D, N = shape
    ks = jax.random.split(jax.random.PRNGKey(B * S), 7)
    x = jax.random.normal(ks[0], (B, S, D))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, D)))
    a = -jnp.exp(jax.random.normal(ks[2], (D, N)) * 0.3)
    b_t = jax.random.normal(ks[3], (B, S, N))
    c_t = jax.random.normal(ks[4], (B, S, N))
    d_skip = jax.random.normal(ks[5], (D,))
    h0 = jax.random.normal(ks[6], (B, D, N))
    y_ref, h_ref = selective_scan(x, dt, a, b_t, c_t, d_skip, h0, 8)
    y_k, h_k = ssm_scan(x, dt, a, b_t, c_t, d_skip, h0, chunk=8, d_tile=4)
    np.testing.assert_allclose(y_k, y_ref, atol=2e-4, rtol=2e-4)
    np.testing.assert_allclose(h_k, h_ref, atol=2e-4, rtol=2e-4)
