"""Per-architecture smoke tests (assignment deliverable f): every assigned
arch instantiates a reduced config of the same family and runs one forward
AND one SlimAdam train step on CPU — shapes asserted, no NaNs."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_reduced
from repro.core import rules_as_tree, table3_rules, validate_meta
from repro.core.slim_adam import slim_adam
from repro.models import forward, init_decode_cache, decode_step
from repro.train.step import make_train_step

B, S = 2, 16


def _batch(cfg, key):
    batch = {}
    if cfg.embed_inputs:
        batch["tokens"] = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
        batch["labels"] = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
        if cfg.extra_embed_len:
            batch["frontend_embeds"] = jax.random.normal(key, (B, cfg.extra_embed_len, cfg.d_model))
    elif cfg.input_proj_dim:
        batch["patches"] = jax.random.normal(key, (B, S, cfg.input_proj_dim))
        batch["labels"] = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    else:
        batch["frontend_embeds"] = jax.random.normal(key, (B, S, cfg.d_model))
        batch["labels"] = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    return batch


@pytest.mark.slow
@pytest.mark.parametrize("arch", ARCH_IDS)
def test_forward_shapes_no_nans(arch):
    cfg = get_reduced(arch)
    params, meta = cfg.init(jax.random.PRNGKey(0))
    validate_meta(params, meta)
    batch = _batch(cfg, jax.random.PRNGKey(1))
    logits, aux = jax.jit(lambda p, b: forward(cfg, p, b))(params, batch)
    expect_s = S + (cfg.extra_embed_len if cfg.embed_inputs else 0)
    assert logits.shape == (B, expect_s, cfg.vocab_size)
    assert not bool(jnp.any(jnp.isnan(logits)))
    assert not bool(jnp.isnan(aux))
    if cfg.n_experts:
        assert float(aux) > 0.0  # MoE aux losses flow


@pytest.mark.slow
@pytest.mark.parametrize("arch", ARCH_IDS)
def test_slim_train_step(arch):
    cfg = get_reduced(arch)
    params, meta = cfg.init(jax.random.PRNGKey(0))
    rules = table3_rules(meta)
    dims = rules_as_tree(rules, params, meta)
    tx = slim_adam(1e-3, dims)
    step = jax.jit(make_train_step(cfg, tx))
    opt = tx.init(params)
    batch = _batch(cfg, jax.random.PRNGKey(2))
    new_params, new_opt, metrics = step(params, opt, batch)
    assert np.isfinite(float(metrics["loss"]))
    assert np.isfinite(float(metrics["grad_norm"]))
    # parameters actually moved
    moved = any(
        float(jnp.max(jnp.abs(a - b))) > 0
        for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(new_params))
    )
    assert moved
    # loss decreases over a few steps on repeated data (sanity of the whole stack)
    p, o = new_params, new_opt
    first = float(metrics["loss"])
    for _ in range(5):
        p, o, metrics = step(p, o, batch)
    assert float(metrics["loss"]) < first


DECODE_ARCHS = [a for a in ARCH_IDS if get_reduced(a).causal and get_reduced(a).embed_inputs
                and not get_reduced(a).extra_embed_len]


@pytest.mark.parametrize("arch", DECODE_ARCHS)
def test_decode_matches_forward(arch):
    """Step-by-step decode with KV/SSM caches reproduces the parallel forward."""
    cfg = get_reduced(arch)
    params, _ = cfg.init(jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, 12), 0, cfg.vocab_size)
    full_logits, _ = forward(cfg, params, {"tokens": toks})
    cache = init_decode_cache(cfg, B, 32, dtype=jnp.float32)
    dec = jax.jit(lambda p, c, t: decode_step(cfg, p, c, t))
    outs = []
    for i in range(12):
        lg, cache = dec(params, cache, toks[:, i:i + 1])
        outs.append(lg)
    err = float(jnp.max(jnp.abs(jnp.concatenate(outs, 1) - full_logits)))
    assert err < 5e-3, f"{arch}: decode diverges from forward by {err}"


def test_int8_kv_cache_decode():
    """int8-quantized KV cache decode stays within 5% of full precision and
    preserves argmax (the qwen1.5-32b decode_32k capacity fix)."""
    import dataclasses

    cfg = get_reduced("qwen15_32b")
    cfgq = dataclasses.replace(cfg, kv_quant=True)
    params, _ = cfg.init(jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 12), 0, cfg.vocab_size)
    full_logits, _ = forward(cfg, params, {"tokens": toks})
    cache = init_decode_cache(cfgq, 2, 32, dtype=jnp.float32)
    dec = jax.jit(lambda p, c, t: decode_step(cfgq, p, c, t))
    outs = []
    for i in range(12):
        lg, cache = dec(params, cache, toks[:, i:i + 1])
        outs.append(lg)
    dec_logits = jnp.concatenate(outs, 1)
    rel = float(jnp.max(jnp.abs(dec_logits - full_logits))) / float(jnp.max(jnp.abs(full_logits)))
    agree = float(jnp.mean(jnp.argmax(dec_logits, -1) == jnp.argmax(full_logits, -1)))
    assert rel < 0.05 and agree > 0.95


@pytest.mark.slow
def test_resnet_smoke():
    """Paper §3.1.3 regime: reduced ResNet forward + SlimAdam step on CPU."""
    from repro.models.resnet import ResNetConfig, forward as resnet_forward, synthetic_cifar
    from repro.core import validate_meta as _vm
    from repro.train.loss import cross_entropy
    from repro.optim import apply_updates

    cfg = ResNetConfig(stages=(1, 1), width=8, classes=10)
    params, meta = cfg.init(jax.random.PRNGKey(0))
    _vm(params, meta)
    batch = synthetic_cifar(jax.random.PRNGKey(1), 4, 10, size=8)
    logits, _ = jax.jit(lambda p, b: resnet_forward(cfg, p, b))(params, batch)
    assert logits.shape == (4, 10)
    assert not bool(jnp.any(jnp.isnan(logits)))

    rules = table3_rules(meta)
    tx = slim_adam(1e-3, rules_as_tree(rules, params, meta))
    state = tx.init(params)

    def loss_fn(p):
        lg, _ = resnet_forward(cfg, p, batch)
        return cross_entropy(lg[:, None, :], batch["labels"][:, None])

    l0 = float(loss_fn(params))
    step = jax.jit(lambda p, s: (lambda u_s: (apply_updates(p, u_s[0]), u_s[1]))(
        tx.update(jax.grad(loss_fn)(p), s, p)))
    for _ in range(8):
        params, state = step(params, state)
    assert float(loss_fn(params)) < l0
