"""Shard-aware fused backend: regime planning (in-process, device-free) and
shard_map-vs-single-device parity on an 8-host-device mesh (subprocess, the
pattern from tests/test_sharding.py)."""
import json
import subprocess
import sys
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.sharding.shardspec import (
    SpecMesh,
    dim_shards,
    even_spec,
    local_shape,
    masked_spec,
    mesh_is_trivial,
    normalize_spec_leaves,
    owning_axes,
    plan_sharded_leaf,
    regime_counts,
)

MESH = SpecMesh({"data": 4, "model": 2})


class TestShardGeometry:
    def test_dim_shards_and_local_shape(self):
        assert dim_shards((8, 16), P("data", "model"), MESH) == (4, 2)
        assert local_shape((8, 16), P("data", "model"), MESH) == (2, 8)

    def test_non_dividing_dim_replicates(self):
        # 6 % 4 != 0 -> defensive replication, and even_spec drops the entry
        assert dim_shards((6, 16), P("data", "model"), MESH) == (1, 2)
        assert even_spec((6, 16), P("data", "model"), MESH) == P(None, "model")

    def test_short_spec_pads(self):
        assert dim_shards((8, 16, 4), P("data"), MESH) == (4, 1, 1)

    def test_masked_spec_drops_reduced_entries(self):
        # fan_in-compressed moment of a TP-sharded matrix loses its TP axis
        assert masked_spec((8, 16), P("data", "model"), MESH, (1,)) == P("data", None)

    def test_owning_axes(self):
        assert owning_axes((8, 16), P("data", "model"), MESH, (1,)) == ("model",)
        assert owning_axes((8, 16), P("data", "model"), MESH, (0,)) == ("data",)
        assert owning_axes((8, 16), P(None, "model"), MESH, (0,)) == ()

    def test_trivial_mesh(self):
        assert mesh_is_trivial(SpecMesh({"data": 1, "model": 1}))
        assert not mesh_is_trivial(MESH)


class TestRegimePlans:
    def test_local_when_reduced_unsharded(self):
        pl = plan_sharded_leaf((8, 16), jnp.float32, (1,), P("data", None), MESH, n_bufs=5)
        assert pl.regime == "local" and pl.psum_axes == ()

    def test_psum_when_reduced_sharded(self):
        pl = plan_sharded_leaf((8, 16), jnp.float32, (1,), P("data", "model"), MESH, n_bufs=5)
        assert pl.regime == "psum"
        assert pl.psum_axes == ("model",) and pl.red_total == 16
        assert pl.red_spec == P("data", None)

    def test_jnp_for_interleaved_k(self):
        # reduced {0, 2} with kept {1, 3}: no contiguous reduced block
        pl = plan_sharded_leaf((4, 6, 8, 10), jnp.float32, (0, 2), P(), MESH, n_bufs=5)
        assert pl.regime == "jnp"

    def test_dense_always_local(self):
        pl = plan_sharded_leaf((8, 16), jnp.float32, (), P("data", "model"), MESH, n_bufs=5)
        assert pl.regime == "local"

    def test_regime_counts(self):
        plans = [
            plan_sharded_leaf((8, 16), jnp.float32, (1,), P("data", None), MESH, n_bufs=5),
            plan_sharded_leaf((8, 16), jnp.float32, (1,), P(None, "model"), MESH, n_bufs=5),
            plan_sharded_leaf((4, 6, 8, 10), jnp.float32, (0, 2), P(), MESH, n_bufs=5),
        ]
        assert regime_counts(plans) == {"local": 1, "psum": 1, "psum_jnp": 0,
                                        "degraded": 0,
                                        "jnp": 1}

    def test_normalize_spec_leaves_validates_structure(self):
        treedef = jax.tree_util.tree_structure({"a": 0, "b": 0, "c": 0})
        with pytest.raises(ValueError, match="does not mirror"):
            normalize_spec_leaves({"a": P(), "b": P()}, treedef, "test")
        # same leaf count but different structure must also be rejected
        with pytest.raises(ValueError, match="does not mirror"):
            normalize_spec_leaves({"a": P(), "b": P(), "z": P()}, treedef, "test")

    def test_normalize_spec_leaves_accepts_none_entries(self):
        # None = replicated, the standard pjit idiom
        treedef = jax.tree_util.tree_structure({"a": 0, "b": 0})
        leaves = normalize_spec_leaves({"a": P("data"), "b": None}, treedef, "test")
        assert leaves == [P("data"), None]
        # pre-flattened leaf-aligned list passes through
        assert normalize_spec_leaves([P("data"), None], treedef, "t") == [P("data"), None]

    def test_half_specified_pair_warns_and_runs_unsharded(self):
        from repro.sharding.shardspec import sharded_pair

        with pytest.warns(UserWarning, match="UNSHARDED"):
            mesh, specs = sharded_pair(MESH, None, "test")
        assert mesh is None and specs is None
        assert sharded_pair(None, None, "test") == (None, None)


class TestRebaseCenteredStats:
    def test_matches_common_shift_recompute(self):
        """Per-shard sums with local shifts, rebased to a common shift, must
        equal the sums computed directly under that shift."""
        from repro.kernels.ref import rebase_centered_stats

        rng = np.random.default_rng(0)
        line = 1.0 + 1e-5 * rng.standard_normal(32).astype(np.float64)
        shift = np.float64(line.mean())
        for lo, hi in ((0, 16), (16, 32)):
            seg = line[lo:hi]
            first = seg[0]
            s1c = np.sum(seg - first)
            s2c = np.sum((seg - first) ** 2)
            s1c_r, s2c_r = rebase_centered_stats(s1c, s2c, first, shift, len(seg))
            np.testing.assert_allclose(s1c_r, np.sum(seg - shift), rtol=1e-12)
            np.testing.assert_allclose(s2c_r, np.sum((seg - shift) ** 2), rtol=1e-12)


class TestOptStateSpecsValidation:
    def test_mismatched_state_raises_clear_error(self):
        from repro.core.slim_adam import scale_by_slim_adam
        from repro.sharding.state_shardings import opt_state_specs

        params = {"w": jax.ShapeDtypeStruct((8, 16), jnp.float32)}
        state = jax.eval_shape(scale_by_slim_adam({"w": (1,)}).init, params)
        other = {"w": jax.ShapeDtypeStruct((8, 16), jnp.float32),
                 "b": jax.ShapeDtypeStruct((16,), jnp.float32)}
        with pytest.raises(ValueError, match="does not mirror the parameter tree"):
            opt_state_specs(state, other, {"w": P(), "b": P()})

    def test_mismatched_spec_tree_raises(self):
        from repro.optim.adam import scale_by_adam
        from repro.sharding.state_shardings import opt_state_specs

        params = {"w": jax.ShapeDtypeStruct((8, 16), jnp.float32)}
        state = jax.eval_shape(scale_by_adam().init, params)
        with pytest.raises(ValueError, match="param_spec_tree"):
            opt_state_specs(state, params, {"w": P(), "extra": P()})


PARITY_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import jax, jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.core.slim_adam import scale_by_slim_adam
from repro.core.snr import snr_along_dims
from repro.optim.adam import scale_by_adam
from repro.optim import fused as F
from repro.sharding.shardspec import regime_counts

mesh = jax.make_mesh((4, 2), ("data", "model"))
key = jax.random.PRNGKey(0)
params = {
    "fanin": jax.random.normal(key, (32, 16)),        # K=(1,), kept dim sharded -> local kernel
    "psum":  jax.random.normal(key, (16, 32)),        # K=(1,), reduced dim sharded -> psum
    "inter": jax.random.normal(key, (4, 6, 8, 10)),   # K=(0,2) interleaved -> jnp fallback
    "dense": jax.random.normal(key, (24, 16)),        # K=() dense kernel
    "vec":   jnp.linspace(-1.0, 1.0, 64),             # small leaf (bucket path)
}
dims  = {"fanin": (1,), "psum": (1,), "inter": (0, 2), "dense": (), "vec": ()}
specs = {"fanin": P("data", None), "psum": P(None, "model"), "inter": P(),
         "dense": P("data", "model"), "vec": P("data")}
grads = jax.tree.map(
    lambda p: 0.1 * jax.random.normal(jax.random.PRNGKey(p.size % 13), p.shape), params)

out = {}

# regime report
gl, td = jax.tree_util.tree_flatten(params)
plans = F.sharded_tree_plans(gl, [tuple(d) for d in td.flatten_up_to(dims)],
                             td.flatten_up_to(specs), mesh)
out["regimes"] = regime_counts(plans)

def leaf_errs(u1, u2):
    return {k: {"exact": bool(np.array_equal(np.asarray(u1[k]), np.asarray(u2[k]))),
                "err": float(np.max(np.abs(np.asarray(u1[k]) - np.asarray(u2[k]))))}
            for k in u1}

# SlimAdam: single-device fused vs sharded fused, 2 steps
tx1 = scale_by_slim_adam(dims, backend="fused")
tx2 = scale_by_slim_adam(dims, backend="fused", mesh=mesh, param_specs=specs)
s1, s2 = tx1.init(params), tx2.init(params)
for _ in range(2):
    u1, s1 = jax.jit(tx1.update)(grads, s1)
    u2, s2 = jax.jit(tx2.update)(grads, s2)
out["slim_u"] = leaf_errs(u1, u2)
out["slim_nu"] = leaf_errs(s1.nu, s2.nu)

# dense Adam tree: elementwise -> bit-exact under sharding
ta1 = scale_by_adam(backend="fused")
ta2 = scale_by_adam(backend="fused", mesh=mesh, param_specs=specs)
a1, a2 = ta1.init(params), ta2.init(params)
ua1, a1 = jax.jit(ta1.update)(grads, a1)
ua2, a2 = jax.jit(ta2.update)(grads, a2)
out["adam_u"] = leaf_errs(ua1, ua2)

# SNR: sharded vs single device, both backends, incl. a psum leaf in the
# near-constant high-SNR regime the centered kernels exist for
snr = {}
v_hi = (1.0 + 1e-4 * jax.random.normal(key, (16, 32))) ** 2   # SNR >> 1
cases = {"fanin": (params["fanin"] ** 2, (1,)), "psum": (params["psum"] ** 2, (1,)),
         "psum_hi": (v_hi, (1,)), "inter": (params["inter"] ** 2, (0, 2))}
for name, (v, d) in cases.items():
    spec = specs.get(name, specs["psum"] if name == "psum_hi" else P())
    sharded_v = jax.device_put(v, NamedSharding(mesh, spec))
    for be in ("jnp", "fused"):
        a = float(snr_along_dims(v, d, backend=be))
        b = float(snr_along_dims(sharded_v, d, backend=be, mesh=mesh, spec=spec))
        snr[f"{name}_{be}"] = {"single": a, "sharded": b,
                               "rel": abs(a - b) / max(abs(a), 1e-30)}
out["snr"] = snr
print(json.dumps(out))
"""


@pytest.mark.slow
@pytest.mark.multidevice
def test_sharded_fused_parity(tmp_path):
    """shard_map fused SlimAdam/Adam + SNR == single-device fused path:
    bit-exact for local-regime leaves, <= 1e-6 for psum and jnp-fallback
    leaves (fp32 reassociation across the shard boundary)."""
    script = tmp_path / "sharded_parity.py"
    script.write_text(PARITY_SCRIPT)
    src = str(Path(__file__).resolve().parents[1] / "src")
    proc = subprocess.run([sys.executable, str(script)], capture_output=True, text=True,
                          env={**__import__("os").environ, "PYTHONPATH": src}, timeout=900)
    assert proc.returncode == 0, proc.stderr[-3000:]
    out = json.loads(proc.stdout.strip().splitlines()[-1])

    # fanin + dense + vec run the unchanged kernels on local shards; psum and
    # interleaved-K leaves take the cross-shard / per-shard jnp paths.
    assert out["regimes"] == {"local": 3, "psum": 1, "psum_jnp": 0,
                              "jnp": 1, "degraded": 0}, out["regimes"]

    for group in ("slim_u", "slim_nu", "adam_u"):
        for leaf, r in out[group].items():
            tol = 0.0 if group == "adam_u" or leaf in ("fanin", "dense", "vec") else 1e-6
            assert r["err"] <= tol, (group, leaf, r)

    for case, r in out["snr"].items():
        assert r["rel"] <= 1e-6, (case, r)
