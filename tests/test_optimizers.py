"""Optimizer semantics: SlimAdam family equivalences + baselines sanity."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hyp import given, settings, st  # hypothesis, or deterministic fallback

from repro.core import ParamMeta, rules_as_tree, second_moment_elements, table3_rules
from repro.core.baselines import (
    adafactor,
    adalayer_rules,
    adam_mini_v2_rules,
    lion,
    sm3,
)
from repro.core.slim_adam import scale_by_slim_adam, slim_adam
from repro.optim import adamw, apply_updates, global_norm, multi_steps, scale_by_adam, sgdm
from repro.optim.schedules import warmup_cosine


def _toy():
    key = jax.random.PRNGKey(0)
    params = {
        "w": jax.random.normal(key, (12, 8)),
        "e": jax.random.normal(key, (32, 12)),
        "n": jnp.ones((12,)),
    }
    meta = {
        "w": ParamMeta(axes=("embed", "mlp"), role="mlp_up", fan_in=("embed",), fan_out=("mlp",)),
        "e": ParamMeta(axes=("vocab", "embed"), role="token_embedding",
                       fan_in=("vocab",), fan_out=("embed",)),
        "n": ParamMeta(axes=("embed",), role="norm"),
    }
    def grad_fn(p, seed=1):
        k = jax.random.PRNGKey(seed)
        return jax.tree.map(lambda x: jax.random.normal(k, x.shape) * 0.1, p)
    return params, meta, grad_fn


class TestSlimEqualsAdam:
    """K = () for every tensor must reproduce Adam bit-for-bit (paper §2)."""

    @settings(max_examples=10, deadline=None)
    @given(st.integers(min_value=1, max_value=5))
    def test_trajectory_equivalence(self, n_steps):
        params, meta, grad_fn = _toy()
        dims = jax.tree.map(lambda p: (), params)
        tx_slim = slim_adam(1e-3, dims, weight_decay=0.1)
        tx_adam = adamw(1e-3, weight_decay=0.1)
        s1, s2 = tx_slim.init(params), tx_adam.init(params)
        p1 = p2 = params
        for i in range(n_steps):
            g1, g2 = grad_fn(p1, i), grad_fn(p2, i)
            u1, s1 = tx_slim.update(g1, s1, p1)
            u2, s2 = tx_adam.update(g2, s2, p2)
            p1, p2 = apply_updates(p1, u1), apply_updates(p2, u2)
        for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)):
            np.testing.assert_allclose(a, b, rtol=1e-6, atol=1e-7)

    def test_constant_along_k_exact(self):
        """If g^2 is constant along K, compression is lossless: SlimAdam with
        K equals Adam exactly — the paper's core premise."""
        params = {"w": jnp.zeros((4, 6))}
        g = {"w": jnp.broadcast_to(jnp.arange(1.0, 5.0)[:, None], (4, 6))}  # const along axis 1
        tx_slim = slim_adam(1e-2, {"w": (1,)}, weight_decay=0.0)
        tx_adam = adamw(1e-2, weight_decay=0.0)
        s1, s2 = tx_slim.init(params), tx_adam.init(params)
        p1 = p2 = params
        for _ in range(3):
            u1, s1 = tx_slim.update(g, s1, p1)
            u2, s2 = tx_adam.update(g, s2, p2)
            p1, p2 = apply_updates(p1, u1), apply_updates(p2, u2)
        np.testing.assert_allclose(p1["w"], p2["w"], rtol=1e-6)

    def test_state_is_reduced(self):
        params, meta, _ = _toy()
        rules = table3_rules(meta)
        dims = rules_as_tree(rules, params, meta)
        tx = scale_by_slim_adam(dims)
        state = tx.init(params)
        assert state.nu["w"].shape == (12, 1)   # mlp_up: fan_out ('mlp') reduced
        assert state.nu["e"].shape == (32, 1)   # embedding dim reduced, vocab kept
        assert state.nu["n"].shape == (12,)     # vector-like untouched
        stored = second_moment_elements(params, dims)
        assert stored == 12 + 32 + 12

    def test_adalayer_is_full_reduction(self):
        params, meta, _ = _toy()
        dims = rules_as_tree(adalayer_rules(meta), params, meta)
        tx = scale_by_slim_adam(dims)
        state = tx.init(params)
        assert state.nu["w"].shape == (1, 1)
        assert state.nu["n"].shape == (1,)

    def test_adam_mini_v2_shapes(self):
        params, meta, _ = _toy()
        dims = rules_as_tree(adam_mini_v2_rules(meta), params, meta)
        tx = scale_by_slim_adam(dims)
        state = tx.init(params)
        assert state.nu["w"].shape == (1, 8)    # one moment per output neuron
        assert state.nu["e"].shape == (32, 1)   # one per token
        assert state.nu["n"].shape == (1,)      # norms compressed


class TestTransformations:
    def test_clip_by_global_norm(self):
        from repro.optim import clip_by_global_norm
        tx = clip_by_global_norm(1.0)
        g = {"a": jnp.full((4,), 10.0)}
        u, _ = tx.update(g, tx.init(g), g)
        np.testing.assert_allclose(float(global_norm(u)), 1.0, rtol=1e-5)
        small = {"a": jnp.full((4,), 0.01)}
        u2, _ = tx.update(small, tx.init(small), small)
        np.testing.assert_allclose(u2["a"], small["a"])  # never amplifies

    def test_warmup_cosine_schedule(self):
        sched = warmup_cosine(peak=1.0, warmup_steps=10, total_steps=110)
        assert float(sched(jnp.asarray(0))) == 0.0
        np.testing.assert_allclose(float(sched(jnp.asarray(10))), 1.0, rtol=1e-5)
        np.testing.assert_allclose(float(sched(jnp.asarray(110))), 0.1, rtol=1e-4)
        assert float(sched(jnp.asarray(5))) == pytest.approx(0.5)

    def test_multi_steps_matches_big_batch(self):
        """k accumulation micro-steps == one step on the averaged gradient."""
        params = {"w": jnp.ones((4, 4))}
        inner = adamw(1e-2, weight_decay=0.0)
        acc = multi_steps(inner, every_k=4)
        gs = [jax.tree.map(lambda p: jax.random.normal(jax.random.PRNGKey(i), p.shape), params)
              for i in range(4)]
        s = acc.init(params)
        p1 = params
        for g in gs:
            u, s = acc.update(g, s, p1)
            p1 = apply_updates(p1, u)
        g_mean = jax.tree.map(lambda *x: sum(x) / 4, *gs)
        s2 = inner.init(params)
        u2, s2 = inner.update(g_mean, s2, params)
        p2 = apply_updates(params, u2)
        np.testing.assert_allclose(p1["w"], p2["w"], rtol=1e-6)

    def test_bias_correction_first_step(self):
        """After one step from zero state, update == g/|g| elementwise (+eps)."""
        params = {"w": jnp.zeros((3,))}
        tx = scale_by_adam(b1=0.9, b2=0.999, eps=0.0)
        g = {"w": jnp.array([1.0, -2.0, 0.5])}
        u, _ = tx.update(g, tx.init(params), params)
        np.testing.assert_allclose(u["w"], jnp.sign(g["w"]), rtol=1e-5)


class TestBaselines:
    @pytest.mark.parametrize("maker", [
        lambda: adafactor(3e-2), lambda: adafactor(3e-2, momentum=0.9),
        lambda: sm3(3e-2), lambda: lion(3e-2), lambda: sgdm(3e-2),
    ])
    def test_runs_and_descends_quadratic(self, maker):
        """Every baseline optimizes a convex quadratic."""
        tx = maker()
        p = {"w": jnp.array([3.0, -2.0, 1.5, 4.0])}
        s = tx.init(p)
        loss0 = float(jnp.sum(p["w"] ** 2))
        for _ in range(200):
            g = jax.tree.map(lambda x: 2 * x, p)
            u, s = tx.update(g, s, p)
            p = apply_updates(p, u)
        assert float(jnp.sum(p["w"] ** 2)) < loss0 * 0.5

    def test_adafactor_factored_state_is_sublinear(self):
        p = {"w": jnp.ones((64, 32))}
        tx = adafactor(1e-3)
        s = tx.init(p)
        inner = s.inner_states[1]  # (clip, core, lr)
        assert inner.vr["w"].shape == (64,)
        assert inner.vc["w"].shape == (32,)

    def test_sm3_state_is_per_axis(self):
        p = {"w": jnp.ones((8, 6))}
        tx = sm3(1e-3)
        s = tx.init(p)
        accs = s.inner_states[1].accs["w"]
        assert accs[0].shape == (8, 1) and accs[1].shape == (1, 6)
