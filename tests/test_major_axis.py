"""Major-axis (sublane-reduction) kernel parity, mirroring
tests/test_fused_backend.py: the transpose-free path for leaves whose
compression dims are *leading* must agree with the jnp path to 1e-5 across
every compression spec, including leaves where only the major orientation is
reshape-reachable."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.slim_adam import scale_by_slim_adam
from repro.kernels import canon2d, canon_apply, canon_restore
from repro.kernels.ops import slim_precond_major, slim_update_major
from repro.kernels.ref import slim_update_ref
from repro.kernels.slim_update import slim_precond

TOL = dict(rtol=1e-5, atol=1e-6)


def _tree_allclose(a, b, **tol):
    tol = tol or TOL
    for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_allclose(np.asarray(x, np.float32), np.asarray(y, np.float32), **tol)


def _grads(params, i):
    k = jax.random.PRNGKey(i)
    return jax.tree.map(lambda x: jax.random.normal(k, x.shape).astype(x.dtype) * 0.1, params)


class TestCanon2DOrientation:
    """The planner must emit a reshape-only plan whenever one exists."""

    @pytest.mark.parametrize("shape,dims,orientation", [
        ((12, 8), (1,), "minor"),          # fan_in: reduced trailing
        ((12, 8), (0,), "major"),          # fan_out: reduced leading
        ((257, 129), (0,), "major"),
        ((3, 3, 8, 16), (0, 1, 2), "major"),   # conv fan_in: leading multi-dim K
        ((2, 3, 4), (1, 2), "minor"),
        ((37,), (0,), "minor"),            # fully reduced 1-D: minor wins
        ((12, 8), (0, 1), "minor"),        # AdaLayer: kept empty, minor wins
        ((1, 6, 10), (0, 2), "minor"),     # size-1 axes never force a transpose
        ((6, 1, 10), (0, 1), "major"),
    ])
    def test_reshape_only_plans(self, shape, dims, orientation):
        cn = canon2d(shape, dims)
        assert not cn.is_transpose
        assert cn.orientation == orientation

    @pytest.mark.parametrize("shape,dims", [
        ((4, 6, 10), (0, 2)),   # interleaved multi-dim K (kept dim inside the red span)
        ((2, 3, 4, 5), (1, 3)),
    ])
    def test_interleaved_k_still_transposes(self, shape, dims):
        cn = canon2d(shape, dims)
        assert cn.is_transpose
        assert cn.orientation == "minor"   # canonical fallback

    @pytest.mark.parametrize("shape,dims,batch", [
        ((2, 3, 4), (1,), 2),            # middle dim reduced -> batched major
        ((3, 96, 3, 32), (1,), 3),       # scan-stacked wq/wk reducing embed
        ((2, 1, 5, 7), (2,), 2),         # size-1 kept axis inside the prefix
    ])
    def test_middle_k_plans_batched_major(self, shape, dims, batch):
        """A kept-prefix / reduced-block / kept-suffix pattern splits the
        prefix off as a batch axis instead of transposing."""
        cn = canon2d(shape, dims)
        assert not cn.is_transpose
        assert cn.orientation == "major" and cn.batch == batch

    @pytest.mark.parametrize("shape,dims", [
        ((12, 8), (0,)), ((3, 3, 8, 16), (0, 1, 2)), ((6, 1, 10), (0, 1)),
        ((4, 6, 10), (0, 2)), ((2, 3, 4), (1,)),
    ])
    def test_roundtrip_and_reduction_axis(self, shape, dims):
        x = jnp.arange(np.prod(shape), dtype=jnp.float32).reshape(shape)
        cn = canon2d(shape, dims)
        x2 = canon_apply(x, cn)
        assert x2.shape == cn.view
        np.testing.assert_array_equal(canon_restore(x2, cn, shape), x)
        np.testing.assert_allclose(
            jnp.mean(x2, axis=cn.red_axis).ravel(), jnp.mean(x, axis=dims).ravel(),
            rtol=1e-6)
        assert cn.red_size * cn.kept_size == int(np.prod(shape))


class TestMajorKernelParity:
    """slim_update_major / slim_precond_major vs the (transposed) minor oracle."""

    SHAPES = [(16, 128), (100, 300), (257, 129), (8, 1024), (1024, 8)]

    @pytest.mark.parametrize("shape", SHAPES)
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_slim_update_major_allclose(self, shape, dtype):
        ks = jax.random.split(jax.random.PRNGKey(shape[0]), 4)
        p = jax.random.normal(ks[0], shape).astype(dtype)
        g = (jax.random.normal(ks[1], shape) * 0.1).astype(dtype)
        m = jax.random.normal(ks[2], shape) * 0.01
        v = jnp.abs(jax.random.normal(ks[3], (1, shape[1]))) * 1e-3
        kw = dict(lr=1e-3, b1=0.9, b2=0.95, eps=1e-8, wd=0.1, count=3)
        out_k = slim_update_major(p, g, m, v, **kw)
        out_r = tuple(t.T for t in slim_update_ref(p.T, g.T, m.T, v.T, **kw))
        tol = 2e-2 if dtype == jnp.bfloat16 else 1e-5
        for a, b, name in zip(out_k, out_r, ("p", "m", "v")):
            np.testing.assert_allclose(np.asarray(a, np.float32), np.asarray(b, np.float32),
                                       atol=tol, rtol=tol, err_msg=name)

    @pytest.mark.parametrize("shape", SHAPES)
    def test_precond_major_matches_minor_on_transpose(self, shape):
        """Both orientations implement the same math: major(g) == minor(g.T).T."""
        ks = jax.random.split(jax.random.PRNGKey(7), 3)
        g = jax.random.normal(ks[0], shape) * 0.1
        m = jax.random.normal(ks[1], shape) * 0.01
        v = jnp.abs(jax.random.normal(ks[2], (1, shape[1]))) * 1e-3
        kw = dict(b1=0.9, b2=0.95, eps=1e-8, count=4)
        u_maj, m_maj, v_maj = slim_precond_major(g, m, v, **kw)
        u_min, m_min, v_min = slim_precond(g.T, m.T, v.T, **kw)
        np.testing.assert_allclose(u_maj, u_min.T, **TOL)
        np.testing.assert_allclose(m_maj, m_min.T, **TOL)
        np.testing.assert_allclose(v_maj, v_min.T, **TOL)

    def test_col_strip_tiling_vmem_bound(self):
        """A tall reduced dim must shrink the column strip, not overflow."""
        from repro.kernels.tiling import VMEM_BUDGET, fit_strip_block
        tall = 300_000  # a (300k, tc) strip: tc must shrink to fit
        tc = fit_strip_block(tall, 256, 512, 5)
        assert 1 <= tc < 256
        assert tall * 4 * 5 * tc <= VMEM_BUDGET   # strip working set fits
        assert fit_strip_block(16, 256, 512, 5) == 256  # small stays at block


class TestMajorBackendParity:
    """Fused backend == jnp over specs where the *major* orientation serves,
    incl. those only major reaches by pure reshape."""

    SPECS = [
        ((12, 8), (0,)),             # fan_out: only major is reshape-reachable
        ((257, 129), (0,)),          # padding path through the major kernel
        ((3, 3, 8, 16), (0, 1, 2)),  # conv fan_in: leading multi-dim K
        ((6, 1, 10), (0, 1)),        # size-1 kept axis interleaved
        ((64, 32, 4), (0,)),         # 3-D leading single dim
    ]

    @pytest.mark.parametrize("shape,dims", SPECS)
    def test_leaf_spec_parity(self, shape, dims):
        assert canon2d(shape, dims).orientation == "major"
        params = {"w": jax.random.normal(jax.random.PRNGKey(2), shape)}
        tx_j = scale_by_slim_adam({"w": dims})
        tx_f = scale_by_slim_adam({"w": dims}, backend="fused")
        sj, sf = tx_j.init(params), tx_f.init(params)
        assert jax.tree.leaves(sj.nu)[0].shape == jax.tree.leaves(sf.nu)[0].shape
        for i in range(2):
            g = _grads(params, i)
            uj, sj = jax.jit(tx_j.update)(g, sj)
            uf, sf = jax.jit(tx_f.update)(g, sf)
        _tree_allclose(uj, uf)
        _tree_allclose(sj.nu, sf.nu)

    def test_mixed_orientation_tree(self):
        """fan_in (minor), fan_out (major), and interleaved (transpose
        fallback) leaves in one tree, multi-step."""
        key = jax.random.PRNGKey(0)
        params = {
            "fi": jax.random.normal(key, (24, 16)),
            "fo": jax.random.normal(key, (24, 16)),
            "conv": jax.random.normal(key, (3, 3, 8, 16)),
            "interleaved": jax.random.normal(key, (4, 6, 10)),
        }
        dims = {"fi": (1,), "fo": (0,), "conv": (0, 1, 2), "interleaved": (0, 2)}
        tx_j = scale_by_slim_adam(dims)
        tx_f = scale_by_slim_adam(dims, backend="fused")
        sj, sf = tx_j.init(params), tx_f.init(params)
        for i in range(3):
            g = _grads(params, i)
            uj, sj = jax.jit(tx_j.update)(g, sj)
            uf, sf = jax.jit(tx_f.update)(g, sf)
        _tree_allclose(uj, uf)
        _tree_allclose(sj.nu, sf.nu)


class TestSNRMajorParity:
    @pytest.mark.parametrize("shape,dims", [
        ((37, 130), (0,)),          # major orientation, transpose-free
        ((130, 37), (0,)),
        ((5, 8, 12), (0, 1)),       # leading multi-dim K
    ])
    def test_snr_backend_parity(self, shape, dims):
        from repro.core.snr import snr_along_dims
        assert canon2d(shape, dims).orientation == "major"
        v = jnp.abs(jax.random.normal(jax.random.PRNGKey(7), shape)) + 0.1
        a = float(snr_along_dims(v, dims))
        b = float(snr_along_dims(v, dims, backend="fused"))
        np.testing.assert_allclose(a, b, rtol=1e-4)

    def test_high_snr_near_constant_cols(self):
        """The centered major kernel must track the two-pass jnp value in the
        high-SNR regime (naive one-pass cancels catastrophically)."""
        from repro.core.snr import snr_along_dims
        noise = jax.random.normal(jax.random.PRNGKey(8), (256, 16)) * 1e-5
        v = 1.0 + noise  # mean ~1, var ~1e-10 -> SNR ~1e10
        a = float(snr_along_dims(v, (0,)))
        b = float(snr_along_dims(v, (0,), backend="fused"))
        assert a > 1e8
        np.testing.assert_allclose(a, b, rtol=1e-2)

    def test_centered_stats_major_oracle(self):
        from repro.kernels.snr_stats import snr_stats_centered_major
        from repro.kernels.ref import snr_from_centered_stats
        v = jnp.abs(jax.random.normal(jax.random.PRNGKey(9), (100, 300))) + 0.1
        s1, s1c, s2c = snr_stats_centered_major(v)
        np.testing.assert_allclose(s1, jnp.sum(v, axis=0), rtol=1e-5)
        d = v - v[0:1, :]
        np.testing.assert_allclose(s1c, jnp.sum(d, axis=0), rtol=1e-5, atol=1e-4)
        np.testing.assert_allclose(s2c, jnp.sum(d * d, axis=0), rtol=1e-5)
        snr = float(snr_from_centered_stats(s1, s1c, s2c, v.shape[0]))
        mean = jnp.mean(v, axis=0)
        var = jnp.var(v, axis=0)
        ref = float(jnp.mean(jnp.square(mean) / (var + 1e-30)))
        np.testing.assert_allclose(snr, ref, rtol=1e-4)


class TestGPTSmallTreeMajorRoofline:
    def test_full_tree_fused_matches_jnp_and_planner_optimal(self):
        """Acceptance: over the GPT-small tree *no* compressed leaf
        transposes — trailing K plans minor, leading K plans major, and the
        scan-stacked kept/K/kept leaves (wq/wk reducing embed) plan batched
        major — and fused == jnp to 1e-5."""
        from repro.configs import gpt_small
        from repro.core import rules_as_tree, table3_rules

        cfg = gpt_small.reduced()
        params, meta = cfg.init(jax.random.PRNGKey(0))
        dims = rules_as_tree(table3_rules(meta), params, meta)
        p_leaves, treedef = jax.tree_util.tree_flatten(params)
        d_leaves = [tuple(d) for d in treedef.flatten_up_to(dims)]
        saw_batched = False
        for p, d in zip(p_leaves, d_leaves):
            if not d:
                continue
            cn = canon2d(p.shape, d)
            assert not cn.is_transpose, (p.shape, d)
            saw_batched |= cn.batch > 1
        assert saw_batched  # the stacked wq/wk leaves exercise the batched path

        tx_j = scale_by_slim_adam(dims)
        tx_f = scale_by_slim_adam(dims, backend="fused")
        sj, sf = tx_j.init(params), tx_f.init(params)
        for i in range(2):
            g = _grads(params, i)
            uj, sj = jax.jit(tx_j.update)(g, sj)
            uf, sf = jax.jit(tx_f.update)(g, sf)
        _tree_allclose(uj, uf, rtol=1e-5, atol=1e-5)
        _tree_allclose(sj.nu, sf.nu, rtol=1e-5, atol=1e-6)

    def test_tree_bytes_fan_out_at_floor(self):
        """The opt_speed roofline must hold fan_out leaves to the same
        transpose-free 5/7 floor as fan_in (no re-layout traffic charged)."""
        from benchmarks.opt_speed import _tree_bytes

        params = {"fi": jnp.zeros((256, 128)), "fo": jnp.zeros((256, 128)),
                  "dense": jnp.zeros((64, 64))}
        dims_by_name = {"dense": (), "fi": (1,), "fo": (0,)}
        dims_leaves = [dims_by_name[k] for k in sorted(params)]  # leaf order
        dense_b, comp_b, comp_dense, tf_b, tf_dense = _tree_bytes(
            params, dims_leaves)
        # both compressed leaves are transpose-free now
        assert tf_b == comp_b and tf_dense == comp_dense
        n = 256 * 128 * 4
        # fi keeps 256 rows, fo keeps 128 cols; both at the 5-pass floor
        assert comp_b == (5 * n + 2 * 256 * 4) + (5 * n + 2 * 128 * 4)
        assert comp_dense == 2 * 7 * n
        assert dense_b == 7 * 64 * 64 * 4
